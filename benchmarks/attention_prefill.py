"""Attention prefill benchmark: tuned vs fixed-tile flash attention.

The third kernel family through the tuner-vs-fixed lens (matmul:
table1_matmul, SpMV: table2_spmv).  'fixed' is what `mha_attention` callers
ran before the engine: the hand-picked (512, 512) default block pair.
'tuned' goes through the full DSE -> (measure) -> cache path
(`autotune.tune("attention", ...)`).  Shapes are the serving prefill shapes — the
(batch*heads, prompt, prompt, head_dim) folds `launch.serve` pre-tunes at
startup — derived from real arch configs so the benchmark tracks what the
server actually runs.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

import repro.configs as configs
from repro.core import cost_model
from repro.kernels import autotune, registry
from repro.kernels.attention import kernel as attn_kernel

# (arch, serving batch, prompt length) -> the prefill fold the server tunes.
PREFILL_POINTS = [
    ("qwen3_14b", 8, 2048),
    ("qwen3_14b", 8, 8192),
    ("phi3_mini_3_8b", 16, 4096),
    ("h2o_danube_1_8b", 32, 2048),
]

FIXED_BLOCK = 512           # mha_attention's pre-engine default


def _interleaved_best_us(thunks: dict, reps: int, trials: int) -> dict:
    """Best-of-``trials`` wall time per config, measured interleaved so
    machine drift hits all configs alike (the table1 timing discipline).
    ``thunks``: {key: zero-arg callable returning a jax array}."""
    slots = {key: float("inf") for key in thunks}
    for _ in range(trials):
        for key, fn in thunks.items():
            slots[key] = min(slots[key], autotune.measure(fn, reps=reps))
    return slots


def prefill_shapes():
    out = []
    for arch, batch, prompt in PREFILL_POINTS:
        cfg = configs.get(arch)
        out.append({
            "arch": cfg.name, "batch": batch, "prompt": prompt,
            "bh": batch * cfg.num_heads, "sq": prompt, "sk": prompt,
            "dh": cfg.head_dim, "causal": cfg.causal,
            "window": cfg.sliding_window,
        })
    return out


def tuned_vs_fixed():
    """Tuner vs the fixed (512, 512) blocks on the serving prefill shapes.

    Both sides are scored by the same machine model
    (`cost_model.attention_time_model`); the tuner's candidate set contains
    the fixed pair whenever it is feasible, so ``speedup_model >= 1`` unless
    a wall-clock measurement overrode the analytic winner (then
    ``measured_us`` is the evidence, as in table1).
    """
    recs = []
    for s in prefill_shapes():
        fq = min(FIXED_BLOCK, s["sq"])
        fk = min(FIXED_BLOCK, s["sk"])
        problem = {"bh": s["bh"], "sq": s["sq"], "sk": s["sk"],
                   "dh": s["dh"], "causal": s["causal"],
                   "window": s["window"]}
        spec = registry.get("attention")
        fixed = cost_model.attention_time_model(
            s["bh"], s["sq"], s["sk"], s["dh"], fq, fk, causal=s["causal"],
            window=s["window"])
        plan = autotune.tune("attention", problem, jnp.bfloat16)
        tuned = spec.cost_fn(problem, plan.knobs)
        recs.append({
            "arch": s["arch"], "batch": s["batch"], "prompt": s["prompt"],
            "shape": [s["bh"], s["sq"], s["sk"], s["dh"]],
            "fixed_block": [fq, fk],
            "tuned_block": [plan.knobs["block_q"], plan.knobs["block_k"]],
            "tuned_source": plan.source,
            "tuned_measured_us": plan.measured_us,
            "gflops_fixed_model": fixed["gflops"],
            "gflops_tuned_model": tuned["gflops"],
            "speedup_model": fixed["time_s"] / tuned["time_s"],
        })
    return recs


def causal_skip_measured(bh: int = 2, seq: int = 1024, dh: int = 32,
                         block_q: int = 128, block_k: int = 128,
                         reps: int = 3, trials: int = 3):
    """Block-skipping vs dense execution of the causal kernel at the SAME
    (block_q, block_k) — the tentpole's perf claim, recorded two ways:

    * ``kstep_speedup``: dense grid block pairs / active block pairs
      (`cost_model.attention_active_block_pairs`) — the exact count of
      K-steps the kernel streams and multiplies, deterministic on any
      backend (>= 1.5x for >= 3 q-blocks, ~2x asymptotically at sq=sk);
    * ``wall_speedup``: interleaved best-of-``trials`` wall-clock of the
      two kernels (interpret mode off-TPU, so grid overhead dilutes it —
      the K-step count is the load-bearing number there).
    """
    interpret = jax.default_backend() != "tpu"
    scale = 1.0 / (dh ** 0.5)
    q = jax.random.normal(jax.random.PRNGKey(0), (bh, seq, dh), jnp.float32)
    k = jax.random.normal(jax.random.PRNGKey(1), (bh, seq, dh), jnp.float32)
    v = jax.random.normal(jax.random.PRNGKey(2), (bh, seq, dh), jnp.float32)

    slots = _interleaved_best_us({
        skip: (lambda skip=skip: attn_kernel.flash_attention(
            q, k, v, scale=scale, causal=True, block_q=block_q,
            block_k=block_k, interpret=interpret, block_skipping=skip))
        for skip in (True, False)}, reps, trials)

    active, total = cost_model.attention_active_block_pairs(
        seq, seq, block_q, block_k, causal=True)
    return {
        "shape": [bh, seq, seq, dh],
        "block": [block_q, block_k],
        "k_steps_dense": total,
        "k_steps_skip": active,
        "kstep_speedup": total / active,
        "skip_us": slots[True],
        "dense_us": slots[False],
        "wall_speedup": slots[False] / slots[True],
        "interpret": interpret,
    }


def decode_step_measured(b: int = 2, hq: int = 8, hkv: int = 2,
                         dh: int = 64, cache_len: int = 1024,
                         length: int | None = None,
                         reps: int = 3, trials: int = 3):
    """One fused decode-attention step: tuned block_k vs the fixed (512)
    default, wall-clocked where feasible — the decode analogue of the
    tuned-vs-fixed prefill rows.  ``length`` defaults to a ragged 3/4 of
    the cache so the tail over-fetch the tuner prices actually occurs."""
    from repro.kernels.attention import decode as attn_decode

    interpret = jax.default_backend() != "tpu"
    if length is None:
        length = cache_len * 3 // 4 + 1          # ragged on purpose
    g = hq // hkv
    problem = {"bkv": b * hkv, "g": g, "cache_len": cache_len, "dh": dh}
    plan = autotune.tune("decode", problem, jnp.float32)
    tuned_bk = plan.knobs["block_k"]
    fixed_bk = min(FIXED_BLOCK, cache_len)
    scale = 1.0 / (dh ** 0.5)
    q = jax.random.normal(jax.random.PRNGKey(0), (b * hkv, g, dh),
                          jnp.float32)
    k = jax.random.normal(jax.random.PRNGKey(1), (b * hkv, cache_len, dh),
                          jnp.float32)
    v = jax.random.normal(jax.random.PRNGKey(2), (b * hkv, cache_len, dh),
                          jnp.float32)

    slots = _interleaved_best_us({
        bk: (lambda bk=bk: attn_decode.decode_attention(
            q, k, v, scale=scale, length=length, block_k=bk,
            interpret=interpret))
        for bk in {tuned_bk, fixed_bk}}, reps, trials)

    model = registry.get("decode").cost_fn(problem, plan.knobs)
    return {
        "shape": [b * hkv, g, cache_len, dh],
        "length": length,
        "tuned_block_k": tuned_bk,
        "tuned_source": plan.source,
        "tuned_us": slots[tuned_bk],
        "fixed_block_k": fixed_bk,
        "fixed_us": slots[fixed_bk],
        "speedup_vs_fixed": slots[fixed_bk] / slots[tuned_bk],
        "model_time_us": model["time_s"] * 1e6,
        "interpret": interpret,
    }


# Declared accuracy budget for the int8 KV stream: max |attention-output
# error| of the quantized kernel vs the float oracle on the same inputs.
# tools/check_bench.py re-asserts the measured error against this budget
# (and caps the budget itself, so a report cannot fabricate a loose one).
INT8_ERR_BUDGET = 0.05


def decode_int8_measured(b: int = 2, hq: int = 8, hkv: int = 2,
                         dh: int = 64, cache_len: int = 1024,
                         length: int | None = None,
                         reps: int = 3, trials: int = 3):
    """Int8 quantized KV stream vs the bf16 stream at the same decode
    shape — the bandwidth-vs-accuracy trade the quantized family #5 is
    for, recorded two ways:

    * ``bytes_ratio``: bf16 KV bytes per token / int8+scale bytes per
      token (``quantize.bytes_per_token``) — the exact per-token stream
      the kernel fetches, deterministic on any backend (2*dh/(dh+4),
      >= 1.6x for dh >= 16, ~2x asymptotically);
    * ``tuned_us`` vs ``bf16_us``: interleaved best-of-``trials``
      wall-clock of the int8 kernel at its tuned block against the float
      decode kernel streaming a bf16 cache (interpret mode off-TPU, so
      dequant overhead dominates — the byte count is the load-bearing
      number there).

    ``max_abs_err`` is the quantized kernel's output error against the
    float-cache oracle on the same pre-quantization values; it must land
    under the declared ``err_budget`` (gated in tools/check_bench.py).
    """
    from repro.kernels.attention import decode as attn_decode
    from repro.kernels.attention import decode_int8 as attn_decode_int8
    from repro.runtime import quantize

    interpret = jax.default_backend() != "tpu"
    if length is None:
        length = cache_len * 3 // 4 + 1          # ragged on purpose
    g = hq // hkv
    problem = {"bkv": b * hkv, "g": g, "cache_len": cache_len, "dh": dh}
    plan = autotune.tune("decode_int8", problem, jnp.bfloat16)
    tuned_bk = plan.knobs["block_k"]
    scale = 1.0 / (dh ** 0.5)
    q = jax.random.normal(jax.random.PRNGKey(0), (b * hkv, g, dh),
                          jnp.float32)
    k = jax.random.normal(jax.random.PRNGKey(1), (b * hkv, cache_len, dh),
                          jnp.float32)
    v = jax.random.normal(jax.random.PRNGKey(2), (b * hkv, cache_len, dh),
                          jnp.float32)
    kq, ks = quantize.quantize_rows(k)
    vq, vs = quantize.quantize_rows(v)
    kb, vb = k.astype(jnp.bfloat16), v.astype(jnp.bfloat16)

    slots = _interleaved_best_us({
        "int8": lambda: attn_decode_int8.quantized_decode_attention(
            q, kq, ks, vq, vs, scale=scale, length=length,
            block_k=tuned_bk, interpret=interpret),
        "bf16": lambda: attn_decode.decode_attention(
            q.astype(jnp.bfloat16), kb, vb, scale=scale, length=length,
            block_k=tuned_bk, interpret=interpret),
    }, reps, trials)

    # Accuracy of the shipped kernel path against the float oracle on the
    # ORIGINAL (pre-quantization) values — this is the quantization error
    # plus any kernel-numerics error, i.e. what serving actually eats.
    out_q = attn_decode_int8.quantized_decode_attention(
        q, kq, ks, vq, vs, scale=scale, length=length, block_k=tuned_bk,
        interpret=interpret)
    out_f = attn_decode.decode_ref(
        q, k[:, :, None, :], v[:, :, None, :], length=length, scale=scale)
    max_abs_err = float(jnp.max(jnp.abs(
        out_q.astype(jnp.float32) - out_f.astype(jnp.float32))))

    bpt_int8 = quantize.bytes_per_token(dh)
    bpt_bf16 = 2 * dh * 2                        # K + V rows at 2 B/elem
    model = registry.get("decode_int8").cost_fn(problem, plan.knobs)
    return {
        "shape": [b * hkv, g, cache_len, dh],
        "length": length,
        "tuned_block_k": tuned_bk,
        "tuned_source": plan.source,
        "tuned_us": slots["int8"],
        "bf16_us": slots["bf16"],
        "bytes_per_token_int8": bpt_int8,
        "bytes_per_token_bf16": bpt_bf16,
        "bytes_ratio": bpt_bf16 / bpt_int8,
        "max_abs_err": max_abs_err,
        "err_budget": INT8_ERR_BUDGET,
        "model_time_us": model["time_s"] * 1e6,
        "interpret": interpret,
    }


def decode_ragged_measured(b: int = 4, hq: int = 4, hkv: int = 2,
                           dh: int = 32, cache_len: int = 256,
                           block_k: int = 64,
                           reps: int = 3, trials: int = 3):
    """Ragged per-slot lengths vs the shared-scalar broadcast through the
    SAME fused decode kernel — the continuous-batching perf claim,
    recorded two ways:

    * ``fetched_speedup``: K/V blocks streamed under the batch-max
      broadcast / blocks streamed with per-row lengths
      (`cost_model.decode_time_model`'s active-prefix accounting) — the
      exact per-row block count the kernel's scalar-prefetch skip
      executes, deterministic on any backend;
    * ``wall_speedup``: interleaved best-of-``trials`` wall-clock of the
      two calls (interpret mode off-TPU dilutes it with grid overhead —
      the block count is the load-bearing number there).

    The ragged lengths are the staggered steady state of a continuous
    batch: slot i at depth ~(2i+1)/(2b) of the cache.
    """
    from repro.kernels.attention import decode as attn_decode

    interpret = jax.default_backend() != "tpu"
    g = hq // hkv
    lengths = [max(1, ((2 * i + 1) * cache_len) // (2 * b))
               for i in range(b)]
    scale = 1.0 / (dh ** 0.5)
    q = jax.random.normal(jax.random.PRNGKey(0), (b, hq, dh), jnp.float32)
    k = jax.random.normal(jax.random.PRNGKey(1), (b, cache_len, hkv, dh),
                          jnp.float32)
    v = jax.random.normal(jax.random.PRNGKey(2), (b, cache_len, hkv, dh),
                          jnp.float32)
    len_vec = jnp.asarray(lengths, jnp.int32)

    slots = _interleaved_best_us({
        key: (lambda length=length: attn_decode.gqa_decode_attention(
            q, k, v, scale=scale, length=length, block_k=block_k,
            interpret=interpret))
        for key, length in (("ragged", len_vec), ("broadcast", cache_len))},
        reps, trials)

    problem = {"bkv": b * hkv, "g": g, "cache_len": cache_len, "dh": dh}
    ragged = cost_model.decode_time_model(
        problem["bkv"], g, cache_len, dh, block_k, lengths=lengths)
    broadcast = cost_model.decode_time_model(
        problem["bkv"], g, cache_len, dh, block_k)
    return {
        "shape": [b, hq, hkv, cache_len, dh],
        "lengths": lengths,
        "block_k": block_k,
        "fetched_ragged": ragged["fetched_k"],
        "fetched_broadcast": broadcast["fetched_k"],
        "fetched_speedup": broadcast["fetched_k"] / ragged["fetched_k"],
        "model_speedup": broadcast["time_s"] / ragged["time_s"],
        "ragged_us": slots["ragged"],
        "broadcast_us": slots["broadcast"],
        "wall_speedup": slots["broadcast"] / slots["ragged"],
        "interpret": interpret,
    }


def tuned_vs_fixed_measured(bh: int = 4, seq: int = 256, dh: int = 32,
                            reps: int = 3, trials: int = 3):
    """Wall-clock tuned-vs-fixed at a size where CPU interpret timing is
    feasible; on TPU this measures the real kernel at the same size.
    Interleaved best-of-``trials`` timing, one slot per distinct block pair
    (same discipline as table1_matmul.tuned_vs_fixed_measured)."""
    interpret = jax.default_backend() != "tpu"
    plan = autotune.tune("attention", {"bh": bh, "sq": seq, "sk": seq,
                                       "dh": dh, "causal": True,
                                       "window": None}, jnp.float32)
    tuned = (plan.knobs["block_q"], plan.knobs["block_k"])
    fixed = (min(FIXED_BLOCK, seq), min(FIXED_BLOCK, seq))
    scale = 1.0 / (dh ** 0.5)
    q = jax.random.normal(jax.random.PRNGKey(0), (bh, seq, dh), jnp.float32)
    k = jax.random.normal(jax.random.PRNGKey(1), (bh, seq, dh), jnp.float32)
    v = jax.random.normal(jax.random.PRNGKey(2), (bh, seq, dh), jnp.float32)

    slots = _interleaved_best_us({
        (bq, bk): (lambda bq=bq, bk=bk: attn_kernel.flash_attention(
            q, k, v, scale=scale, causal=True, block_q=bq, block_k=bk,
            interpret=interpret))
        for (bq, bk) in {tuned, fixed}}, reps, trials)

    tuned_us = slots[tuned]
    return {
        "shape": [bh, seq, seq, dh],
        "tuned_block": list(tuned),
        "tuned_source": plan.source,
        "tuned_us": tuned_us,
        "fixed_block": list(fixed),
        "fixed_us": slots[fixed],
        "speedup_vs_fixed": slots[fixed] / tuned_us,
        "interpret": interpret,
    }


def main(tuned_recs=None, measured_rec=None, skip_rec=None, decode_rec=None,
         ragged_rec=None, int8_rec=None):
    lines = []
    for r in (tuned_recs if tuned_recs is not None else tuned_vs_fixed()):
        bh, sq, sk, dh = r["shape"]
        lines.append(
            f"attn.tuned_{r['arch']}_b{r['batch']}_p{r['prompt']},0.0,"
            f"speedup_model={r['speedup_model']:.3f};"
            f"block={r['tuned_block'][0]}/{r['tuned_block'][1]};"
            f"src={r['tuned_source']}")
    m = measured_rec if measured_rec is not None else tuned_vs_fixed_measured()
    lines.append(
        f"attn.measured_bh{m['shape'][0]}_s{m['shape'][1]},"
        f"{m['tuned_us']:.1f},"
        f"speedup_vs_fixed={m['speedup_vs_fixed']:.3f};"
        f"block={m['tuned_block'][0]}/{m['tuned_block'][1]}")
    s = skip_rec if skip_rec is not None else causal_skip_measured()
    lines.append(
        f"attn.causal_skip_s{s['shape'][1]},{s['skip_us']:.1f},"
        f"kstep_speedup={s['kstep_speedup']:.3f};"
        f"wall_speedup={s['wall_speedup']:.3f};"
        f"block={s['block'][0]}/{s['block'][1]}")
    d = decode_rec if decode_rec is not None else decode_step_measured()
    lines.append(
        f"attn.decode_bkv{d['shape'][0]}_l{d['shape'][2]},"
        f"{d['tuned_us']:.1f},"
        f"speedup_vs_fixed={d['speedup_vs_fixed']:.3f};"
        f"block_k={d['tuned_block_k']};src={d['tuned_source']}")
    rg = ragged_rec if ragged_rec is not None else decode_ragged_measured()
    lines.append(
        f"attn.decode_ragged_b{rg['shape'][0]}_l{rg['shape'][3]},"
        f"{rg['ragged_us']:.1f},"
        f"fetched_speedup={rg['fetched_speedup']:.3f};"
        f"wall_speedup={rg['wall_speedup']:.3f};"
        f"block_k={rg['block_k']}")
    q8 = int8_rec if int8_rec is not None else decode_int8_measured()
    lines.append(
        f"attn.decode_int8_bkv{q8['shape'][0]}_l{q8['shape'][2]},"
        f"{q8['tuned_us']:.1f},"
        f"bytes_ratio={q8['bytes_ratio']:.3f};"
        f"max_abs_err={q8['max_abs_err']:.4f};"
        f"block_k={q8['tuned_block_k']};src={q8['tuned_source']}")
    return lines


if __name__ == "__main__":
    print("\n".join(main()))
