"""Table II analogue: sparse matrix-vector multiplication across the paper's
four test matrices (synthesized to their published NNZ / M / NNZ-per-column
statistics), HW-vs-baseline ratio, and the load-balance measurement.

Paper columns: NNZ, M, NNZ/col range, ARM exec, HW exec, ratio.  Ours: same
matrix stats; "ARM" = jnp dense matvec baseline; "HW" = the balanced-ELL
SpMV path; plus the paper's §V-B balance stat (fraction of nnz per worker,
round-robin vs LPT) and the TPU-adaptation metric (ELL padding waste).
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import loadbalance
from repro.kernels import autotune
from repro.kernels.spmv import pack_csr, spmv

# Published stats: name -> (NNZ, M(rows), nnz_per_col_range)
MATRICES = {
    "Maragal_2": (4_357, 555, (0, 139)),
    "flower_5_4": (43_942, 5_226, (1, 3)),
    "BIBD_14_7": (72_072, 91, (21, 21)),
    "LD_pilot87": (74_949, 2_030, (1, 96)),
}


def synthesize(name: str, seed: int = 0):
    """Random matrix matching (NNZ, M, nnz-per-row range) of the original."""
    nnz, m, (lo, hi) = MATRICES[name]
    rng = np.random.default_rng(seed + hash(name) % 1000)
    if lo == hi:
        per_row = np.full(m, nnz // m)
    else:
        raw = rng.integers(max(lo, 0), hi + 1, size=m).astype(np.float64)
        per_row = np.maximum((raw / raw.sum() * nnz).astype(int), 0)
    n_cols = max(int(per_row.max()) + 1, 128)
    indptr = np.concatenate([[0], np.cumsum(per_row)]).astype(np.int32)
    indices = np.concatenate([
        rng.choice(n_cols, size=c, replace=False) for c in per_row
    ]).astype(np.int32)
    data = rng.standard_normal(indptr[-1]).astype(np.float32)
    return indptr, indices, data, (m, n_cols)


def bench_one(name: str, reps: int = 5):
    indptr, indices, data, shape = synthesize(name)
    m, n = shape
    x = np.random.default_rng(1).standard_normal(n).astype(np.float32)

    # "ARM baseline": dense matvec
    dense = np.zeros(shape, np.float32)
    for r in range(m):
        dense[r, indices[indptr[r]:indptr[r + 1]]] = \
            data[indptr[r]:indptr[r + 1]]
    dense_j = jnp.asarray(dense)
    xj = jnp.asarray(x)
    base_fn = jax.jit(lambda A, v: A @ v)
    base_fn(dense_j, xj).block_until_ready()
    t0 = time.perf_counter()
    for _ in range(reps):
        y_base = base_fn(dense_j, xj).block_until_ready()
    base_us = (time.perf_counter() - t0) / reps * 1e6

    # "HW": balanced-ELL SpMV (oracle path times the same math the kernel
    # does; kernel itself is validated in tests via interpret mode)
    mat = pack_csr(indptr, indices, data, shape, scheme="round_robin")
    spmv(mat, xj, use_kernel=False).block_until_ready()
    t0 = time.perf_counter()
    for _ in range(reps):
        y_hw = spmv(mat, xj, use_kernel=False).block_until_ready()
    hw_us = (time.perf_counter() - t0) / reps * 1e6
    err = float(jnp.max(jnp.abs(y_hw - y_base)))

    # paper's balance stat for 4 workers
    _, rr = loadbalance.nnz_balanced_row_order(indptr, 4)
    _, greedy = loadbalance.nnz_balanced_row_order(indptr, 4, "lpt")

    # Machine-model HW/baseline ratio at TARGET bandwidth (the paper's
    # HW/ARM column): sparse traffic (vals+cols, sliced-ELL with the
    # sorted packing law) vs dense matvec traffic, both bandwidth-bound.
    sorted_mat = pack_csr(indptr, indices, data, shape, scheme="sorted")
    sliced = {
        "round_robin": mat.sliced_waste(),
        "sorted": sorted_mat.sliced_waste(),
    }
    sparse_bytes = int(indptr[-1]) * sliced["sorted"] * 8
    dense_bytes = m * n * 4
    ratio_model = dense_bytes / max(sparse_bytes, 1)

    return {
        "name": name,
        "nnz": int(indptr[-1]), "m": m,
        "base_us": base_us, "hw_us": hw_us,
        "ratio_model": ratio_model,
        "rr_max_frac": rr.max_fraction,
        "lpt_max_frac": greedy.max_fraction,
        "ell_waste": mat.padding_waste,
        "sliced_rr": sliced["round_robin"],
        "sliced_sorted": sliced["sorted"],
        "err": err,
    }


def tuned_records(check_blocked_on: str = "Maragal_2"):
    """Autotuner plans for the Table-2 matrices (JSON rows for run.py).

    The tuner ranks (block_rows, block_cols) with the bandwidth model fed
    by the active/fetched balance metric; small matrices additionally get
    measured (interpret on CPU).  For ``check_blocked_on`` the blocked-x
    kernel is executed and compared against the ELL oracle — the
    correctness half of the acceptance bar (the large-n half lives in
    tests/test_autotune.py with a forced small VMEM budget).
    """
    recs = []
    for name in MATRICES:
        indptr, indices, data, shape = synthesize(name)
        mat = pack_csr(indptr, indices, data, shape, scheme="sorted")
        plan = autotune.tune("spmv", {"mat": mat},
                             max_measure_elems=1 << 18)
        rec = {
            "matrix": name, "shape": list(shape), "nnz": mat.nnz,
            "block_rows": plan.knobs["block_rows"],
            "block_cols": plan.knobs["block_cols"],
            "source": plan.source, "waste": plan.detail.get("waste"),
            "model_time_us": plan.model_time_us,
            "measured_us": plan.measured_us,
        }
        if name == check_blocked_on:
            n = shape[1]
            x = jnp.asarray(
                np.random.default_rng(2).standard_normal(n), jnp.float32)
            y_blk = spmv(mat, x, block_rows=plan.knobs["block_rows"],
                         block_cols=max(128, (n // 2) // 128 * 128),
                         interpret=True)
            y_ref = spmv(mat, x, use_kernel=False)
            rec["blocked_vs_ref_err"] = float(jnp.max(jnp.abs(y_blk - y_ref)))
        recs.append(rec)
    return recs


def main():
    lines = []
    for name in MATRICES:
        r = bench_one(name)
        lines.append(
            f"table2.{r['name']},{r['hw_us']:.1f},"
            f"base_us={r['base_us']:.1f};ratio_model={r['ratio_model']:.2f};"
            f"rr_frac={r['rr_max_frac']:.3f};lpt_frac={r['lpt_max_frac']:.3f};"
            f"sliced_rr={r['sliced_rr']:.2f};"
            f"sliced_sorted={r['sliced_sorted']:.2f};err={r['err']:.2e}")
    return lines


if __name__ == "__main__":
    print("\n".join(main()))
