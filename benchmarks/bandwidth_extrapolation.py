"""§V-B extrapolation analogue: SpMV efficiency vs memory bandwidth.

The paper extrapolates its SpMV across memory bandwidths and reports
efficiency (sustained / bandwidth-ideal) of 44-66%, vs 80-90% for designs
whose models idealize memory ([26], [27]).  The efficiency loss is random
access overfetch: a DRAM hit for x[col] drags a whole cacheline.  We model
three designs at each bandwidth:

  ideal        — every byte useful (the [27]-style theoretical bound)
  cacheline    — the paper's DMA cacheline buffer: partial reuse of the
                 fetched line (hit-rate h = 0.5 on their matrix mix)
  vmem_x       — OUR TPU adaptation: x resident in VMEM, gathers never
                 touch HBM; only the (padded) ELL stream is read

Sustained GFLOP/s = 2 flops/nnz / (bytes-per-nnz / BW).
"""

from __future__ import annotations

CACHELINE = 64.0
VAL_IDX = 8.0          # 4B value + 4B column index per nnz
ELL_PAD = 1.3          # measured padding factor on the Table-II mix (LPT)


def bytes_per_nnz(design: str) -> float:
    if design == "ideal":
        return VAL_IDX + 4.0                     # + one useful x byte word
    if design == "cacheline":
        return VAL_IDX + 0.5 * CACHELINE         # half the line wasted
    if design == "vmem_x":
        return VAL_IDX * ELL_PAD                 # x gathers stay in VMEM
    raise ValueError(design)


def main():
    lines = []
    ideal = bytes_per_nnz("ideal")
    for bw_gbs in (1.6, 3.2, 6.4, 12.8, 25.6, 819.0):
        for design in ("ideal", "cacheline", "vmem_x"):
            bpn = bytes_per_nnz(design)
            gflops = 2.0 / bpn * bw_gbs
            eff = ideal / bpn
            lines.append(
                f"bandwidth.spmv_{design}_at_{bw_gbs:g}GBs,0.0,"
                f"gflops={gflops:.2f};eff_vs_ideal={eff:.2f}")
    return lines


if __name__ == "__main__":
    print("\n".join(main()))
