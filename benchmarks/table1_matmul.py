"""Table I analogue: blocked dense matmul efficiency vs configuration.

The paper's Table I sweeps the many-core configuration (16 vs 32 cores,
local-memory size) and reports cycles + GFLOPs + efficiency (measured/peak)
from their SystemC machine model.  Here the configuration axis is the VMEM
tile plan; efficiency comes from the same style of analytical machine model
(`core.cost_model.matmul_time_model`), and the kernel itself is additionally
executed (interpret mode, small sizes) to verify the plan is real.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import cost_model, dse, tiling
from repro.core.hardware import TPU_V5E
from repro.kernels import autotune, registry
from repro.kernels.matmul import matmul
from repro.kernels.matmul.ref import matmul_ref

# The paper's Table-I problem sizes (scaled to the TPU regime): the shapes
# the acceptance bar compares tuned-vs-fixed on.
TABLE1_SHAPES = [(4096, 4096, 4096), (8192, 8192, 8192),
                 (16384, 16384, 16384), (8192, 2048, 8192)]


def rows():
    out = []
    # Configuration sweep: the paper's {16 cores/32KB, 32 cores/16KB} becomes
    # {VMEM budget} x {problem size}; eq.2 picks the tile.  Small budgets
    # reproduce the paper's regime where the memory term eats into
    # efficiency (their 84-86%); VMEM-scale budgets saturate compute.
    for vmem_mb, n in [(0.25, 4096), (0.5, 4096), (1, 4096), (2, 4096),
                       (8, 8192), (32, 4096), (64, 8192), (96, 8192),
                       (96, 16384)]:
        t = tiling.solve_tpu(vmem_bytes=int(vmem_mb * 2**20), m=n, n=n, k=n)
        res = cost_model.matmul_time_model(n, n, n, t)
        out.append({
            "name": f"matmul_n{n}_vmem{vmem_mb}MB",
            "tile": f"y{t.y}/x{t.x}/z{t.z}",
            "gflops_model": res["gflops"],
            "efficiency": res["efficiency"],
            "time_model_s": res["time_s"],
        })
    # DSE-autotuned point (paper flow, automated)
    t = dse.autotune_matmul_tile(8192, 8192, 8192)
    res = cost_model.matmul_time_model(8192, 8192, 8192, t)
    out.append({
        "name": "matmul_n8192_dse",
        "tile": f"y{t.y}/x{t.x}/z{t.z}",
        "gflops_model": res["gflops"],
        "efficiency": res["efficiency"],
        "time_model_s": res["time_s"],
    })
    return out


def tuned_vs_fixed():
    """Autotuner vs the fixed eq.2 tile on the Table-1 shapes.

    'fixed' is what blocked_matmul callers used before the engine: the
    closed-form eq.2/solve_tpu tile.  'tuned' goes through the full
    DSE -> (measure) -> cache path.  Both are scored by the same machine
    model.  When the plan was selected analytically the tuner's candidate
    set contains the eq.2 seed, so speedup_model >= 1 by construction; a
    wall-clock-selected plan (source='measured', possible on TPU where the
    Table-1 shapes are measurable) may trade model time for real time —
    then measured_us, not speedup_model, is the evidence.
    """
    recs = []
    for m, n, k in TABLE1_SHAPES:
        fixed = tiling.solve_tpu(m=m, n=n, k=k)
        fixed_res = cost_model.matmul_time_model(m, n, k, fixed)
        problem = {"m": m, "n": n, "k": k}
        plan = autotune.tune("matmul", problem, jnp.bfloat16)
        tuned_res = registry.get("matmul").cost_fn(problem, plan.knobs)
        recs.append({
            "shape": [m, n, k],
            "fixed_tile": [fixed.y, fixed.x, fixed.z],
            "tuned_tile": list(plan.knobs["tile"]),
            "tuned_source": plan.source,
            "tuned_measured_us": plan.measured_us,
            "gflops_fixed_model": fixed_res["gflops"],
            "gflops_tuned_model": tuned_res["gflops"],
            "speedup_model": fixed_res["time_s"] / tuned_res["time_s"],
        })
    return recs


def tuned_vs_fixed_measured(size: int = 256, reps: int = 6, trials: int = 3):
    """Wall-clock comparison at a size where CPU interpret timing is
    feasible; on TPU this measures the real kernels at the same size.

    Two baselines, both real pre-engine callers: 'mxu' is the hardcoded
    128^3 tile the tests/benchmarks executed, 'eq2' is what ``tile=None``
    callers got from the closed-form law (clamped to the problem, so at
    small sizes it may coincide with the tuned tile — then its speedup is
    honestly ~1).  Interpret-mode timing is noisy, so take the best of
    ``trials`` alternating measurements per config."""
    m = n = k = size
    key = jax.random.PRNGKey(0)
    a = jax.random.normal(key, (m, k), jnp.float32)
    b = jax.random.normal(jax.random.PRNGKey(1), (k, n), jnp.float32)
    interpret = jax.default_backend() != "tpu"
    plan = autotune.tune("matmul", {"m": m, "n": n, "k": k}, jnp.float32)
    tuned_tile = tiling.Tile(*plan.knobs["tile"])
    from repro.kernels.matmul.ops import clamp_tile
    baselines = {
        "mxu": tiling.Tile(128, 128, 128),
        "eq2": clamp_tile(tiling.solve_tpu(m=m, n=n, k=k,
                                           dtype_bytes=4), m, n, k),
    }

    # One timing slot per distinct tile (a baseline identical to the tuned
    # tile shares its number — two measurements of the same jitted call
    # would otherwise report drift as speedup), measured interleaved so
    # machine drift hits all configs alike.
    slots = {tuned_tile: float("inf")}
    for t in baselines.values():
        slots.setdefault(t, float("inf"))
    for _ in range(trials):
        for t in slots:
            slots[t] = min(slots[t], autotune.measure(
                lambda t=t: matmul(a, b, tile=t, interpret=interpret,
                                   use_kernel=True), reps=reps))

    tuned_us = slots[tuned_tile]
    out = {
        "shape": [m, n, k],
        "tuned_tile": [tuned_tile.y, tuned_tile.x, tuned_tile.z],
        "tuned_source": plan.source,
        "tuned_us": tuned_us,
        "interpret": interpret,
    }
    for name, t in baselines.items():
        out[f"{name}_tile"] = [t.y, t.x, t.z]
        out[f"{name}_us"] = slots[t]
        out[f"speedup_vs_{name}"] = slots[t] / tuned_us
    return out


def kernel_check(reps: int = 3):
    """Execute the kernel (interpret) and the oracle; report us/call + error."""
    key = jax.random.PRNGKey(0)
    a = jax.random.normal(key, (256, 256), jnp.float32)
    b = jax.random.normal(key, (256, 256), jnp.float32)
    t = tiling.Tile(128, 128, 128)
    out = matmul(a, b, tile=t, interpret=True)
    err = float(jnp.max(jnp.abs(out - matmul_ref(a, b))))
    ref_fn = jax.jit(lambda a, b: matmul_ref(a, b))
    ref_fn(a, b).block_until_ready()
    t0 = time.perf_counter()
    for _ in range(reps):
        ref_fn(a, b).block_until_ready()
    us = (time.perf_counter() - t0) / reps * 1e6
    return {"name": "matmul_kernel_check_256", "us_per_call": us,
            "max_err": err}


def main(tuned_recs=None):
    lines = []
    for r in rows():
        lines.append(
            f"table1.{r['name']},{r['time_model_s'] * 1e6:.1f},"
            f"eff={r['efficiency']:.3f};gflops={r['gflops_model']:.0f};"
            f"tile={r['tile']}")
    for r in (tuned_recs if tuned_recs is not None else tuned_vs_fixed()):
        m, n, k = r["shape"]
        lines.append(
            f"table1.tuned_m{m}n{n}k{k},0.0,"
            f"speedup_model={r['speedup_model']:.3f};"
            f"tile={'/'.join(map(str, r['tuned_tile']))};"
            f"src={r['tuned_source']}")
    kc = kernel_check()
    lines.append(f"table1.{kc['name']},{kc['us_per_call']:.1f},"
                 f"max_err={kc['max_err']:.2e}")
    return lines


if __name__ == "__main__":
    print("\n".join(main()))
