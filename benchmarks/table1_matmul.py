"""Table I analogue: blocked dense matmul efficiency vs configuration.

The paper's Table I sweeps the many-core configuration (16 vs 32 cores,
local-memory size) and reports cycles + GFLOPs + efficiency (measured/peak)
from their SystemC machine model.  Here the configuration axis is the VMEM
tile plan; efficiency comes from the same style of analytical machine model
(`core.cost_model.matmul_time_model`), and the kernel itself is additionally
executed (interpret mode, small sizes) to verify the plan is real.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import cost_model, dse, tiling
from repro.core.hardware import TPU_V5E
from repro.kernels.matmul import matmul
from repro.kernels.matmul.ref import matmul_ref


def rows():
    out = []
    # Configuration sweep: the paper's {16 cores/32KB, 32 cores/16KB} becomes
    # {VMEM budget} x {problem size}; eq.2 picks the tile.  Small budgets
    # reproduce the paper's regime where the memory term eats into
    # efficiency (their 84-86%); VMEM-scale budgets saturate compute.
    for vmem_mb, n in [(0.25, 4096), (0.5, 4096), (1, 4096), (2, 4096),
                       (8, 8192), (32, 4096), (64, 8192), (96, 8192),
                       (96, 16384)]:
        t = tiling.solve_tpu(vmem_bytes=int(vmem_mb * 2**20), m=n, n=n, k=n)
        res = cost_model.matmul_time_model(n, n, n, t)
        out.append({
            "name": f"matmul_n{n}_vmem{vmem_mb}MB",
            "tile": f"y{t.y}/x{t.x}/z{t.z}",
            "gflops_model": res["gflops"],
            "efficiency": res["efficiency"],
            "time_model_s": res["time_s"],
        })
    # DSE-autotuned point (paper flow, automated)
    t = dse.autotune_matmul_tile(8192, 8192, 8192)
    res = cost_model.matmul_time_model(8192, 8192, 8192, t)
    out.append({
        "name": "matmul_n8192_dse",
        "tile": f"y{t.y}/x{t.x}/z{t.z}",
        "gflops_model": res["gflops"],
        "efficiency": res["efficiency"],
        "time_model_s": res["time_s"],
    })
    return out


def kernel_check(reps: int = 3):
    """Execute the kernel (interpret) and the oracle; report us/call + error."""
    key = jax.random.PRNGKey(0)
    a = jax.random.normal(key, (256, 256), jnp.float32)
    b = jax.random.normal(key, (256, 256), jnp.float32)
    t = tiling.Tile(128, 128, 128)
    out = matmul(a, b, tile=t, interpret=True)
    err = float(jnp.max(jnp.abs(out - matmul_ref(a, b))))
    ref_fn = jax.jit(lambda a, b: matmul_ref(a, b))
    ref_fn(a, b).block_until_ready()
    t0 = time.perf_counter()
    for _ in range(reps):
        ref_fn(a, b).block_until_ready()
    us = (time.perf_counter() - t0) / reps * 1e6
    return {"name": "matmul_kernel_check_256", "us_per_call": us,
            "max_err": err}


def main():
    lines = []
    for r in rows():
        lines.append(
            f"table1.{r['name']},{r['time_model_s'] * 1e6:.1f},"
            f"eff={r['efficiency']:.3f};gflops={r['gflops_model']:.0f};"
            f"tile={r['tile']}")
    kc = kernel_check()
    lines.append(f"table1.{kc['name']},{kc['us_per_call']:.1f},"
                 f"max_err={kc['max_err']:.2e}")
    return lines


if __name__ == "__main__":
    print("\n".join(main()))
