"""Roofline report: aggregates artifacts/dryrun/*.json into the §Roofline
table (per arch x shape: 3 terms, dominant, useful fraction, fix note)."""

from __future__ import annotations

import json
from pathlib import Path

ARTIFACTS = Path(__file__).resolve().parents[1] / "artifacts" / "dryrun"

FIX_NOTES = {
    "compute": "raise per-chip utilization: MXU-aligned tiles, fewer remat "
               "recompute flops, larger per-device batch",
    "memory": "cut HBM traffic: fuse (flash/xent kernels), bf16 streams, "
              "reuse-friendly tiling (eq.2)",
    "collective": "cut ICI bytes: sequence-parallel reduce-scatter instead "
                  "of all-reduce, bf16 grad sync, overlap a2a with expert "
                  "compute",
}


def load(mesh: str = "single"):
    rows = []
    for f in sorted(ARTIFACTS.glob(f"*__{mesh}.json")):
        rec = json.loads(f.read_text())
        rows.append(rec)
    return rows


def markdown_table(mesh: str = "single") -> str:
    rows = load(mesh)
    out = ["| arch | shape | compute(s) | memory(s) | collective(s) | "
           "dominant | MODEL/HLO | MFU bound | note |",
           "|---|---|---|---|---|---|---|---|---|"]
    for rec in rows:
        if rec.get("status") == "skipped":
            out.append(f"| {rec['arch']} | {rec['shape']} | — | — | — | "
                       f"skipped | — | — | {rec['reason']} |")
            continue
        if rec.get("status") != "ok" or "roofline" not in rec:
            out.append(f"| {rec['arch']} | {rec['shape']} | — | — | — | "
                       f"ERROR | — | — | {rec.get('error', '?')[:60]} |")
            continue
        r = rec["roofline"]
        out.append(
            f"| {rec['arch']} | {rec['shape']} | {r['compute_s']:.4g} | "
            f"{r['memory_s']:.4g} | {r['collective_s']:.4g} | "
            f"{r['dominant']} | {r['useful_fraction']:.2f} | "
            f"{r['mfu_bound']:.3f} | {FIX_NOTES[r['dominant']][:52]} |")
    return "\n".join(out)


def csv_lines(mesh: str = "single"):
    lines = []
    for rec in load(mesh):
        if rec.get("status") != "ok" or "roofline" not in rec:
            continue
        r = rec["roofline"]
        bound = max(r["compute_s"], r["memory_s"], r["collective_s"])
        lines.append(
            f"roofline.{rec['arch']}.{rec['shape']}.{mesh},"
            f"{bound * 1e6:.1f},"
            f"dominant={r['dominant']};mfu_bound={r['mfu_bound']:.3f};"
            f"useful={r['useful_fraction']:.2f}")
    return lines


def main():
    return csv_lines("single")


if __name__ == "__main__":
    print(markdown_table("single"))
