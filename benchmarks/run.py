"""Benchmark harness — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV lines:
  table1.*    — paper Table I analogue (blocked matmul config sweep)
  table2.*    — paper Table II analogue (SpMV on the four matrices)
  bandwidth.* — paper §V-B bandwidth-extrapolation figure
  roofline.*  — §Roofline rows from the dry-run artifacts (if present)
"""

from __future__ import annotations


def main() -> None:
    from benchmarks import (bandwidth_extrapolation, roofline_report,
                            table1_matmul, table2_spmv)

    lines: list[str] = []
    lines += table1_matmul.main()
    lines += table2_spmv.main()
    lines += bandwidth_extrapolation.main()
    try:
        lines += roofline_report.main()
    except Exception as e:  # dry-run artifacts may not exist yet
        lines.append(f"roofline.unavailable,0.0,{e!r}")
    print("name,us_per_call,derived")
    for ln in lines:
        print(ln)


if __name__ == "__main__":
    main()
