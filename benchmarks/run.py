"""Benchmark harness — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV lines:
  table1.*    — paper Table I analogue (blocked matmul config sweep)
  table2.*    — paper Table II analogue (SpMV on the four matrices)
  bandwidth.* — paper §V-B bandwidth-extrapolation figure
  roofline.*  — §Roofline rows from the dry-run artifacts (if present)

and writes ``BENCH_kernels.json`` (``--out`` to relocate): the
machine-readable kernel-perf record tracked across PRs — autotuned tile per
Table-1 shape, model GFLOP/s, tuner-vs-fixed speedup, measured wall-clock
where feasible, and the SpMV tuner plans with the balance metric.
"""

from __future__ import annotations

import argparse
import json
import os
import platform
import tempfile


BENCH_SCHEMA = 3

# --smoke shrinks the wall-clocked shapes so the whole run (plus the
# schema check in tools/check_bench.py) fits a CI smoke job; every report
# key and derived row is still produced.
SMOKE_ATTN_MEASURED = dict(bh=2, seq=128, dh=32, reps=2, trials=2)
SMOKE_CAUSAL_SKIP = dict(bh=1, seq=256, dh=32, block_q=64, block_k=64,
                         reps=2, trials=2)
SMOKE_DECODE = dict(b=1, hq=4, hkv=2, dh=32, cache_len=256, reps=2, trials=2)
SMOKE_RAGGED = dict(b=2, hq=4, hkv=2, dh=32, cache_len=128, block_k=32,
                    reps=2, trials=2)
SMOKE_INT8 = dict(b=1, hq=4, hkv=2, dh=32, cache_len=256, reps=2, trials=2)


def kernel_report(tuned_recs=None, attn_recs=None, attn_measured=None,
                  attn_skip=None, attn_decode=None,
                  attn_ragged=None, attn_int8=None) -> dict:
    import jax

    from benchmarks import attention_prefill, table1_matmul, table2_spmv

    return {
        "schema": BENCH_SCHEMA,
        "backend": jax.default_backend(),
        "host": platform.machine(),
        "matmul_tuned_vs_fixed": (tuned_recs if tuned_recs is not None
                                  else table1_matmul.tuned_vs_fixed()),
        "matmul_measured": table1_matmul.tuned_vs_fixed_measured(),
        "spmv_tuned": table2_spmv.tuned_records(),
        "attention_tuned_vs_fixed": (
            attn_recs if attn_recs is not None
            else attention_prefill.tuned_vs_fixed()),
        "attention_measured": (
            attn_measured if attn_measured is not None
            else attention_prefill.tuned_vs_fixed_measured()),
        "attention_causal_skip": (
            attn_skip if attn_skip is not None
            else attention_prefill.causal_skip_measured()),
        "attention_decode": (
            attn_decode if attn_decode is not None
            else attention_prefill.decode_step_measured()),
        "decode_ragged": (
            attn_ragged if attn_ragged is not None
            else attention_prefill.decode_ragged_measured()),
        "decode_int8": (
            attn_int8 if attn_int8 is not None
            else attention_prefill.decode_int8_measured()),
    }


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="BENCH_kernels.json",
                    help="path for the machine-readable kernel report")
    ap.add_argument("--skip-json", action="store_true")
    ap.add_argument("--smoke", action="store_true",
                    help="small wall-clocked shapes for the CI smoke job "
                         "(full schema, reduced measurement cost)")
    args = ap.parse_args(argv)

    # The report must reflect the code under benchmark, not whatever an
    # earlier run left in the user-global autotune cache — tune fresh in a
    # throwaway cache unless the caller explicitly pinned one.
    if "REPRO_AUTOTUNE_CACHE" not in os.environ:
        os.environ["REPRO_AUTOTUNE_CACHE"] = os.path.join(
            tempfile.mkdtemp(prefix="repro-bench-"), "autotune.json")

    from benchmarks import (attention_prefill, bandwidth_extrapolation,
                            roofline_report, table1_matmul, table2_spmv)

    # Tune/measure once; the CSV pass and the JSON report share the records.
    tuned_recs = table1_matmul.tuned_vs_fixed()
    attn_recs = attention_prefill.tuned_vs_fixed()
    attn_measured = attention_prefill.tuned_vs_fixed_measured(
        **(SMOKE_ATTN_MEASURED if args.smoke else {}))
    attn_skip = attention_prefill.causal_skip_measured(
        **(SMOKE_CAUSAL_SKIP if args.smoke else {}))
    attn_decode = attention_prefill.decode_step_measured(
        **(SMOKE_DECODE if args.smoke else {}))
    attn_ragged = attention_prefill.decode_ragged_measured(
        **(SMOKE_RAGGED if args.smoke else {}))
    attn_int8 = attention_prefill.decode_int8_measured(
        **(SMOKE_INT8 if args.smoke else {}))
    lines: list[str] = []
    lines += table1_matmul.main(tuned_recs)
    lines += table2_spmv.main()
    lines += attention_prefill.main(attn_recs, attn_measured, attn_skip,
                                    attn_decode, attn_ragged, attn_int8)
    lines += bandwidth_extrapolation.main()
    try:
        lines += roofline_report.main()
    except Exception as e:  # dry-run artifacts may not exist yet
        lines.append(f"roofline.unavailable,0.0,{e!r}")
    print("name,us_per_call,derived")
    for ln in lines:
        print(ln)

    if not args.skip_json:
        report = kernel_report(tuned_recs, attn_recs, attn_measured,
                               attn_skip, attn_decode, attn_ragged,
                               attn_int8)
        # Atomic temp+fsync+rename: a run killed mid-save leaves the
        # previous committed report, never a torn BENCH_kernels.json.
        from repro.core.ioutil import atomic_write_json
        atomic_write_json(args.out, report)
        print(f"# wrote {args.out}")


if __name__ == "__main__":
    main()
