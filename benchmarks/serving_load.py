"""Traffic-shaped serving benchmark: drive `serve_loop` with seeded load
mixes and emit ``BENCH_serving.json``, the end-to-end analogue of
``BENCH_kernels.json``.

The paper's methodology validates a design by *measured* performance on
the target workload, not per-kernel numbers; this harness is that
measurement for the serving stack.  Each mix in :data:`MIXES` is a
seeded workload shape (`runtime.loadgen`):

* ``steady``      — open-loop Poisson arrivals at ~half the predicted
  capacity: the regime `select_serving_batch` prices, staggered prompt
  lengths matching the sweep's slot-depth model.
* ``bursty``      — open-loop arrivals at ~3x predicted capacity: an
  overload burst that builds a queue, the regime TTFT SLOs exist for.
* ``interactive`` — closed-loop think-time sessions: each user submits
  the next request only after the previous answer, so a slow server
  sheds its own offered load.
* ``heavytail``   — open-loop arrivals with lognormal prompt/gen lengths
  (most requests short, a few very long): the production shape where a
  paged KV cache beats per-slot worst-case allocation.  Runs paged with
  the ``spf`` admission policy (docs/PAGING.md).
* ``quantized``   — the steady workload on the int8 KV cache
  (``kv_dtype: int8``): the quantized decode family #5 end-to-end, with
  the step-time prediction priced by the int8+scale byte stream
  (docs/AUTOTUNE.md "Quantized streaming").

The report's ``paging`` block replays the heavy-tail workload twice at
the **same KV-memory budget** — contiguous per-slot reservations vs the
paged pool — and gates that paging sustains >= ``ratio_floor`` more
concurrent active slots (`tools/check_load.py`).

Every mix runs on the **virtual clock** (one predicted decode-step of
time per loop step), so TTFT / per-token percentiles and tokens/sec are
deterministic "model-milliseconds": same seeds, same numbers, on any
machine.  Wall-clock measurements ride along in each mix's ``wall``
block (a VOLATILE field, see `loadgen.strip_volatile`) — the
predicted-vs-measured step-time loop of the coarse-grain estimator.

SLO budgets are priced in *steps* (``ttft_p99_steps`` etc.) and
converted to ms at the mix's predicted step time, so a cost-model change
rescales the budget and the measurement together; the gate
(`tools/check_load.py`) only breaks when *scheduling* regresses — queue
growth, slot starvation, lost requests — not when the analytic model is
retuned.

Always runs the smoke (CPU-sized) model config; ``--smoke`` shrinks the
request counts for CI.  See docs/SERVING_BENCH.md.
"""

from __future__ import annotations

import argparse
import json
import os
import pathlib
import platform
import tempfile
import time

import numpy as np

SERVING_SCHEMA = 3

# The paged-vs-contiguous concurrency floor the paging block is gated on:
# at the same KV-memory budget the paged allocator must sustain at least
# this many times the contiguous path's concurrent active slots.
PAGING_RATIO_FLOOR = 1.5

# One entry per workload shape.  `requests` is the full-run count,
# `smoke_requests` the CI count; slo budgets are denominated in decode
# steps of the mix's predicted step time (see module docstring).
MIXES: dict[str, dict] = {
    "steady": {
        "kind": "open",
        "seed": 11,
        "requests": 24,
        "smoke_requests": 10,
        "rate_factor": 0.5,            # x predicted capacity
        "prompt_dist": {"kind": "staggered", "base": 8, "spread": 8},
        "gen_dist": {"kind": "fixed", "value": 8},
        "queue_limit": 0,
        "slo": {"ttft_p99_steps": 30, "per_token_p99_steps": 3,
                "min_tok_per_step_frac": 0.15},
    },
    "bursty": {
        "kind": "open",
        "seed": 13,
        "requests": 28,
        "smoke_requests": 12,
        "rate_factor": 3.0,            # overload: arrivals outrun capacity
        "prompt_dist": {"kind": "uniform", "lo": 6, "hi": 14},
        "gen_dist": {"kind": "choice", "values": [4, 8, 16],
                     "weights": [0.5, 0.375, 0.125]},
        # cap the sweep so the burst actually outruns the server and the
        # queue (and TTFT tail) is exercised, not absorbed by slots
        "batch_candidates": [1, 2, 4],
        "queue_limit": 0,
        "slo": {"ttft_p99_steps": 90, "per_token_p99_steps": 3,
                "min_tok_per_step_frac": 0.3},
    },
    "interactive": {
        "kind": "closed",
        "seed": 17,
        "sessions": 4,
        "requests": 24,
        "smoke_requests": 12,
        "think_steps": {"kind": "exponential", "mean": 5.0},
        "prompt_dist": {"kind": "uniform", "lo": 8, "hi": 12},
        "gen_dist": {"kind": "fixed", "value": 6},
        "queue_limit": 0,
        "slo": {"ttft_p99_steps": 30, "per_token_p99_steps": 3,
                "min_tok_per_step_frac": 0.05},
    },
    "quantized": {
        "kind": "open",
        "seed": 23,
        "requests": 24,
        "smoke_requests": 10,
        "rate_factor": 0.5,
        "prompt_dist": {"kind": "staggered", "base": 8, "spread": 8},
        "gen_dist": {"kind": "fixed", "value": 8},
        "queue_limit": 0,
        "kv_dtype": "int8",
        "slo": {"ttft_p99_steps": 30, "per_token_p99_steps": 3,
                "min_tok_per_step_frac": 0.15},
    },
    "heavytail": {
        "kind": "open",
        "seed": 19,
        "requests": 24,
        "smoke_requests": 12,
        "rate_factor": 1.5,
        "prompt_dist": {"kind": "lognormal", "mean": 8, "sigma": 0.6,
                        "lo": 4, "hi": 48},
        "gen_dist": {"kind": "lognormal", "mean": 6, "sigma": 0.8,
                     "lo": 2, "hi": 40},
        "batch_candidates": [1, 2, 4, 8],
        "queue_limit": 0,
        "paged": True,
        "page_size": 8,
        "sched": "spf",
        "slo": {"ttft_p99_steps": 160, "per_token_p99_steps": 4,
                "min_tok_per_step_frac": 0.15},
    },
}


def build_trace(spec: dict, n: int, step_s: float, batch: int):
    """The mix's seeded trace.  Lengths are drawn *before* arrivals (the
    batch sweep needs the slot-depth distribution, the arrival rate needs
    the chosen batch's step time), from independent seeded streams so the
    two-phase construction stays deterministic."""
    seed = spec["seed"]
    len_rng = np.random.default_rng(seed)
    from repro.runtime import loadgen
    prompts = [max(1, p) for p in
               loadgen.sample_lengths(len_rng, n, spec["prompt_dist"])]
    gens = [max(1, g) for g in
            loadgen.sample_lengths(len_rng, n, spec["gen_dist"])]

    if spec["kind"] == "open":
        mean_gen = sum(gens) / n
        # capacity ~= batch slots finishing every (gen+1) steps
        rate_rps = spec["rate_factor"] * batch / ((mean_gen + 1.0) * step_s)
        gaps = np.random.default_rng(seed + 1).exponential(
            1.0 / rate_rps, size=n)
        arrivals = np.cumsum(gaps)
        thinks = [0.0] * n
    else:
        n_sessions = spec["sessions"]
        # sessions_from_trace round-robins rids: session si starts with
        # rid si — stagger those first arrivals one step apart.
        arrivals = np.array([(i % n_sessions) * step_s for i in range(n)])
        think_steps = loadgen.sample_times(
            np.random.default_rng(seed + 2), n, spec["think_steps"])
        thinks = [t * step_s for t in think_steps]
        rate_rps = None

    trace = [loadgen.TraceRequest(
        rid=i, arrival_s=float(arrivals[i]), prompt_len=prompts[i],
        gen_len=gens[i], think_s=thinks[i]) for i in range(n)]
    return trace, rate_rps


def run_mix(cfg, name: str, spec: dict, *, smoke: bool = False,
            batch: int = 0, batch_candidates=(1, 2, 4, 8),
            emit_dir=None, pool_pages: int = 0) -> dict:
    """Run one load mix end-to-end and return its report row.  ``batch``
    forces the decode batch (0 = `select_serving_batch` picks); tests use
    the override to replay the same trace at two batch sizes.

    Spec keys ``paged`` / ``page_size`` / ``sched`` run the mix on the
    paged KV cache under the named admission policy; ``pool_pages``
    overrides the physical pool size (0 = the spec's own ``pool_pages``
    key, falling back to contiguous-equivalent) — the paging comparison
    uses it to pin both paths to the same KV-memory budget."""
    import jax.numpy as jnp

    from repro.kernels import autotune
    from repro.launch import serve, specs
    from repro.launch.mesh import make_host_mesh, set_mesh
    from repro.launch.scheduler import Scheduler
    from repro.parallel import sharding as shd
    from repro.runtime import fault_tolerance, loadgen, paging
    from repro.runtime.lifecycle import Lifecycle

    n = spec["smoke_requests"] if smoke else spec["requests"]
    seed = spec["seed"]
    kv_dtype = jnp.dtype(spec.get("kv_dtype", "float32"))

    # Phase 1: lengths only — the workload's slot-depth distribution the
    # batch sweep prices (same midpoint model as launch/serve.py).
    len_rng = np.random.default_rng(seed)
    prompts = [max(1, p) for p in
               loadgen.sample_lengths(len_rng, n, spec["prompt_dist"])]
    gens = [max(1, g) for g in
            loadgen.sample_lengths(len_rng, n, spec["gen_dist"])]
    prefill_len = max(prompts)
    max_len = max(p + g for p, g in zip(prompts, gens)) + 8
    dist = sorted(p + g // 2 for p, g in zip(prompts, gens))

    if batch > 0:
        step_us = autotune.predict_decode_step_us(
            cfg, batch, cache_len=max_len, kv_dtype=kv_dtype,
            lengths=autotune._quantile_lengths(batch, dist, max_len))
        decision = {"batch": batch, "source": "flag",
                    "predicted_step_us": round(step_us, 3)}
    else:
        batch_candidates = spec.get("batch_candidates", batch_candidates)
        cands = [c for c in batch_candidates if c <= n] \
            or [min(batch_candidates)]
        decision = autotune.select_serving_batch(
            cfg, cache_len=max_len, prefill_len=prefill_len,
            kv_dtype=kv_dtype, candidates=tuple(cands),
            slot_lengths=dist)
        decision["source"] = "autotune"
        batch = decision["batch"]
        step_us = decision["predicted_step_us"]
    # The virtual clock runs at the predicted step time floored to one
    # model-ms (loadgen.MIN_VIRTUAL_STEP_US); predicted-vs-measured keeps
    # the raw prediction.
    clock_us = loadgen.virtual_step_us(step_us)
    step_s = clock_us * 1e-6

    # Phase 2: arrivals at a rate derived from the chosen batch's
    # predicted capacity, then the virtual-clock run itself.
    trace, rate_rps = build_trace(spec, n, step_s, batch)
    if emit_dir is not None:
        loadgen.save_trace(pathlib.Path(emit_dir) / f"{name}.jsonl", trace)

    clock = loadgen.VirtualClock(step_s)
    lc = Lifecycle(queue_limit=spec.get("queue_limit", 0), clock=clock)
    if spec["kind"] == "closed":
        source = loadgen.SessionSource(
            loadgen.sessions_from_trace(trace, spec["sessions"]),
            cfg.vocab_size, seed=seed)
    else:
        source = loadgen.TraceSource(trace, cfg.vocab_size, seed=seed)

    paged_spec = None
    if spec.get("paged"):
        paged_spec = paging.PageSpec.build(
            batch, max_len, spec.get("page_size", 8),
            pool_pages=pool_pages or spec.get("pool_pages", 0))
    sched = spec.get("sched", "fcfs")

    mesh = make_host_mesh(data=1, model=1)
    with set_mesh(mesh), shd.use_rules(specs.rules_for(mesh)):
        server = serve.Server(cfg, batch, max_len, prefill_len=prefill_len,
                              slot_lengths=dist, paged=paged_spec,
                              kv_dtype=kv_dtype)
        scheduler = (Scheduler(sched, allocator=server.allocator)
                     if (paged_spec is not None or sched != "fcfs")
                     else None)
        recorder = loadgen.StepTimeRecorder(
            fault_tolerance.DecodeWatchdog(step_us))
        t0 = time.time()
        stats = serve.serve_loop(server, lc, watchdog=recorder,
                                 source=source, scheduler=scheduler)
        wall = time.time() - t0

    metrics = loadgen.collect_metrics(lc, predicted_step_us=step_us,
                                      step_times=recorder.times,
                                      queue_depth=source.queue_depth)

    # SLO evaluation: budgets priced in steps, converted at this mix's
    # predicted step time (see module docstring).
    budgets = spec["slo"]
    step_ms = clock_us * 1e-3
    slo = {
        "ttft_p99_ms": round(budgets["ttft_p99_steps"] * step_ms, 3),
        "per_token_p99_ms": round(
            budgets["per_token_p99_steps"] * step_ms, 3),
        "min_tok_per_s": round(
            budgets["min_tok_per_step_frac"] * batch / step_s, 3),
        "budget_steps": dict(budgets),
    }
    violations = []
    ttft_p99 = metrics["ttft_ms"]["p99"]
    if ttft_p99 is None or ttft_p99 > slo["ttft_p99_ms"]:
        violations.append(
            f"ttft p99 {ttft_p99} ms > budget {slo['ttft_p99_ms']} ms")
    ptok_p99 = metrics["per_token_ms"]["p99"]
    if ptok_p99 is None or ptok_p99 > slo["per_token_p99_ms"]:
        violations.append(
            f"per-token p99 {ptok_p99} ms > budget "
            f"{slo['per_token_p99_ms']} ms")
    tok_per_s = metrics["tok_per_s"]
    if tok_per_s is None or tok_per_s < slo["min_tok_per_s"]:
        violations.append(
            f"sustained {tok_per_s} tok/s < floor {slo['min_tok_per_s']}")

    row = {
        "name": name,
        "kind": spec["kind"],
        "seed": seed,
        "batch": batch,
        "batch_source": decision["source"],
        "serving_plan": {k: decision[k] for k in
                         ("batch", "predicted_step_us",
                          "predicted_tok_per_s", "latency_budget_ms")
                         if k in decision},
        "step_time_us": round(clock_us, 3),
        "rate_rps": None if rate_rps is None else round(rate_rps, 3),
        "trace": [t.record() for t in trace],
        "decode_steps": stats["steps"],
        "generated": stats["generated"],
        "max_concurrent": stats.get("max_concurrent", 0),
        "paged": paged_spec is not None,
        "sched": sched,
        "kv_dtype": kv_dtype.name,
        **metrics,
        "slo": slo,
        "slo_ok": not violations,
        "slo_violations": violations,
        "wall": {"wall_s": round(wall, 3),
                 "wall_tok_per_s": round(stats["generated"]
                                         / max(wall, 1e-9), 1),
                 **recorder.summary()},
    }
    if paged_spec is not None:
        # pages-allocated-vs-tokens-resident at the pool's peak — the
        # KV-memory utilization the report (and its gate) cares about
        row["kv"] = {**(stats.get("kv_peak")
                        or server.allocator.utilization()),
                     "pages_peak": stats.get("kv_pages_peak", 0),
                     "kv_ooms": stats.get("kv_ooms", 0)}
        server.allocator.check_conserved()   # pool must drain leak-free
    return row


def measure_recovery(arch: str = "qwen3_14b", *, smoke: bool = False) -> dict:
    """The crash-recovery row of BENCH_serving.json: run the serving CLI
    end-to-end with a pinned injected crash (`serve --crash --crash-step`)
    and then `serve --resume`, measuring how much the journal bounded the
    replay (``replayed_steps``, must be <= the snapshot interval) and the
    recovery latency (``--resume`` start to the first *newly generated*
    token — the wall block; volatile).  Exactly-once accounting across the
    two process lifetimes rides in ``outcomes``/``conserved``."""
    import contextlib
    import io

    from repro.launch import serve

    n = 6 if smoke else 10
    gen = 12
    crash_step = 9
    snapshot_every = 4
    state_dir = tempfile.mkdtemp(prefix="repro-recovery-")
    base = ["--arch", arch, "--smoke", "--requests", str(n),
            "--prompt-len", "12", "--gen", str(gen),
            "--state-dir", state_dir,
            "--snapshot-every", str(snapshot_every)]

    crash_buf = io.StringIO()
    with contextlib.redirect_stdout(crash_buf):
        crash_rc = serve.main(base + ["--crash", "--crash-step",
                                      str(crash_step)])

    resume_buf = io.StringIO()
    t0 = time.time()
    with contextlib.redirect_stdout(resume_buf):
        resume_rc = serve.main(["--resume", "--state-dir", state_dir])
    resume_wall = time.time() - t0

    summary = {}
    for line in resume_buf.getvalue().splitlines():
        line = line.strip()
        if line.startswith("{"):
            try:
                row = json.loads(line)
            except ValueError:
                continue
            if "tokens_generated" in row:
                summary = row
    rec = summary.get("recovery", {})
    outcomes = summary.get("outcomes", {})
    submitted = summary.get("submitted", 0)
    terminal = sum(outcomes.get(k, 0) for k in
                   ("completed", "timed_out", "failed", "rejected"))
    return {
        "requests": n,
        "gen": gen,
        "crash_step": crash_step,
        "snapshot_every": snapshot_every,
        "crash_exit_ok": crash_rc == serve.CRASH_EXIT,
        "resume_exit_ok": resume_rc == 0,
        "snapshot_step": rec.get("snapshot_step"),
        "resume_step": rec.get("resume_step"),
        "replayed_steps": rec.get("replayed_steps"),
        "replayed_records": rec.get("replayed_records"),
        "reprefilled_slots": rec.get("reprefilled_slots"),
        "submitted": submitted,
        "outcomes": outcomes,
        "conserved": bool(submitted) and terminal == submitted,
        "wall": {
            "resume_wall_s": round(resume_wall, 3),
            "prepare_s": rec.get("prepare_s"),
            "first_new_token_s": rec.get("first_new_token_s"),
        },
    }


def measure_paging(cfg, *, smoke: bool = False) -> dict:
    """The paging block of BENCH_serving.json: replay the heavy-tail
    workload at the **same KV-memory budget** twice — contiguous
    per-slot worst-case reservations vs the paged pool — and measure the
    concurrent active slots each sustains under saturating load.

    The budget is ``cont_batch * max_len`` tokens: exactly what the
    contiguous cache must reserve for ``cont_batch`` slots.  The paged
    run gets the same tokens as a shared pool
    (``budget // page_size`` pages) with more slots than the pool could
    cover at worst case — the allocator + spf admission turn the
    heavy-tail length distribution into extra concurrency, which is the
    whole argument for paging (docs/PAGING.md).  Gated by
    `tools/check_load.py` at :data:`PAGING_RATIO_FLOOR`.
    """
    from repro.runtime import loadgen

    spec = MIXES["heavytail"]
    n = spec["smoke_requests"] if smoke else spec["requests"]
    len_rng = np.random.default_rng(spec["seed"])
    prompts = [max(1, p) for p in
               loadgen.sample_lengths(len_rng, n, spec["prompt_dist"])]
    gens = [max(1, g) for g in
            loadgen.sample_lengths(len_rng, n, spec["gen_dist"])]
    max_len = max(p + g for p, g in zip(prompts, gens)) + 8
    page_size = spec.get("page_size", 8)
    cont_batch = 2
    budget_tokens = cont_batch * max_len
    pool_pages = budget_tokens // page_size
    paged_batch = 8

    def brief(row):
        return {"batch": row["batch"],
                "max_concurrent": row["max_concurrent"],
                "generated": row["generated"],
                "decode_steps": row["decode_steps"],
                "tok_per_s": row["tok_per_s"],
                "outcomes": row["outcomes"]}

    cont = run_mix(cfg, "paging_contiguous",
                   {**spec, "paged": False, "sched": "fcfs"},
                   smoke=smoke, batch=cont_batch)
    paged = run_mix(cfg, "paging_paged", spec, smoke=smoke,
                    batch=paged_batch, pool_pages=pool_pages)
    ratio = (paged["max_concurrent"]
             / max(1, cont["max_concurrent"]))
    return {
        "mix": "heavytail",
        "page_size": page_size,
        "max_len": max_len,
        "budget_tokens": budget_tokens,
        "pool_pages": pool_pages,
        "contiguous": brief(cont),
        "paged": {**brief(paged), "pool_pages": pool_pages,
                  "kv": paged["kv"]},
        "concurrency_ratio": round(ratio, 3),
        "ratio_floor": PAGING_RATIO_FLOOR,
        "ratio_ok": ratio >= PAGING_RATIO_FLOOR,
    }


def build_report(arch: str = "qwen3_14b", mixes=None, smoke: bool = False,
                 emit_dir=None) -> dict:
    """The full BENCH_serving.json payload.  Always measures the smoke
    (CPU-sized) model config — the harness gates *scheduling*, which is
    model-size-independent on the virtual clock; non-smoke mode only
    scales the request counts."""
    import jax

    import repro.configs as configs

    cfg = configs.get_smoke(arch)
    names = list(mixes) if mixes else list(MIXES)
    rows = {}
    for name in names:
        rows[name] = run_mix(cfg, name, MIXES[name], smoke=smoke,
                             emit_dir=emit_dir)
        r = rows[name]
        print(json.dumps({"mix": name, "batch": r["batch"],
                          "ttft_ms": r["ttft_ms"],
                          "per_token_ms": r["per_token_ms"],
                          "tok_per_s": r["tok_per_s"],
                          "queue_depth_max": r["queue_depth_max"],
                          "slo_ok": r["slo_ok"],
                          "slo_violations": r["slo_violations"]}))
    recovery = measure_recovery(arch, smoke=smoke)
    print(json.dumps({"recovery": {
        k: recovery[k] for k in ("crash_step", "snapshot_every",
                                 "replayed_steps", "conserved",
                                 "crash_exit_ok", "resume_exit_ok")}}))
    paging = measure_paging(cfg, smoke=smoke)
    print(json.dumps({"paging": {
        k: paging[k] for k in ("budget_tokens", "pool_pages",
                               "concurrency_ratio", "ratio_floor",
                               "ratio_ok")}}))
    return {
        "schema": SERVING_SCHEMA,
        "arch": cfg.name,
        "backend": jax.default_backend(),
        "host": platform.machine(),
        "smoke": bool(smoke),
        "mixes": rows,
        "recovery": recovery,
        "paging": paging,
        "slo_ok": all(r["slo_ok"] for r in rows.values()),
    }


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="BENCH_serving.json")
    ap.add_argument("--arch", default="qwen3_14b")
    ap.add_argument("--smoke", action="store_true",
                    help="CI request counts (same mixes, same schema)")
    ap.add_argument("--mixes", nargs="+", default=None,
                    choices=sorted(MIXES))
    ap.add_argument("--emit-traces", default=None, metavar="DIR",
                    help="also write each mix's trace as DIR/<mix>.jsonl "
                         "(replayable via launch.serve --load-trace)")
    args = ap.parse_args(argv)

    # Tune fresh in a throwaway cache unless the caller pinned one — the
    # report must reflect the code under benchmark (same rule as run.py).
    if "REPRO_AUTOTUNE_CACHE" not in os.environ:
        os.environ["REPRO_AUTOTUNE_CACHE"] = os.path.join(
            tempfile.mkdtemp(prefix="repro-serving-"), "autotune.json")
    if args.emit_traces:
        pathlib.Path(args.emit_traces).mkdir(parents=True, exist_ok=True)

    report = build_report(args.arch, mixes=args.mixes, smoke=args.smoke,
                          emit_dir=args.emit_traces)
    # Atomic: a benchmark run killed mid-save must leave the previous
    # committed report, not a torn one for check_load.py to choke on.
    from repro.core.ioutil import atomic_write_json
    atomic_write_json(args.out, report)
    print(f"# wrote {args.out}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
