"""Pallas TPU kernels for the perf-critical compute layers.

- matmul/    — paper §V-A: eq.2-tiled blocked dense matmul (fused epilogue)
- spmv/      — paper §V-B: nnz-balanced ELL sparse matvec (+ blocked-x)
- attention/ — flash attention (prefill hot spot; beyond-paper) + the fused
               single-query decode kernel
- registry   — declarative KernelSpec API: a tuned kernel family is a
               registration (candidates + cost model + launcher), not a
               pipeline copy; each family's spec lives in
               `<family>/spec.py`
- autotune   — the one generic DSE -> measure -> cache engine:
               `tune(spec, problem)` and `dispatch(family, *args)` are the
               entry points production paths should call (the legacy
               `tuned_*` wrappers remain as deprecation shims).
               `select_serving_batch` lifts the same loop to the
               serving-batch knob.

Each kernel dir has kernel.py (pl.pallas_call + BlockSpec), ops.py (jitted
wrapper with backend dispatch), ref.py (pure-jnp oracle), spec.py (the
KernelSpec registration).  Tests sweep shapes/dtypes in interpret mode
against the oracles.
"""

from repro.kernels.autotune import (dispatch, plan_for_model,
                                    select_serving_batch, tune,
                                    tune_attention, tune_decode,
                                    tune_matmul, tune_spmv,
                                    tuned_attention, tuned_decode,
                                    tuned_matmul, tuned_spmv)  # noqa: F401
from repro.kernels.registry import (KernelSpec, Plan, families,
                                    register)  # noqa: F401
