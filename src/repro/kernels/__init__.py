"""Pallas TPU kernels for the perf-critical compute layers.

- matmul/    — paper §V-A: eq.2-tiled blocked dense matmul
- spmv/      — paper §V-B: nnz-balanced ELL sparse matvec
- attention/ — flash attention (prefill hot spot; beyond-paper)

Each has kernel.py (pl.pallas_call + BlockSpec), ops.py (jitted wrapper with
backend dispatch), ref.py (pure-jnp oracle).  Tests sweep shapes/dtypes in
interpret mode against the oracles.
"""
