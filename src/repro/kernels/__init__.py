"""Pallas TPU kernels for the perf-critical compute layers.

- matmul/    — paper §V-A: eq.2-tiled blocked dense matmul (fused epilogue)
- spmv/      — paper §V-B: nnz-balanced ELL sparse matvec (+ blocked-x)
- attention/ — flash attention (prefill hot spot; beyond-paper)
- autotune   — DSE -> measure -> cache engine; `tuned_matmul`/`tuned_spmv`/
               `tuned_attention`/`tuned_decode` are the entry points
               production paths should call.  `select_serving_batch` lifts
               the same loop to the serving-batch knob.

Each kernel dir has kernel.py (pl.pallas_call + BlockSpec), ops.py (jitted
wrapper with backend dispatch), ref.py (pure-jnp oracle).  Tests sweep
shapes/dtypes in interpret mode against the oracles.
"""

from repro.kernels.autotune import (select_serving_batch, tune_attention,
                                    tune_decode, tune_matmul, tune_spmv,
                                    tuned_attention, tuned_decode,
                                    tuned_matmul, tuned_spmv)  # noqa: F401
