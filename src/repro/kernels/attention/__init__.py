from repro.kernels.attention.ops import mha_attention  # noqa: F401
from repro.kernels.attention.decode import (decode_ref,
                                            gqa_decode_attention)  # noqa: F401
