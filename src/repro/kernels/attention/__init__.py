from repro.kernels.attention.ops import mha_attention  # noqa: F401
