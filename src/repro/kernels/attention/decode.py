"""Fused single-query decode-attention Pallas kernel (KV-cache resident).

One generated token per sequence attends over the whole KV cache — the
serving decode hot loop.  The jnp path materializes (B, H, 1, L) logits and
re-reads the cache per head group; this kernel fuses qK^T -> online softmax
-> pV into one pass that streams each K/V block exactly once.

GQA head folding: the ``g = Hq/Hkv`` query heads sharing a KV head become
the q-*row* axis of a (g, dh) block, so the MXU contraction amortizes the
K/V stream across the whole group (the same fold the prefill kernel gets
from `ops.mha_attention`, but per KV head instead of per q head — decode
must not `jnp.repeat` the cache).

Cache-length skipping: the valid prefix length is a traced value at
serving time, so it rides a scalar-prefetch argument — one int32 *per
folded row* (continuous batching gives every sequence its own prefix; a
shared scalar is the degenerate broadcast case).  For each row, the K/V
index maps clamp every grid step past its last valid block onto it (Pallas
elides the repeated DMA) and a `@pl.when` guard skips the FLOPs — blocks
past a row's write index are neither streamed nor multiplied, the decode
analogue of the prefill kernel's causal block triangle.  Cache lengths not
divisible by block_k are padded once at the call site and masked via the
same per-row length.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _decode_kernel(len_ref, q_ref, k_ref, v_ref, o_ref,
                   m_ref, l_ref, acc_ref, *,
                   scale: float, block_k: int, k_steps: int):
    bb = pl.program_id(0)
    jj = pl.program_id(1)
    length = len_ref[bb]
    last = jnp.maximum(0, (length - 1) // block_k)

    @pl.when(jj == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    @pl.when(jj <= last)
    def _compute():
        q = q_ref[0]                                     # (g, dh)
        k = k_ref[0]                                     # (block_k, dh)
        v = v_ref[0]                                     # (block_k, dh)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale  # (g, block_k)
        k_pos = jj * block_k + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        s = jnp.where(k_pos < length, s, NEG_INF)

        m_prev = m_ref[...]                              # (g, 1)
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        p = jnp.exp(s - m_new)
        p = jnp.where(m_new <= NEG_INF, 0.0, p)          # fully-masked block
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=1, keepdims=True)
        m_ref[...] = m_new
        acc_ref[...] = acc_ref[...] * corr + jax.lax.dot_general(
            p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)

    @pl.when(jj == k_steps - 1)
    def _store():
        o_ref[0] = (acc_ref[...] /
                    jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


def _row_lengths(length, rows: int, kl: int) -> jax.Array:
    """Normalize ``length`` (python int / traced scalar / per-row vector)
    to a clamped int32 vector of one valid-prefix length per folded row —
    the scalar-prefetch payload.  The scalar case is the degenerate
    uniform broadcast."""
    lv = jnp.asarray(length, jnp.int32)
    if lv.ndim == 0:
        lv = jnp.full((rows,), lv, jnp.int32)
    elif lv.shape != (rows,):
        raise ValueError(
            f"length must be a scalar or a ({rows},) per-row vector, "
            f"got shape {lv.shape}")
    return jnp.minimum(lv, kl)


def decode_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                     scale: float, length, block_k: int = 512,
                     interpret: bool = False) -> jax.Array:
    """q: (BKV, g, dh); k, v: (BKV, L, dh); length: valid cache prefix.

    ``length`` may be a python int, a traced int32 scalar (the serving
    cache index + 1), or a per-row int32 vector of shape (BKV,) — the
    continuous-batching case where every sequence sits at its own depth.
    Keys at positions >= the row's length are masked and their blocks
    skipped per row.  The KV-head fold (BKV = B * Hkv) is the caller's
    job — see `gqa_decode_attention`.
    """
    out_dtype = q.dtype
    if q.dtype != k.dtype:
        # The q rows are tiny; upcasting them to the cache dtype is free
        # (serving keeps an f32/bf16 cache while activations may differ).
        # The output is cast back so the kernel and oracle paths agree.
        q = q.astype(k.dtype)
    bkv, g, dh = q.shape
    _, kl, _ = k.shape
    block_k = min(block_k, kl)
    k_pad = -kl % block_k
    if k_pad:
        k = jnp.pad(k, ((0, 0), (0, k_pad), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, k_pad), (0, 0)))
    k_steps = (kl + k_pad) // block_k
    lengths = _row_lengths(length, bkv, kl)

    def kv_index(b, j, len_ref):
        last = jnp.maximum(0, (len_ref[b] - 1) // block_k)
        return (b, jnp.minimum(j, last), 0)

    fn = functools.partial(_decode_kernel, scale=scale, block_k=block_k,
                           k_steps=k_steps)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(bkv, k_steps),
        in_specs=[
            pl.BlockSpec((1, g, dh), lambda b, j, len_ref: (b, 0, 0)),
            pl.BlockSpec((1, block_k, dh), kv_index),
            pl.BlockSpec((1, block_k, dh), kv_index),
        ],
        out_specs=pl.BlockSpec((1, g, dh), lambda b, j, len_ref: (b, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((g, 1), jnp.float32),
            pltpu.VMEM((g, 1), jnp.float32),
            pltpu.VMEM((g, dh), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        fn,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((bkv, g, dh), q.dtype),
        interpret=interpret,
    )(lengths, q, k, v)
    return out.astype(out_dtype)


def gqa_decode_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                         length, scale: float | None = None,
                         block_k: int = 512,
                         interpret: bool = False) -> jax.Array:
    """q: (B, Hq, dh); k, v: (B, L, Hkv, dh) -> (B, Hq, dh).

    Folds the GQA group into the q-row axis per KV head (no cache repeat)
    and dispatches to the fused kernel.  ``length`` is a scalar or a (B,)
    per-sequence vector; the fold repeats it across each sequence's KV
    heads (row b*Hkv+h belongs to sequence b).
    """
    b, hq, dh = q.shape
    _, kl, hkv, _ = k.shape
    g = hq // hkv
    if scale is None:
        scale = 1.0 / (dh ** 0.5)
    lv = jnp.asarray(length, jnp.int32)
    if lv.ndim == 1:
        if lv.shape != (b,):
            raise ValueError(
                f"length must be a scalar or a ({b},) per-sequence vector, "
                f"got shape {lv.shape}")
        length = jnp.repeat(lv, hkv)
    qf = q.reshape(b, hkv, g, dh).reshape(b * hkv, g, dh)
    kf = k.transpose(0, 2, 1, 3).reshape(b * hkv, kl, dh)
    vf = v.transpose(0, 2, 1, 3).reshape(b * hkv, kl, dh)
    out = decode_attention(qf, kf, vf, scale=scale, length=length,
                           block_k=block_k, interpret=interpret)
    return out.reshape(b, hkv, g, dh).reshape(b, hq, dh)


def _paged_decode_kernel(len_ref, pt_ref, q_ref, k_ref, v_ref, o_ref,
                         m_ref, l_ref, acc_ref, *,
                         scale: float, page_size: int, max_pages: int):
    """Same online-softmax body as `_decode_kernel`, but the grid's k axis
    walks the slot's *page table* instead of a contiguous cache: grid step
    j streams physical page ``pt_ref[slot, j]`` (the index maps below do
    the translation; ``pt_ref`` itself is unused here but must ride the
    scalar-prefetch signature)."""
    del pt_ref
    bb = pl.program_id(0)
    jj = pl.program_id(1)
    length = len_ref[bb]
    last = jnp.maximum(0, (length - 1) // page_size)

    @pl.when(jj == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    @pl.when(jj <= last)
    def _compute():
        q = q_ref[0]                                     # (g, dh)
        k = k_ref[0, :, 0]                               # (page_size, dh)
        v = v_ref[0, :, 0]
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale  # (g, page_size)
        k_pos = jj * page_size + jax.lax.broadcasted_iota(jnp.int32,
                                                          s.shape, 1)
        s = jnp.where(k_pos < length, s, NEG_INF)

        m_prev = m_ref[...]                              # (g, 1)
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        p = jnp.exp(s - m_new)
        p = jnp.where(m_new <= NEG_INF, 0.0, p)          # fully-masked page
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=1, keepdims=True)
        m_ref[...] = m_new
        acc_ref[...] = acc_ref[...] * corr + jax.lax.dot_general(
            p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)

    @pl.when(jj == max_pages - 1)
    def _store():
        o_ref[0] = (acc_ref[...] /
                    jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


def paged_gqa_decode_attention(q: jax.Array, k_pool: jax.Array,
                               v_pool: jax.Array, pages: jax.Array, *,
                               length, scale: float | None = None,
                               interpret: bool = False) -> jax.Array:
    """Fused decode attention through a paged KV cache.

    q: (B, Hq, dh); k_pool, v_pool: (num_pages, page_size, Hkv, dh) —
    the layer's shared physical page pools; pages: (B, max_pages) int32
    per-slot page table (-1 = unassigned); length: (B,) valid-prefix
    token counts.  Returns (B, Hq, dh).

    The page table rides the *second* scalar-prefetch argument next to
    the lengths vector: the K/V BlockSpec index maps read
    ``pages[slot, min(j, last)]`` to pick the physical pool row each grid
    step streams, so a slot touches exactly its own pages — blocks past a
    slot's depth are neither streamed nor multiplied, same skip law as
    the contiguous kernel, and unassigned (-1) entries are never reached
    because ``j`` is clamped to the slot's last valid page.  The GQA
    group folds into the q-row axis per KV head exactly like
    `gqa_decode_attention`; the pool is NOT folded (it has no batch
    axis — that is the whole point), so the index maps carry the
    row -> (slot, kv_head) split instead.
    """
    out_dtype = q.dtype
    if q.dtype != k_pool.dtype:
        q = q.astype(k_pool.dtype)
    b, hq, dh = q.shape
    num_pages, page_size, hkv, _ = k_pool.shape
    max_pages = pages.shape[1]
    g = hq // hkv
    if scale is None:
        scale = 1.0 / (dh ** 0.5)
    lengths = _row_lengths(length, b, max_pages * page_size)
    lengths = jnp.repeat(lengths, hkv)              # row r -> slot r // hkv
    pt = jnp.asarray(pages, jnp.int32)
    qf = q.reshape(b, hkv, g, dh).reshape(b * hkv, g, dh)
    bkv = b * hkv

    def kv_index(r, j, len_ref, pt_ref):
        last = jnp.maximum(0, (len_ref[r] - 1) // page_size)
        page = pt_ref[r // hkv, jnp.minimum(j, last)]
        # Clamp keeps even a pathological table in bounds; the length
        # mask already zeroes anything past the valid prefix.
        return (jnp.clip(page, 0, num_pages - 1), 0, r % hkv, 0)

    fn = functools.partial(_paged_decode_kernel, scale=scale,
                           page_size=page_size, max_pages=max_pages)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(bkv, max_pages),
        in_specs=[
            pl.BlockSpec((1, g, dh), lambda r, j, len_ref, pt_ref: (r, 0, 0)),
            pl.BlockSpec((1, page_size, 1, dh), kv_index),
            pl.BlockSpec((1, page_size, 1, dh), kv_index),
        ],
        out_specs=pl.BlockSpec((1, g, dh),
                               lambda r, j, len_ref, pt_ref: (r, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((g, 1), jnp.float32),
            pltpu.VMEM((g, 1), jnp.float32),
            pltpu.VMEM((g, dh), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        fn,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((bkv, g, dh), q.dtype),
        interpret=interpret,
    )(lengths, pt, qf, k_pool, v_pool)
    return out.reshape(b, hkv, g, dh).reshape(b, hq, dh).astype(out_dtype)


def paged_decode_ref(q: jax.Array, k_pool: jax.Array, v_pool: jax.Array,
                     pages: jax.Array, *, length,
                     scale: float | None = None) -> jax.Array:
    """Pure-jnp oracle for `paged_gqa_decode_attention`: gather each
    slot's pages back into a contiguous view, then reuse `decode_ref`."""
    b = q.shape[0]
    num_pages, page_size, hkv, dh = k_pool.shape
    max_pages = pages.shape[1]
    safe = jnp.clip(jnp.asarray(pages, jnp.int32), 0, num_pages - 1)
    kg = k_pool[safe].reshape(b, max_pages * page_size, hkv, dh)
    vg = v_pool[safe].reshape(b, max_pages * page_size, hkv, dh)
    lv = jnp.asarray(length, jnp.int32)
    if lv.ndim == 0:
        lv = jnp.full((b,), lv, jnp.int32)
    return decode_ref(q, kg, vg, length=lv, scale=scale)


def decode_ref(q: jax.Array, k: jax.Array, v: jax.Array, *,
               length, scale: float | None = None) -> jax.Array:
    """Pure-jnp oracle for `gqa_decode_attention` (materialized logits).
    ``length`` is a scalar or a (B,) per-sequence vector."""
    b, hq, dh = q.shape
    _, kl, hkv, _ = k.shape
    g = hq // hkv
    if scale is None:
        scale = 1.0 / (dh ** 0.5)
    qr = q.reshape(b, hkv, g, dh).astype(jnp.float32)
    kr = k.transpose(0, 2, 1, 3).astype(jnp.float32)    # (b, hkv, kl, dh)
    vr = v.transpose(0, 2, 1, 3).astype(jnp.float32)
    s = jnp.einsum("bhgd,bhkd->bhgk", qr, kr) * scale
    lv = jnp.asarray(length, jnp.int32)
    if lv.ndim == 0:
        lv = jnp.full((b,), lv, jnp.int32)
    valid = jnp.arange(kl)[None, :] < lv[:, None]       # (b, kl)
    s = jnp.where(valid[:, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhgk,bhkd->bhgd", p, vr)
    # A slot with no valid keys (length 0 — an idle continuous-batching
    # slot) outputs zeros, matching the kernel's fully-masked-row path;
    # softmax over an all-masked row would otherwise fabricate uniform
    # attention onto garbage cache contents.
    out = jnp.where((lv > 0)[:, None, None, None], out, 0.0)
    return out.reshape(b, hq, dh).astype(q.dtype)
