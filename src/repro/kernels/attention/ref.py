"""Pure-jnp oracle for flash attention (materialized-logits softmax)."""

import jax
import jax.numpy as jnp


def attention_ref(q, k, v, *, scale, causal=True, window=None):
    """q: (BH, Sq, dh); k, v: (BH, Sk, dh)."""
    s = jnp.einsum("bqd,bkd->bqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    sq, sk = s.shape[1], s.shape[2]
    q_pos = jnp.arange(sq)[:, None]
    k_pos = jnp.arange(sk)[None, :]
    ok = jnp.ones((sq, sk), bool)
    if causal:
        ok &= q_pos >= k_pos
    if window is not None:
        ok &= (q_pos - k_pos) < window
    s = jnp.where(ok[None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    # A q row with zero surviving keys (reachable only at sq > sk with a
    # window) outputs 0, matching the kernel's l-floor convention — not
    # the uniform-softmax mean a raw softmax over -1e30 logits yields.
    p = p * ok.any(axis=-1, keepdims=True)[None]
    return jnp.einsum("bqk,bkd->bqd", p,
                      v.astype(jnp.float32)).astype(q.dtype)
