"""Flash attention (forward) Pallas kernel.

The prefill hot spot: (Sq, Sk) logits never leave VMEM.  Online-softmax
carries (m, l, acc) in VMEM scratch across the K-block grid axis; Q/K/V
blocks stream with Pallas double-buffering (eq.2's doubled B buffer again —
traffic is independent of the K-block depth, so the block sizes come from the
same VMEM-constrained solver family as the matmul kernel).

Supports causal masking, sliding windows, and GQA (grouped q heads fold into
the q-block row axis).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                  scale: float, causal: bool, window: int | None,
                  block_q: int, block_k: int, k_steps: int):
    qi = pl.program_id(1)
    kj = pl.program_id(2)

    @pl.when(kj == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0]                                     # (block_q, dh)
    k = k_ref[0]                                     # (block_k, dh)
    v = v_ref[0]                                     # (block_k, dh)
    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32) * scale  # (block_q, block_k)

    q_pos = qi * block_q + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
    k_pos = kj * block_k + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    ok = jnp.ones(s.shape, jnp.bool_)
    if causal:
        ok &= q_pos >= k_pos
    if window is not None:
        ok &= (q_pos - k_pos) < window
    s = jnp.where(ok, s, NEG_INF)

    m_prev = m_ref[...]                              # (block_q, 1)
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
    p = jnp.exp(s - m_new)
    corr = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=1, keepdims=True)
    m_ref[...] = m_new
    acc_ref[...] = acc_ref[...] * corr + jax.lax.dot_general(
        p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)

    @pl.when(kj == k_steps - 1)
    def _store():
        o_ref[0] = (acc_ref[...] /
                    jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    scale: float, causal: bool = True,
                    window: int | None = None,
                    block_q: int = 512, block_k: int = 512,
                    interpret: bool = False) -> jax.Array:
    """q: (BH, Sq, dh); k, v: (BH, Sk, dh) — heads pre-folded into batch.

    GQA callers tile/fold so q and kv agree on the BH axis (see ops.py).
    """
    bh, sq, dh = q.shape
    _, sk, _ = k.shape
    block_q = min(block_q, sq)
    block_k = min(block_k, sk)
    assert sq % block_q == 0 and sk % block_k == 0, (sq, sk, block_q, block_k)
    k_steps = sk // block_k
    grid = (bh, sq // block_q, k_steps)

    fn = functools.partial(
        _flash_kernel, scale=scale, causal=causal, window=window,
        block_q=block_q, block_k=block_k, k_steps=k_steps)
    return pl.pallas_call(
        fn,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, dh), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, block_k, dh), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, block_k, dh), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, dh), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, sq, dh), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, dh), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
