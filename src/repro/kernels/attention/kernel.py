"""Flash attention (forward) Pallas kernel, with mask-driven block skipping.

The prefill hot spot: (Sq, Sk) logits never leave VMEM.  Online-softmax
carries (m, l, acc) in VMEM scratch across the K-block grid axis; Q/K/V
blocks stream with Pallas double-buffering (eq.2's doubled B buffer again —
traffic is independent of the K-block depth, so the block sizes come from the
same VMEM-constrained solver family as the matmul kernel).

Masked work is free: each q-block's active K-step range
[`first`, `last`] is derived from the causal/sliding-window mask
(`core.cost_model.attention_step_bounds` is the shared block-level law).
The grid's K axis is sized to the *widest* active range
(`attention_max_k_steps` — a window shrinks it outright), the K/V index
maps clamp into the active range so skipped blocks are never streamed into
VMEM (Pallas elides the DMA when consecutive grid steps map to the same
block), and a `@pl.when` guard skips their FLOPs.  Causal prefill at sq=sk
runs the block triangle — ~2x fewer K-steps than the dense grid.

Ragged shapes are padded: q rows up to a block_q multiple (tail rows are
sliced off the output), K/V up to a block_k multiple (tail keys masked via
the true kv length), so tuned (block_q, block_k) plans apply to any prefill
length instead of tripping a divisibility assert.

Supports causal masking, sliding windows, and GQA (grouped q heads fold into
the q-block row axis).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.cost_model import attention_max_k_steps

NEG_INF = -1e30


def _first_step(qi, *, block_q: int, block_k: int, k_steps: int,
                window: int | None):
    """First active K-step for q-block ``qi`` (traced mirror of
    `cost_model.attention_step_bounds`)."""
    if window is None:
        return qi * 0
    return jnp.clip((qi * block_q - window + 1) // block_k, 0, k_steps - 1)


def _last_step(qi, *, block_q: int, block_k: int, k_steps: int, causal: bool):
    """Last active K-step for q-block ``qi``."""
    if not causal:
        return qi * 0 + (k_steps - 1)
    return jnp.minimum(k_steps - 1, ((qi + 1) * block_q - 1) // block_k)


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                  scale: float, causal: bool, window: int | None,
                  block_q: int, block_k: int, k_steps: int, grid_k: int,
                  kv_len: int, skip: bool):
    qi = pl.program_id(1)
    jj = pl.program_id(2)

    @pl.when(jj == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    if skip:
        first = _first_step(qi, block_q=block_q, block_k=block_k,
                            k_steps=k_steps, window=window)
        last = _last_step(qi, block_q=block_q, block_k=block_k,
                          k_steps=k_steps, causal=causal)
        kj = first + jj
        active = kj <= last
    else:
        kj = jj
        active = jj >= 0          # trivially true, keeps one code path

    @pl.when(active)
    def _compute():
        q = q_ref[0]                                     # (block_q, dh)
        k = k_ref[0]                                     # (block_k, dh)
        v = v_ref[0]                                     # (block_k, dh)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale  # (block_q, block_k)

        q_pos = qi * block_q + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
        k_pos = kj * block_k + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        ok = jnp.ones(s.shape, jnp.bool_)
        if causal:
            ok &= q_pos >= k_pos
        if window is not None:
            ok &= (q_pos - k_pos) < window
        if kv_len < k_steps * block_k:   # padded K/V tail
            ok &= k_pos < kv_len
        s = jnp.where(ok, s, NEG_INF)

        m_prev = m_ref[...]                              # (block_q, 1)
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        p = jnp.exp(s - m_new)
        # Rows with no surviving key yet sit at m == NEG_INF; exp(s - m)
        # would turn fully-masked logits into 1s.  Zero them so l stays 0
        # and the store's l-floor makes such rows output 0 — the pinned
        # convention for degenerate rows (padded q/K tails, and window
        # rows beyond the cache at sq > sk), shared with `ref.attention_ref`.
        p = jnp.where(m_new <= NEG_INF, 0.0, p)
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=1, keepdims=True)
        m_ref[...] = m_new
        acc_ref[...] = acc_ref[...] * corr + jax.lax.dot_general(
            p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)

    @pl.when(jj == grid_k - 1)
    def _store():
        o_ref[0] = (acc_ref[...] /
                    jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    scale: float, causal: bool = True,
                    window: int | None = None,
                    block_q: int = 512, block_k: int = 512,
                    interpret: bool = False,
                    block_skipping: bool = True) -> jax.Array:
    """q: (BH, Sq, dh); k, v: (BH, Sk, dh) — heads pre-folded into batch.

    GQA callers tile/fold so q and kv agree on the BH axis (see ops.py).
    ``block_skipping=False`` forces the dense every-block grid (the
    pre-skipping kernel) — kept for A/B benchmarking of the skip credit.
    """
    bh, sq, dh = q.shape
    _, sk, _ = k.shape
    block_q = min(block_q, sq)
    block_k = min(block_k, sk)
    q_pad = -sq % block_q
    k_pad = -sk % block_k
    if q_pad:
        q = jnp.pad(q, ((0, 0), (0, q_pad), (0, 0)))
    if k_pad:
        k = jnp.pad(k, ((0, 0), (0, k_pad), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, k_pad), (0, 0)))
    sq_p, sk_p = sq + q_pad, sk + k_pad
    k_steps = sk_p // block_k
    q_blocks = sq_p // block_q

    skip = block_skipping and (causal or window is not None)
    # The grid's K axis covers only the widest active range; per-q-block
    # offsets and @pl.when guards do the rest.  Bounds use the padded q
    # range so tail (sliced-off) rows stay inside the grid.
    grid_k = (attention_max_k_steps(sq_p, sk_p, block_q, block_k,
                                    causal=causal, window=window)
              if skip else k_steps)
    grid = (bh, q_blocks, grid_k)

    if skip:
        def kv_index(b, i, j):
            first = _first_step(i, block_q=block_q, block_k=block_k,
                                k_steps=k_steps, window=window)
            last = _last_step(i, block_q=block_q, block_k=block_k,
                              k_steps=k_steps, causal=causal)
            # Clamp into the active range: out-of-range grid steps revisit
            # the last active block, so Pallas never streams it again.
            return (b, jnp.minimum(first + j, last), 0)
    else:
        def kv_index(b, i, j):
            return (b, j, 0)

    fn = functools.partial(
        _flash_kernel, scale=scale, causal=causal, window=window,
        block_q=block_q, block_k=block_k, k_steps=k_steps, grid_k=grid_k,
        kv_len=sk, skip=skip)
    out = pl.pallas_call(
        fn,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, dh), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, block_k, dh), kv_index),
            pl.BlockSpec((1, block_k, dh), kv_index),
        ],
        out_specs=pl.BlockSpec((1, block_q, dh), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, sq_p, dh), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, dh), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
    return out[:, :sq] if q_pad else out
