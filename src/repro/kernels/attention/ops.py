"""Public flash-attention wrapper with GQA folding and backend dispatch."""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp

from repro.kernels.attention import kernel, ref


@functools.partial(jax.jit, static_argnames=(
    "causal", "window", "block_q", "block_k", "interpret", "use_kernel"))
def mha_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                  causal: bool = True, window: int | None = None,
                  block_q: int = 512, block_k: int = 512,
                  interpret: bool = False, use_kernel: bool | None = None):
    """q: (B, Sq, Hq, dh); k, v: (B, Sk, Hkv, dh) -> (B, Sq, Hq, dh)."""
    b, sq, hq, dh = q.shape
    _, sk, hkv, _ = k.shape
    g = hq // hkv
    scale = 1.0 / math.sqrt(dh)
    if use_kernel is None:
        use_kernel = interpret or jax.default_backend() == "tpu"

    # Fold heads into batch; repeat KV across the GQA group.
    qf = q.transpose(0, 2, 1, 3).reshape(b * hq, sq, dh)
    kf = jnp.repeat(k.transpose(0, 2, 1, 3), g, axis=0).reshape(b * hq, sk, dh)
    vf = jnp.repeat(v.transpose(0, 2, 1, 3), g, axis=0).reshape(b * hq, sk, dh)

    if use_kernel:
        out = kernel.flash_attention(
            qf, kf, vf, scale=scale, causal=causal, window=window,
            block_q=block_q, block_k=block_k, interpret=interpret)
    else:
        out = ref.attention_ref(qf, kf, vf, scale=scale, causal=causal,
                                window=window)
    return out.reshape(b, hq, sq, dh).transpose(0, 2, 1, 3)
