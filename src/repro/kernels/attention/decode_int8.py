"""Int8 quantized-streaming variant of the fused decode-attention kernel.

Same online-softmax / per-row valid-prefix-skip structure as
`decode.py`, but the KV cache is stored and **streamed as int8** with
one f32 scale per token row per KV head (`runtime/quantize.py`):
each grid step fetches an int8 K/V block plus its (block_k,) scale
vector, dequantizes **in register** (``q8.astype(f32) * scale[:, None]``
— the Pallas int8 pattern: upcast once in VMEM, never in HBM), and
accumulates in f32.  Streamed bytes per token per KV head drop from
``2 * dh * itemsize`` to ``dh + 4`` for each of K and V — ~1.88x at
dh = 64 — at a bounded accuracy cost (half a quantization step per
element, see the quantize module; the `decode_int8` bench row gates
both numbers in CI).

Tokens are quantized once at cache-write time (`models/layers.py`
scatter-on-write), so this kernel never quantizes — it only streams and
dequantizes.  The q rows stay in float and the contraction accumulates
in f32 (`preferred_element_type`), mirroring the bf16-stream /
f32-accumulate matmul path.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.attention.decode import NEG_INF, _row_lengths, decode_ref
from repro.runtime import quantize


def _quantized_decode_kernel(len_ref, q_ref, kq_ref, ks_ref, vq_ref, vs_ref,
                             o_ref, m_ref, l_ref, acc_ref, *,
                             scale: float, block_k: int, k_steps: int):
    bb = pl.program_id(0)
    jj = pl.program_id(1)
    length = len_ref[bb]
    last = jnp.maximum(0, (length - 1) // block_k)

    @pl.when(jj == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    @pl.when(jj <= last)
    def _compute():
        q = q_ref[0].astype(jnp.float32)                 # (g, dh)
        # In-register dequant: int8 block * per-row f32 scale.
        k = kq_ref[0].astype(jnp.float32) * ks_ref[0][:, None]
        v = vq_ref[0].astype(jnp.float32) * vs_ref[0][:, None]
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale  # (g, block_k)
        k_pos = jj * block_k + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        s = jnp.where(k_pos < length, s, NEG_INF)

        m_prev = m_ref[...]                              # (g, 1)
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        p = jnp.exp(s - m_new)
        p = jnp.where(m_new <= NEG_INF, 0.0, p)          # fully-masked block
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=1, keepdims=True)
        m_ref[...] = m_new
        acc_ref[...] = acc_ref[...] * corr + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)

    @pl.when(jj == k_steps - 1)
    def _store():
        o_ref[0] = (acc_ref[...] /
                    jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


def quantized_decode_attention(q: jax.Array, kq: jax.Array, ks: jax.Array,
                               vq: jax.Array, vs: jax.Array, *,
                               scale: float, length, block_k: int = 512,
                               interpret: bool = False) -> jax.Array:
    """q: (BKV, g, dh) float; kq, vq: (BKV, L, dh) int8;
    ks, vs: (BKV, L) f32 per-row scales; length as in `decode_attention`.

    The int8 cache is streamed verbatim — the q rows are NOT upcast to
    the cache dtype (that is the whole point); they run in f32 against
    the in-register dequantized blocks.
    """
    out_dtype = q.dtype
    q = q.astype(jnp.float32)
    bkv, g, dh = q.shape
    _, kl, _ = kq.shape
    block_k = min(block_k, kl)
    k_pad = -kl % block_k
    if k_pad:
        kq = jnp.pad(kq, ((0, 0), (0, k_pad), (0, 0)))
        vq = jnp.pad(vq, ((0, 0), (0, k_pad), (0, 0)))
        ks = jnp.pad(ks, ((0, 0), (0, k_pad)))
        vs = jnp.pad(vs, ((0, 0), (0, k_pad)))
    k_steps = (kl + k_pad) // block_k
    lengths = _row_lengths(length, bkv, kl)

    def kv_index(b, j, len_ref):
        last = jnp.maximum(0, (len_ref[b] - 1) // block_k)
        return (b, jnp.minimum(j, last), 0)

    def scale_index(b, j, len_ref):
        last = jnp.maximum(0, (len_ref[b] - 1) // block_k)
        return (b, jnp.minimum(j, last))

    fn = functools.partial(_quantized_decode_kernel, scale=scale,
                           block_k=block_k, k_steps=k_steps)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(bkv, k_steps),
        in_specs=[
            pl.BlockSpec((1, g, dh), lambda b, j, len_ref: (b, 0, 0)),
            pl.BlockSpec((1, block_k, dh), kv_index),
            pl.BlockSpec((1, block_k), scale_index),
            pl.BlockSpec((1, block_k, dh), kv_index),
            pl.BlockSpec((1, block_k), scale_index),
        ],
        out_specs=pl.BlockSpec((1, g, dh), lambda b, j, len_ref: (b, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((g, 1), jnp.float32),
            pltpu.VMEM((g, 1), jnp.float32),
            pltpu.VMEM((g, dh), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        fn,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((bkv, g, dh), jnp.float32),
        interpret=interpret,
    )(lengths, q, kq, ks, vq, vs)
    return out.astype(out_dtype)


def quantized_gqa_decode_attention(q: jax.Array, kq: jax.Array,
                                   ks: jax.Array, vq: jax.Array,
                                   vs: jax.Array, *, length,
                                   scale: float | None = None,
                                   block_k: int = 512,
                                   interpret: bool = False) -> jax.Array:
    """q: (B, Hq, dh); kq, vq: (B, L, Hkv, dh) int8;
    ks, vs: (B, L, Hkv) f32 -> (B, Hq, dh).

    The GQA fold mirrors `gqa_decode_attention`: the group becomes the
    q-row axis per KV head and the int8 cache (with its scales) is
    streamed once per KV head, never repeated.
    """
    b, hq, dh = q.shape
    _, kl, hkv, _ = kq.shape
    g = hq // hkv
    if scale is None:
        scale = 1.0 / (dh ** 0.5)
    lv = jnp.asarray(length, jnp.int32)
    if lv.ndim == 1:
        if lv.shape != (b,):
            raise ValueError(
                f"length must be a scalar or a ({b},) per-sequence vector, "
                f"got shape {lv.shape}")
        length = jnp.repeat(lv, hkv)
    qf = q.reshape(b, hkv, g, dh).reshape(b * hkv, g, dh)
    kqf = kq.transpose(0, 2, 1, 3).reshape(b * hkv, kl, dh)
    vqf = vq.transpose(0, 2, 1, 3).reshape(b * hkv, kl, dh)
    ksf = ks.transpose(0, 2, 1).reshape(b * hkv, kl)
    vsf = vs.transpose(0, 2, 1).reshape(b * hkv, kl)
    out = quantized_decode_attention(qf, kqf, ksf, vqf, vsf, scale=scale,
                                     length=length, block_k=block_k,
                                     interpret=interpret)
    return out.reshape(b, hkv, g, dh).reshape(b, hq, dh)


def _paged_quantized_decode_kernel(len_ref, pt_ref, q_ref, kq_ref, ks_ref,
                                   vq_ref, vs_ref, o_ref,
                                   m_ref, l_ref, acc_ref, *,
                                   scale: float, page_size: int,
                                   max_pages: int):
    """Paged variant: the k axis walks the slot's page table (the index
    maps translate grid step -> physical pool page, as in
    `_paged_decode_kernel`); each fetched page dequantizes in register."""
    del pt_ref
    bb = pl.program_id(0)
    jj = pl.program_id(1)
    length = len_ref[bb]
    last = jnp.maximum(0, (length - 1) // page_size)

    @pl.when(jj == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    @pl.when(jj <= last)
    def _compute():
        q = q_ref[0].astype(jnp.float32)                 # (g, dh)
        k = kq_ref[0, :, 0].astype(jnp.float32) * ks_ref[0, :, 0][:, None]
        v = vq_ref[0, :, 0].astype(jnp.float32) * vs_ref[0, :, 0][:, None]
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale  # (g, page_size)
        k_pos = jj * page_size + jax.lax.broadcasted_iota(jnp.int32,
                                                          s.shape, 1)
        s = jnp.where(k_pos < length, s, NEG_INF)

        m_prev = m_ref[...]                              # (g, 1)
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        p = jnp.exp(s - m_new)
        p = jnp.where(m_new <= NEG_INF, 0.0, p)          # fully-masked page
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=1, keepdims=True)
        m_ref[...] = m_new
        acc_ref[...] = acc_ref[...] * corr + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)

    @pl.when(jj == max_pages - 1)
    def _store():
        o_ref[0] = (acc_ref[...] /
                    jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


def paged_quantized_gqa_decode_attention(
        q: jax.Array, kq_pool: jax.Array, ks_pool: jax.Array,
        vq_pool: jax.Array, vs_pool: jax.Array, pages: jax.Array, *,
        length, scale: float | None = None,
        interpret: bool = False) -> jax.Array:
    """Fused decode attention through an int8 paged KV cache.

    q: (B, Hq, dh); kq_pool, vq_pool: (num_pages, page_size, Hkv, dh)
    int8; ks_pool, vs_pool: (num_pages, page_size, Hkv) f32 per-row
    scales; pages: (B, max_pages) int32 page table; length: (B,) valid
    prefixes.  Returns (B, Hq, dh).  Page-table translation and the
    per-slot skip law are identical to `paged_gqa_decode_attention`; the
    scale pools ride two extra inputs whose index maps drop the dh axis.
    """
    out_dtype = q.dtype
    q = q.astype(jnp.float32)
    b, hq, dh = q.shape
    num_pages, page_size, hkv, _ = kq_pool.shape
    max_pages = pages.shape[1]
    g = hq // hkv
    if scale is None:
        scale = 1.0 / (dh ** 0.5)
    lengths = _row_lengths(length, b, max_pages * page_size)
    lengths = jnp.repeat(lengths, hkv)              # row r -> slot r // hkv
    pt = jnp.asarray(pages, jnp.int32)
    qf = q.reshape(b, hkv, g, dh).reshape(b * hkv, g, dh)
    bkv = b * hkv

    def kv_index(r, j, len_ref, pt_ref):
        last = jnp.maximum(0, (len_ref[r] - 1) // page_size)
        page = pt_ref[r // hkv, jnp.minimum(j, last)]
        return (jnp.clip(page, 0, num_pages - 1), 0, r % hkv, 0)

    def scale_index(r, j, len_ref, pt_ref):
        last = jnp.maximum(0, (len_ref[r] - 1) // page_size)
        page = pt_ref[r // hkv, jnp.minimum(j, last)]
        return (jnp.clip(page, 0, num_pages - 1), 0, r % hkv)

    fn = functools.partial(_paged_quantized_decode_kernel, scale=scale,
                           page_size=page_size, max_pages=max_pages)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(bkv, max_pages),
        in_specs=[
            pl.BlockSpec((1, g, dh), lambda r, j, len_ref, pt_ref: (r, 0, 0)),
            pl.BlockSpec((1, page_size, 1, dh), kv_index),
            pl.BlockSpec((1, page_size, 1), scale_index),
            pl.BlockSpec((1, page_size, 1, dh), kv_index),
            pl.BlockSpec((1, page_size, 1), scale_index),
        ],
        out_specs=pl.BlockSpec((1, g, dh),
                               lambda r, j, len_ref, pt_ref: (r, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((g, 1), jnp.float32),
            pltpu.VMEM((g, 1), jnp.float32),
            pltpu.VMEM((g, dh), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        fn,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((bkv, g, dh), jnp.float32),
        interpret=interpret,
    )(lengths, pt, qf, kq_pool, ks_pool, vq_pool, vs_pool)
    return out.reshape(b, hkv, g, dh).reshape(b, hq, dh).astype(out_dtype)


def quantized_decode_ref(q: jax.Array, kq: jax.Array, ks: jax.Array,
                         vq: jax.Array, vs: jax.Array, *, length,
                         scale: float | None = None) -> jax.Array:
    """Pure-jnp oracle: dequantize the whole cache (what the kernel does
    block-by-block in register), then reuse `decode_ref`."""
    k = quantize.dequantize_rows(kq, ks)
    v = quantize.dequantize_rows(vq, vs)
    return decode_ref(q.astype(jnp.float32), k, v, length=length,
                      scale=scale).astype(q.dtype)


def paged_quantized_decode_ref(q: jax.Array, kq_pool: jax.Array,
                               ks_pool: jax.Array, vq_pool: jax.Array,
                               vs_pool: jax.Array, pages: jax.Array, *,
                               length,
                               scale: float | None = None) -> jax.Array:
    """Oracle for the paged variant: gather each slot's pages (values and
    scales) into a contiguous view, dequantize, `decode_ref`."""
    b = q.shape[0]
    num_pages, page_size, hkv, dh = kq_pool.shape
    max_pages = pages.shape[1]
    safe = jnp.clip(jnp.asarray(pages, jnp.int32), 0, num_pages - 1)
    kg = kq_pool[safe].reshape(b, max_pages * page_size, hkv, dh)
    vg = vq_pool[safe].reshape(b, max_pages * page_size, hkv, dh)
    ksg = ks_pool[safe].reshape(b, max_pages * page_size, hkv)
    vsg = vs_pool[safe].reshape(b, max_pages * page_size, hkv)
    lv = jnp.asarray(length, jnp.int32)
    if lv.ndim == 0:
        lv = jnp.full((b,), lv, jnp.int32)
    return quantized_decode_ref(q, kg, ksg, vg, vsg, length=lv, scale=scale)
