"""KernelSpec registrations for the flash-attention families.

Two specs live here: ``attention`` (the block-skipping prefill kernel,
knobs = (block_q, block_k)) and ``decode`` (the fused single-query
KV-cache kernel, knob = block_k).  Candidate enumeration moved out of
`core/dse.py`'s `rank_attention_blocks`/`rank_decode_blocks`; the cost
wrappers delegate to `cost_model.attention_time_model` /
`decode_time_model`.  Both families dispatch inside jit traces at serving
time, so their ``default_measure_k`` is 0 — measured winners come from
offline callers (benchmarks) through the shared cache.
"""

from __future__ import annotations

from typing import Sequence

import jax

from repro.core import cost_model, dse, hardware
from repro.kernels import registry
from repro.kernels.attention import decode as attn_decode
from repro.kernels.attention import decode_int8 as attn_decode_int8
from repro.kernels.attention import kernel as attn_kernel
from repro.kernels.attention import ops as attn_ops
from repro.runtime import quantize


# ---------------------------------------------------------------------------
# Prefill flash attention
# ---------------------------------------------------------------------------

def rank_attention_blocks(
    bh: int, sq: int, sk: int, dh: int,
    vmem_bytes: int | None = None,
    dtype_bytes: int = 2,
    causal: bool = True,
    window: int | None = None,
    block_cands: Sequence[int] = (128, 256, 512, 1024),
    top: int = 8,
) -> list[dse.Candidate]:
    """Sweep (block_q, block_k) pairs for the flash-attention kernel; score
    with `cost_model.attention_time_model` under the VMEM budget.

    The kernel clamps blocks to the sequence (``min(block, s)``) and pads
    ragged remainders, so candidates are enumerated in *effective* block
    space and deduped — a 64-token prefill collapses every block_q
    candidate onto 64.  The mask enters the score: with block skipping the
    model credits the causal triangle / window band, so the ranking trades
    deeper q-blocks (less K/V re-streaming) against coarser masked-area
    coverage instead of assuming every block runs.  Ranking is
    deterministic: model time with (block_q, block_k) as the tie-break,
    descending block_q preferred on ties.  Each ``Candidate.detail``
    carries the effective blocks plus the model row.  Never returns empty:
    if the budget rejects everything, the smallest legal pair is scored and
    returned anyway (the kernel itself is the final arbiter on real VMEM).
    """
    chip = hardware.TPU_V5E
    budget = vmem_bytes if vmem_bytes is not None else chip.usable_vmem()

    # The kernel pads ragged remainders (and masks the tail), so candidates
    # need not divide the sequence — enumerate effective (clamped) blocks
    # and dedupe; a 64-token prefill still collapses onto a single pair.
    pairs = []
    seen = set()
    for bq in block_cands:
        for bk in block_cands:
            ebq, ebk = min(bq, sq), min(bk, sk)
            if (ebq, ebk) in seen:
                continue
            seen.add((ebq, ebk))
            pairs.append({"block_q": ebq, "block_k": ebk})

    def evaluate(knobs: dict) -> tuple[float, dict]:
        res = cost_model.attention_time_model(
            bh, sq, sk, dh, knobs["block_q"], knobs["block_k"],
            causal=causal, window=window, dtype_bytes=dtype_bytes)
        if res["vmem_bytes"] > budget:
            return float("inf"), {}
        return res["time_s"], {**knobs, **res}

    # Score ALL pairs before truncating: explore()'s internal top-cut is
    # insertion-ordered on ties, which would drop the deeper-block_q
    # candidates the tie-break below exists to prefer.
    ranked = dse.explore(pairs, evaluate, top=len(pairs))
    ranked = [c for c in ranked if c.detail and "block_q" in c.detail]
    ranked.sort(key=lambda c: (c.score, -c.detail["block_q"],
                               c.detail["block_k"]))
    if not ranked:
        knobs = min(pairs, key=lambda p: (p["block_q"], p["block_k"]))
        res = cost_model.attention_time_model(
            bh, sq, sk, dh, knobs["block_q"], knobs["block_k"],
            causal=causal, window=window, dtype_bytes=dtype_bytes)
        ranked = [dse.Candidate(knobs, res["time_s"], {**knobs, **res})]
    return ranked[:top]


def _attn_key_fn(problem: dict, dtype: str, backend: str) -> str:
    window = problem["window"]
    return (f"{problem['bh']}x{problem['sq']}x{problem['sk']}"
            f"x{problem['dh']}:c{int(problem['causal'])}"
            f":w{'none' if window is None else window}:{dtype}:{backend}")


def _attn_enumerate(problem: dict, dtype_bytes: int,
                    vmem_bytes: int | None, top: int) -> list[dse.Candidate]:
    # Over-request so the ENGINE's (score, tie_break) sort performs the
    # authoritative top-cut (the ranker's internal order serves only the
    # standalone deprecated rank_* API).
    ranked = rank_attention_blocks(
        problem["bh"], problem["sq"], problem["sk"], problem["dh"],
        vmem_bytes=vmem_bytes, dtype_bytes=dtype_bytes,
        causal=problem["causal"], window=problem["window"],
        top=max(top, 8))
    return [dse.Candidate({"block_q": c.detail["block_q"],
                           "block_k": c.detail["block_k"]}, c.score, {})
            for c in ranked]


def _attn_cost_fn(problem: dict, knobs: dict, dtype_bytes: int = 2) -> dict:
    return cost_model.attention_time_model(
        problem["bh"], problem["sq"], problem["sk"], problem["dh"],
        knobs["block_q"], knobs["block_k"], causal=problem["causal"],
        window=problem["window"], dtype_bytes=dtype_bytes)


def _attn_make_inputs(problem: dict, dtype) -> tuple:
    bh, sq, sk, dh = (problem["bh"], problem["sq"], problem["sk"],
                      problem["dh"])
    q = jax.random.normal(jax.random.PRNGKey(0), (bh, sq, dh), dtype)
    k = jax.random.normal(jax.random.PRNGKey(1), (bh, sk, dh), dtype)
    v = jax.random.normal(jax.random.PRNGKey(2), (bh, sk, dh), dtype)
    return q, k, v


def _attn_build_launcher(problem: dict, knobs: dict, interpret: bool):
    scale = 1.0 / (problem["dh"] ** 0.5)
    return lambda q, k, v: attn_kernel.flash_attention(
        q, k, v, scale=scale, causal=problem["causal"],
        window=problem["window"], block_q=knobs["block_q"],
        block_k=knobs["block_k"], interpret=interpret)


def _attn_problem_fn(q, k, v, causal=True, window=None) -> tuple[dict, object]:
    b, sq, hq, dh = q.shape
    _, sk, _, _ = k.shape
    return {"bh": b * hq, "sq": sq, "sk": sk, "dh": dh,
            "causal": causal, "window": window}, q.dtype


def _attn_run_fn(plan: registry.Plan, q, k, v, *, interpret=False,
                 causal=True, window=None):
    return attn_ops.mha_attention(q, k, v, causal=causal, window=window,
                                  block_q=plan.knobs["block_q"],
                                  block_k=plan.knobs["block_k"],
                                  interpret=interpret, use_kernel=True)


def _attn_reference_fn(q, k, v, causal=True, window=None):
    return attn_ops.mha_attention(q, k, v, causal=causal, window=window,
                                  use_kernel=False)


registry.register(registry.KernelSpec(
    name="attention",
    key_fn=_attn_key_fn,
    enumerate_candidates=_attn_enumerate,
    cost_fn=_attn_cost_fn,
    make_inputs=_attn_make_inputs,
    build_launcher=_attn_build_launcher,
    reference_fn=_attn_reference_fn,
    problem_fn=_attn_problem_fn,
    run_fn=_attn_run_fn,
    measure_elems=lambda p: p["bh"] * (p["sq"] + 2 * p["sk"]) * p["dh"],
    tie_break=lambda knobs: (-knobs["block_q"], knobs["block_k"]),
    default_measure_k=0,     # dispatched inside the serving jit trace
    bench_key="attention_tuned_vs_fixed",
))


# ---------------------------------------------------------------------------
# Fused single-query decode attention
# ---------------------------------------------------------------------------

def rank_decode_blocks(
    bkv: int, g: int, kv_len: int, dh: int,
    vmem_bytes: int | None = None,
    dtype_bytes: int = 2,
    block_cands: Sequence[int] = (128, 256, 512, 1024, 2048),
    top: int = 8,
    lengths: Sequence[int] | None = None,
) -> list[dse.Candidate]:
    """Sweep block_k for the fused decode-attention kernel
    (kernels/attention/decode.py); score with
    `cost_model.decode_time_model` under the VMEM budget.

    ``bkv = batch*kv_heads`` folded rows, ``g`` the GQA query group riding
    each row, ``kv_len`` the KV-cache depth the server allocated.  The knob
    trades tail over-fetch (coarse block_k rounds the cache up) against
    grid-step count; ranking is deterministic — model time, then *larger*
    block_k on ties (fewer grid steps for the same traffic).  Never empty:
    the smallest candidate is scored unconditionally if the budget rejects
    everything (the kernel is the final arbiter on real VMEM).

    ``lengths`` (optional) is a ragged batch's per-sequence valid-prefix
    distribution: candidates are scored on each row's block-rounded
    *active prefix* instead of the full ``kv_len``, so a batch mixing
    shallow and deep slots prefers a finer block_k that lets the shallow
    rows skip — the fetched-vs-active load-balancing argument applied to
    the serving plan.
    """
    chip = hardware.TPU_V5E
    budget = vmem_bytes if vmem_bytes is not None else chip.usable_vmem()

    cands = sorted({min(bk, max(kv_len, 1)) for bk in block_cands})

    def evaluate(knobs: dict) -> tuple[float, dict]:
        res = cost_model.decode_time_model(bkv, g, kv_len, dh,
                                           knobs["block_k"],
                                           dtype_bytes=dtype_bytes,
                                           lengths=lengths)
        if res["vmem_bytes"] > budget:
            return float("inf"), {}
        return res["time_s"], {**knobs, **res}

    ranked = dse.explore([{"block_k": bk} for bk in cands], evaluate,
                         top=len(cands))
    ranked = [c for c in ranked if c.detail and "block_k" in c.detail]
    ranked.sort(key=lambda c: (c.score, -c.detail["block_k"]))
    if not ranked:
        bk = cands[0]
        res = cost_model.decode_time_model(bkv, g, kv_len, dh, bk,
                                           dtype_bytes=dtype_bytes,
                                           lengths=lengths)
        ranked = [dse.Candidate({"block_k": bk}, res["time_s"],
                                {"block_k": bk, **res})]
    return ranked[:top]


def _decode_key_fn(problem: dict, dtype: str, backend: str) -> str:
    # The optional per-slot length distribution is part of the key: a plan
    # tuned for a ragged workload must not shadow the batch-max one.
    lengths = problem.get("lengths")
    ltag = ("" if not lengths
            else ":l" + "-".join(str(int(l)) for l in lengths))
    return (f"{problem['bkv']}x{problem['g']}x{problem['cache_len']}"
            f"x{problem['dh']}{ltag}:{dtype}:{backend}")


def _decode_lengths(problem: dict) -> list[int] | None:
    lengths = problem.get("lengths")
    return list(lengths) if lengths else None


def _decode_enumerate(problem: dict, dtype_bytes: int,
                      vmem_bytes: int | None, top: int) -> list[dse.Candidate]:
    # Over-request: the engine's tie_break performs the authoritative cut.
    ranked = rank_decode_blocks(
        problem["bkv"], problem["g"], problem["cache_len"], problem["dh"],
        vmem_bytes=vmem_bytes, dtype_bytes=dtype_bytes, top=max(top, 8),
        lengths=_decode_lengths(problem))
    return [dse.Candidate({"block_k": c.detail["block_k"]}, c.score, {})
            for c in ranked]


def _decode_cost_fn(problem: dict, knobs: dict, dtype_bytes: int = 2) -> dict:
    return cost_model.decode_time_model(
        problem["bkv"], problem["g"], problem["cache_len"], problem["dh"],
        knobs["block_k"], dtype_bytes=dtype_bytes,
        lengths=_decode_lengths(problem))


def _decode_make_inputs(problem: dict, dtype) -> tuple:
    bkv, g, cache_len, dh = (problem["bkv"], problem["g"],
                             problem["cache_len"], problem["dh"])
    q = jax.random.normal(jax.random.PRNGKey(0), (bkv, g, dh), dtype)
    k = jax.random.normal(jax.random.PRNGKey(1), (bkv, cache_len, dh), dtype)
    v = jax.random.normal(jax.random.PRNGKey(2), (bkv, cache_len, dh), dtype)
    return q, k, v


def _decode_build_launcher(problem: dict, knobs: dict, interpret: bool):
    import numpy as np

    scale = 1.0 / (problem["dh"] ** 0.5)
    # Measured at the depths the plan is priced at: the per-row ragged
    # lengths when the problem carries a distribution (each sequence's
    # length repeated across its folded KV heads), else the full cache
    # depth — the worst case the server allocated for.
    lengths = _decode_lengths(problem)
    if lengths:
        rep = problem["bkv"] // len(lengths)
        length = np.repeat(np.asarray(lengths, np.int32), rep)
    else:
        length = problem["cache_len"]
    return lambda q, k, v: attn_decode.decode_attention(
        q, k, v, scale=scale, length=length,
        block_k=knobs["block_k"], interpret=interpret)


def _decode_problem_fn(q, k, v, length=None) -> tuple[dict, object]:
    b, hq, dh = q.shape
    _, kl, hkv, _ = k.shape
    # The kernel streams the cache (and upcasts q to it), so the plan is
    # keyed and priced on the *cache* dtype — an f32 cache costs twice the
    # KV traffic of a bf16 one regardless of the activation dtype.
    return {"bkv": b * hkv, "g": hq // hkv, "cache_len": kl,
            "dh": dh}, k.dtype


def _decode_run_fn(plan: registry.Plan, q, k, v, *, interpret=False,
                   length=None):
    return attn_decode.gqa_decode_attention(q, k, v, length=length,
                                            block_k=plan.knobs["block_k"],
                                            interpret=interpret)


registry.register(registry.KernelSpec(
    name="decode",
    key_fn=_decode_key_fn,
    enumerate_candidates=_decode_enumerate,
    cost_fn=_decode_cost_fn,
    make_inputs=_decode_make_inputs,
    build_launcher=_decode_build_launcher,
    reference_fn=lambda q, k, v, length=None: attn_decode.decode_ref(
        q, k, v, length=length),
    problem_fn=_decode_problem_fn,
    run_fn=_decode_run_fn,
    measure_elems=lambda p: p["bkv"] * (p["g"] + 2 * p["cache_len"])
    * p["dh"],
    tie_break=lambda knobs: (-knobs["block_k"],),
    default_measure_k=0,     # dispatched inside the serving jit trace
    bench_key="attention_decode",
))


# ---------------------------------------------------------------------------
# Int8 quantized-streaming decode attention (kernel family #5)
# ---------------------------------------------------------------------------
# The ~50-line KernelSpec recipe: the quantized kernel shares the decode
# family's problem shape and block_k knob, but streams int8 K/V + f32
# per-row scales and is priced by `quantized_decode_time_model` — whose
# honest scale-stream + dequant-FLOP accounting lets the DSE lose to the
# bf16 stream where it should (small dh, compute-bound corners).

def _decode_int8_key_fn(problem: dict, dtype: str, backend: str) -> str:
    # `q8` tags the quantized cache layout; `dtype` remains the activation
    # dtype the q rows and output carry.
    lengths = problem.get("lengths")
    ltag = ("" if not lengths
            else ":l" + "-".join(str(int(l)) for l in lengths))
    return (f"{problem['bkv']}x{problem['g']}x{problem['cache_len']}"
            f"x{problem['dh']}{ltag}:q8:{dtype}:{backend}")


def _decode_int8_enumerate(problem: dict, dtype_bytes: int,
                           vmem_bytes: int | None,
                           top: int) -> list[dse.Candidate]:
    chip = hardware.TPU_V5E
    budget = vmem_bytes if vmem_bytes is not None else chip.usable_vmem()
    kv_len = problem["cache_len"]
    cands = sorted({min(bk, max(kv_len, 1))
                    for bk in (128, 256, 512, 1024, 2048)})

    def evaluate(knobs: dict) -> tuple[float, dict]:
        res = _decode_int8_cost_fn(problem, knobs)
        if res["vmem_bytes"] > budget:
            return float("inf"), {}
        return res["time_s"], {**knobs, **res}

    ranked = dse.explore([{"block_k": bk} for bk in cands], evaluate,
                         top=len(cands))
    ranked = [c for c in ranked if c.detail and "block_k" in c.detail]
    ranked.sort(key=lambda c: (c.score, -c.detail["block_k"]))
    if not ranked:
        bk = cands[0]
        res = _decode_int8_cost_fn(problem, {"block_k": bk})
        ranked = [dse.Candidate({"block_k": bk}, res["time_s"],
                                {"block_k": bk, **res})]
    return [dse.Candidate({"block_k": c.detail["block_k"]}, c.score, {})
            for c in ranked[:top]]


def _decode_int8_cost_fn(problem: dict, knobs: dict,
                         dtype_bytes: int = 1) -> dict:
    # dtype_bytes is fixed by the layout (int8 values + f32 scales); the
    # engine's argument is accepted and ignored.
    return cost_model.quantized_decode_time_model(
        problem["bkv"], problem["g"], problem["cache_len"], problem["dh"],
        knobs["block_k"], lengths=_decode_lengths(problem))


def _decode_int8_make_inputs(problem: dict, dtype) -> tuple:
    bkv, g, cache_len, dh = (problem["bkv"], problem["g"],
                             problem["cache_len"], problem["dh"])
    q = jax.random.normal(jax.random.PRNGKey(0), (bkv, g, dh), dtype)
    k = jax.random.normal(jax.random.PRNGKey(1), (bkv, cache_len, dh))
    v = jax.random.normal(jax.random.PRNGKey(2), (bkv, cache_len, dh))
    kq, ks = quantize.quantize_rows(k)
    vq, vs = quantize.quantize_rows(v)
    return q, kq, ks, vq, vs


def _decode_int8_build_launcher(problem: dict, knobs: dict, interpret: bool):
    import numpy as np

    scale = 1.0 / (problem["dh"] ** 0.5)
    lengths = _decode_lengths(problem)
    if lengths:
        rep = problem["bkv"] // len(lengths)
        length = np.repeat(np.asarray(lengths, np.int32), rep)
    else:
        length = problem["cache_len"]
    return lambda q, kq, ks, vq, vs: attn_decode_int8.quantized_decode_attention(
        q, kq, ks, vq, vs, scale=scale, length=length,
        block_k=knobs["block_k"], interpret=interpret)


def _decode_int8_problem_fn(q, kq, ks, vq, vs,
                            length=None) -> tuple[dict, object]:
    b, hq, dh = q.shape
    _, kl, hkv, _ = kq.shape
    # The cache layout is fixed (int8 + f32 scales, tagged `q8` in the
    # key), so unlike the float decode family the plan keys on the
    # *activation* dtype the q rows carry.
    return {"bkv": b * hkv, "g": hq // hkv, "cache_len": kl,
            "dh": dh}, q.dtype


def _decode_int8_run_fn(plan: registry.Plan, q, kq, ks, vq, vs, *,
                        interpret=False, length=None):
    return attn_decode_int8.quantized_gqa_decode_attention(
        q, kq, ks, vq, vs, length=length,
        block_k=plan.knobs["block_k"], interpret=interpret)


registry.register(registry.KernelSpec(
    name="decode_int8",
    key_fn=_decode_int8_key_fn,
    enumerate_candidates=_decode_int8_enumerate,
    cost_fn=_decode_int8_cost_fn,
    make_inputs=_decode_int8_make_inputs,
    build_launcher=_decode_int8_build_launcher,
    reference_fn=lambda q, kq, ks, vq, vs, length=None:
        attn_decode_int8.quantized_decode_ref(q, kq, ks, vq, vs,
                                              length=length),
    problem_fn=_decode_int8_problem_fn,
    run_fn=_decode_int8_run_fn,
    measure_elems=lambda p: p["bkv"] * (p["g"] + 2 * p["cache_len"])
    * p["dh"],
    tie_break=lambda knobs: (-knobs["block_k"],),
    default_measure_k=0,     # dispatched inside the serving jit trace
    bench_key="decode_int8",
))
