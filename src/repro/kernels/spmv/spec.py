"""KernelSpec registration for the blocked ELL SpMV family.

Candidate enumeration (moved out of the old `autotune.rank_spmv_configs`),
the `spmv_time_model` cost wrapper fed with the active/fetched balance
metric, and the Pallas launcher — declared once, driven by the generic
engine.  The tuning problem carries the live `EllMatrix` (its packing
determines the balance metric); the cache key uses only its scalars plus
the layout fingerprint.
"""

from __future__ import annotations

from typing import Sequence

import jax

from repro.core import cost_model, dse, hardware
from repro.kernels import registry
from repro.kernels.spmv import ops as spmv_ops


def rank_configs(
    mat: spmv_ops.EllMatrix,
    vmem_bytes: int | None = None,
    block_rows_cands: Sequence[int] = (8, 16, 32, 64),
    block_cols_cands: Sequence[int | None] = (None, 256, 512, 1024, 2048),
) -> list[tuple[float, int, int | None, float]]:
    """Rank (block_rows, block_cols) configs by the bandwidth model.

    The active/fetched balance metric (`EllMatrix.sliced_waste`, built on
    `core.loadbalance`) enters the score as the fetch-amplification of the
    ELL payload — the tuner's analogue of the paper's "% of nnz per core"
    column.  Returns (score, block_rows, block_cols, waste) ascending,
    deterministically tie-broken.
    """
    budget = vmem_bytes if vmem_bytes is not None \
        else hardware.TPU_V5E.usable_vmem()
    rows, width = mat.cols.shape
    _, n = mat.shape
    out = []
    for br in block_rows_cands:
        if rows % br:
            continue
        waste = mat.sliced_waste(block_rows=br)
        for bc in block_cols_cands:
            if bc is not None and bc >= n + 128:
                continue  # slab larger than the vector: same as resident
            res = cost_model.spmv_time_model(rows, width, n, mat.nnz,
                                             block_rows=br, block_cols=bc,
                                             waste=waste)
            if res["vmem_bytes"] > budget:
                continue
            out.append((res["time_s"], br, bc, waste))
    out.sort(key=lambda r: (r[0], r[1], r[2] if r[2] is not None else 0))
    return out


def _key_fn(problem: dict, dtype: str, backend: str) -> str:
    mat = problem["mat"]
    rows, width = mat.cols.shape
    _, n = mat.shape
    return (f"{rows}x{width}:n{n}:nnz{mat.nnz}:l{mat.layout_fingerprint()}"
            f":{dtype}:{backend}")


def _enumerate(problem: dict, dtype_bytes: int, vmem_bytes: int | None,
               top: int) -> list[dse.Candidate]:
    mat = problem["mat"]
    ranked = rank_configs(mat, vmem_bytes=vmem_bytes)
    if not ranked:
        # Degenerate budget: fall back to the smallest legal blocked-x
        # config, scored normally so the cache entry stays finite JSON.
        rows, width = mat.cols.shape
        _, n = mat.shape
        fb = cost_model.spmv_time_model(rows, width, n, mat.nnz,
                                        block_rows=8, block_cols=256,
                                        waste=mat.padding_waste)
        ranked = [(fb["time_s"], 8, 256, mat.padding_waste)]
    return [dse.Candidate({"block_rows": br, "block_cols": bc}, score,
                          {"waste": waste})
            for score, br, bc, waste in ranked]


def _cost_fn(problem: dict, knobs: dict, dtype_bytes: int = 4) -> dict:
    mat = problem["mat"]
    rows, width = mat.cols.shape
    _, n = mat.shape
    return cost_model.spmv_time_model(
        rows, width, n, mat.nnz, block_rows=knobs["block_rows"],
        block_cols=knobs["block_cols"],
        waste=mat.sliced_waste(block_rows=knobs["block_rows"]))


def _measure_elems(problem: dict) -> int:
    mat = problem["mat"]
    rows, width = mat.cols.shape
    _, n = mat.shape
    return rows * width + n


def _make_inputs(problem: dict, dtype) -> tuple:
    _, n = problem["mat"].shape
    return (jax.random.normal(jax.random.PRNGKey(0), (n,), dtype),)


def _build_launcher(problem: dict, knobs: dict, interpret: bool):
    mat = problem["mat"]
    return lambda x: spmv_ops.spmv(mat, x, block_rows=knobs["block_rows"],
                                   block_cols=knobs["block_cols"],
                                   interpret=interpret, use_kernel=True)


def _problem_fn(mat, x) -> tuple[dict, object]:
    return {"mat": mat}, x.dtype


def _run_fn(plan: registry.Plan, mat, x, *, interpret=False):
    return spmv_ops.spmv(mat, x, block_rows=plan.knobs["block_rows"],
                         block_cols=plan.knobs["block_cols"],
                         interpret=interpret, use_kernel=True)


registry.register(registry.KernelSpec(
    name="spmv",
    key_fn=_key_fn,
    enumerate_candidates=_enumerate,
    cost_fn=_cost_fn,
    make_inputs=_make_inputs,
    build_launcher=_build_launcher,
    reference_fn=lambda mat, x: spmv_ops.spmv(mat, x, use_kernel=False),
    problem_fn=_problem_fn,
    run_fn=_run_fn,
    measure_elems=_measure_elems,
    tie_break=lambda knobs: (knobs["block_rows"],
                             knobs["block_cols"]
                             if knobs["block_cols"] is not None else 0),
    detail_keys=("waste",),
    default_measure_k=3,
    bench_key="spmv_tuned",
))
