"""Public SpMV API: host-side packing (balancing + ELL) and jitted dispatch."""

from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import loadbalance
from repro.kernels.spmv import kernel, ref


@dataclasses.dataclass(frozen=True)
class EllMatrix:
    """Padded ELL representation with a row permutation for balance."""

    cols: jax.Array        # (rows_padded, W) int32; pads point at column 0
    vals: jax.Array        # (rows_padded, W); pads are 0.0
    perm: np.ndarray       # packed row r holds original row perm[r]
    shape: tuple           # original (M, N)
    nnz: int
    row_lens: np.ndarray | None = None   # true packed-row lengths (CSR nnz)

    def _lens(self) -> np.ndarray:
        """Packed-row lengths; falls back to counting nonzero values for
        matrices built before row_lens existed (misses explicit zeros)."""
        if self.row_lens is not None:
            return self.row_lens
        return np.asarray((self.vals != 0).sum(axis=1))

    @property
    def padding_waste(self) -> float:
        """fetched / active — 1.0 is perfect (the balance-quality metric)."""
        total = self.cols.shape[0] * self.cols.shape[1]
        return total / max(self.nnz, 1)

    def layout_fingerprint(self) -> str:
        """Digest of the packed row-length layout.  Two packings of the same
        matrix (same nnz/shape, different permutation) fetch differently on
        SIMD hardware, so tuning results must not be shared between them."""
        import hashlib
        lens = np.asarray(self._lens(), np.int64)
        return hashlib.sha1(lens.tobytes()).hexdigest()[:12]

    def sliced_waste(self, block_rows: int = 8, align: int = 8) -> float:
        """fetched/active if each row BLOCK used its own width (sliced ELL,
        realizable with a per-block width array + masked k-chunks).  This is
        where the packing scheme matters on SIMD hardware: 'sorted' puts
        similar-length rows together and minimizes per-block max width."""
        lens = self._lens()
        fetched = 0
        for s in range(0, len(lens), block_rows):
            w = int(lens[s:s + block_rows].max()) if s < len(lens) else 0
            w = (w + align - 1) // align * align
            fetched += w * min(block_rows, len(lens) - s)
        return fetched / max(self.nnz, 1)


def pack_csr(indptr: np.ndarray, indices: np.ndarray, data: np.ndarray,
             shape: tuple, scheme: str = "round_robin",
             block_rows: int = 8, align: int = 128) -> EllMatrix:
    """CSR -> balanced ELL.  ``scheme`` is the paper's row-assignment law:
    'round_robin' (theirs), 'lpt' (greedy), or 'none' (natural order)."""
    m, n = shape
    nnz_per_row = np.diff(indptr)
    if scheme == "none":
        perm = np.arange(m)
    elif scheme == "sorted":
        # TPU adaptation of the paper's balancing law: on SIMD hardware the
        # imbalance cost is per-block *padding*, not per-core time, so the
        # optimal layout groups similar-length rows (descending sort).
        perm = np.argsort(-nnz_per_row, kind="stable")
    else:
        # Assign rows to block_rows-sized groups with the balancing law,
        # then lay groups out contiguously.
        groups = max(1, int(np.ceil(m / block_rows)))
        if scheme == "round_robin":
            assign = loadbalance.round_robin(nnz_per_row, groups)
        elif scheme == "lpt":
            assign = loadbalance.lpt(nnz_per_row, groups)
        else:
            raise ValueError(scheme)
        perm = np.argsort(assign, kind="stable")
    width = int(max(1, nnz_per_row.max()))
    width = (width + align - 1) // align * align
    rows_padded = (m + block_rows - 1) // block_rows * block_rows

    cols = np.zeros((rows_padded, width), np.int32)
    vals = np.zeros((rows_padded, width), data.dtype)
    row_lens = np.zeros(rows_padded, np.int64)
    for packed_r, orig_r in enumerate(perm):
        s, e = indptr[orig_r], indptr[orig_r + 1]
        cols[packed_r, : e - s] = indices[s:e]
        vals[packed_r, : e - s] = data[s:e]
        row_lens[packed_r] = e - s
    return EllMatrix(jnp.asarray(cols), jnp.asarray(vals), perm, shape,
                     int(nnz_per_row.sum()), row_lens)


@functools.partial(jax.jit, static_argnames=(
    "block_rows", "block_cols", "interpret", "use_kernel"))
def _spmv_packed(cols, vals, x_padded, block_rows, block_cols, interpret,
                 use_kernel):
    if use_kernel:
        if block_cols is not None:
            pad = (-x_padded.shape[0]) % block_cols
            return kernel.ell_spmv_blocked(
                jnp.pad(x_padded, (0, pad)), cols, vals,
                block_rows=block_rows, block_cols=block_cols,
                interpret=interpret)
        return kernel.ell_spmv(x_padded, cols, vals, block_rows=block_rows,
                               interpret=interpret)
    return ref.spmv_ell_ref(cols, vals, x_padded)


def spmv(mat: EllMatrix, x: jax.Array, block_rows: int = 8,
         block_cols: int | None = None, interpret: bool = False,
         use_kernel: bool | None = None) -> jax.Array:
    """y = A @ x.  Result is in ORIGINAL row order.

    ``block_cols=None`` keeps the whole x vector VMEM-resident (the original
    kernel, n limited by VMEM); an integer streams x in slabs of that many
    columns (``kernel.ell_spmv_blocked``), unlocking arbitrarily large n.
    """
    if use_kernel is None:
        use_kernel = interpret or jax.default_backend() == "tpu"
    m, n = mat.shape
    x_padded = x  # cols only reference valid columns
    y_packed = _spmv_packed(mat.cols, mat.vals, x_padded, block_rows,
                            block_cols, interpret, use_kernel)
    y = jnp.zeros((m,), y_packed.dtype)
    return y.at[jnp.asarray(mat.perm)].set(y_packed[: m])
