"""Pure-jnp oracle for sparse matrix-vector multiplication (CSR)."""

import jax
import jax.numpy as jnp


def spmv_csr_ref(indptr, indices, data, x, num_rows: int) -> jax.Array:
    """y = A @ x from CSR arrays (host-precomputed row ids)."""
    row_ids = jnp.repeat(
        jnp.arange(num_rows, dtype=jnp.int32),
        jnp.diff(indptr),
        total_repeat_length=indices.shape[0],
    )
    prods = data * x[indices]
    return jax.ops.segment_sum(prods, row_ids, num_segments=num_rows)


def spmv_ell_ref(ell_cols, ell_vals, x) -> jax.Array:
    """Oracle on the padded ELL representation itself (pads have val 0)."""
    return jnp.sum(ell_vals * x[ell_cols], axis=1)
