"""Sparse matrix-vector multiply Pallas kernel — the paper's §V-B workload.

Hardware adaptation (DESIGN.md): the paper's MIMD cores absorb nnz imbalance
in *time*; a SIMD/systolic TPU core absorbs it as *padding* in a regular
layout.  So the CSC + round-robin-rows scheme becomes: rows are permuted by
the same balancing law (`core.loadbalance`: round_robin or LPT over nnz),
packed into an ELLPACK (rows, W) layout, and the kernel processes row blocks
of shape (bm, W) with the x vector resident in VMEM (the paper's DMA
cacheline buffer becomes the VMEM-resident gather source).  Balance quality
shows up as the active/fetched ratio reported by the benchmark — the direct
analogue of the paper's "~25% of nnz per core" measurement.

Two variants:

* ``ell_spmv``          — whole x vector resident in VMEM (fast, but caps n
                          at the VMEM budget);
* ``ell_spmv_blocked``  — x streamed in ``block_cols``-sized column slabs;
                          each (row-block, slab) grid step gathers only the
                          columns that fall inside the current slab and
                          accumulates partial sums in an f32 scratch.  This
                          unlocks n far beyond VMEM at the cost of one
                          masked pass over the ELL block per slab, and is
                          the knob the autotuner trades against the
                          active/fetched balance metric.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _spmv_kernel(x_ref, cols_ref, vals_ref, y_ref):
    x = x_ref[...]                       # (n_padded,) resident in VMEM
    cols = cols_ref[...]                 # (bm, W)
    vals = vals_ref[...]                 # (bm, W)
    gathered = jnp.take(x, cols, axis=0)  # (bm, W)
    y_ref[...] = jnp.sum(vals * gathered, axis=1)


def ell_spmv(x: jax.Array, ell_cols: jax.Array, ell_vals: jax.Array,
             block_rows: int = 8, interpret: bool = False) -> jax.Array:
    """y = A @ x with A in padded ELL form.  Rows must divide block_rows."""
    rows, width = ell_cols.shape
    assert rows % block_rows == 0, (rows, block_rows)
    grid = (rows // block_rows,)
    return pl.pallas_call(
        _spmv_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec(x.shape, lambda i: (0,)),          # x: whole vector
            pl.BlockSpec((block_rows, width), lambda i: (i, 0)),
            pl.BlockSpec((block_rows, width), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((block_rows,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((rows,), ell_vals.dtype),
        compiler_params=pltpu.TPUCompilerParams(
            dimension_semantics=("parallel",)),
        interpret=interpret,
    )(x, ell_cols, ell_vals)


def _spmv_blocked_kernel(x_ref, cols_ref, vals_ref, y_ref, acc_ref, *,
                         n_slabs: int, block_cols: int):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    start = j * block_cols
    cols = cols_ref[...]                             # (bm, W) global indices
    in_slab = (cols >= start) & (cols < start + block_cols)
    local = jnp.where(in_slab, cols - start, 0)      # clamp out-of-slab to 0
    gathered = jnp.take(x_ref[...], local, axis=0)   # (bm, W) from the slab
    partial = jnp.where(in_slab, vals_ref[...] * gathered, 0.0)
    acc_ref[...] += jnp.sum(partial.astype(jnp.float32), axis=1)

    @pl.when(j == n_slabs - 1)
    def _store():
        y_ref[...] = acc_ref[...].astype(y_ref.dtype)


def ell_spmv_blocked(x: jax.Array, ell_cols: jax.Array, ell_vals: jax.Array,
                     block_rows: int = 8, block_cols: int = 512,
                     interpret: bool = False) -> jax.Array:
    """y = A @ x with x streamed slab-by-slab (n may exceed VMEM).

    ``x`` must be padded to a multiple of ``block_cols`` (ops.py pads; the
    pad region is never referenced because every column index is < n).
    The ELL block index map is constant along the slab axis, so Pallas's
    revisiting optimization fetches cols/vals once per row-block while x
    slabs stream underneath.
    """
    rows, width = ell_cols.shape
    (n_padded,) = x.shape
    assert rows % block_rows == 0, (rows, block_rows)
    assert n_padded % block_cols == 0, (n_padded, block_cols)
    n_slabs = n_padded // block_cols
    grid = (rows // block_rows, n_slabs)
    return pl.pallas_call(
        functools.partial(_spmv_blocked_kernel, n_slabs=n_slabs,
                          block_cols=block_cols),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_cols,), lambda i, j: (j,)),
            pl.BlockSpec((block_rows, width), lambda i, j: (i, 0)),
            pl.BlockSpec((block_rows, width), lambda i, j: (i, 0)),
        ],
        out_specs=pl.BlockSpec((block_rows,), lambda i, j: (i,)),
        out_shape=jax.ShapeDtypeStruct((rows,), ell_vals.dtype),
        scratch_shapes=[pltpu.VMEM((block_rows,), jnp.float32)],
        # Row blocks are independent; the slab axis carries the accumulator.
        compiler_params=pltpu.TPUCompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(x, ell_cols, ell_vals)
