"""Sparse matrix-vector multiply Pallas kernel — the paper's §V-B workload.

Hardware adaptation (DESIGN.md): the paper's MIMD cores absorb nnz imbalance
in *time*; a SIMD/systolic TPU core absorbs it as *padding* in a regular
layout.  So the CSC + round-robin-rows scheme becomes: rows are permuted by
the same balancing law (`core.loadbalance`: round_robin or LPT over nnz),
packed into an ELLPACK (rows, W) layout, and the kernel processes row blocks
of shape (bm, W) with the x vector resident in VMEM (the paper's DMA
cacheline buffer becomes the VMEM-resident gather source).  Balance quality
shows up as the active/fetched ratio reported by the benchmark — the direct
analogue of the paper's "~25% of nnz per core" measurement.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _spmv_kernel(x_ref, cols_ref, vals_ref, y_ref):
    x = x_ref[...]                       # (n_padded,) resident in VMEM
    cols = cols_ref[...]                 # (bm, W)
    vals = vals_ref[...]                 # (bm, W)
    gathered = jnp.take(x, cols, axis=0)  # (bm, W)
    y_ref[...] = jnp.sum(vals * gathered, axis=1)


def ell_spmv(x: jax.Array, ell_cols: jax.Array, ell_vals: jax.Array,
             block_rows: int = 8, interpret: bool = False) -> jax.Array:
    """y = A @ x with A in padded ELL form.  Rows must divide block_rows."""
    rows, width = ell_cols.shape
    assert rows % block_rows == 0, (rows, block_rows)
    grid = (rows // block_rows,)
    return pl.pallas_call(
        _spmv_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec(x.shape, lambda i: (0,)),          # x: whole vector
            pl.BlockSpec((block_rows, width), lambda i: (i, 0)),
            pl.BlockSpec((block_rows, width), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((block_rows,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((rows,), ell_vals.dtype),
        interpret=interpret,
    )(x, ell_cols, ell_vals)
