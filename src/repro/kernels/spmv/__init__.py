from repro.kernels.spmv.ops import EllMatrix, pack_csr, spmv  # noqa: F401
