from repro.kernels.matmul.ops import matmul, pick_tile  # noqa: F401
