"""Jitted public wrapper: tile selection (eq.2/DSE), padding, and backend
dispatch (Pallas on TPU, oracle elsewhere, interpret for tests)."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core import dse, tiling
from repro.kernels.matmul import kernel, ref


def _pad_to(v: int, mult: int) -> int:
    return (v + mult - 1) // mult * mult


def pick_tile(m: int, n: int, k: int, dtype_bytes: int = 2,
              vmem_bytes: int | None = None, align: int = 128) -> tiling.Tile:
    """DSE-autotuned tile (never worse than the paper's eq.2 seed), clamped
    to the (padded) problem."""
    t = dse.autotune_matmul_tile(m, n, k, vmem_bytes=vmem_bytes,
                                 dtype_bytes=dtype_bytes, align=align)
    return clamp_tile(t, m, n, k, align=align)


def clamp_tile(t: tiling.Tile, m: int, n: int, k: int,
               align: int = 128) -> tiling.Tile:
    """Shrink a tile to the padded problem so tiny shapes don't over-pad."""
    return tiling.Tile(
        y=min(t.y, _pad_to(m, align)),
        x=min(t.x, _pad_to(n, align)),
        z=min(t.z, _pad_to(k, align)),
    )


@functools.partial(jax.jit, static_argnames=(
    "tile", "activation", "interpret", "use_kernel", "compute_dtype",
    "out_dtype"))
def matmul(a: jax.Array, b: jax.Array, tile: tiling.Tile | None = None,
           bias: jax.Array | None = None, activation: str | None = None,
           interpret: bool = False, use_kernel: bool | None = None,
           compute_dtype=None, out_dtype=None):
    """C = act(A @ B + bias) with eq.2-tiled Pallas execution on TPU.

    ``use_kernel=None`` auto-selects: Pallas on TPU backend, oracle on CPU
    (the multi-pod dry-run lowers the oracle path; tests pass
    ``interpret=True`` to execute the kernel body on CPU).

    ``compute_dtype`` (e.g. ``jnp.bfloat16``) down-casts the streamed A/B
    operands before the kernel; accumulation stays f32 in VMEM scratch and
    the result is produced in ``out_dtype`` (default: A's original dtype).
    ``bias`` is a length-N vector fused into the kernel epilogue together
    with ``activation`` (see ``kernel.ACTIVATIONS``).
    """
    out_dtype = out_dtype or a.dtype
    if use_kernel is None:
        use_kernel = interpret or jax.default_backend() == "tpu"
    if bias is not None and bias.ndim == 1:
        bias = bias[None, :]
    if compute_dtype is not None:
        a = a.astype(compute_dtype)
        b = b.astype(compute_dtype)
    if not use_kernel:
        return ref.matmul_ref(a, b, bias=bias, activation=activation,
                              out_dtype=out_dtype)

    m, k = a.shape
    _, n = b.shape
    if tile is None:
        tile = pick_tile(m, n, k, dtype_bytes=a.dtype.itemsize)
    mp, np_, kp = _pad_to(m, tile.y), _pad_to(n, tile.x), _pad_to(k, tile.z)
    ap = jnp.pad(a, ((0, mp - m), (0, kp - k)))
    bp = jnp.pad(b, ((0, kp - k), (0, np_ - n)))
    bias_p = (None if bias is None
              else jnp.pad(bias, ((0, 0), (0, np_ - n))))
    out = kernel.blocked_matmul(ap, bp, tile, bias=bias_p,
                                activation=activation, out_dtype=out_dtype,
                                interpret=interpret)
    return out[:m, :n]
