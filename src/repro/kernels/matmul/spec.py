"""KernelSpec registration for the blocked dense-matmul family.

The family-specific halves of the old `tune_matmul`/`tuned_matmul` pipeline
live here as a declaration: candidate enumeration (the paper's Table-I
sweep, moved out of `core/dse.py`), the `matmul_time_model` cost wrapper,
and the Pallas launcher.  The generic engine in `kernels/autotune.py` does
the rest.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import cost_model, dse, hardware, tiling
from repro.kernels import registry
from repro.kernels.matmul import ops as matmul_ops


def rank_tiles(
    m: int, n: int, k: int,
    vmem_bytes: int | None = None,
    dtype_bytes: int = 2,
    align: int = hardware.MXU_DIM,
    top: int = 8,
) -> list[dse.Candidate]:
    """Sweep aligned (y, x) pairs; score with the analytical matmul model.

    This is the paper's Table-I exploration (vary cores/local-mem, simulate,
    rank) compressed to one call.  The eq.2 seed is always included, so the
    top candidate is never worse than the paper's closed form.  The ranking
    is deterministic: candidates are scored by model time with (y, x, z) as
    the tie-break, so equal-cost points always order the same way — this is
    what makes the autotune cache reproducible.  Each returned
    ``Candidate.detail`` carries the concrete ``tiling.Tile`` plus the model
    row (`cost_model.matmul_time_model`).
    """
    chip = hardware.TPU_V5E
    budget = vmem_bytes if vmem_bytes is not None else chip.usable_vmem()

    def evaluate(knobs: dict) -> tuple[float, dict]:
        y, x = knobs["y"], knobs["x"]
        z_budget = (budget - y * x * 4) // max((y + 2 * x) * dtype_bytes, 1)
        z = max(align, (min(z_budget, k) // align) * align)
        t = tiling.Tile(y, x, z)
        if t.vmem_elems() * dtype_bytes + y * x * 4 > budget + y * x * dtype_bytes:
            return float("inf"), {}
        res = cost_model.matmul_time_model(m, n, k, t, dtype_bytes=dtype_bytes)
        return res["time_s"], {"tile": t, **res}

    seed = tiling.solve_tpu(budget, dtype_bytes, m=m, n=n, k=k)
    ys = sorted({align, 2 * align, 4 * align, 8 * align, seed.y})
    xs = sorted({align, 2 * align, 4 * align, 8 * align, seed.x})
    space = {"y": [v for v in ys if v <= max(m, align)],
             "x": [v for v in xs if v <= max(n, align)]}
    ranked = dse.explore(space, evaluate, top=max(top, 1))
    ranked = [c for c in ranked if c.detail and "tile" in c.detail]
    ranked.sort(key=lambda c: (c.score, c.detail["tile"].y,
                               c.detail["tile"].x, c.detail["tile"].z))
    if not ranked:
        res = cost_model.matmul_time_model(m, n, k, seed,
                                           dtype_bytes=dtype_bytes)
        ranked = [dse.Candidate({"y": seed.y, "x": seed.x}, res["time_s"],
                                {"tile": seed, **res})]
    return ranked[:top]


def _key_fn(problem: dict, dtype: str, backend: str) -> str:
    return f"{problem['m']}x{problem['n']}x{problem['k']}:{dtype}:{backend}"


def _enumerate(problem: dict, dtype_bytes: int, vmem_bytes: int | None,
               top: int) -> list[dse.Candidate]:
    m, n, k = problem["m"], problem["n"], problem["k"]
    # Over-request so the ENGINE's (score, tie_break) sort performs the
    # authoritative top-cut — the ranker's internal order serves only the
    # standalone deprecated rank_* API.
    ranked = rank_tiles(m, n, k, vmem_bytes=vmem_bytes,
                        dtype_bytes=dtype_bytes, top=max(top, 8))
    # Clamp to the padded problem (small shapes collapse many candidates
    # onto the same effective tile; the engine dedupes by knobs).
    out = []
    for c in ranked:
        t = matmul_ops.clamp_tile(c.detail["tile"], m, n, k)
        out.append(dse.Candidate({"tile": [t.y, t.x, t.z]}, c.score, {}))
    return out


def _cost_fn(problem: dict, knobs: dict, dtype_bytes: int = 2) -> dict:
    return cost_model.matmul_time_model(
        problem["m"], problem["n"], problem["k"],
        tiling.Tile(*knobs["tile"]), dtype_bytes=dtype_bytes)


def _make_inputs(problem: dict, dtype) -> tuple:
    m, n, k = problem["m"], problem["n"], problem["k"]
    a = jax.random.normal(jax.random.PRNGKey(0), (m, k), jnp.float32)
    b = jax.random.normal(jax.random.PRNGKey(1), (k, n), jnp.float32)
    return a.astype(dtype), b.astype(dtype)


def _build_launcher(problem: dict, knobs: dict, interpret: bool):
    tile = tiling.Tile(*knobs["tile"])
    return lambda a, b: matmul_ops.matmul(a, b, tile=tile,
                                          interpret=interpret,
                                          use_kernel=True)


def _problem_fn(a, b, bias=None, activation=None, compute_dtype=None,
                out_dtype=None) -> tuple[dict, object]:
    m, k = a.shape
    _, n = b.shape
    dtype = jnp.dtype(compute_dtype) if compute_dtype is not None else a.dtype
    return {"m": m, "n": n, "k": k}, dtype


def _run_fn(plan: registry.Plan, a, b, *, interpret=False, bias=None,
            activation=None, compute_dtype=None, out_dtype=None):
    return matmul_ops.matmul(a, b, tile=tiling.Tile(*plan.knobs["tile"]),
                             bias=bias, activation=activation,
                             interpret=interpret, use_kernel=True,
                             compute_dtype=compute_dtype,
                             out_dtype=out_dtype)


def _reference_fn(a, b, bias=None, activation=None, compute_dtype=None,
                  out_dtype=None):
    return matmul_ops.matmul(a, b, bias=bias, activation=activation,
                             use_kernel=False, compute_dtype=compute_dtype,
                             out_dtype=out_dtype)


registry.register(registry.KernelSpec(
    name="matmul",
    key_fn=_key_fn,
    enumerate_candidates=_enumerate,
    cost_fn=_cost_fn,
    make_inputs=_make_inputs,
    build_launcher=_build_launcher,
    reference_fn=_reference_fn,
    problem_fn=_problem_fn,
    run_fn=_run_fn,
    measure_elems=lambda p: p["m"] * p["k"] + p["k"] * p["n"]
    + p["m"] * p["n"],
    tie_break=lambda knobs: tuple(knobs["tile"]),
    default_measure_k=3,
    bench_key="matmul_tuned_vs_fixed",
))
