"""Blocked dense matmul Pallas kernel — the paper's §V-A workload on the MXU.

The BlockSpec tiling is the paper's eq.2 law adapted to VMEM
(`core.tiling.solve_tpu`): the C tile (y, x) is the stationary accumulator in
VMEM (f32), A (y, z) and B (z, x) tiles stream HBM->VMEM with Pallas's
automatic double-buffering — the hardware analogue of the paper's doubled B
buffer.  The A tile's reuse across the N grid axis plays the role of the
paper's broadcast of A to all cores.

The kernel accumulates in f32 regardless of the input dtype (bf16 inputs hit
the MXU's native mixed-precision path) and supports a fused bias/activation
epilogue applied while the C tile is still resident in VMEM — the alternative
is a second elementwise pass that re-reads and re-writes all of C through HBM.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core import tiling

# Fused epilogue nonlinearities.  Static strings (jit/cache friendly) rather
# than callables; extend here when a new serving activation shows up.
ACTIVATIONS = {
    None: lambda v: v,
    "relu": lambda v: jnp.maximum(v, 0.0),
    "gelu": jax.nn.gelu,
    "silu": jax.nn.silu,
    "tanh": jnp.tanh,
}


def _epilogue(acc, bias, activation):
    if bias is not None:
        acc = acc + bias
    return ACTIVATIONS[activation](acc)


def _matmul_kernel(*refs, k_steps: int, activation: str | None,
                   has_bias: bool):
    if has_bias:
        a_ref, b_ref, bias_ref, o_ref, acc_ref = refs
    else:
        (a_ref, b_ref, o_ref, acc_ref), bias_ref = refs, None

    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(
        a_ref[...], b_ref[...], preferred_element_type=jnp.float32
    )

    @pl.when(pl.program_id(2) == k_steps - 1)
    def _store():
        # bias block is (1, x) and broadcasts over the tile's y rows.
        bias = None if bias_ref is None else bias_ref[...].astype(jnp.float32)
        o_ref[...] = _epilogue(acc_ref[...], bias, activation).astype(
            o_ref.dtype)


def blocked_matmul(
    a: jax.Array,
    b: jax.Array,
    tile: tiling.Tile,
    bias: jax.Array | None = None,
    activation: str | None = None,
    out_dtype=None,
    interpret: bool = False,
) -> jax.Array:
    """(M, K) @ (K, N) with explicit (y, x, z) VMEM tiling.

    Shapes must be multiples of the tile (ops.py pads).  ``bias`` is a
    (1, N) row added to C in the epilogue; ``activation`` is a key of
    ``ACTIVATIONS`` applied after the bias, both fused into the final
    k-step's store so C makes exactly one HBM round-trip.
    """
    m, k = a.shape
    k2, n = b.shape
    assert k == k2, (a.shape, b.shape)
    y, x, z = tile.y, tile.x, tile.z
    assert m % y == 0 and n % x == 0 and k % z == 0, (a.shape, b.shape, tile)
    assert activation in ACTIVATIONS, activation
    out_dtype = out_dtype or a.dtype
    k_steps = k // z

    grid = (m // y, n // x, k_steps)
    in_specs = [
        pl.BlockSpec((y, z), lambda i, j, kk: (i, kk)),
        pl.BlockSpec((z, x), lambda i, j, kk: (kk, j)),
    ]
    operands = [a, b]
    if bias is not None:
        assert bias.shape == (1, n), (bias.shape, n)
        in_specs.append(pl.BlockSpec((1, x), lambda i, j, kk: (0, j)))
        operands.append(bias)
    return pl.pallas_call(
        functools.partial(_matmul_kernel, k_steps=k_steps,
                          activation=activation, has_bias=bias is not None),
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((y, x), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), out_dtype),
        scratch_shapes=[pltpu.VMEM((y, x), jnp.float32)],
        # M/N grid axes are independent; only the K axis carries the
        # accumulator, so Mosaic may parallelize the first two.
        compiler_params=pltpu.TPUCompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(*operands)
