"""Blocked dense matmul Pallas kernel — the paper's §V-A workload on the MXU.

The BlockSpec tiling is the paper's eq.2 law adapted to VMEM
(`core.tiling.solve_tpu`): the C tile (y, x) is the stationary accumulator in
VMEM (f32), A (y, z) and B (z, x) tiles stream HBM->VMEM with Pallas's
automatic double-buffering — the hardware analogue of the paper's doubled B
buffer.  The A tile's reuse across the N grid axis plays the role of the
paper's broadcast of A to all cores.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core import tiling


def _matmul_kernel(a_ref, b_ref, o_ref, acc_ref, *, k_steps: int):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(
        a_ref[...], b_ref[...], preferred_element_type=jnp.float32
    )

    @pl.when(pl.program_id(2) == k_steps - 1)
    def _store():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def blocked_matmul(
    a: jax.Array,
    b: jax.Array,
    tile: tiling.Tile,
    out_dtype=None,
    interpret: bool = False,
) -> jax.Array:
    """(M, K) @ (K, N) with explicit (y, x, z) VMEM tiling.

    Shapes must be multiples of the tile (ops.py pads).
    """
    m, k = a.shape
    k2, n = b.shape
    assert k == k2, (a.shape, b.shape)
    y, x, z = tile.y, tile.x, tile.z
    assert m % y == 0 and n % x == 0 and k % z == 0, (a.shape, b.shape, tile)
    out_dtype = out_dtype or a.dtype
    k_steps = k // z

    grid = (m // y, n // x, k_steps)
    return pl.pallas_call(
        functools.partial(_matmul_kernel, k_steps=k_steps),
        grid=grid,
        in_specs=[
            pl.BlockSpec((y, z), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((z, x), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((y, x), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), out_dtype),
        scratch_shapes=[pltpu.VMEM((y, x), jnp.float32)],
        interpret=interpret,
    )(a, b)
