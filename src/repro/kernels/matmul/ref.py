"""Pure-jnp oracle for the blocked matmul kernel (+ fused epilogue)."""

import jax
import jax.numpy as jnp


def matmul_ref(a: jax.Array, b: jax.Array, bias: jax.Array | None = None,
               activation: str | None = None, out_dtype=None) -> jax.Array:
    from repro.kernels.matmul.kernel import ACTIVATIONS

    out_dtype = out_dtype or a.dtype
    y = jnp.dot(a, b, preferred_element_type=jnp.float32)
    if bias is not None:
        y = y + bias.astype(jnp.float32)
    y = ACTIVATIONS[activation](y)
    return y.astype(out_dtype)
