"""Declarative kernel-family registry: a tuned kernel family is a *spec*.

The paper's core claim is that a many-core system is *generated from a set
of architectural parameters* rather than hand-designed.  The tuning layer
now holds itself to the same standard: instead of four copy-pasted
DSE → measure → cache pipelines (one per kernel family), a family is a
single declarative :class:`KernelSpec` — candidates + cost model +
launcher — and the generic engine in ``kernels/autotune.py``
(``tune``/``dispatch``) does everything else: deterministic ranking,
top-K wall-clock measurement, the interpret fallback, the analytic-entry
upgrade rule, and the unified versioned JSON cache.  Adding kernel family
#5 is one ``register(KernelSpec(...))`` call, not another pipeline copy.

This module is deliberately jax-free: the specs themselves live next to
their kernels (``kernels/<family>/spec.py``, loaded lazily on first
lookup), so the registry — and tools like ``tools/check_registry.py`` —
can be reasoned about without touching device state.

Spec contract (``problem`` is the family's plain-dict shape description,
``knobs`` the JSON-able chosen configuration):

=====================  =====================================================
field                  signature / meaning
=====================  =====================================================
``name``               unique family name; the cache-key prefix
``key_fn``             ``(problem, dtype_name, backend) -> str`` key suffix
``enumerate_candidates``  ``(problem, dtype_bytes, vmem_bytes, top) ->
                       list[core.dse.Candidate]`` scored ascending, never
                       empty (the family provides its own fallback)
``cost_fn``            ``(problem, knobs, dtype_bytes) -> dict`` — the
                       analytic model row (wraps ``core.cost_model``)
``make_inputs``        ``(problem, dtype) -> tuple[Array, ...]`` synthetic
                       operands for wall-clock measurement
``build_launcher``     ``(problem, knobs, interpret) -> fn(*inputs)`` — the
                       Pallas call the engine times
``reference_fn``       the pure-jnp oracle path ``dispatch`` uses off-TPU
``problem_fn``         ``(*args, **kwargs) -> (problem, dtype)`` — derive
                       the tuning problem from runtime dispatch arguments
``run_fn``             ``(plan, *args, interpret=..., **kwargs) -> Array``
                       — execute the kernel with the plan's knobs
``measure_elems``      ``(problem) -> int`` operand-element count gating
                       interpret-mode measurement
``tie_break``          ``(knobs) -> tuple`` deterministic ranking tie-break
``detail_keys``        candidate-detail fields persisted into the plan
``default_measure_k``  measurement depth when ``dispatch`` tunes implicitly
                       (0 for families dispatched inside a jit trace)
``bench_key``          the family's row in BENCH_kernels.json (checked by
                       ``tools/check_registry.py``)
=====================  =====================================================
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Sequence


@dataclasses.dataclass(frozen=True)
class Plan:
    """A tuned configuration for one (family, problem) point — the typed
    object serving-plan logging and step-time prediction consume.

    ``source`` is where *this* plan object came from (``"cache"`` for a
    file hit); ``provenance`` is the durable answer to "was the winner
    wall-clocked or only ranked analytically", stable across cache trips.
    """

    family: str
    key: str
    problem: dict
    knobs: dict
    source: str                  # "cache" | "measured" | "model"
    model_time_s: float
    measured_us: float | None = None
    detail: dict = dataclasses.field(default_factory=dict)

    @property
    def model_time_us(self) -> float:
        return self.model_time_s * 1e6

    @property
    def provenance(self) -> str:
        return "measured" if self.measured_us is not None else "analytic"

    def record(self) -> dict:
        """JSON-able log row (problem included — serving problems are
        plain scalars; families whose problem holds live objects should
        log the key instead)."""
        return {
            "family": self.family,
            "key": self.key,
            "knobs": dict(self.knobs),
            "source": self.source,
            "provenance": self.provenance,
            "model_time_us": self.model_time_us,
            "measured_us": self.measured_us,
            **({"detail": dict(self.detail)} if self.detail else {}),
        }


def _default_tie_break(knobs: dict) -> tuple:
    return tuple(sorted((k, repr(v)) for k, v in knobs.items()))


@dataclasses.dataclass(frozen=True)
class KernelSpec:
    """Everything the generic engine needs to tune and run one family."""

    name: str
    key_fn: Callable[[dict, str, str], str]
    enumerate_candidates: Callable[..., Sequence[Any]]
    cost_fn: Callable[..., dict]
    make_inputs: Callable[..., tuple]
    build_launcher: Callable[..., Callable]
    reference_fn: Callable[..., Any]
    problem_fn: Callable[..., tuple]
    run_fn: Callable[..., Any]
    measure_elems: Callable[[dict], int]
    tie_break: Callable[[dict], tuple] = _default_tie_break
    detail_keys: tuple = ()
    default_measure_k: int = 3
    bench_key: str = ""


_REGISTRY: dict[str, KernelSpec] = {}

# Built-in families, loaded on first lookup so `import repro.kernels.registry`
# stays jax-free.  tools/check_registry.py parses these module paths
# statically to enumerate the shipped families without importing jax.
BUILTIN_SPEC_MODULES = (
    "repro.kernels.matmul.spec",
    "repro.kernels.spmv.spec",
    "repro.kernels.attention.spec",
)
# The names those modules register, declared statically: `unregister`
# refuses them without loading anything, and no runtime snapshot is needed
# (a snapshot taken mid-load misses a family whose spec module triggered
# the load from inside its own in-flight registration).  Agreement with
# the modules is asserted post-load and by tests/test_registry.py.
BUILTIN_FAMILIES = ("matmul", "spmv", "attention", "decode", "decode_int8")
_builtins_loaded = False
_loading_builtins = False


def register(spec: KernelSpec) -> KernelSpec:
    """Add a family to the registry; duplicate names are a hard error."""
    if not isinstance(spec, KernelSpec):
        raise TypeError(f"register() takes a KernelSpec, got {type(spec)!r}")
    # Load the built-ins first so a caller can't silently shadow a builtin
    # name before the first lookup (which would then trip the duplicate
    # guard *inside* _load_builtins forever).  The spec modules' own
    # register() calls re-enter here mid-load; the _loading guard makes
    # that a no-op.
    _load_builtins()
    if spec.name in _REGISTRY:
        raise ValueError(
            f"kernel family {spec.name!r} is already registered; "
            f"unregister() it first or pick a unique name")
    _REGISTRY[spec.name] = spec
    return spec


def unregister(name: str) -> None:
    """Remove a family (tests registering toy specs clean up with this).

    Built-in families are refused: their spec modules register at import
    time, so once unregistered they could never be reloaded in-process
    (the builtin latch is one-way) and every later lookup would fail.
    """
    if name in BUILTIN_FAMILIES:
        raise ValueError(f"cannot unregister built-in family {name!r}")
    _REGISTRY.pop(name, None)


def _load_builtins() -> None:
    global _builtins_loaded, _loading_builtins
    if _builtins_loaded or _loading_builtins:
        return
    import importlib
    _loading_builtins = True
    try:
        for mod in BUILTIN_SPEC_MODULES:
            # Roll back a module's partial registrations if its import
            # fails: Python evicts the failed module from sys.modules, so
            # the next lookup re-executes it — which would otherwise trip
            # the duplicate-name guard on whatever it had registered
            # before dying, hiding the real error.
            before = set(_REGISTRY)
            try:
                importlib.import_module(mod)
            except Exception:
                for name in set(_REGISTRY) - before:
                    del _REGISTRY[name]
                raise
        # Latched only after every module imported: a failed import
        # surfaces its real error again on the next lookup instead of
        # collapsing into a misleading "unknown family" KeyError forever.
        # (A spec module that triggered this load from inside its own
        # registration finishes inserting its name right after we return,
        # within the same synchronous call — see register().)
        _builtins_loaded = True
    finally:
        _loading_builtins = False


def get(name: str) -> KernelSpec:
    """Look up a family, loading the built-in specs on first miss."""
    spec = _REGISTRY.get(name)
    if spec is None:
        _load_builtins()
        spec = _REGISTRY.get(name)
    if spec is None:
        raise KeyError(
            f"unknown kernel family {name!r}; registered: {families()}")
    return spec


def families() -> list[str]:
    """Registered family names (built-ins included), sorted."""
    _load_builtins()
    return sorted(_REGISTRY)
