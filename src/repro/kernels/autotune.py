"""Autotuning kernel engine: the paper's DSE loop, closed over real kernels.

The paper's §IV flow is: enumerate candidate configurations, *simulate* each
(SystemC machine model), pick the winner, synthesize.  The repo has had the
first half for a while (`core.dse` ranks `Tile` candidates with the analytic
`core.cost_model`) but the Pallas kernels ran with fixed hand-picked tiles.
This module closes the loop:

1. **candidates** — `core.dse.rank_matmul_tiles` / `rank_spmv_configs` rank
   feasible configurations under the VMEM budget with the analytic model
   (the "simulate" step, at a few microseconds per point);
2. **measure**    — the top-K survivors are timed on the real backend
   (Pallas on TPU; interpret-mode on CPU for small problems, analytic
   fallback above `max_measure_elems` where interpret timing is
   meaningless);
3. **memoize**    — winners land in an on-disk JSON cache keyed by
   (kernel, shape, dtype, backend), so a serving process pays the search
   once per shape, ever.

`tuned_matmul` / `tuned_spmv` are the drop-in entry points benchmarks,
examples and the serving path call instead of fixed tiles.
"""

from __future__ import annotations

import dataclasses
import json
import os
import pathlib
import time
from typing import Callable, Sequence

import jax
import jax.numpy as jnp

from repro.core import cost_model, dse, hardware, tiling
from repro.kernels.matmul import ops as matmul_ops
from repro.kernels.spmv import ops as spmv_ops

ENGINE_VERSION = 1
CACHE_ENV = "REPRO_AUTOTUNE_CACHE"

# Above this many total operand elements, CPU interpret-mode timing is both
# glacial and unrepresentative — the analytic ranking decides alone.
MAX_MEASURE_ELEMS = 1 << 22


# ---------------------------------------------------------------------------
# On-disk memo cache
# ---------------------------------------------------------------------------

def default_cache_path() -> pathlib.Path:
    env = os.environ.get(CACHE_ENV)
    if env:
        return pathlib.Path(env)
    return pathlib.Path.home() / ".cache" / "repro" / "autotune.json"


class TuneCache:
    """Tiny write-through JSON cache: {key: plan-dict}.

    One file per machine (keys embed the backend), loaded lazily and
    rewritten on every put — tuning happens once per shape so write
    amplification is irrelevant, and a plain-text file keeps the cache
    inspectable and diffable.
    """

    def __init__(self, path: str | os.PathLike | None = None):
        self.path = pathlib.Path(path) if path else default_cache_path()
        self._data: dict | None = None
        self.hits = 0
        self.misses = 0

    def _load(self) -> dict:
        if self._data is None:
            try:
                raw = json.loads(self.path.read_text())
            except (OSError, ValueError):
                raw = None
            if not (isinstance(raw, dict)
                    and raw.get("version") == ENGINE_VERSION
                    and isinstance(raw.get("entries"), dict)):
                raw = {"version": ENGINE_VERSION, "entries": {}}
            self._data = raw
        return self._data

    def get(self, key: str) -> dict | None:
        entry = self._load()["entries"].get(key)
        if entry is None:
            self.misses += 1
        else:
            self.hits += 1
        return entry

    def put(self, key: str, value: dict) -> None:
        data = self._load()
        data["entries"][key] = value
        try:
            self.path.parent.mkdir(parents=True, exist_ok=True)
            tmp = self.path.with_suffix(".tmp")
            tmp.write_text(json.dumps(data, indent=1, sort_keys=True))
            tmp.replace(self.path)
        except OSError:
            # An unwritable cache must never take down the compute path;
            # the in-memory entry above still serves this process.
            pass


_default_cache: TuneCache | None = None


def get_cache() -> TuneCache:
    """Process-wide cache bound to the current $REPRO_AUTOTUNE_CACHE."""
    global _default_cache
    path = default_cache_path()
    if _default_cache is None or _default_cache.path != path:
        _default_cache = TuneCache(path)
    return _default_cache


# ---------------------------------------------------------------------------
# Measurement harness
# ---------------------------------------------------------------------------

def measure(fn: Callable[[], jax.Array], reps: int = 3,
            warmup: int = 1) -> float:
    """Median-free best-effort wall timing of ``fn`` in microseconds."""
    for _ in range(max(warmup, 0)):
        fn().block_until_ready()
    t0 = time.perf_counter()
    for _ in range(max(reps, 1)):
        fn().block_until_ready()
    return (time.perf_counter() - t0) / max(reps, 1) * 1e6


def _backend() -> str:
    return jax.default_backend()


# ---------------------------------------------------------------------------
# Matmul
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class MatmulPlan:
    tile: tiling.Tile
    source: str                  # "cache" | "measured" | "model"
    model_time_s: float
    measured_us: float | None
    key: str


def _budget_tag(vmem_bytes: int | None) -> str:
    # The budget shapes the feasible set, so constrained and default
    # tunings must not share cache entries.
    return "dflt" if vmem_bytes is None else str(vmem_bytes)


def _matmul_key(m: int, n: int, k: int, dtype: str, backend: str,
                vmem_bytes: int | None) -> str:
    return f"matmul:{m}x{n}x{k}:{dtype}:{backend}:v{_budget_tag(vmem_bytes)}"


def tune_matmul(
    m: int, n: int, k: int, dtype=jnp.float32, *,
    measure_k: int = 3,
    vmem_bytes: int | None = None,
    max_measure_elems: int = MAX_MEASURE_ELEMS,
    cache: TuneCache | None = None,
    interpret: bool | None = None,
) -> MatmulPlan:
    """Pick a Tile for an (m,k)@(k,n) product via DSE -> measure -> cache.

    ``measure_k=0`` disables measurement (pure analytic ranking) — used by
    planning paths that must stay fast, e.g. server startup on CPU.
    """
    dtype = jnp.dtype(dtype)
    backend = _backend()
    cache = cache or get_cache()
    key = _matmul_key(m, n, k, dtype.name, backend, vmem_bytes)
    measurable = (measure_k > 0
                  and (backend == "tpu"
                       or m * k + k * n + m * n <= max_measure_elems))

    hit = cache.get(key)
    # An analytic-only entry (e.g. written by serve startup with
    # measure_k=0) is upgraded, not returned, once a measuring caller
    # shows up — otherwise the measure step would be skipped forever.
    if hit is not None and not (measurable and hit.get("source") == "model"):
        return MatmulPlan(tiling.Tile(*hit["tile"]), "cache",
                          hit["model_time_s"], hit.get("measured_us"), key)

    ranked = dse.rank_matmul_tiles(m, n, k, vmem_bytes=vmem_bytes,
                                   dtype_bytes=dtype.itemsize,
                                   top=max(measure_k, 1))
    # Clamp to the padded problem and dedupe (small shapes collapse many
    # candidates onto the same effective tile).
    seen, cands = set(), []
    for c in ranked:
        t = matmul_ops.clamp_tile(c.detail["tile"], m, n, k)
        if t not in seen:
            seen.add(t)
            cands.append((c.score, t))

    interpret = (backend != "tpu") if interpret is None else interpret
    measured_us = None
    if measurable and len(cands) > 0:
        a = jax.random.normal(jax.random.PRNGKey(0), (m, k), jnp.float32)
        b = jax.random.normal(jax.random.PRNGKey(1), (k, n), jnp.float32)
        a, b = a.astype(dtype), b.astype(dtype)
        best_t, best_us = None, float("inf")
        for _, t in cands[:measure_k]:
            try:
                us = measure(lambda t=t: matmul_ops.matmul(
                    a, b, tile=t, interpret=interpret, use_kernel=True))
            except Exception:
                continue  # e.g. real VMEM overflow the model missed
            if us < best_us:
                best_t, best_us = t, us
        measurable = best_t is not None
    if measurable:
        tile, source, measured_us = best_t, "measured", best_us
        model_time_s = next(s for s, t in cands if t == tile)
    else:
        model_time_s, tile = cands[0]
        source = "model"
        measured_us = None

    cache.put(key, {"tile": [tile.y, tile.x, tile.z], "source": source,
                    "model_time_s": model_time_s,
                    "measured_us": measured_us})
    return MatmulPlan(tile, source, model_time_s, measured_us, key)


def tuned_matmul(a: jax.Array, b: jax.Array,
                 bias: jax.Array | None = None,
                 activation: str | None = None,
                 interpret: bool = False,
                 use_kernel: bool | None = None,
                 compute_dtype=None, out_dtype=None,
                 cache: TuneCache | None = None) -> jax.Array:
    """C = act(A @ B + bias) with the autotuned tile for A/B's shape.

    Same dispatch semantics as `kernels.matmul.matmul` (Pallas on TPU /
    interpret, oracle otherwise) — the tuner only runs when the kernel
    path would, so CPU oracle callers pay nothing.
    """
    if use_kernel is None:
        use_kernel = interpret or _backend() == "tpu"
    tile = None
    if use_kernel:
        m, k = a.shape
        _, n = b.shape
        dtype = jnp.dtype(compute_dtype) if compute_dtype is not None \
            else a.dtype
        tile = tune_matmul(m, n, k, dtype, cache=cache,
                           interpret=interpret).tile
    return matmul_ops.matmul(a, b, tile=tile, bias=bias,
                             activation=activation, interpret=interpret,
                             use_kernel=use_kernel,
                             compute_dtype=compute_dtype,
                             out_dtype=out_dtype)


# ---------------------------------------------------------------------------
# SpMV
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class SpmvPlan:
    block_rows: int
    block_cols: int | None       # None -> whole-x-resident kernel
    source: str                  # "cache" | "measured" | "model"
    model_time_s: float
    measured_us: float | None
    waste: float                 # active/fetched input metric at block_rows
    key: str


def _spmv_key(rows: int, width: int, n: int, nnz: int, layout: str,
              dtype: str, backend: str, vmem_bytes: int | None) -> str:
    return (f"spmv:{rows}x{width}:n{n}:nnz{nnz}:l{layout}:{dtype}:{backend}"
            f":v{_budget_tag(vmem_bytes)}")


def rank_spmv_configs(
    mat: spmv_ops.EllMatrix,
    vmem_bytes: int | None = None,
    block_rows_cands: Sequence[int] = (8, 16, 32, 64),
    block_cols_cands: Sequence[int | None] = (None, 256, 512, 1024, 2048),
) -> list[tuple[float, int, int | None, float]]:
    """Rank (block_rows, block_cols) configs by the bandwidth model.

    The active/fetched balance metric (`EllMatrix.sliced_waste`, built on
    `core.loadbalance`) enters the score as the fetch-amplification of the
    ELL payload — the tuner's analogue of the paper's "% of nnz per core"
    column.  Returns (score, block_rows, block_cols, waste) ascending,
    deterministically tie-broken.
    """
    budget = vmem_bytes if vmem_bytes is not None \
        else hardware.TPU_V5E.usable_vmem()
    rows, width = mat.cols.shape
    _, n = mat.shape
    out = []
    for br in block_rows_cands:
        if rows % br:
            continue
        waste = mat.sliced_waste(block_rows=br)
        for bc in block_cols_cands:
            if bc is not None and bc >= n + 128:
                continue  # slab larger than the vector: same as resident
            res = cost_model.spmv_time_model(rows, width, n, mat.nnz,
                                             block_rows=br, block_cols=bc,
                                             waste=waste)
            if res["vmem_bytes"] > budget:
                continue
            out.append((res["time_s"], br, bc, waste))
    out.sort(key=lambda r: (r[0], r[1], r[2] if r[2] is not None else 0))
    return out


def tune_spmv(
    mat: spmv_ops.EllMatrix, dtype=jnp.float32, *,
    measure_k: int = 3,
    vmem_bytes: int | None = None,
    max_measure_elems: int = MAX_MEASURE_ELEMS,
    cache: TuneCache | None = None,
    interpret: bool | None = None,
) -> SpmvPlan:
    """Pick (block_rows, block_cols) for an ELL matrix: DSE -> measure -> cache."""
    dtype = jnp.dtype(dtype)
    backend = _backend()
    cache = cache or get_cache()
    rows, width = mat.cols.shape
    _, n = mat.shape
    key = _spmv_key(rows, width, n, mat.nnz, mat.layout_fingerprint(),
                    dtype.name, backend, vmem_bytes)
    measurable = (measure_k > 0
                  and (backend == "tpu"
                       or rows * width + n <= max_measure_elems))

    hit = cache.get(key)
    # Same upgrade rule as tune_matmul: analytic-only entries don't block
    # a later measuring caller.
    if hit is not None and not (measurable and hit.get("source") == "model"):
        return SpmvPlan(hit["block_rows"], hit["block_cols"], "cache",
                        hit["model_time_s"], hit.get("measured_us"),
                        hit.get("waste", 0.0), key)

    ranked = rank_spmv_configs(mat, vmem_bytes=vmem_bytes)
    if not ranked:
        # Degenerate budget: fall back to the smallest legal blocked-x
        # config, scored normally so the cache entry stays finite JSON.
        fb = cost_model.spmv_time_model(rows, width, n, mat.nnz,
                                        block_rows=8, block_cols=256,
                                        waste=mat.padding_waste)
        ranked = [(fb["time_s"], 8, 256, mat.padding_waste)]

    interpret = (backend != "tpu") if interpret is None else interpret
    measured_us = None
    if measurable:
        x = jax.random.normal(jax.random.PRNGKey(0), (n,), dtype)
        best, best_us = None, float("inf")
        for score, br, bc, waste in ranked[:measure_k]:
            try:
                us = measure(lambda br=br, bc=bc: spmv_ops.spmv(
                    mat, x, block_rows=br, block_cols=bc,
                    interpret=interpret, use_kernel=True))
            except Exception:
                continue  # e.g. real VMEM overflow the model missed
            if us < best_us:
                best, best_us = (score, br, bc, waste), us
        measurable = best is not None
    if measurable:
        score, br, bc, waste = best
        source, measured_us = "measured", best_us
    else:
        score, br, bc, waste = ranked[0]
        source = "model"
        measured_us = None

    cache.put(key, {"block_rows": br, "block_cols": bc, "source": source,
                    "model_time_s": score, "measured_us": measured_us,
                    "waste": waste})
    return SpmvPlan(br, bc, source, score, measured_us, waste, key)


def tuned_spmv(mat: spmv_ops.EllMatrix, x: jax.Array,
               interpret: bool = False,
               use_kernel: bool | None = None,
               cache: TuneCache | None = None) -> jax.Array:
    """y = A @ x with autotuned (block_rows, block_cols) for A's layout."""
    if use_kernel is None:
        use_kernel = interpret or _backend() == "tpu"
    if not use_kernel:
        return spmv_ops.spmv(mat, x, use_kernel=False)
    plan = tune_spmv(mat, x.dtype, cache=cache, interpret=interpret)
    return spmv_ops.spmv(mat, x, block_rows=plan.block_rows,
                         block_cols=plan.block_cols, interpret=interpret,
                         use_kernel=True)


# ---------------------------------------------------------------------------
# Model-serving plans
# ---------------------------------------------------------------------------

def plan_for_model(cfg, batch: int, *, cache: TuneCache | None = None,
                   measure_k: int = 0) -> list[dict]:
    """Pre-tune the decode-path matmul shapes of a model config.

    Called by `launch.serve` at server startup so the first request never
    pays the search.  Measurement defaults off (analytic ranking only):
    startup happens on the serving critical path.
    """
    d, f, v = cfg.d_model, cfg.d_ff or cfg.d_model * 4, cfg.vocab_size
    qkv = max(cfg.num_heads * cfg.head_dim, d) or d
    shapes = [
        ("qkv_proj", batch, qkv, d),
        ("out_proj", batch, d, qkv),
        ("ffn_up", batch, f, d),
        ("ffn_down", batch, d, f),
        ("logits", batch, v, d),
    ]
    plans = []
    for name, m, n, k in shapes:
        p = tune_matmul(m, n, k, jnp.bfloat16, measure_k=measure_k,
                        cache=cache)
        plans.append({"op": name, "mnk": [m, n, k],
                      "tile": [p.tile.y, p.tile.x, p.tile.z],
                      "source": p.source,
                      "model_time_us": p.model_time_s * 1e6})
    return plans
