"""Autotuning kernel engine: one generic DSE → measure → cache pipeline.

The paper's §IV flow is: enumerate candidate configurations, *simulate*
each (SystemC machine model), pick the winner, synthesize.  Earlier PRs
closed that loop once per kernel family — and accumulated four parallel
copies of the same pipeline.  This module now holds exactly one:

1. **candidates** — the family's ``KernelSpec.enumerate_candidates``
   ranks feasible configurations under the VMEM budget with the analytic
   model (the "simulate" step, microseconds per point);
2. **measure**    — the top-K survivors are timed on the real backend
   (Pallas on TPU; interpret-mode on CPU for small problems, analytic
   fallback above ``max_measure_elems`` where interpret timing is
   meaningless);
3. **memoize**    — winners land in a unified on-disk JSON cache keyed
   ``family:{spec.key_fn(...)}:v{budget}`` (schema v3; v2 files are
   migrated in place, preserving measured entries).

`tune(spec, problem) -> Plan` and `dispatch(family, *args)` are the only
engine entry points; which families exist is entirely the registry's
business (`kernels/registry.py`).  The legacy per-family
`tune_*`/`tuned_*` functions remain as thin deprecation shims so older
call sites keep working while they migrate.
"""

from __future__ import annotations

import dataclasses
import json
import os
import pathlib
import time
import warnings
from typing import Callable, Sequence

import jax
import jax.numpy as jnp

from repro.core import hardware, ioutil, tiling
from repro.kernels import registry
from repro.kernels.registry import KernelSpec, Plan

# v3: the declarative KernelSpec registry unified the four per-family
# pipelines and entry formats ({"knobs": ..., "detail": ...} instead of
# family-specific field names).  The *meaning* of a cached winner is
# unchanged from v2, so v2 files are migrated in place (measured entries
# survive, re-shaped under the same family-prefixed keys); files from any
# other version are dropped wholesale (see TuneCache._load) — v1 predates
# block skipping and its winners must never be mis-applied.
ENGINE_VERSION = 3
CACHE_ENV = "REPRO_AUTOTUNE_CACHE"

# Above this many total operand elements, CPU interpret-mode timing is both
# glacial and unrepresentative — the analytic ranking decides alone.
MAX_MEASURE_ELEMS = 1 << 22


# ---------------------------------------------------------------------------
# On-disk memo cache
# ---------------------------------------------------------------------------

def default_cache_path() -> pathlib.Path:
    env = os.environ.get(CACHE_ENV)
    if env:
        return pathlib.Path(env)
    return pathlib.Path.home() / ".cache" / "repro" / "autotune.json"


# v2 entries carried family-specific field names; map them onto the v3
# {"knobs", "detail"} shape by key prefix.  Unknown prefixes are dropped
# (there is no family left to interpret them).
_V2_KNOB_FIELDS = {
    "matmul": (("tile",), ()),
    "spmv": (("block_rows", "block_cols"), ("waste",)),
    "attention": (("block_q", "block_k"), ()),
    "decode": (("block_k",), ()),
}


def _migrate_v2_entry(key: str, entry: dict) -> dict | None:
    family = key.split(":", 1)[0]
    fields = _V2_KNOB_FIELDS.get(family)
    if fields is None or not isinstance(entry, dict):
        return None
    knob_names, detail_names = fields
    if any(f not in entry for f in knob_names):
        return None
    return {
        "knobs": {f: entry[f] for f in knob_names},
        "source": entry.get("source", "model"),
        "model_time_s": entry.get("model_time_s", 0.0),
        "measured_us": entry.get("measured_us"),
        "detail": {f: entry[f] for f in detail_names if f in entry},
    }


class TuneCache:
    """Tiny write-through JSON cache: {key: plan-dict}.

    One file per machine (keys embed the backend), loaded lazily and
    rewritten on every put — tuning happens once per shape so write
    amplification is irrelevant, and a plain-text file keeps the cache
    inspectable and diffable.
    """

    def __init__(self, path: str | os.PathLike | None = None):
        self.path = pathlib.Path(path) if path else default_cache_path()
        self._data: dict | None = None
        self.hits = 0
        self.misses = 0

    def _load(self) -> dict:
        if self._data is None:
            raw = None
            try:
                text = self.path.read_text()
            except OSError:
                text = None          # no file yet: a fresh cache, silently
            if text is not None:
                try:
                    raw = json.loads(text)
                except ValueError:
                    # Corrupt JSON (truncated write, disk fault, stray
                    # edit): starting a fresh cache silently would destroy
                    # the evidence AND any measured entries a human might
                    # recover.  Quarantine the file instead and warn.
                    self._quarantine_corrupt()
            if (isinstance(raw, dict) and raw.get("version") == 2
                    and isinstance(raw.get("entries"), dict)):
                # v2 -> v3: same winners, new entry shape.  Measured TPU
                # entries are expensive; migration preserves them instead
                # of dropping the whole file.
                migrated = {}
                for key, entry in raw["entries"].items():
                    new = _migrate_v2_entry(key, entry)
                    if new is not None:
                        migrated[key] = new
                raw = {"version": ENGINE_VERSION, "entries": migrated}
            if not (isinstance(raw, dict)
                    and raw.get("version") == ENGINE_VERSION
                    and isinstance(raw.get("entries"), dict)):
                raw = {"version": ENGINE_VERSION, "entries": {}}
            self._data = raw
        return self._data

    def _quarantine_corrupt(self) -> None:
        corrupt = self.path.with_name(self.path.name + ".corrupt")
        try:
            self.path.replace(corrupt)
        except OSError:
            return               # unrenamable (e.g. read-only fs): move on
        warnings.warn(
            f"autotune cache {self.path} held corrupt JSON; quarantined it "
            f"to {corrupt} and starting a fresh cache", RuntimeWarning,
            stacklevel=3)

    def get(self, key: str) -> dict | None:
        entry = self._load()["entries"].get(key)
        if entry is None:
            self.misses += 1
        else:
            self.hits += 1
        return entry

    def put(self, key: str, value: dict) -> None:
        data = self._load()
        data["entries"][key] = value
        try:
            # Atomic temp+fsync+rename (core.ioutil): a process killed
            # mid-save leaves the previous cache intact instead of a torn
            # file for the next run to quarantine.
            ioutil.atomic_write_json(self.path, data)
        except OSError:
            # An unwritable cache must never take down the compute path;
            # the in-memory entry above still serves this process.
            pass


_default_cache: TuneCache | None = None


def get_cache() -> TuneCache:
    """Process-wide cache bound to the current $REPRO_AUTOTUNE_CACHE."""
    global _default_cache
    path = default_cache_path()
    if _default_cache is None or _default_cache.path != path:
        _default_cache = TuneCache(path)
    return _default_cache


# ---------------------------------------------------------------------------
# Measurement harness
# ---------------------------------------------------------------------------

def measure(fn: Callable[[], jax.Array], reps: int = 3,
            warmup: int = 1) -> float:
    """Median-free best-effort wall timing of ``fn`` in microseconds."""
    for _ in range(max(warmup, 0)):
        fn().block_until_ready()
    t0 = time.perf_counter()
    for _ in range(max(reps, 1)):
        fn().block_until_ready()
    return (time.perf_counter() - t0) / max(reps, 1) * 1e6


def _backend() -> str:
    return jax.default_backend()


def _budget_tag(vmem_bytes: int | None) -> str:
    # The budget shapes the feasible set, so constrained and default
    # tunings must not share cache entries.
    return "dflt" if vmem_bytes is None else str(vmem_bytes)


def cache_key(spec: KernelSpec, problem: dict, dtype_name: str,
              backend: str, vmem_bytes: int | None) -> str:
    """`family:{spec suffix}:v{budget}` — the unified v3 key format."""
    return (f"{spec.name}:{spec.key_fn(problem, dtype_name, backend)}"
            f":v{_budget_tag(vmem_bytes)}")


# ---------------------------------------------------------------------------
# The generic engine
# ---------------------------------------------------------------------------

def tune(
    spec: KernelSpec | str, problem: dict, dtype=jnp.float32, *,
    measure_k: int = 3,
    vmem_bytes: int | None = None,
    max_measure_elems: int = MAX_MEASURE_ELEMS,
    cache: TuneCache | None = None,
    interpret: bool | None = None,
) -> Plan:
    """Pick the family's knobs for ``problem`` via DSE → measure → cache.

    ``measure_k=0`` disables measurement (pure analytic ranking) — used by
    planning paths that must stay fast, e.g. server startup on CPU.
    """
    if isinstance(spec, str):
        spec = registry.get(spec)
    dtype = jnp.dtype(dtype)
    backend = _backend()
    cache = cache or get_cache()
    key = cache_key(spec, problem, dtype.name, backend, vmem_bytes)
    measurable = (measure_k > 0
                  and (backend == "tpu"
                       or spec.measure_elems(problem) <= max_measure_elems))

    hit = cache.get(key)
    if hit is not None and hit.get("poisoned"):
        # A kernel launch with this winner failed at dispatch
        # (`mark_plan_poisoned`): never serve it again — re-run the DSE,
        # and the fresh put below replaces the quarantined entry.
        hit = None
    # An analytic-only entry (e.g. written by serve startup with
    # measure_k=0) is upgraded, not returned, once a measuring caller
    # shows up — otherwise the measure step would be skipped forever.
    if hit is not None and not (measurable and hit.get("source") == "model"):
        return Plan(spec.name, key, dict(problem), dict(hit["knobs"]),
                    "cache", hit["model_time_s"], hit.get("measured_us"),
                    dict(hit.get("detail") or {}))

    ranked = spec.enumerate_candidates(problem, dtype_bytes=dtype.itemsize,
                                       vmem_bytes=vmem_bytes,
                                       top=max(measure_k, 1))
    # Deterministic order + dedupe are the engine's job: score first, the
    # family's declared tie-break second, identical knob sets collapsed
    # (small problems clamp many candidates onto the same point).
    seen, cands = set(), []
    for c in sorted(ranked, key=lambda c: (c.score, spec.tie_break(c.knobs))):
        sig = json.dumps(c.knobs, sort_keys=True)
        if sig not in seen:
            seen.add(sig)
            cands.append(c)

    interpret = (backend != "tpu") if interpret is None else interpret
    best, best_us = None, float("inf")
    if measurable and cands:
        inputs = spec.make_inputs(problem, dtype)
        for c in cands[:measure_k]:
            fn = spec.build_launcher(problem, c.knobs, interpret=interpret)
            try:
                us = measure(lambda fn=fn: fn(*inputs))
            except Exception:
                continue  # e.g. real VMEM overflow the model missed
            if us < best_us:
                best, best_us = c, us
    if best is not None:
        chosen, source, measured_us = best, "measured", best_us
    else:
        chosen, source, measured_us = cands[0], "model", None

    detail = {f: chosen.detail[f] for f in spec.detail_keys
              if chosen.detail and f in chosen.detail}
    cache.put(key, {"knobs": chosen.knobs, "source": source,
                    "model_time_s": chosen.score,
                    "measured_us": measured_us, "detail": detail})
    return Plan(spec.name, key, dict(problem), dict(chosen.knobs), source,
                chosen.score, measured_us, detail)


# Chaos-injection hook consulted by `dispatch` just before a kernel launch
# (`runtime.faults.FaultInjector.dispatch_hook` via `install_dispatch_hook`).
# None in production: the hot path pays one None-check.
_dispatch_fault_hook: Callable[[str], None] | None = None


def install_dispatch_hook(hook: Callable[[str], None] | None) -> None:
    """Install (or clear, with None) the kernel-dispatch fault hook."""
    global _dispatch_fault_hook
    _dispatch_fault_hook = hook


def mark_plan_poisoned(key: str, cache: TuneCache | None = None) -> None:
    """Quarantine a cached winner whose kernel launch failed: the entry is
    kept (forensics) but flagged, so the next `tune` of its problem re-runs
    the DSE instead of serving the known-bad knobs."""
    cache = cache or get_cache()
    entry = dict(cache._load()["entries"].get(key) or {})
    entry["poisoned"] = True
    cache.put(key, entry)


def dispatch(family: str, *args, cache: TuneCache | None = None,
             interpret: bool = False, use_kernel: bool | None = None,
             measure_k: int | None = None, **kwargs):
    """Run ``family``'s kernel on ``args`` with its autotuned plan.

    Keeps the repo's dispatch convention: Pallas on TPU (or with
    ``interpret=True`` anywhere), the family's pure-jnp oracle otherwise —
    so CPU callers that never reach the kernel path pay no tuning cost.
    ``measure_k=None`` uses the family's declared default (0 for families
    dispatched inside a jit trace, where wall-clocking is impossible;
    measured winners then come from offline callers through the shared
    cache).

    Graceful degradation: a kernel launch that raises (real Pallas
    failure, or the chaos hook) falls back one-shot to the family's
    pure-jnp reference path — numerically equivalent, just slower — and
    the plan is marked poisoned in the cache so the next tune re-runs the
    DSE instead of re-serving the knobs that just failed.  A serving
    request must complete slowly, not die on a kernel.
    """
    spec = registry.get(family)
    if use_kernel is None:
        use_kernel = interpret or _backend() == "tpu"
    if not use_kernel:
        return spec.reference_fn(*args, **kwargs)
    problem, dtype = spec.problem_fn(*args, **kwargs)
    plan = tune(spec, problem, dtype,
                measure_k=spec.default_measure_k
                if measure_k is None else measure_k,
                cache=cache, interpret=interpret)
    try:
        if _dispatch_fault_hook is not None:
            _dispatch_fault_hook(family)
        return spec.run_fn(plan, *args, interpret=interpret, **kwargs)
    except Exception as e:
        mark_plan_poisoned(plan.key, cache=cache)
        warnings.warn(
            f"kernel dispatch for family '{family}' failed ({e!r}); "
            f"falling back to the jnp reference path and poisoning plan "
            f"{plan.key} for re-tune", RuntimeWarning, stacklevel=2)
        return spec.reference_fn(*args, **kwargs)


# ---------------------------------------------------------------------------
# Deprecated per-family shims
# ---------------------------------------------------------------------------
# Everything below delegates to tune()/dispatch(); the per-family plan
# dataclasses and tune_*/tuned_* signatures are kept only so pre-registry
# call sites keep working.  New code should call the engine directly:
#
#     plan = autotune.tune("attention", {...})
#     out = autotune.dispatch("matmul", a, b, activation="gelu")

@dataclasses.dataclass(frozen=True)
class MatmulPlan:
    tile: tiling.Tile
    source: str                  # "cache" | "measured" | "model"
    model_time_s: float
    measured_us: float | None
    key: str


@dataclasses.dataclass(frozen=True)
class SpmvPlan:
    block_rows: int
    block_cols: int | None       # None -> whole-x-resident kernel
    source: str                  # "cache" | "measured" | "model"
    model_time_s: float
    measured_us: float | None
    waste: float                 # active/fetched input metric at block_rows
    key: str


@dataclasses.dataclass(frozen=True)
class AttentionPlan:
    block_q: int
    block_k: int
    source: str                  # "cache" | "measured" | "model"
    model_time_s: float
    measured_us: float | None
    key: str


@dataclasses.dataclass(frozen=True)
class DecodePlan:
    block_k: int
    source: str                  # "cache" | "measured" | "model"
    model_time_s: float
    measured_us: float | None
    key: str


def _attention_key(bh: int, sq: int, sk: int, dh: int, causal: bool,
                   window: int | None, dtype: str, backend: str,
                   vmem_bytes: int | None) -> str:
    """Deprecated: compose `cache_key` with the spec's key_fn instead."""
    return cache_key(registry.get("attention"),
                     {"bh": bh, "sq": sq, "sk": sk, "dh": dh,
                      "causal": causal, "window": window},
                     dtype, backend, vmem_bytes)


def rank_spmv_configs(mat, vmem_bytes: int | None = None,
                      block_rows_cands: Sequence[int] = (8, 16, 32, 64),
                      block_cols_cands: Sequence[int | None] = (None, 256,
                                                                512, 1024,
                                                                2048)):
    """Deprecated: moved to `kernels.spmv.spec.rank_configs`."""
    from repro.kernels.spmv import spec as spmv_spec
    return spmv_spec.rank_configs(mat, vmem_bytes=vmem_bytes,
                                  block_rows_cands=block_rows_cands,
                                  block_cols_cands=block_cols_cands)


def tune_matmul(m: int, n: int, k: int, dtype=jnp.float32, *,
                measure_k: int = 3, vmem_bytes: int | None = None,
                max_measure_elems: int = MAX_MEASURE_ELEMS,
                cache: TuneCache | None = None,
                interpret: bool | None = None) -> MatmulPlan:
    """Deprecated shim over ``tune("matmul", ...)``."""
    p = tune("matmul", {"m": m, "n": n, "k": k}, dtype,
             measure_k=measure_k, vmem_bytes=vmem_bytes,
             max_measure_elems=max_measure_elems, cache=cache,
             interpret=interpret)
    return MatmulPlan(tiling.Tile(*p.knobs["tile"]), p.source,
                      p.model_time_s, p.measured_us, p.key)


def tune_spmv(mat, dtype=jnp.float32, *,
              measure_k: int = 3, vmem_bytes: int | None = None,
              max_measure_elems: int = MAX_MEASURE_ELEMS,
              cache: TuneCache | None = None,
              interpret: bool | None = None) -> SpmvPlan:
    """Deprecated shim over ``tune("spmv", ...)``."""
    p = tune("spmv", {"mat": mat}, dtype, measure_k=measure_k,
             vmem_bytes=vmem_bytes, max_measure_elems=max_measure_elems,
             cache=cache, interpret=interpret)
    return SpmvPlan(p.knobs["block_rows"], p.knobs["block_cols"], p.source,
                    p.model_time_s, p.measured_us,
                    p.detail.get("waste", 0.0), p.key)


def tune_attention(bh: int, sq: int, sk: int, dh: int, dtype=jnp.float32, *,
                   causal: bool = True, window: int | None = None,
                   measure_k: int = 3, vmem_bytes: int | None = None,
                   max_measure_elems: int = MAX_MEASURE_ELEMS,
                   cache: TuneCache | None = None,
                   interpret: bool | None = None) -> AttentionPlan:
    """Deprecated shim over ``tune("attention", ...)``."""
    p = tune("attention", {"bh": bh, "sq": sq, "sk": sk, "dh": dh,
                           "causal": causal, "window": window}, dtype,
             measure_k=measure_k, vmem_bytes=vmem_bytes,
             max_measure_elems=max_measure_elems, cache=cache,
             interpret=interpret)
    return AttentionPlan(p.knobs["block_q"], p.knobs["block_k"], p.source,
                         p.model_time_s, p.measured_us, p.key)


def tune_decode(bkv: int, g: int, cache_len: int, dh: int,
                dtype=jnp.float32, *,
                measure_k: int = 3, vmem_bytes: int | None = None,
                max_measure_elems: int = MAX_MEASURE_ELEMS,
                cache: TuneCache | None = None,
                interpret: bool | None = None) -> DecodePlan:
    """Deprecated shim over ``tune("decode", ...)``."""
    p = tune("decode", {"bkv": bkv, "g": g, "cache_len": cache_len,
                        "dh": dh}, dtype,
             measure_k=measure_k, vmem_bytes=vmem_bytes,
             max_measure_elems=max_measure_elems, cache=cache,
             interpret=interpret)
    return DecodePlan(p.knobs["block_k"], p.source, p.model_time_s,
                      p.measured_us, p.key)


def tuned_matmul(a: jax.Array, b: jax.Array,
                 bias: jax.Array | None = None,
                 activation: str | None = None,
                 interpret: bool = False,
                 use_kernel: bool | None = None,
                 compute_dtype=None, out_dtype=None,
                 cache: TuneCache | None = None) -> jax.Array:
    """Deprecated shim over ``dispatch("matmul", ...)``."""
    return dispatch("matmul", a, b, bias=bias, activation=activation,
                    interpret=interpret, use_kernel=use_kernel,
                    compute_dtype=compute_dtype, out_dtype=out_dtype,
                    cache=cache)


def tuned_spmv(mat, x: jax.Array,
               interpret: bool = False,
               use_kernel: bool | None = None,
               cache: TuneCache | None = None) -> jax.Array:
    """Deprecated shim over ``dispatch("spmv", ...)``."""
    return dispatch("spmv", mat, x, interpret=interpret,
                    use_kernel=use_kernel, cache=cache)


def tuned_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, window: int | None = None,
                    interpret: bool = False,
                    use_kernel: bool | None = None,
                    measure_k: int = 0,
                    cache: TuneCache | None = None) -> jax.Array:
    """Deprecated shim over ``dispatch("attention", ...)``."""
    return dispatch("attention", q, k, v, causal=causal, window=window,
                    interpret=interpret, use_kernel=use_kernel,
                    measure_k=measure_k, cache=cache)


def tuned_decode(q: jax.Array, k: jax.Array, v: jax.Array, *,
                 length, interpret: bool = False,
                 use_kernel: bool | None = None,
                 measure_k: int = 0,
                 cache: TuneCache | None = None) -> jax.Array:
    """Deprecated shim over ``dispatch("decode", ...)``."""
    return dispatch("decode", q, k, v, length=length, interpret=interpret,
                    use_kernel=use_kernel, measure_k=measure_k, cache=cache)


# ---------------------------------------------------------------------------
# Model-serving plans
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class OpPlan:
    """A tuned Plan bound to a named serving op (e.g. "ffn_up") — the unit
    `plan_for_model` returns and `predict_decode_step_us` consumes."""

    op: str
    plan: Plan

    def record(self) -> dict:
        return {"op": self.op, "problem": dict(self.plan.problem),
                **self.plan.record()}


def plan_for_model(cfg, batch: int, *, prefill_len: int = 0,
                   cache_len: int = 0,
                   kv_dtype=jnp.bfloat16,
                   slot_lengths: Sequence[int] | None = None,
                   cache: TuneCache | None = None,
                   measure_k: int = 0) -> list[OpPlan]:
    """Pre-tune the serving-path kernel shapes of a model config.

    Called by `launch.serve` at server startup so the first request never
    pays the search.  Measurement defaults off (analytic ranking only):
    startup happens on the serving critical path.  Covers the decode-path
    matmuls, — when ``prefill_len`` is given — the prefill flash-attention
    shape, and — when ``cache_len`` is given — the fused decode-attention
    fold, so every registered serving family shares one warmup.  Returns
    typed `OpPlan`s; `.record()` them for logging.

    ``slot_lengths`` (optional) is the workload's steady-state slot-depth
    distribution: the decode plan is then tuned on ``batch`` quantiles of
    it (per-slot active-prefix accounting — a ragged batch prefers a finer
    block_k so shallow slots skip more), and the winner is *pinned* under
    the plain runtime dispatch key so the jitted serve step — whose traced
    problem cannot carry the distribution — actually runs the
    workload-aware block.  Pinning never overwrites a measured entry.
    """
    d, f, v = cfg.d_model, cfg.d_ff or cfg.d_model * 4, cfg.vocab_size
    qkv = max(cfg.num_heads * cfg.head_dim, d) or d
    shapes = [
        ("qkv_proj", batch, qkv, d),
        ("out_proj", batch, d, qkv),
        ("ffn_up", batch, f, d),
        ("ffn_down", batch, d, f),
        ("logits", batch, v, d),
    ]
    plans = []
    for name, m, n, k in shapes:
        plans.append(OpPlan(name, tune(
            "matmul", {"m": m, "n": n, "k": k}, jnp.bfloat16,
            measure_k=measure_k, cache=cache)))
    if prefill_len > 0 and cfg.num_heads:
        plans.append(OpPlan("attn_prefill", tune(
            "attention",
            {"bh": batch * cfg.num_heads, "sq": prefill_len,
             "sk": prefill_len, "dh": cfg.head_dim,
             "causal": cfg.causal, "window": cfg.sliding_window},
            jnp.bfloat16, measure_k=measure_k, cache=cache)))
    if cache_len > 0 and cfg.num_heads and cfg.num_kv_heads:
        # Keyed on the KV-cache dtype the server allocates (`kv_dtype`) —
        # the decode kernel streams the cache, not the activations.  An
        # int8 cache routes to the quantized family instead: its layout
        # is fixed (q8 tag in the key), so the plan keys on the bf16
        # activation dtype the serve loop's q rows carry.
        quantized = jnp.dtype(kv_dtype) == jnp.int8
        family = "decode_int8" if quantized else "decode"
        tune_dtype = jnp.bfloat16 if quantized else kv_dtype
        problem = {"bkv": batch * cfg.num_kv_heads,
                   "g": cfg.num_heads // cfg.num_kv_heads,
                   "cache_len": cache_len, "dh": cfg.head_dim}
        if slot_lengths:
            problem["lengths"] = tuple(
                _quantile_lengths(batch, slot_lengths, cache_len))
        plan = tune(family, problem, tune_dtype, measure_k=measure_k,
                    cache=cache)
        if slot_lengths:
            # Pin the workload-aware winner under the runtime dispatch key
            # (the jit-traced problem has no distribution field), unless a
            # measured winner already owns it.
            run_problem = {k: v for k, v in problem.items()
                           if k != "lengths"}
            spec = registry.get(family)
            cache_obj = cache or get_cache()
            run_key = cache_key(spec, run_problem,
                                jnp.dtype(tune_dtype).name, _backend(), None)
            existing = cache_obj._load()["entries"].get(run_key)
            if existing is None or existing.get("source") == "model":
                # Re-score the pinned knobs at the runtime problem: the
                # entry's model time must describe the key it lives under
                # (batch-max accounting), not the ragged score.
                run_cost = spec.cost_fn(run_problem, plan.knobs)
                cache_obj.put(run_key, {
                    "knobs": dict(plan.knobs), "source": "model",
                    "model_time_s": run_cost["time_s"],
                    "measured_us": None,
                    "detail": {"pinned_from": plan.key}})
        plans.append(OpPlan("attn_decode", plan))
    return plans


def _attn_layer_count(cfg) -> int:
    return sum(1 for l in range(cfg.num_layers) if cfg.is_attn_layer(l))


def _quantile_lengths(batch: int, slot_lengths: Sequence[int],
                      cache_len: int) -> list[int]:
    """Resample a workload slot-depth distribution to ``batch`` evenly
    spaced quantiles (sorted, clamped to the allocated cache) — the
    per-slot lengths a candidate batch is priced at."""
    ls = sorted(max(0, min(int(l), cache_len)) for l in slot_lengths)
    return [ls[((2 * i + 1) * len(ls)) // (2 * batch)] for i in range(batch)]


def predict_decode_step_us(cfg, batch: int, *, cache_len: int,
                           kv_dtype=jnp.bfloat16,
                           lengths: Sequence[int] | None = None,
                           plans: list[OpPlan] | None = None,
                           cache: TuneCache | None = None,
                           block_k: int | None = None) -> float:
    """Predicted wall time of one decode step at this batch, from the tuned
    plans' model times.

    The qkv/out projections and the KV-stream term are charged per
    *attention* layer (a hybrid's mamba layers have neither — their mixer
    matmuls are an uncounted approximation), the FFN matmuls per layer, the
    logits matmul once.  The KV stream (`2 * batch * cache_len * kv_dim`
    bf16 bytes per attention layer at `hbm_bw`) is the decode hot loop's
    memory floor.

    ``lengths`` (optional, one valid prefix per slot) prices the KV term
    at the ragged batch's active prefixes — the block-rounded per-row
    stream the fused kernel actually executes — instead of the batch-max
    broadcast that charges every short slot the full ``cache_len``.

    ``block_k`` (optional) overrides the tuned plan's KV block in the
    re-priced term: the paged decode kernel streams one *page* per grid
    step, so a paged server prices the stream at its page size rather
    than the contiguous plan's tuned block.
    """
    lengths = lengths or None            # empty == no distribution
    plans = plans if plans is not None else plan_for_model(
        cfg, batch, cache_len=cache_len, kv_dtype=kv_dtype,
        slot_lengths=lengths, cache=cache)
    attn_ops_ = {"qkv_proj", "out_proj"}
    ffn_ops = {"ffn_up", "ffn_down"}
    n_attn = _attn_layer_count(cfg)
    attn_us = sum(p.plan.model_time_us for p in plans if p.op in attn_ops_)
    ffn_us = sum(p.plan.model_time_us for p in plans if p.op in ffn_ops)
    logits_us = sum(p.plan.model_time_us for p in plans if p.op == "logits")
    decode_plan = next((p for p in plans if p.op == "attn_decode"), None)
    if decode_plan is not None:
        # The tuned decode-attention plan prices the KV stream *and* the
        # attention FLOPs at the chosen block_k (including ragged-tail
        # over-fetch) — strictly more faithful than the raw byte floor.
        if lengths is not None:
            # Re-price the tuned block_k on the actual length
            # distribution (the plan itself is tuned at the allocated
            # cache depth — the worst case the kernel must still fit).
            from repro.core import cost_model
            prob = decode_plan.plan.problem
            bk = block_k or decode_plan.plan.knobs["block_k"]
            if jnp.dtype(kv_dtype) == jnp.int8:
                model = cost_model.quantized_decode_time_model(
                    prob["bkv"], prob["g"], prob["cache_len"], prob["dh"],
                    bk, lengths=list(lengths))
            else:
                model = cost_model.decode_time_model(
                    prob["bkv"], prob["g"], prob["cache_len"], prob["dh"],
                    bk, dtype_bytes=jnp.dtype(kv_dtype).itemsize,
                    lengths=list(lengths))
            kv_us = n_attn * model["time_s"] * 1e6
        else:
            kv_us = n_attn * decode_plan.plan.model_time_us
    else:
        streamed = (float(sum(lengths)) if lengths is not None
                    else float(batch * cache_len))
        if jnp.dtype(kv_dtype) == jnp.int8:
            # int8 values + one f32 scale per token per KV head, K and V.
            kv_bytes = 2.0 * streamed * (cfg.kv_dim
                                         + 4 * cfg.num_kv_heads)
        else:
            kv_bytes = (2.0 * streamed * cfg.kv_dim
                        * jnp.dtype(kv_dtype).itemsize)        # K+V stream
        kv_us = n_attn * kv_bytes / hardware.TPU_V5E.hbm_bw * 1e6
    return (n_attn * attn_us + cfg.num_layers * ffn_us + logits_us + kv_us)


def select_serving_batch(
    cfg, *, cache_len: int, prefill_len: int = 0,
    kv_dtype=jnp.bfloat16,
    candidates: Sequence[int] = (1, 2, 4, 8, 16, 32, 64),
    latency_budget_ms: float | None = None,
    slot_lengths: Sequence[int] | None = None,
    cache: TuneCache | None = None,
    pool_pages: int | None = None,
    page_size: int | None = None,
) -> dict:
    """Sweep candidate batch sizes against the tuned plans' predicted step
    time; pick the batch maximizing predicted decode throughput under the
    latency budget.

    This is the paper's DSE methodology lifted one level: the design knob is
    no longer a kernel tile but the *serving batch*, and the simulator is
    the same analytic machine model the kernel tuner ranks with — so the
    continuous-batching loop's shape is a tuner output, not a hand-picked
    default.  Deterministic: analytic model times only (measured cache
    entries, when present, refine the underlying plans but the sweep itself
    never wall-clocks).  Returns the decision record `launch.serve` logs at
    startup: {"batch", "latency_budget_ms", "sweep": [...]}.

    ``slot_lengths`` (optional) is the workload's steady-state slot-depth
    distribution; each candidate batch is priced at ``b`` evenly spaced
    quantiles of it (per-slot active-prefix accounting) instead of the
    batch-max broadcast that over-charges ragged batches — so a mixed
    16/500-token batch no longer pays 500 everywhere in the sweep.

    ``page_size`` (optional, paged serving) adds the free-page term: each
    candidate's steady-state KV demand in pages is checked against the
    physical pool (``pool_pages``, or the candidate's contiguous
    equivalent when None) — a batch whose page demand overflows the pool
    is infeasible no matter its predicted throughput, and the KV stream
    is re-priced at the page granularity the paged kernel walks.
    """
    slot_lengths = slot_lengths or None   # empty queue == no distribution
    sweep = []
    best = None
    decode_plans = {}
    for b in candidates:
        plans = plan_for_model(cfg, b, prefill_len=prefill_len,
                               cache_len=cache_len, kv_dtype=kv_dtype,
                               slot_lengths=slot_lengths, cache=cache)
        lengths_b = (None if slot_lengths is None
                     else _quantile_lengths(b, slot_lengths, cache_len))
        dp = next((p for p in plans if p.op == "attn_decode"), None)
        # Provenance ("model" cold vs "cache" warm) and wall-clock numbers
        # are volatile across runs, so they are stripped from the record;
        # the kept knobs/model_time_us are reproducible *given the same
        # cache contents* (a measured winner in the shared cache
        # deliberately refines the plan — and hence the sweep — relative
        # to a cold cache).  Full provenance lives in the Server's
        # kernel_plan log.
        if dp is not None:
            rec = dp.record()
            for volatile in ("source", "provenance", "measured_us"):
                rec.pop(volatile, None)
            decode_plans[b] = rec
        else:
            decode_plans[b] = None
        step_us = predict_decode_step_us(cfg, b, cache_len=cache_len,
                                         kv_dtype=kv_dtype, plans=plans,
                                         lengths=lengths_b,
                                         block_k=page_size)
        tok_per_s = b / (step_us * 1e-6)
        feasible = (latency_budget_ms is None
                    or step_us <= latency_budget_ms * 1e3)
        row = {"batch": b, "step_us": step_us,
               "tok_per_s": tok_per_s, "feasible": feasible}
        if lengths_b is not None:
            row["slot_lengths"] = lengths_b
            row["mean_len"] = sum(lengths_b) / len(lengths_b)
        if page_size:
            # free-page term: steady-state page demand at the priced
            # lengths vs the physical pool
            lens = lengths_b if lengths_b is not None else [cache_len] * b
            kv_pages = sum(-(-max(1, l) // page_size) for l in lens)
            pool = pool_pages or b * (-(-cache_len // page_size))
            row["kv_pages"] = kv_pages
            row["pool_pages"] = pool
            row["free_pages"] = max(0, pool - kv_pages)
            row["kv_fits"] = kv_pages <= pool
            row["feasible"] = feasible = feasible and row["kv_fits"]
        sweep.append(row)
        if feasible and (best is None or tok_per_s > best["tok_per_s"]):
            best = sweep[-1]
    if best is None:       # nothing met the budget: least-bad latency wins
        # (but never a batch whose pages overflow the pool — that one
        # cannot be served at all)
        fits = [r for r in sweep if r.get("kv_fits", True)]
        best = min(fits or sweep, key=lambda r: r["step_us"])
    return {"batch": best["batch"],
            "predicted_step_us": best["step_us"],
            "predicted_tok_per_s": best["tok_per_s"],
            "latency_budget_ms": latency_budget_ms,
            "length_model": ("active-prefix" if slot_lengths is not None
                             else "batch-max"),
            "decode_plan": decode_plans[best["batch"]],
            "sweep": sweep}
