"""Autotuning kernel engine: the paper's DSE loop, closed over real kernels.

The paper's §IV flow is: enumerate candidate configurations, *simulate* each
(SystemC machine model), pick the winner, synthesize.  The repo has had the
first half for a while (`core.dse` ranks `Tile` candidates with the analytic
`core.cost_model`) but the Pallas kernels ran with fixed hand-picked tiles.
This module closes the loop:

1. **candidates** — `core.dse.rank_matmul_tiles` / `rank_spmv_configs` rank
   feasible configurations under the VMEM budget with the analytic model
   (the "simulate" step, at a few microseconds per point);
2. **measure**    — the top-K survivors are timed on the real backend
   (Pallas on TPU; interpret-mode on CPU for small problems, analytic
   fallback above `max_measure_elems` where interpret timing is
   meaningless);
3. **memoize**    — winners land in an on-disk JSON cache keyed by
   (kernel, shape, dtype, backend), so a serving process pays the search
   once per shape, ever.

`tuned_matmul` / `tuned_spmv` are the drop-in entry points benchmarks,
examples and the serving path call instead of fixed tiles.
"""

from __future__ import annotations

import dataclasses
import json
import os
import pathlib
import time
from typing import Callable, Sequence

import jax
import jax.numpy as jnp

from repro.core import cost_model, dse, hardware, tiling
from repro.kernels.attention import decode as attn_decode
from repro.kernels.attention import kernel as attn_kernel
from repro.kernels.attention import ops as attn_ops
from repro.kernels.matmul import ops as matmul_ops
from repro.kernels.spmv import ops as spmv_ops

# v2: block-skipping flash kernel — a cached (block_q, block_k) for
# causal=True now means triangular traffic/FLOPs, so v1 winners (ranked
# under every-block accounting) are stale and must be re-tuned, and the
# decode kernel family joins the cache.  Entries from any other version
# are ignored wholesale (see TuneCache._load), never mis-applied.
ENGINE_VERSION = 2
CACHE_ENV = "REPRO_AUTOTUNE_CACHE"

# Above this many total operand elements, CPU interpret-mode timing is both
# glacial and unrepresentative — the analytic ranking decides alone.
MAX_MEASURE_ELEMS = 1 << 22


# ---------------------------------------------------------------------------
# On-disk memo cache
# ---------------------------------------------------------------------------

def default_cache_path() -> pathlib.Path:
    env = os.environ.get(CACHE_ENV)
    if env:
        return pathlib.Path(env)
    return pathlib.Path.home() / ".cache" / "repro" / "autotune.json"


class TuneCache:
    """Tiny write-through JSON cache: {key: plan-dict}.

    One file per machine (keys embed the backend), loaded lazily and
    rewritten on every put — tuning happens once per shape so write
    amplification is irrelevant, and a plain-text file keeps the cache
    inspectable and diffable.
    """

    def __init__(self, path: str | os.PathLike | None = None):
        self.path = pathlib.Path(path) if path else default_cache_path()
        self._data: dict | None = None
        self.hits = 0
        self.misses = 0

    def _load(self) -> dict:
        if self._data is None:
            try:
                raw = json.loads(self.path.read_text())
            except (OSError, ValueError):
                raw = None
            if not (isinstance(raw, dict)
                    and raw.get("version") == ENGINE_VERSION
                    and isinstance(raw.get("entries"), dict)):
                raw = {"version": ENGINE_VERSION, "entries": {}}
            self._data = raw
        return self._data

    def get(self, key: str) -> dict | None:
        entry = self._load()["entries"].get(key)
        if entry is None:
            self.misses += 1
        else:
            self.hits += 1
        return entry

    def put(self, key: str, value: dict) -> None:
        data = self._load()
        data["entries"][key] = value
        try:
            self.path.parent.mkdir(parents=True, exist_ok=True)
            tmp = self.path.with_suffix(".tmp")
            tmp.write_text(json.dumps(data, indent=1, sort_keys=True))
            tmp.replace(self.path)
        except OSError:
            # An unwritable cache must never take down the compute path;
            # the in-memory entry above still serves this process.
            pass


_default_cache: TuneCache | None = None


def get_cache() -> TuneCache:
    """Process-wide cache bound to the current $REPRO_AUTOTUNE_CACHE."""
    global _default_cache
    path = default_cache_path()
    if _default_cache is None or _default_cache.path != path:
        _default_cache = TuneCache(path)
    return _default_cache


# ---------------------------------------------------------------------------
# Measurement harness
# ---------------------------------------------------------------------------

def measure(fn: Callable[[], jax.Array], reps: int = 3,
            warmup: int = 1) -> float:
    """Median-free best-effort wall timing of ``fn`` in microseconds."""
    for _ in range(max(warmup, 0)):
        fn().block_until_ready()
    t0 = time.perf_counter()
    for _ in range(max(reps, 1)):
        fn().block_until_ready()
    return (time.perf_counter() - t0) / max(reps, 1) * 1e6


def _backend() -> str:
    return jax.default_backend()


# ---------------------------------------------------------------------------
# Matmul
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class MatmulPlan:
    tile: tiling.Tile
    source: str                  # "cache" | "measured" | "model"
    model_time_s: float
    measured_us: float | None
    key: str


def _budget_tag(vmem_bytes: int | None) -> str:
    # The budget shapes the feasible set, so constrained and default
    # tunings must not share cache entries.
    return "dflt" if vmem_bytes is None else str(vmem_bytes)


def _matmul_key(m: int, n: int, k: int, dtype: str, backend: str,
                vmem_bytes: int | None) -> str:
    return f"matmul:{m}x{n}x{k}:{dtype}:{backend}:v{_budget_tag(vmem_bytes)}"


def tune_matmul(
    m: int, n: int, k: int, dtype=jnp.float32, *,
    measure_k: int = 3,
    vmem_bytes: int | None = None,
    max_measure_elems: int = MAX_MEASURE_ELEMS,
    cache: TuneCache | None = None,
    interpret: bool | None = None,
) -> MatmulPlan:
    """Pick a Tile for an (m,k)@(k,n) product via DSE -> measure -> cache.

    ``measure_k=0`` disables measurement (pure analytic ranking) — used by
    planning paths that must stay fast, e.g. server startup on CPU.
    """
    dtype = jnp.dtype(dtype)
    backend = _backend()
    cache = cache or get_cache()
    key = _matmul_key(m, n, k, dtype.name, backend, vmem_bytes)
    measurable = (measure_k > 0
                  and (backend == "tpu"
                       or m * k + k * n + m * n <= max_measure_elems))

    hit = cache.get(key)
    # An analytic-only entry (e.g. written by serve startup with
    # measure_k=0) is upgraded, not returned, once a measuring caller
    # shows up — otherwise the measure step would be skipped forever.
    if hit is not None and not (measurable and hit.get("source") == "model"):
        return MatmulPlan(tiling.Tile(*hit["tile"]), "cache",
                          hit["model_time_s"], hit.get("measured_us"), key)

    ranked = dse.rank_matmul_tiles(m, n, k, vmem_bytes=vmem_bytes,
                                   dtype_bytes=dtype.itemsize,
                                   top=max(measure_k, 1))
    # Clamp to the padded problem and dedupe (small shapes collapse many
    # candidates onto the same effective tile).
    seen, cands = set(), []
    for c in ranked:
        t = matmul_ops.clamp_tile(c.detail["tile"], m, n, k)
        if t not in seen:
            seen.add(t)
            cands.append((c.score, t))

    interpret = (backend != "tpu") if interpret is None else interpret
    measured_us = None
    if measurable and len(cands) > 0:
        a = jax.random.normal(jax.random.PRNGKey(0), (m, k), jnp.float32)
        b = jax.random.normal(jax.random.PRNGKey(1), (k, n), jnp.float32)
        a, b = a.astype(dtype), b.astype(dtype)
        best_t, best_us = None, float("inf")
        for _, t in cands[:measure_k]:
            try:
                us = measure(lambda t=t: matmul_ops.matmul(
                    a, b, tile=t, interpret=interpret, use_kernel=True))
            except Exception:
                continue  # e.g. real VMEM overflow the model missed
            if us < best_us:
                best_t, best_us = t, us
        measurable = best_t is not None
    if measurable:
        tile, source, measured_us = best_t, "measured", best_us
        model_time_s = next(s for s, t in cands if t == tile)
    else:
        model_time_s, tile = cands[0]
        source = "model"
        measured_us = None

    cache.put(key, {"tile": [tile.y, tile.x, tile.z], "source": source,
                    "model_time_s": model_time_s,
                    "measured_us": measured_us})
    return MatmulPlan(tile, source, model_time_s, measured_us, key)


def tuned_matmul(a: jax.Array, b: jax.Array,
                 bias: jax.Array | None = None,
                 activation: str | None = None,
                 interpret: bool = False,
                 use_kernel: bool | None = None,
                 compute_dtype=None, out_dtype=None,
                 cache: TuneCache | None = None) -> jax.Array:
    """C = act(A @ B + bias) with the autotuned tile for A/B's shape.

    Same dispatch semantics as `kernels.matmul.matmul` (Pallas on TPU /
    interpret, oracle otherwise) — the tuner only runs when the kernel
    path would, so CPU oracle callers pay nothing.
    """
    if use_kernel is None:
        use_kernel = interpret or _backend() == "tpu"
    tile = None
    if use_kernel:
        m, k = a.shape
        _, n = b.shape
        dtype = jnp.dtype(compute_dtype) if compute_dtype is not None \
            else a.dtype
        tile = tune_matmul(m, n, k, dtype, cache=cache,
                           interpret=interpret).tile
    return matmul_ops.matmul(a, b, tile=tile, bias=bias,
                             activation=activation, interpret=interpret,
                             use_kernel=use_kernel,
                             compute_dtype=compute_dtype,
                             out_dtype=out_dtype)


# ---------------------------------------------------------------------------
# SpMV
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class SpmvPlan:
    block_rows: int
    block_cols: int | None       # None -> whole-x-resident kernel
    source: str                  # "cache" | "measured" | "model"
    model_time_s: float
    measured_us: float | None
    waste: float                 # active/fetched input metric at block_rows
    key: str


def _spmv_key(rows: int, width: int, n: int, nnz: int, layout: str,
              dtype: str, backend: str, vmem_bytes: int | None) -> str:
    return (f"spmv:{rows}x{width}:n{n}:nnz{nnz}:l{layout}:{dtype}:{backend}"
            f":v{_budget_tag(vmem_bytes)}")


def rank_spmv_configs(
    mat: spmv_ops.EllMatrix,
    vmem_bytes: int | None = None,
    block_rows_cands: Sequence[int] = (8, 16, 32, 64),
    block_cols_cands: Sequence[int | None] = (None, 256, 512, 1024, 2048),
) -> list[tuple[float, int, int | None, float]]:
    """Rank (block_rows, block_cols) configs by the bandwidth model.

    The active/fetched balance metric (`EllMatrix.sliced_waste`, built on
    `core.loadbalance`) enters the score as the fetch-amplification of the
    ELL payload — the tuner's analogue of the paper's "% of nnz per core"
    column.  Returns (score, block_rows, block_cols, waste) ascending,
    deterministically tie-broken.
    """
    budget = vmem_bytes if vmem_bytes is not None \
        else hardware.TPU_V5E.usable_vmem()
    rows, width = mat.cols.shape
    _, n = mat.shape
    out = []
    for br in block_rows_cands:
        if rows % br:
            continue
        waste = mat.sliced_waste(block_rows=br)
        for bc in block_cols_cands:
            if bc is not None and bc >= n + 128:
                continue  # slab larger than the vector: same as resident
            res = cost_model.spmv_time_model(rows, width, n, mat.nnz,
                                             block_rows=br, block_cols=bc,
                                             waste=waste)
            if res["vmem_bytes"] > budget:
                continue
            out.append((res["time_s"], br, bc, waste))
    out.sort(key=lambda r: (r[0], r[1], r[2] if r[2] is not None else 0))
    return out


def tune_spmv(
    mat: spmv_ops.EllMatrix, dtype=jnp.float32, *,
    measure_k: int = 3,
    vmem_bytes: int | None = None,
    max_measure_elems: int = MAX_MEASURE_ELEMS,
    cache: TuneCache | None = None,
    interpret: bool | None = None,
) -> SpmvPlan:
    """Pick (block_rows, block_cols) for an ELL matrix: DSE -> measure -> cache."""
    dtype = jnp.dtype(dtype)
    backend = _backend()
    cache = cache or get_cache()
    rows, width = mat.cols.shape
    _, n = mat.shape
    key = _spmv_key(rows, width, n, mat.nnz, mat.layout_fingerprint(),
                    dtype.name, backend, vmem_bytes)
    measurable = (measure_k > 0
                  and (backend == "tpu"
                       or rows * width + n <= max_measure_elems))

    hit = cache.get(key)
    # Same upgrade rule as tune_matmul: analytic-only entries don't block
    # a later measuring caller.
    if hit is not None and not (measurable and hit.get("source") == "model"):
        return SpmvPlan(hit["block_rows"], hit["block_cols"], "cache",
                        hit["model_time_s"], hit.get("measured_us"),
                        hit.get("waste", 0.0), key)

    ranked = rank_spmv_configs(mat, vmem_bytes=vmem_bytes)
    if not ranked:
        # Degenerate budget: fall back to the smallest legal blocked-x
        # config, scored normally so the cache entry stays finite JSON.
        fb = cost_model.spmv_time_model(rows, width, n, mat.nnz,
                                        block_rows=8, block_cols=256,
                                        waste=mat.padding_waste)
        ranked = [(fb["time_s"], 8, 256, mat.padding_waste)]

    interpret = (backend != "tpu") if interpret is None else interpret
    measured_us = None
    if measurable:
        x = jax.random.normal(jax.random.PRNGKey(0), (n,), dtype)
        best, best_us = None, float("inf")
        for score, br, bc, waste in ranked[:measure_k]:
            try:
                us = measure(lambda br=br, bc=bc: spmv_ops.spmv(
                    mat, x, block_rows=br, block_cols=bc,
                    interpret=interpret, use_kernel=True))
            except Exception:
                continue  # e.g. real VMEM overflow the model missed
            if us < best_us:
                best, best_us = (score, br, bc, waste), us
        measurable = best is not None
    if measurable:
        score, br, bc, waste = best
        source, measured_us = "measured", best_us
    else:
        score, br, bc, waste = ranked[0]
        source = "model"
        measured_us = None

    cache.put(key, {"block_rows": br, "block_cols": bc, "source": source,
                    "model_time_s": score, "measured_us": measured_us,
                    "waste": waste})
    return SpmvPlan(br, bc, source, score, measured_us, waste, key)


def tuned_spmv(mat: spmv_ops.EllMatrix, x: jax.Array,
               interpret: bool = False,
               use_kernel: bool | None = None,
               cache: TuneCache | None = None) -> jax.Array:
    """y = A @ x with autotuned (block_rows, block_cols) for A's layout."""
    if use_kernel is None:
        use_kernel = interpret or _backend() == "tpu"
    if not use_kernel:
        return spmv_ops.spmv(mat, x, use_kernel=False)
    plan = tune_spmv(mat, x.dtype, cache=cache, interpret=interpret)
    return spmv_ops.spmv(mat, x, block_rows=plan.block_rows,
                         block_cols=plan.block_cols, interpret=interpret,
                         use_kernel=True)


# ---------------------------------------------------------------------------
# Attention
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class AttentionPlan:
    block_q: int
    block_k: int
    source: str                  # "cache" | "measured" | "model"
    model_time_s: float
    measured_us: float | None
    key: str


def _attention_key(bh: int, sq: int, sk: int, dh: int, causal: bool,
                   window: int | None, dtype: str, backend: str,
                   vmem_bytes: int | None) -> str:
    return (f"attention:{bh}x{sq}x{sk}x{dh}:c{int(causal)}"
            f":w{'none' if window is None else window}:{dtype}:{backend}"
            f":v{_budget_tag(vmem_bytes)}")


def tune_attention(
    bh: int, sq: int, sk: int, dh: int, dtype=jnp.float32, *,
    causal: bool = True,
    window: int | None = None,
    measure_k: int = 3,
    vmem_bytes: int | None = None,
    max_measure_elems: int = MAX_MEASURE_ELEMS,
    cache: TuneCache | None = None,
    interpret: bool | None = None,
) -> AttentionPlan:
    """Pick (block_q, block_k) for the flash kernel: DSE -> measure -> cache.

    ``bh`` is the folded batch*heads leading axis the kernel sees (GQA
    callers fold before calling — see `attention.ops.mha_attention`).  The
    window size enters both the key and the ranking: the block-skipping
    kernel streams only the active block band, so the scored traffic and
    FLOPs depend on it.
    """
    dtype = jnp.dtype(dtype)
    backend = _backend()
    cache = cache or get_cache()
    key = _attention_key(bh, sq, sk, dh, causal, window, dtype.name, backend,
                         vmem_bytes)
    measurable = (measure_k > 0
                  and (backend == "tpu"
                       or bh * (sq + 2 * sk) * dh <= max_measure_elems))

    hit = cache.get(key)
    # Same upgrade rule as tune_matmul/tune_spmv: an analytic-only entry
    # (e.g. written at serve startup with measure_k=0) never blocks a later
    # measuring caller.
    if hit is not None and not (measurable and hit.get("source") == "model"):
        return AttentionPlan(hit["block_q"], hit["block_k"], "cache",
                             hit["model_time_s"], hit.get("measured_us"), key)

    ranked = dse.rank_attention_blocks(bh, sq, sk, dh,
                                       vmem_bytes=vmem_bytes,
                                       dtype_bytes=dtype.itemsize,
                                       causal=causal, window=window,
                                       top=max(measure_k, 1))
    cands = [(c.score, c.detail["block_q"], c.detail["block_k"])
             for c in ranked]

    interpret = (backend != "tpu") if interpret is None else interpret
    measured_us = None
    if measurable:
        scale = 1.0 / (dh ** 0.5)
        q = jax.random.normal(jax.random.PRNGKey(0), (bh, sq, dh), dtype)
        k = jax.random.normal(jax.random.PRNGKey(1), (bh, sk, dh), dtype)
        v = jax.random.normal(jax.random.PRNGKey(2), (bh, sk, dh), dtype)
        best, best_us = None, float("inf")
        for score, bq, bk in cands[:measure_k]:
            try:
                us = measure(lambda bq=bq, bk=bk: attn_kernel.flash_attention(
                    q, k, v, scale=scale, causal=causal, window=window,
                    block_q=bq, block_k=bk, interpret=interpret))
            except Exception:
                continue  # e.g. real VMEM overflow the model missed
            if us < best_us:
                best, best_us = (score, bq, bk), us
        measurable = best is not None
    if measurable:
        score, bq, bk = best
        source, measured_us = "measured", best_us
    else:
        score, bq, bk = cands[0]
        source = "model"
        measured_us = None

    cache.put(key, {"block_q": bq, "block_k": bk, "source": source,
                    "model_time_s": score, "measured_us": measured_us})
    return AttentionPlan(bq, bk, source, score, measured_us, key)


def tuned_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, window: int | None = None,
                    interpret: bool = False,
                    use_kernel: bool | None = None,
                    measure_k: int = 0,
                    cache: TuneCache | None = None) -> jax.Array:
    """Flash attention with autotuned (block_q, block_k) for q/k/v's shape.

    Same signature/dispatch as `attention.ops.mha_attention` — q is
    (B, Sq, Hq, dh), k/v are (B, Sk, Hkv, dh), GQA folding included.
    ``measure_k`` defaults to 0 (analytic ranking only) because the serving
    prefill path calls this *inside* a jit trace, where wall-clock
    measurement is impossible; measured winners come from offline callers
    (benchmarks) through the shared cache.
    """
    b, sq, hq, dh = q.shape
    _, sk, _, _ = k.shape
    if use_kernel is None:
        use_kernel = interpret or _backend() == "tpu"
    if not use_kernel:
        return attn_ops.mha_attention(q, k, v, causal=causal, window=window,
                                      use_kernel=False)
    plan = tune_attention(b * hq, sq, sk, dh, q.dtype, causal=causal,
                          window=window, measure_k=measure_k, cache=cache,
                          interpret=interpret)
    return attn_ops.mha_attention(q, k, v, causal=causal, window=window,
                                  block_q=plan.block_q, block_k=plan.block_k,
                                  interpret=interpret, use_kernel=True)


# ---------------------------------------------------------------------------
# Decode attention
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class DecodePlan:
    block_k: int
    source: str                  # "cache" | "measured" | "model"
    model_time_s: float
    measured_us: float | None
    key: str


def _decode_key(bkv: int, g: int, cache_len: int, dh: int, dtype: str,
                backend: str, vmem_bytes: int | None) -> str:
    return (f"decode:{bkv}x{g}x{cache_len}x{dh}:{dtype}:{backend}"
            f":v{_budget_tag(vmem_bytes)}")


def tune_decode(
    bkv: int, g: int, cache_len: int, dh: int, dtype=jnp.float32, *,
    measure_k: int = 3,
    vmem_bytes: int | None = None,
    max_measure_elems: int = MAX_MEASURE_ELEMS,
    cache: TuneCache | None = None,
    interpret: bool | None = None,
) -> DecodePlan:
    """Pick block_k for the fused decode kernel: DSE -> measure -> cache.

    ``bkv = batch * kv_heads`` folded rows, ``g = heads / kv_heads`` the GQA
    group per row, ``cache_len`` the allocated KV-cache depth.  The valid
    prefix length is a runtime scalar the kernel skips on, so it is not in
    the key — the plan is ranked and measured at the full cache depth (the
    worst case the server allocated for).
    """
    dtype = jnp.dtype(dtype)
    backend = _backend()
    cache = cache or get_cache()
    key = _decode_key(bkv, g, cache_len, dh, dtype.name, backend, vmem_bytes)
    measurable = (measure_k > 0
                  and (backend == "tpu"
                       or bkv * (g + 2 * cache_len) * dh
                       <= max_measure_elems))

    hit = cache.get(key)
    # Same upgrade rule as the other families: analytic-only entries never
    # block a later measuring caller.
    if hit is not None and not (measurable and hit.get("source") == "model"):
        return DecodePlan(hit["block_k"], "cache", hit["model_time_s"],
                          hit.get("measured_us"), key)

    ranked = dse.rank_decode_blocks(bkv, g, cache_len, dh,
                                    vmem_bytes=vmem_bytes,
                                    dtype_bytes=dtype.itemsize,
                                    top=max(measure_k, 1))
    cands = [(c.score, c.detail["block_k"]) for c in ranked]

    interpret = (backend != "tpu") if interpret is None else interpret
    measured_us = None
    if measurable:
        scale = 1.0 / (dh ** 0.5)
        q = jax.random.normal(jax.random.PRNGKey(0), (bkv, g, dh), dtype)
        k = jax.random.normal(jax.random.PRNGKey(1), (bkv, cache_len, dh),
                              dtype)
        v = jax.random.normal(jax.random.PRNGKey(2), (bkv, cache_len, dh),
                              dtype)
        best, best_us = None, float("inf")
        for score, bk in cands[:measure_k]:
            try:
                us = measure(lambda bk=bk: attn_decode.decode_attention(
                    q, k, v, scale=scale, length=cache_len, block_k=bk,
                    interpret=interpret))
            except Exception:
                continue  # e.g. real VMEM overflow the model missed
            if us < best_us:
                best, best_us = (score, bk), us
        measurable = best is not None
    if measurable:
        score, bk = best
        source, measured_us = "measured", best_us
    else:
        score, bk = cands[0]
        source = "model"
        measured_us = None

    cache.put(key, {"block_k": bk, "source": source, "model_time_s": score,
                    "measured_us": measured_us})
    return DecodePlan(bk, source, score, measured_us, key)


def tuned_decode(q: jax.Array, k: jax.Array, v: jax.Array, *,
                 length, interpret: bool = False,
                 use_kernel: bool | None = None,
                 measure_k: int = 0,
                 cache: TuneCache | None = None) -> jax.Array:
    """Fused decode attention with autotuned block_k for the cache shape.

    q: (B, Hq, dh); k, v: (B, L, Hkv, dh); ``length`` the valid cache
    prefix (python int or traced scalar — the serving index + 1).
    ``measure_k`` defaults to 0 because the serving decode step calls this
    inside a jit trace (same contract as `tuned_attention`); measured
    winners come from offline callers through the shared cache.
    """
    b, hq, dh = q.shape
    _, kl, hkv, _ = k.shape
    if use_kernel is None:
        use_kernel = interpret or _backend() == "tpu"
    if not use_kernel:
        return attn_decode.decode_ref(q, k, v, length=length)
    # The kernel streams the cache (and upcasts q to it), so the plan is
    # keyed and priced on the *cache* dtype — an f32 cache costs twice the
    # KV traffic of a bf16 one regardless of the activation dtype.
    plan = tune_decode(b * hkv, hq // hkv, kl, dh, k.dtype,
                       measure_k=measure_k, cache=cache, interpret=interpret)
    return attn_decode.gqa_decode_attention(q, k, v, length=length,
                                            block_k=plan.block_k,
                                            interpret=interpret)


# ---------------------------------------------------------------------------
# Model-serving plans
# ---------------------------------------------------------------------------

def plan_for_model(cfg, batch: int, *, prefill_len: int = 0,
                   cache_len: int = 0,
                   kv_dtype=jnp.bfloat16,
                   cache: TuneCache | None = None,
                   measure_k: int = 0) -> list[dict]:
    """Pre-tune the serving-path kernel shapes of a model config.

    Called by `launch.serve` at server startup so the first request never
    pays the search.  Measurement defaults off (analytic ranking only):
    startup happens on the serving critical path.  Covers the decode-path
    matmuls, — when ``prefill_len`` is given — the prefill flash-attention
    shape, and — when ``cache_len`` is given — the fused decode-attention
    fold, so all four tuned kernel families share one warmup.
    """
    d, f, v = cfg.d_model, cfg.d_ff or cfg.d_model * 4, cfg.vocab_size
    qkv = max(cfg.num_heads * cfg.head_dim, d) or d
    shapes = [
        ("qkv_proj", batch, qkv, d),
        ("out_proj", batch, d, qkv),
        ("ffn_up", batch, f, d),
        ("ffn_down", batch, d, f),
        ("logits", batch, v, d),
    ]
    plans = []
    for name, m, n, k in shapes:
        p = tune_matmul(m, n, k, jnp.bfloat16, measure_k=measure_k,
                        cache=cache)
        plans.append({"op": name, "mnk": [m, n, k],
                      "tile": [p.tile.y, p.tile.x, p.tile.z],
                      "source": p.source,
                      "model_time_us": p.model_time_s * 1e6})
    if prefill_len > 0 and cfg.num_heads:
        ap = tune_attention(batch * cfg.num_heads, prefill_len, prefill_len,
                            cfg.head_dim, jnp.bfloat16, causal=cfg.causal,
                            window=cfg.sliding_window, measure_k=measure_k,
                            cache=cache)
        plans.append({"op": "attn_prefill",
                      "bh_sq_sk_dh": [batch * cfg.num_heads, prefill_len,
                                      prefill_len, cfg.head_dim],
                      "block": [ap.block_q, ap.block_k],
                      "source": ap.source,
                      "model_time_us": ap.model_time_s * 1e6})
    if cache_len > 0 and cfg.num_heads and cfg.num_kv_heads:
        # Keyed on the KV-cache dtype the server allocates (`kv_dtype`) —
        # the decode kernel streams the cache, not the activations.
        dp = tune_decode(batch * cfg.num_kv_heads,
                         cfg.num_heads // cfg.num_kv_heads, cache_len,
                         cfg.head_dim, kv_dtype, measure_k=measure_k,
                         cache=cache)
        plans.append({"op": "attn_decode",
                      "bkv_g_len_dh": [batch * cfg.num_kv_heads,
                                       cfg.num_heads // cfg.num_kv_heads,
                                       cache_len, cfg.head_dim],
                      "block_k": dp.block_k,
                      "source": dp.source,
                      "model_time_us": dp.model_time_s * 1e6})
    return plans


def _attn_layer_count(cfg) -> int:
    return sum(1 for l in range(cfg.num_layers) if cfg.is_attn_layer(l))


def predict_decode_step_us(cfg, batch: int, *, cache_len: int,
                           kv_dtype=jnp.bfloat16,
                           plans: list[dict] | None = None,
                           cache: TuneCache | None = None) -> float:
    """Predicted wall time of one decode step at this batch, from the tuned
    plans' model times.

    The qkv/out projections and the KV-stream term are charged per
    *attention* layer (a hybrid's mamba layers have neither — their mixer
    matmuls are an uncounted approximation), the FFN matmuls per layer, the
    logits matmul once.  The KV stream (`2 * batch * cache_len * kv_dim`
    bf16 bytes per attention layer at `hbm_bw`) is the decode hot loop's
    memory floor.
    """
    plans = plans if plans is not None else plan_for_model(
        cfg, batch, cache_len=cache_len, kv_dtype=kv_dtype, cache=cache)
    attn_ops_ = {"qkv_proj", "out_proj"}
    ffn_ops = {"ffn_up", "ffn_down"}
    n_attn = _attn_layer_count(cfg)
    attn_us = sum(p["model_time_us"] for p in plans if p["op"] in attn_ops_)
    ffn_us = sum(p["model_time_us"] for p in plans if p["op"] in ffn_ops)
    logits_us = sum(p["model_time_us"] for p in plans if p["op"] == "logits")
    decode_plan = next((p for p in plans if p["op"] == "attn_decode"), None)
    if decode_plan is not None:
        # The tuned decode-attention plan prices the KV stream *and* the
        # attention FLOPs at the chosen block_k (including ragged-tail
        # over-fetch) — strictly more faithful than the raw byte floor.
        kv_us = n_attn * decode_plan["model_time_us"]
    else:
        kv_bytes = (2.0 * batch * cache_len * cfg.kv_dim
                    * jnp.dtype(kv_dtype).itemsize)            # K+V stream
        kv_us = n_attn * kv_bytes / hardware.TPU_V5E.hbm_bw * 1e6
    return (n_attn * attn_us + cfg.num_layers * ffn_us + logits_us + kv_us)


def select_serving_batch(
    cfg, *, cache_len: int, prefill_len: int = 0,
    kv_dtype=jnp.bfloat16,
    candidates: Sequence[int] = (1, 2, 4, 8, 16, 32, 64),
    latency_budget_ms: float | None = None,
    cache: TuneCache | None = None,
) -> dict:
    """Sweep candidate batch sizes against the tuned plans' predicted step
    time; pick the batch maximizing predicted decode throughput under the
    latency budget.

    This is the paper's DSE methodology lifted one level: the design knob is
    no longer a kernel tile but the *serving batch*, and the simulator is
    the same analytic machine model the kernel tuner ranks with — so the
    continuous-batching loop's shape is a tuner output, not a hand-picked
    default.  Deterministic: analytic model times only (measured cache
    entries, when present, refine the underlying plans but the sweep itself
    never wall-clocks).  Returns the decision record `launch.serve` logs at
    startup: {"batch", "latency_budget_ms", "sweep": [...]}.
    """
    sweep = []
    best = None
    decode_plans = {}
    for b in candidates:
        plans = plan_for_model(cfg, b, prefill_len=prefill_len,
                               cache_len=cache_len, kv_dtype=kv_dtype,
                               cache=cache)
        dp = next((p for p in plans if p["op"] == "attn_decode"), None)
        # Provenance ("model" cold vs "cache" warm) is volatile across
        # runs; the decision record must stay deterministic.  Full
        # provenance lives in the Server's kernel_plan log.
        decode_plans[b] = (
            {k: v for k, v in dp.items() if k != "source"}
            if dp is not None else None)
        step_us = predict_decode_step_us(cfg, b, cache_len=cache_len,
                                         kv_dtype=kv_dtype, plans=plans)
        tok_per_s = b / (step_us * 1e-6)
        feasible = (latency_budget_ms is None
                    or step_us <= latency_budget_ms * 1e3)
        sweep.append({"batch": b, "step_us": step_us,
                      "tok_per_s": tok_per_s, "feasible": feasible})
        if feasible and (best is None or tok_per_s > best["tok_per_s"]):
            best = sweep[-1]
    if best is None:       # nothing met the budget: least-bad latency wins
        best = min(sweep, key=lambda r: r["step_us"])
    return {"batch": best["batch"],
            "predicted_step_us": best["step_us"],
            "predicted_tok_per_s": best["tok_per_s"],
            "latency_budget_ms": latency_budget_ms,
            "decode_plan": decode_plans[best["batch"]],
            "sweep": sweep}
