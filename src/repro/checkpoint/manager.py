"""Checkpointing: sharded async save, atomic commit, keep-N retention,
mesh-agnostic restore (the elastic-scaling path).

Format: one directory per step containing
  - ``meta.json``      — step, flat key list, shapes/dtypes, data config hash
  - ``<idx>.npy``      — one file per leaf (full array, gathered)
A ``COMMITTED`` marker is written last; readers ignore uncommitted dirs, so a
crash mid-save can never corrupt the restore point (atomicity).  Saves run on
a background thread (async checkpointing — the train loop continues).

Restore takes a *target mesh + shardings* and `jax.device_put`s each leaf to
its (possibly different) target layout, so a checkpoint written on 256 chips
restores onto 64 or 512 — the elastic re-mesh path (`runtime.elastic`).
"""

from __future__ import annotations

import json
import shutil
import threading
import time
from pathlib import Path

import jax
import numpy as np

COMMITTED = "COMMITTED"


def _flatten_with_paths(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    keys = ["/".join(str(k) for k in path) for path, _ in flat]
    leaves = [leaf for _, leaf in flat]
    return keys, leaves, treedef


class CheckpointManager:
    def __init__(self, directory: str | Path, keep: int = 3):
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        self._thread: threading.Thread | None = None
        self._error: BaseException | None = None

    # ---------------- save ----------------

    def save(self, step: int, state, blocking: bool = False,
             extra_meta: dict | None = None):
        """Snapshot to host memory synchronously (consistency point), write
        to disk on a background thread."""
        self.wait()  # one in-flight save at a time
        keys, leaves, _ = _flatten_with_paths(state)
        host = [np.asarray(leaf) for leaf in leaves]  # device->host now
        meta = {
            "step": int(step),
            "keys": keys,
            "shapes": [list(h.shape) for h in host],
            "dtypes": [str(h.dtype) for h in host],
            "time": time.time(),
            **(extra_meta or {}),
        }

        def _write():
            try:
                tmp = self.dir / f"step_{step:010d}.tmp"
                final = self.dir / f"step_{step:010d}"
                if tmp.exists():
                    shutil.rmtree(tmp)
                tmp.mkdir(parents=True)
                for i, arr in enumerate(host):
                    np.save(tmp / f"{i}.npy", arr)
                (tmp / "meta.json").write_text(json.dumps(meta))
                (tmp / COMMITTED).write_text("ok")
                if final.exists():
                    shutil.rmtree(final)
                tmp.rename(final)
                self._gc()
            except BaseException as e:  # surfaced on next wait()
                self._error = e

        self._thread = threading.Thread(target=_write, daemon=True)
        self._thread.start()
        if blocking:
            self.wait()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    def _gc(self):
        steps = sorted(self._committed_steps())
        for s in steps[: -self.keep]:
            shutil.rmtree(self.dir / f"step_{s:010d}", ignore_errors=True)

    # ---------------- restore ----------------

    def _committed_steps(self):
        out = []
        for p in self.dir.glob("step_*"):
            if p.is_dir() and (p / COMMITTED).exists():
                out.append(int(p.name.split("_")[1]))
        return out

    def latest_step(self) -> int | None:
        steps = self._committed_steps()
        return max(steps) if steps else None

    def restore(self, step: int | None, like, shardings=None):
        """Restore into the structure of ``like`` (a pytree of arrays or
        ShapeDtypeStructs).  ``shardings``: matching pytree of NamedShardings
        for the TARGET mesh (mesh-agnostic restore); None = host arrays."""
        if step is None:
            step = self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no committed checkpoint in {self.dir}")
        d = self.dir / f"step_{step:010d}"
        meta = json.loads((d / "meta.json").read_text())
        keys, leaves, treedef = _flatten_with_paths(like)
        assert keys == meta["keys"], "checkpoint/model structure mismatch"
        sh_leaves = (jax.tree.leaves(
            shardings, is_leaf=lambda x: isinstance(x, jax.sharding.Sharding))
            if shardings is not None else [None] * len(leaves))
        out = []
        for i, (key, sds, sh) in enumerate(zip(keys, leaves, sh_leaves)):
            arr = np.load(d / f"{i}.npy")
            if sh is not None:
                out.append(jax.device_put(arr, sh))
            else:
                out.append(arr)
        return jax.tree.unflatten(treedef, out), meta
