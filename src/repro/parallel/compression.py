"""Gradient compression for the data-parallel reduce.

`compressed_psum` quantizes a tensor to int8 with a per-block f32 scale,
all-reduces the int32-accumulated quanta over the DP axes inside a
`shard_map`, and dequantizes — 4x less ICI traffic than an f32 all-reduce at
a bounded quantization error (tested).  The cheaper/safer default used by
the §Perf variants is bf16 gradient casting (`make_train_step(grad_dtype)`);
this module is the aggressive option for bandwidth-starved multi-pod links.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

QBLOCK = 256


def compressed_psum(x: jax.Array, axis_names, mesh=None) -> jax.Array:
    """Mean of ``x`` over the mesh axes via int8-quantized all-reduce.

    Two-phase: devices first agree on a per-block shared scale (a tiny pmax
    — 1/256 of the payload), then quantize with it, psum the int8 quanta as
    int32, and dequantize.  ``x`` must be replicated-layout on the reduced
    axes.  Quantization error per element is bounded by scale/2.
    """
    # Lazy import: the compat shims live in launch/mesh.py (jax-only, no
    # cycle) so one module owns every cross-version jax API point.
    from repro.launch import mesh as mesh_compat
    if mesh is None:
        mesh = mesh_compat.get_abstract_mesh()
    axes = (axis_names,) if isinstance(axis_names, str) else tuple(axis_names)
    name = axes if len(axes) > 1 else axes[0]
    count = 1
    for a in axes:
        count *= mesh.shape[a]

    def local(xv):
        flat = xv.reshape(-1)
        pad = (-flat.shape[0]) % QBLOCK
        flat = jnp.pad(flat, (0, pad))
        blocks = flat.reshape(-1, QBLOCK)
        local_max = jnp.max(jnp.abs(blocks), axis=1, keepdims=True)
        shared_max = jax.lax.pmax(local_max, name)   # phase 1: shared scale
        scale = jnp.maximum(shared_max / 127.0, 1e-12)
        q = jnp.clip(jnp.round(blocks / scale), -127, 127).astype(jnp.int8)
        qsum = jax.lax.psum(q.astype(jnp.int32), name)  # phase 2: payload
        out = (qsum.astype(jnp.float32) * scale).reshape(-1)
        n = 1
        for d in xv.shape:
            n *= d
        return out[:n].reshape(xv.shape) / count

    manual = frozenset(axes)
    return mesh_compat.shard_map(local, mesh, P(), P(),
                                 axis_names=manual)(x)
