"""Logical-axis sharding rules (DP/TP/EP/SP) — the interconnect half of the
paper's parameter set.

Model code annotates tensors with *logical* axis names; a `Rules` table maps
them to mesh axes.  Swapping the table re-targets the whole model to a new
mesh (single-pod, multi-pod, or a test mesh) without touching model code —
exactly how the paper retargets one algorithm description to different
generated interconnects.
"""

from __future__ import annotations

import contextlib
import contextvars
import dataclasses

import jax
from jax.sharding import PartitionSpec as P

# Logical axes used by the model zoo:
#   batch   - global batch            (data parallel)
#   seq     - sequence                (sequence parallel for long context)
#   embed   - d_model                 (usually replicated)
#   heads   - attention heads         (tensor parallel)
#   kv_heads- kv heads                (tensor parallel when divisible)
#   ff      - feed-forward hidden     (tensor parallel)
#   experts - MoE experts             (expert parallel)
#   vocab   - embedding/logits vocab  (tensor parallel)
#   kv_seq  - cached sequence         (sequence parallel at decode)


@dataclasses.dataclass(frozen=True)
class Rules:
    table: dict
    # mesh axis name -> size; lets `constrain` drop indivisible mappings
    # (e.g. 8 KV heads on a 16-way model axis) instead of forcing XLA into
    # "involuntary full rematerialization" resharding copies.
    sizes: dict = dataclasses.field(default_factory=dict)

    def spec(self, *logical) -> P:
        return P(*(self.table.get(ax) for ax in logical))

    def axis_size(self, mesh_axes) -> int:
        if mesh_axes is None:
            return 1
        if isinstance(mesh_axes, str):
            mesh_axes = (mesh_axes,)
        n = 1
        for a in mesh_axes:
            n *= self.sizes.get(a, 1)
        return n

    def with_sizes(self, mesh) -> "Rules":
        return Rules(self.table, dict(zip(mesh.axis_names,
                                          mesh.devices.shape)))


def single_pod_rules() -> Rules:
    return Rules({
        "batch": ("data",),
        "seq": None,
        "res_seq": None,          # residual-stream seq (block boundaries);
                                  # map to ("model",) for Megatron-style SP
        "embed": None,
        "heads": ("model",),
        "kv_heads": ("model",),
        "ff": ("model",),
        "experts": ("model",),
        "vocab": ("model",),
        "kv_seq": ("data",),
        "dp": ("data",),          # optimizer-state (ZeRO) axis
    })


def multi_pod_rules() -> Rules:
    return Rules({
        "batch": ("pod", "data"),
        "seq": None,
        "res_seq": None,
        "embed": None,
        "heads": ("model",),
        "kv_heads": ("model",),
        "ff": ("model",),
        "experts": ("model",),
        "vocab": ("model",),
        "kv_seq": ("pod", "data"),
        "dp": ("pod", "data"),
    })


def sequence_parallel(base: Rules) -> Rules:
    """Beyond-paper §Perf knob: shard the residual stream's sequence over the
    model axis between blocks.  GSPMD then lowers each block-boundary
    all-reduce into a reduce-scatter + all-gather pair, halving collective
    bytes and sharding the norms' compute."""
    t = dict(base.table)
    t["res_seq"] = t["heads"]     # same axis as tensor parallelism
    return Rules(t, base.sizes)


def data_parallel_attention(base: Rules) -> Rules:
    """§Perf knob (ZeRO-3-style): attention ACTIVATIONS stay batch-sharded
    (heads unsharded) while attention weights remain model-sharded in the
    state and are explicitly GATHERED at use (`gather_weight`) — per-layer
    weight all-gathers are ~2 orders of magnitude less traffic than
    activation all-reduces when d_model is small relative to
    tokens-per-device.  Apply to the activation rules only; keep the base
    rules for parameter/optimizer shardings."""
    t = dict(base.table)
    t["heads"] = None
    t["kv_heads"] = None
    t["zero3_attn"] = True
    return Rules(t, base.sizes)


def gather_weight(w):
    """ZeRO-3 moment: reshard a (state-sharded) weight to replicated right
    before use, so XLA emits a weight all-gather instead of activation
    partial-sum all-reduces.  No-op unless the active rules set
    ``zero3_attn`` (and outside jit/mesh contexts)."""
    rules = _ACTIVE.get()
    if rules is None or not rules.table.get("zero3_attn"):
        return w
    try:
        return jax.lax.with_sharding_constraint(w, P(*([None] * w.ndim)))
    except Exception:
        return w


def data_parallel_only(base: Rules) -> Rules:
    """§Perf knob for small models: drop tensor parallelism entirely (params
    replicated, batch over ALL axes).  Kills the per-layer TP all-reduces
    that dominate small-d_model architectures; the only collective left is
    the gradient reduction."""
    t = dict(base.table)
    model_axes = tuple(t.get("heads") or ())
    t["batch"] = tuple(t.get("batch") or ()) + model_axes
    t["dp"] = tuple(t.get("dp") or ()) + model_axes
    for ax in ("heads", "kv_heads", "ff", "experts", "vocab", "res_seq"):
        t[ax] = None
    return Rules(t, base.sizes)


def decode_rules(base: Rules, batch_replicated: bool = False) -> Rules:
    """Decode shapes.  The KV cache is the dominant decode state, so its
    *sequence* dim always takes the model axis (flash-decoding-style partial
    attention; XLA inserts the softmax reduce); with a replicated batch
    (batch-1 long-context) it additionally takes the DP axes."""
    t = dict(base.table)
    if batch_replicated:
        t["batch"] = None
        t["kv_seq"] = tuple(t["dp"]) + tuple(t["heads"])
    else:
        t["kv_seq"] = t["heads"]          # ("model",)
    # the model axis now carries the cache's seq dim; it can't also carry
    # the kv-head dim of the same tensor
    t["kv_heads"] = None
    return Rules(t, base.sizes)


def test_rules() -> Rules:
    """1-device tests: everything replicated."""
    return Rules({})


_ACTIVE: contextvars.ContextVar[Rules | None] = contextvars.ContextVar(
    "sharding_rules", default=None
)


@contextlib.contextmanager
def use_rules(rules: Rules | None):
    tok = _ACTIVE.set(rules)
    try:
        yield
    finally:
        _ACTIVE.reset(tok)


def active_rules() -> Rules | None:
    return _ACTIVE.get()


def constrain(x, *logical):
    """Apply a sharding constraint from the active rule table (no-op if none).

    Unknown logical names map to None (replicated on that dim).  Mappings
    whose mesh-axis product does not divide the tensor dim are dropped —
    uneven activation shardings force SPMD resharding copies.
    """
    rules = _ACTIVE.get()
    if rules is None:
        return x
    if x.ndim != len(logical):
        raise ValueError(f"rank {x.ndim} vs logical axes {logical}")
    entries = []
    for dim, ax in zip(x.shape, logical):
        mesh_axes = rules.table.get(ax)
        size = rules.axis_size(mesh_axes)
        entries.append(mesh_axes if (size > 1 and dim % size == 0) else None)
    try:
        return jax.lax.with_sharding_constraint(x, P(*entries))
    except Exception:
        # No ambient mesh (plain CPU eager/test) — constraint is advisory.
        return x


def param_spec(path_leaf_shapes: dict) -> dict:
    """Not used directly; per-model param specs live beside init functions."""
    raise NotImplementedError
