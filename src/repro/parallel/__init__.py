from repro.parallel import loss, sharding  # noqa: F401
