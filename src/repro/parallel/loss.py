"""Vocab-shard-friendly cross entropy.

Logits stay sharded over the vocab (model) axis end to end: both the
log-sum-exp and the label log-likelihood are computed as elementwise ops +
reductions over the sharded vocab dim, which XLA fuses (no one-hot, no
gather, no logits all-gather).  With 152k vocabs this is the difference
between a working step and an OOM.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

IGNORE = -1


def fused_cross_entropy(x: jax.Array, table: jax.Array, labels: jax.Array,
                        chunk: int = 2048, unroll: bool = False):
    """Cross entropy with the unembedding folded in and chunked over tokens,
    so the (tokens, V) logits never materialize at once.

    x: (B, S, D) final hidden states; table: (V, D) unembedding; labels (B, S).
    The per-chunk computation is `jax.checkpoint`ed: backward recomputes each
    chunk's logits instead of keeping them.  `unroll=True` uses a python loop
    (for cost probes); default is `lax.scan`.
    """
    b, s, d = x.shape
    xf = x.reshape(b * s, d)
    lf = labels.reshape(b * s)
    n = b * s
    if chunk <= 0 or n <= chunk:
        chunk = n
    pad = (-n) % chunk
    if pad:
        xf = jnp.concatenate([xf, jnp.zeros((pad, d), xf.dtype)])
        lf = jnp.concatenate([lf, jnp.full((pad,), IGNORE, lf.dtype)])
    nchunks = (n + pad) // chunk
    xc = xf.reshape(nchunks, chunk, d)
    lc = lf.reshape(nchunks, chunk)

    @jax.checkpoint
    def chunk_stats(xi, li):
        logits = (xi @ table.T.astype(xi.dtype)).astype(jnp.float32)
        from repro.parallel.sharding import constrain
        logits = constrain(logits, None, "vocab")
        m = jax.lax.stop_gradient(jnp.max(logits, axis=-1, keepdims=True))
        lse = jnp.log(jnp.sum(jnp.exp(logits - m), axis=-1)) + m[..., 0]
        iota = jax.lax.broadcasted_iota(jnp.int32, logits.shape, 1)
        ll = jnp.sum(jnp.where(iota == jnp.maximum(li, 0)[:, None],
                               logits, 0.0), axis=-1)
        mask = (li != IGNORE).astype(jnp.float32)
        return jnp.sum((lse - ll) * mask), jnp.sum(mask)

    if unroll:
        parts = [chunk_stats(xc[i], lc[i]) for i in range(nchunks)]
        nll = sum(p[0] for p in parts)
        cnt = sum(p[1] for p in parts)
    else:
        def body(carry, xs):
            nll_c, cnt_c = chunk_stats(*xs)
            return (carry[0] + nll_c, carry[1] + cnt_c), None

        (nll, cnt), _ = jax.lax.scan(body, (jnp.zeros(()), jnp.zeros(())),
                                     (xc, lc))
    denom = jnp.maximum(cnt, 1.0)
    loss = nll / denom
    return loss, {"loss": loss, "tokens": cnt}


def cross_entropy(logits: jax.Array, labels: jax.Array):
    """logits: (B, S, V); labels: (B, S) int (IGNORE = masked out).

    Returns (mean_nll, metrics).
    """
    lf = logits.astype(jnp.float32)
    m = jax.lax.stop_gradient(jnp.max(lf, axis=-1, keepdims=True))
    shifted = lf - m
    lse = jnp.log(jnp.sum(jnp.exp(shifted), axis=-1)) + m[..., 0]

    vocab_iota = jax.lax.broadcasted_iota(jnp.int32, lf.shape, lf.ndim - 1)
    safe_labels = jnp.maximum(labels, 0)
    ll = jnp.sum(jnp.where(vocab_iota == safe_labels[..., None], lf, 0.0),
                 axis=-1)

    nll = lse - ll
    mask = (labels != IGNORE).astype(jnp.float32)
    denom = jnp.maximum(jnp.sum(mask), 1.0)
    loss = jnp.sum(nll * mask) / denom
    metrics = {
        "loss": loss,
        "tokens": jnp.sum(mask),
        "accuracy_proxy": jnp.sum((ll >= lse - 1e-6) * mask) / denom,
    }
    return loss, metrics
