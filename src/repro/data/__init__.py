from repro.data.pipeline import (  # noqa: F401
    DataConfig,
    MemmapSource,
    Prefetcher,
    SyntheticSource,
    make_source,
)
