"""Data pipeline: deterministic synthetic LM stream + memmap-backed shards,
host-sharded loading with background prefetch.

Determinism contract (needed for fault tolerance): batch contents are a pure
function of (seed, step, shard_id, num_shards).  After a failure/elastic
re-mesh, the restored trainer replays exactly the batches it would have seen
— no data loss, no duplication — because assignment is recomputed from the
new shard count (the paper's DMA "programmed by the host" becomes a pure
indexing scheme).
"""

from __future__ import annotations

import dataclasses
import queue
import threading
from pathlib import Path
from typing import Iterator

import numpy as np

from repro.parallel.loss import IGNORE


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    kind: str = "synthetic"        # "synthetic" | "memmap"
    path: str | None = None        # token file for memmap
    frontend: str | None = None    # None | "frame" | "patch"
    frontend_dim: int = 0
    num_patches: int = 0


def _batch_rng(cfg: DataConfig, step: int, shard: int) -> np.random.Generator:
    return np.random.default_rng(
        np.random.SeedSequence([cfg.seed, step, shard]))


class SyntheticSource:
    """Structured synthetic LM data: noisy affine-recurrence token streams so
    the model has real signal to fit (loss decreases — used by tests and the
    quickstart trainer)."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg

    def batch(self, step: int, shard: int, num_shards: int) -> dict:
        cfg = self.cfg
        assert cfg.global_batch % num_shards == 0
        b = cfg.global_batch // num_shards
        rng = _batch_rng(cfg, step, shard)
        s = cfg.seq_len
        # token t+1 = (a * token t + c) mod V with occasional noise.  The
        # (a, c) "language" is a function of the SEED only, so the mapping is
        # stable across steps/shards (learnable); start tokens and noise vary
        # per (step, shard) (deterministic replay after restart).
        lang = np.random.default_rng(
            np.random.SeedSequence([cfg.seed, 0xA11CE]))
        a = int(lang.integers(2, 8))
        c = int(lang.integers(1, max(cfg.vocab_size - 1, 2)))
        toks = np.empty((b, s + 1), np.int64)
        toks[:, 0] = rng.integers(0, cfg.vocab_size, size=b)
        for t in range(s):
            toks[:, t + 1] = (a * toks[:, t] + c) % cfg.vocab_size
        noise = rng.random((b, s + 1)) < 0.02
        toks[noise] = rng.integers(0, cfg.vocab_size, size=int(noise.sum()))
        batch = {"tokens": toks[:, :-1].astype(np.int32),
                 "labels": toks[:, 1:].astype(np.int32)}
        if cfg.frontend == "frame":
            batch = {
                "frames": rng.standard_normal(
                    (b, s, cfg.frontend_dim)).astype(np.float32),
                "labels": batch["labels"] % cfg.vocab_size,
            }
        elif cfg.frontend == "patch":
            npatch = cfg.num_patches
            labels = np.concatenate(
                [np.full((b, npatch), IGNORE, np.int32),
                 batch["labels"][:, : s - npatch]], axis=1)
            batch = {
                "patches": rng.standard_normal(
                    (b, npatch, cfg.frontend_dim)).astype(np.float32),
                "tokens": batch["tokens"][:, : s - npatch],
                "labels": labels,
            }
        return batch


class MemmapSource:
    """Token-file-backed source (np.memmap of int32), deterministic window
    assignment by (step, shard)."""

    def __init__(self, cfg: DataConfig):
        assert cfg.path, "memmap source needs a path"
        self.cfg = cfg
        self.tokens = np.memmap(cfg.path, dtype=np.int32, mode="r")
        self.windows = (len(self.tokens) - 1) // cfg.seq_len

    def batch(self, step: int, shard: int, num_shards: int) -> dict:
        cfg = self.cfg
        b = cfg.global_batch // num_shards
        rng = _batch_rng(cfg, step, shard)
        idx = rng.integers(0, self.windows, size=b)
        starts = idx * cfg.seq_len
        toks = np.stack([self.tokens[s0 : s0 + cfg.seq_len + 1]
                         for s0 in starts])
        return {"tokens": toks[:, :-1].astype(np.int32),
                "labels": toks[:, 1:].astype(np.int32)}


def make_source(cfg: DataConfig):
    return MemmapSource(cfg) if cfg.kind == "memmap" else SyntheticSource(cfg)


class Prefetcher:
    """Background-thread prefetch of upcoming batches (depth-bounded)."""

    def __init__(self, source, start_step: int, shard: int, num_shards: int,
                 depth: int = 2):
        self.source = source
        self.shard = shard
        self.num_shards = num_shards
        self.q: queue.Queue = queue.Queue(maxsize=depth)
        self._stop = threading.Event()
        self._step = start_step
        self._thread = threading.Thread(target=self._run, daemon=True)
        self._thread.start()

    def _run(self):
        step = self._step
        while not self._stop.is_set():
            batch = self.source.batch(step, self.shard, self.num_shards)
            while not self._stop.is_set():
                try:
                    self.q.put((step, batch), timeout=0.1)
                    break
                except queue.Full:
                    continue
            step += 1

    def __iter__(self) -> Iterator:
        while True:
            yield self.q.get()

    def close(self):
        self._stop.set()
        self._thread.join(timeout=2)
