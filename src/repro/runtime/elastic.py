"""Elastic scaling: rebuild the mesh from surviving devices and reshard.

Checkpoints are mesh-agnostic (full arrays per leaf), so scaling down after
losing a pod slice — or up after repair — is: pick the largest supported mesh
that fits the survivors, rebuild shardings from the SAME logical rules, and
`device_put` the restored leaves.  Data-shard assignment is recomputed from
the new data-axis size; the (seed, step, shard)-deterministic pipeline then
yields exactly the right global batch order.
"""

from __future__ import annotations

import jax

from repro.parallel import sharding as shd


def largest_mesh_shape(num_devices: int, model_parallel: int,
                       min_data: int = 1) -> tuple[int, int]:
    """Largest (data, model) grid with the given TP degree that fits."""
    if num_devices < model_parallel:
        # degrade TP to what's available (powers of two)
        mp = 1
        while mp * 2 <= num_devices:
            mp *= 2
        model_parallel = mp
    data = max(num_devices // model_parallel, min_data)
    return data, model_parallel


def remesh(devices, model_parallel: int) -> jax.sharding.Mesh:
    from repro.launch.mesh import axis_types_kwargs
    data, model = largest_mesh_shape(len(devices), model_parallel)
    used = devices[: data * model]
    import numpy as np
    dmesh = np.asarray(used).reshape(data, model)
    return jax.sharding.Mesh(dmesh, ("data", "model"),
                             **axis_types_kwargs(2))


def reshard_state(state_host, mesh: jax.sharding.Mesh, pspecs):
    """Place host-restored state onto a (new) mesh via its PartitionSpecs."""
    def put(leaf, ps):
        return jax.device_put(leaf,
                              jax.sharding.NamedSharding(mesh, ps))
    return jax.tree.map(
        put, state_host, pspecs,
        is_leaf=lambda x: not isinstance(x, dict))
