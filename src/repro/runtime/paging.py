"""Paged KV-cache allocation (numpy + stdlib only, like the rest of
``runtime/``).

The serving cache today gives every slot a contiguous ``max_len`` KV
region, so batch capacity is bounded by the *worst-case* sequence length
even though most requests finish far shorter.  This module provides the
block-granular alternative: a fixed pool of ``num_pages`` KV pages of
``page_size`` tokens each, shared by all layers (one physical page index
means the same pool row in every layer's K and V pool), plus a per-slot
page table mapping logical page position -> physical page.

Design rules (see docs/PAGING.md):

- **Canonical allocation order.**  The free list is a min-heap, so the
  lowest-index free page is always handed out next.  That makes the
  allocator's full state a pure function of the page table — crash
  recovery rebuilds it from the restored ``cache["pages"]`` array with
  :meth:`PageAllocator.adopt`, nothing extra to snapshot.
- **Reservations price admission.**  The scheduler reserves a request's
  *predicted* footprint (``pages_for(prompt + gen)``) at admission time
  and the reservation is consumed page-by-page as the slot actually
  grows, so ``can_admit`` never over-promises pages already pledged to
  in-flight requests.  With reservation-based admission the mid-decode
  OOM path cannot fire; it exists (``PageOOM``) as a loud invariant
  guard and for deliberately overcommitted configurations.
- **Frees are idempotent** and alloc/free sequences conserve the pool
  exactly (``free + allocated == num_pages`` always) — property-tested
  in tests/test_paging.py.
"""

from __future__ import annotations

import dataclasses
import heapq

import numpy as np

__all__ = ["PageSpec", "PageAllocator", "PageOOM"]


class PageOOM(RuntimeError):
    """The pool has no free page for a required allocation.

    Carries ``slot`` and ``rid`` so the serve loop can turn the failure
    into scheduler backpressure (evict / requeue) instead of a crash.
    """

    def __init__(self, msg: str, *, slot: int = -1, rid: int = -1):
        super().__init__(msg)
        self.slot = slot
        self.rid = rid


@dataclasses.dataclass(frozen=True)
class PageSpec:
    """Static shape of a paged KV pool (threaded as a closure arg, never
    a pytree leaf — it changes the compiled cache layout)."""

    page_size: int     # tokens per page
    num_pages: int     # physical pages in the pool (shared by all layers)
    max_pages: int     # page-table width = ceil(max_len / page_size)

    def __post_init__(self):
        if self.page_size < 1 or self.num_pages < 1 or self.max_pages < 1:
            raise ValueError(f"invalid PageSpec {self!r}")

    @staticmethod
    def build(batch: int, max_len: int, page_size: int,
              pool_pages: int = 0) -> "PageSpec":
        """Spec for a ``batch x max_len`` serving cache.  ``pool_pages=0``
        sizes the pool contiguous-equivalent (batch * per-slot worst
        case); smaller pools are how paging beats contiguous at the same
        KV-memory budget."""
        max_pages = -(-max_len // page_size)
        return PageSpec(page_size=page_size,
                        num_pages=pool_pages or batch * max_pages,
                        max_pages=max_pages)

    def pages_for(self, n_tokens: int) -> int:
        """Pages needed to hold ``n_tokens`` resident KV entries."""
        return max(0, -(-int(n_tokens) // self.page_size))


class PageAllocator:
    """Host-side truth for the paged pool: per-slot page table, min-heap
    free list, per-request footprint reservations."""

    def __init__(self, spec: PageSpec, batch: int):
        self.spec = spec
        self.batch = batch
        self.table = np.full((batch, spec.max_pages), -1, dtype=np.int32)
        # owner[page] = slot holding it, -1 if free (the double-assign guard)
        self._owner = np.full(spec.num_pages, -1, dtype=np.int32)
        self._free = list(range(spec.num_pages))
        heapq.heapify(self._free)
        self._reserved: dict[int, int] = {}     # rid -> pages still pledged
        # tokens each slot has asked `ensure` to cover — the numerator of
        # the pages-vs-tokens utilization the serve summary reports
        self._tokens = np.zeros(batch, dtype=np.int64)

    # -- queries ------------------------------------------------------------

    @property
    def free_pages(self) -> int:
        return len(self._free)

    @property
    def allocated_pages(self) -> int:
        return self.spec.num_pages - len(self._free)

    @property
    def reserved_pages(self) -> int:
        return sum(self._reserved.values())

    def slot_pages(self, slot: int) -> int:
        return int((self.table[slot] >= 0).sum())

    def pages_for(self, n_tokens: int) -> int:
        return self.spec.pages_for(n_tokens)

    def can_admit(self, n_tokens: int) -> bool:
        """True if the pool can cover ``n_tokens`` on top of every page
        already pledged to in-flight requests."""
        return (self.free_pages - self.reserved_pages
                >= self.pages_for(n_tokens))

    def fits_pool(self, n_tokens: int) -> bool:
        """True if ``n_tokens`` could *ever* fit (an empty pool would
        cover it); False means reject loudly, not queue forever."""
        return self.pages_for(n_tokens) <= self.spec.num_pages

    def utilization(self, tokens_resident: int | None = None) -> dict:
        """Pages allocated vs tokens actually resident in them — the
        KV-memory utilization block the serve summary reports.  With no
        explicit ``tokens_resident`` the allocator's own per-slot ensure
        bookkeeping is the numerator."""
        if tokens_resident is None:
            tokens_resident = int(self._tokens.sum())
        cap = self.allocated_pages * self.spec.page_size
        return {
            "page_size": self.spec.page_size,
            "num_pages": self.spec.num_pages,
            "pages_allocated": self.allocated_pages,
            "pages_free": self.free_pages,
            "pages_reserved": self.reserved_pages,
            "tokens_resident": int(tokens_resident),
            "token_capacity": cap,
            "utilization": (tokens_resident / cap) if cap else 1.0,
        }

    # -- reservations (admission pricing) -----------------------------------

    def reserve(self, rid: int, n_tokens: int) -> None:
        self._reserved[rid] = self._reserved.get(rid, 0) \
            + self.pages_for(n_tokens)

    def release_reservation(self, rid: int) -> None:
        self._reserved.pop(rid, None)

    # -- alloc / free -------------------------------------------------------

    def ensure(self, slot: int, n_tokens: int, rid: int = -1) -> bool:
        """Grow ``slot``'s page table until it covers ``n_tokens``.
        Returns True if any page was assigned (the device table needs a
        refresh).  Raises :class:`PageOOM` when the pool is exhausted —
        the caller turns that into backpressure, never a crash."""
        have = self.slot_pages(slot)
        need = self.pages_for(n_tokens)
        if need > self.spec.max_pages:
            raise PageOOM(
                f"slot {slot}: {n_tokens} tokens need {need} pages > "
                f"page-table width {self.spec.max_pages}",
                slot=slot, rid=rid)
        grew = False
        while have < need:
            if not self._free:
                raise PageOOM(
                    f"slot {slot} (rid {rid}): pool exhausted growing to "
                    f"{need} pages ({self.allocated_pages}/"
                    f"{self.spec.num_pages} allocated, "
                    f"{self.reserved_pages} reserved)",
                    slot=slot, rid=rid)
            page = heapq.heappop(self._free)
            if self._owner[page] != -1:      # pragma: no cover - invariant
                raise AssertionError(f"page {page} double-assigned")
            self.table[slot, have] = page
            self._owner[page] = slot
            have += 1
            grew = True
            if rid in self._reserved:        # consume the pledge as it lands
                left = self._reserved[rid] - 1
                if left > 0:
                    self._reserved[rid] = left
                else:
                    del self._reserved[rid]
        self._tokens[slot] = max(int(self._tokens[slot]), int(n_tokens))
        return grew

    def free_slot(self, slot: int, rid: int = -1) -> bool:
        """Return every page of ``slot`` to the pool (idempotent) and
        drop ``rid``'s outstanding reservation.  True if anything was
        actually freed."""
        if rid != -1:
            self.release_reservation(rid)
        self._tokens[slot] = 0
        pages = self.table[slot]
        freed = False
        for i in range(self.spec.max_pages):
            page = int(pages[i])
            if page < 0:
                continue
            self._owner[page] = -1
            heapq.heappush(self._free, page)
            pages[i] = -1
            freed = True
        return freed

    # -- invariants / recovery ----------------------------------------------

    def check_conserved(self) -> None:
        """free + allocated == pool, table and owner agree, no page in
        two slots.  Raises AssertionError on violation."""
        allocated = self.table[self.table >= 0]
        assert len(set(allocated.tolist())) == allocated.size, \
            "a page appears in two page-table entries"
        assert allocated.size + len(self._free) == self.spec.num_pages, \
            (f"pool leak: {allocated.size} allocated + {len(self._free)} "
             f"free != {self.spec.num_pages}")
        assert set(allocated.tolist()) | set(self._free) \
            == set(range(self.spec.num_pages))
        for slot in range(self.batch):
            row = self.table[slot]
            held = row[row >= 0]
            assert (self._owner[held] == slot).all(), \
                f"owner map disagrees with page table for slot {slot}"

    @classmethod
    def adopt(cls, spec: PageSpec, table: np.ndarray) -> "PageAllocator":
        """Rebuild an allocator from a restored page table (crash
        recovery).  Because allocation order is canonical (min-heap),
        the rebuilt free list is exactly the one the dead process had;
        reservations are re-created by the scheduler for whatever is
        still queued."""
        table = np.asarray(table, dtype=np.int32)
        alloc = cls(spec, table.shape[0])
        alloc.table[...] = table
        alloc._owner[...] = -1
        for slot in range(table.shape[0]):
            for page in table[slot]:
                if page >= 0:
                    if alloc._owner[page] != -1:
                        raise ValueError(
                            f"restored page table assigns page {page} to "
                            f"slots {alloc._owner[page]} and {slot}")
                    alloc._owner[page] = slot
        alloc._free = [p for p in range(spec.num_pages)
                       if alloc._owner[p] == -1]
        heapq.heapify(alloc._free)
        alloc.check_conserved()
        return alloc
