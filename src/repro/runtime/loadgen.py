"""Seeded, traffic-shaped load generation for the serving loop.

The paper's methodology is that a system design is validated by *measured*
end-to-end performance on the target workload, not by per-kernel numbers:
the kernel benchmarks (`BENCH_kernels.json`) prove each tuned family wins
in isolation, but only a traffic-shaped run of the serve loop can say
whether `select_serving_batch`'s predicted throughput, the admission
queue, and the retry machinery hold up under real arrival patterns.  This
module is the workload half of that measurement:

* :func:`make_trace` — a seeded, deterministic request trace: Poisson
  arrivals (exponential inter-arrival gaps at a configurable rate),
  prompt/output lengths drawn from declarative distributions (``fixed`` /
  ``uniform`` / ``choice`` / ``staggered`` — the last reproduces the
  staggered steady-state mix `launch/serve.py` prices its batch sweep
  on), and optional per-request think times for closed-loop sessions.
* :class:`VirtualClock` — a deterministic clock driven by the serve
  loop's decode-step counter: one loop step advances time by a fixed
  ``step_time_s`` (typically the tuner's *predicted* decode-step time, so
  latencies come out in model-milliseconds).  `serve_loop` threads its
  step counter into any injected lifecycle clock exposing ``on_step``,
  which is what makes TTFT / per-token percentiles byte-reproducible.
* :class:`TraceSource` / :class:`SessionSource` — arrival pumps the
  serve loop drains requests from: open-loop (arrivals fire at their
  trace times regardless of completions) and closed-loop (each session
  submits its next request ``think_s`` after the previous one reached a
  terminal state).  Both record the queue-depth timeline.
* :func:`collect_metrics` — the per-mix report row: p50/p99
  time-to-first-token, p50/p99 per-token latency, sustained tokens/sec on
  the virtual clock, queue-depth timeline, and per-request
  predicted-vs-measured decode-step time (the coarse-grain estimator
  loop: the analytic model's prediction against the wall clock).

Determinism contract: everything derived from the virtual clock and the
trace seed is byte-identical across runs — same seeds, same outcome
trace, same latency rows.  Wall-clock-derived fields are *volatile* and
enumerated in :data:`VOLATILE_FIELDS`; :func:`strip_volatile` removes
them so regression tests (and humans diffing reports) compare only the
reproducible part.  Like `runtime.faults`, this module is numpy+stdlib
only — it drives the server purely through the lifecycle's public
surface and never imports jax.
"""

from __future__ import annotations

import dataclasses
import json
import math
import pathlib

import numpy as np

from repro.runtime.lifecycle import Lifecycle, State

# Report fields allowed to vary run-to-run (wall-clock derived).  Every
# other field of a mix report is covered by the determinism contract:
# same trace seed + same fault seed => byte-identical values.
VOLATILE_FIELDS = frozenset({
    "wall",                   # the whole wall-clock block of a mix report
    "wall_s", "wall_tok_per_s",
    "measured_step_us",       # per-request measured decode-step time
    "step_time_ratio",        # measured / predicted, per request
    "measured_step_us_p50",   # mix-level watchdog median
    "divergence",             # measured / predicted, mix level
    "stragglers",             # wall-clock watchdog reports
})


def strip_volatile(obj):
    """Recursively drop every VOLATILE_FIELDS key — the deterministic
    projection of a report, the thing regression tests compare."""
    if isinstance(obj, dict):
        return {k: strip_volatile(v) for k, v in obj.items()
                if k not in VOLATILE_FIELDS}
    if isinstance(obj, (list, tuple)):
        return [strip_volatile(v) for v in obj]
    return obj


# ---------------------------------------------------------------------------
# virtual clock
# ---------------------------------------------------------------------------

# Floor for the virtual clock's per-step time: smoke-sized configs predict
# sub-microsecond decode steps, and every latency row is rounded to
# 1e-3 ms — without a floor the whole report would collapse to zeros.
# One model-millisecond per step keeps virtual latencies readable (TTFT in
# ms == steps waited) and never binds for a real config, whose predicted
# step is always far above 1 ms.
MIN_VIRTUAL_STEP_US = 1000.0


def virtual_step_us(predicted_us: float) -> float:
    """The step time a VirtualClock should run at for a given predicted
    decode-step time (the floor above applied)."""
    return max(float(predicted_us), MIN_VIRTUAL_STEP_US)


class VirtualClock:
    """A lifecycle clock driven by the serve loop's decode-step counter.

    `serve_loop` calls ``on_step(step)`` at the top of every iteration
    (including virtual-clock jumps over retry backoff or idle arrival
    gaps), so time is a pure function of loop progress: deadlines, TTFT,
    and per-token latencies all become deterministic.  ``step_time_s`` is
    the cost charged per loop step — use the tuner's predicted
    decode-step time to get latencies in model-milliseconds.
    """

    def __init__(self, step_time_s: float, start_s: float = 0.0):
        if step_time_s <= 0:
            raise ValueError(f"step_time_s must be positive, got "
                             f"{step_time_s}")
        self.step_time_s = float(step_time_s)
        self.start_s = float(start_s)
        self.step = 0

    def on_step(self, step: int) -> None:
        self.step = int(step)

    def step_for(self, t_s: float) -> int:
        """First step index at which the clock reads >= ``t_s`` — how an
        idle serve loop jumps straight to the next arrival."""
        if t_s <= self.start_s:
            return 0
        return int(math.ceil((t_s - self.start_s) / self.step_time_s))

    def __call__(self) -> float:
        return self.start_s + self.step * self.step_time_s


# ---------------------------------------------------------------------------
# trace generation
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class TraceRequest:
    """One request of a load trace (lengths only — prompt *tokens* are
    derived deterministically from the trace seed + rid at submit time,
    keeping trace files compact and replayable)."""

    rid: int
    arrival_s: float          # open-loop arrival time on the trace clock
    prompt_len: int
    gen_len: int
    think_s: float = 0.0      # closed-loop: wait after the previous
                              # request of the session terminates
    ttft_deadline_s: float | None = None
    deadline_s: float | None = None

    def record(self) -> dict:
        return dataclasses.asdict(self)


def sample_lengths(rng: np.random.Generator, n: int, dist: dict) -> list[int]:
    """Draw ``n`` integer lengths from a declarative distribution spec:

    ``{"kind": "fixed", "value": v}``
    ``{"kind": "uniform", "lo": a, "hi": b}``           (inclusive)
    ``{"kind": "choice", "values": [...], "weights": [...]?}``
    ``{"kind": "staggered", "base": p, "spread": g}`` — the deterministic
    ramp ``p + (2i+1)*g // 2n`` over the request index: the steady-state
    slot-depth mix `launch/serve.py` builds for its batch sweep, as an
    arrival-order length pattern.
    ``{"kind": "lognormal", "mean": m, "sigma": s, "lo": a, "hi": b}`` —
    the heavy-tail production shape: most requests are short, a few are
    very long (``exp(N(ln m, s))``, rounded and clamped to [a, b]).
    This is the mix where a paged KV cache beats per-slot worst-case
    allocation — the tail sets the contiguous reservation, the body
    wastes it (docs/PAGING.md).
    """
    kind = dist["kind"]
    if kind == "fixed":
        return [int(dist["value"])] * n
    if kind == "uniform":
        return [int(x) for x in
                rng.integers(int(dist["lo"]), int(dist["hi"]) + 1, size=n)]
    if kind == "choice":
        return [int(x) for x in rng.choice(dist["values"], size=n,
                                           p=dist.get("weights"))]
    if kind == "staggered":
        base, spread = int(dist["base"]), int(dist["spread"])
        return [base + ((2 * i + 1) * spread) // (2 * n) for i in range(n)]
    if kind == "lognormal":
        lo = int(dist.get("lo", 1))
        hi = int(dist["hi"])
        draws = rng.lognormal(mean=np.log(float(dist["mean"])),
                              sigma=float(dist["sigma"]), size=n)
        return [int(np.clip(round(x), lo, hi)) for x in draws]
    raise ValueError(f"unknown length distribution kind {kind!r}")


def sample_times(rng: np.random.Generator, n: int, dist: dict) -> list[float]:
    """Float-valued sibling of :func:`sample_lengths` for think times:
    ``fixed`` / ``uniform`` / ``exponential`` (``{"mean": m}``)."""
    kind = dist["kind"]
    if kind == "fixed":
        return [float(dist["value"])] * n
    if kind == "uniform":
        return [float(x) for x in
                rng.uniform(float(dist["lo"]), float(dist["hi"]), size=n)]
    if kind == "exponential":
        return [float(x) for x in rng.exponential(float(dist["mean"]),
                                                  size=n)]
    raise ValueError(f"unknown time distribution kind {kind!r}")


def make_trace(*, seed: int, n: int, rate_rps: float, prompt_dist: dict,
               gen_dist: dict, think_dist: dict | None = None,
               start_s: float = 0.0,
               ttft_deadline_s: float | None = None,
               deadline_s: float | None = None) -> list[TraceRequest]:
    """A seeded Poisson request trace: inter-arrival gaps are exponential
    at ``rate_rps`` (``rate_rps <= 0`` = everything arrives at
    ``start_s``), lengths drawn per the distribution specs.  Same seed,
    same trace — the determinism the whole harness gates on."""
    rng = np.random.default_rng(seed)
    if rate_rps > 0:
        gaps = rng.exponential(1.0 / rate_rps, size=n)
        arrivals = start_s + np.cumsum(gaps)
    else:
        arrivals = np.full(n, start_s)
    prompts = sample_lengths(rng, n, prompt_dist)
    gens = sample_lengths(rng, n, gen_dist)
    thinks = (sample_times(rng, n, think_dist) if think_dist is not None
              else [0.0] * n)
    return [TraceRequest(rid=i, arrival_s=float(arrivals[i]),
                         prompt_len=max(1, prompts[i]),
                         gen_len=max(1, gens[i]), think_s=thinks[i],
                         ttft_deadline_s=ttft_deadline_s,
                         deadline_s=deadline_s)
            for i in range(n)]


def save_trace(path, trace: list[TraceRequest]) -> None:
    """One JSON object per line (see docs/SERVING_BENCH.md, trace format)."""
    with open(path, "w") as f:
        for t in trace:
            f.write(json.dumps(t.record()) + "\n")


class TraceError(ValueError):
    """A corrupt trace file — raised with the line number and payload so
    a bad trace fails loudly instead of silently serving a subset."""


def load_trace(path) -> list[TraceRequest]:
    """Load a JSONL trace, failing loudly on corruption.

    Every malformed line raises :class:`TraceError` with its line number
    and (truncated) payload.  A malformed **final** line with no trailing
    newline is reported distinctly — it is the torn-write signature of a
    producer killed mid-append, which calls for regenerating the trace,
    not for debugging the generator."""
    path = pathlib.Path(path)
    raw = path.read_text()
    lines = raw.split("\n")
    # split() leaves a trailing "" when the file ends in a newline; a
    # non-empty last element means the final line was never terminated.
    unterminated = bool(lines) and lines[-1] != ""
    if not unterminated and lines:
        lines.pop()
    trace = []
    for ln, line in enumerate(lines, start=1):
        if not line.strip():
            continue
        torn = unterminated and ln == len(lines)
        try:
            trace.append(TraceRequest(**json.loads(line)))
        except (ValueError, TypeError) as e:
            if torn:
                raise TraceError(
                    f"{path}:{ln}: partial final line (producer killed "
                    f"mid-write? regenerate the trace): {e}; payload: "
                    f"{line[:200]!r}") from None
            raise TraceError(
                f"{path}:{ln}: corrupt trace line: {e}; payload: "
                f"{line[:200]!r}") from None
    return trace


def sessions_from_trace(trace: list[TraceRequest],
                        n_sessions: int) -> list[list[TraceRequest]]:
    """Round-robin a trace into ``n_sessions`` closed-loop sessions
    (order within a session preserved)."""
    sessions: list[list[TraceRequest]] = [[] for _ in range(n_sessions)]
    for i, t in enumerate(trace):
        sessions[i % n_sessions].append(t)
    return [s for s in sessions if s]


def prompt_tokens(seed: int, rid: int, prompt_len: int,
                  vocab_size: int) -> np.ndarray:
    """Deterministic prompt tokens for a trace request — a pure function
    of (trace seed, rid), so a replay regenerates the same prompts."""
    rng = np.random.default_rng(np.random.SeedSequence([seed, rid]))
    return rng.integers(0, vocab_size, size=prompt_len, dtype=np.int64)


# ---------------------------------------------------------------------------
# arrival sources (what serve_loop pumps)
# ---------------------------------------------------------------------------

class _SourceBase:
    """Queue-depth sampling shared by both sources: one (step, queued,
    open) row whenever the counts change, capped so a runaway trace can't
    bloat the report."""

    MAX_SAMPLES = 4096

    def __init__(self, vocab_size: int, seed: int):
        self.vocab_size = vocab_size
        self.seed = seed
        self.queue_depth: list[tuple[int, int, int]] = []
        self.submitted = 0

    def _submit(self, lc: Lifecycle, t: TraceRequest) -> None:
        lc.submit(t.rid,
                  prompt_tokens(self.seed, t.rid, t.prompt_len,
                                self.vocab_size),
                  t.gen_len, ttft_deadline_s=t.ttft_deadline_s,
                  deadline_s=t.deadline_s)
        self.submitted += 1

    def _sample(self, lc: Lifecycle, step: int) -> None:
        row = (int(step), len(lc._queue), lc.open_count())
        if ((not self.queue_depth or self.queue_depth[-1][1:] != row[1:])
                and len(self.queue_depth) < self.MAX_SAMPLES):
            self.queue_depth.append(row)


class TraceSource(_SourceBase):
    """Open-loop arrivals: each trace request is submitted at the first
    loop step whose clock reading reaches its ``arrival_s`` — the classic
    Poisson load test (arrivals don't wait for completions)."""

    def __init__(self, trace: list[TraceRequest], vocab_size: int, *,
                 seed: int = 0):
        super().__init__(vocab_size, seed)
        self.trace = sorted(trace, key=lambda t: (t.arrival_s, t.rid))
        self._i = 0

    def pump(self, lc: Lifecycle, step: int) -> None:
        now = lc.clock()
        while self._i < len(self.trace) and \
                self.trace[self._i].arrival_s <= now:
            self._submit(lc, self.trace[self._i])
            self._i += 1
        self._sample(lc, step)

    def exhausted(self) -> bool:
        return self._i >= len(self.trace)

    def skip_submitted(self, lc: Lifecycle) -> int:
        """Re-cursor for `serve --resume`: advance past every trace
        request the restored lifecycle already knows.  Arrival cursors are
        not persisted — the journal is — so a resumed source must simply
        never re-submit a journaled rid.  Returns the skip count."""
        skipped = 0
        while self._i < len(self.trace) and \
                self.trace[self._i].rid in lc.requests:
            self._i += 1
            skipped += 1
        self.submitted += skipped
        return skipped

    def next_arrival_step(self, lc: Lifecycle, step: int) -> int | None:
        """Step to jump an idle loop to (None once exhausted).  Without a
        step-addressable clock the loop can only step forward one at a
        time and let the wall clock catch up."""
        if self.exhausted():
            return None
        step_for = getattr(lc.clock, "step_for", None)
        if step_for is None:
            return step + 1
        return max(step + 1, step_for(self.trace[self._i].arrival_s))


class SessionSource(_SourceBase):
    """Closed-loop think-time sessions: within a session, request ``i+1``
    becomes eligible ``think_s`` after request ``i`` reached a terminal
    state (its ``finish_t`` on the lifecycle clock).  The first request
    of each session uses its open-loop ``arrival_s``.  This is the
    interactive-user model: a slow server *slows its own offered load*,
    which an open-loop trace cannot express."""

    def __init__(self, sessions: list[list[TraceRequest]], vocab_size: int,
                 *, seed: int = 0):
        super().__init__(vocab_size, seed)
        self.sessions = [list(s) for s in sessions if s]
        self._idx = [0] * len(self.sessions)

    def _arrival(self, lc: Lifecycle, si: int) -> float | None:
        """Eligibility time of session si's next request; None when the
        session is done or its predecessor hasn't terminated yet."""
        i = self._idx[si]
        sess = self.sessions[si]
        if i >= len(sess):
            return None
        if i == 0:
            return sess[0].arrival_s
        prev = lc.requests.get(sess[i - 1].rid)
        if prev is None or prev.finish_t is None:
            return None
        return prev.finish_t + sess[i].think_s

    def pump(self, lc: Lifecycle, step: int) -> None:
        now = lc.clock()
        progress = True
        while progress:   # a submit can unblock nothing mid-pump, but a
            progress = False   # REJECTED terminates instantly — resweep
            for si in range(len(self.sessions)):
                t_arr = self._arrival(lc, si)
                if t_arr is not None and t_arr <= now:
                    self._submit(lc, self.sessions[si][self._idx[si]])
                    self._idx[si] += 1
                    progress = True
        self._sample(lc, step)

    def exhausted(self) -> bool:
        return all(i >= len(s) for i, s in zip(self._idx, self.sessions))

    def skip_submitted(self, lc: Lifecycle) -> int:
        """Per-session sibling of `TraceSource.skip_submitted` (resume
        re-cursor): advance each session past its journaled requests."""
        skipped = 0
        for si, sess in enumerate(self.sessions):
            while self._idx[si] < len(sess) and \
                    sess[self._idx[si]].rid in lc.requests:
                self._idx[si] += 1
                skipped += 1
        self.submitted += skipped
        return skipped

    def next_arrival_step(self, lc: Lifecycle, step: int) -> int | None:
        arrivals = [a for si in range(len(self.sessions))
                    if (a := self._arrival(lc, si)) is not None]
        if not arrivals:
            return None
        step_for = getattr(lc.clock, "step_for", None)
        if step_for is None:
            return step + 1
        return max(step + 1, step_for(min(arrivals)))


# ---------------------------------------------------------------------------
# measurement
# ---------------------------------------------------------------------------

class StepTimeRecorder:
    """Watchdog shim recording *every* decode step's wall time (the
    rolling-median watchdog only keeps a window) so per-request
    predicted-vs-measured rows can be built after the run.  Forwards to a
    wrapped DecodeWatchdog when given one."""

    def __init__(self, watchdog=None):
        self.watchdog = watchdog
        self.times: dict[int, float] = {}

    def observe(self, step: int, step_time_s: float):
        self.times[int(step)] = float(step_time_s)
        if self.watchdog is not None:
            return self.watchdog.observe(step, step_time_s)
        return None

    def summary(self) -> dict:
        if self.watchdog is not None:
            return self.watchdog.summary()
        return {"predicted_step_us": None, "measured_step_us_p50": None,
                "divergence": None, "stragglers": []}


def _decode_span(req) -> tuple[int, int] | None:
    """(first decode step, terminal step) of a request's *final* attempt
    (retries restart the span), for attributing wall step times."""
    start = None
    for state, step in req.history:
        if state is State.DECODING:
            start = step
    if start is None or not req.history:
        return None
    end = req.history[-1][1]
    return (start, end) if end >= start else None


def collect_metrics(lc: Lifecycle, *, predicted_step_us: float | None = None,
                    step_times: dict[int, float] | None = None,
                    queue_depth: list | None = None) -> dict:
    """The per-mix measurement block of BENCH_serving.json: latency
    percentiles and throughput on the lifecycle clock (deterministic
    under a VirtualClock), queue-depth timeline, and per-request rows
    with predicted-vs-measured decode-step time (wall-derived fields are
    VOLATILE_FIELDS)."""
    rows = []
    for rid in sorted(lc.requests):
        r = lc.requests[rid]
        row = r.outcome()
        row["per_token_ms"] = (None if r.per_token_ms is None
                               else round(r.per_token_ms, 3))
        if step_times:
            span = _decode_span(r)
            vals = ([step_times[s] for s in range(span[0], span[1] + 1)
                     if s in step_times] if span else [])
            if vals:
                measured_us = float(np.mean(vals)) * 1e6
                row["measured_step_us"] = round(measured_us, 1)
                if predicted_step_us:
                    row["step_time_ratio"] = round(
                        measured_us / predicted_step_us, 3)
        rows.append(row)

    tokens_total = sum(len(r.tokens) for r in lc.requests.values())
    starts = [r.submit_t for r in lc.requests.values()]
    finishes = [r.finish_t for r in lc.requests.values()
                if r.finish_t is not None]
    span_s = (max(finishes) - min(starts)) if starts and finishes else None
    tok_per_s = (tokens_total / span_s if span_s else None)

    pvm = {"predicted_step_us": (None if predicted_step_us is None
                                 else round(predicted_step_us, 3))}
    if step_times:
        med_us = float(np.median(list(step_times.values()))) * 1e6
        pvm["measured_step_us_p50"] = round(med_us, 1)
        if predicted_step_us:
            pvm["divergence"] = round(med_us / predicted_step_us, 3)

    queue_depth = list(queue_depth or [])
    return {
        "submitted": lc.submitted,
        "outcomes": lc.counters(),
        "conserved": lc.conserved(),
        "tokens_total": tokens_total,
        "ttft_ms": lc.ttft_percentiles(),
        "per_token_ms": lc.per_token_percentiles(),
        "span_s": None if span_s is None else round(span_s, 6),
        "tok_per_s": None if tok_per_s is None else round(tok_per_s, 3),
        "queue_depth": [list(q) for q in queue_depth],
        "queue_depth_max": max((q[1] for q in queue_depth), default=0),
        "predicted_vs_measured": pvm,
        "requests": rows,
    }
