"""Atomic, incremental snapshots of the serving state — the second half
of the crash-tolerance story (`runtime.journal` is the first).

A snapshot is everything `serve --resume` needs to rebuild a server
mid-run without replaying the whole history: the KV-cache leaves and
per-slot ``lengths``, the slot↔request map and per-slot decode counters,
the full lifecycle table, the virtual-clock step, the loadgen arrival
cursor, and the serving-plan key — plus the journal ``seq`` it covers,
which bounds the journal tail a recovery replays to at most
``snapshot_every`` decode steps' worth of records.

Durability discipline (the part a crash can never tear):

* array payloads land in ``snap-<step>.npz`` via temp-file +
  ``os.replace`` — a crash mid-write leaves only a ``*.tmp`` orphan;
* the JSON **manifest** ``snap-<step>.json`` is written *last*, also via
  temp + rename: its presence is the commit point.  A manifest that
  references a missing/corrupt payload (the torn-write window) is
  treated as uncommitted and skipped by :func:`latest_snapshot`.
* snapshots are **incremental** by content: each array leaf is hashed,
  and a leaf unchanged since the previous snapshot is *referenced* from
  the older payload file instead of rewritten (idle slots, frozen
  recurrent state, the long steady tail of a draining run).  Pruning
  keeps every payload file the surviving manifests still reference.

:func:`atomic_write_json` is the shared torn-write guard: the autotune
cache (`kernels.autotune.TuneCache`) and every ``BENCH_*.json`` emitter
write through it, so a crash mid-save can quarantine nothing — the old
file survives intact until the new one is fully on disk.

Like `runtime.journal`, numpy+stdlib only — the server hands its jax
trees over as flat ``{name: np.ndarray}`` dicts (see
`launch.serve.Server.export_state`).
"""

from __future__ import annotations

import hashlib
import json
import pathlib
import zipfile

import numpy as np

# Re-exported here because the snapshot layer is where the durability
# discipline is *documented*; the implementation lives in `core.ioutil`
# so the autotune cache and the benchmark emitters (layers below runtime)
# write through the same guard.
from repro.core.ioutil import atomic_write_bytes, atomic_write_json  # noqa: F401
from repro.runtime.lifecycle import Lifecycle, Request, State

SNAPSHOT_SCHEMA = 1


# ---------------------------------------------------------------------------
# snapshot write / read
# ---------------------------------------------------------------------------

def _leaf_hash(a: np.ndarray) -> str:
    h = hashlib.sha1()
    h.update(str(a.dtype).encode())
    h.update(str(a.shape).encode())
    h.update(np.ascontiguousarray(a).tobytes())
    return h.hexdigest()


def _manifest_paths(dirpath) -> list[pathlib.Path]:
    return sorted(pathlib.Path(dirpath).glob("snap-*.json"))


class SnapshotStore:
    """Reader/writer over one snapshot directory.

    ``save`` is called by the serve loop every ``every`` decode steps
    (``due(step)``); ``keep`` bounds how many committed snapshots — and
    transitively, which payload files — survive pruning.
    """

    def __init__(self, dirpath, *, every: int = 8, keep: int = 3):
        if every < 1:
            raise ValueError(f"snapshot interval must be >= 1, got {every}")
        self.dir = pathlib.Path(dirpath)
        self.every = int(every)
        self.keep = max(1, int(keep))
        self.dir.mkdir(parents=True, exist_ok=True)
        self._prev: dict | None = None     # last committed manifest
        self.saved = 0

    def due(self, step: int, last_step: int) -> bool:
        """True when ``step`` crossed a snapshot boundary since
        ``last_step`` (the loop may jump the virtual clock)."""
        return step // self.every > last_step // self.every

    def save(self, *, step: int, arrays: dict, meta: dict,
             journal_seq: int) -> pathlib.Path:
        """Commit one snapshot; returns the manifest path."""
        name = f"snap-{step:08d}"
        payload_file = f"{name}.npz"
        prev_arrays = (self._prev or {}).get("arrays", {})
        entries: dict[str, dict] = {}
        fresh: dict[str, np.ndarray] = {}
        for leaf, a in arrays.items():
            a = np.asarray(a)
            sha = _leaf_hash(a)
            prev = prev_arrays.get(leaf)
            if (prev and prev["sha"] == sha
                    and (self.dir / prev["file"]).exists()):
                entries[leaf] = dict(prev)          # incremental: reuse
            else:
                key = f"a{len(fresh)}"
                fresh[key] = a
                entries[leaf] = {"file": payload_file, "key": key,
                                 "sha": sha}
        if fresh:
            import io
            buf = io.BytesIO()
            np.savez(buf, **fresh)
            atomic_write_bytes(self.dir / payload_file, buf.getvalue())
        manifest = {
            "schema": SNAPSHOT_SCHEMA,
            "step": int(step),
            "journal_seq": int(journal_seq),
            "meta": meta,
            "arrays": entries,
        }
        atomic_write_json(self.dir / f"{name}.json", manifest)
        self._prev = manifest
        self.saved += 1
        self._prune()
        return self.dir / f"{name}.json"

    def _prune(self) -> None:
        manifests = _manifest_paths(self.dir)
        drop, keep = manifests[:-self.keep], manifests[-self.keep:]
        referenced = set()
        for m in keep:
            try:
                man = json.loads(m.read_text())
                referenced |= {e["file"] for e in man["arrays"].values()}
            except (ValueError, KeyError, OSError):
                continue
        for m in drop:
            payload = m.with_suffix(".npz")
            m.unlink(missing_ok=True)
            if payload.name not in referenced:
                payload.unlink(missing_ok=True)


def load_snapshot(manifest_path) -> tuple[dict, dict]:
    """Load one committed snapshot: ``(manifest, {leaf: np.ndarray})``.
    Raises on a manifest whose payloads are missing, torn, or fail their
    content hash — the caller falls back to an older snapshot."""
    manifest_path = pathlib.Path(manifest_path)
    manifest = json.loads(manifest_path.read_text())
    if manifest.get("schema") != SNAPSHOT_SCHEMA:
        raise ValueError(f"{manifest_path}: snapshot schema "
                         f"{manifest.get('schema')!r} != {SNAPSHOT_SCHEMA}")
    by_file: dict[str, dict] = {}
    arrays: dict[str, np.ndarray] = {}
    for leaf, e in manifest["arrays"].items():
        if e["file"] not in by_file:
            with np.load(manifest_path.parent / e["file"]) as z:
                by_file[e["file"]] = {k: z[k] for k in z.files}
        a = by_file[e["file"]][e["key"]]
        if _leaf_hash(a) != e["sha"]:
            raise ValueError(f"{manifest_path}: leaf {leaf!r} failed its "
                             f"content hash — torn or corrupted payload")
        arrays[leaf] = a
    return manifest, arrays


def latest_snapshot(dirpath) -> tuple[dict, dict] | None:
    """The newest *committed and loadable* snapshot of a directory (None
    when there is none).  An unreadable or hash-failing snapshot — the
    crash-mid-write window — is skipped with the next-older one tried,
    so recovery degrades by one interval instead of failing."""
    for manifest_path in reversed(_manifest_paths(dirpath)):
        try:
            return load_snapshot(manifest_path)
        except (ValueError, OSError, KeyError, zipfile.BadZipFile):
            continue
    return None


# ---------------------------------------------------------------------------
# lifecycle table <-> JSON state
# ---------------------------------------------------------------------------

def lifecycle_state(lc: Lifecycle) -> dict:
    """The lifecycle table as a JSON-able snapshot payload: every request
    record in full (prompt tokens, history, deadlines), the queue order,
    and the event counters."""
    reqs = []
    for rid in sorted(lc.requests):
        r = lc.requests[rid]
        reqs.append({
            "rid": r.rid,
            "prompt": [int(t) for t in np.asarray(r.prompt).tolist()],
            "gen_len": int(r.gen_len),
            "submit_t": float(r.submit_t),
            "ttft_deadline_s": r.ttft_deadline_s,
            "deadline_s": r.deadline_s,
            "state": r.state.value,
            "retries": int(r.retries),
            "not_before_step": int(r.not_before_step),
            "first_token_t": r.first_token_t,
            "finish_t": r.finish_t,
            "tokens": [int(t) for t in r.tokens],
            "history": [[s.value, int(st)] for s, st in r.history],
        })
    return {
        "queue_limit": lc.queue_limit,
        "max_retries": lc.max_retries,
        "backoff_steps": lc.backoff_steps,
        "evicted_events": lc.evicted_events,
        "retried_events": lc.retried_events,
        "queue": [r.rid for r in lc._queue],
        "requests": reqs,
    }


def restore_lifecycle(state: dict, *, clock=None) -> Lifecycle:
    """Rebuild a Lifecycle (requests, queue order, counters) from
    :func:`lifecycle_state` output.  ``clock`` is the resumed run's clock
    (typically a `loadgen.VirtualClock` restored to the crash step)."""
    kw = {} if clock is None else {"clock": clock}
    lc = Lifecycle(queue_limit=state["queue_limit"],
                   max_retries=state["max_retries"],
                   backoff_steps=state["backoff_steps"], **kw)
    lc.evicted_events = state["evicted_events"]
    lc.retried_events = state["retried_events"]
    for r in state["requests"]:
        req = Request(
            rid=r["rid"], prompt=np.asarray(r["prompt"], np.int32),
            gen_len=r["gen_len"], submit_t=r["submit_t"],
            ttft_deadline_s=r["ttft_deadline_s"], deadline_s=r["deadline_s"],
            state=State(r["state"]), retries=r["retries"],
            not_before_step=r["not_before_step"],
            first_token_t=r["first_token_t"], finish_t=r["finish_t"],
            tokens=list(r["tokens"]),
            history=[(State(s), st) for s, st in r["history"]])
        lc.requests[req.rid] = req
    for rid in state["queue"]:
        lc._queue.append(lc.requests[rid])
    return lc
