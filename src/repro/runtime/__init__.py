from repro.runtime import (elastic, fault_tolerance, faults, journal,  # noqa: F401
                           lifecycle, snapshot)
