from repro.runtime import elastic, fault_tolerance, faults, lifecycle  # noqa: F401
