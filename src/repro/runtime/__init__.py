from repro.runtime import elastic, fault_tolerance  # noqa: F401
