"""Request lifecycle for fault-tolerant serving: a state machine with
deadlines, bounded admission, and retry-with-backoff.

Every request a server ever sees moves through

    QUEUED -> PREFILLING -> DECODING -> COMPLETED
       |           |            |
       |           +---- EVICTED ----> QUEUED (retry, backoff)  or  FAILED
       |           |            |
       +-------- TIMED_OUT <----+          (deadline sweep, any open state)

    submit() when the admission queue is full -> REJECTED (backpressure)

and the tracker enforces the edges: an illegal transition is a bug in the
serve loop, not a condition to paper over, so it raises.  Terminal states
are {COMPLETED, TIMED_OUT, FAILED, REJECTED}; EVICTED is transient — the
fault-handling states (slot quarantined after a NaN, kernel fault,
interrupted prefill) resolve to a retry or, once ``max_retries`` is spent,
to FAILED.  The invariant the whole layer exists for is **conservation**:
at drain time every submitted request is in exactly one terminal state,
``submitted == completed + timed_out + failed + rejected`` — a request can
be slow, evicted, or refused, but never silently lost (the failure mode of
the old ``while completed < requests`` loop, which span forever the moment
one request fell out of a slot).

Time enters twice, deliberately separated so chaos runs stay
deterministic: *deadlines* (time-to-first-token and total) are checked
against an injectable wall ``clock``, while *retry backoff* is priced in
decode **steps** (``backoff_steps * 2**(retries-1)``) — the virtual clock
every fault-injection schedule is keyed on.
"""

from __future__ import annotations

import dataclasses
import enum
import time
from collections import deque
from typing import Callable, Sequence

import numpy as np


class State(enum.Enum):
    QUEUED = "queued"
    PREFILLING = "prefilling"
    DECODING = "decoding"
    COMPLETED = "completed"
    TIMED_OUT = "timed_out"
    EVICTED = "evicted"
    FAILED = "failed"
    REJECTED = "rejected"


TERMINAL = frozenset({State.COMPLETED, State.TIMED_OUT, State.FAILED,
                      State.REJECTED})

# The legal edges.  Initial states (QUEUED / REJECTED) are set by submit();
# terminal states have no exits.
_ALLOWED: dict[State, frozenset[State]] = {
    # QUEUED -> REJECTED is scheduler backpressure: a paged-pool
    # admission policy refuses a request whose KV footprint can never
    # fit the pool (launch/scheduler.py) — loud, terminal, conserved.
    State.QUEUED: frozenset({State.PREFILLING, State.TIMED_OUT,
                             State.REJECTED}),
    State.PREFILLING: frozenset({State.DECODING, State.EVICTED,
                                 State.TIMED_OUT}),
    State.DECODING: frozenset({State.COMPLETED, State.EVICTED,
                               State.TIMED_OUT}),
    State.EVICTED: frozenset({State.QUEUED, State.FAILED}),
}


class TransitionError(RuntimeError):
    """An edge the state machine does not allow — a serve-loop bug."""


@dataclasses.dataclass
class Request:
    """One request's full lifecycle record."""

    rid: int
    prompt: np.ndarray
    gen_len: int
    submit_t: float
    ttft_deadline_s: float | None = None     # seconds after submit_t
    deadline_s: float | None = None          # seconds after submit_t
    state: State = State.QUEUED
    retries: int = 0
    not_before_step: int = 0                 # retry-backoff eligibility
    first_token_t: float | None = None
    finish_t: float | None = None            # clock time of terminal entry
    tokens: list = dataclasses.field(default_factory=list)
    history: list = dataclasses.field(default_factory=list)  # (state, step)

    @property
    def ttft_ms(self) -> float | None:
        if self.first_token_t is None:
            return None
        return (self.first_token_t - self.submit_t) * 1e3

    @property
    def per_token_ms(self) -> float | None:
        """Mean decode latency per post-first token, on the lifecycle
        clock (virtual-deterministic when a virtual clock is injected)."""
        if self.first_token_t is None or self.finish_t is None:
            return None
        extra = len(self.tokens) - 1
        if extra < 1:
            return None
        return (self.finish_t - self.first_token_t) * 1e3 / extra

    def outcome(self) -> dict:
        """The JSON-able per-request row of the serving summary (and the
        chaos determinism trace: final state + retry count)."""
        return {"rid": self.rid, "state": self.state.value,
                "retries": self.retries, "tokens": len(self.tokens),
                "ttft_ms": (None if self.ttft_ms is None
                            else round(self.ttft_ms, 3))}


class Lifecycle:
    """Tracker + bounded admission queue for every request of a serve run.

    ``queue_limit`` bounds the number of requests *waiting* in the
    admission queue: a submit that would exceed it is REJECTED outright
    (backpressure — the caller hears "no" immediately instead of holding a
    doomed deadline).  Retries re-enter the queue past the bound: an
    admitted request is owed a terminal answer and eviction must not turn
    into silent loss.
    """

    def __init__(self, *, queue_limit: int = 0, max_retries: int = 2,
                 backoff_steps: int = 4,
                 clock: Callable[[], float] = time.monotonic,
                 journal=None):
        self.queue_limit = queue_limit
        self.max_retries = max_retries
        self.backoff_steps = backoff_steps
        self.clock = clock
        # Optional write-ahead log (`runtime.journal.Journal`): every
        # submit and state transition is journaled *before* it takes
        # effect, so a crashed serve loop can be replayed deterministically
        # (docs/ROBUSTNESS.md, "Crash recovery").
        self.journal = journal
        self.requests: dict[int, Request] = {}
        self._queue: deque[Request] = deque()
        self.evicted_events = 0
        self.retried_events = 0

    # -- admission ----------------------------------------------------------

    def submit(self, rid: int, prompt, gen_len: int, *,
               ttft_deadline_s: float | None = None,
               deadline_s: float | None = None) -> Request:
        if rid in self.requests:
            raise ValueError(f"duplicate request id {rid}")
        req = Request(rid, np.asarray(prompt), gen_len, self.clock(),
                      ttft_deadline_s=ttft_deadline_s,
                      deadline_s=deadline_s)
        rejected = self.queue_limit and len(self._queue) >= self.queue_limit
        if self.journal is not None:
            # Write-ahead: the admission decision is durable before the
            # caller can observe it.
            self.journal.submit(rid, req.prompt, gen_len,
                                ttft_deadline_s=ttft_deadline_s,
                                deadline_s=deadline_s)
            self.journal.state(rid, (State.REJECTED if rejected
                                     else State.QUEUED).value, -1)
        if rejected:
            req.state = State.REJECTED
            req.finish_t = req.submit_t
            req.history.append((State.REJECTED, -1))
        else:
            req.history.append((State.QUEUED, -1))
            self._queue.append(req)
        self.requests[rid] = req
        return req

    def pop_ready(self, step: int) -> Request | None:
        """Next queued request whose retry backoff has elapsed (FCFS among
        the eligible)."""
        for i, req in enumerate(self._queue):
            if req.not_before_step <= step:
                del self._queue[i]
                return req
        return None

    def eligible(self, step: int) -> list[Request]:
        """Every queued request whose retry backoff has elapsed, in FCFS
        order — the candidate set a pluggable admission policy
        (launch/scheduler.py) picks from."""
        return [r for r in self._queue if r.not_before_step <= step]

    def take(self, req: Request) -> None:
        """Remove a specific request from the admission queue (the
        scheduler admitted it out of FCFS order)."""
        self._queue.remove(req)

    def next_eligible_step(self) -> int | None:
        """Earliest step at which *some* queued request becomes eligible
        (None if the queue is empty) — lets an otherwise-idle loop jump its
        virtual clock instead of spinning empty decode steps."""
        if not self._queue:
            return None
        return min(r.not_before_step for r in self._queue)

    # -- transitions --------------------------------------------------------

    def transition(self, req: Request, new: State, step: int) -> None:
        if new not in _ALLOWED.get(req.state, frozenset()):
            raise TransitionError(
                f"request {req.rid}: illegal transition "
                f"{req.state.value} -> {new.value} at step {step}")
        if self.journal is not None:
            # Write-ahead: the edge is durable before it takes effect.  A
            # QUEUED entry carries the retry-backoff eligibility so a
            # recovery reconstructs the backoff schedule exactly.
            self.journal.state(
                req.rid, new.value, step, retries=req.retries,
                **({"not_before_step": req.not_before_step}
                   if new is State.QUEUED else {}))
        req.state = new
        if new in TERMINAL:
            req.finish_t = self.clock()
        req.history.append((new, step))

    def record_first_token(self, req: Request) -> None:
        req.first_token_t = self.clock()

    def evict(self, req: Request, step: int, reason: str = "") -> bool:
        """Quarantine a request (NaN slot, kernel fault, interrupted
        prefill): EVICTED, then either requeued with exponential step
        backoff (returns True) or FAILED once retries are spent.  A
        retried request starts over — its tokens are discarded so the
        retry reproduces solo decode token-for-token from a fresh slot."""
        self.transition(req, State.EVICTED, step)
        self.evicted_events += 1
        req.tokens = []
        if req.retries < self.max_retries:
            req.retries += 1
            req.not_before_step = (
                step + self.backoff_steps * 2 ** (req.retries - 1))
            self.transition(req, State.QUEUED, step)
            self._queue.append(req)
            self.retried_events += 1
            return True
        self.transition(req, State.FAILED, step)
        return False

    def reject(self, req: Request, step: int) -> None:
        """Backpressure a QUEUED request out of the system entirely —
        used by the paged-pool scheduler when a request's predicted KV
        footprint exceeds what the pool could ever hold.  Terminal and
        conserved, never silently dropped."""
        if req in self._queue:
            self._queue.remove(req)
        self.transition(req, State.REJECTED, step)

    def check_deadlines(self, step: int) -> list[Request]:
        """Sweep every open request against its deadlines; newly
        TIMED_OUT requests are returned so the loop can free their slots
        (queued ones are dropped from the admission queue here)."""
        now = self.clock()
        expired = []
        for req in self.requests.values():
            if req.state in TERMINAL or req.state is State.EVICTED:
                continue
            waited = now - req.submit_t
            over_total = (req.deadline_s is not None
                          and waited > req.deadline_s)
            over_ttft = (req.ttft_deadline_s is not None
                         and req.first_token_t is None
                         and waited > req.ttft_deadline_s)
            if over_total or over_ttft:
                if req in self._queue:
                    self._queue.remove(req)
                self.transition(req, State.TIMED_OUT, step)
                expired.append(req)
        return expired

    # -- accounting ---------------------------------------------------------

    def open_requests(self) -> list[Request]:
        return [r for r in self.requests.values() if r.state not in TERMINAL]

    def open_count(self) -> int:
        return len(self.open_requests())

    def counters(self) -> dict:
        by_state = {s.value: 0 for s in
                    (State.COMPLETED, State.TIMED_OUT, State.FAILED,
                     State.REJECTED)}
        for r in self.requests.values():
            if r.state in TERMINAL:
                by_state[r.state.value] += 1
        by_state["evicted"] = self.evicted_events
        by_state["retried"] = self.retried_events
        return by_state

    @property
    def submitted(self) -> int:
        return len(self.requests)

    def conserved(self) -> bool:
        """submitted == completed + timed_out + failed + rejected — every
        request in exactly one terminal state."""
        c = self.counters()
        terminal = (c["completed"] + c["timed_out"] + c["failed"]
                    + c["rejected"])
        return terminal == self.submitted

    def ttft_percentiles(self) -> dict:
        return _percentiles([r.ttft_ms for r in self.requests.values()
                             if r.ttft_ms is not None])

    def per_token_percentiles(self) -> dict:
        return _percentiles([r.per_token_ms for r in self.requests.values()
                             if r.per_token_ms is not None])

    def outcome_trace(self) -> list[dict]:
        """Per-request final states + retry counts, rid-ordered — the
        record chaos determinism is asserted on."""
        return [self.requests[rid].outcome()
                for rid in sorted(self.requests)]

    def table(self) -> str:
        """Human-readable lifecycle table — what the no-progress guard
        prints instead of spinning forever."""
        lines = [f"{'rid':>5}  {'state':<11} {'retries':>7}  {'tokens':>6}  "
                 f"history"]
        for rid in sorted(self.requests):
            r = self.requests[rid]
            hist = " -> ".join(f"{s.value}@{step}" for s, step in r.history)
            lines.append(f"{rid:>5}  {r.state.value:<11} {r.retries:>7}  "
                         f"{len(r.tokens):>6}  {hist}")
        return "\n".join(lines)


def _percentiles(vals: list) -> dict:
    if not vals:
        return {"p50": None, "p99": None, "n": 0}
    p50, p99 = np.percentile(vals, [50, 99])
    return {"p50": round(float(p50), 3), "p99": round(float(p99), 3),
            "n": len(vals)}


def submit_all(lc: Lifecycle, requests: Sequence[tuple], *,
               ttft_deadline_s: float | None = None,
               deadline_s: float | None = None) -> None:
    """Admit a [(rid, prompt, gen_len)] batch (the CLI's arrival model:
    everything at t0)."""
    for rid, prompt, gen_len in requests:
        lc.submit(rid, prompt, gen_len, ttft_deadline_s=ttft_deadline_s,
                  deadline_s=deadline_s)
