"""Deterministic fault injection for the serving loop (chaos harness).

The source paper's position is that failure is a *system-level* design
concern: you do not build exotic per-core hardware to avoid faults, you
build software that detects and absorbs them.  Absorption you cannot
rehearse is absorption you do not have — so this module turns faults into
a seeded, replayable schedule: a :class:`FaultPlan` is a list of
:class:`FaultEvent`\\ s keyed on the serve loop's *virtual clock* (decode
step index; prefill ordinal for prefill interrupts), generated from an
integer seed.  The same ``--fault-seed`` therefore produces the same
faults at the same points of the same execution — and must produce the
same outcome trace (per-request final states and retry counts), which the
chaos tests assert.

Fault classes (one of each in the smoke schedule):

``nan_logits``        NaN into a chosen slot's logits for one decode step
                      (the slot's next sampled token is garbage; nothing
                      else is touched) — exercises the per-slot guard.
``kv_corrupt``        NaN over a chosen slot's KV/state cache rows —
                      poisoned *state*, not just one step's output; the
                      guard must quarantine exactly that slot.
``kernel_dispatch``   raise :class:`KernelDispatchFault` from the decode
                      dispatch — exercises the one-shot jnp-reference
                      fallback + plan poisoning.
``straggler``         stall one decode step by ``stall_s`` — exercises the
                      measured-vs-predicted decode watchdog.
``prefill_interrupt`` raise :class:`PrefillInterrupt` mid-prefill (after
                      the slot reset, before the forward) — exercises
                      evict + retry from a half-initialized slot.

Injection points are explicit hooks: ``Server.prefill`` calls
``prefill_hook``, ``Server.decode_step`` calls ``apply_decode_faults``,
and ``kernels.autotune.dispatch`` consults the hook installed by
:func:`install_dispatch_hook` (unit-level: a kernel launch that raises).
This module is numpy+stdlib only — it manipulates the server through its
public surface (``poison`` mask, ``corrupt_kv``) and never imports jax.
"""

from __future__ import annotations

import dataclasses

import numpy as np


class InjectedFault(Exception):
    """Base class for every injected failure."""


class KernelDispatchFault(InjectedFault):
    """Injected kernel-dispatch failure (stands in for a Pallas launch
    error / VMEM overflow the plan missed)."""


class PrefillInterrupt(InjectedFault):
    """Injected mid-prefill interruption (stands in for preemption or a
    host fault between slot reset and cache write)."""


class CrashFault(InjectedFault):
    """Injected process death at a decode step (stands in for power loss,
    a watchdog reboot, or an OOM kill — the paper's embedded operating
    conditions).  Unlike every other fault class this one is NOT absorbed
    by the serve loop: it propagates out, the process exits without a
    summary, and only the journal + snapshots survive.  `serve --resume`
    must then reproduce the uninterrupted run token-for-token
    (docs/ROBUSTNESS.md, "Crash recovery")."""

    def __init__(self, msg: str, step: int = -1):
        super().__init__(msg)
        self.step = step


# Classes the --chaos smoke schedule absorbs in-process.  "crash" is the
# sixth class (FaultPlan.crash / serve --crash): it kills the loop instead
# of being absorbed, so it is scheduled explicitly, never by smoke().
SMOKE_FAULT_CLASSES = ("nan_logits", "kv_corrupt", "kernel_dispatch",
                       "straggler", "prefill_interrupt")
FAULT_CLASSES = SMOKE_FAULT_CLASSES + ("crash",)


@dataclasses.dataclass(frozen=True)
class FaultEvent:
    kind: str          # one of FAULT_CLASSES
    step: int          # decode step index; prefill ordinal for interrupts
    slot: int          # target slot hint (resolved modulo batch, occupied)
    stall_s: float = 0.0

    def record(self) -> dict:
        return dataclasses.asdict(self)


class FaultPlan:
    """An ordered, seeded fault schedule."""

    def __init__(self, events):
        self.events = sorted(events, key=lambda e: (e.step, e.kind, e.slot))

    @classmethod
    def smoke(cls, seed: int, *, max_step: int = 14,
              stall_s: float = 0.25) -> "FaultPlan":
        """One fault of every class at seeded-random steps/slots — the
        ``serve --chaos`` schedule the chaos-smoke CI job runs.  Steps are
        drawn from [2, max_step] so the batch is warm when faults land;
        the straggler lands late enough (>= 8 observations) for the
        rolling-median watchdog to have a baseline."""
        rng = np.random.default_rng(seed)
        events = []
        for kind in ("nan_logits", "kv_corrupt", "kernel_dispatch"):
            events.append(FaultEvent(kind, int(rng.integers(2, max_step + 1)),
                                     int(rng.integers(0, 64))))
        events.append(FaultEvent("straggler",
                                 int(rng.integers(9, max_step + 3)),
                                 0, stall_s=stall_s))
        # prefill ordinal 1 = the second prefill of the run: slot 0's very
        # first fill stays clean so the loop always gets off the ground.
        events.append(FaultEvent("prefill_interrupt",
                                 int(rng.integers(1, 3)),
                                 int(rng.integers(0, 64))))
        return cls(events)

    @classmethod
    def crash(cls, seed: int, *, step: int | None = None,
              max_step: int = 14) -> "FaultPlan":
        """A single seeded crash fault: the serve loop dies at an
        arbitrary decode step in [4, max_step] (or exactly ``step`` when
        pinned).  Combine with the smoke schedule via
        :meth:`FaultPlan.merge`."""
        return cls([crash_event(seed, step=step, max_step=max_step)])

    def merge(self, other: "FaultPlan") -> "FaultPlan":
        return FaultPlan(self.events + other.events)

    def record(self) -> list[dict]:
        return [e.record() for e in self.events]


def crash_event(seed: int, *, step: int | None = None,
                max_step: int = 14) -> FaultEvent:
    if step is None:
        step = int(np.random.default_rng(
            np.random.SeedSequence([seed, 0xC4A54])).integers(4,
                                                              max_step + 1))
    return FaultEvent("crash", int(step), 0)


class FaultInjector:
    """Executes a FaultPlan against a live server via the explicit hooks.

    Events whose virtual-clock point has arrived are *consumed* (each
    fires at most once), and everything that fired lands in ``self.fired``
    for the serving summary.  Slot hints resolve deterministically onto an
    occupied slot; an event with no occupied slot to hit is consumed and
    recorded as skipped.
    """

    def __init__(self, plan: FaultPlan, *, sleep=None):
        import time
        self.plan = plan
        self.pending = list(plan.events)
        self.fired: list[dict] = []
        self.prefill_count = 0
        self._sleep = sleep if sleep is not None else time.sleep

    # -- hooks --------------------------------------------------------------

    def prefill_hook(self, slot: int, rid: int) -> None:
        """Called by Server.prefill after the slot reset, before the
        forward; may raise PrefillInterrupt."""
        ordinal = self.prefill_count
        self.prefill_count += 1
        for ev in list(self.pending):
            if ev.kind == "prefill_interrupt" and ev.step == ordinal:
                self.pending.remove(ev)
                self.fired.append({**ev.record(), "slot": slot, "rid": rid})
                raise PrefillInterrupt(
                    f"injected prefill interrupt (request {rid}, "
                    f"slot {slot}, prefill #{ordinal})")

    def apply_decode_faults(self, server, step: int) -> None:
        """Called by Server.decode_step before the forward.  Applies every
        event scheduled at ``step``: corrupts KV, arms the logits-poison
        mask, stalls, and — last, so same-step state faults still land —
        raises KernelDispatchFault.

        A due ``crash`` event preempts everything: a real power cut does
        not let the other faults of the step fire first, so the crash is
        consumed alone (the rest stay pending — a snapshot taken earlier
        carries them into the resumed process) and CrashFault propagates
        out of the serve loop entirely."""
        for ev in list(self.pending):
            if ev.kind == "crash" and ev.step <= step:
                self.pending.remove(ev)
                self.fired.append({**ev.record(), "fired_step": step})
                raise CrashFault(
                    f"injected crash at decode step {step} (scheduled "
                    f"step {ev.step})", step)
        due = [ev for ev in self.pending if ev.kind != "prefill_interrupt"
               and ev.step <= step]
        raise_dispatch = None
        for ev in due:
            self.pending.remove(ev)
            slot = self._resolve_slot(server, ev.slot)
            if slot is None:
                self.fired.append({**ev.record(), "skipped": True})
                continue
            rec = {**ev.record(), "slot": slot, "fired_step": step}
            if ev.kind == "nan_logits":
                server.poison[slot] = True
            elif ev.kind == "kv_corrupt":
                server.corrupt_kv(slot)
            elif ev.kind == "straggler":
                self._sleep(ev.stall_s)
            elif ev.kind == "kernel_dispatch":
                raise_dispatch = ev
            self.fired.append(rec)
        if raise_dispatch is not None:
            raise KernelDispatchFault(
                f"injected kernel-dispatch failure at step {step}")

    def dispatch_hook(self, family: str) -> None:
        """autotune.dispatch-level hook: fail the next kernel launch of a
        family with a pending kernel_dispatch event at step <= 0 (the
        unit-level injection point; the serve loop handles step-scheduled
        dispatch faults itself because the jitted step traces dispatch
        only once)."""
        for ev in list(self.pending):
            if ev.kind == "kernel_dispatch" and ev.step < 0:
                self.pending.remove(ev)
                self.fired.append({**ev.record(), "family": family})
                raise KernelDispatchFault(
                    f"injected dispatch failure for family '{family}'")

    # -- helpers ------------------------------------------------------------

    @staticmethod
    def _resolve_slot(server, hint: int) -> int | None:
        """Deterministically aim a slot hint at an occupied slot."""
        occupied = [s for s in range(server.batch) if server.slot_req[s] >= 0]
        if not occupied:
            return None
        return occupied[hint % len(occupied)]

    def record(self) -> dict:
        return {"schedule": self.plan.record(), "fired": list(self.fired),
                "pending": [e.record() for e in self.pending]}

    # -- crash-tolerance (snapshot payload) ---------------------------------

    def state(self) -> dict:
        """JSON-able injector state for `runtime.snapshot`: which events
        are still pending and how many prefills have run, so a resumed
        process keeps executing the *same* seeded schedule instead of
        restarting it."""
        return {"pending": [e.record() for e in self.pending],
                "fired": list(self.fired),
                "prefill_count": self.prefill_count}

    @classmethod
    def restore(cls, plan: "FaultPlan", state: dict, *,
                resume_step: int = 0, sleep=None) -> "FaultInjector":
        """Rebuild an injector from snapshot state.  Pending ``crash``
        events scheduled at or before ``resume_step`` are dropped — they
        are the fault that killed the previous process (the snapshot
        predates the crash, so the event still looks pending); replaying
        one would crash-loop the recovery.  Every other pending event is
        kept: a fault scheduled inside the replay window is simply
        absorbed again."""
        inj = cls(plan, sleep=sleep)
        inj.pending = [
            ev for ev in (FaultEvent(**{k: r[k] for k in
                                        ("kind", "step", "slot", "stall_s")})
                          for r in state.get("pending", []))
            if not (ev.kind == "crash" and ev.step <= resume_step)]
        inj.fired = list(state.get("fired", []))
        inj.prefill_count = int(state.get("prefill_count", 0))
        return inj
