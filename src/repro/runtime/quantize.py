"""Symmetric int8 quantization for the streamed KV cache.

Decode is bandwidth-bound: the fused decode kernel streams every valid
K/V row of the cache once per generated token, so halving the streamed
bytes is a direct tokens/sec multiplier (ROADMAP item 2; the
bf16-stream/f32-accumulate matmul path is the in-repo precedent, and
`optim/adamw.py`'s blockwise int8 moments are the storage-side one).

The quantization block here is **one token row**: each written token's
(dh,)-vector per KV head gets one f32 scale (absmax / 127, the same
symmetric law as `adamw.quantize_blockwise`, block width = dh instead of
128).  A coarser `page_size`-row block would amortize the scale stream
further, but scatter-on-write lands one token at a time — re-quantizing
a shared block on every write would perturb tokens already in the cache,
breaking the solo-vs-batched determinism contract and byte-identical
crash/resume.  Per-row scales keep every cache write idempotent and
write-once while still cutting the stream to
``dh + 4`` bytes per token per KV head vs ``2*dh`` for bf16
(>= 1.6x for dh >= 16, ~1.88x at dh = 64 — the `decode_int8` bench row,
CI-gated by `tools/check_bench.py`).

Properties the tests pin (`tests/test_quantize.py`):

* round-trip error is bounded by half a quantization step:
  ``|x - deq(quant(x))| <= absmax(row) / 127 / 2`` (+ float eps);
* an all-zero row quantizes to zeros with scale 0 and round-trips
  exactly (the scale floor keeps the division finite);
* an outlier dominates its own row's scale only — other rows keep full
  resolution (the reason the block is a row, not a page);
* re-quantization is idempotent: ``quant(deq(quant(x))) == quant(x)``
  bit-for-bit, so a crash/resume cycle through the snapshot (which
  stores q + scale, never dequantized values) cannot drift.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

# Quantized values span [-127, 127] (symmetric; -128 unused so negation
# is exact), one f32 scale per row.
QMAX = 127
SCALE_FLOOR = 1e-12


def quantize_rows(x: jax.Array) -> tuple[jax.Array, jax.Array]:
    """``x (..., dh) float -> (q int8 (..., dh), scale f32 (...))``.

    Per-row symmetric absmax quantization: the row element of largest
    magnitude maps to exactly +-QMAX, everything else rounds to the
    nearest step.  A zero row gets scale 0 (the floor only guards the
    division) and quantizes to zeros.
    """
    xf = x.astype(jnp.float32)
    scale = jnp.max(jnp.abs(xf), axis=-1) / QMAX
    q = jnp.round(xf / jnp.maximum(scale[..., None], SCALE_FLOOR))
    q = jnp.clip(q, -QMAX, QMAX).astype(jnp.int8)
    return q, scale


def dequantize_rows(q: jax.Array, scale: jax.Array) -> jax.Array:
    """Inverse of :func:`quantize_rows`: ``q * scale`` in f32."""
    return q.astype(jnp.float32) * scale[..., None].astype(jnp.float32)


def quantized_zeros(shape: tuple[int, ...],
                    ) -> tuple[jax.Array, jax.Array]:
    """Fresh (q, scale) leaves for an empty cache of ``shape`` token rows
    (last axis is dh): all-zero int8 values with all-zero scales — the
    exact image of `quantize_rows(zeros)`, so a reset slot is bitwise a
    fresh one."""
    return (jnp.zeros(shape, jnp.int8),
            jnp.zeros(shape[:-1], jnp.float32))


def bytes_per_token(dh: int, *, kv: int = 2) -> int:
    """Streamed bytes per token per KV head for the int8 layout: dh int8
    values + one f32 scale, for each of K and V (``kv = 2``).  The
    honest-accounting number the cost model and the CI gate recompute."""
    return kv * (dh + 4)


def max_abs_error_bound(x: jax.Array) -> jax.Array:
    """Per-row round-trip error bound: half a quantization step,
    ``absmax(row) / QMAX / 2``.  Used by the property tests and the
    bench row's declared accuracy budget."""
    xf = x.astype(jnp.float32)
    return jnp.max(jnp.abs(xf), axis=-1) / QMAX / 2.0
