"""Fault-tolerance runtime: heartbeats, straggler detection, restart policy.

On a real multi-pod deployment each host runs a `HostMonitor`; the trainer
wraps its step loop in `run_resilient`, which

  1. checkpoints every N steps (async, atomic — `checkpoint.manager`),
  2. watches per-step wall time and flags stragglers against a rolling
     median (mitigation on TPU = restart/evict the slow host and re-mesh:
     ICI collectives are synchronous, so unlike the paper's MIMD cores a
     single slow chip stalls the whole pod — detection is global by design),
  3. on failure (exception or missed heartbeats) restores the latest
     committed checkpoint — possibly onto a SMALLER surviving mesh via
     `runtime.elastic` — and resumes from the restored step with identical
     data order (the pipeline is (seed, step, shard)-deterministic).

The CPU container exercises all of this logic for real (tests inject faults);
only the node-level process management is necessarily simulated.
"""

from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Callable

import numpy as np


@dataclasses.dataclass
class StragglerReport:
    step: int
    step_time: float
    median: float
    ratio: float


class StragglerMonitor:
    """Rolling-median step-time watchdog (the paper's 'system-level
    simulation' instinct applied at runtime: the model of normal tells you
    what abnormal is)."""

    def __init__(self, window: int = 32, threshold: float = 2.0):
        self.times: deque = deque(maxlen=window)
        self.threshold = threshold
        self.reports: list[StragglerReport] = []

    def observe(self, step: int, step_time: float) -> StragglerReport | None:
        median = float(np.median(self.times)) if self.times else step_time
        self.times.append(step_time)
        if len(self.times) >= 8 and step_time > self.threshold * median:
            report = StragglerReport(step, step_time, median,
                                     step_time / median)
            self.reports.append(report)
            return report
        return None


class DecodeWatchdog:
    """Serving-side watchdog: the StragglerMonitor wired to the autotuner's
    predicted decode-step time.

    The coarse-grain estimator line of work (PAPERS.md) uses a
    predicted-vs-measured performance model as the natural misbehaving-
    execution signal; here the prediction is `autotune.predict_decode_step_us`
    (the same analytic machine model the kernel tuner ranks with) and the
    measurement is the serve loop's per-step wall clock.  Two signals come
    out: *stragglers* (a step way off the rolling median — transient) and
    *divergence* (the run's median vs the model — systematic), both
    reported in the serving summary rather than gated: on CPU
    interpret-mode the model predicts TPU time, so divergence is
    informational there and a gate only on real hardware.
    """

    def __init__(self, predicted_us: float | None,
                 threshold: float = 2.0):
        self.predicted_us = predicted_us
        self.monitor = StragglerMonitor(threshold=threshold)

    def observe(self, step: int, step_time_s: float) -> StragglerReport | None:
        return self.monitor.observe(step, step_time_s)

    def summary(self) -> dict:
        times = list(self.monitor.times)
        measured_us = float(np.median(times)) * 1e6 if times else None
        divergence = None
        if measured_us is not None and self.predicted_us:
            divergence = measured_us / self.predicted_us
        return {
            "predicted_step_us": self.predicted_us,
            "measured_step_us_p50": measured_us,
            "divergence": divergence,
            "stragglers": [dataclasses.asdict(r)
                           for r in self.monitor.reports],
        }


class Heartbeat:
    """Per-host liveness: hosts `beat()`; the coordinator calls `dead()`."""

    def __init__(self, num_hosts: int, timeout_s: float = 60.0,
                 clock: Callable[[], float] = time.monotonic):
        self.last = {h: clock() for h in range(num_hosts)}
        self.timeout = timeout_s
        self.clock = clock

    def beat(self, host: int):
        self.last[host] = self.clock()

    def dead(self) -> list[int]:
        now = self.clock()
        return [h for h, t in self.last.items()
                if now - t > self.timeout]


@dataclasses.dataclass
class ResilienceConfig:
    checkpoint_every: int = 50
    max_restarts: int = 3
    straggler_threshold: float = 2.0


def run_resilient(step_fn, state, num_steps: int, ckpt_manager,
                  batch_fn, start_step: int = 0,
                  config: ResilienceConfig = ResilienceConfig(),
                  fault_hook=None, on_restore=None):
    """Drive `state = step_fn(state, batch)` with checkpoint/restart.

    ``fault_hook(step)`` may raise to inject a failure (tests).
    ``on_restore(step)`` -> (state, step) rebuilds state from the latest
    checkpoint (supplied by the trainer so it can re-mesh first).
    Returns (state, metrics_history, monitor).
    """
    monitor = StragglerMonitor(threshold=config.straggler_threshold)
    history = []
    restarts = 0
    step = start_step
    while step < num_steps:
        try:
            t0 = time.monotonic()
            if fault_hook is not None:
                fault_hook(step)
            batch = batch_fn(step)
            state, metrics = step_fn(state, batch)
            dt = time.monotonic() - t0
            monitor.observe(step, dt)
            history.append({"step": step, "time": dt, **jax_to_float(metrics)})
            step += 1
            if step % config.checkpoint_every == 0:
                ckpt_manager.save(step, state)
        except KeyboardInterrupt:
            raise
        except Exception:
            restarts += 1
            if restarts > config.max_restarts or on_restore is None:
                raise
            try:
                ckpt_manager.wait()  # drain any in-flight async save first
            except Exception:
                pass
            state, step = on_restore(step)
    ckpt_manager.save(num_steps, state, blocking=True)
    return state, history, monitor


def jax_to_float(metrics: dict) -> dict:
    out = {}
    for k, v in metrics.items():
        try:
            out[k] = float(v)
        except Exception:
            pass
    return out
