"""Append-only request journal: the write-ahead log of the serving loop.

The paper's embedded deployments (ZYNQ-class hosts) treat resets, power
loss, and watchdog reboots as *routine* operating conditions — so the
serve loop must be able to die at any decode step and come back without
losing a request or emitting a duplicate token.  The durability story has
two halves (docs/ROBUSTNESS.md, "Crash recovery"):

* this module — a **journal**: an append-only JSONL log of lifecycle
  transitions and emitted tokens, written *before* the corresponding
  effect becomes externally visible (write-ahead discipline).  After a
  crash, the journal is the authoritative record of what the outside
  world may already have seen.
* `runtime.snapshot` — periodic atomic **snapshots** of the full server
  state, which bound how much journal tail a recovery has to replay.

Record kinds (every record also carries a monotonically increasing
``seq`` stamped by the writer):

``submit``      rid, prompt (token ids), gen_len, deadlines — enough to
                re-prefill the request from nothing on recovery.
``state``       rid, state, step — one per lifecycle transition.
``token``       rid, i (index into the request's token list), tok, step —
                one per emitted token, written before the token is
                appended to the request record (the externally visible
                effect).
``snapshot``    step, path — a commit marker for a snapshot that covers
                every record with smaller ``seq``.

Crash tolerance of the log itself: a process dying mid-append leaves a
*partial final line* (no trailing newline, or truncated JSON).  The
reader treats exactly that — a malformed **final** line — as the crash
signature and drops it (the write never "happened": its effect was not
yet visible).  A malformed line anywhere *else* is corruption, not a
crash, and raises :class:`JournalError` with the line number and payload.

Like `runtime.faults` and `runtime.loadgen`, this module is
numpy+stdlib only and never imports jax.
"""

from __future__ import annotations

import json
import os
import pathlib

import numpy as np

RECORD_KINDS = ("submit", "state", "token", "snapshot")


class JournalError(RuntimeError):
    """Corrupt journal interior — not the partial-final-line crash
    signature, which the reader absorbs silently."""


def _jsonable(v):
    if isinstance(v, np.ndarray):
        return [int(x) for x in v.tolist()]
    if isinstance(v, (np.integer,)):
        return int(v)
    if isinstance(v, (np.floating,)):
        return float(v)
    return v


class Journal:
    """Append-only JSONL writer with atomic, durable appends.

    Each :meth:`append` writes one complete line and flushes it to the OS
    (plus ``fsync`` unless ``durable=False`` — tests that append thousands
    of records can opt out; the serve loop keeps the default).  A line is
    the atomicity unit: the reader discards a torn final line, so a crash
    mid-append loses only the record being written — whose effect, by the
    write-ahead discipline, was not yet externally visible.
    """

    def __init__(self, path, *, durable: bool = True):
        self.path = pathlib.Path(path)
        self.durable = durable
        self.path.parent.mkdir(parents=True, exist_ok=True)
        self.seq = 0
        if self.path.exists():
            # Resume appending after existing committed records; a torn
            # final line is truncated away so the next append starts on a
            # clean line boundary.
            records, torn = read_journal(self.path, return_torn=True)
            self.seq = (records[-1]["seq"] + 1) if records else 0
            if torn is not None:
                good = "".join(json.dumps(r, sort_keys=True) + "\n"
                               for r in records)
                self.path.write_text(good)
        self._f = open(self.path, "a")

    def append(self, kind: str, **fields) -> dict:
        if kind not in RECORD_KINDS:
            raise ValueError(f"unknown journal record kind {kind!r}")
        rec = {"kind": kind, "seq": self.seq,
               **{k: _jsonable(v) for k, v in fields.items()}}
        self._f.write(json.dumps(rec, sort_keys=True) + "\n")
        self._f.flush()
        if self.durable:
            os.fsync(self._f.fileno())
        self.seq += 1
        return rec

    # -- convenience wrappers (the serve loop's write-ahead points) --------

    def submit(self, rid: int, prompt, gen_len: int, *,
               ttft_deadline_s=None, deadline_s=None) -> dict:
        return self.append("submit", rid=rid, prompt=np.asarray(prompt),
                           gen_len=gen_len, ttft_deadline_s=ttft_deadline_s,
                           deadline_s=deadline_s)

    def state(self, rid: int, state: str, step: int, *, retries: int = 0,
              not_before_step: int | None = None) -> dict:
        extra = ({} if not_before_step is None
                 else {"not_before_step": not_before_step})
        return self.append("state", rid=rid, state=state, step=step,
                           retries=retries, **extra)

    def token(self, rid: int, i: int, tok: int, step: int) -> dict:
        return self.append("token", rid=rid, i=i, tok=tok, step=step)

    def snapshot(self, step: int, path: str) -> dict:
        return self.append("snapshot", step=step, path=str(path))

    def close(self) -> None:
        if not self._f.closed:
            self._f.flush()
            if self.durable:
                os.fsync(self._f.fileno())
            self._f.close()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()


def read_journal(path, *, return_torn: bool = False):
    """Read every committed record of a journal, tolerating the
    crash-truncation signature.

    Returns the record list, or ``(records, torn)`` with
    ``return_torn=True`` where ``torn`` is the dropped partial final line
    (None for a clean log).  Raises :class:`JournalError` — with line
    number and offending payload — for a malformed line that is *not* the
    final one, or for records whose ``seq`` is missing or out of order
    (interior truncation: records were lost, not merely torn).
    """
    path = pathlib.Path(path)
    raw = path.read_text() if path.exists() else ""
    lines = raw.split("\n")
    # split() leaves a trailing "" when the file ends in a newline — the
    # clean-shutdown shape.  A non-empty last element = no trailing
    # newline = a torn final append.
    torn = lines.pop() if lines and lines[-1] != "" else None
    records: list[dict] = []
    expect = None
    for ln, line in enumerate(lines, start=1):
        if not line.strip():
            continue
        try:
            rec = json.loads(line)
        except ValueError as e:
            raise JournalError(
                f"{path}:{ln}: corrupt journal line (not the torn-final-"
                f"line crash signature): {e}; payload: {line[:200]!r}"
            ) from None
        if not isinstance(rec, dict) or not isinstance(rec.get("seq"), int):
            raise JournalError(
                f"{path}:{ln}: journal record without integer 'seq': "
                f"{line[:200]!r}")
        if expect is not None and rec["seq"] != expect:
            raise JournalError(
                f"{path}:{ln}: journal seq jumped {expect} -> "
                f"{rec['seq']} — interior records lost")
        expect = rec["seq"] + 1
        records.append(rec)
    if torn is not None:
        try:
            rec = json.loads(torn)
            # parseable but newline-less: the crash hit between the
            # payload and the newline — still a torn append; keep it,
            # it is complete.
            if isinstance(rec, dict) and isinstance(rec.get("seq"), int) \
                    and (expect is None or rec["seq"] == expect):
                records.append(rec)
                torn = None
        except ValueError:
            pass        # genuinely truncated JSON: drop it
    return (records, torn) if return_torn else records


def replay(records: list[dict]) -> dict:
    """Fold a journal into per-request recovery state.

    Returns ``{rid: {"prompt": [...], "gen_len": int, "state": str,
    "retries": int, "tokens": [...], "last_step": int,
    "ttft_deadline_s": ..., "deadline_s": ...}}`` — the view `serve
    --resume` rebuilds the lifecycle and in-flight slots from.  Token
    records are applied by index (``i``), so a re-emitted token after an
    eviction (retries discard partial output) overwrites instead of
    duplicating.
    """
    reqs: dict[int, dict] = {}
    for rec in records:
        kind = rec["kind"]
        if kind == "submit":
            reqs[rec["rid"]] = {
                "prompt": list(rec["prompt"]), "gen_len": rec["gen_len"],
                "state": "queued", "retries": 0, "tokens": [],
                "last_step": 0, "not_before_step": 0,
                "ttft_deadline_s": rec.get("ttft_deadline_s"),
                "deadline_s": rec.get("deadline_s"),
            }
        elif kind == "state":
            r = reqs.get(rec["rid"])
            if r is None:
                raise JournalError(
                    f"state record for unknown rid {rec['rid']} "
                    f"(seq {rec['seq']}) — journal tail without its head")
            r["state"] = rec["state"]
            r["retries"] = rec.get("retries", r["retries"])
            r["last_step"] = rec["step"]
            if rec["state"] == "queued":
                r["not_before_step"] = rec.get("not_before_step", 0)
                if r["tokens"]:
                    r["tokens"] = []  # eviction requeue discards output
        elif kind == "token":
            r = reqs.get(rec["rid"])
            if r is None:
                raise JournalError(
                    f"token record for unknown rid {rec['rid']} "
                    f"(seq {rec['seq']})")
            i = rec["i"]
            del r["tokens"][i:]
            if i != len(r["tokens"]):
                raise JournalError(
                    f"token index gap for rid {rec['rid']}: got i={i}, "
                    f"have {len(r['tokens'])} tokens (seq {rec['seq']})")
            r["tokens"].append(rec["tok"])
            r["last_step"] = rec["step"]
    return reqs
