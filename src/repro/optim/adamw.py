"""In-house AdamW with large-scale memory tricks.

- global-norm gradient clipping
- linear-warmup + cosine decay schedule
- optional **blockwise int8 moment quantization** (needed to fit 398B-param
  optimizer state in 16 GB/chip HBM — see DESIGN.md §Risks): moments are
  stored as int8 with one f32 scale per 128-wide block of the last dim,
  dequantized/requantized around each update.
- ZeRO-1-style state sharding happens at the sharding-spec level (see
  `launch.specs.zero_shard`): moment leaves get an extra DP-axis shard on
  top of the parameter's TP sharding.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

Pytree = Any
QBLOCK = 128


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    peak_lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    moment_dtype: str = "float32"      # "float32" | "int8"


def schedule(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    step = step.astype(jnp.float32)
    warm = step / jnp.maximum(cfg.warmup_steps, 1)
    decay_steps = jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1)
    frac = jnp.clip((step - cfg.warmup_steps) / decay_steps, 0.0, 1.0)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * frac))
    mult = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * cos
    return cfg.peak_lr * jnp.where(step < cfg.warmup_steps, warm, mult)


# ---------------------------------------------------------------------------
# Blockwise int8 moment quantization
# ---------------------------------------------------------------------------

def _pad_to_block(x: jax.Array):
    last = x.shape[-1]
    pad = (-last) % QBLOCK
    if pad:
        x = jnp.pad(x, [(0, 0)] * (x.ndim - 1) + [(0, pad)])
    return x, last


def quantize_blockwise(x: jax.Array) -> dict:
    """f32 -> {q: int8 (padded last dim), scale: f32 per 128-block}.

    The original last-dim size is NOT stored (it would be a static leaf in a
    traced pytree); `dequantize_blockwise` takes it from the caller.
    """
    xp, _ = _pad_to_block(x.astype(jnp.float32))
    blocks = xp.reshape(*xp.shape[:-1], -1, QBLOCK)
    scale = jnp.max(jnp.abs(blocks), axis=-1) / 127.0
    q = jnp.round(blocks / jnp.maximum(scale[..., None], 1e-12))
    q = jnp.clip(q, -127, 127).astype(jnp.int8)
    return {"q": q.reshape(xp.shape), "scale": scale}


def dequantize_blockwise(packed: dict, orig_last: int) -> jax.Array:
    q = packed["q"].astype(jnp.float32)
    blocks = q.reshape(*q.shape[:-1], -1, QBLOCK)
    x = blocks * packed["scale"][..., None]
    x = x.reshape(q.shape)
    return x[..., :orig_last]


def _moment_zeros(p: jax.Array, moment_dtype: str):
    if moment_dtype == "int8":
        return quantize_blockwise(jnp.zeros(p.shape, jnp.float32))
    return jnp.zeros(p.shape, jnp.float32)


def _moment_read(m, moment_dtype: str, orig_last: int) -> jax.Array:
    return dequantize_blockwise(m, orig_last) if moment_dtype == "int8" else m


def _moment_write(x: jax.Array, moment_dtype: str):
    return quantize_blockwise(x) if moment_dtype == "int8" else x


# ---------------------------------------------------------------------------
# State / update
# ---------------------------------------------------------------------------

def init_state(params: Pytree, cfg: AdamWConfig) -> dict:
    return {
        "step": jnp.zeros((), jnp.int32),
        "m": jax.tree.map(lambda p: _moment_zeros(p, cfg.moment_dtype), params),
        "v": jax.tree.map(lambda p: _moment_zeros(p, cfg.moment_dtype), params),
    }


def global_norm(tree: Pytree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def _is_moment_leaf(x) -> bool:
    return isinstance(x, dict) and set(x) == {"q", "scale"}


def update(params: Pytree, grads: Pytree, opt_state: dict,
           cfg: AdamWConfig) -> tuple[Pytree, dict, dict]:
    """One AdamW step.  Returns (new_params, new_opt_state, metrics)."""
    step = opt_state["step"] + 1
    lr = schedule(cfg, step)

    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-12))
    b1, b2 = cfg.b1, cfg.b2
    c1 = 1 - b1 ** step.astype(jnp.float32)
    c2 = 1 - b2 ** step.astype(jnp.float32)

    def leaf_update(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m_f = _moment_read(m, cfg.moment_dtype, p.shape[-1])
        v_f = _moment_read(v, cfg.moment_dtype, p.shape[-1])
        m_f = b1 * m_f + (1 - b1) * g
        v_f = b2 * v_f + (1 - b2) * jnp.square(g)
        upd = (m_f / c1) / (jnp.sqrt(v_f / c2) + cfg.eps)
        p_f = p.astype(jnp.float32)
        new_p = p_f - lr * (upd + cfg.weight_decay * p_f)
        return (new_p.astype(p.dtype),
                _moment_write(m_f, cfg.moment_dtype),
                _moment_write(v_f, cfg.moment_dtype))

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = treedef.flatten_up_to(opt_state["m"])
    flat_v = treedef.flatten_up_to(opt_state["v"])
    out = [leaf_update(p, g, m, v)
           for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_params = jax.tree.unflatten(treedef, [o[0] for o in out])
    new_m = jax.tree.unflatten(treedef, [o[1] for o in out])
    new_v = jax.tree.unflatten(treedef, [o[2] for o in out])
    new_state = {"step": step, "m": new_m, "v": new_v}
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_params, new_state, metrics
