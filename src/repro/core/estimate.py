"""Analytical corrections for XLA cost-analysis blind spots.

XLA's `cost_analysis()` counts a while-loop body ONCE regardless of trip
count (verified empirically — see EXPERIMENTS.md §Dry-run).  The dry-run
therefore compiles *unrolled differential probes* (1- and 2-layer versions at
full input shape) and extrapolates per-layer costs linearly — exact for
everything expressed as unrolled HLO.

The only compute still hidden inside loops after unrolling the layer stack is
the per-timestep *recurrence interior* of Mamba / RWKV sequence scans (their
projections/convs are full-sequence matmuls outside the scan and are counted
by the probes).  This module supplies closed-form corrections for those
interiors; they are elementwise-dominated and small relative to matmul work,
but skipping them would bias SSM/hybrid rooflines low.
"""

from __future__ import annotations

from repro.models.config import ModelConfig


def _bwd_factor(kind: str, remat: str) -> float:
    """fwd=1; backward ~2x fwd; full remat recomputes fwd once more."""
    if kind != "train":
        return 1.0
    return 4.0 if remat == "full" else 3.0


def mamba_recurrence_per_token(cfg: ModelConfig) -> tuple[float, float]:
    """(flops, hbm_bytes) per token per mamba layer, forward."""
    d_in = cfg.ssm_expand * cfg.d_model
    n = cfg.ssm_state
    flops = 7.0 * d_in * n            # exp(dA), h update, y contraction
    # streamed per step: delta/x (d_in), B/C (2n), y out (d_in) at f32;
    # the carried state h stays VMEM-resident on TPU.
    bytes_ = (2 * d_in + 2 * n + d_in) * 4.0
    return flops, bytes_


def rwkv_recurrence_per_token(cfg: ModelConfig) -> tuple[float, float]:
    """(flops, hbm_bytes) per token per rwkv layer, forward."""
    d = cfg.d_model
    dh = cfg.rwkv_head_dim
    flops = 5.0 * d * dh              # kv outer, bonus read, state decay+add
    bytes_ = 5 * d * 4.0              # r,k,v,w streams + y out (f32)
    return flops, bytes_


def recurrence_correction(cfg: ModelConfig, tokens: float,
                          kind: str) -> tuple[float, float]:
    """Total (flops, bytes) hidden in seq-scan interiors for one step call."""
    factor = _bwd_factor(kind, cfg.remat)
    flops = bytes_ = 0.0
    if cfg.family == "ssm":
        f, b = rwkv_recurrence_per_token(cfg)
        flops += f * tokens * cfg.num_layers
        bytes_ += b * tokens * cfg.num_layers
    elif cfg.family == "hybrid":
        n_mamba = sum(1 for l in range(cfg.num_layers)
                      if not cfg.is_attn_layer(l))
        f, b = mamba_recurrence_per_token(cfg)
        flops += f * tokens * n_mamba
        bytes_ += b * tokens * n_mamba
    return flops * factor, bytes_ * factor


# ---------------------------------------------------------------------------
# Analytical HBM-traffic model (the memory roofline term)
# ---------------------------------------------------------------------------
# XLA's CPU-compiled `bytes accessed` reflects CPU fusion, which materializes
# intermediates a TPU compilation (and our Pallas kernels: flash attention,
# fused xent) keeps in VMEM.  The roofline's memory term therefore uses this
# closed-form model of the *deployed TPU path*, with the probe-measured HLO
# bytes recorded alongside as a (CPU-fusion-pessimistic) upper bound.
# Accounting notes are inline; constants are deliberately conservative.

def _layer_counts(cfg: ModelConfig):
    n_attn = sum(1 for l in range(cfg.num_layers) if cfg.is_attn_layer(l))
    n_moe = sum(1 for l in range(cfg.num_layers) if cfg.is_moe_layer(l))
    if cfg.family == "ssm":
        n_attn = 0
    n_mamba = (cfg.num_layers - n_attn) if cfg.family == "hybrid" else 0
    return n_attn, n_mamba, n_moe


def bytes_model(cfg: ModelConfig, *, batch: int, seq: int, kind: str,
                param_bytes: int, moment_bytes: float = 4.0,
                cache_len: int = 0, flash_block_q: int = 512,
                loss_fused_kernel: bool = False) -> dict:
    """Whole-cluster HBM bytes for one step.  Returns a breakdown dict."""
    p = cfg.param_count()
    d, v = cfg.d_model, cfg.vocab_size
    tokens = batch * seq
    act = 2.0  # bf16 activations
    n_attn, n_mamba, n_moe = _layer_counts(cfg)
    l = cfg.num_layers
    out: dict = {}

    if kind == "train":
        # params: fwd read + bwd read (+1 remat re-read); grad write+read;
        # opt: param read+write, 2 moments read+write.
        reads = 3 if cfg.remat == "full" else 2
        out["params"] = p * param_bytes * (reads + 2 + 2) \
            + p * moment_bytes * 4
        # activations: save layer input (write+read) + ~8 intermediate
        # streams per layer during fwd/recompute/bwd.
        out["activations"] = l * tokens * d * act * 10
        # flash attention: K+V re-read once per q block (+bwd ~2x).
        window = cfg.sliding_window or seq
        kv_len = min(seq, window)
        kv_bytes = kv_len * cfg.num_kv_heads * cfg.head_dim * 2 * act
        out["attention_kv"] = n_attn * batch * (seq / flash_block_q) \
            * kv_bytes * 3
        # fused-xent: chunk logits write + lse read + bwd recompute ~3
        # accesses (0 with the Pallas xent kernel, which keeps them in VMEM).
        out["loss"] = 0.0 if loss_fused_kernel else tokens * v * 4.0 * 3
        out["embed"] = tokens * d * param_bytes * 3
        # MoE buffers: dispatch gather + expert in/out + combine scatter.
        if n_moe:
            out["moe_buffers"] = n_moe * tokens * cfg.top_k * d * act * 6
    elif kind == "prefill":
        out["params"] = p * param_bytes
        out["activations"] = l * tokens * d * act * 6
        window = cfg.sliding_window or seq
        kv_len = min(seq, window)
        kv_bytes = kv_len * cfg.num_kv_heads * cfg.head_dim * 2 * act
        out["attention_kv"] = n_attn * batch * (seq / flash_block_q) \
            * kv_bytes
        out["loss"] = batch * v * 4.0
        out["embed"] = tokens * d * param_bytes
        if n_moe:
            out["moe_buffers"] = n_moe * tokens * cfg.top_k * d * act * 3
    else:  # decode: one token per sequence, full cache read
        out["params"] = cfg.active_param_count() * param_bytes
        window = cfg.sliding_window or cache_len
        kv_len = min(cache_len, window)
        kv_bytes = kv_len * cfg.num_kv_heads * cfg.head_dim * 2 * act
        out["attention_kv"] = n_attn * batch * kv_bytes
        # ssm/rwkv states: read+write per layer
        if cfg.family == "ssm":
            dh = cfg.rwkv_head_dim
            out["state"] = l * batch * d * dh * 4.0 * 2
        elif cfg.family == "hybrid":
            d_in = cfg.ssm_expand * d
            out["state"] = n_mamba * batch * d_in * cfg.ssm_state * 4.0 * 2
        out["activations"] = l * batch * d * act * 8
        out["loss"] = batch * v * 4.0
    out["total"] = float(sum(out.values()))
    return out
