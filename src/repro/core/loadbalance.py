"""Work balancing — the paper's SpMV scheduling law, generalized.

Section V-B assigns sparse-matrix rows to cores *round-robin by row index* and
shows the nnz per core converges to ~1/p of the total.  We implement that law
plus an LPT (longest-processing-time greedy) alternative, and reuse the same
machinery for MoE expert dispatch: tokens are the nonzeros, experts are the
cores, and the balance statistic is the paper's "percentage of total nnz".
"""

from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class BalanceStats:
    per_worker: np.ndarray          # total weight per worker
    imbalance: float                # max/mean - 1  (0 == perfect)
    max_fraction: float             # heaviest worker's share of total

    @classmethod
    def of(cls, per_worker: np.ndarray) -> "BalanceStats":
        per_worker = np.asarray(per_worker, dtype=np.float64)
        total = per_worker.sum()
        mean = total / per_worker.size if per_worker.size else 0.0
        imb = float(per_worker.max() / mean - 1.0) if mean > 0 else 0.0
        frac = float(per_worker.max() / total) if total > 0 else 0.0
        return cls(per_worker, imb, frac)


def round_robin(weights: np.ndarray, p: int) -> np.ndarray:
    """Paper's scheme: item i -> worker i mod p.  Returns assignment array."""
    n = len(weights)
    return np.arange(n, dtype=np.int32) % p


def lpt(weights: np.ndarray, p: int) -> np.ndarray:
    """Greedy longest-processing-time: heaviest item to the lightest worker."""
    weights = np.asarray(weights)
    order = np.argsort(-weights, kind="stable")
    loads = np.zeros(p, dtype=np.float64)
    assign = np.empty(len(weights), dtype=np.int32)
    for i in order:
        w = int(np.argmin(loads))
        assign[i] = w
        loads[w] += float(weights[i])
    return assign


def stats_for(assign: np.ndarray, weights: np.ndarray, p: int) -> BalanceStats:
    per_worker = np.zeros(p, dtype=np.float64)
    np.add.at(per_worker, assign, np.asarray(weights, dtype=np.float64))
    return BalanceStats.of(per_worker)


def nnz_balanced_row_order(indptr: np.ndarray, p: int, scheme: str = "round_robin"):
    """Partition CSR rows across p workers, balanced by nnz.

    Returns (assign, stats).  ``indptr`` is the CSR row-pointer array; row i
    has ``indptr[i+1]-indptr[i]`` nonzeros.  This is the exact object the
    paper measures in Table II ("percentage of nonzeros assigned to each
    processor ... around 25% for each of 4 processors").
    """
    nnz_per_row = np.diff(indptr)
    if scheme == "round_robin":
        assign = round_robin(nnz_per_row, p)
    elif scheme == "lpt":
        assign = lpt(nnz_per_row, p)
    else:
        raise ValueError(f"unknown scheme {scheme!r}")
    return assign, stats_for(assign, nnz_per_row, p)


def expert_capacity(num_tokens: int, num_experts: int, top_k: int,
                    capacity_factor: float = 1.25, align: int = 8) -> int:
    """MoE per-expert capacity with the paper's balance assumption.

    Round-robin/near-uniform routing implies each expert sees about
    ``tokens*k/E``; the capacity factor absorbs residual imbalance exactly as
    the paper's round-robin absorbs nnz skew.
    """
    cap = int(np.ceil(num_tokens * top_k / num_experts * capacity_factor))
    return max(align, ((cap + align - 1) // align) * align)
