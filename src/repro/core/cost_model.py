"""Three-term analytical roofline — the dry-run replacement for the paper's
SystemC cycle simulation.

The paper evaluates every candidate many-core configuration by simulating the
generated SystemC model to get a cycle count.  On a fixed TPU target the same
role is played by an analytical machine model evaluated on the *compiled*
program:

    compute   = HLO_FLOPs            / (chips * peak_FLOP/s)
    memory    = HLO_bytes            / (chips * HBM_bw)
    collective= collective_bytes     / (chips * ICI_link_bw)

The dominant term is the bottleneck the perf loop iterates on.
"""

from __future__ import annotations

import dataclasses
from typing import Sequence

from repro.core import hardware


@dataclasses.dataclass(frozen=True)
class Roofline:
    compute_s: float
    memory_s: float
    collective_s: float
    chips: int
    flops: float
    bytes_accessed: float
    collective_bytes: float
    model_flops: float = 0.0  # 6*N*D useful flops, if known

    @property
    def dominant(self) -> str:
        terms = {
            "compute": self.compute_s,
            "memory": self.memory_s,
            "collective": self.collective_s,
        }
        return max(terms, key=terms.get)

    @property
    def bound_s(self) -> float:
        """Roofline-model step time: the max of the three terms."""
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def useful_fraction(self) -> float:
        """MODEL_FLOPS / HLO_FLOPs — how much compiled compute is useful."""
        return self.model_flops / self.flops if self.flops else 0.0

    @property
    def mfu_bound(self) -> float:
        """Upper bound on MFU implied by the roofline (useful flops / peak)."""
        if self.bound_s <= 0:
            return 0.0
        peak = self.chips * hardware.TPU_V5E.peak_flops
        return (self.model_flops / self.bound_s) / peak if self.model_flops else 0.0

    def row(self) -> dict:
        return {
            "compute_s": self.compute_s,
            "memory_s": self.memory_s,
            "collective_s": self.collective_s,
            "dominant": self.dominant,
            "flops": self.flops,
            "bytes": self.bytes_accessed,
            "collective_bytes": self.collective_bytes,
            "model_flops": self.model_flops,
            "useful_fraction": self.useful_fraction,
            "mfu_bound": self.mfu_bound,
        }


def roofline(
    flops: float,
    bytes_accessed: float,
    collective_bytes: float,
    chips: int,
    model_flops: float = 0.0,
    chip: hardware.Chip = hardware.TPU_V5E,
) -> Roofline:
    """Build the three-term roofline for a compiled step.

    ``flops``/``bytes_accessed`` from ``cost_analysis()`` are whole-program
    (all chips); collective_bytes likewise is the summed operand traffic.
    """
    return Roofline(
        compute_s=flops / (chips * chip.peak_flops),
        memory_s=bytes_accessed / (chips * chip.hbm_bw),
        collective_s=collective_bytes / (chips * chip.ici_bw_per_link),
        chips=chips,
        flops=flops,
        bytes_accessed=bytes_accessed,
        collective_bytes=collective_bytes,
        model_flops=model_flops,
    )


def model_flops_train(n_params_active: float, tokens: float) -> float:
    """6*N*D rule of thumb for a train step (fwd + bwd)."""
    return 6.0 * n_params_active * tokens


def model_flops_decode(n_params_active: float, tokens: float) -> float:
    """2*N per generated token (forward only)."""
    return 2.0 * n_params_active * tokens


def matmul_time_model(
    m: int, n: int, k: int, tile, chip: hardware.Chip = hardware.TPU_V5E,
    dtype_bytes: int = 2, p: int = 1,
) -> dict:
    """Analytical cycle-model for the paper's Table-I style evaluation.

    Returns compute-bound and memory-bound times plus the 'efficiency' the
    paper reports (peak/measured) under the machine model: the run time is
    max(compute, traffic) assuming perfect overlap (their double-buffering).
    """
    from repro.core import tiling as _tiling

    flops = 2.0 * m * n * k
    traffic_elems = _tiling.comm_volume_rect(m, n, k, tile, p=p)
    compute_s = flops / chip.peak_flops
    memory_s = traffic_elems * dtype_bytes / chip.hbm_bw
    total_s = max(compute_s, memory_s)
    return {
        "flops": flops,
        "traffic_bytes": traffic_elems * dtype_bytes,
        "compute_s": compute_s,
        "memory_s": memory_s,
        "time_s": total_s,
        "efficiency": compute_s / total_s,
        "gflops": flops / total_s / 1e9,
    }


def attention_step_bounds(
    i: int, block_q: int, block_k: int, k_steps: int,
    causal: bool = True, window: int | None = None,
) -> tuple[int, int]:
    """[first, last] K-step bounds for q-block ``i`` under the causal /
    sliding-window mask — the block-level skip law shared by the kernel
    (grid sizing + in-kernel guards) and the cost model (skip credit).

    A K-block j is *active* iff some (q, k) pair inside the
    (block_q, block_k) tile survives the mask: causal caps ``last`` at the
    block holding the deepest row's diagonal, the window floors ``first``
    at the block still inside the band of the shallowest row.
    """
    q_lo, q_hi = i * block_q, (i + 1) * block_q - 1
    last = k_steps - 1
    if causal:
        last = min(last, q_hi // block_k)
    first = 0
    if window is not None:
        # active iff the block's deepest k reaches past q_lo - window
        first = max(0, (q_lo - window + 1) // block_k)
    return min(first, last), last


def attention_active_block_pairs(
    sq: int, sk: int, block_q: int, block_k: int,
    causal: bool = True, window: int | None = None,
) -> tuple[int, int]:
    """(active, total) (q_block, k_block) pair counts for the mask — the
    fetched-vs-active accounting of the block-skipping flash kernel.
    ``total`` is the dense grid the non-skipping kernel executes; the
    skipping kernel streams and multiplies only ``active`` pairs
    (causal ≈ triangle, window ≈ band)."""
    q_blocks = max(1, -(-sq // block_q))
    k_steps = max(1, -(-sk // block_k))
    active = 0
    for i in range(q_blocks):
        first, last = attention_step_bounds(i, block_q, block_k, k_steps,
                                            causal=causal, window=window)
        active += last - first + 1
    return active, q_blocks * k_steps


def attention_max_k_steps(
    sq: int, sk: int, block_q: int, block_k: int,
    causal: bool = True, window: int | None = None,
) -> int:
    """Tightest static grid depth over the K axis: the widest per-q-block
    active range.  Causal prefill at sq=sk keeps the full depth (the last
    row needs every block); a sliding window shrinks it to ~window/block_k."""
    q_blocks = max(1, -(-sq // block_q))
    k_steps = max(1, -(-sk // block_k))
    widest = 1
    for i in range(q_blocks):
        first, last = attention_step_bounds(i, block_q, block_k, k_steps,
                                            causal=causal, window=window)
        widest = max(widest, last - first + 1)
    return widest


def attention_time_model(
    bh: int, sq: int, sk: int, dh: int,
    block_q: int, block_k: int,
    causal: bool = True,
    window: int | None = None,
    chip: hardware.Chip = hardware.TPU_V5E,
    dtype_bytes: int = 2,
    block_skipping: bool = True,
) -> dict:
    """Roofline model of the flash-attention forward kernel for the tuner's
    candidate ranking — the communication-avoiding analysis of the
    (block_q, block_k) tile space.

    Kernel shape (kernels/attention/kernel.py): grid (bh, sq/bq, K-depth),
    Q/O blocks revisit across the k axis so Q is fetched and O written once,
    while each q-row-block streams its *active* K/V blocks
    (`attention_active_block_pairs`):

        traffic = 2*bh*sq*dh  +  2*bh*active*block_k*dh
        flops   = 4*bh*active*block_q*block_k*dh

    Dense (no mask, or ``block_skipping=False``) this reduces to the old
    every-block accounting: active = ceil(sq/bq) * ceil(sk/bk).  With the
    causal mask the active set is the block triangle (~half the traffic and
    FLOPs at sq=sk); a sliding window keeps only the block band.  K/V
    re-streaming still falls as block_q grows (the matmul eq.2 story), but
    coarser q-blocks also cover more masked area — the model now prices
    that tension instead of ignoring the mask.

    VMEM: double-buffered Q/K/V input blocks + the O block, the f32 online-
    softmax scratch (m, l: block_q x 1; acc: block_q x dh), and the f32
    logits/probs intermediates (block_q x block_k each).
    """
    if block_skipping:
        active, total = attention_active_block_pairs(
            sq, sk, block_q, block_k, causal=causal, window=window)
    else:
        q_blocks = max(1, -(-sq // block_q))
        k_steps = max(1, -(-sk // block_k))
        active = total = q_blocks * k_steps
    flops = 4.0 * bh * active * block_q * block_k * dh   # QK^T + PV
    qo_bytes = 2.0 * bh * sq * dh * dtype_bytes
    kv_bytes = 2.0 * bh * active * block_k * dh * dtype_bytes
    memory_s = (qo_bytes + kv_bytes) / chip.hbm_bw
    compute_s = flops / chip.peak_flops
    total_s = max(compute_s, memory_s)
    vmem_bytes = (
        2 * (block_q + 2 * block_k) * dh * dtype_bytes   # double-buffered in
        + block_q * dh * dtype_bytes                     # O block
        + (2 * block_q + block_q * dh) * 4               # m, l, acc scratch
        + 2 * block_q * block_k * 4                      # s, p intermediates
    )
    return {
        "flops": flops,
        "traffic_bytes": qo_bytes + kv_bytes,
        "vmem_bytes": vmem_bytes,
        "compute_s": compute_s,
        "memory_s": memory_s,
        "time_s": total_s,
        "gflops": flops / total_s / 1e9,
        "causal": causal,
        "window": window,
        "active_block_pairs": active,
        "total_block_pairs": total,
        "skip_fraction": 1.0 - active / total if total else 0.0,
    }


def decode_time_model(
    bkv: int, g: int, kv_len: int, dh: int,
    block_k: int,
    chip: hardware.Chip = hardware.TPU_V5E,
    dtype_bytes: int = 2,
    lengths: Sequence[int] | None = None,
) -> dict:
    """Bandwidth model of the fused decode-attention kernel
    (kernels/attention/decode.py) for the tuner's candidate ranking.

    One generated token attends over the KV cache: ``bkv = batch*kv_heads``
    folded rows, each carrying its ``g = heads/kv_heads`` GQA query group
    as the q-row axis.  The kernel streams each row's *own* block-rounded
    valid prefix — the decode hot loop's memory floor — and ``waste`` is
    the same fetched-vs-active metric the SpMV load-balance model charges:
    a coarse block_k over-fetches the ragged tail, a fine one adds grid
    steps for free traffic.

    ``lengths`` (optional) is the per-sequence valid-prefix distribution of
    a ragged continuous batch; ``bkv`` must be a multiple of its size (the
    per-KV-head fold repeats each sequence's length).  Each row is charged
    ceil(len_i/block_k) blocks, clamped to the allocated ``kv_len`` — the
    active-prefix accounting, not the batch max.  ``lengths=None`` is the
    shared-scalar broadcast: every row pays the full ``kv_len``.
    """
    if lengths is not None:
        if not lengths or bkv % len(lengths):
            raise ValueError(
                f"bkv={bkv} must be a positive multiple of "
                f"len(lengths)={len(lengths)}")
        rep = bkv // len(lengths)
        clamped = [min(max(int(l), 0), kv_len) for l in lengths]
        # The kernel always executes block 0 even for an idle slot.
        row_steps = [max(1, -(-l // block_k)) for l in clamped]
        fetched_total = rep * sum(s * block_k for s in row_steps)
        active_total = rep * sum(max(l, 1) for l in clamped)
        fetched = fetched_total / bkv        # mean per-row stream
        active = active_total / bkv
    else:
        k_steps = max(1, -(-max(kv_len, 1) // block_k))
        fetched = k_steps * block_k          # block-rounded cache stream
        fetched_total = bkv * fetched
        active = min(kv_len, fetched)
        active_total = bkv * max(kv_len, 1)
    kv_bytes = 2.0 * fetched_total * dh * dtype_bytes
    qo_bytes = 2.0 * bkv * g * dh * dtype_bytes
    flops = 4.0 * g * fetched_total * dh     # qK^T + pV over fetched blocks
    memory_s = (kv_bytes + qo_bytes) / chip.hbm_bw
    compute_s = flops / chip.peak_flops
    total_s = max(compute_s, memory_s)
    vmem_bytes = (
        2 * 2 * block_k * dh * dtype_bytes   # double-buffered K/V blocks
        + 2 * g * dh * dtype_bytes           # q + o rows
        + (2 * g + g * dh) * 4               # m, l, acc scratch
        + 2 * g * block_k * 4                # s, p intermediates
    )
    return {
        "flops": flops,
        "traffic_bytes": kv_bytes + qo_bytes,
        "vmem_bytes": vmem_bytes,
        "compute_s": compute_s,
        "memory_s": memory_s,
        "time_s": total_s,
        "gflops": flops / total_s / 1e9,
        "fetched_k": fetched,
        "active_k": active,
        "waste": fetched_total / active_total,
    }


def quantized_decode_time_model(
    bkv: int, g: int, kv_len: int, dh: int,
    block_k: int,
    chip: hardware.Chip = hardware.TPU_V5E,
    lengths: Sequence[int] | None = None,
) -> dict:
    """Bandwidth model of the int8 quantized-streaming decode kernel
    (kernels/attention/decode_int8.py).

    Honest accounting relative to :func:`decode_time_model`: the K/V
    stream drops to 1 byte per element **plus** a 4-byte f32 scale per
    fetched token row per K and V (the scale stream is real traffic —
    ``dh + 4`` bytes per token per KV head each for K and V, which is why
    the win is ``2*dh / (dh + 4)``, not 2x), and the in-register dequant
    adds one multiply per fetched K/V element on top of the attention
    FLOPs.  For small ``dh`` or compute-bound regimes the model can and
    should lose to the bf16 stream — the DSE compares, it doesn't assume.
    """
    base = decode_time_model(bkv, g, kv_len, dh, block_k, chip=chip,
                             dtype_bytes=1, lengths=lengths)
    fetched_total = base["fetched_k"] * bkv
    # f32 scale per fetched token row, for each of K and V.
    scale_bytes = 2.0 * fetched_total * 4
    # q/o rows stay float (f32 here; decode_time_model charged them at
    # the 1-byte cache width, so re-charge at 4).
    qo_bytes = 2.0 * bkv * g * dh * 4
    kv_bytes = 2.0 * fetched_total * dh * 1
    # One dequant multiply per streamed K/V element.
    flops = base["flops"] + 2.0 * fetched_total * dh
    memory_s = (kv_bytes + scale_bytes + qo_bytes) / chip.hbm_bw
    compute_s = flops / chip.peak_flops
    total_s = max(compute_s, memory_s)
    # VMEM: int8 K/V blocks + f32 scale vectors + f32 q/o/scratch.
    vmem_bytes = (
        2 * 2 * block_k * dh * 1             # double-buffered int8 K/V
        + 2 * 2 * block_k * 4                # double-buffered scale rows
        + 2 * g * dh * 4                     # q + o rows (f32)
        + (2 * g + g * dh) * 4               # m, l, acc scratch
        + 2 * g * block_k * 4                # s, p intermediates
    )
    out = dict(base)
    out.update({
        "flops": flops,
        "traffic_bytes": kv_bytes + scale_bytes + qo_bytes,
        "scale_bytes": scale_bytes,
        "vmem_bytes": vmem_bytes,
        "compute_s": compute_s,
        "memory_s": memory_s,
        "time_s": total_s,
        "gflops": flops / total_s / 1e9,
        "bytes_per_token": 2 * (dh + 4),     # per token per KV head
    })
    return out


def spmv_time_model(
    rows: int, width: int, n: int, nnz: int,
    block_rows: int, block_cols: int | None = None,
    waste: float | None = None,
    chip: hardware.Chip = hardware.TPU_V5E,
    val_bytes: int = 4, idx_bytes: int = 4,
) -> dict:
    """Bandwidth model of the ELL SpMV kernel for the tuner's candidate
    ranking (the paper's Table-II evaluation, analytically).

    ``waste`` is the active/fetched balance metric from `core.loadbalance` /
    `EllMatrix.sliced_waste(block_rows)`: fetched nnz per active nnz under
    the current packing law at this block size.  When given, the ELL traffic
    is ``nnz * waste`` (the realizable sliced-ELL fetch volume); otherwise
    the dense (rows * width) ELL footprint is charged.

    ``block_cols=None`` models whole-x VMEM residency (x fetched once);
    an integer models the blocked-x kernel, where every row-block re-streams
    all ceil(n/block_cols) slabs of x.
    """
    fetched = nnz * waste if waste is not None else rows * width
    ell_bytes = fetched * (val_bytes + idx_bytes)
    row_blocks = max(1, -(-rows // block_rows))
    if block_cols is None:
        x_bytes = n * val_bytes                      # resident: fetched once
        vmem_bytes = n * val_bytes
    else:
        slabs = max(1, -(-n // block_cols))
        x_bytes = slabs * block_cols * val_bytes * row_blocks
        vmem_bytes = block_cols * val_bytes
    # Double-buffered cols+vals blocks alongside the x working set.
    vmem_bytes += 2 * block_rows * width * (val_bytes + idx_bytes)
    y_bytes = rows * val_bytes
    memory_s = (ell_bytes + x_bytes + y_bytes) / chip.hbm_bw
    flops = 2.0 * nnz
    compute_s = flops / chip.peak_flops
    total_s = max(compute_s, memory_s)
    return {
        "flops": flops,
        "traffic_bytes": ell_bytes + x_bytes + y_bytes,
        "vmem_bytes": vmem_bytes,
        "compute_s": compute_s,
        "memory_s": memory_s,
        "time_s": total_s,
        "gflops": flops / total_s / 1e9,
    }
