"""Core library: the paper's contribution as composable JAX-side modules.

- `hardware`  — target-machine constants (the fixed "FPGA" we generate for)
- `manycore`  — ManyCoreConfig: system-level parameter set -> concrete plan
- `tiling`    — eq.2 communication-minimizing tile solver (VMEM-adapted)
- `cost_model`— 3-term analytical roofline (the SystemC-simulation analogue)
- `hlo_stats` — compiled-HLO parser (FLOPs / bytes / per-collective bytes)
- `dse`       — automated design-space exploration over the parameter set
- `loadbalance` — round-robin / LPT nnz balancing (SpMV rows, MoE experts)
- `ioutil`    — atomic file writes (the repo-wide torn-write guard)
"""

from repro.core import (  # noqa: F401
    cost_model,
    dse,
    hardware,
    hlo_stats,
    ioutil,
    loadbalance,
    manycore,
    tiling,
)
