"""Hardware constants for the target machine (TPU v5e pod).

The paper parameterizes an FPGA (LUT/DSP/BRAM budgets, frequency).  On a fixed
TPU target the analogous description is the peak-rate triple below plus the
VMEM capacity that plays the role of the paper's per-core local memory ``L``.
"""

from __future__ import annotations

import dataclasses

# Per-chip peaks (TPU v5e), per the assignment brief.
PEAK_FLOPS_BF16 = 197e12      # FLOP/s per chip (bf16 MXU)
HBM_BW = 819e9                # bytes/s per chip
ICI_BW_PER_LINK = 50e9        # bytes/s per ICI link

# Memory capacities.
HBM_BYTES = 16 * 2**30        # 16 GiB HBM per v5e chip
VMEM_BYTES = 128 * 2**20      # ~128 MiB VMEM per core (v5e); the paper's "L"
VMEM_USABLE_FRACTION = 0.75   # headroom for pipelining/semaphores/spills

# MXU systolic array dimension — tiles should be multiples of this.
MXU_DIM = 128
# Lane/sublane granularity for the VPU (last dim 128, second-minor 8 for f32).
LANE = 128
SUBLANE = 8

DTYPE_BYTES = {
    "float32": 4, "f32": 4,
    "bfloat16": 2, "bf16": 2,
    "float16": 2, "f16": 2,
    "int8": 1, "s8": 1, "u8": 1,
    "int32": 4, "s32": 4, "u32": 4,
    "int64": 8, "s64": 8, "u64": 8,
    "float64": 8, "f64": 8,
    "bool": 1, "pred": 1,
    "int16": 2, "s16": 2, "u16": 2,
    "f8e4m3": 1, "f8e5m2": 1,
    "c64": 8, "c128": 16,
}


@dataclasses.dataclass(frozen=True)
class Chip:
    """One accelerator chip — the paper's 'core', scaled up."""

    peak_flops: float = PEAK_FLOPS_BF16
    hbm_bw: float = HBM_BW
    hbm_bytes: int = HBM_BYTES
    vmem_bytes: int = VMEM_BYTES
    ici_bw_per_link: float = ICI_BW_PER_LINK

    def usable_vmem(self) -> int:
        return int(self.vmem_bytes * VMEM_USABLE_FRACTION)


TPU_V5E = Chip()
