"""ManyCoreConfig — the paper's system-level parameter set, on a TPU pod.

The paper's generator takes {number of cores, local-memory sizes, interconnect
topology, per-core arithmetic repertoire, number formats} and emits a concrete
machine plus its SystemC model.  Here the same parameter set describes how a
JAX program is laid onto a pod: mesh geometry (cores + interconnect), VMEM
budget (local memory), kernel repertoire (arithmetic ops), and dtype policy
(number formats).  `plan()` emits the concrete artifacts: a mesh, tile plans
for the kernel library, and a sharding-rule table — i.e. the "generated
design" — without the user writing any distribution code by hand.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Sequence

import jax

from repro.core import hardware, tiling


@dataclasses.dataclass(frozen=True)
class DTypePolicy:
    """The paper's 'number format' parameter."""

    param_dtype: str = "bfloat16"
    compute_dtype: str = "bfloat16"
    accum_dtype: str = "float32"

    @property
    def param_bytes(self) -> int:
        return hardware.DTYPE_BYTES[self.param_dtype]

    @property
    def compute_bytes(self) -> int:
        return hardware.DTYPE_BYTES[self.compute_dtype]


# Kernel repertoire — the paper's per-core arithmetic-operation library.
KERNEL_LIBRARY = ("matmul", "spmv", "flash_attention")


@dataclasses.dataclass(frozen=True)
class ManyCoreConfig:
    """System-level description of the machine + how to use it."""

    # interconnect topology: mesh axis sizes and names (paper: bus/ring/NoC).
    mesh_shape: tuple = (16, 16)
    mesh_axes: tuple = ("data", "model")
    # local memory per core (paper's L); None = chip default.
    vmem_bytes: int | None = None
    # arithmetic repertoire each core is configured with.
    kernels: tuple = KERNEL_LIBRARY
    # number formats.
    dtypes: DTypePolicy = DTypePolicy()
    chip: hardware.Chip = hardware.TPU_V5E

    @property
    def num_chips(self) -> int:
        return math.prod(self.mesh_shape)

    @property
    def usable_vmem(self) -> int:
        return self.vmem_bytes if self.vmem_bytes is not None else self.chip.usable_vmem()

    def make_mesh(self) -> jax.sharding.Mesh:
        return jax.make_mesh(self.mesh_shape, self.mesh_axes)

    def axis(self, name: str) -> int:
        return self.mesh_shape[self.mesh_axes.index(name)]

    def data_axes(self) -> tuple:
        return tuple(a for a in self.mesh_axes if a in ("pod", "data"))

    def model_axis(self) -> str:
        return "model"

    def matmul_tile(self, m: int | None = None, n: int | None = None,
                    k: int | None = None) -> tiling.Tile:
        """Eq.2-derived VMEM tile plan for this config's matmul kernel."""
        return tiling.solve_tpu(
            vmem_bytes=self.usable_vmem,
            dtype_bytes=self.dtypes.compute_bytes,
            m=m, n=n, k=k,
        )

    def peak_flops(self) -> float:
        return self.num_chips * self.chip.peak_flops

    def describe(self) -> str:
        lines = [
            f"many-core: {self.num_chips} chips, mesh {dict(zip(self.mesh_axes, self.mesh_shape))}",
            f"local memory (VMEM budget): {self.usable_vmem / 2**20:.0f} MiB/core",
            f"kernel repertoire: {', '.join(self.kernels)}",
            f"number formats: params={self.dtypes.param_dtype} compute={self.dtypes.compute_dtype} accum={self.dtypes.accum_dtype}",
            f"peak: {self.peak_flops() / 1e12:.0f} TFLOP/s aggregate",
        ]
        return "\n".join(lines)


SINGLE_POD = ManyCoreConfig(mesh_shape=(16, 16), mesh_axes=("data", "model"))
MULTI_POD = ManyCoreConfig(mesh_shape=(2, 16, 16), mesh_axes=("pod", "data", "model"))


def host_test_config(data: int = 1, model: int = 1) -> ManyCoreConfig:
    """A 1-chip (or tiny) config for CPU tests — the paper's '1 core' point."""
    return ManyCoreConfig(mesh_shape=(data, model), mesh_axes=("data", "model"))
