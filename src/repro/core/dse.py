"""Design-space exploration — the paper's flow, automated.

The paper's designer manually picks a configuration, auto-generates a SystemC
model, simulates for a cycle count, and iterates.  Here the candidate space is
enumerated programmatically and each point is scored either by the fast
analytical machine model (`core.cost_model`) or by an actual dry-run
lower+compile (`score=\"compiled\"`), which is the exact analogue of "simulate
the generated model".  Going from manual to automated DSE is a deliberate
beyond-paper improvement (recorded in DESIGN.md).

This module now holds only the *generic* DSE machinery (`Candidate`,
`grid`, `explore`) plus the sharding axis.  The per-kernel-family candidate
enumerations that used to live here moved next to their kernels as
declarative `KernelSpec` registrations (`kernels/<family>/spec.py`); the
`rank_*` functions below are kept as thin delegating shims for older call
sites (they import the spec modules lazily, so the core layer stays
import-clean of kernels).
"""

from __future__ import annotations

import dataclasses
import itertools
from typing import Any, Callable, Iterable, Sequence

from repro.core import hardware


@dataclasses.dataclass
class Candidate:
    knobs: dict
    score: float = float("inf")   # seconds — lower is better
    detail: dict | None = None

    def __repr__(self) -> str:
        return f"Candidate({self.knobs}, score={self.score:.6g})"


def grid(space: dict) -> Iterable[dict]:
    """Cartesian product of a {knob: [values]} space."""
    keys = list(space)
    for combo in itertools.product(*(space[k] for k in keys)):
        yield dict(zip(keys, combo))


def explore(
    space: dict | Sequence[dict],
    evaluate: Callable[[dict], tuple[float, dict]],
    top: int = 5,
) -> list[Candidate]:
    """Score every candidate; return the best `top`, ascending by score."""
    cands = []
    points = grid(space) if isinstance(space, dict) else space
    for knobs in points:
        try:
            score, detail = evaluate(knobs)
        except Exception as e:  # infeasible point (OOM, indivisible shard…)
            score, detail = float("inf"), {"error": repr(e)}
        cands.append(Candidate(knobs, score, detail))
    cands.sort(key=lambda c: c.score)
    return cands[:top]


# ---------------------------------------------------------------------------
# Kernel-family rankings — moved to kernels/<family>/spec.py
# ---------------------------------------------------------------------------

def rank_matmul_tiles(
    m: int, n: int, k: int,
    vmem_bytes: int | None = None,
    dtype_bytes: int = 2,
    align: int = hardware.MXU_DIM,
    top: int = 8,
) -> list[Candidate]:
    """Deprecated: moved to `kernels.matmul.spec.rank_tiles` (the matmul
    family's KernelSpec enumeration).  Kept as a delegating shim."""
    from repro.kernels.matmul import spec as matmul_spec
    return matmul_spec.rank_tiles(m, n, k, vmem_bytes=vmem_bytes,
                                  dtype_bytes=dtype_bytes, align=align,
                                  top=top)


def autotune_matmul_tile(
    m: int, n: int, k: int,
    vmem_bytes: int | None = None,
    dtype_bytes: int = 2,
    align: int = hardware.MXU_DIM,
):
    """Best analytical tile — `rank_matmul_tiles` winner (paper flow, one
    call).  Kept as the cheap non-measuring entry point; the measuring
    engine lives in `repro.kernels.autotune`."""
    ranked = rank_matmul_tiles(m, n, k, vmem_bytes=vmem_bytes,
                               dtype_bytes=dtype_bytes, align=align, top=1)
    return ranked[0].detail["tile"]


def rank_attention_blocks(
    bh: int, sq: int, sk: int, dh: int,
    vmem_bytes: int | None = None,
    dtype_bytes: int = 2,
    causal: bool = True,
    window: int | None = None,
    block_cands: Sequence[int] = (128, 256, 512, 1024),
    top: int = 8,
) -> list[Candidate]:
    """Deprecated: moved to `kernels.attention.spec.rank_attention_blocks`
    (the attention family's KernelSpec enumeration).  Delegating shim."""
    from repro.kernels.attention import spec as attn_spec
    return attn_spec.rank_attention_blocks(
        bh, sq, sk, dh, vmem_bytes=vmem_bytes, dtype_bytes=dtype_bytes,
        causal=causal, window=window, block_cands=block_cands, top=top)


def rank_decode_blocks(
    bkv: int, g: int, kv_len: int, dh: int,
    vmem_bytes: int | None = None,
    dtype_bytes: int = 2,
    block_cands: Sequence[int] = (128, 256, 512, 1024, 2048),
    top: int = 8,
    lengths: Sequence[int] | None = None,
) -> list[Candidate]:
    """Deprecated: moved to `kernels.attention.spec.rank_decode_blocks`
    (the decode family's KernelSpec enumeration).  Delegating shim."""
    from repro.kernels.attention import spec as attn_spec
    return attn_spec.rank_decode_blocks(
        bkv, g, kv_len, dh, vmem_bytes=vmem_bytes, dtype_bytes=dtype_bytes,
        block_cands=block_cands, top=top, lengths=lengths)


def sharding_candidates(num_chips: int, min_model: int = 1) -> list[dict]:
    """Enumerate (data, model) factorizations — the interconnect DSE axis."""
    out = []
    d = 1
    while d <= num_chips:
        if num_chips % d == 0:
            mdl = num_chips // d
            if mdl >= min_model:
                out.append({"data": d, "model": mdl})
        d *= 2
    return out
