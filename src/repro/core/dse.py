"""Design-space exploration — the paper's flow, automated.

The paper's designer manually picks a configuration, auto-generates a SystemC
model, simulates for a cycle count, and iterates.  Here the candidate space is
enumerated programmatically and each point is scored either by the fast
analytical machine model (`core.cost_model`) or by an actual dry-run
lower+compile (`score=\"compiled\"`), which is the exact analogue of "simulate
the generated model".  Going from manual to automated DSE is a deliberate
beyond-paper improvement (recorded in DESIGN.md).
"""

from __future__ import annotations

import dataclasses
import itertools
from typing import Any, Callable, Iterable, Sequence

from repro.core import cost_model, hardware, tiling


@dataclasses.dataclass
class Candidate:
    knobs: dict
    score: float = float("inf")   # seconds — lower is better
    detail: dict | None = None

    def __repr__(self) -> str:
        return f"Candidate({self.knobs}, score={self.score:.6g})"


def grid(space: dict) -> Iterable[dict]:
    """Cartesian product of a {knob: [values]} space."""
    keys = list(space)
    for combo in itertools.product(*(space[k] for k in keys)):
        yield dict(zip(keys, combo))


def explore(
    space: dict | Sequence[dict],
    evaluate: Callable[[dict], tuple[float, dict]],
    top: int = 5,
) -> list[Candidate]:
    """Score every candidate; return the best `top`, ascending by score."""
    cands = []
    points = grid(space) if isinstance(space, dict) else space
    for knobs in points:
        try:
            score, detail = evaluate(knobs)
        except Exception as e:  # infeasible point (OOM, indivisible shard…)
            score, detail = float("inf"), {"error": repr(e)}
        cands.append(Candidate(knobs, score, detail))
    cands.sort(key=lambda c: c.score)
    return cands[:top]


# ---------------------------------------------------------------------------
# Ready-made explorations
# ---------------------------------------------------------------------------

def rank_matmul_tiles(
    m: int, n: int, k: int,
    vmem_bytes: int | None = None,
    dtype_bytes: int = 2,
    align: int = hardware.MXU_DIM,
    top: int = 8,
) -> list[Candidate]:
    """Sweep aligned (y, x) pairs; score with the analytical matmul model.

    This is the paper's Table-I exploration (vary cores/local-mem, simulate,
    rank) compressed to one call.  The eq.2 seed is always included, so the
    top candidate is never worse than the paper's closed form.  The ranking
    is deterministic: candidates are scored by model time with (y, x, z) as
    the tie-break, so equal-cost points always order the same way — this is
    what makes the autotune cache reproducible.  Each returned
    ``Candidate.detail`` carries the concrete ``tiling.Tile`` plus the model
    row (`cost_model.matmul_time_model`).
    """
    chip = hardware.TPU_V5E
    budget = vmem_bytes if vmem_bytes is not None else chip.usable_vmem()

    def evaluate(knobs: dict) -> tuple[float, dict]:
        y, x = knobs["y"], knobs["x"]
        z_budget = (budget - y * x * 4) // max((y + 2 * x) * dtype_bytes, 1)
        z = max(align, (min(z_budget, k) // align) * align)
        t = tiling.Tile(y, x, z)
        if t.vmem_elems() * dtype_bytes + y * x * 4 > budget + y * x * dtype_bytes:
            return float("inf"), {}
        res = cost_model.matmul_time_model(m, n, k, t, dtype_bytes=dtype_bytes)
        return res["time_s"], {"tile": t, **res}

    seed = tiling.solve_tpu(budget, dtype_bytes, m=m, n=n, k=k)
    ys = sorted({align, 2 * align, 4 * align, 8 * align, seed.y})
    xs = sorted({align, 2 * align, 4 * align, 8 * align, seed.x})
    space = {"y": [v for v in ys if v <= max(m, align)],
             "x": [v for v in xs if v <= max(n, align)]}
    ranked = explore(space, evaluate, top=max(top, 1))
    ranked = [c for c in ranked if c.detail and "tile" in c.detail]
    ranked.sort(key=lambda c: (c.score, c.detail["tile"].y,
                               c.detail["tile"].x, c.detail["tile"].z))
    if not ranked:
        res = cost_model.matmul_time_model(m, n, k, seed,
                                           dtype_bytes=dtype_bytes)
        ranked = [Candidate({"y": seed.y, "x": seed.x}, res["time_s"],
                            {"tile": seed, **res})]
    return ranked[:top]


def autotune_matmul_tile(
    m: int, n: int, k: int,
    vmem_bytes: int | None = None,
    dtype_bytes: int = 2,
    align: int = hardware.MXU_DIM,
) -> tiling.Tile:
    """Best analytical tile — `rank_matmul_tiles` winner (paper flow, one
    call).  Kept as the cheap non-measuring entry point; the measuring
    engine lives in `repro.kernels.autotune`."""
    ranked = rank_matmul_tiles(m, n, k, vmem_bytes=vmem_bytes,
                               dtype_bytes=dtype_bytes, align=align, top=1)
    return ranked[0].detail["tile"]


def rank_attention_blocks(
    bh: int, sq: int, sk: int, dh: int,
    vmem_bytes: int | None = None,
    dtype_bytes: int = 2,
    causal: bool = True,
    window: int | None = None,
    block_cands: Sequence[int] = (128, 256, 512, 1024),
    top: int = 8,
) -> list[Candidate]:
    """Sweep (block_q, block_k) pairs for the flash-attention kernel; score
    with `cost_model.attention_time_model` under the VMEM budget.

    The kernel clamps blocks to the sequence (``min(block, s)``) and pads
    ragged remainders, so candidates are enumerated in *effective* block
    space and deduped — a 64-token prefill collapses every block_q
    candidate onto 64.  The mask enters the score: with block skipping the
    model credits the causal triangle / window band, so the ranking trades
    deeper q-blocks (less K/V re-streaming) against coarser masked-area
    coverage instead of assuming every block runs.  Ranking is
    deterministic: model time with (block_q, block_k) as the tie-break,
    descending block_q preferred on ties.  Each ``Candidate.detail``
    carries the effective blocks plus the model row.  Never returns empty:
    if the budget rejects everything, the smallest legal pair is scored and
    returned anyway (the kernel itself is the final arbiter on real VMEM).
    """
    chip = hardware.TPU_V5E
    budget = vmem_bytes if vmem_bytes is not None else chip.usable_vmem()

    # The kernel pads ragged remainders (and masks the tail), so candidates
    # need not divide the sequence — enumerate effective (clamped) blocks
    # and dedupe; a 64-token prefill still collapses onto a single pair.
    pairs = []
    seen = set()
    for bq in block_cands:
        for bk in block_cands:
            ebq, ebk = min(bq, sq), min(bk, sk)
            if (ebq, ebk) in seen:
                continue
            seen.add((ebq, ebk))
            pairs.append({"block_q": ebq, "block_k": ebk})

    def evaluate(knobs: dict) -> tuple[float, dict]:
        res = cost_model.attention_time_model(
            bh, sq, sk, dh, knobs["block_q"], knobs["block_k"],
            causal=causal, window=window, dtype_bytes=dtype_bytes)
        if res["vmem_bytes"] > budget:
            return float("inf"), {}
        return res["time_s"], {**knobs, **res}

    # Score ALL pairs before truncating: explore()'s internal top-cut is
    # insertion-ordered on ties, which would drop the deeper-block_q
    # candidates the tie-break below exists to prefer.
    ranked = explore(pairs, evaluate, top=len(pairs))
    ranked = [c for c in ranked if c.detail and "block_q" in c.detail]
    ranked.sort(key=lambda c: (c.score, -c.detail["block_q"],
                               c.detail["block_k"]))
    if not ranked:
        knobs = min(pairs, key=lambda p: (p["block_q"], p["block_k"]))
        res = cost_model.attention_time_model(
            bh, sq, sk, dh, knobs["block_q"], knobs["block_k"],
            causal=causal, window=window, dtype_bytes=dtype_bytes)
        ranked = [Candidate(knobs, res["time_s"], {**knobs, **res})]
    return ranked[:top]


def rank_decode_blocks(
    bkv: int, g: int, kv_len: int, dh: int,
    vmem_bytes: int | None = None,
    dtype_bytes: int = 2,
    block_cands: Sequence[int] = (128, 256, 512, 1024, 2048),
    top: int = 8,
) -> list[Candidate]:
    """Sweep block_k for the fused decode-attention kernel
    (kernels/attention/decode.py); score with
    `cost_model.decode_time_model` under the VMEM budget.

    ``bkv = batch*kv_heads`` folded rows, ``g`` the GQA query group riding
    each row, ``kv_len`` the KV-cache depth the server allocated.  The knob
    trades tail over-fetch (coarse block_k rounds the cache up) against
    grid-step count; ranking is deterministic — model time, then *larger*
    block_k on ties (fewer grid steps for the same traffic).  Never empty:
    the smallest candidate is scored unconditionally if the budget rejects
    everything (the kernel is the final arbiter on real VMEM).
    """
    chip = hardware.TPU_V5E
    budget = vmem_bytes if vmem_bytes is not None else chip.usable_vmem()

    cands = sorted({min(bk, max(kv_len, 1)) for bk in block_cands})

    def evaluate(knobs: dict) -> tuple[float, dict]:
        res = cost_model.decode_time_model(bkv, g, kv_len, dh,
                                           knobs["block_k"],
                                           dtype_bytes=dtype_bytes)
        if res["vmem_bytes"] > budget:
            return float("inf"), {}
        return res["time_s"], {**knobs, **res}

    ranked = explore([{"block_k": bk} for bk in cands], evaluate,
                     top=len(cands))
    ranked = [c for c in ranked if c.detail and "block_k" in c.detail]
    ranked.sort(key=lambda c: (c.score, -c.detail["block_k"]))
    if not ranked:
        bk = cands[0]
        res = cost_model.decode_time_model(bkv, g, kv_len, dh, bk,
                                           dtype_bytes=dtype_bytes)
        ranked = [Candidate({"block_k": bk}, res["time_s"],
                            {"block_k": bk, **res})]
    return ranked[:top]


def sharding_candidates(num_chips: int, min_model: int = 1) -> list[dict]:
    """Enumerate (data, model) factorizations — the interconnect DSE axis."""
    out = []
    d = 1
    while d <= num_chips:
        if num_chips % d == 0:
            mdl = num_chips // d
            if mdl >= min_model:
                out.append({"data": d, "model": mdl})
        d *= 2
    return out
