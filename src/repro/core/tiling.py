"""Communication-minimizing blocked-matmul tiling (the paper's eq. 2), adapted
from FPGA BRAM to TPU VMEM.

Paper model (section V-A, following their ref. [25])
-----------------------------------------------------
``C = A @ B`` with ``n x n`` operands.  A group of ``p`` cores computes an
``n x (x*p)`` column panel of C; each core owns an ``n x x`` strip processed in
``y x x`` blocks ``C_ij``.  For one row-block ``i`` the ``y x n`` strip of A is
*broadcast once* to all ``p`` cores while each core streams its own ``n x x``
strip of B.  Per-core local memory must hold the B sub-block (``z*x``, doubled
for double-buffering) and the C block (``x*y``).

External traffic for the whole product:

    Q(x, y) = n^3 / (p*x)   (A, broadcast)
            + n^3 / y       (B, reloaded once per row-block)
            + n^2           (C, written once)

subject to ``x*(2z + y) <= L`` with ``z = 1`` (Q is z-independent, so the
paper shrinks z to minimize memory).  Lagrange minimization gives eq. 2:

    y = sqrt(p*L),     x = L / (2 + sqrt(p*L))

TPU adaptation
--------------
``L`` becomes the usable VMEM budget in *elements*.  Two facts change:

* the MXU is a 128x128 systolic array, so tiles must be multiples of 128 and
  ``z = 1`` would waste the contraction dimension entirely.  Q is independent
  of z, so we raise z to an MXU-friendly depth "for free" in traffic — but z
  now occupies VMEM (A tile ``y*z``, double-buffered B tile ``2*z*x``, C
  accumulator ``y*x``), giving the refined constraint

      y*z + 2*z*x + x*y <= L.

* the broadcast of A across cores becomes A-tile *reuse across the grid's N
  axis* inside one chip (p = 1 in-kernel) and an all-gather of the stationary
  operand across chips (p = number of chips sharing the panel).

`solve_paper` returns the faithful eq.2 point; `solve_tpu` returns the
MXU-aligned point found by local search around it.  Both are validated against
brute force in tests/test_tiling.py.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Iterable

from repro.core import hardware


@dataclasses.dataclass(frozen=True)
class Tile:
    """A (y, x, z) block assignment: C tile is y*x, contraction depth z."""

    y: int  # rows of the C tile (M axis)
    x: int  # cols of the C tile (N axis)
    z: int  # contraction tile (K axis)

    def vmem_elems(self, double_buffer: bool = True) -> int:
        db = 2 if double_buffer else 1
        return self.y * self.z + db * self.z * self.x + self.y * self.x

    def as_block_shapes(self):
        """BlockSpec shapes for (A, B, C) of a y/x/z-tiled matmul."""
        return (self.y, self.z), (self.z, self.x), (self.y, self.x)


def comm_volume(n: int, tile: Tile, p: int = 1) -> float:
    """External-memory traffic (elements) for an n x n matmul — paper's Q."""
    if tile.x <= 0 or tile.y <= 0:
        return math.inf
    return n**3 / (p * tile.x) + n**3 / tile.y + n**2


def comm_volume_rect(m: int, n: int, k: int, tile: Tile, p: int = 1) -> float:
    """Rectangular generalization of Q for an (m,k) @ (k,n) product.

    Each operand is streamed at least once (the ``max(1, ...)`` floors):
    below one tile per axis the fractional panel counts would otherwise
    charge *less* than one full pass over B — exactly the decode regime
    (m = batch << tile.y) where the weight stream is the traffic floor the
    serving batch sweep trades against.
    """
    if tile.x <= 0 or tile.y <= 0:
        return math.inf
    a_traffic = (m * k) * max(1.0, n / (p * tile.x))  # A loaded once per N-panel
    b_traffic = (k * n) * max(1.0, m / tile.y)        # B reloaded per row-block
    c_traffic = m * n
    return a_traffic + b_traffic + c_traffic


def solve_paper(L: int, p: int = 1) -> Tile:
    """Eq. 2 of the paper, verbatim: z = 1, y = sqrt(pL), x = L/(2+sqrt(pL))."""
    if L <= 4:
        return Tile(1, 1, 1)
    y_star = math.sqrt(p * L)
    x_star = L / (2.0 + y_star)
    # Integer repair of the continuous optimum.  The feasible set x(2+y)<=L
    # is a sawtooth in integers, so probe both axes: for integer y near y*,
    # the best x is the constraint maximum L//(2+y); for integer x near x*,
    # the best y is L//x - 2.  Pick the lowest-traffic candidate.
    cands = set()
    for y in {max(1, math.floor(y_star)), max(1, math.ceil(y_star))}:
        cands.add((int(y), max(1, L // (2 + int(y)))))
    for x in {max(1, math.floor(x_star)), max(1, math.ceil(x_star))}:
        y = max(1, L // int(x) - 2)
        cands.add((int(y), int(x)))
    best, best_q = None, math.inf
    for y, x in cands:
        if x * (2 + y) > L:
            continue
        t = Tile(y, x, 1)
        q = comm_volume(4096, t, p)
        if q < best_q:
            best, best_q = t, q
    return best if best is not None else Tile(1, 1, 1)


def _aligned_candidates(upper: int, align: int) -> Iterable[int]:
    v = align
    while v <= max(align, upper):
        yield v
        v += align


def solve_tpu(
    vmem_bytes: int | None = None,
    dtype_bytes: int = 2,
    accum_bytes: int = 4,
    p: int = 1,
    align: int = hardware.MXU_DIM,
    m: int | None = None,
    n: int | None = None,
    k: int | None = None,
    double_buffer: bool = True,
) -> Tile:
    """MXU-aligned tile minimizing traffic under the refined VMEM constraint.

    Searches 128-aligned (y, x, z) near the eq.2 analytical point.  The C
    accumulator is held at ``accum_bytes`` (f32 accumulation on the MXU);
    streamed A/B tiles at ``dtype_bytes``.
    """
    chip = hardware.TPU_V5E
    budget = vmem_bytes if vmem_bytes is not None else chip.usable_vmem()
    db = 2 if double_buffer else 1

    def fits(y: int, x: int, z: int) -> bool:
        used = (y * z + db * z * x) * dtype_bytes + y * x * accum_bytes
        return used <= budget

    # Analytical seed: treat L as budget in "effective elements".
    L_eff = budget // max(dtype_bytes, 1)
    seed = solve_paper(L_eff, p)

    def clampdim(v: int, dim: int | None) -> int:
        if dim is None:
            return v
        return min(v, max(align, math.ceil(dim / align) * align))

    best: Tile | None = None
    best_q = math.inf
    y_hi = clampdim(max(align, int(seed.y * 2)), m)
    x_hi = clampdim(max(align, int(seed.x * 4)), n)
    mm = m or 8192
    nn = n or 8192
    kk = k or 8192
    for y in _aligned_candidates(y_hi, align):
        for x in _aligned_candidates(x_hi, align):
            # Largest aligned z that still fits — traffic is z-independent,
            # deeper z amortizes accumulator read/write and MXU pipelining.
            z_max = (budget - y * x * accum_bytes) // max(
                (y + db * x) * dtype_bytes, 1
            )
            z_max = clampdim(z_max, k)
            z = (z_max // align) * align
            if z < align:
                continue
            if not fits(y, x, z):
                continue
            q = comm_volume_rect(mm, nn, kk, Tile(y, x, z), p)
            if q < best_q:
                best_q = q
                best = Tile(y, x, z)
    if best is None:
        # Degenerate VMEM budget: fall back to one MXU tile.
        best = Tile(align, align, align)
    return best


def brute_force_paper(L: int, p: int = 1, n: int = 4096) -> Tile:
    """Exhaustive integer search of the paper's constrained problem (tests).
    x >= 1 requires 2 + y <= L, so y ranges over [1, L-2]."""
    best, best_q = Tile(1, 1, 1), math.inf
    for y in range(1, max(L - 1, 2)):
        x = L // (2 + y)
        if x >= 1:
            q = comm_volume(n, Tile(y, x, 1), p)
            if q < best_q:
                best_q, best = q, Tile(y, x, 1)
    return best
