"""Parse compiled/lowered HLO text for roofline inputs.

`cost_analysis()` reports FLOPs and bytes accessed but NOT collective traffic,
so we reconstruct it from the HLO: map every instruction name to its result
shape, then for each collective op sum the byte sizes of its *operands* (per
the roofline methodology).  This is the dry-run analogue of the paper's
SystemC cycle trace: a machine-model-level account of what the generated
design moves over the interconnect.
"""

from __future__ import annotations

import dataclasses
import re
from collections import defaultdict

from repro.core.hardware import DTYPE_BYTES

COLLECTIVE_OPS = (
    "all-gather",
    "all-reduce",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
)

# `%name = <shape> opcode(...)` — shape may be a tuple.
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(\([^=]*?\)|[\w\[\],{}\/#:]+)\s+([\w\-]+)")
_SHAPE_RE = re.compile(r"([a-z]\d+|pred|token|bf16|f8e4m3|f8e5m2)\[([\d,]*)\]")
_OPERAND_RE = re.compile(r"%([\w.\-]+)")


def shape_bytes(shape_str: str) -> int:
    """Bytes of an HLO shape string (handles tuples by summing)."""
    total = 0
    for dtype, dims in _SHAPE_RE.findall(shape_str):
        if dtype == "token":
            continue
        nbytes = DTYPE_BYTES.get(dtype)
        if nbytes is None:
            # e.g. u16/s16 style "x16" dtypes
            m = re.match(r"[a-z](\d+)", dtype)
            nbytes = int(m.group(1)) // 8 if m else 4
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * nbytes
    return total


@dataclasses.dataclass
class CollectiveStats:
    bytes_by_op: dict
    count_by_op: dict

    @property
    def total_bytes(self) -> int:
        return sum(self.bytes_by_op.values())

    def summary(self) -> str:
        parts = [
            f"{op}: n={self.count_by_op.get(op, 0)} bytes={self.bytes_by_op.get(op, 0):,}"
            for op in COLLECTIVE_OPS
            if self.count_by_op.get(op)
        ]
        return "; ".join(parts) if parts else "none"


def collect_collectives(hlo_text: str) -> CollectiveStats:
    """Sum operand bytes of every collective in an HLO module dump."""
    # Pass 1: instruction name -> result shape bytes.
    def_shape: dict[str, int] = {}
    lines = hlo_text.splitlines()
    for ln in lines:
        m = _DEF_RE.match(ln)
        if m:
            name, shape_str, _op = m.groups()
            def_shape[name] = shape_bytes(shape_str)

    bytes_by_op: dict[str, int] = defaultdict(int)
    count_by_op: dict[str, int] = defaultdict(int)
    for ln in lines:
        m = _DEF_RE.match(ln)
        if not m:
            continue
        name, shape_str, opcode = m.groups()
        base = None
        for coll in COLLECTIVE_OPS:
            if opcode == coll or opcode.startswith(coll + "-start"):
                base = coll
                break
        if base is None:
            continue
        # Operand bytes: everything referenced inside the call parens.
        paren = ln.find("(", m.end(3) - len(opcode))
        operand_bytes = 0
        if paren >= 0:
            # First level of parens only (arguments).
            depth, j = 0, paren
            args_end = len(ln)
            for j in range(paren, len(ln)):
                if ln[j] == "(":
                    depth += 1
                elif ln[j] == ")":
                    depth -= 1
                    if depth == 0:
                        args_end = j
                        break
            args = ln[paren + 1 : args_end]
            for opname in _OPERAND_RE.findall(args):
                operand_bytes += def_shape.get(opname, 0)
            if operand_bytes == 0:
                # Operands may be unprefixed (no %) in newer dumps: fall back
                # to inline shapes in the arg list, else the result shape.
                inline = shape_bytes(args)
                operand_bytes = inline if inline else def_shape.get(name, 0)
        else:
            operand_bytes = def_shape.get(name, 0)
        bytes_by_op[base] += operand_bytes
        count_by_op[base] += 1
    return CollectiveStats(dict(bytes_by_op), dict(count_by_op))


def cost_analysis_stats(compiled) -> tuple[float, float]:
    """(flops, bytes accessed) from a compiled executable's cost analysis."""
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0]
    flops = float(ca.get("flops", 0.0))
    bytes_accessed = float(ca.get("bytes accessed", ca.get("bytes_accessed", 0.0)))
    return flops, bytes_accessed
