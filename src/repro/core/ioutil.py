"""Atomic file-write primitives — the repo-wide torn-write guard.

The paper's embedded hosts treat power loss and watchdog resets as
routine, so *every* durable artifact of this repo — the autotune cache
(`kernels.autotune.TuneCache`), every ``BENCH_*.json`` report, the
serving-state snapshots and manifests (`runtime.snapshot`) — must be
written such that a crash at any instant leaves either the old file or
the new one, never a torn hybrid.  The recipe is the classic one: write
to a temp file in the *same directory* (``os.replace`` must not cross
filesystems), ``fsync`` the payload so it is on disk before the name is,
then rename over the target in one atomic step.

Lives in ``core`` because it is stdlib-only and every layer above
(kernels, runtime, benchmarks) writes through it.
"""

from __future__ import annotations

import json
import os
import pathlib
import tempfile


def atomic_write_bytes(path, data: bytes) -> None:
    """Write ``data`` to ``path`` such that a crash at any instant leaves
    either the old contents or the new — never a torn file."""
    path = pathlib.Path(path)
    path.parent.mkdir(parents=True, exist_ok=True)
    fd, tmp = tempfile.mkstemp(dir=path.parent,
                               prefix=path.name + ".", suffix=".tmp")
    try:
        with os.fdopen(fd, "wb") as f:
            f.write(data)
            f.flush()
            os.fsync(f.fileno())
        os.replace(tmp, path)
    except BaseException:
        try:
            os.unlink(tmp)
        except OSError:
            pass
        raise


def atomic_write_json(path, obj, *, indent: int = 1,
                      sort_keys: bool = True) -> None:
    """`json.dumps` through :func:`atomic_write_bytes` — the only way any
    module of this repo is allowed to write a JSON report or cache."""
    atomic_write_bytes(path, (json.dumps(obj, indent=indent,
                                         sort_keys=sort_keys) + "\n")
                       .encode())
