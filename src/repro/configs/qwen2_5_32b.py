"""Qwen2.5-32B [hf:Qwen/Qwen2.5 family].  Dense, GQA kv=8, QKV bias."""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen2.5-32b",
    family="dense",
    num_layers=64,
    d_model=5120,
    d_ff=27648,
    vocab_size=152064,
    num_heads=40,
    num_kv_heads=8,
    head_dim=128,
    qkv_bias=True,
    remat="full",
)

SMOKE = ModelConfig(
    name="qwen2.5-32b-smoke",
    family="dense",
    num_layers=2,
    d_model=64,
    d_ff=128,
    vocab_size=128,
    num_heads=4,
    num_kv_heads=2,
    qkv_bias=True,
)
