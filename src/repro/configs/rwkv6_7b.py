"""RWKV-6 "Finch" 7B [arXiv:2404.05892].  Attention-free; data-dependent
decay; O(1) decode state => long_500k runs at constant per-token cost.
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="rwkv6-7b",
    family="ssm",
    num_layers=32,
    d_model=4096,
    d_ff=14336,
    vocab_size=65536,
    rwkv_head_dim=64,
    rwkv_lora_dim=64,
    remat="full",
)

SMOKE = ModelConfig(
    name="rwkv6-smoke",
    family="ssm",
    num_layers=2,
    d_model=64,
    d_ff=128,
    vocab_size=128,
    rwkv_head_dim=16,
    rwkv_lora_dim=8,
)
