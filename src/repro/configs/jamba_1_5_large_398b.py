"""Jamba-1.5-Large (398B total / ~94B active) [arXiv:2403.19887; hf].

Hybrid Mamba+attention at 1:7 (one attention layer per period-8 group, at
in-group offset 4 as in the HF config), MoE (16 experts, top-2) on every
other layer.
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="jamba-1.5-large-398b",
    family="hybrid",
    num_layers=72,
    d_model=8192,
    d_ff=24576,
    vocab_size=65536,
    num_heads=64,
    num_kv_heads=8,
    head_dim=128,
    num_experts=16,
    top_k=2,
    moe_d_ff=24576,
    moe_every=2,
    moe_offset=1,
    attn_period=8,
    attn_offset=4,
    ssm_state=16,
    ssm_conv=4,
    ssm_expand=2,
    remat="full",
)

SMOKE = ModelConfig(
    name="jamba-smoke",
    family="hybrid",
    num_layers=8,
    d_model=64,
    d_ff=128,
    vocab_size=128,
    num_heads=4,
    num_kv_heads=2,
    num_experts=4,
    top_k=2,
    moe_d_ff=64,
    moe_every=2,
    moe_offset=1,
    attn_period=8,
    attn_offset=4,
    ssm_state=4,
    ssm_conv=3,
    ssm_expand=2,
)
