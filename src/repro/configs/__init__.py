"""Architecture config registry: ``--arch <id>`` resolves here.

Each module defines ``CONFIG`` (the exact published configuration) and
``SMOKE`` (a reduced same-family config for CPU tests).
"""

from __future__ import annotations

import importlib

ARCHS = (
    "jamba_1_5_large_398b",
    "phi3_5_moe_42b",
    "qwen3_moe_235b",
    "phi3_mini_3_8b",
    "qwen3_14b",
    "qwen2_5_32b",
    "h2o_danube_1_8b",
    "hubert_xlarge",
    "rwkv6_7b",
    "internvl2_2b",
)

_ALIASES = {a.replace("_", "-"): a for a in ARCHS}
_ALIASES.update({
    "jamba-1.5-large-398b": "jamba_1_5_large_398b",
    "phi3.5-moe-42b-a6.6b": "phi3_5_moe_42b",
    "qwen3-moe-235b-a22b": "qwen3_moe_235b",
    "phi3-mini-3.8b": "phi3_mini_3_8b",
    "qwen3-14b": "qwen3_14b",
    "qwen2.5-32b": "qwen2_5_32b",
    "h2o-danube-1.8b": "h2o_danube_1_8b",
    "hubert-xlarge": "hubert_xlarge",
    "rwkv6-7b": "rwkv6_7b",
    "internvl2-2b": "internvl2_2b",
})


def _module(arch: str):
    arch = _ALIASES.get(arch, arch)
    if arch not in ARCHS:
        raise KeyError(f"unknown arch {arch!r}; known: {sorted(_ALIASES)}")
    return importlib.import_module(f"repro.configs.{arch}")


def get(arch: str):
    return _module(arch).CONFIG


def get_smoke(arch: str):
    return _module(arch).SMOKE


def list_archs():
    return list(ARCHS)
