"""Phi-3.5-MoE (42B total / 6.6B active) [hf:microsoft/Phi-3.5-MoE-instruct].

16 experts, top-2, MoE on every layer; GQA with 8 KV heads.
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="phi3.5-moe-42b-a6.6b",
    family="moe",
    num_layers=32,
    d_model=4096,
    d_ff=6400,
    vocab_size=32064,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    num_experts=16,
    top_k=2,
    moe_d_ff=6400,
    moe_every=1,
    remat="full",
)

SMOKE = ModelConfig(
    name="phi3.5-moe-smoke",
    family="moe",
    num_layers=2,
    d_model=64,
    d_ff=96,
    vocab_size=128,
    num_heads=4,
    num_kv_heads=2,
    num_experts=4,
    top_k=2,
    moe_d_ff=96,
)
