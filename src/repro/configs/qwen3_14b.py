"""Qwen3-14B [hf:Qwen/Qwen3-8B family].  Dense, qk-norm, GQA kv=8."""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-14b",
    family="dense",
    num_layers=40,
    d_model=5120,
    d_ff=17408,
    vocab_size=151936,
    num_heads=40,
    num_kv_heads=8,
    head_dim=128,
    qk_norm=True,
    remat="full",
)

SMOKE = ModelConfig(
    name="qwen3-14b-smoke",
    family="dense",
    num_layers=2,
    d_model=64,
    d_ff=128,
    vocab_size=160,
    num_heads=4,
    num_kv_heads=2,
    qk_norm=True,
)
