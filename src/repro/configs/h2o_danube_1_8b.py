"""H2O-Danube 1.8B [arXiv:2401.16818].  Llama/Mistral mix with sliding-window
attention (window 4096), GQA kv=8.  SWA makes long-context decode
linear-in-window, so this arch RUNS long_500k (ring-buffer KV cache).
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="h2o-danube-1.8b",
    family="dense",
    num_layers=24,
    d_model=2560,
    d_ff=6912,
    vocab_size=32000,
    num_heads=32,
    num_kv_heads=8,
    head_dim=80,
    sliding_window=4096,
    remat="full",
)

SMOKE = ModelConfig(
    name="h2o-danube-smoke",
    family="dense",
    num_layers=2,
    d_model=64,
    d_ff=128,
    vocab_size=128,
    num_heads=4,
    num_kv_heads=2,
    sliding_window=8,
)
