"""Phi-3-mini 3.8B [arXiv:2404.14219].  Dense; kv=32 => plain MHA."""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="phi3-mini-3.8b",
    family="dense",
    num_layers=32,
    d_model=3072,
    d_ff=8192,
    vocab_size=32064,
    num_heads=32,
    num_kv_heads=32,
    head_dim=96,
    remat="full",
)

SMOKE = ModelConfig(
    name="phi3-mini-smoke",
    family="dense",
    num_layers=2,
    d_model=64,
    d_ff=128,
    vocab_size=128,
    num_heads=4,
    num_kv_heads=4,
)
