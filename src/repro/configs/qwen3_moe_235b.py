"""Qwen3-MoE 235B-A22B family config [hf:Qwen/Qwen3-30B-A3B scaled per brief].

128 experts, top-8, per-expert FFN 1536; qk-norm; GQA with 4 KV heads.
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-moe-235b-a22b",
    family="moe",
    num_layers=94,
    d_model=4096,
    d_ff=1536,
    vocab_size=151936,
    num_heads=64,
    num_kv_heads=4,
    head_dim=128,
    qk_norm=True,
    num_experts=128,
    top_k=8,
    moe_d_ff=1536,
    moe_every=1,
    remat="full",
)

SMOKE = ModelConfig(
    name="qwen3-moe-smoke",
    family="moe",
    num_layers=2,
    d_model=64,
    d_ff=48,
    vocab_size=160,
    num_heads=4,
    num_kv_heads=2,
    qk_norm=True,
    num_experts=8,
    top_k=2,
    moe_d_ff=48,
)
