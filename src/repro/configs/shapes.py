"""Assigned input-shape sets and (arch x shape) applicability rules."""

from __future__ import annotations

import dataclasses

from repro.models.config import ModelConfig


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    kind: str              # "train" | "prefill" | "decode"
    seq_len: int
    global_batch: int


SHAPES = {
    "train_4k": ShapeSpec("train_4k", "train", 4_096, 256),
    "prefill_32k": ShapeSpec("prefill_32k", "prefill", 32_768, 32),
    "decode_32k": ShapeSpec("decode_32k", "decode", 32_768, 128),
    "long_500k": ShapeSpec("long_500k", "decode", 524_288, 1),
}


def applicable(cfg: ModelConfig, shape: ShapeSpec) -> tuple[bool, str]:
    """Whether this (arch, shape) cell runs, with the reason if skipped.

    Per the brief: encoder-only archs have no decode step; ``long_500k``
    needs sub-quadratic attention (SSM / hybrid / sliding-window qualify;
    pure full-attention archs skip).
    """
    if cfg.family == "encoder" and shape.kind == "decode":
        return False, "encoder-only: no decode step"
    if shape.name == "long_500k":
        sub_quadratic = (cfg.family in ("ssm", "hybrid")
                         or cfg.sliding_window is not None)
        if not sub_quadratic:
            return False, "full attention is quadratic at 500k; skipped per brief"
    return True, ""


def cells(cfg: ModelConfig):
    """All applicable ShapeSpecs for an arch."""
    return [s for s in SHAPES.values() if applicable(cfg, s)[0]]
