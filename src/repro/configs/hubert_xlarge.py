"""HuBERT X-Large [arXiv:2106.07447].  Encoder-only audio transformer
(wav2vec2-style backbone).  The conv feature extractor is a STUB per the
brief: ``input_specs()`` feeds precomputed 512-d frame embeddings.
No decode shapes (encoder-only).
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="hubert-xlarge",
    family="encoder",
    num_layers=48,
    d_model=1280,
    d_ff=5120,
    vocab_size=504,
    num_heads=16,
    num_kv_heads=16,
    head_dim=80,
    causal=False,
    frontend="frame",
    frontend_dim=512,
    remat="full",
)

SMOKE = ModelConfig(
    name="hubert-smoke",
    family="encoder",
    num_layers=2,
    d_model=64,
    d_ff=128,
    vocab_size=32,
    num_heads=4,
    num_kv_heads=4,
    causal=False,
    frontend="frame",
    frontend_dim=24,
)
