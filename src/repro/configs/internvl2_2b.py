"""InternVL2-2B [arXiv:2404.16821].  InternLM2-1.8B language backbone; the
InternViT vision tower is a STUB per the brief: ``input_specs()`` feeds
precomputed 1024-d patch embeddings which a projector maps into d_model.
"""

from repro.models.config import ModelConfig

# Number of visual patch embeddings prepended to the text sequence.
NUM_PATCHES = 1024

CONFIG = ModelConfig(
    name="internvl2-2b",
    family="dense",
    num_layers=24,
    d_model=2048,
    d_ff=8192,
    vocab_size=92553,
    num_heads=16,
    num_kv_heads=8,
    head_dim=128,
    frontend="patch",
    frontend_dim=1024,
    remat="full",
)

SMOKE = ModelConfig(
    name="internvl2-smoke",
    family="dense",
    num_layers=2,
    d_model=64,
    d_ff=128,
    vocab_size=128,
    num_heads=4,
    num_kv_heads=2,
    frontend="patch",
    frontend_dim=32,
)
