"""Dry-run sweep driver: one subprocess per (arch x shape x mesh) cell.

Per-cell isolation keeps one failed compile from killing the sweep and
bounds memory growth.  Single-pod cells run with differential cost probes
(they feed the roofline table); multi-pod cells prove lowering/compile +
memory only (the brief's roofline table is single-pod).

  PYTHONPATH=src python -m repro.launch.sweep --mesh single
  PYTHONPATH=src python -m repro.launch.sweep --mesh multi
"""

from __future__ import annotations

import argparse
import json
import subprocess
import sys
import time
from pathlib import Path

import repro.configs as configs
from repro.configs.shapes import SHAPES, applicable

ARTIFACTS = Path(__file__).resolve().parents[3] / "artifacts" / "dryrun"


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", choices=["single", "multi", "both"],
                    default="both")
    ap.add_argument("--timeout", type=int, default=3600)
    ap.add_argument("--force", action="store_true",
                    help="re-run cells that already have ok artifacts")
    args = ap.parse_args(argv)

    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    cells = []
    for mesh in meshes:
        for arch in configs.list_archs():
            for shape in SHAPES:
                cells.append((arch, shape, mesh))

    done = failed = skipped = 0
    for arch, shape, mesh in cells:
        tag = f"{arch}__{shape}__{mesh}"
        art = ARTIFACTS / f"{tag}.json"
        cfg = configs.get(arch)
        ok, reason = applicable(cfg, SHAPES[shape])
        if not ok:
            ARTIFACTS.mkdir(parents=True, exist_ok=True)
            art.write_text(json.dumps({
                "arch": arch, "shape": shape, "mesh": mesh,
                "status": "skipped", "reason": reason}, indent=2))
            skipped += 1
            print(f"[skip] {tag}: {reason}", flush=True)
            continue
        if art.exists() and not args.force:
            try:
                prev = json.loads(art.read_text())
                if prev.get("status") == "ok" and (
                        mesh == "multi" or "extrapolated" in prev):
                    done += 1
                    print(f"[cached] {tag}", flush=True)
                    continue
            except Exception:
                pass
        cmd = [sys.executable, "-m", "repro.launch.dryrun",
               "--arch", arch, "--shape", shape, "--mesh", mesh]
        if mesh == "multi":
            cmd.append("--no-probes")
        t0 = time.time()
        try:
            proc = subprocess.run(cmd, capture_output=True, text=True,
                                  timeout=args.timeout)
            rc = proc.returncode
        except subprocess.TimeoutExpired:
            rc = -9
        dt = time.time() - t0
        status = "ok" if rc == 0 else "FAIL"
        if rc != 0:
            failed += 1
            ARTIFACTS.mkdir(parents=True, exist_ok=True)
            if not art.exists():
                art.write_text(json.dumps({
                    "arch": arch, "shape": shape, "mesh": mesh,
                    "status": "error",
                    "error": f"subprocess rc={rc}"}, indent=2))
        else:
            done += 1
        print(f"[{status}] {tag} ({dt:.0f}s)", flush=True)
    print(f"sweep complete: ok={done} failed={failed} skipped={skipped}",
          flush=True)
    return 1 if failed else 0


if __name__ == "__main__":
    raise SystemExit(main())
