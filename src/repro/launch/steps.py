"""Step functions: train_step (fwd+bwd+AdamW) and serve_step (decode)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import transformer
from repro.models.config import ModelConfig
from repro.optim import adamw
from repro.parallel.loss import cross_entropy, fused_cross_entropy

AUX_WEIGHT = 1e-2


def make_train_step(cfg: ModelConfig, opt_cfg: adamw.AdamWConfig,
                    grad_dtype=None):
    """``grad_dtype=jnp.bfloat16`` compresses the gradient all-reduce
    (beyond-paper distributed trick; moments still accumulate in f32)."""

    def train_step(state, batch):
        inputs = {k: v for k, v in batch.items() if k != "labels"}

        def loss_fn(params):
            hidden, _, aux = transformer.forward(cfg, params, inputs,
                                                 return_hidden=True)
            head = params["embed" if cfg.tie_embeddings else "head"]["table"]
            loss, metrics = fused_cross_entropy(
                hidden, head, batch["labels"], chunk=cfg.loss_chunk,
                unroll=cfg.probe_unroll)
            return loss + AUX_WEIGHT * aux, (metrics, aux)

        (total, (metrics, aux)), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(state["params"])
        if grad_dtype is not None:
            grads = jax.tree.map(lambda g: g.astype(grad_dtype), grads)
        new_params, new_opt, opt_metrics = adamw.update(
            state["params"], grads, state["opt"], opt_cfg)
        out_metrics = {**metrics, **opt_metrics,
                       "total_loss": total, "aux_loss": aux}
        return {"params": new_params, "opt": new_opt}, out_metrics

    return train_step


def make_eval_step(cfg: ModelConfig):
    def eval_step(params, batch):
        inputs = {k: v for k, v in batch.items() if k != "labels"}
        logits, _, _ = transformer.forward(cfg, params, inputs)
        loss, metrics = cross_entropy(logits, batch["labels"])
        return metrics

    return eval_step


def make_prefill_step(cfg: ModelConfig):
    """Serving prefill: forward over the prompt, no cache mutation needed for
    the dry-run shape (prefill_32k measures the forward itself)."""

    def prefill_step(params, batch):
        inputs = {k: v for k, v in batch.items() if k != "labels"}
        logits, _, _ = transformer.forward(cfg, params, inputs,
                                           last_only=True)
        return jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)

    return prefill_step


def _last_valid_logits(logits, active, s):
    """Final-position logits per slot.  With a (B, S) chunked-prefill
    ``active`` each slot's "final position" is the last one it actually
    wrote (variable-length prompts packed into one chunk); everywhere
    else it is literally the last column."""
    if active is not None and active.ndim == 2:
        idx = jnp.clip(jnp.sum(active, axis=1, dtype=jnp.int32) - 1, 0,
                       s - 1)
        return jnp.take_along_axis(logits, idx[:, None, None], axis=1)[:, 0]
    return logits[:, -1]


def make_serve_step(cfg: ModelConfig, paged=None):
    """One decode step: new token(s) in, next token + updated cache out.

    ``active`` ((B,) bool, optional) is the ragged continuous-batching
    mask: only active slots write cache rows and advance their per-slot
    ``lengths``; ``None`` advances everyone (the uniform-batch case).
    The same step serves two shapes: S=1 is the decode hot loop, S>1 with
    a one-hot ``active`` is the masked batched prefill that fills exactly
    one slot's cache from depth 0 without touching its neighbours — and a
    (B, S) ``active`` is the chunked prefill that packs several
    variable-length prompts (plus riding decode slots) into one forward.
    ``paged`` (a `runtime.paging.PageSpec`, static) switches the cache to
    the paged pool layout.
    """

    def serve_step(params, cache, tokens, active=None):
        logits, new_cache, _ = transformer.forward(
            cfg, params, {"tokens": tokens}, cache=cache, active=active,
            paged=paged)
        last = _last_valid_logits(logits, active, tokens.shape[1])
        nxt = jnp.argmax(last, axis=-1).astype(jnp.int32)
        return nxt[:, None], new_cache

    return serve_step


def make_guarded_serve_step(cfg: ModelConfig, paged=None):
    """`make_serve_step` plus the per-slot NaN/Inf logits guard (and the
    chaos logits-poison hook) — the step the fault-tolerant server runs.

    Returns ``(next_token, ok, cache)`` where ``ok`` is a (B,) bool: True
    iff the slot's final-position logits are entirely finite.  A False
    slot's token is garbage and its cache may be poisoned — the serve loop
    quarantines exactly that slot (reset + requeue) while its neighbours,
    whose rows are untouched (per-slot masked writes), keep decoding
    bitwise-identically to a fault-free run.  ``poison`` ((B,) bool,
    chaos-injection only) overwrites a slot's logits with NaN *after* the
    forward, so the guard is exercised without corrupting model state.
    """

    def serve_step(params, cache, tokens, active=None, poison=None):
        logits, new_cache, _ = transformer.forward(
            cfg, params, {"tokens": tokens}, cache=cache, active=active,
            paged=paged)
        last = _last_valid_logits(logits, active, tokens.shape[1])
        if poison is not None:
            last = jnp.where(poison[:, None], jnp.nan, last)
        ok = jnp.all(jnp.isfinite(last), axis=-1)
        nxt = jnp.argmax(last, axis=-1).astype(jnp.int32)
        return nxt[:, None], ok, new_cache

    return serve_step
