"""End-to-end trainer: config -> mesh -> data -> resilient step loop.

Runs for real on CPU with reduced configs (``--smoke``), and is the same code
path the production mesh uses.  Demonstrates: sharded state init, the
deterministic data pipeline, async atomic checkpointing with resume, and the
straggler monitor.

  PYTHONPATH=src python -m repro.launch.train --arch qwen3_14b --smoke \
      --steps 30 --batch 8 --seq 64
"""

from __future__ import annotations

import argparse
import json
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

import repro.configs as configs
from repro.data import DataConfig, make_source
from repro.checkpoint import CheckpointManager
from repro.launch import policy, specs, steps
from repro.launch.mesh import (make_host_mesh, make_production_mesh,
                               set_mesh)
from repro.models import transformer
from repro.optim import adamw
from repro.parallel import sharding as shd
from repro.runtime.fault_tolerance import (ResilienceConfig, run_resilient)


def build_state(cfg, opt_cfg, key, mesh, rules):
    """Initialize sharded train state on the mesh."""
    p_pspecs = specs.param_pspecs(cfg, rules, mesh)
    params_abs = specs.abstract_params(cfg)
    opt_abs = specs.abstract_opt_state(params_abs, opt_cfg)
    o_pspecs = specs.opt_pspecs(cfg, params_abs, opt_abs, rules, mesh)
    state_sh = {
        "params": jax.tree.map(
            lambda ps: jax.sharding.NamedSharding(mesh, ps), p_pspecs,
            is_leaf=lambda x: isinstance(x, jax.sharding.PartitionSpec)),
        "opt": jax.tree.map(
            lambda ps: jax.sharding.NamedSharding(mesh, ps), o_pspecs,
            is_leaf=lambda x: isinstance(x, jax.sharding.PartitionSpec)),
    }

    def init_fn(k):
        params = transformer.init(cfg, k, dtype=policy.param_dtype(cfg))
        return {"params": params, "opt": adamw.init_state(params, opt_cfg)}

    init_sharded = jax.jit(init_fn, out_shardings=state_sh)
    return init_sharded(key), state_sh


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3_14b",
                    choices=configs.list_archs())
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--ckpt-dir", default="checkpoints")
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--data", default="synthetic", choices=["synthetic",
                                                            "memmap"])
    ap.add_argument("--data-path", default=None)
    ap.add_argument("--production-mesh", action="store_true",
                    help="use the 16x16 pod mesh (needs 256 devices)")
    args = ap.parse_args(argv)

    cfg = configs.get_smoke(args.arch) if args.smoke else configs.get(args.arch)
    opt_cfg = adamw.AdamWConfig(peak_lr=args.lr, warmup_steps=10,
                                total_steps=args.steps,
                                moment_dtype=policy.moment_dtype(cfg))
    if args.production_mesh:
        mesh = make_production_mesh()
    else:
        n = len(jax.devices())
        mesh = make_host_mesh(data=n, model=1)
    rules = specs.rules_for(mesh).with_sizes(mesh)

    dcfg = DataConfig(
        vocab_size=cfg.vocab_size, seq_len=args.seq,
        global_batch=args.batch, kind=args.data, path=args.data_path,
        frontend=cfg.frontend, frontend_dim=cfg.frontend_dim,
        num_patches=min(8, args.seq // 4) if cfg.frontend == "patch" else 0)
    source = make_source(dcfg)

    ckpt = CheckpointManager(Path(args.ckpt_dir) / cfg.name, keep=3)
    train_step = jax.jit(steps.make_train_step(cfg, opt_cfg),
                         donate_argnums=(0,))

    with set_mesh(mesh), shd.use_rules(rules):
        state, state_sh = build_state(cfg, opt_cfg, jax.random.PRNGKey(0),
                                      mesh, rules)
        start_step = 0
        if args.resume and ckpt.latest_step() is not None:
            abs_state = jax.eval_shape(lambda: state)
            state, meta = ckpt.restore(None, abs_state, state_sh)
            start_step = meta["step"]
            print(f"resumed from step {start_step}")

        def batch_fn(step):
            b = source.batch(step, 0, 1)
            return {k: jnp.asarray(v) for k, v in b.items()}

        def on_restore(_step):
            abs_state = jax.eval_shape(lambda: state)
            restored, meta = ckpt.restore(None, abs_state, state_sh)
            print(f"restored from step {meta['step']}")
            return restored, meta["step"]

        t0 = time.time()
        state, history, monitor = run_resilient(
            train_step, state, args.steps, ckpt, batch_fn,
            start_step=start_step,
            config=ResilienceConfig(checkpoint_every=args.ckpt_every),
            on_restore=on_restore)
        wall = time.time() - t0

    losses = [h["loss"] for h in history if "loss" in h]
    print(json.dumps({
        "arch": cfg.name,
        "steps": len(history),
        "wall_s": round(wall, 2),
        "first_loss": round(losses[0], 4) if losses else None,
        "last_loss": round(losses[-1], 4) if losses else None,
        "stragglers": len(monitor.reports),
        "final_ckpt": ckpt.latest_step(),
    }))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
