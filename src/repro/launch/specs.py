"""ShapeDtypeStruct stand-ins + shardings for every (arch x shape) cell.

`input_specs` produces the exact abstract inputs a step function consumes —
weak-type-correct and shardable, with zero device allocation.  The dry-run
lowers against these.  Modality frontends are STUBS per the brief: [audio]
gets precomputed frame embeddings, [vlm] precomputed patch embeddings.
"""

from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import internvl2_2b
from repro.configs.shapes import ShapeSpec
from repro.models import transformer
from repro.models.config import ModelConfig
from repro.optim import adamw
from repro.parallel import sharding as shd

Pytree = Any


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def rules_for(mesh: jax.sharding.Mesh, shape: ShapeSpec | None = None) -> shd.Rules:
    multi = "pod" in mesh.axis_names
    rules = shd.multi_pod_rules() if multi else shd.single_pod_rules()
    if shape is not None and shape.kind == "decode":
        dp = 1
        for a in rules.table["dp"]:
            dp *= mesh.shape[a]
        rules = shd.decode_rules(
            rules, batch_replicated=bool(shape.global_batch % dp))
    return rules.with_sizes(mesh)


# ---------------------------------------------------------------------------
# Batch specs (train / prefill)
# ---------------------------------------------------------------------------

def batch_specs(cfg: ModelConfig, shape: ShapeSpec) -> dict:
    """Abstract train/prefill batch: inputs dict + labels."""
    b, s = shape.global_batch, shape.seq_len
    batch: dict = {}
    if cfg.frontend == "frame":
        batch["frames"] = _sds((b, s, cfg.frontend_dim), jnp.bfloat16)
        batch["labels"] = _sds((b, s), jnp.int32)
        return batch
    if cfg.frontend == "patch":
        npatch = min(internvl2_2b.NUM_PATCHES, s // 4)
        batch["patches"] = _sds((b, npatch, cfg.frontend_dim), jnp.bfloat16)
        batch["tokens"] = _sds((b, s - npatch), jnp.int32)
        batch["labels"] = _sds((b, s), jnp.int32)
        return batch
    batch["tokens"] = _sds((b, s), jnp.int32)
    batch["labels"] = _sds((b, s), jnp.int32)
    return batch


def batch_shardings(cfg: ModelConfig, shape: ShapeSpec, mesh, rules) -> dict:
    def shard_one(sds):
        axes = ("batch",) + (None,) * (len(sds.shape) - 1)
        return NamedSharding(mesh, fit_spec(rules.spec(*axes), sds.shape,
                                            rules))

    return {k: shard_one(v) for k, v in batch_specs(cfg, shape).items()}


# ---------------------------------------------------------------------------
# State specs (params + optimizer)
# ---------------------------------------------------------------------------

def abstract_params(cfg: ModelConfig, dtype=None) -> Pytree:
    if dtype is None:
        from repro.launch import policy
        dtype = policy.param_dtype(cfg)
    return jax.eval_shape(
        lambda: transformer.init(cfg, jax.random.PRNGKey(0), dtype=dtype))


def abstract_opt_state(params: Pytree, opt_cfg: adamw.AdamWConfig) -> Pytree:
    return jax.eval_shape(lambda: adamw.init_state(
        jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), params), opt_cfg))


def _is_axes(x) -> bool:
    return isinstance(x, tuple)


def logical_to_pspec(tree: Pytree, rules: shd.Rules) -> Pytree:
    return jax.tree.map(lambda axes: rules.spec(*axes), tree, is_leaf=_is_axes)


def fit_spec(spec: P, shape: tuple, rules: shd.Rules) -> P:
    """Drop spec entries whose mesh-axis product does not divide the dim —
    jit-boundary shardings (unlike internal constraints) must divide exactly."""
    entries = list(spec) + [None] * (len(shape) - len(spec))
    out = []
    for dim, e in zip(shape, entries):
        size = rules.axis_size(e)
        out.append(e if (size > 1 and dim % size == 0) else None)
    return P(*out)


def fit_pspecs(pspec_tree: Pytree, abs_tree: Pytree,
               rules: shd.Rules) -> Pytree:
    return jax.tree.map(
        lambda ps, sds: fit_spec(ps, sds.shape, rules),
        pspec_tree, abs_tree, is_leaf=lambda x: isinstance(x, P))


def param_pspecs(cfg: ModelConfig, rules: shd.Rules, mesh=None) -> Pytree:
    base = fit_pspecs(logical_to_pspec(transformer.param_specs(cfg), rules),
                      abstract_params(cfg), rules)
    from repro.launch import policy
    if mesh is None or not policy.use_fsdp(cfg):
        return base
    # FSDP storage: add the DP axes on the first free divisible dim of each
    # leaf (beyond TP).  XLA all-gathers weights at use; required for >=10B
    # models to fit 16 GB/chip (see EXPERIMENTS §Dry-run).
    dp_axes = tuple(rules.table.get("dp") or ())
    return jax.tree.map(
        lambda ps, sds: zero_shard(ps, sds.shape, mesh, dp_axes),
        base, abstract_params(cfg),
        is_leaf=lambda x: isinstance(x, P))


def zero_shard(pspec: P, shape: tuple, mesh, dp_axes: tuple) -> P:
    """ZeRO-1/FSDP: add the DP axes to the first unsharded, divisible dim.
    No-op if the spec already uses any DP axis (idempotent)."""
    dp = 1
    for a in dp_axes:
        dp *= mesh.shape[a]
    if dp <= 1:
        return pspec
    used = set()
    for e in pspec:
        if e is None:
            continue
        for a in (e if isinstance(e, tuple) else (e,)):
            used.add(a)
    if used & set(dp_axes):
        return pspec
    entries = list(pspec) + [None] * (len(shape) - len(pspec))
    for i, (e, dim) in enumerate(zip(entries, shape)):
        if e is None and dim % dp == 0 and dim >= dp:
            entries[i] = tuple(dp_axes) if len(dp_axes) > 1 else dp_axes[0]
            return P(*entries)
    return pspec


def opt_pspecs(cfg: ModelConfig, params_abs: Pytree, opt_abs: Pytree,
               rules: shd.Rules, mesh, zero: bool = True) -> Pytree:
    """Moment shardings: parameter sharding + extra ZeRO DP-axis shard.

    int8-quantized moments are {"q","scale"} dicts; both inherit the
    parameter's (zero-sharded) spec, truncated to their rank.
    """
    p_pspecs = param_pspecs(cfg, rules, mesh)
    dp_axes = tuple(rules.table.get("dp") or ())

    def moment_spec(ps: P, p_sds, m_sds):
        spec = zero_shard(ps, p_sds.shape, mesh, dp_axes) if zero else ps
        if isinstance(m_sds, dict):  # quantized {"q","scale"}
            entries = list(spec) + [None] * (len(p_sds.shape) - len(spec))
            return {
                "q": fit_spec(P(*entries), m_sds["q"].shape, rules),
                "scale": fit_spec(P(*entries[: len(m_sds["scale"].shape)]),
                                  m_sds["scale"].shape, rules),
            }
        return fit_spec(spec, m_sds.shape, rules)

    m_specs = jax.tree.map(
        moment_spec, p_pspecs, params_abs,
        jax.tree.map(lambda x: x, opt_abs["m"],
                     is_leaf=adamw._is_moment_leaf),
        is_leaf=lambda x: isinstance(x, P),
    )
    return {"step": P(), "m": m_specs, "v": m_specs}


def state_shardings(cfg: ModelConfig, opt_cfg: adamw.AdamWConfig, mesh,
                    rules: shd.Rules, zero: bool = True):
    """(abstract_state, shardings) for {"params", "opt"}."""
    params_abs = abstract_params(cfg)
    opt_abs = abstract_opt_state(params_abs, opt_cfg)
    p_pspecs = param_pspecs(cfg, rules, mesh)
    o_pspecs = opt_pspecs(cfg, params_abs, opt_abs, rules, mesh, zero)
    to_sh = lambda tree: jax.tree.map(
        lambda ps: NamedSharding(mesh, ps), tree,
        is_leaf=lambda x: isinstance(x, P))
    state_abs = {"params": params_abs, "opt": opt_abs}
    state_sh = {"params": to_sh(p_pspecs), "opt": to_sh(o_pspecs)}
    return state_abs, state_sh


# ---------------------------------------------------------------------------
# Decode specs
# ---------------------------------------------------------------------------

def decode_specs(cfg: ModelConfig, shape: ShapeSpec, mesh, rules,
                 state_rules=None):
    """(abstract {params, cache, tokens}, shardings) for serve_step."""
    params_abs = abstract_params(cfg)
    b = shape.global_batch
    cache_abs = jax.eval_shape(
        lambda: transformer.cache_init(cfg, b, shape.seq_len,
                                       dtype=jnp.bfloat16))
    p_pspecs = param_pspecs(cfg, state_rules or rules, mesh)
    c_pspecs = fit_pspecs(
        logical_to_pspec(transformer.cache_specs(cfg), rules), cache_abs,
        rules)
    tok_abs = _sds((b, 1), jnp.int32)
    tok_spec = fit_spec(P(rules.table.get("batch"), None), (b, 1), rules)
    to_sh = lambda tree: jax.tree.map(
        lambda ps: NamedSharding(mesh, ps), tree,
        is_leaf=lambda x: isinstance(x, P))
    abs_ = {"params": params_abs, "cache": cache_abs, "tokens": tok_abs}
    sh = {"params": to_sh(p_pspecs), "cache": to_sh(c_pspecs),
          "tokens": NamedSharding(mesh, tok_spec)}
    return abs_, sh
