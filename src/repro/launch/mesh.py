"""Production mesh construction.

Defined as FUNCTIONS (not module constants) so importing this module never
touches jax device state.  The two jax API points that moved across the
pinned-version boundary (``jax.sharding.AxisType``, ``jax.set_mesh``) are
wrapped in compat helpers here so every caller imports cleanly on jax
0.4.x and newer alike.
"""

from __future__ import annotations

import contextlib
import math

import jax


def axis_types_kwargs(n_axes: int) -> dict:
    """``{"axis_types": (Auto,) * n}`` where supported, ``{}`` otherwise.

    ``jax.sharding.AxisType`` does not exist on older pinned jax versions
    (e.g. 0.4.37), where every mesh axis is implicitly Auto — so omitting
    the kwarg there is semantically identical, not a downgrade.
    """
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return {}
    return {"axis_types": (axis_type.Auto,) * n_axes}


def set_mesh(mesh: jax.sharding.Mesh):
    """Context manager installing ``mesh`` as the ambient mesh.

    ``jax.set_mesh`` is the modern spelling; on jax versions predating it
    the ``Mesh`` object itself is the context manager with the same scope
    semantics.
    """
    if hasattr(jax, "set_mesh"):
        return jax.set_mesh(mesh)
    if hasattr(jax.sharding, "use_mesh"):
        return jax.sharding.use_mesh(mesh)
    return contextlib.nullcontext(mesh) if mesh is None else mesh


def _mesh(shape, axes) -> jax.sharding.Mesh:
    # Auto axis types: the models rely on GSPMD propagation.  Pin the device
    # subset explicitly so a 512-device dry-run host can build a 256-chip pod.
    n = math.prod(shape)
    devices = jax.devices()[:n]
    from jax.experimental import mesh_utils
    dmesh = mesh_utils.create_device_mesh(shape, devices=devices)
    return jax.sharding.Mesh(dmesh, axes, **axis_types_kwargs(len(axes)))


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _mesh(shape, axes)


def make_host_mesh(data: int = 1, model: int = 1) -> jax.sharding.Mesh:
    """Tiny mesh over whatever devices exist (CPU tests)."""
    return _mesh((data, model), ("data", "model"))
