"""Production mesh construction.

Defined as FUNCTIONS (not module constants) so importing this module never
touches jax device state.  The jax API points that moved across the
pinned-version boundary (``jax.sharding.AxisType``, ``jax.set_mesh``,
``jax.make_mesh(axis_types=...)``, ``jax.sharding.get_abstract_mesh``,
``jax.shard_map``) are wrapped in compat helpers here so every caller —
including ``parallel/compression.py`` and ``models/moe.py``, which import
them lazily inside the function body to keep the layer diagram acyclic —
runs on jax 0.4.x and newer alike.
"""

from __future__ import annotations

import contextlib
import math

import jax


def axis_types_kwargs(n_axes: int) -> dict:
    """``{"axis_types": (Auto,) * n}`` where supported, ``{}`` otherwise.

    ``jax.sharding.AxisType`` does not exist on older pinned jax versions
    (e.g. 0.4.37), where every mesh axis is implicitly Auto — so omitting
    the kwarg there is semantically identical, not a downgrade.
    """
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return {}
    return {"axis_types": (axis_type.Auto,) * n_axes}


def set_mesh(mesh: jax.sharding.Mesh):
    """Context manager installing ``mesh`` as the ambient mesh.

    ``jax.set_mesh`` is the modern spelling; on jax versions predating it
    the ``Mesh`` object itself is the context manager with the same scope
    semantics.
    """
    if hasattr(jax, "set_mesh"):
        return jax.set_mesh(mesh)
    if hasattr(jax.sharding, "use_mesh"):
        return jax.sharding.use_mesh(mesh)
    return contextlib.nullcontext(mesh) if mesh is None else mesh


def make_mesh(axis_shapes, axis_names) -> jax.sharding.Mesh:
    """``jax.make_mesh`` with Auto axis types where the kwarg exists.

    Older jax (0.4.x) has no ``axis_types`` parameter — and no axis types
    at all, so every axis is implicitly Auto and omitting the kwarg is
    semantically identical.
    """
    return jax.make_mesh(tuple(axis_shapes), tuple(axis_names),
                         **axis_types_kwargs(len(tuple(axis_names))))


def get_abstract_mesh():
    """The ambient mesh: ``jax.sharding.get_abstract_mesh()`` where it
    exists, the 0.4.x thread-resources physical mesh otherwise (both are
    what ``set_mesh`` above installed)."""
    if hasattr(jax.sharding, "get_abstract_mesh"):
        return jax.sharding.get_abstract_mesh()
    from jax._src import mesh as _mesh_lib
    return _mesh_lib.thread_resources.env.physical_mesh


def shard_map(f, mesh, in_specs, out_specs, axis_names=None):
    """``jax.shard_map`` across the API move.

    Modern jax spells partial-manual mode ``axis_names={...}`` and replica
    checking ``check_vma``; 0.4.x has ``jax.experimental.shard_map`` with
    the complement ``auto={...}`` and ``check_rep``.  Checking is disabled
    on both: the repo's callers reduce manually (psum/pmean) inside the
    mapped body.
    """
    if hasattr(jax, "shard_map"):
        kw = {} if axis_names is None else {"axis_names": frozenset(axis_names)}
        try:
            return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                                 out_specs=out_specs, check_vma=False, **kw)
        except TypeError:  # pre-rename spelling of the same knob
            return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                                 out_specs=out_specs, check_rep=False, **kw)
    from jax.experimental.shard_map import shard_map as _shard_map
    kw = {}
    if axis_names is not None:
        auto = frozenset(mesh.axis_names) - frozenset(axis_names)
        if auto:
            kw["auto"] = auto
    return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      check_rep=False, **kw)


def _mesh(shape, axes) -> jax.sharding.Mesh:
    # Auto axis types: the models rely on GSPMD propagation.  Pin the device
    # subset explicitly so a 512-device dry-run host can build a 256-chip pod.
    n = math.prod(shape)
    devices = jax.devices()[:n]
    from jax.experimental import mesh_utils
    dmesh = mesh_utils.create_device_mesh(shape, devices=devices)
    return jax.sharding.Mesh(dmesh, axes, **axis_types_kwargs(len(axes)))


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _mesh(shape, axes)


def make_host_mesh(data: int = 1, model: int = 1) -> jax.sharding.Mesh:
    """Tiny mesh over whatever devices exist (CPU tests)."""
    return _mesh((data, model), ("data", "model"))
