"""Production mesh construction.

Defined as FUNCTIONS (not module constants) so importing this module never
touches jax device state.
"""

from __future__ import annotations

import math

import jax


def _mesh(shape, axes) -> jax.sharding.Mesh:
    # Auto axis types: the models rely on GSPMD propagation.  Pin the device
    # subset explicitly so a 512-device dry-run host can build a 256-chip pod.
    n = math.prod(shape)
    devices = jax.devices()[:n]
    from jax.experimental import mesh_utils
    dmesh = mesh_utils.create_device_mesh(shape, devices=devices)
    return jax.sharding.Mesh(
        dmesh, axes,
        axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _mesh(shape, axes)


def make_host_mesh(data: int = 1, model: int = 1) -> jax.sharding.Mesh:
    """Tiny mesh over whatever devices exist (CPU tests)."""
    return _mesh((data, model), ("data", "model"))
