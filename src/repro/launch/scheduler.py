"""Pluggable admission scheduling over the Lifecycle queue.

``serve --sched fcfs|spf|paged-aware`` picks which eligible queued
request fills an idle slot next; with a paged KV cache the scheduler is
also the backpressure valve — a request is admitted only when the
:class:`~repro.runtime.paging.PageAllocator` can cover its *predicted*
footprint (``pages_for(prompt + gen)``), reserved at admission and
consumed as the slot actually grows, so a full pool shows up as
REJECTED/queued requests, never as a mid-decode crash.

Policies (all deterministic; ties broken by rid):

- ``fcfs`` — strict arrival order among backoff-eligible requests; if
  the head does not fit the pool, nothing is admitted (head-of-line
  blocking is the point: arrival order is the contract).
- ``spf`` — shortest-predicted-footprint first: the request with the
  smallest ``prompt + gen`` goes first, which drains heavy-tail mixes
  with far less pool pressure.
- ``paged-aware`` — FCFS order, but *first fit*: scan past requests the
  pool cannot cover right now and admit the first that fits, so one
  giant request at the head does not idle free pages.

A request whose footprint exceeds what an **empty** pool could hold can
never be admitted; the scheduler rejects it loudly
(QUEUED -> REJECTED) instead of queueing it forever.
"""

from __future__ import annotations

from repro.runtime.lifecycle import Lifecycle, Request
from repro.runtime.paging import PageAllocator

POLICIES = ("fcfs", "spf", "paged-aware")


class Scheduler:
    """Admission policy over ``Lifecycle.eligible``; pool-aware when an
    allocator is attached, plain request ordering when not."""

    def __init__(self, policy: str = "fcfs",
                 allocator: PageAllocator | None = None):
        if policy not in POLICIES:
            raise ValueError(f"unknown policy {policy!r}; "
                             f"expected one of {POLICIES}")
        self.policy = policy
        self.allocator = allocator
        self.rejected_oversize = 0

    @staticmethod
    def footprint_tokens(req: Request) -> int:
        """Predicted resident KV tokens at completion: the prompt plus
        one cache entry per generated token."""
        return int(len(req.prompt)) + int(req.gen_len)

    def _fits_now(self, req: Request) -> bool:
        return self.allocator is None or \
            self.allocator.can_admit(self.footprint_tokens(req))

    def pop_ready(self, lc: Lifecycle, step: int) -> Request | None:
        """Admit (and pool-reserve) the next request, or None when
        nothing eligible fits.  Drop-in for ``Lifecycle.pop_ready``."""
        candidates = lc.eligible(step)

        # Oversize requests can never be served: reject them all now so
        # they stop occupying queue positions (loud backpressure).
        if self.allocator is not None:
            for req in list(candidates):
                if not self.allocator.fits_pool(self.footprint_tokens(req)):
                    lc.reject(req, step)
                    self.rejected_oversize += 1
                    candidates.remove(req)
        if not candidates:
            return None

        if self.policy == "spf":
            candidates.sort(key=lambda r: (self.footprint_tokens(r), r.rid))
            pick = candidates[0] if self._fits_now(candidates[0]) else None
        elif self.policy == "paged-aware":
            pick = next((r for r in candidates if self._fits_now(r)), None)
        else:                               # fcfs: head of line or nothing
            pick = candidates[0] if self._fits_now(candidates[0]) else None
        if pick is None:
            return None

        lc.take(pick)
        if self.allocator is not None:
            # Pledge the full predicted footprint; PageAllocator.ensure
            # consumes the pledge page-by-page as decode actually grows.
            self.allocator.reserve(pick.rid, self.footprint_tokens(pick))
        return pick
