"""Batched serving driver: ragged continuous batching end to end.

Requests queue up; the server packs up to ``--batch`` sequences, prefills
each arriving request with a *masked batched prefill* (only the target
slot's cache rows are written, from depth 0), then decodes with per-slot
cache depths — every slot attends only over its own valid prefix, carried
as the cache's ``lengths: (B,)`` vector all the way into the fused decode
kernel's scalar-prefetch skip.  Finished slots are zeroed and refilled
from the queue (continuous batching).  ``--batch 0`` (the default) asks
the autotuner for the batch: `autotune.select_serving_batch` sweeps
candidate batch sizes against the cached kernel plans' predicted step
time — priced at quantiles of the workload's slot-depth distribution, the
active-prefix accounting, not the batch max — and picks the batch
maximizing predicted decode throughput under ``--latency-budget-ms`` —
the DSE loop driving a serving decision instead of a kernel tile.  Runs
on CPU with smoke configs:

  PYTHONPATH=src python -m repro.launch.serve --arch qwen3_14b --smoke \
      --requests 6 --prompt-len 16 --gen 12
"""

from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

import repro.configs as configs
from repro.kernels import autotune
from repro.launch import steps
from repro.launch.mesh import make_host_mesh, set_mesh
from repro.launch import specs
from repro.models import transformer
from repro.parallel import sharding as shd


class Server:
    def __init__(self, cfg, batch: int, max_len: int,
                 prefill_len: int = 0, autotune_kernels: bool = True,
                 slot_lengths=None):
        self.cfg = cfg
        self.batch = batch
        self.max_len = max_len
        # Close the DSE loop before taking traffic: pre-tune the decode-path
        # matmul shapes, the prefill flash-attention shape AND the fused
        # decode-attention fold so the kernel engine's cache is warm
        # (analytic-only here — measurement happens offline / on first TPU
        # run).  `plan_for_model` returns typed OpPlans; they are
        # serialized via `.record()` when logged below.
        # kv_dtype matches the cache_init dtype below — the decode plan is
        # keyed on the dtype the kernel actually streams.
        # `slot_lengths` is the workload's steady-state slot-depth
        # distribution: the decode plan is tuned on its quantiles (and
        # pinned under the runtime dispatch key), so the fused kernel runs
        # the ragged-workload-aware block, not the batch-max one.
        self.kernel_plan = (autotune.plan_for_model(cfg, batch,
                                                    prefill_len=prefill_len,
                                                    cache_len=max_len,
                                                    kv_dtype=jnp.float32,
                                                    slot_lengths=slot_lengths)
                            if autotune_kernels else [])
        self.params = transformer.init(cfg, jax.random.PRNGKey(0),
                                       dtype=jnp.float32)
        self.serve_step = jax.jit(steps.make_serve_step(cfg))
        self.cache = transformer.cache_init(cfg, batch, max_len,
                                            dtype=jnp.float32)
        self.slot_len = np.zeros(batch, np.int32)      # tokens generated
        self.slot_target = np.zeros(batch, np.int32)   # stop length
        self.slot_req = -np.ones(batch, np.int32)      # request id
        self.last_tok = jnp.zeros((batch, 1), jnp.int32)

    def prefill(self, slot: int, req_id: int, prompt: np.ndarray,
                gen_len: int):
        """Masked batched prefill of one slot: the whole prompt in a single
        forward whose ``active`` mask is the slot's one-hot, so ONLY this
        slot's cache rows are written and only its per-slot length advances
        from depth 0.  (The previous slot-local loop stepped the *shared*
        cache with zero tokens for every other slot, silently polluting
        their KV entries and advancing their depths.)  The recycled slot's
        stale KV/state rows are zeroed first — a refilled slot must be
        indistinguishable from a fresh one."""
        prompt = np.asarray(prompt, np.int32)
        if self.cfg.sliding_window:
            # The ring buffer keeps at most `window` keys; feeding more in
            # one masked scatter would alias ring rows. A fresh slot only
            # ever attends the last `window` prompt tokens anyway.
            prompt = prompt[-self.cfg.sliding_window:]
        self.cache = transformer.cache_reset_slot(self.cache, slot)
        toks = jnp.zeros((self.batch, prompt.size),
                         jnp.int32).at[slot].set(prompt)
        active = jnp.zeros((self.batch,), jnp.bool_).at[slot].set(True)
        nxt, self.cache = self.serve_step(self.params, self.cache, toks,
                                          active)
        self.last_tok = self.last_tok.at[slot, 0].set(int(nxt[slot, 0]))
        self.slot_len[slot] = 0
        self.slot_target[slot] = gen_len
        self.slot_req[slot] = req_id

    def decode_step(self):
        """One ragged decode step: every occupied slot attends over its own
        valid cache prefix (per-slot ``lengths`` threaded down to the fused
        decode kernel's scalar-prefetch vector); idle slots neither write
        nor advance."""
        active = jnp.asarray(self.slot_req >= 0)
        nxt, self.cache = self.serve_step(self.params, self.cache,
                                          self.last_tok, active)
        self.last_tok = jnp.where(active[:, None], nxt, self.last_tok)
        self.slot_len[self.slot_req >= 0] += 1
        done = [s for s in range(self.batch)
                if self.slot_req[s] >= 0
                and self.slot_len[s] >= self.slot_target[s]]
        return nxt, done


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3_14b",
                    choices=configs.list_archs())
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--batch", type=int, default=0,
                    help="decode batch; 0 = let the autotuner pick "
                         "(select_serving_batch sweep)")
    ap.add_argument("--batch-candidates", type=int, nargs="+",
                    default=[1, 2, 4, 8, 16, 32])
    ap.add_argument("--latency-budget-ms", type=float, default=None,
                    help="per-decode-step latency ceiling for the batch "
                         "sweep (None = pure throughput)")
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen", type=int, default=12)
    args = ap.parse_args(argv)

    cfg = configs.get_smoke(args.arch) if args.smoke else configs.get(args.arch)
    if cfg.family == "encoder":
        print("encoder-only arch has no decode path; nothing to serve")
        return 0
    mesh = make_host_mesh(data=1, model=1)
    rules = specs.rules_for(mesh)
    max_len = args.prompt_len + args.gen + 8

    # Steady-state slot-depth distribution: continuous batching staggers
    # occupied slots roughly uniformly across [prompt, prompt + gen] — the
    # length model the batch sweep and the decode-plan tuning both price.
    n_dist = max(args.batch_candidates + [args.batch, 1])
    dist = [args.prompt_len + ((2 * i + 1) * args.gen) // (2 * n_dist)
            for i in range(n_dist)]

    if args.batch > 0:
        batch = args.batch
        decision = {"batch": batch, "source": "flag"}
    else:
        # The tuner drives the batch: predicted-throughput argmax under the
        # latency budget, from the same cached plans the kernels run with.
        # Candidates beyond the queued workload are pointless (empty slots
        # still pay the step), so cap the sweep at --requests.
        cands = [c for c in args.batch_candidates if c <= args.requests]
        cands = cands or [min(args.batch_candidates)]
        # The sweep prices each candidate at quantiles of the slot-depth
        # distribution — the ragged batch the kernel actually skips on,
        # not the batch-max broadcast that over-charges every short slot.
        decision = autotune.select_serving_batch(
            cfg, cache_len=max_len, prefill_len=args.prompt_len,
            kv_dtype=jnp.float32,          # the Server's cache dtype
            candidates=tuple(cands),
            slot_lengths=dist,
            latency_budget_ms=args.latency_budget_ms)
        decision["source"] = "autotune"
        batch = decision["batch"]
    print(json.dumps({"serving_plan": decision}))

    rng = np.random.default_rng(0)
    queue = [(i, rng.integers(0, cfg.vocab_size, size=args.prompt_len),
              args.gen) for i in range(args.requests)]

    with set_mesh(mesh), shd.use_rules(rules):
        server = Server(cfg, batch, max_len, prefill_len=args.prompt_len,
                        slot_lengths=dist)
        t0 = time.time()
        completed, generated = 0, 0
        # initial fill
        for slot in range(min(batch, len(queue))):
            rid, prompt, gen = queue.pop(0)
            server.prefill(slot, rid, prompt, gen)
        while completed < args.requests:
            _, done = server.decode_step()
            generated += int((server.slot_req >= 0).sum())
            for slot in done:
                completed += 1
                server.slot_req[slot] = -1
                if queue:  # continuous batching: refill immediately
                    rid, prompt, gen = queue.pop(0)
                    server.prefill(slot, rid, prompt, gen)
        wall = time.time() - t0

    print(json.dumps({
        "arch": cfg.name, "requests": completed,
        "batch": batch, "batch_source": decision["source"],
        "tokens_generated": generated,
        "wall_s": round(wall, 2),
        "tok_per_s": round(generated / wall, 1),
        "kernel_plan": [p.record() for p in server.kernel_plan],
    }))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
