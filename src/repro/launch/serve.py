"""Fault-tolerant batched serving driver: ragged continuous batching with a
request lifecycle, graceful degradation, and a chaos mode.

Requests enter a bounded admission queue (`runtime.lifecycle`) and move
through an enforced state machine (QUEUED → PREFILLING → DECODING →
{COMPLETED, TIMED_OUT, EVICTED, FAILED, REJECTED}); the server packs up to
``--batch`` sequences, prefills each arriving request with a *masked
batched prefill* (only the target slot's cache rows are written, from
depth 0), then decodes with per-slot cache depths — every slot attends
only over its own valid prefix, carried as the cache's ``lengths: (B,)``
vector all the way into the fused decode kernel's scalar-prefetch skip.
Finished slots are zeroed and refilled from the queue (continuous
batching); ``--batch 0`` (the default) asks the autotuner for the batch
(`autotune.select_serving_batch`, priced at quantiles of the workload's
slot-depth distribution under ``--latency-budget-ms``).

The robustness layer on top (see docs/ROBUSTNESS.md):

* a per-slot NaN/Inf logits guard — a poisoned slot is quarantined alone
  (reset + requeued with backoff) while its neighbours keep decoding
  bitwise-identically;
* kernel-dispatch failure falls back one-shot to the jnp reference step
  with the plan marked poisoned for re-tune;
* per-request deadlines (TTFT and total) and retry-with-backoff, with the
  drain loop failing loudly (lifecycle table) instead of spinning when no
  progress is possible;
* a decode watchdog (`runtime.fault_tolerance.DecodeWatchdog`) comparing
  measured step time against `predict_decode_step_us`;
* ``--chaos --fault-seed N``: a deterministic fault schedule
  (`runtime.faults`) injecting one fault of each class;
* ``--load-trace trace.jsonl``: replay a seeded `runtime.loadgen` trace —
  arrivals fire on a deterministic virtual clock (one predicted
  decode-step of time per loop step), the replay path behind the
  traffic-shaped benchmark `benchmarks/serving_load.py`
  (docs/SERVING_BENCH.md).

The final summary line conserves every submitted request exactly once:
``submitted == completed + timed_out + failed + rejected``.  Runs on CPU
with smoke configs:

  PYTHONPATH=src python -m repro.launch.serve --arch qwen3_14b --smoke \
      --requests 6 --prompt-len 16 --gen 12 [--chaos --fault-seed 0]
"""

from __future__ import annotations

import argparse
import json
import pathlib
import time

import jax
import jax.numpy as jnp
import numpy as np

import repro.configs as configs
from repro.kernels import autotune
from repro.launch import steps
from repro.launch.mesh import make_host_mesh, set_mesh
from repro.launch import specs
from repro.launch.scheduler import POLICIES, Scheduler
from repro.models import transformer
from repro.parallel import sharding as shd
from repro.runtime import fault_tolerance, faults, loadgen, paging
from repro.runtime import journal as journal_mod
from repro.runtime import snapshot as snapshot_mod
from repro.runtime.lifecycle import (Lifecycle, Request, State, TERMINAL)

# Exit code of a run killed by an injected crash fault: distinct from both
# success and ordinary failure so the crash-smoke CI job can assert the
# process really died mid-serve before it attempts `serve --resume`.
CRASH_EXIT = 17


class Server:
    def __init__(self, cfg, batch: int, max_len: int,
                 prefill_len: int = 0, autotune_kernels: bool = True,
                 slot_lengths=None, injector=None, paged=None,
                 kv_dtype=jnp.float32):
        self.cfg = cfg
        self.batch = batch
        self.max_len = max_len
        # The KV-cache storage dtype (`--kv-dtype`): f32 (default), bf16,
        # or int8 — int8 caches carry per-token-row scale leaves and
        # decode through the quantized kernel family (decode_int8).
        self.kv_dtype = jnp.dtype(kv_dtype)
        # `paged` (a runtime.paging.PageSpec, or None for the contiguous
        # cache) switches the KV cache to the pooled page layout
        # (docs/PAGING.md): every layer shares one physical page pool and
        # the cache carries a per-slot page table the host-side allocator
        # mirrors.  The allocator is the truth; `_sync_pages` pushes its
        # table to the device cache after any alloc/free.
        self.paged = paged
        self.allocator = (paging.PageAllocator(paged, batch)
                          if paged is not None else None)
        # Close the DSE loop before taking traffic: pre-tune the decode-path
        # matmul shapes, the prefill flash-attention shape AND the fused
        # decode-attention fold so the kernel engine's cache is warm
        # (analytic-only here — measurement happens offline / on first TPU
        # run).  `plan_for_model` returns typed OpPlans; they are
        # serialized via `.record()` when logged below.
        # kv_dtype matches the cache_init dtype below — the decode plan is
        # keyed on the dtype the kernel actually streams.
        # `slot_lengths` is the workload's steady-state slot-depth
        # distribution: the decode plan is tuned on its quantiles (and
        # pinned under the runtime dispatch key), so the fused kernel runs
        # the ragged-workload-aware block, not the batch-max one.
        self.kernel_plan = (autotune.plan_for_model(cfg, batch,
                                                    prefill_len=prefill_len,
                                                    cache_len=max_len,
                                                    kv_dtype=self.kv_dtype,
                                                    slot_lengths=slot_lengths)
                            if autotune_kernels else [])
        self.params = transformer.init(cfg, jax.random.PRNGKey(0),
                                       dtype=jnp.float32)
        self.serve_step = jax.jit(
            steps.make_guarded_serve_step(cfg, paged=paged))
        # The degradation step: same math forced onto the jnp reference
        # path ($REPRO_DECODE_KERNEL=off at trace time) — built lazily on
        # the first kernel-dispatch fault.
        self._serve_step_ref = None
        self.injector = injector
        self.cache = transformer.cache_init(cfg, batch, max_len,
                                            dtype=self.kv_dtype, paged=paged)
        self.slot_len = np.zeros(batch, np.int32)      # tokens generated
        self.slot_target = np.zeros(batch, np.int32)   # stop length
        self.slot_req = -np.ones(batch, np.int32)      # request id
        self.last_tok = jnp.zeros((batch, 1), jnp.int32)
        self.poison = np.zeros(batch, bool)            # chaos logits-NaN arm

    def prefill(self, slot: int, req_id: int, prompt: np.ndarray,
                gen_len: int) -> bool:
        """Masked batched prefill of one slot: the whole prompt in a single
        forward whose ``active`` mask is the slot's one-hot, so ONLY this
        slot's cache rows are written and only its per-slot length advances
        from depth 0.  (The previous slot-local loop stepped the *shared*
        cache with zero tokens for every other slot, silently polluting
        their KV entries and advancing their depths.)  The recycled slot's
        stale KV/state rows are zeroed first — a refilled slot must be
        indistinguishable from a fresh one.

        Returns True iff the slot's first-token logits were finite (the
        per-slot guard); may raise `faults.PrefillInterrupt` in chaos mode
        *after* the slot reset — the interrupted slot is left zeroed, so a
        caller can simply release it and requeue the request."""
        prompt = np.asarray(prompt, np.int32)
        if self.cfg.sliding_window:
            # The ring buffer keeps at most `window` keys; feeding more in
            # one masked scatter would alias ring rows. A fresh slot only
            # ever attends the last `window` prompt tokens anyway.
            prompt = prompt[-self.cfg.sliding_window:]
        self.cache = transformer.cache_reset_slot(self.cache, slot,
                                                  paged=self.paged)
        if self.allocator is not None:
            # Drop any pages a previous occupant left behind (idempotent),
            # then cover the prompt before the forward — the masked scatter
            # needs physical rows to land in.  `ensure` consumes the
            # scheduler's admission reservation as the pages land.
            self.allocator.free_slot(slot, rid=int(self.slot_req[slot]))
            self.allocator.ensure(slot, prompt.size, rid=req_id)
            self._sync_pages()
        if self.injector is not None:
            self.injector.prefill_hook(slot, req_id)   # may raise
        toks = jnp.zeros((self.batch, prompt.size),
                         jnp.int32).at[slot].set(prompt)
        active = jnp.zeros((self.batch,), jnp.bool_).at[slot].set(True)
        nxt, ok, self.cache = self.serve_step(self.params, self.cache, toks,
                                              active)
        self.last_tok = self.last_tok.at[slot, 0].set(int(nxt[slot, 0]))
        self.slot_len[slot] = 0
        self.slot_target[slot] = gen_len
        self.slot_req[slot] = req_id
        return bool(np.asarray(ok)[slot])

    def can_chunk(self) -> bool:
        """Chunked prefill needs the (B, S) active-mask machinery, which
        only the attention families implement (per-slot valid-prefix
        scatter); the ring-buffer SWA layout and the chaos injector's
        ordinal-keyed prefill faults stay on the one-slot path."""
        return (self.cfg.family in ("dense", "moe") and self.cfg.causal
                and not self.cfg.sliding_window and self.injector is None)

    def admit_chunk(self, admits, step: int = 0):
        """Chunked prefill: pack several variable-length prompts into ONE
        forward, with every in-flight decode slot riding along at column
        0 (its next decode token) — prefill no longer stalls decode.

        ``admits`` is a list of ``(slot, rid, prompt, gen_len)`` for idle
        slots.  Each admitted slot's row carries its prompt left-aligned
        under a (B, S) active mask (only valid columns write cache rows
        and advance the slot's length); a riding decode slot's row is its
        ``last_tok`` at column 0.  The guarded step picks each slot's
        *last valid* logits, so admitted slots get their first token and
        riding slots their next one from the same forward.

        Returns ``(ok_admit, nxt, rode, done, bad)``: per-admitted-slot
        finite-logits verdicts, the token array, the riding slots, and
        the riding slots that finished / went non-finite this step
        (mirroring `decode_step`'s contract for exactly those slots)."""
        width = max(int(np.asarray(p).size) for _, _, p, _ in admits)
        rode = [s for s in range(self.batch) if self.slot_req[s] >= 0]
        for slot, rid, prompt, _ in admits:
            self.cache = transformer.cache_reset_slot(self.cache, slot,
                                                      paged=self.paged)
            if self.allocator is not None:
                self.allocator.free_slot(slot, rid=int(self.slot_req[slot]))
                self.allocator.ensure(slot, np.asarray(prompt).size, rid=rid)
        if self.allocator is not None:
            depths = np.asarray(self.cache["lengths"])
            for s in rode:                     # riding slots grow one token
                self.allocator.ensure(s, int(depths[s]) + 1,
                                      rid=int(self.slot_req[s]))
            self._sync_pages()
        tokens = np.zeros((self.batch, width), np.int32)
        act = np.zeros((self.batch, width), bool)
        last = np.asarray(self.last_tok)
        for s in rode:
            tokens[s, 0] = int(last[s, 0])
            act[s, 0] = True
        for slot, _, prompt, _ in admits:
            p = np.asarray(prompt, np.int32)
            tokens[slot, :p.size] = p
            act[slot, :p.size] = True
        nxt, ok, self.cache = self.serve_step(self.params, self.cache,
                                              jnp.asarray(tokens),
                                              jnp.asarray(act),
                                              jnp.asarray(self.poison))
        self.poison[:] = False
        ok = np.asarray(ok)
        nxt_np = np.asarray(nxt)
        ok_admit = {}
        new_last = last.copy()
        for slot, rid, _, gen_len in admits:
            new_last[slot, 0] = int(nxt_np[slot, 0])
            self.slot_len[slot] = 0
            self.slot_target[slot] = gen_len
            self.slot_req[slot] = rid
            ok_admit[slot] = bool(ok[slot])
        adv = [s for s in rode if ok[s]]
        for s in adv:
            new_last[s, 0] = int(nxt_np[s, 0])
            self.slot_len[s] += 1
        self.last_tok = jnp.asarray(new_last)
        done = [s for s in adv if self.slot_len[s] >= self.slot_target[s]]
        bad = [s for s in rode if not ok[s]]
        return ok_admit, nxt, rode, done, bad

    def restore_slot(self, slot: int, rid: int, prompt, tokens,
                     gen_len: int) -> None:
        """Re-prefill an in-flight request to its exact crash-point state
        (crash recovery, docs/ROBUSTNESS.md).

        ``tokens`` is the request's journaled output (first token +
        decode tokens).  After emitting token m-1 the live server held
        cache = prompt ++ tokens[:-1] with ``last_tok`` = tokens[-1] —
        so one masked batched prefill over that prefix (through the same
        `cache_reset_slot` + one-hot-active path a retry uses) rebuilds
        the KV state, and because decode is teacher-forcing-equivalent,
        its next-token prediction must equal the journaled tokens[-1].
        A mismatch means recovery is NOT deterministic (changed params,
        config drift, a corrupted journal) and raises rather than
        silently serving a diverged continuation."""
        tokens = [int(t) for t in tokens]
        if not tokens:
            raise ValueError(f"restore_slot needs >= 1 journaled token "
                             f"for request {rid}")
        prefix = np.concatenate([np.asarray(prompt, np.int32),
                                 np.asarray(tokens[:-1], np.int32)])
        # Injector hooks stay out of the restore path: a prefill fault
        # schedule is keyed on live prefill ordinals, not recovery work.
        inj, self.injector = self.injector, None
        try:
            ok = self.prefill(slot, rid, prefix, gen_len)
        finally:
            self.injector = inj
        predicted = int(self.last_tok[slot, 0])
        if not ok or predicted != tokens[-1]:
            raise RuntimeError(
                f"deterministic recovery violated for request {rid}: "
                f"re-prefill of {prefix.size} tokens predicted "
                f"{predicted} (finite={ok}) but the journal recorded "
                f"{tokens[-1]} — params/config drift or a corrupt "
                f"journal; refusing to serve a diverged continuation")
        self.slot_len[slot] = len(tokens) - 1

    # -- crash-tolerance: full-state export / restore -----------------------

    def export_state(self) -> dict:
        """The server's complete mutable state as flat numpy arrays — the
        payload `runtime.snapshot` persists: every cache leaf (KV blocks,
        SSM conv/state, RWKV shifts, per-slot ``lengths``, the legacy
        ``index``) plus the slot bookkeeping vectors."""
        leaves, _ = jax.tree_util.tree_flatten_with_path(self.cache)
        arrays = {"cache" + jax.tree_util.keystr(path): np.asarray(leaf)
                  for path, leaf in leaves}
        arrays["slot_len"] = self.slot_len.copy()
        arrays["slot_target"] = self.slot_target.copy()
        arrays["slot_req"] = self.slot_req.copy()
        arrays["last_tok"] = np.asarray(self.last_tok)
        return arrays

    def restore_state(self, arrays: dict) -> None:
        """Inverse of :meth:`export_state`: load a snapshot's arrays into
        this (same-config, same-batch) server, bitwise.  Shape/dtype
        mismatches mean the snapshot belongs to a different serving
        configuration and raise."""
        leaves, treedef = jax.tree_util.tree_flatten_with_path(self.cache)
        new_leaves = []
        for path, leaf in leaves:
            name = "cache" + jax.tree_util.keystr(path)
            if name not in arrays:
                raise ValueError(f"snapshot missing cache leaf {name!r}")
            a = arrays[name]
            if tuple(a.shape) != tuple(leaf.shape) or a.dtype != leaf.dtype:
                raise ValueError(
                    f"snapshot leaf {name!r} is {a.dtype}{a.shape}, server "
                    f"expects {leaf.dtype}{tuple(leaf.shape)} — snapshot "
                    f"from a different serving configuration")
            new_leaves.append(jnp.asarray(a))
        self.cache = jax.tree_util.tree_unflatten(treedef, new_leaves)
        self.slot_len = np.asarray(arrays["slot_len"], np.int32).copy()
        self.slot_target = np.asarray(arrays["slot_target"], np.int32).copy()
        self.slot_req = np.asarray(arrays["slot_req"], np.int32).copy()
        self.last_tok = jnp.asarray(np.asarray(arrays["last_tok"],
                                               np.int32))
        self.poison[:] = False
        if self.paged is not None:
            # Allocation order is canonical (min-heap), so the restored
            # page table fully determines the allocator state — rebuild
            # it rather than snapshotting it (docs/PAGING.md).
            self.allocator = paging.PageAllocator.adopt(
                self.paged, np.asarray(self.cache["pages"]))

    def release_slot(self, slot: int) -> None:
        """Free a slot and zero its cache rows — quarantine for a poisoned
        slot, plain recycling for a completed one (the zeroing is also done
        by the next prefill; doing it here means a NaN-corrupted slot never
        sits armed in the cache).  In paged mode the slot's pages return
        to the pool and its outstanding reservation is dropped."""
        rid = int(self.slot_req[slot])
        self.slot_req[slot] = -1
        self.cache = transformer.cache_reset_slot(self.cache, slot,
                                                  paged=self.paged)
        if self.allocator is not None:
            self.allocator.free_slot(slot, rid=rid)
            self._sync_pages()

    def _sync_pages(self) -> None:
        """Push the host allocator's page table to the device cache (the
        allocator is the truth; the cache copy is what the kernels read)."""
        self.cache["pages"] = jnp.asarray(self.allocator.table)

    def corrupt_kv(self, slot: int) -> None:
        """Chaos hook: NaN over one slot's KV/state cache rows."""
        self.cache = transformer.cache_poison_slot(self.cache, slot,
                                                   paged=self.paged)

    def decode_step(self, step: int = 0, use_ref: bool = False):
        """One ragged decode step: every occupied slot attends over its own
        valid cache prefix (per-slot ``lengths`` threaded down to the fused
        decode kernel's scalar-prefetch vector); idle slots neither write
        nor advance.

        Returns ``(next_tokens, done_slots, bad_slots)``: ``done`` slots
        hit their stop length this step; ``bad`` slots produced non-finite
        logits (per-slot guard) — their token is discarded, they did not
        advance, and the caller must quarantine them.  ``use_ref=True``
        runs the jnp-reference step (kernel-dispatch degradation path).
        May raise `faults.KernelDispatchFault` in chaos mode."""
        if self.injector is not None and not use_ref:
            self.injector.apply_decode_faults(self, step)   # may raise
        if self.allocator is not None:
            # Decode-boundary crossing: every occupied slot writes one
            # token this step — grow its page table to cover depth + 1
            # *before* the forward so the scatter has a physical row.
            # With reservation-priced admission this never OOMs; an
            # overcommitted pool raises PageOOM and the serve loop turns
            # it into an eviction (backpressure), not a crash.
            depths = np.asarray(self.cache["lengths"])
            grew = False
            for slot in range(self.batch):
                if self.slot_req[slot] >= 0:
                    grew |= self.allocator.ensure(
                        slot, int(depths[slot]) + 1,
                        rid=int(self.slot_req[slot]))
            if grew:
                self._sync_pages()
        active = jnp.asarray(self.slot_req >= 0)
        poison = jnp.asarray(self.poison)
        step_fn = self._ref_step() if use_ref else self.serve_step
        nxt, ok, self.cache = step_fn(self.params, self.cache,
                                      self.last_tok, active, poison)
        self.poison[:] = False
        ok = np.asarray(ok)
        adv = (self.slot_req >= 0) & ok
        self.last_tok = jnp.where(jnp.asarray(adv)[:, None], nxt,
                                  self.last_tok)
        self.slot_len[adv] += 1
        done = [s for s in range(self.batch)
                if adv[s] and self.slot_len[s] >= self.slot_target[s]]
        bad = [s for s in range(self.batch)
               if self.slot_req[s] >= 0 and not ok[s]]
        return nxt, done, bad

    def _ref_step(self):
        """The jnp-reference serve step, traced with the fused decode
        kernel forced off (env read at trace time — the jitted trace is
        cached, so the env flip is scoped to the first call)."""
        if self._serve_step_ref is None:
            import os
            fn = jax.jit(steps.make_guarded_serve_step(self.cfg,
                                                       paged=self.paged))
            old = os.environ.get("REPRO_DECODE_KERNEL")
            os.environ["REPRO_DECODE_KERNEL"] = "off"
            try:
                # trace now, under the env override
                fn(self.params, self.cache,
                   self.last_tok, jnp.asarray(self.slot_req >= 0),
                   jnp.asarray(self.poison))
            finally:
                if old is None:
                    os.environ.pop("REPRO_DECODE_KERNEL", None)
                else:
                    os.environ["REPRO_DECODE_KERNEL"] = old
            self._serve_step_ref = fn
        return self._serve_step_ref


def serve_loop(server: Server, lc: Lifecycle, *, watchdog=None,
               max_steps: int = 100_000, source=None, journal=None,
               snapshots=None, start_step: int = 0,
               scheduler=None) -> dict:
    """Drain every admitted request to a terminal state.

    ``scheduler`` (optional, `launch.scheduler.Scheduler`) replaces the
    lifecycle's plain FCFS pop with a pluggable admission policy; with a
    paged server it is also the backpressure valve — requests are
    admitted only when the page allocator can cover their predicted
    footprint, and requests that could never fit are REJECTED loudly.

    The loop invariant replacing the old ``while completed < requests``
    spin: it runs while *any* request is non-terminal (or an arrival
    ``source`` still has requests to submit), and every iteration either
    fills a slot, decodes, jumps the virtual clock to the next
    retry-backoff eligibility or arrival, or raises with the lifecycle
    table — no silent no-progress spinning.  Returns loop-level stats for
    the summary (generated token count, steps, kernel fallbacks).

    ``source`` (optional, see `runtime.loadgen`) is pumped every
    iteration: it submits trace requests whose arrival time has been
    reached on the lifecycle clock.  The loop drives any injected clock
    exposing ``on_step`` with its step counter *before* pumping, filling
    slots, or sweeping deadlines — so a virtual clock (one predicted
    decode-step per loop step) makes arrivals, deadlines, TTFT, and
    per-token latencies fully deterministic.  (Previously an injected
    clock was only ever *read*, never advanced, so chaos/load runs got
    wall-clock — i.e. non-reproducible — TTFT percentiles.)

    Crash tolerance (docs/ROBUSTNESS.md, "Crash recovery"): with a
    ``journal`` (`runtime.journal.Journal`, shared with ``lc.journal``)
    every emitted token is logged write-ahead — durably on disk *before*
    it is appended to the request record — and with ``snapshots``
    (`runtime.snapshot.SnapshotStore`) the full server + lifecycle +
    injector state is checkpointed atomically every ``snapshots.every``
    decode steps, bounding the journal tail a `serve --resume` replays.
    ``start_step`` is the resumed run's virtual-clock origin.  An
    injected `faults.CrashFault` deliberately propagates out of this
    loop: a crash is the one fault the process must NOT absorb.
    """
    step = start_step
    last_snap = start_step
    generated = 0
    kernel_fallbacks = 0
    max_concurrent = 0
    kv_pages_peak = 0
    kv_peak = None           # allocator utilization snapshot at the peak
    kv_ooms = 0
    chunked_prefills = 0
    t_start = time.monotonic()

    def note_kv() -> None:
        nonlocal kv_pages_peak, kv_peak
        a = server.allocator
        if a is not None and a.allocated_pages >= kv_pages_peak:
            kv_pages_peak = a.allocated_pages
            kv_peak = a.utilization()
    first_new_token_s = None
    tick = getattr(lc.clock, "on_step", None)

    def emit(req, tok: int) -> None:
        """Write-ahead token emission: journal first, then append (the
        externally visible effect)."""
        nonlocal first_new_token_s
        if journal is not None:
            journal.token(req.rid, len(req.tokens), tok, step)
        req.tokens.append(tok)
        if first_new_token_s is None:
            first_new_token_s = time.monotonic() - t_start

    def take_snapshot() -> None:
        nonlocal last_snap
        arrays = server.export_state()
        meta = {
            "step": step,
            "lifecycle": snapshot_mod.lifecycle_state(lc),
            "injector": (server.injector.state()
                         if server.injector is not None else None),
        }
        path = snapshots.save(step=step, arrays=arrays, meta=meta,
                              journal_seq=(journal.seq if journal is not None
                                           else 0))
        if journal is not None:
            journal.snapshot(step, path.name)
        last_snap = step

    def pending() -> bool:
        return (lc.open_count() > 0
                or (source is not None and not source.exhausted()))

    while pending():
        if tick is not None:
            tick(step)
        if source is not None:
            source.pump(lc, step)
        if step > max_steps:
            raise RuntimeError(
                f"serve loop exceeded {max_steps} steps without draining; "
                f"lifecycle table:\n{lc.table()}")
        # -- periodic snapshot (crash-tolerance checkpoint) -----------------
        if snapshots is not None and snapshots.due(step, last_snap):
            take_snapshot()
        # -- fill idle slots from the admission queue -----------------------
        admits = []
        for slot in range(server.batch):
            if server.slot_req[slot] >= 0:
                continue
            req = (scheduler.pop_ready(lc, step) if scheduler is not None
                   else lc.pop_ready(step))
            if req is None:
                break
            admits.append((slot, req))
        chunk = None
        if len(admits) > 1 and server.can_chunk():
            # Chunked prefill: every admitted prompt — plus each in-flight
            # decode slot's next token — packed into ONE forward, so a
            # burst of arrivals costs one step instead of stalling decode
            # behind per-request prefills.
            for slot, req in admits:
                lc.transition(req, State.PREFILLING, step)
            ok_admit, c_nxt, c_rode, c_done, c_bad = server.admit_chunk(
                [(slot, req.rid, req.prompt, req.gen_len)
                 for slot, req in admits], step)
            chunked_prefills += 1
            for slot, req in admits:
                if not ok_admit[slot]:
                    server.release_slot(slot)
                    lc.evict(req, step, reason="nan_prefill")
                    continue
                emit(req, int(server.last_tok[slot, 0]))
                lc.record_first_token(req)
                lc.transition(req, State.DECODING, step)
            chunk = (c_nxt, c_rode, c_done, c_bad)
        else:
            for slot, req in admits:
                lc.transition(req, State.PREFILLING, step)
                try:
                    ok = server.prefill(slot, req.rid, req.prompt,
                                        req.gen_len)
                except faults.PrefillInterrupt:
                    # the slot was reset before the interrupt: release it
                    server.release_slot(slot)
                    if server.allocator is not None:
                        server.allocator.release_reservation(req.rid)
                    lc.evict(req, step, reason="prefill_interrupt")
                    continue
                except paging.PageOOM:
                    # Defensive: admission reservations normally cover the
                    # prompt; an overcommitted pool requeues the request
                    # (backpressure), never crashes the server.
                    kv_ooms += 1
                    server.release_slot(slot)
                    if server.allocator is not None:
                        server.allocator.release_reservation(req.rid)
                    lc.evict(req, step, reason="kv_oom")
                    continue
                if not ok:
                    server.release_slot(slot)
                    lc.evict(req, step, reason="nan_prefill")
                    continue
                emit(req, int(server.last_tok[slot, 0]))
                lc.record_first_token(req)
                lc.transition(req, State.DECODING, step)
        max_concurrent = max(max_concurrent,
                             int((server.slot_req >= 0).sum()))
        note_kv()
        # -- deadline sweep -------------------------------------------------
        for req in lc.check_deadlines(step):
            tslot = np.nonzero(server.slot_req == req.rid)[0]
            if tslot.size:
                server.release_slot(int(tslot[0]))
        if not pending():
            break
        # -- progress check -------------------------------------------------
        occupied = server.slot_req >= 0
        if not occupied.any():
            jumps = [s for s in (
                lc.next_eligible_step(),
                source.next_arrival_step(lc, step)
                if source is not None else None) if s is not None]
            if not jumps:
                raise RuntimeError(
                    "serve loop stalled: no occupied slots, empty queue, "
                    f"but {lc.open_count()} request(s) not in a terminal "
                    f"state — a request leaked.  Lifecycle table:\n"
                    f"{lc.table()}")
            # every queued request is in retry backoff (or the next trace
            # arrival is in the future): jump the virtual clock to the
            # earliest eligibility instead of spinning
            step = max(step + 1, min(jumps))
            continue
        # -- one ragged decode step (or the chunk's riding results) ---------
        if chunk is not None:
            # the chunked forward already advanced every riding decode
            # slot; newly admitted slots take their first decode step on
            # the next iteration
            nxt, rode, done, bad = chunk
            advanced = [s for s in rode if s not in bad]
        else:
            t0 = time.monotonic()
            try:
                nxt, done, bad = server.decode_step(step)
            except faults.KernelDispatchFault:
                # graceful degradation: finish the step on the jnp
                # reference path and quarantine the tuned decode plan for
                # re-tune
                kernel_fallbacks += 1
                dp = next((p for p in server.kernel_plan
                           if p.op == "attn_decode"), None)
                if dp is not None:
                    autotune.mark_plan_poisoned(dp.plan.key)
                nxt, done, bad = server.decode_step(step, use_ref=True)
            except paging.PageOOM:
                # Pool overcommit mid-decode (reservations disabled, or a
                # resume without them): evict the cheapest-to-redo slot —
                # fewest generated tokens, deterministic tie-break — and
                # retry the step with its pages back in the pool.
                kv_ooms += 1
                victim = min((s for s in range(server.batch)
                              if server.slot_req[s] >= 0),
                             key=lambda s: (int(server.slot_len[s]), s))
                vreq = lc.requests[int(server.slot_req[victim])]
                server.release_slot(victim)
                lc.evict(vreq, step, reason="kv_oom")
                step += 1
                continue
            if watchdog is not None:
                watchdog.observe(step, time.monotonic() - t0)
            advanced = [s for s in range(server.batch)
                        if server.slot_req[s] >= 0 and s not in bad]
        note_kv()                    # decode growth can also set the peak
        # tokens for every slot that advanced this step
        for slot in advanced:
            emit(lc.requests[int(server.slot_req[slot])], int(nxt[slot, 0]))
            generated += 1
        for slot in bad:
            # quarantine exactly the poisoned slot: reset + requeue; the
            # neighbours' rows were never touched (per-slot masked writes)
            req = lc.requests[int(server.slot_req[slot])]
            server.release_slot(slot)
            lc.evict(req, step, reason="nan_decode")
        for slot in done:
            req = lc.requests[int(server.slot_req[slot])]
            lc.transition(req, State.COMPLETED, step)
            server.release_slot(slot)
        step += 1
    if not lc.conserved():
        raise RuntimeError(
            "request conservation violated after drain: "
            f"{lc.counters()} vs submitted={lc.submitted}.  Lifecycle "
            f"table:\n{lc.table()}")
    return {"generated": generated, "steps": step,
            "kernel_fallbacks": kernel_fallbacks,
            "first_new_token_s": first_new_token_s,
            "max_concurrent": max_concurrent,
            "kv_pages_peak": kv_pages_peak,
            "kv_peak": kv_peak,
            "kv_ooms": kv_ooms,
            "chunked_prefills": chunked_prefills,
            "snapshots_saved": 0 if snapshots is None else snapshots.saved}


def build_fault_plan(*, chaos: bool, fault_seed: int, crash: bool,
                     crash_step: int | None = None):
    """The run's fault schedule: the smoke plan (--chaos), a seeded crash
    (--crash [--crash-step]), or their merge.  None = no injection."""
    plan = faults.FaultPlan.smoke(fault_seed) if chaos else None
    if crash:
        cp = faults.FaultPlan.crash(fault_seed, step=crash_step)
        plan = cp if plan is None else plan.merge(cp)
    return plan


def prepare_resume(state_dir, cfg=None) -> dict:
    """Rebuild the complete serving state of a crashed run from its
    ``--state-dir`` (docs/ROBUSTNESS.md, "Crash recovery").

    Three durable artifacts drive the reconstruction:

    * ``serving.json`` — the static serving context (arch, batch, cache
      geometry, fault schedule, clock rate), written atomically at run
      start so even a crash *before the first snapshot* is resumable;
    * the newest committed snapshot (``snaps/``) — lifecycle table +
      server arrays + injector state at some step S;
    * the journal tail — every record with ``seq`` past the snapshot's
      covered prefix, folded on top to bring the lifecycle to the crash
      point (bounded by the snapshot interval).

    In-flight requests are re-placed onto slots: a slot whose snapshot
    cache already matches the journal (same token count, same last token)
    is kept bitwise; one that advanced past the snapshot — or never made
    it into one — is rebuilt by `Server.restore_slot`'s deterministic
    re-prefill, which *verifies* the journaled continuation.  Requests
    the crash caught mid-transition (PREFILLING, EVICTED, token-less
    DECODING) are demoted to QUEUED and start over, exactly like a fault
    retry.  Must be called inside the mesh/sharding-rules context.

    Returns a dict: cfg, serving, server, lc, journal, snapshots,
    injector, source, step_us, start_step, recovery (the summary block).
    """
    import collections

    sd = pathlib.Path(state_dir)
    serving_path = sd / "serving.json"
    if not serving_path.exists():
        raise FileNotFoundError(
            f"{serving_path}: no serving.json — --resume needs the "
            f"--state-dir of a previous journaled run")
    serving = json.loads(serving_path.read_text())
    if cfg is None:
        cfg = (configs.get_smoke(serving["arch"]) if serving["smoke"]
               else configs.get(serving["arch"]))

    records = journal_mod.read_journal(sd / "journal.jsonl")
    snap = snapshot_mod.latest_snapshot(sd / "snaps")
    step_us = serving.get("step_time_us")
    clock = loadgen.VirtualClock(step_us * 1e-6) if step_us else None

    if snap is not None:
        manifest, arrays = snap
        snap_step = int(manifest["step"])
        start_seq = int(manifest["journal_seq"])
        lc = snapshot_mod.restore_lifecycle(manifest["meta"]["lifecycle"],
                                            clock=clock)
        inj_state = manifest["meta"].get("injector")
    else:
        manifest, arrays = None, None
        snap_step, start_seq = 0, 0
        lc = Lifecycle(queue_limit=serving["queue_limit"],
                       max_retries=serving["max_retries"],
                       **({} if clock is None else {"clock": clock}))
        inj_state = None

    # -- fold the journal tail onto the snapshot ----------------------------
    # Direct field mutation, not transition(): we are replaying a history
    # the state machine already validated, and the admission queue is
    # rebuilt wholesale below (tail records change its membership).
    queued_order = [r.rid for r in lc._queue]

    def queue_drop(rid: int) -> None:
        if rid in queued_order:
            queued_order.remove(rid)

    tail = [r for r in records if r["seq"] >= start_seq]
    last_step = snap_step
    for rec in tail:
        step = int(rec.get("step", -1))
        last_step = max(last_step, step)
        if clock is not None:
            # virtual time is a pure function of the step, so replayed
            # submit/finish stamps land exactly where the live run put them
            clock.on_step(max(step, snap_step))
        kind = rec["kind"]
        if kind == "submit":
            if rec["rid"] in lc.requests:
                continue
            req = Request(rid=rec["rid"],
                          prompt=np.asarray(rec["prompt"], np.int32),
                          gen_len=int(rec["gen_len"]), submit_t=lc.clock(),
                          ttft_deadline_s=rec.get("ttft_deadline_s"),
                          deadline_s=rec.get("deadline_s"))
            lc.requests[req.rid] = req
        elif kind == "state":
            req = lc.requests[rec["rid"]]
            new = State(rec["state"])
            req.retries = int(rec.get("retries", req.retries))
            if new is State.EVICTED:
                lc.evicted_events += 1
            if new is State.QUEUED:
                req.not_before_step = int(rec.get("not_before_step", 0))
                if req.tokens:
                    req.tokens = []       # eviction requeue discards output
                if step >= 0:             # retry requeue, not admission
                    lc.retried_events += 1
                queue_drop(req.rid)
                queued_order.append(req.rid)
            else:
                queue_drop(req.rid)
            if new in TERMINAL and req.finish_t is None:
                req.finish_t = lc.clock()
            req.state = new
            req.history.append((new, step))
        elif kind == "token":
            req = lc.requests[rec["rid"]]
            del req.tokens[int(rec["i"]):]
            req.tokens.append(int(rec["tok"]))
            if req.first_token_t is None:
                req.first_token_t = lc.clock()

    resume_step = last_step + 1

    # -- demote requests the crash caught mid-transition --------------------
    demoted = []

    def demote(req) -> None:
        req.state = State.QUEUED
        req.tokens = []
        req.not_before_step = resume_step
        req.history.append((State.QUEUED, resume_step))
        queue_drop(req.rid)
        queued_order.append(req.rid)
        demoted.append(req.rid)

    for rid in sorted(lc.requests):
        req = lc.requests[rid]
        if req.state in (State.PREFILLING, State.EVICTED) or (
                req.state is State.DECODING and not req.tokens):
            demote(req)

    lc._queue = collections.deque(
        lc.requests[rid] for rid in queued_order
        if lc.requests[rid].state is State.QUEUED)

    if clock is not None:
        clock.on_step(resume_step)
    else:
        # Wall-clock runs: rebase the restored stamps onto this process's
        # monotonic clock so deadlines don't charge the downtime (or a
        # clock discontinuity) to requests that were making progress.
        times = [t for r in lc.requests.values()
                 for t in (r.submit_t, r.first_token_t, r.finish_t)
                 if t is not None]
        if times:
            offset = time.monotonic() - max(times)
            for r in lc.requests.values():
                r.submit_t += offset
                if r.first_token_t is not None:
                    r.first_token_t += offset
                if r.finish_t is not None:
                    r.finish_t += offset

    # -- injector: same seeded schedule, minus the crash that fired ---------
    plan = build_fault_plan(chaos=serving.get("chaos", False),
                            fault_seed=serving.get("fault_seed", 0),
                            crash=serving.get("crash", False),
                            crash_step=serving.get("crash_step"))
    injector = None
    if plan is not None:
        if inj_state is None:
            # crash before the first snapshot: the full plan is pending;
            # prefill ordinals are recovered by counting journaled prefills
            inj_state = {"pending": plan.record(), "fired": [],
                         "prefill_count": sum(
                             1 for r in records if r["kind"] == "state"
                             and r["state"] == State.PREFILLING.value)}
        injector = faults.FaultInjector.restore(plan, inj_state,
                                                resume_step=resume_step)

    # -- server: snapshot arrays + deterministic re-prefill -----------------
    pg = serving.get("paging")
    paged = (paging.PageSpec(page_size=int(pg["page_size"]),
                             num_pages=int(pg["num_pages"]),
                             max_pages=int(pg["max_pages"]))
             if pg else None)
    server = Server(cfg, int(serving["batch"]), int(serving["max_len"]),
                    prefill_len=int(serving["prefill_len"]),
                    slot_lengths=serving["dist"], injector=injector,
                    paged=paged,
                    kv_dtype=jnp.dtype(serving.get("kv_dtype", "float32")))
    if arrays is not None:
        # restore_state re-adopts the page allocator from the restored
        # table (canonical allocation order makes it snapshot-free)
        server.restore_state(arrays)

    reprefilled, placed = [], set()
    for slot in range(server.batch):
        rid = int(server.slot_req[slot])
        if rid < 0:
            continue
        req = lc.requests.get(rid)
        if req is None or req.state is not State.DECODING:
            server.release_slot(slot)     # finished/demoted in the tail
            continue
        if (len(req.tokens) == int(server.slot_len[slot]) + 1
                and int(np.asarray(server.last_tok)[slot, 0])
                == req.tokens[-1]):
            placed.add(rid)               # snapshot already at crash point
            continue
        server.restore_slot(slot, rid, req.prompt, req.tokens, req.gen_len)
        placed.add(rid)
        reprefilled.append(rid)
    for rid in sorted(lc.requests):       # in-flight but not on any slot
        req = lc.requests[rid]
        if req.state is not State.DECODING or rid in placed:
            continue
        free = [s for s in range(server.batch)
                if int(server.slot_req[s]) < 0]
        if not free:
            demote(req)
            lc._queue.append(req)
            continue
        server.restore_slot(free[0], rid, req.prompt, req.tokens,
                            req.gen_len)
        placed.add(rid)
        reprefilled.append(rid)

    # -- scheduler: re-pledge in-flight footprints ---------------------------
    sched_policy = serving.get("sched", "fcfs")
    scheduler = (Scheduler(sched_policy, allocator=server.allocator)
                 if (paged is not None or sched_policy != "fcfs") else None)
    if server.allocator is not None:
        # The dead process's reservations died with it; re-pledge each
        # placed request's *remaining* footprint so post-resume admission
        # prices the pool exactly like the uninterrupted run.
        for slot in range(server.batch):
            rid = int(server.slot_req[slot])
            if rid < 0 or rid not in lc.requests:
                continue
            req = lc.requests[rid]
            total = int(len(req.prompt)) + int(req.gen_len)
            short = (server.allocator.pages_for(total)
                     - server.allocator.slot_pages(slot))
            if short > 0:
                server.allocator.reserve(rid, short * paged.page_size)

    # -- arrival source: re-cursor past the journaled prefix ----------------
    source = None
    if serving.get("load_trace"):
        trace = loadgen.load_trace(serving["load_trace"])
        source = loadgen.TraceSource(trace, cfg.vocab_size)
        source.skip_submitted(lc)

    # -- reattach durability (Journal.__init__ truncates a torn tail) -------
    journal = journal_mod.Journal(sd / "journal.jsonl")
    lc.journal = journal
    snapshots = snapshot_mod.SnapshotStore(
        sd / "snaps", every=serving.get("snapshot_every", 8),
        keep=serving.get("snapshot_keep", 3))

    recovery = {
        "resumed": True,
        "snapshot_step": None if manifest is None else snap_step,
        "resume_step": resume_step,
        "replayed_steps": resume_step - snap_step,
        "replayed_records": len(tail),
        "reprefilled_slots": len(reprefilled),
        "restored_requests": len(lc.requests),
        "demoted": demoted,
    }
    return {"cfg": cfg, "serving": serving, "server": server, "lc": lc,
            "journal": journal, "snapshots": snapshots,
            "injector": injector, "source": source, "step_us": step_us,
            "start_step": resume_step, "recovery": recovery,
            "scheduler": scheduler}


def _summary(server, lc, stats, wall, *, batch, batch_source,
             watchdog, scheduler=None) -> dict:
    """The final conservation-bearing summary line (shared between a
    fresh run and `serve --resume`)."""
    outcomes = lc.counters()
    out = {
        "arch": server.cfg.name,
        "requests": outcomes["completed"],      # back-compat: served count
        "submitted": lc.submitted,
        "batch": batch, "batch_source": batch_source,
        "tokens_generated": stats["generated"],
        "decode_steps": stats["steps"],
        "wall_s": round(wall, 2),
        "tok_per_s": round(stats["generated"] / max(wall, 1e-9), 1),
        "outcomes": outcomes,
        "retries_total": lc.retried_events,
        "kernel_fallbacks": stats["kernel_fallbacks"],
        "snapshots_saved": stats.get("snapshots_saved", 0),
        "max_concurrent": stats.get("max_concurrent", 0),
        "chunked_prefills": stats.get("chunked_prefills", 0),
        "ttft_ms": lc.ttft_percentiles(),
        "per_token_ms": lc.per_token_percentiles(),
        "request_outcomes": lc.outcome_trace(),
        "watchdog": watchdog.summary(),
        "kv_dtype": server.kv_dtype.name,
        "kernel_plan": [p.record() for p in server.kernel_plan],
    }
    if scheduler is not None:
        out["sched"] = {"policy": scheduler.policy,
                        "rejected_oversize": scheduler.rejected_oversize}
    if server.allocator is not None:
        # KV-memory utilization: pages allocated vs tokens actually
        # resident in them at drain (plus the run's peak), the numbers
        # BENCH_serving.json's paging comparison is gated on.
        resident = int(np.asarray(server.cache["lengths"])[
            server.slot_req >= 0].sum())
        out["kv"] = {**server.allocator.utilization(resident),
                     "pages_peak": stats.get("kv_pages_peak", 0),
                     "peak": stats.get("kv_peak"),
                     "kv_ooms": stats.get("kv_ooms", 0)}
    return out


def _run_resume(args) -> int:
    """`serve --resume`: rebuild from --state-dir and drain to a summary
    whose completions are token-for-token those of the uninterrupted
    run."""
    mesh = make_host_mesh(data=1, model=1)
    rules = specs.rules_for(mesh)
    t0 = time.time()
    try:
        with set_mesh(mesh), shd.use_rules(rules):
            R = prepare_resume(args.state_dir)
            server, lc, serving = R["server"], R["lc"], R["serving"]
            if R["injector"] is not None:
                autotune.install_dispatch_hook(R["injector"].dispatch_hook)
            predicted_us = (autotune.predict_decode_step_us(
                server.cfg, server.batch, cache_len=server.max_len,
                kv_dtype=server.kv_dtype,
                lengths=autotune._quantile_lengths(
                    server.batch, serving["dist"], server.max_len),
                plans=server.kernel_plan) if server.kernel_plan else None)
            watchdog = fault_tolerance.DecodeWatchdog(predicted_us)
            prep_s = time.time() - t0
            print(json.dumps({"recovery": {**R["recovery"],
                                           "prepare_s": round(prep_s, 3)}}))
            try:
                stats = serve_loop(server, lc, watchdog=watchdog,
                                   source=R["source"], journal=R["journal"],
                                   snapshots=R["snapshots"],
                                   start_step=R["start_step"],
                                   scheduler=R["scheduler"])
            except faults.CrashFault as cf:
                print(json.dumps({"crash": {"step": cf.step,
                                            "msg": str(cf),
                                            "state_dir": args.state_dir}}))
                R["journal"].close()
                return CRASH_EXIT
            wall = time.time() - t0
            R["journal"].close()
    finally:
        autotune.install_dispatch_hook(None)

    summary = _summary(server, lc, stats, wall, batch=server.batch,
                       batch_source="resume", watchdog=watchdog,
                       scheduler=R["scheduler"])
    summary["recovery"] = {
        **R["recovery"],
        "prepare_s": round(prep_s, 3),
        # --resume start -> first newly generated token: the recovery-
        # latency number the serving benchmark's `recovery` row reports
        "first_new_token_s": (
            None if stats["first_new_token_s"] is None
            else round(prep_s + stats["first_new_token_s"], 3)),
    }
    if R["injector"] is not None:
        summary["faults"] = R["injector"].record()
    if R["source"] is not None:
        summary["load"] = {
            "trace": serving.get("load_trace"),
            "arrivals": len(R["source"].trace),
            "step_time_us": (None if R["step_us"] is None
                             else round(R["step_us"], 3)),
            "queue_depth_max": max((q[1] for q in R["source"].queue_depth),
                                   default=0),
        }
    print(json.dumps(summary))
    return 0


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3_14b",
                    choices=configs.list_archs())
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--batch", type=int, default=0,
                    help="decode batch; 0 = let the autotuner pick "
                         "(select_serving_batch sweep)")
    ap.add_argument("--batch-candidates", type=int, nargs="+",
                    default=[1, 2, 4, 8, 16, 32])
    ap.add_argument("--latency-budget-ms", type=float, default=None,
                    help="per-decode-step latency ceiling for the batch "
                         "sweep (None = pure throughput)")
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen", type=int, default=12)
    ap.add_argument("--paged", action="store_true",
                    help="paged KV cache: a fixed pool of page-size-token "
                         "KV blocks shared across slots through per-slot "
                         "page tables (docs/PAGING.md)")
    ap.add_argument("--page-size", type=int, default=16,
                    help="tokens per KV page (with --paged)")
    ap.add_argument("--pool-pages", type=int, default=0,
                    help="physical pages in the shared pool; 0 = "
                         "contiguous-equivalent "
                         "(batch * ceil(max_len / page_size))")
    ap.add_argument("--kv-dtype", default="f32",
                    choices=["f32", "bf16", "int8"],
                    help="KV-cache storage dtype: int8 streams quantized "
                         "K/V + per-row scales through the decode_int8 "
                         "kernel family (~1.9x fewer bytes per token at "
                         "dh=64)")
    ap.add_argument("--sched", default="fcfs", choices=list(POLICIES),
                    help="admission policy over the request queue; with "
                         "--paged admission is additionally gated on the "
                         "allocator covering the request's predicted "
                         "KV footprint")
    ap.add_argument("--queue-limit", type=int, default=0,
                    help="admission-queue bound; submits past it are "
                         "REJECTED (0 = unbounded)")
    ap.add_argument("--max-retries", type=int, default=2,
                    help="retry budget for evicted/faulted requests")
    ap.add_argument("--ttft-ms", type=float, default=None,
                    help="time-to-first-token deadline per request")
    ap.add_argument("--deadline-ms", type=float, default=None,
                    help="total deadline per request")
    ap.add_argument("--chaos", action="store_true",
                    help="inject the deterministic smoke fault schedule "
                         "(one fault of each class)")
    ap.add_argument("--fault-seed", type=int, default=0,
                    help="seed for the --chaos fault schedule")
    ap.add_argument("--load-trace", default=None,
                    help="replay a runtime.loadgen JSONL trace: arrivals "
                         "fire on a deterministic virtual clock (one "
                         "predicted decode-step per loop step) instead of "
                         "submitting --requests synthetic prompts at t0")
    ap.add_argument("--step-time-us", type=float, default=0.0,
                    help="virtual decode-step time for --load-trace "
                         "replay; 0 = the tuner's predicted step time")
    ap.add_argument("--state-dir", default=None,
                    help="directory for the request journal + state "
                         "snapshots (enables crash tolerance and "
                         "--resume)")
    ap.add_argument("--snapshot-every", type=int, default=8,
                    help="decode steps between state snapshots")
    ap.add_argument("--snapshot-keep", type=int, default=3,
                    help="committed snapshots retained after pruning")
    ap.add_argument("--crash", action="store_true",
                    help="inject a seeded crash fault: the process dies "
                         f"mid-serve (exit {CRASH_EXIT}) leaving only "
                         "the journal + snapshots; combine with "
                         "--state-dir, then `serve --resume`")
    ap.add_argument("--crash-step", type=int, default=None,
                    help="pin the --crash decode step (default: seeded)")
    ap.add_argument("--resume", action="store_true",
                    help="resume a crashed run from --state-dir instead "
                         "of starting fresh")
    args = ap.parse_args(argv)

    if args.resume:
        if not args.state_dir:
            ap.error("--resume requires --state-dir")
        return _run_resume(args)

    cfg = configs.get_smoke(args.arch) if args.smoke else configs.get(args.arch)
    if cfg.family == "encoder":
        print("encoder-only arch has no decode path; nothing to serve")
        return 0
    kv_dtype = {"f32": jnp.float32, "bf16": jnp.bfloat16,
                "int8": jnp.int8}[args.kv_dtype]
    mesh = make_host_mesh(data=1, model=1)
    rules = specs.rules_for(mesh)

    trace = None
    if args.load_trace:
        # Replay mode: the workload comes from the trace file, so the
        # slot-depth distribution and cache allocation are derived from
        # its actual lengths (midpoint depth per request = a slot serving
        # it spends its steady state there).
        trace = loadgen.load_trace(args.load_trace)
        args.requests = len(trace)
        prefill_len = max(t.prompt_len for t in trace)
        max_len = max(t.prompt_len + t.gen_len for t in trace) + 8
        dist = sorted(t.prompt_len + t.gen_len // 2 for t in trace)
    else:
        prefill_len = args.prompt_len
        max_len = args.prompt_len + args.gen + 8
        # Steady-state slot-depth distribution: continuous batching
        # staggers occupied slots roughly uniformly across
        # [prompt, prompt + gen] — the length model the batch sweep and
        # the decode-plan tuning both price.
        n_dist = max(args.batch_candidates + [args.batch, 1])
        dist = [args.prompt_len + ((2 * i + 1) * args.gen) // (2 * n_dist)
                for i in range(n_dist)]

    if args.batch > 0:
        batch = args.batch
        decision = {"batch": batch, "source": "flag"}
    else:
        # The tuner drives the batch: predicted-throughput argmax under the
        # latency budget, from the same cached plans the kernels run with.
        # Candidates beyond the queued workload are pointless (empty slots
        # still pay the step), so cap the sweep at --requests.
        cands = [c for c in args.batch_candidates if c <= args.requests]
        cands = cands or [min(args.batch_candidates)]
        # The sweep prices each candidate at quantiles of the slot-depth
        # distribution — the ragged batch the kernel actually skips on,
        # not the batch-max broadcast that over-charges every short slot.
        decision = autotune.select_serving_batch(
            cfg, cache_len=max_len, prefill_len=prefill_len,
            kv_dtype=kv_dtype,             # the Server's cache dtype
            candidates=tuple(cands),
            slot_lengths=dist,
            latency_budget_ms=args.latency_budget_ms,
            pool_pages=(args.pool_pages or None) if args.paged else None,
            page_size=args.page_size if args.paged else None)
        decision["source"] = "autotune"
        batch = decision["batch"]
    print(json.dumps({"serving_plan": decision}))

    paged = None
    if args.paged:
        if cfg.family not in ("dense", "moe") or not cfg.causal \
                or cfg.sliding_window:
            ap.error("--paged needs a dense/moe causal arch without "
                     "sliding-window attention (the SWA ring buffer is "
                     "contiguous-only)")
        paged = paging.PageSpec.build(batch, max_len, args.page_size,
                                      pool_pages=args.pool_pages)
        print(json.dumps({"paging": {"page_size": paged.page_size,
                                     "num_pages": paged.num_pages,
                                     "max_pages": paged.max_pages}}))

    injector = None
    plan = build_fault_plan(chaos=args.chaos, fault_seed=args.fault_seed,
                            crash=args.crash, crash_step=args.crash_step)
    if plan is not None:
        injector = faults.FaultInjector(plan)
        autotune.install_dispatch_hook(injector.dispatch_hook)
        print(json.dumps({"fault_plan": {"seed": args.fault_seed,
                                         "schedule": plan.record()}}))

    journal = None
    snapshots = None
    state_dir = pathlib.Path(args.state_dir) if args.state_dir else None
    if state_dir is not None:
        # A fresh run owns its state dir: stale journal/snapshot artifacts
        # from a previous run would corrupt recovery accounting.
        state_dir.mkdir(parents=True, exist_ok=True)
        (state_dir / "journal.jsonl").unlink(missing_ok=True)
        for p in (state_dir / "snaps").glob("snap-*"):
            p.unlink()
        journal = journal_mod.Journal(state_dir / "journal.jsonl")
        snapshots = snapshot_mod.SnapshotStore(state_dir / "snaps",
                                               every=args.snapshot_every,
                                               keep=args.snapshot_keep)

    source = None
    step_us = None
    if trace is not None:
        # Virtual clock: one predicted decode-step of wall time per loop
        # step, so TTFT / per-token percentiles are deterministic and
        # denominated in model-milliseconds.
        step_us = args.step_time_us or loadgen.virtual_step_us(
            decision.get("predicted_step_us")
            or autotune.predict_decode_step_us(
                cfg, batch, cache_len=max_len, kv_dtype=kv_dtype,
                lengths=autotune._quantile_lengths(batch, dist, max_len)))
        clock = loadgen.VirtualClock(step_us * 1e-6)
        source = loadgen.TraceSource(trace, cfg.vocab_size)
        lc = Lifecycle(queue_limit=args.queue_limit,
                       max_retries=args.max_retries, clock=clock,
                       journal=journal)
    else:
        rng = np.random.default_rng(0)
        reqs = [(i, rng.integers(0, cfg.vocab_size, size=args.prompt_len),
                 args.gen) for i in range(args.requests)]
        lc = Lifecycle(queue_limit=args.queue_limit,
                       max_retries=args.max_retries, journal=journal)
        for rid, prompt, gen in reqs:
            lc.submit(rid, prompt, gen,
                      ttft_deadline_s=(args.ttft_ms / 1e3
                                       if args.ttft_ms else None),
                      deadline_s=(args.deadline_ms / 1e3
                                  if args.deadline_ms else None))

    if state_dir is not None:
        # The static serving context, durable before any decode step can
        # crash: `serve --resume` derives the server geometry, clock rate
        # and fault schedule from this even when the crash predates the
        # first snapshot.
        snapshot_mod.atomic_write_json(state_dir / "serving.json", {
            "arch": args.arch, "smoke": bool(args.smoke),
            "batch": batch, "max_len": max_len,
            "prefill_len": prefill_len, "dist": [int(d) for d in dist],
            "decision": decision,
            "queue_limit": args.queue_limit,
            "max_retries": args.max_retries,
            "snapshot_every": args.snapshot_every,
            "snapshot_keep": args.snapshot_keep,
            "step_time_us": step_us,
            "load_trace": args.load_trace,
            "chaos": bool(args.chaos), "fault_seed": args.fault_seed,
            "crash": bool(args.crash), "crash_step": args.crash_step,
            "requests": args.requests, "prompt_len": args.prompt_len,
            "gen": args.gen,
            "ttft_ms": args.ttft_ms, "deadline_ms": args.deadline_ms,
            "paging": (None if paged is None else
                       {"page_size": paged.page_size,
                        "num_pages": paged.num_pages,
                        "max_pages": paged.max_pages}),
            "sched": args.sched,
            "kv_dtype": jnp.dtype(kv_dtype).name,
        })

    try:
        with set_mesh(mesh), shd.use_rules(rules):
            server = Server(cfg, batch, max_len,
                            prefill_len=prefill_len,
                            slot_lengths=dist, injector=injector,
                            paged=paged, kv_dtype=kv_dtype)
            scheduler = (Scheduler(args.sched, allocator=server.allocator)
                         if (paged is not None or args.sched != "fcfs")
                         else None)
            predicted_us = (autotune.predict_decode_step_us(
                cfg, batch, cache_len=max_len, kv_dtype=kv_dtype,
                lengths=autotune._quantile_lengths(batch, dist, max_len),
                plans=server.kernel_plan)
                if server.kernel_plan else None)
            watchdog = fault_tolerance.DecodeWatchdog(predicted_us)
            t0 = time.time()
            try:
                stats = serve_loop(server, lc, watchdog=watchdog,
                                   source=source, journal=journal,
                                   snapshots=snapshots,
                                   scheduler=scheduler)
            except faults.CrashFault as cf:
                # The one fault class the process must NOT absorb: die
                # with no summary (the conservation line never prints) and
                # a distinct exit code.  Only the journal + snapshots
                # survive, for `serve --resume`.
                print(json.dumps({"crash": {"step": cf.step,
                                            "msg": str(cf),
                                            "state_dir": args.state_dir}}))
                if journal is not None:
                    journal.close()
                return CRASH_EXIT
            wall = time.time() - t0
            if journal is not None:
                journal.close()
    finally:
        autotune.install_dispatch_hook(None)

    summary = _summary(server, lc, stats, wall, batch=batch,
                       batch_source=decision["source"], watchdog=watchdog,
                       scheduler=scheduler)
    if injector is not None:
        summary["faults"] = injector.record()
    if source is not None:
        summary["load"] = {
            "trace": args.load_trace,
            "arrivals": len(trace),
            "step_time_us": round(step_us, 3),
            "queue_depth_max": max((q[1] for q in source.queue_depth),
                                   default=0),
        }
    print(json.dumps(summary))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
