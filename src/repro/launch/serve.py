"""Fault-tolerant batched serving driver: ragged continuous batching with a
request lifecycle, graceful degradation, and a chaos mode.

Requests enter a bounded admission queue (`runtime.lifecycle`) and move
through an enforced state machine (QUEUED → PREFILLING → DECODING →
{COMPLETED, TIMED_OUT, EVICTED, FAILED, REJECTED}); the server packs up to
``--batch`` sequences, prefills each arriving request with a *masked
batched prefill* (only the target slot's cache rows are written, from
depth 0), then decodes with per-slot cache depths — every slot attends
only over its own valid prefix, carried as the cache's ``lengths: (B,)``
vector all the way into the fused decode kernel's scalar-prefetch skip.
Finished slots are zeroed and refilled from the queue (continuous
batching); ``--batch 0`` (the default) asks the autotuner for the batch
(`autotune.select_serving_batch`, priced at quantiles of the workload's
slot-depth distribution under ``--latency-budget-ms``).

The robustness layer on top (see docs/ROBUSTNESS.md):

* a per-slot NaN/Inf logits guard — a poisoned slot is quarantined alone
  (reset + requeued with backoff) while its neighbours keep decoding
  bitwise-identically;
* kernel-dispatch failure falls back one-shot to the jnp reference step
  with the plan marked poisoned for re-tune;
* per-request deadlines (TTFT and total) and retry-with-backoff, with the
  drain loop failing loudly (lifecycle table) instead of spinning when no
  progress is possible;
* a decode watchdog (`runtime.fault_tolerance.DecodeWatchdog`) comparing
  measured step time against `predict_decode_step_us`;
* ``--chaos --fault-seed N``: a deterministic fault schedule
  (`runtime.faults`) injecting one fault of each class;
* ``--load-trace trace.jsonl``: replay a seeded `runtime.loadgen` trace —
  arrivals fire on a deterministic virtual clock (one predicted
  decode-step of time per loop step), the replay path behind the
  traffic-shaped benchmark `benchmarks/serving_load.py`
  (docs/SERVING_BENCH.md).

The final summary line conserves every submitted request exactly once:
``submitted == completed + timed_out + failed + rejected``.  Runs on CPU
with smoke configs:

  PYTHONPATH=src python -m repro.launch.serve --arch qwen3_14b --smoke \
      --requests 6 --prompt-len 16 --gen 12 [--chaos --fault-seed 0]
"""

from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

import repro.configs as configs
from repro.kernels import autotune
from repro.launch import steps
from repro.launch.mesh import make_host_mesh, set_mesh
from repro.launch import specs
from repro.models import transformer
from repro.parallel import sharding as shd
from repro.runtime import fault_tolerance, faults, loadgen
from repro.runtime.lifecycle import Lifecycle, State


class Server:
    def __init__(self, cfg, batch: int, max_len: int,
                 prefill_len: int = 0, autotune_kernels: bool = True,
                 slot_lengths=None, injector=None):
        self.cfg = cfg
        self.batch = batch
        self.max_len = max_len
        # Close the DSE loop before taking traffic: pre-tune the decode-path
        # matmul shapes, the prefill flash-attention shape AND the fused
        # decode-attention fold so the kernel engine's cache is warm
        # (analytic-only here — measurement happens offline / on first TPU
        # run).  `plan_for_model` returns typed OpPlans; they are
        # serialized via `.record()` when logged below.
        # kv_dtype matches the cache_init dtype below — the decode plan is
        # keyed on the dtype the kernel actually streams.
        # `slot_lengths` is the workload's steady-state slot-depth
        # distribution: the decode plan is tuned on its quantiles (and
        # pinned under the runtime dispatch key), so the fused kernel runs
        # the ragged-workload-aware block, not the batch-max one.
        self.kernel_plan = (autotune.plan_for_model(cfg, batch,
                                                    prefill_len=prefill_len,
                                                    cache_len=max_len,
                                                    kv_dtype=jnp.float32,
                                                    slot_lengths=slot_lengths)
                            if autotune_kernels else [])
        self.params = transformer.init(cfg, jax.random.PRNGKey(0),
                                       dtype=jnp.float32)
        self.serve_step = jax.jit(steps.make_guarded_serve_step(cfg))
        # The degradation step: same math forced onto the jnp reference
        # path ($REPRO_DECODE_KERNEL=off at trace time) — built lazily on
        # the first kernel-dispatch fault.
        self._serve_step_ref = None
        self.injector = injector
        self.cache = transformer.cache_init(cfg, batch, max_len,
                                            dtype=jnp.float32)
        self.slot_len = np.zeros(batch, np.int32)      # tokens generated
        self.slot_target = np.zeros(batch, np.int32)   # stop length
        self.slot_req = -np.ones(batch, np.int32)      # request id
        self.last_tok = jnp.zeros((batch, 1), jnp.int32)
        self.poison = np.zeros(batch, bool)            # chaos logits-NaN arm

    def prefill(self, slot: int, req_id: int, prompt: np.ndarray,
                gen_len: int) -> bool:
        """Masked batched prefill of one slot: the whole prompt in a single
        forward whose ``active`` mask is the slot's one-hot, so ONLY this
        slot's cache rows are written and only its per-slot length advances
        from depth 0.  (The previous slot-local loop stepped the *shared*
        cache with zero tokens for every other slot, silently polluting
        their KV entries and advancing their depths.)  The recycled slot's
        stale KV/state rows are zeroed first — a refilled slot must be
        indistinguishable from a fresh one.

        Returns True iff the slot's first-token logits were finite (the
        per-slot guard); may raise `faults.PrefillInterrupt` in chaos mode
        *after* the slot reset — the interrupted slot is left zeroed, so a
        caller can simply release it and requeue the request."""
        prompt = np.asarray(prompt, np.int32)
        if self.cfg.sliding_window:
            # The ring buffer keeps at most `window` keys; feeding more in
            # one masked scatter would alias ring rows. A fresh slot only
            # ever attends the last `window` prompt tokens anyway.
            prompt = prompt[-self.cfg.sliding_window:]
        self.cache = transformer.cache_reset_slot(self.cache, slot)
        if self.injector is not None:
            self.injector.prefill_hook(slot, req_id)   # may raise
        toks = jnp.zeros((self.batch, prompt.size),
                         jnp.int32).at[slot].set(prompt)
        active = jnp.zeros((self.batch,), jnp.bool_).at[slot].set(True)
        nxt, ok, self.cache = self.serve_step(self.params, self.cache, toks,
                                              active)
        self.last_tok = self.last_tok.at[slot, 0].set(int(nxt[slot, 0]))
        self.slot_len[slot] = 0
        self.slot_target[slot] = gen_len
        self.slot_req[slot] = req_id
        return bool(np.asarray(ok)[slot])

    def release_slot(self, slot: int) -> None:
        """Free a slot and zero its cache rows — quarantine for a poisoned
        slot, plain recycling for a completed one (the zeroing is also done
        by the next prefill; doing it here means a NaN-corrupted slot never
        sits armed in the cache)."""
        self.slot_req[slot] = -1
        self.cache = transformer.cache_reset_slot(self.cache, slot)

    def corrupt_kv(self, slot: int) -> None:
        """Chaos hook: NaN over one slot's KV/state cache rows."""
        self.cache = transformer.cache_poison_slot(self.cache, slot)

    def decode_step(self, step: int = 0, use_ref: bool = False):
        """One ragged decode step: every occupied slot attends over its own
        valid cache prefix (per-slot ``lengths`` threaded down to the fused
        decode kernel's scalar-prefetch vector); idle slots neither write
        nor advance.

        Returns ``(next_tokens, done_slots, bad_slots)``: ``done`` slots
        hit their stop length this step; ``bad`` slots produced non-finite
        logits (per-slot guard) — their token is discarded, they did not
        advance, and the caller must quarantine them.  ``use_ref=True``
        runs the jnp-reference step (kernel-dispatch degradation path).
        May raise `faults.KernelDispatchFault` in chaos mode."""
        if self.injector is not None and not use_ref:
            self.injector.apply_decode_faults(self, step)   # may raise
        active = jnp.asarray(self.slot_req >= 0)
        poison = jnp.asarray(self.poison)
        step_fn = self._ref_step() if use_ref else self.serve_step
        nxt, ok, self.cache = step_fn(self.params, self.cache,
                                      self.last_tok, active, poison)
        self.poison[:] = False
        ok = np.asarray(ok)
        adv = (self.slot_req >= 0) & ok
        self.last_tok = jnp.where(jnp.asarray(adv)[:, None], nxt,
                                  self.last_tok)
        self.slot_len[adv] += 1
        done = [s for s in range(self.batch)
                if adv[s] and self.slot_len[s] >= self.slot_target[s]]
        bad = [s for s in range(self.batch)
               if self.slot_req[s] >= 0 and not ok[s]]
        return nxt, done, bad

    def _ref_step(self):
        """The jnp-reference serve step, traced with the fused decode
        kernel forced off (env read at trace time — the jitted trace is
        cached, so the env flip is scoped to the first call)."""
        if self._serve_step_ref is None:
            import os
            fn = jax.jit(steps.make_guarded_serve_step(self.cfg))
            old = os.environ.get("REPRO_DECODE_KERNEL")
            os.environ["REPRO_DECODE_KERNEL"] = "off"
            try:
                # trace now, under the env override
                fn(self.params, self.cache,
                   self.last_tok, jnp.asarray(self.slot_req >= 0),
                   jnp.asarray(self.poison))
            finally:
                if old is None:
                    os.environ.pop("REPRO_DECODE_KERNEL", None)
                else:
                    os.environ["REPRO_DECODE_KERNEL"] = old
            self._serve_step_ref = fn
        return self._serve_step_ref


def serve_loop(server: Server, lc: Lifecycle, *, watchdog=None,
               max_steps: int = 100_000, source=None) -> dict:
    """Drain every admitted request to a terminal state.

    The loop invariant replacing the old ``while completed < requests``
    spin: it runs while *any* request is non-terminal (or an arrival
    ``source`` still has requests to submit), and every iteration either
    fills a slot, decodes, jumps the virtual clock to the next
    retry-backoff eligibility or arrival, or raises with the lifecycle
    table — no silent no-progress spinning.  Returns loop-level stats for
    the summary (generated token count, steps, kernel fallbacks).

    ``source`` (optional, see `runtime.loadgen`) is pumped every
    iteration: it submits trace requests whose arrival time has been
    reached on the lifecycle clock.  The loop drives any injected clock
    exposing ``on_step`` with its step counter *before* pumping, filling
    slots, or sweeping deadlines — so a virtual clock (one predicted
    decode-step per loop step) makes arrivals, deadlines, TTFT, and
    per-token latencies fully deterministic.  (Previously an injected
    clock was only ever *read*, never advanced, so chaos/load runs got
    wall-clock — i.e. non-reproducible — TTFT percentiles.)
    """
    step = 0
    generated = 0
    kernel_fallbacks = 0
    tick = getattr(lc.clock, "on_step", None)

    def pending() -> bool:
        return (lc.open_count() > 0
                or (source is not None and not source.exhausted()))

    while pending():
        if tick is not None:
            tick(step)
        if source is not None:
            source.pump(lc, step)
        if step > max_steps:
            raise RuntimeError(
                f"serve loop exceeded {max_steps} steps without draining; "
                f"lifecycle table:\n{lc.table()}")
        # -- fill idle slots from the admission queue -----------------------
        for slot in range(server.batch):
            if server.slot_req[slot] >= 0:
                continue
            req = lc.pop_ready(step)
            if req is None:
                break
            lc.transition(req, State.PREFILLING, step)
            try:
                ok = server.prefill(slot, req.rid, req.prompt, req.gen_len)
            except faults.PrefillInterrupt:
                # the slot was reset before the interrupt: just release it
                server.release_slot(slot)
                lc.evict(req, step, reason="prefill_interrupt")
                continue
            if not ok:
                server.release_slot(slot)
                lc.evict(req, step, reason="nan_prefill")
                continue
            req.tokens.append(int(server.last_tok[slot, 0]))
            lc.record_first_token(req)
            lc.transition(req, State.DECODING, step)
        # -- deadline sweep -------------------------------------------------
        for req in lc.check_deadlines(step):
            tslot = np.nonzero(server.slot_req == req.rid)[0]
            if tslot.size:
                server.release_slot(int(tslot[0]))
        if not pending():
            break
        # -- progress check -------------------------------------------------
        occupied = server.slot_req >= 0
        if not occupied.any():
            jumps = [s for s in (
                lc.next_eligible_step(),
                source.next_arrival_step(lc, step)
                if source is not None else None) if s is not None]
            if not jumps:
                raise RuntimeError(
                    "serve loop stalled: no occupied slots, empty queue, "
                    f"but {lc.open_count()} request(s) not in a terminal "
                    f"state — a request leaked.  Lifecycle table:\n"
                    f"{lc.table()}")
            # every queued request is in retry backoff (or the next trace
            # arrival is in the future): jump the virtual clock to the
            # earliest eligibility instead of spinning
            step = max(step + 1, min(jumps))
            continue
        # -- one ragged decode step -----------------------------------------
        t0 = time.monotonic()
        try:
            nxt, done, bad = server.decode_step(step)
        except faults.KernelDispatchFault:
            # graceful degradation: finish the step on the jnp reference
            # path and quarantine the tuned decode plan for re-tune
            kernel_fallbacks += 1
            dp = next((p for p in server.kernel_plan
                       if p.op == "attn_decode"), None)
            if dp is not None:
                autotune.mark_plan_poisoned(dp.plan.key)
            nxt, done, bad = server.decode_step(step, use_ref=True)
        if watchdog is not None:
            watchdog.observe(step, time.monotonic() - t0)
        # tokens for every slot that advanced this step
        for slot in range(server.batch):
            rid = int(server.slot_req[slot])
            if rid >= 0 and slot not in bad:
                lc.requests[rid].tokens.append(int(nxt[slot, 0]))
                generated += 1
        for slot in bad:
            # quarantine exactly the poisoned slot: reset + requeue; the
            # neighbours' rows were never touched (per-slot masked writes)
            req = lc.requests[int(server.slot_req[slot])]
            server.release_slot(slot)
            lc.evict(req, step, reason="nan_decode")
        for slot in done:
            req = lc.requests[int(server.slot_req[slot])]
            lc.transition(req, State.COMPLETED, step)
            server.release_slot(slot)
        step += 1
    if not lc.conserved():
        raise RuntimeError(
            "request conservation violated after drain: "
            f"{lc.counters()} vs submitted={lc.submitted}.  Lifecycle "
            f"table:\n{lc.table()}")
    return {"generated": generated, "steps": step,
            "kernel_fallbacks": kernel_fallbacks}


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3_14b",
                    choices=configs.list_archs())
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--batch", type=int, default=0,
                    help="decode batch; 0 = let the autotuner pick "
                         "(select_serving_batch sweep)")
    ap.add_argument("--batch-candidates", type=int, nargs="+",
                    default=[1, 2, 4, 8, 16, 32])
    ap.add_argument("--latency-budget-ms", type=float, default=None,
                    help="per-decode-step latency ceiling for the batch "
                         "sweep (None = pure throughput)")
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen", type=int, default=12)
    ap.add_argument("--queue-limit", type=int, default=0,
                    help="admission-queue bound; submits past it are "
                         "REJECTED (0 = unbounded)")
    ap.add_argument("--max-retries", type=int, default=2,
                    help="retry budget for evicted/faulted requests")
    ap.add_argument("--ttft-ms", type=float, default=None,
                    help="time-to-first-token deadline per request")
    ap.add_argument("--deadline-ms", type=float, default=None,
                    help="total deadline per request")
    ap.add_argument("--chaos", action="store_true",
                    help="inject the deterministic smoke fault schedule "
                         "(one fault of each class)")
    ap.add_argument("--fault-seed", type=int, default=0,
                    help="seed for the --chaos fault schedule")
    ap.add_argument("--load-trace", default=None,
                    help="replay a runtime.loadgen JSONL trace: arrivals "
                         "fire on a deterministic virtual clock (one "
                         "predicted decode-step per loop step) instead of "
                         "submitting --requests synthetic prompts at t0")
    ap.add_argument("--step-time-us", type=float, default=0.0,
                    help="virtual decode-step time for --load-trace "
                         "replay; 0 = the tuner's predicted step time")
    args = ap.parse_args(argv)

    cfg = configs.get_smoke(args.arch) if args.smoke else configs.get(args.arch)
    if cfg.family == "encoder":
        print("encoder-only arch has no decode path; nothing to serve")
        return 0
    mesh = make_host_mesh(data=1, model=1)
    rules = specs.rules_for(mesh)

    trace = None
    if args.load_trace:
        # Replay mode: the workload comes from the trace file, so the
        # slot-depth distribution and cache allocation are derived from
        # its actual lengths (midpoint depth per request = a slot serving
        # it spends its steady state there).
        trace = loadgen.load_trace(args.load_trace)
        args.requests = len(trace)
        prefill_len = max(t.prompt_len for t in trace)
        max_len = max(t.prompt_len + t.gen_len for t in trace) + 8
        dist = sorted(t.prompt_len + t.gen_len // 2 for t in trace)
    else:
        prefill_len = args.prompt_len
        max_len = args.prompt_len + args.gen + 8
        # Steady-state slot-depth distribution: continuous batching
        # staggers occupied slots roughly uniformly across
        # [prompt, prompt + gen] — the length model the batch sweep and
        # the decode-plan tuning both price.
        n_dist = max(args.batch_candidates + [args.batch, 1])
        dist = [args.prompt_len + ((2 * i + 1) * args.gen) // (2 * n_dist)
                for i in range(n_dist)]

    if args.batch > 0:
        batch = args.batch
        decision = {"batch": batch, "source": "flag"}
    else:
        # The tuner drives the batch: predicted-throughput argmax under the
        # latency budget, from the same cached plans the kernels run with.
        # Candidates beyond the queued workload are pointless (empty slots
        # still pay the step), so cap the sweep at --requests.
        cands = [c for c in args.batch_candidates if c <= args.requests]
        cands = cands or [min(args.batch_candidates)]
        # The sweep prices each candidate at quantiles of the slot-depth
        # distribution — the ragged batch the kernel actually skips on,
        # not the batch-max broadcast that over-charges every short slot.
        decision = autotune.select_serving_batch(
            cfg, cache_len=max_len, prefill_len=prefill_len,
            kv_dtype=jnp.float32,          # the Server's cache dtype
            candidates=tuple(cands),
            slot_lengths=dist,
            latency_budget_ms=args.latency_budget_ms)
        decision["source"] = "autotune"
        batch = decision["batch"]
    print(json.dumps({"serving_plan": decision}))

    injector = None
    if args.chaos:
        plan = faults.FaultPlan.smoke(args.fault_seed)
        injector = faults.FaultInjector(plan)
        autotune.install_dispatch_hook(injector.dispatch_hook)
        print(json.dumps({"fault_plan": {"seed": args.fault_seed,
                                         "schedule": plan.record()}}))

    source = None
    step_us = None
    if trace is not None:
        # Virtual clock: one predicted decode-step of wall time per loop
        # step, so TTFT / per-token percentiles are deterministic and
        # denominated in model-milliseconds.
        step_us = args.step_time_us or loadgen.virtual_step_us(
            decision.get("predicted_step_us")
            or autotune.predict_decode_step_us(
                cfg, batch, cache_len=max_len, kv_dtype=jnp.float32,
                lengths=autotune._quantile_lengths(batch, dist, max_len)))
        clock = loadgen.VirtualClock(step_us * 1e-6)
        source = loadgen.TraceSource(trace, cfg.vocab_size)
        lc = Lifecycle(queue_limit=args.queue_limit,
                       max_retries=args.max_retries, clock=clock)
    else:
        rng = np.random.default_rng(0)
        reqs = [(i, rng.integers(0, cfg.vocab_size, size=args.prompt_len),
                 args.gen) for i in range(args.requests)]
        lc = Lifecycle(queue_limit=args.queue_limit,
                       max_retries=args.max_retries)
        for rid, prompt, gen in reqs:
            lc.submit(rid, prompt, gen,
                      ttft_deadline_s=(args.ttft_ms / 1e3
                                       if args.ttft_ms else None),
                      deadline_s=(args.deadline_ms / 1e3
                                  if args.deadline_ms else None))

    try:
        with set_mesh(mesh), shd.use_rules(rules):
            server = Server(cfg, batch, max_len,
                            prefill_len=prefill_len,
                            slot_lengths=dist, injector=injector)
            predicted_us = (autotune.predict_decode_step_us(
                cfg, batch, cache_len=max_len, kv_dtype=jnp.float32,
                lengths=autotune._quantile_lengths(batch, dist, max_len),
                plans=server.kernel_plan)
                if server.kernel_plan else None)
            watchdog = fault_tolerance.DecodeWatchdog(predicted_us)
            t0 = time.time()
            stats = serve_loop(server, lc, watchdog=watchdog, source=source)
            wall = time.time() - t0
    finally:
        autotune.install_dispatch_hook(None)

    outcomes = lc.counters()
    summary = {
        "arch": cfg.name,
        "requests": outcomes["completed"],      # back-compat: served count
        "submitted": lc.submitted,
        "batch": batch, "batch_source": decision["source"],
        "tokens_generated": stats["generated"],
        "decode_steps": stats["steps"],
        "wall_s": round(wall, 2),
        "tok_per_s": round(stats["generated"] / max(wall, 1e-9), 1),
        "outcomes": outcomes,
        "retries_total": lc.retried_events,
        "kernel_fallbacks": stats["kernel_fallbacks"],
        "ttft_ms": lc.ttft_percentiles(),
        "per_token_ms": lc.per_token_percentiles(),
        "request_outcomes": lc.outcome_trace(),
        "watchdog": watchdog.summary(),
        "kernel_plan": [p.record() for p in server.kernel_plan],
    }
    if injector is not None:
        summary["faults"] = injector.record()
    if source is not None:
        summary["load"] = {
            "trace": args.load_trace,
            "arrivals": len(trace),
            "step_time_us": round(step_us, 3),
            "queue_depth_max": max((q[1] for q in source.queue_depth),
                                   default=0),
        }
    print(json.dumps(summary))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
