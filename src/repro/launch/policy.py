"""Number-format policy per architecture (the paper's 'number format' knob).

Models above ~100B parameters store bf16 weights and int8 blockwise optimizer
moments so state fits 16 GB/chip HBM on the 256-chip pod (DESIGN.md §Risks);
smaller models keep f32 master weights and f32 moments.
"""

from __future__ import annotations

import jax.numpy as jnp

from repro.models.config import ModelConfig

BIG_MODEL_PARAMS = 100e9
FSDP_PARAMS = 10e9


def param_dtype(cfg: ModelConfig):
    return jnp.bfloat16 if cfg.param_count() > BIG_MODEL_PARAMS else jnp.float32


def moment_dtype(cfg: ModelConfig) -> str:
    return "int8" if cfg.param_count() > BIG_MODEL_PARAMS else "float32"


def use_fsdp(cfg: ModelConfig) -> bool:
    """>=10B params: store parameters sharded over the DP axes too (FSDP);
    XLA gathers weights at use — per-layer weight all-gathers are tiny next
    to activation traffic, and TP-only storage doesn't fit 16 GB/chip."""
    return cfg.param_count() >= FSDP_PARAMS
