import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

This is the system's analogue of the paper's SystemC system-level simulation:
the compiled artifact proves the generated design is coherent (shardings
compose, memory fits) and yields the machine-model numbers (FLOPs, bytes,
collective traffic) the roofline analysis consumes.

Usage:
  python -m repro.launch.dryrun --arch qwen3_14b --shape train_4k --mesh single
  python -m repro.launch.dryrun --all            # every applicable cell
"""

import argparse
import dataclasses
import json
import sys
import time
import traceback
from pathlib import Path

import jax
import jax.numpy as jnp

import repro.configs as configs
from repro.configs.shapes import SHAPES, applicable
from repro.core import cost_model, estimate, hlo_stats
from repro.launch import policy, specs, steps
from repro.launch.mesh import make_production_mesh, set_mesh
from repro.optim import adamw
from repro.parallel import sharding as shd

ARTIFACTS = Path(__file__).resolve().parents[3] / "artifacts" / "dryrun"


def _mesh(kind: str):
    return make_production_mesh(multi_pod=(kind == "multi"))


def opt_config(cfg) -> adamw.AdamWConfig:
    return adamw.AdamWConfig(moment_dtype=policy.moment_dtype(cfg))


# §Perf hillclimb variants: each is (rules transform, cfg transform,
# train-step kwargs).  "baseline" is the paper-faithful configuration.
VARIANTS = {
    "baseline": {},
    "sp": {"rules": "sequence_parallel"},          # Megatron-style SP
    "bf16grad": {"grad_dtype": "bfloat16"},        # compressed grad sync
    "sp_bf16grad": {"rules": "sequence_parallel",
                    "grad_dtype": "bfloat16"},
    "lowcap": {"cfg": {"capacity_factor": 1.0}},   # tighter MoE capacity
    "sp_lowcap": {"rules": "sequence_parallel",
                  "cfg": {"capacity_factor": 1.0}},
    "sp_bf16grad_lowcap": {"rules": "sequence_parallel",
                           "grad_dtype": "bfloat16",
                           "cfg": {"capacity_factor": 1.0}},
    "bigchunk": {"cfg": {"attn_chunk": 2048}},     # fewer, larger q-chunks
    "dp_only": {"rules": "data_parallel_only"},    # no TP (small models)
    "dp_only_bf16grad": {"rules": "data_parallel_only",
                         "grad_dtype": "bfloat16"},
    # ZeRO-3-style: weights stay sharded in state, attention activations
    # batch-sharded (XLA gathers weights per layer instead of all-reducing
    # activations).  act_rules only — state keeps the base shardings.
    "attn_dp": {"act_rules": "data_parallel_attention"},
    "attn_dp_lowcap": {"act_rules": "data_parallel_attention",
                       "cfg": {"capacity_factor": 1.0}},
    "sp_attn_dp": {"rules": "sequence_parallel",
                   "act_rules": "data_parallel_attention"},
}

_RULE_FNS = {
    "sequence_parallel": shd.sequence_parallel,
    "data_parallel_only": shd.data_parallel_only,
    "data_parallel_attention": shd.data_parallel_attention,
}


def apply_variant(cfg, rules, variant: str):
    """Returns (cfg, act_rules, state_rules, step_kwargs)."""
    spec = VARIANTS[variant]
    state_rules = rules
    if "rules" in spec:  # applies to both activations and state
        rules = _RULE_FNS[spec["rules"]](rules)
        state_rules = rules
    if "act_rules" in spec:
        rules = _RULE_FNS[spec["act_rules"]](rules)
    if "cfg" in spec:
        cfg = dataclasses.replace(cfg, **spec["cfg"])
    kwargs = {}
    if "grad_dtype" in spec:
        kwargs["grad_dtype"] = jnp.bfloat16
    return cfg, rules, state_rules, kwargs


def _lower_step(cfg, shape, mesh, rules, donate: bool = True,
                step_kwargs: dict | None = None, state_rules=None):
    """Build + lower the step for one cell.  Returns (lowered, tokens,
    model_flops).  ``state_rules`` (default = rules) governs param/optimizer
    shardings; ``rules`` governs activations/batch."""
    step_kwargs = step_kwargs or {}
    state_rules = state_rules or rules
    if shape.kind == "decode":
        abs_, sh = specs.decode_specs(cfg, shape, mesh, rules,
                                      state_rules=state_rules)
        step = steps.make_serve_step(cfg)
        jitted = jax.jit(
            step,
            in_shardings=(sh["params"], sh["cache"], sh["tokens"]),
            out_shardings=(sh["tokens"], sh["cache"]),
            donate_argnums=(1,) if donate else (),
        )
        lowered = jitted.lower(abs_["params"], abs_["cache"], abs_["tokens"])
        tokens = shape.global_batch  # one new token per sequence
        model_flops = cost_model.model_flops_decode(
            cfg.active_param_count(), tokens)
    else:
        opt_cfg = opt_config(cfg)
        state_abs, state_sh = specs.state_shardings(cfg, opt_cfg, mesh,
                                                    state_rules)
        b_abs = specs.batch_specs(cfg, shape)
        b_sh = specs.batch_shardings(cfg, shape, mesh, rules)
        tokens = shape.global_batch * shape.seq_len
        if shape.kind == "train":
            step = steps.make_train_step(cfg, opt_cfg, **step_kwargs)
            jitted = jax.jit(
                step,
                in_shardings=(state_sh, b_sh),
                out_shardings=(state_sh, None),
                donate_argnums=(0,) if donate else (),
            )
            lowered = jitted.lower(state_abs, b_abs)
            model_flops = cost_model.model_flops_train(
                cfg.active_param_count(), tokens)
        else:  # prefill
            step = steps.make_prefill_step(cfg)
            jitted = jax.jit(step, in_shardings=(state_sh["params"], b_sh))
            lowered = jitted.lower(state_abs["params"], b_abs)
            model_flops = cost_model.model_flops_decode(
                cfg.active_param_count(), tokens)
    return lowered, tokens, model_flops


def _compiled_stats(compiled, chips: int) -> dict:
    """Whole-cluster stats.  cost_analysis() and the HLO dump describe ONE
    device's SPMD program, so totals scale by the chip count."""
    flops, bytes_accessed = hlo_stats.cost_analysis_stats(compiled)
    colls = hlo_stats.collect_collectives(compiled.as_text())
    return {
        "flops": flops * chips,
        "bytes_accessed": bytes_accessed * chips,
        "collective_bytes": float(colls.total_bytes) * chips,
        "collectives": {k: float(v) * chips
                        for k, v in colls.bytes_by_op.items()},
        "collective_counts": dict(colls.count_by_op),
    }


def _probe_layers(cfg) -> tuple[int, int]:
    period = cfg.attn_period if cfg.family == "hybrid" else max(
        cfg.moe_every, 1)
    period = max(period, 1)
    return period, 2 * period


def _scale_stats(s1: dict, s2: dict, l1: int, l2: int, l_full: int) -> dict:
    """Affine extrapolation per statistic: f(L) = f(L1) + (L-L1) * slope."""

    def extrap(a, b):
        slope = (b - a) / (l2 - l1)
        return max(a + (l_full - l1) * slope, 0.0)

    out = {
        "flops": extrap(s1["flops"], s2["flops"]),
        "bytes_accessed": extrap(s1["bytes_accessed"], s2["bytes_accessed"]),
    }
    coll = {}
    for op in set(s1["collectives"]) | set(s2["collectives"]):
        coll[op] = extrap(s1["collectives"].get(op, 0.0),
                          s2["collectives"].get(op, 0.0))
    out["collectives"] = coll
    out["collective_bytes"] = sum(coll.values())
    return out


def probe_cell(cfg, shape, mesh, rules, step_kwargs=None,
               state_rules=None) -> dict:
    """Differential cost probes: compile unrolled L1/L2-layer versions at the
    full input shape and extrapolate per-layer costs to the real depth.
    Needed because XLA cost analysis counts while-loop bodies once."""
    l1, l2 = _probe_layers(cfg)
    stats = []
    for lp in (l1, l2):
        # Unroll the layer stack and the attention q-chunk loop so every op is
        # visible to cost analysis.  The fused loss is lowered UNchunked
        # (identical flops/bytes; unrolling its ~512 token-chunks would
        # explode compile time, and probe memory is never allocated).
        pcfg = dataclasses.replace(cfg, num_layers=lp, scan_layers=False,
                                   probe_unroll=True, loss_chunk=0)
        lowered, _, _ = _lower_step(pcfg, shape, mesh, rules, donate=False,
                                    step_kwargs=step_kwargs,
                                    state_rules=state_rules)
        stats.append(_compiled_stats(lowered.compile(), mesh.size))
    return _scale_stats(stats[0], stats[1], l1, l2, cfg.num_layers)


def run_cell(arch: str, shape_name: str, mesh_kind: str,
             probes: bool = True, variant: str = "baseline") -> dict:
    cfg = configs.get(arch)
    shape = SHAPES[shape_name]
    ok, reason = applicable(cfg, shape)
    if not ok:
        return {"arch": arch, "shape": shape_name, "mesh": mesh_kind,
                "status": "skipped", "reason": reason}

    mesh = _mesh(mesh_kind)
    rules = specs.rules_for(mesh, shape)
    cfg, rules, state_rules, step_kwargs = apply_variant(cfg, rules, variant)
    chips = mesh.size
    record = {"arch": arch, "shape": shape_name, "mesh": mesh_kind,
              "variant": variant, "chips": chips, "status": "ok"}

    with set_mesh(mesh), shd.use_rules(rules):
        t0 = time.time()
        lowered, tokens, model_flops = _lower_step(
            cfg, shape, mesh, rules, step_kwargs=step_kwargs,
            state_rules=state_rules)
        record["lower_s"] = round(time.time() - t0, 2)
        t1 = time.time()
        compiled = lowered.compile()
        record["compile_s"] = round(time.time() - t1, 2)

        # Memory proof comes from the real (scanned) compile.
        mem = compiled.memory_analysis()
        for attr in ("argument_size_in_bytes", "output_size_in_bytes",
                     "temp_size_in_bytes", "alias_size_in_bytes",
                     "generated_code_size_in_bytes"):
            try:
                record[attr] = int(getattr(mem, attr))
            except Exception:
                pass
        record["raw"] = _compiled_stats(compiled, chips)  # undercounted (scan)

        # Compute + collective terms come from the differential probes (+
        # recurrence-interior correction); the memory term from the
        # analytical TPU-path traffic model, with probe HLO bytes kept as the
        # CPU-fusion upper bound (see core/estimate.py).
        pbytes = 2 if policy.param_dtype(cfg) == jnp.bfloat16 else 4
        mbytes = 1.03 if policy.moment_dtype(cfg) == "int8" else 4.0
        bm = estimate.bytes_model(
            cfg, batch=shape.global_batch,
            seq=1 if shape.kind == "decode" else shape.seq_len,
            kind=shape.kind, param_bytes=pbytes, moment_bytes=mbytes,
            cache_len=shape.seq_len if shape.kind == "decode" else 0)
        record["bytes_model"] = bm
        if probes:
            t2 = time.time()
            ext = probe_cell(cfg, shape, mesh, rules, step_kwargs,
                             state_rules)
            record["probe_s"] = round(time.time() - t2, 2)
            rec_f, rec_b = estimate.recurrence_correction(cfg, tokens,
                                                          shape.kind)
            ext["flops"] += rec_f
            ext["bytes_accessed"] += rec_b
            ext["recurrence_correction"] = {"flops": rec_f, "bytes": rec_b}
            record["extrapolated"] = ext
            flops = ext["flops"]
            coll_bytes = ext["collective_bytes"]
        else:
            raw = record["raw"]
            flops = raw["flops"]
            coll_bytes = raw["collective_bytes"]
        bytes_accessed = bm["total"]

        roof = cost_model.roofline(flops, bytes_accessed, coll_bytes,
                                   chips, model_flops=model_flops)
        record.update({"model_flops": model_flops, "tokens": tokens,
                       "roofline": roof.row()})
    return record


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=configs.list_archs())
    ap.add_argument("--shape", choices=list(SHAPES))
    ap.add_argument("--mesh", choices=["single", "multi"], default="single")
    ap.add_argument("--variant", choices=list(VARIANTS), default="baseline")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--no-probes", action="store_true",
                    help="skip the differential cost probes (faster)")
    ap.add_argument("--out", default=str(ARTIFACTS))
    args = ap.parse_args(argv)
    outdir = Path(args.out)
    outdir.mkdir(parents=True, exist_ok=True)

    cells = []
    if args.all:
        for arch in configs.list_archs():
            for shape in SHAPES:
                for mesh_kind in ("single", "multi"):
                    cells.append((arch, shape, mesh_kind))
    else:
        if not (args.arch and args.shape):
            ap.error("--arch and --shape required unless --all")
        cells.append((args.arch, args.shape, args.mesh))

    failures = 0
    for arch, shape, mesh_kind in cells:
        tag = f"{arch}__{shape}__{mesh_kind}"
        if args.variant != "baseline":
            tag += f"__{args.variant}"
        try:
            rec = run_cell(arch, shape, mesh_kind, probes=not args.no_probes,
                           variant=args.variant)
        except Exception as e:
            rec = {"arch": arch, "shape": shape, "mesh": mesh_kind,
                   "status": "error", "error": repr(e),
                   "traceback": traceback.format_exc()}
            failures += 1
        (outdir / f"{tag}.json").write_text(json.dumps(rec, indent=2))
        status = rec["status"]
        extra = ""
        if status == "ok":
            r = rec["roofline"]
            extra = (f" dominant={r['dominant']}"
                     f" compute={r['compute_s']:.4f}s"
                     f" memory={r['memory_s']:.4f}s"
                     f" coll={r['collective_s']:.4f}s"
                     f" useful={r['useful_fraction']:.2f}"
                     f" (lower {rec['lower_s']}s compile {rec['compile_s']}s)")
        elif status == "skipped":
            extra = f" ({rec['reason']})"
        else:
            extra = f" {rec['error']}"
        print(f"[{status:7s}] {tag}{extra}", flush=True)
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
