# NOTE: do not import dryrun here — it sets XLA_FLAGS at import time.
from repro.launch import mesh, policy, specs, steps  # noqa: F401
