"""Unified model configuration for the assigned architecture zoo."""

from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                    # dense | moe | ssm | hybrid | encoder
    num_layers: int
    d_model: int
    d_ff: int
    vocab_size: int

    # attention (ignored by attn-free families)
    num_heads: int = 0
    num_kv_heads: int = 0
    head_dim: int = 0
    qk_norm: bool = False
    qkv_bias: bool = False
    sliding_window: int | None = None
    causal: bool = True
    rope_theta: float = 10_000.0

    # MoE
    num_experts: int = 0
    top_k: int = 0
    moe_d_ff: int = 0              # per-expert hidden dim (0 -> d_ff)
    moe_every: int = 1             # MoE on layers where (l % moe_every == moe_offset)
    moe_offset: int = 0
    capacity_factor: float = 1.25

    # hybrid (Jamba): attention on layers where (l % attn_period == attn_offset)
    attn_period: int = 0
    attn_offset: int = 0

    # Mamba (ssm half of hybrid)
    ssm_state: int = 16
    ssm_conv: int = 4
    ssm_expand: int = 2
    ssm_dt_rank: int = 0           # 0 -> d_model // 16

    # RWKV6
    rwkv_head_dim: int = 64
    rwkv_lora_dim: int = 64

    # modality frontend stub: None | "frame" (audio) | "patch" (vlm)
    frontend: str | None = None
    frontend_dim: int = 0          # precomputed embedding dim fed by input_specs

    # training-time details
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    remat: str = "none"            # none | full  (activation checkpointing)
    scan_layers: bool = True
    attn_chunk: int = -1           # -1 auto; 0 never chunk; >0 fixed q-chunk
    loss_chunk: int = 2048         # fused-xent token-chunk size (0 = unchunked)
    probe_unroll: bool = False     # unroll inner chunk loops (cost probes)

    def __post_init__(self):
        if self.num_heads and not self.head_dim:
            object.__setattr__(self, "head_dim", self.d_model // self.num_heads)
        if self.num_experts and not self.moe_d_ff:
            object.__setattr__(self, "moe_d_ff", self.d_ff)
        if self.family in ("ssm", "hybrid") and not self.ssm_dt_rank:
            object.__setattr__(self, "ssm_dt_rank", max(1, self.d_model // 16))

    # ---- derived sizes -------------------------------------------------
    @property
    def q_dim(self) -> int:
        return self.num_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.num_kv_heads * self.head_dim

    def is_attn_layer(self, l: int) -> bool:
        if self.family in ("dense", "moe", "encoder"):
            return True
        if self.family == "ssm":
            return False
        return self.attn_period > 0 and (l % self.attn_period == self.attn_offset)

    def is_moe_layer(self, l: int) -> bool:
        if not self.num_experts:
            return False
        return l % self.moe_every == self.moe_offset

    def param_count(self) -> int:
        """Analytical parameter count (validates against published sizes)."""
        emb = self.vocab_size * self.d_model * (1 if self.tie_embeddings else 2)
        total = emb
        for l in range(self.num_layers):
            if self.family == "ssm":
                total += self._rwkv_layer_params()
                continue
            if self.is_attn_layer(l):
                total += (
                    self.d_model * self.q_dim
                    + 2 * self.d_model * self.kv_dim
                    + self.q_dim * self.d_model
                )
                if self.qkv_bias:
                    total += self.q_dim + 2 * self.kv_dim
            else:  # mamba layer of a hybrid
                total += self._mamba_layer_params()
            if self.family in ("dense", "moe", "hybrid", "encoder"):
                if self.is_moe_layer(l):
                    total += self.num_experts * 3 * self.d_model * self.moe_d_ff
                    total += self.d_model * self.num_experts  # router
                elif self.family == "encoder":
                    total += 2 * self.d_model * self.d_ff  # GELU MLP
                else:
                    total += 3 * self.d_model * self.d_ff  # SwiGLU
            total += 2 * self.d_model  # norms
        total += self.d_model  # final norm
        return total

    def active_param_count(self) -> int:
        """Params touched per token (MoE: top_k of num_experts)."""
        if not self.num_experts:
            return self.param_count()
        total = self.param_count()
        for l in range(self.num_layers):
            if self.is_moe_layer(l):
                total -= (self.num_experts - self.top_k) * 3 * self.d_model * self.moe_d_ff
        return total

    def _mamba_layer_params(self) -> int:
        d_in = self.ssm_expand * self.d_model
        return (
            self.d_model * 2 * d_in                       # in_proj
            + d_in * self.ssm_conv                        # depthwise conv
            + d_in * (self.ssm_dt_rank + 2 * self.ssm_state)  # x_proj
            + self.ssm_dt_rank * d_in + d_in              # dt_proj
            + d_in * self.ssm_state + d_in                # A_log, D
            + d_in * self.d_model                         # out_proj
        )

    def _rwkv_layer_params(self) -> int:
        d, r = self.d_model, self.rwkv_lora_dim
        time_mix = 5 * d * d + d * d  # r,k,v,g,o? (r,k,v,g + output) + decay
        lora = 6 * (d * r + r * d) + 2 * d * r  # ddlerp + decay/gate loras (approx)
        channel = 2 * d * self.d_ff + d * d
        return time_mix + lora + channel
