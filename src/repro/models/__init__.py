from repro.models import config, layers, moe, rwkv, ssm, transformer  # noqa: F401
from repro.models.config import ModelConfig  # noqa: F401
