"""Mamba-style selective SSM block — the SSM half of the Jamba hybrid.

Train path scans the selective recurrence over the sequence; decode carries an
O(1) state (conv tail + SSM hidden), which is what makes `long_500k` decoding
sub-quadratic for hybrid/ssm architectures.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import layers
from repro.parallel.sharding import constrain

Params = dict


def mamba_init(key, cfg, dtype=jnp.float32) -> Params:
    d = cfg.d_model
    d_in = cfg.ssm_expand * d
    n = cfg.ssm_state
    r = cfg.ssm_dt_rank
    ks = jax.random.split(key, 6)
    a = jnp.tile(jnp.arange(1, n + 1, dtype=jnp.float32)[None, :], (d_in, 1))
    return {
        "in_proj": layers._dense_init(ks[0], (d, 2 * d_in), dtype=dtype),
        "conv_w": layers._dense_init(ks[1], (cfg.ssm_conv, d_in), scale=0.1, dtype=dtype),
        "conv_b": jnp.zeros((d_in,), dtype),
        "x_proj": layers._dense_init(ks[2], (d_in, r + 2 * n), dtype=dtype),
        "dt_proj": layers._dense_init(ks[3], (r, d_in), scale=r**-0.5, dtype=dtype),
        "dt_bias": jnp.full((d_in,), -4.6, dtype),     # softplus^-1(0.01)
        "A_log": jnp.log(a),                           # kept f32
        "D": jnp.ones((d_in,), jnp.float32),
        "out_proj": layers._dense_init(ks[4], (d_in, d), dtype=dtype),
    }


def mamba_param_specs(cfg) -> Params:
    return {
        "in_proj": ("embed", "ff"),
        "conv_w": (None, "ff"),
        "conv_b": ("ff",),
        "x_proj": ("ff", None),
        "dt_proj": (None, "ff"),
        "dt_bias": ("ff",),
        "A_log": ("ff", None),
        "D": ("ff",),
        "out_proj": ("ff", "embed"),
    }


def _causal_depthwise_conv(x: jax.Array, w: jax.Array, b: jax.Array,
                           tail: jax.Array | None = None):
    """x: (B,S,C); w: (W,C) depthwise causal conv.  tail: (B,W-1,C) history."""
    width = w.shape[0]
    tail_dtype = x.dtype if tail is None else tail.dtype
    if tail is None:
        tail = jnp.zeros((x.shape[0], width - 1, x.shape[2]), x.dtype)
    xp = jnp.concatenate([tail.astype(x.dtype), x], axis=1)  # (B, S+W-1, C)
    out = sum(
        xp[:, i : i + x.shape[1], :] * w[i][None, None, :] for i in range(width)
    )
    new_tail = (xp[:, -(width - 1):, :].astype(tail_dtype)
                if width > 1 else tail)
    return out + b[None, None, :], new_tail


def _selective_scan(delta, a, b_ssm, c_ssm, x, h0):
    """delta,x: (B,S,Din); a: (Din,N); b_ssm,c_ssm: (B,S,N); h0: (B,Din,N)."""

    def step(h, inp):
        d_t, b_t, c_t, x_t = inp                       # (B,Din),(B,N),(B,N),(B,Din)
        da = jnp.exp(d_t[..., None] * a[None])         # (B,Din,N)
        dbx = d_t[..., None] * b_t[:, None, :] * x_t[..., None]
        h = da * h + dbx
        y = jnp.einsum("bdn,bn->bd", h, c_t)
        return h, y

    xs = (
        jnp.moveaxis(delta, 1, 0),
        jnp.moveaxis(b_ssm, 1, 0),
        jnp.moveaxis(c_ssm, 1, 0),
        jnp.moveaxis(x, 1, 0),
    )
    h_last, ys = jax.lax.scan(step, h0, xs)
    return jnp.moveaxis(ys, 0, 1), h_last              # (B,S,Din), (B,Din,N)


def mamba_apply(params: Params, x: jax.Array, cfg,
                cache: Params | None = None):
    """x: (B,S,D) -> (B,S,D).  cache: {"conv": (B,W-1,Din), "h": (B,Din,N)}."""
    b, s, d = x.shape
    d_in = cfg.ssm_expand * d
    n = cfg.ssm_state
    r = cfg.ssm_dt_rank

    xz = x @ params["in_proj"].astype(x.dtype)         # (B,S,2*Din)
    xb, z = jnp.split(xz, 2, axis=-1)
    xb = constrain(xb, "batch", "seq", "ff")

    tail = cache["conv"] if cache is not None else None
    xb, new_tail = _causal_depthwise_conv(
        xb, params["conv_w"].astype(x.dtype), params["conv_b"].astype(x.dtype), tail)
    xb = jax.nn.silu(xb)

    dbl = (xb @ params["x_proj"].astype(x.dtype)).astype(jnp.float32)
    dt, b_ssm, c_ssm = jnp.split(dbl, [r, r + n], axis=-1)
    delta = jax.nn.softplus(
        dt @ params["dt_proj"].astype(jnp.float32) + params["dt_bias"].astype(jnp.float32))
    a = -jnp.exp(params["A_log"])

    h0 = (cache["h"] if cache is not None
          else jnp.zeros((b, d_in, n), jnp.float32))
    # delta stays f32 (exp stability); the B/C/x streams are bf16 — halves
    # the dominant activation traffic of the scan (§Perf iteration J2).
    y, h_last = _selective_scan(delta, a, b_ssm.astype(jnp.bfloat16),
                                c_ssm.astype(jnp.bfloat16),
                                xb.astype(jnp.bfloat16), h0)
    y = (y + params["D"][None, None, :] * xb.astype(jnp.float32)).astype(x.dtype)
    y = y * jax.nn.silu(z)
    out = y @ params["out_proj"].astype(x.dtype)
    new_cache = {"conv": new_tail, "h": h_last} if cache is not None else None
    return constrain(out, "batch", "res_seq", "embed"), new_cache


def mamba_cache_init(cfg, batch: int, dtype=jnp.bfloat16) -> Params:
    d_in = cfg.ssm_expand * cfg.d_model
    return {
        "conv": jnp.zeros((batch, cfg.ssm_conv - 1, d_in), dtype),
        "h": jnp.zeros((batch, d_in, cfg.ssm_state), jnp.float32),
    }
