"""Model assembly: embeddings -> scanned block stack -> head.

One entry point serves every assigned architecture family:

- dense / moe / encoder : uniform layers, `lax.scan` over stacked params
- ssm (RWKV6)           : uniform RWKV layers, same scan
- hybrid (Jamba)        : period-`attn_period` heterogeneous groups; scan over
                          groups, sub-layers unrolled inside the group body

`forward` handles train (cache=None) and decode (cache given, S small).
Decode state is {"blocks": stacked per-layer caches, "index": scalar}.
Layer stacks always scan (compact HLO — a 94-layer model lowers to one loop).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.models import layers, moe, rwkv, ssm
from repro.models.config import ModelConfig
from repro.parallel.sharding import constrain

Params = dict


# ---------------------------------------------------------------------------
# Per-layer init / apply / cache-init, keyed by the cfg-static layer kind
# ---------------------------------------------------------------------------

def _layer_init(key, cfg: ModelConfig, l: int, dtype) -> Params:
    k1, k2 = jax.random.split(key)
    p: Params = {"ln1": layers.rmsnorm_init(cfg.d_model),
                 "ln2": layers.rmsnorm_init(cfg.d_model)}
    if cfg.family == "ssm":
        p["mixer"] = rwkv.rwkv_time_init(k1, cfg, dtype)
        p["mlp"] = rwkv.rwkv_channel_init(k2, cfg, dtype)
        return p
    if cfg.is_attn_layer(l):
        p["mixer"] = layers.attention_init(k1, cfg, dtype)
    else:
        p["mixer"] = ssm.mamba_init(k1, cfg, dtype)
    if cfg.is_moe_layer(l):
        p["mlp"] = moe.moe_init(k2, cfg, dtype)
    elif cfg.family == "encoder":
        p["mlp"] = layers.gelu_mlp_init(k2, cfg.d_model, cfg.d_ff, dtype)
    else:
        p["mlp"] = layers.swiglu_init(k2, cfg.d_model, cfg.d_ff, dtype)
    return p


def _keep_inactive(new_c, old_c, active):
    """Mask a recurrent per-layer cache update: inactive slots keep their
    old state.  Only the SSM/RWKV leaves need this — the attention cache
    is protected at the write itself (inactive slots' scatter rows are
    dropped), and re-masking its full (B, L, Hkv, dh) buffers would
    double the decode hot loop's KV-cache traffic for nothing."""
    if active is None or new_c is None:
        return new_c
    if active.ndim == 2:     # (B, S) chunked mask -> per-slot any()
        active = active.any(axis=1)
    return jax.tree.map(
        lambda n, o: jnp.where(
            active.reshape((-1,) + (1,) * (n.ndim - 1)), n, o),
        new_c, old_c)


def _layer_apply(p: Params, x, cfg: ModelConfig, l: int, positions,
                 cache: Params | None, lengths, active,
                 prefill: bool = False, pages=None, paged=None):
    """Pre-norm block l.  Returns (x, new_cache, aux).

    ``lengths`` is the per-slot valid cache prefix ((B,) int32) and
    ``active`` the per-slot advance mask ((B,) — or (B, S) for chunked
    prefill) — the ragged continuous-batching contract threaded from the
    serve loop; both are None outside decode.  ``pages``/``paged`` carry
    the shared page table + static PageSpec when the KV cache is paged.
    """
    aux = jnp.zeros((), jnp.float32)
    h = layers.rmsnorm(p["ln1"], x, cfg.norm_eps)
    if cfg.family == "ssm":
        h, new_t = rwkv.rwkv_time_mix(p["mixer"], h, cfg, cache)
        x = x + h
        h2 = layers.rmsnorm(p["ln2"], x, cfg.norm_eps)
        h2, new_c = rwkv.rwkv_channel_mix(p["mlp"], h2, cfg, cache)
        x = x + h2
        new_cache = ({**new_t, **new_c} if cache is not None else None)
        return x, _keep_inactive(new_cache, cache, active), aux

    if cfg.is_attn_layer(l):
        # Per-slot write masking happens inside the scatter — no
        # _keep_inactive pass over the KV buffers.
        h, new_mix_cache = layers.attention_apply(
            p["mixer"], h, cfg, positions, cache=cache, lengths=lengths,
            active=active, prefill=prefill, pages=pages, paged=paged)
    else:
        h, new_mix_cache = ssm.mamba_apply(p["mixer"], h, cfg, cache=cache)
        new_mix_cache = _keep_inactive(new_mix_cache, cache, active)
    x = x + h

    h2 = layers.rmsnorm(p["ln2"], x, cfg.norm_eps)
    if cfg.is_moe_layer(l):
        h2, aux = moe.apply_sharded(p["mlp"], h2, cfg)
    elif cfg.family == "encoder":
        h2 = layers.gelu_mlp_apply(p["mlp"], h2)
    else:
        h2 = layers.swiglu_apply(p["mlp"], h2)
    x = x + h2
    return x, new_mix_cache, aux


def _layer_cache_init(cfg: ModelConfig, l: int, batch: int, cache_len: int,
                      dtype=jnp.bfloat16, paged=None) -> Params:
    if cfg.family == "ssm":
        return rwkv.rwkv_cache_init(cfg, batch, dtype)
    if cfg.is_attn_layer(l):
        return layers.attention_cache_init(cfg, batch, cache_len, dtype,
                                           paged=paged)
    return ssm.mamba_cache_init(cfg, batch, dtype)


def _stack(dicts: list) -> Params:
    return jax.tree.map(lambda *xs: jnp.stack(xs), *dicts)


# ---------------------------------------------------------------------------
# Logical sharding specs (mirror the init/cache structures exactly)
# ---------------------------------------------------------------------------

def _mlp_specs(cfg: ModelConfig, l: int):
    if cfg.family == "ssm":
        return rwkv.rwkv_channel_param_specs(cfg)
    if cfg.is_moe_layer(l):
        return moe.moe_param_specs()
    if cfg.family == "encoder":
        return layers.gelu_mlp_param_specs()
    return layers.swiglu_param_specs()


def _layer_specs(cfg: ModelConfig, l: int):
    p = {"ln1": {"scale": (None,)}, "ln2": {"scale": (None,)}}
    if cfg.family == "ssm":
        p["mixer"] = rwkv.rwkv_time_param_specs(cfg)
    elif cfg.is_attn_layer(l):
        p["mixer"] = layers.attention_param_specs(cfg)
    else:
        p["mixer"] = ssm.mamba_param_specs(cfg)
    p["mlp"] = _mlp_specs(cfg, l)
    return p


def _is_axes(x) -> bool:
    return isinstance(x, tuple)


def _prepend_layer_axis(tree):
    return jax.tree.map(lambda axes: (None, *axes), tree, is_leaf=_is_axes)


def param_specs(cfg: ModelConfig):
    """Pytree of logical-axis tuples matching `init`'s structure."""
    specs: dict = {"embed": {"table": ("vocab", "embed")}}
    if cfg.frontend:
        specs["frontend"] = {"proj": (None, "embed")}
    if cfg.family == "hybrid":
        period = cfg.attn_period
        group = {str(i): _layer_specs(cfg, i) for i in range(period)}
        specs["blocks"] = _prepend_layer_axis(group)
    else:
        specs["blocks"] = _prepend_layer_axis(_layer_specs(cfg, 0))
    specs["final_norm"] = {"scale": (None,)}
    if not cfg.tie_embeddings:
        specs["head"] = {"table": ("vocab", "embed")}
    return specs


def _layer_cache_specs(cfg: ModelConfig, l: int, paged=None,
                       quantized: bool = False):
    if cfg.family == "ssm":
        return {"shift_t": ("batch", None, "embed"),
                "wkv": ("batch", "heads", None, None),
                "shift_c": ("batch", None, "embed")}
    if cfg.is_attn_layer(l):
        if paged is not None:
            # Pool axes: (num_pages, page_size, Hkv, dh) — no batch axis;
            # pages are interleaved across slots, so only heads shard.
            specs = {"k": (None, None, "kv_heads", None),
                     "v": (None, None, "kv_heads", None)}
            if quantized:
                # Scale leaves drop the dh axis (one f32 per token row).
                specs["k_scale"] = (None, None, "kv_heads")
                specs["v_scale"] = (None, None, "kv_heads")
            return specs
        specs = {"k": ("batch", "kv_seq", "kv_heads", None),
                 "v": ("batch", "kv_seq", "kv_heads", None)}
        if quantized:
            specs["k_scale"] = ("batch", "kv_seq", "kv_heads")
            specs["v_scale"] = ("batch", "kv_seq", "kv_heads")
        return specs
    return {"conv": ("batch", None, "ff"), "h": ("batch", "ff", None)}


def cache_specs(cfg: ModelConfig, paged=None, kv_dtype=None):
    """Pytree of logical-axis tuples matching `cache_init`'s structure.

    ``kv_dtype`` mirrors `cache_init`'s dtype: int8 caches carry the
    extra per-row scale leaves, so their spec tree must too."""
    quantized = kv_dtype is not None and jnp.dtype(kv_dtype) == jnp.int8
    if cfg.family == "hybrid":
        period = cfg.attn_period
        group = {str(i): _layer_cache_specs(cfg, i, paged, quantized)
                 for i in range(period)}
        blocks = _prepend_layer_axis(group)
    else:
        blocks = _prepend_layer_axis(
            _layer_cache_specs(cfg, 0, paged, quantized))
    specs = {"blocks": blocks, "index": (), "lengths": ("batch",)}
    if paged is not None:
        specs["pages"] = ("batch", None)
    return specs


# ---------------------------------------------------------------------------
# Init / cache init
# ---------------------------------------------------------------------------

def init(cfg: ModelConfig, key, dtype=jnp.float32) -> Params:
    ke, kl, kh, kf = jax.random.split(key, 4)
    params: Params = {"embed": layers.embedding_init(ke, cfg.vocab_size,
                                                     cfg.d_model, dtype)}
    if cfg.frontend:
        params["frontend"] = {
            "proj": layers._dense_init(kf, (cfg.frontend_dim, cfg.d_model),
                                       dtype=dtype)
        }
    keys = jax.random.split(kl, cfg.num_layers)
    if cfg.family == "hybrid":
        period = cfg.attn_period
        groups = [
            {str(i): _layer_init(keys[g * period + i], cfg, g * period + i,
                                 dtype)
             for i in range(period)}
            for g in range(cfg.num_layers // period)
        ]
        params["blocks"] = _stack(groups)
    else:
        params["blocks"] = _stack(
            [_layer_init(k, cfg, 0, dtype) for k in keys])
    params["final_norm"] = layers.rmsnorm_init(cfg.d_model)
    if not cfg.tie_embeddings:
        params["head"] = layers.embedding_init(kh, cfg.vocab_size,
                                               cfg.d_model, dtype)
    return params


def cache_init(cfg: ModelConfig, batch: int, cache_len: int,
               dtype=jnp.bfloat16, index: int = 0, paged=None) -> Params:
    if cfg.family == "hybrid":
        period = cfg.attn_period
        groups = [
            {str(i): _layer_cache_init(cfg, g * period + i, batch, cache_len,
                                       dtype, paged=paged)
             for i in range(period)}
            for g in range(cfg.num_layers // period)
        ]
        blocks = _stack(groups)
    else:
        blocks = _stack([
            _layer_cache_init(cfg, l, batch, cache_len, dtype, paged=paged)
            for l in range(cfg.num_layers)
        ])
    cache = {"blocks": blocks, "index": jnp.full((), index, jnp.int32),
             "lengths": jnp.full((batch,), index, jnp.int32)}
    if paged is not None:
        # ONE page table for the whole stack: logical page j of slot b is
        # the same pool row in every layer's K and V pool.  -1 = no page
        # assigned; the host-side PageAllocator owns the truth and the
        # server refreshes this device copy after allocation changes.
        cache["pages"] = jnp.full((batch, paged.max_pages), -1, jnp.int32)
    return cache


def _is_pool_leaf(a, paged) -> bool:
    """A stacked paged attention pool leaf: (L, num_pages, page_size, ...)
    — distinguishes the pool K/V from batched SSM/RWKV leaves in hybrid
    stacks."""
    return (a.ndim >= 3 and a.shape[1] == paged.num_pages
            and a.shape[2] == paged.page_size)


def _slot_page_mask(cache: Params, slot: int, paged) -> jax.Array:
    """(num_pages,) bool: pool rows held by ``slot`` per its table row."""
    row = cache["pages"][slot]                            # (max_pages,)
    safe = jnp.clip(row, 0, paged.num_pages - 1)
    return jnp.zeros((paged.num_pages,), bool).at[safe].set(row >= 0)


def cache_reset_slot(cache: Params, slot: int, paged=None) -> Params:
    """Zero one slot's rows across every per-layer cache leaf (KV rows,
    SSM conv tails / states, RWKV shifts) and reset its length to 0.

    A recycled continuous-batching slot must start from a state identical
    to a freshly initialized one: the per-slot length masks already hide
    the stale prefix from attention, but zeroing is the defense in depth
    that makes a refilled slot reproduce single-sequence decode bitwise
    (and resets the recurrent states masking cannot reach).

    Paged: pool leaves have no batch axis, so the slot's rows are the
    pool pages its table row names — those are zeroed and the table row
    cleared to -1 (the host-side allocator frees them separately).
    """
    if paged is not None:
        mask = _slot_page_mask(cache, slot, paged)

        def reset(a):
            if _is_pool_leaf(a, paged):           # (L, num_pages, ps, ...)
                m = mask.reshape((1, -1) + (1,) * (a.ndim - 2))
                return jnp.where(m, 0, a)
            return a.at[:, slot].set(0)           # SSM/RWKV leaves: batched
        return {"blocks": jax.tree.map(reset, cache["blocks"]),
                "index": cache["index"],
                "lengths": cache["lengths"].at[slot].set(0),
                "pages": cache["pages"].at[slot].set(-1)}
    blocks = jax.tree.map(lambda a: a.at[:, slot].set(0), cache["blocks"])
    return {"blocks": blocks, "index": cache["index"],
            "lengths": cache["lengths"].at[slot].set(0)}


def cache_poison_slot(cache: Params, slot: int, paged=None) -> Params:
    """Overwrite one slot's float cache rows with NaN (fault injection:
    a corrupted KV block / recurrent state).

    The chaos harness's `kv_corrupt` fault class: NaN lands in every float
    leaf of the slot's per-layer cache (KV rows, SSM conv/state, RWKV
    shifts) so the next decode step's logits for that slot go non-finite
    and the per-slot guard must quarantine it.  Integer leaves and the
    shared index/lengths bookkeeping are untouched — the fault corrupts
    *data*, not control state, exactly like a flipped HBM block would.
    Paged: the slot's "rows" are the pool pages its table row names.
    """
    if paged is not None:
        mask = _slot_page_mask(cache, slot, paged)

        def poison(a):
            if not jnp.issubdtype(a.dtype, jnp.floating):
                return a
            if _is_pool_leaf(a, paged):
                m = mask.reshape((1, -1) + (1,) * (a.ndim - 2))
                return jnp.where(m, jnp.nan, a)
            return a.at[:, slot].set(jnp.nan)
        return {"blocks": jax.tree.map(poison, cache["blocks"]),
                "index": cache["index"], "lengths": cache["lengths"],
                "pages": cache["pages"]}

    def poison(a):
        if not jnp.issubdtype(a.dtype, jnp.floating):
            return a
        return a.at[:, slot].set(jnp.nan)
    return {"blocks": jax.tree.map(poison, cache["blocks"]),
            "index": cache["index"], "lengths": cache["lengths"]}


# ---------------------------------------------------------------------------
# Forward
# ---------------------------------------------------------------------------

def _embed_inputs(cfg: ModelConfig, params: Params, inputs: dict) -> jax.Array:
    parts = []
    key = "frames" if cfg.frontend == "frame" else "patches"
    if cfg.frontend in ("frame", "patch") and key in inputs:
        # modality frontends feed prompts; decode steps are token-only
        feats = inputs[key]
        parts.append(feats @ params["frontend"]["proj"].astype(feats.dtype))
    if "tokens" in inputs:
        parts.append(layers.embedding_lookup(params["embed"],
                                             inputs["tokens"]))
    x = parts[0] if len(parts) == 1 else jnp.concatenate(parts, axis=1)
    return constrain(x, "batch", "res_seq", "embed")


def forward(cfg: ModelConfig, params: Params, inputs: dict,
            cache: Params | None = None, compute_dtype=jnp.bfloat16,
            return_hidden: bool = False, last_only: bool = False,
            active: jax.Array | None = None, paged=None):
    """Returns (logits-or-hidden, new_cache, aux_loss).

    ``return_hidden`` skips the unembedding (the caller fuses it into a
    chunked loss); ``last_only`` unembeds only the final position (prefill).
    ``active`` ((B,) bool, decode only) masks which slots advance this
    step: inactive slots neither write cache rows nor move their per-slot
    ``lengths`` — the ragged continuous-batching contract (a masked
    batched prefill is ``active`` = one-hot of the refilled slot).  A
    (B, S) ``active`` is the chunked-prefill generalization: each slot
    writes/advances only its own valid prefix of the packed chunk.
    ``paged`` (a `runtime.paging.PageSpec`, static) marks the cache as
    paged; the shared (B, max_pages) page table rides ``cache["pages"]``
    and is threaded to every attention layer.
    """
    x = _embed_inputs(cfg, params, inputs).astype(compute_dtype)
    b, s, _ = x.shape
    index = cache["index"] if cache is not None else None
    lengths = None
    if cache is not None:
        lengths = cache.get("lengths")
        if lengths is None:          # legacy cache without the vector
            lengths = jnp.full((b,), index, jnp.int32)
        # Per-slot absolute positions: each sequence continues from its
        # own depth (uniform lengths reproduce the old shared `index`).
        positions = lengths[:, None] + jnp.arange(s, dtype=jnp.int32)[None]
    else:
        positions = jnp.arange(s, dtype=jnp.int32)
    act = None
    if cache is not None and active is not None:
        act = jnp.asarray(active).astype(bool)
    pages = cache.get("pages") if (cache is not None and paged is not None) \
        else None

    blocks = params["blocks"]
    block_caches = cache["blocks"] if cache is not None else None
    decode = cache is not None
    # Serving prefill (the `make_prefill_step` path: forward-only, no
    # gradient) routes attention through the autotuned flash kernel; the
    # flag stays a Python-level static so training keeps the jnp path.
    prefill = last_only and cache is None
    apply_fn = functools.partial(_layer_apply, prefill=prefill,
                                 pages=pages, paged=paged)

    if cfg.family == "hybrid":
        period = cfg.attn_period
        # Per-SUB-layer checkpointing: a period-8 Jamba group holds 7 mamba
        # layers whose scan inputs are large; rematting each sub-layer keeps
        # only one sub-layer's working set live during the group's backward.
        lapply = (jax.checkpoint(apply_fn, static_argnums=(2, 3),
                                 prevent_cse=False)
                  if cfg.remat == "full" and not decode else apply_fn)

        def body(xx, gp, gc):
            new_gc = {}
            aux_tot = jnp.zeros((), jnp.float32)
            for i in range(period):
                lc = gc[str(i)] if decode else None
                xx, nc, aux = lapply(gp[str(i)], xx, cfg, i, positions,
                                     lc, lengths, act)
                aux_tot += aux
                if decode:
                    new_gc[str(i)] = nc
            return xx, (new_gc if decode else 0), aux_tot
    else:

        def body(xx, gp, gc):
            xx, nc, aux = apply_fn(gp, xx, cfg, 0, positions, gc, lengths,
                                   act)
            return xx, (nc if decode else 0), aux

    if cfg.remat == "full":
        body = jax.checkpoint(body, prevent_cse=False)

    if cfg.scan_layers:
        if decode:
            def scan_fn(carry, pc):
                gp, gc = pc
                xx, nc, aux = body(carry, gp, gc)
                return xx, (nc, aux)

            x, (new_caches, auxs) = jax.lax.scan(scan_fn, x,
                                                 (blocks, block_caches))
        else:
            def scan_fn(carry, gp):
                xx, _, aux = body(carry, gp, None)
                return xx, aux

            x, auxs = jax.lax.scan(scan_fn, x, blocks)
            new_caches = None
        aux = jnp.sum(auxs)
    else:
        # Unrolled stack — used by the dry-run's differential cost probes
        # (XLA cost analysis counts while-loop bodies once; unrolled layers
        # are counted fully).
        n = jax.tree.leaves(blocks)[0].shape[0]
        aux = jnp.zeros((), jnp.float32)
        caches_out = []
        for l in range(n):
            gp = jax.tree.map(lambda a: a[l], blocks)
            gc = (jax.tree.map(lambda a: a[l], block_caches)
                  if decode else None)
            x, nc, a = body(x, gp, gc)
            aux = aux + a
            if decode:
                caches_out.append(nc)
        new_caches = (jax.tree.map(lambda *xs: jnp.stack(xs), *caches_out)
                      if decode else None)

    x = layers.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    new_cache = None
    if cache is not None:
        if act is None:
            adv = s
        elif act.ndim == 2:        # chunked: per-slot valid-position count
            adv = jnp.sum(act, axis=1, dtype=jnp.int32)
        else:
            adv = s * act.astype(jnp.int32)
        new_cache = {"blocks": new_caches, "index": index + s,
                     "lengths": lengths + adv}
        if pages is not None:
            # The table itself only changes host-side (allocation); the
            # device copy rides along unchanged.
            new_cache["pages"] = pages
    if return_hidden:
        return x, new_cache, aux
    head = params["embed"] if cfg.tie_embeddings else params["head"]
    if last_only:
        x = x[:, -1:]
    logits = layers.unembed(head, x)
    return logits, new_cache, aux
