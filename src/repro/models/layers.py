"""Shared neural-net layers (functional JAX): norms, RoPE, GQA attention,
SwiGLU/GELU MLPs, embeddings.

Everything is init/apply pairs over plain dict pytrees; layer stacks hold
*stacked* params (leading layer axis) so the model can `lax.scan` over depth.
Sharding is expressed through logical-axis constraints (`parallel.sharding`).
"""

from __future__ import annotations

import math
import os
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.parallel.sharding import constrain
from repro.runtime import quantize

Params = dict
DEFAULT_INIT_SCALE = 0.02


def _dense_init(key, shape, scale=DEFAULT_INIT_SCALE, dtype=jnp.float32):
    return (jax.random.normal(key, shape, dtype=jnp.float32) * scale).astype(dtype)


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def rmsnorm_init(d: int, dtype=jnp.float32) -> Params:
    return {"scale": jnp.ones((d,), dtype=dtype)}


def rmsnorm(params: Params, x: jax.Array, eps: float = 1e-5) -> jax.Array:
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    y = x32 * jax.lax.rsqrt(var + eps)
    return (y * params["scale"].astype(jnp.float32)).astype(dt)


# ---------------------------------------------------------------------------
# Rotary position embedding
# ---------------------------------------------------------------------------

def rope_frequencies(head_dim: int, theta: float) -> jax.Array:
    half = head_dim // 2
    return 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., seq, heads, head_dim); positions: (..., seq) int32."""
    half = x.shape[-1] // 2
    freqs = rope_frequencies(x.shape[-1], theta)                     # (half,)
    angles = positions[..., :, None].astype(jnp.float32) * freqs    # (..., seq, half)
    cos = jnp.cos(angles)[..., :, None, :]                           # (..., seq, 1, half)
    sin = jnp.sin(angles)[..., :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    x1f, x2f = x1.astype(jnp.float32), x2.astype(jnp.float32)
    out = jnp.concatenate([x1f * cos - x2f * sin, x2f * cos + x1f * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# GQA attention (options: qk-norm, qkv-bias, sliding window, non-causal)
# ---------------------------------------------------------------------------

def attention_init(key, cfg, dtype=jnp.float32) -> Params:
    ks = jax.random.split(key, 4)
    p = {
        "wq": _dense_init(ks[0], (cfg.d_model, cfg.q_dim), dtype=dtype),
        "wk": _dense_init(ks[1], (cfg.d_model, cfg.kv_dim), dtype=dtype),
        "wv": _dense_init(ks[2], (cfg.d_model, cfg.kv_dim), dtype=dtype),
        "wo": _dense_init(ks[3], (cfg.q_dim, cfg.d_model), dtype=dtype),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((cfg.q_dim,), dtype)
        p["bk"] = jnp.zeros((cfg.kv_dim,), dtype)
        p["bv"] = jnp.zeros((cfg.kv_dim,), dtype)
    if cfg.qk_norm:
        p["q_norm"] = rmsnorm_init(cfg.head_dim, dtype)
        p["k_norm"] = rmsnorm_init(cfg.head_dim, dtype)
    return p


def attention_param_specs(cfg) -> Params:
    """Logical axes per attention param leaf (mirrors attention_init)."""
    p = {
        "wq": ("embed", "heads"),
        "wk": ("embed", "kv_heads"),
        "wv": ("embed", "kv_heads"),
        "wo": ("heads", "embed"),
    }
    if cfg.qkv_bias:
        p.update({"bq": ("heads",), "bk": ("kv_heads",), "bv": ("kv_heads",)})
    if cfg.qk_norm:
        p["q_norm"] = {"scale": (None,)}
        p["k_norm"] = {"scale": (None,)}
    return p


def _mask_block(q_pos, k_pos, causal: bool, window: int | None,
                k_valid=None):
    """Boolean mask from position vectors.

    Every operand may be shared across the batch (1-D: ``q_pos (Sq,)``,
    ``k_pos (Sk,)``, ``k_valid (Sk,)``) or per-sequence (2-D with a
    leading batch axis) — ragged continuous batching gives each slot its
    own positions and valid cache prefix.  Returns ``(Sq, Sk)`` when all
    operands are shared, ``(B, Sq, Sk)`` otherwise.
    """
    diff = q_pos[..., :, None] - k_pos[..., None, :]
    ok = jnp.ones(diff.shape, dtype=bool)
    if causal:
        ok &= diff >= 0
    if window is not None:
        ok &= diff < window
    if k_valid is not None:
        ok = ok & k_valid[..., None, :]
    return ok


def attention_core(q, k, v, q_pos, k_pos, *, causal, window, scale,
                   k_valid=None, chunk_q: int | None = None,
                   unroll: bool = False, remat_chunks: bool = False):
    """Memory-safe multi-head attention with GQA grouping.

    q: (B,Sq,Hq,dh), k/v: (B,Sk,Hkv,dh), q_pos: (Sq,), k_pos: (Sk,).
    ``q_pos``/``k_pos``/``k_valid`` may also carry a leading batch axis
    ((B, Sq) / (B, Sk)) — the ragged continuous-batching decode path, where
    every slot sits at its own cache depth and masks its own prefix.
    When ``chunk_q`` divides Sq, query blocks are processed sequentially with
    `lax.scan` so the (Sq, Sk) logits never materialize — the jnp analogue of
    the Pallas flash-attention kernel's VMEM blocking.
    """
    b, sq, hq, dh = q.shape
    hkv = k.shape[2]
    g = hq // hkv
    qr = q.reshape(b, sq, hkv, g, dh)

    def blk(q_blk, qp_blk):
        # bf16 operands, f32 accumulation — no f32 copies of Q/K/V in HBM.
        logits = jnp.einsum("bqhgd,bkhd->bhgqk", q_blk, k,
                            preferred_element_type=jnp.float32) * scale
        mask = _mask_block(qp_blk, k_pos, causal, window, k_valid)
        # (q, k) masks are shared across (b, h, g); (b, q, k) masks are
        # per-sequence and broadcast over (h, g) only.
        mask = (mask[None, None, None] if mask.ndim == 2
                else mask[:, None, None])
        logits = jnp.where(mask, logits, -1e30)
        probs = jax.nn.softmax(logits, axis=-1)
        return jnp.einsum("bhgqk,bkhd->bqhgd", probs.astype(v.dtype), v,
                          preferred_element_type=jnp.float32)

    if chunk_q and sq > chunk_q and sq % chunk_q == 0:
        nchunks = sq // chunk_q
        qc = jnp.moveaxis(qr.reshape(b, nchunks, chunk_q, hkv, g, dh), 1, 0)
        pc = (jnp.moveaxis(q_pos.reshape(b, nchunks, chunk_q), 1, 0)
              if q_pos.ndim == 2 else q_pos.reshape(nchunks, chunk_q))
        fn = blk
        if remat_chunks and not unroll:
            # backward recomputes each chunk's logits/probs instead of
            # saving nchunks of them (flash-attention-style memory)
            fn = jax.checkpoint(blk, prevent_cse=False)
        if unroll:
            # python loop: identical math, fully visible to cost analysis
            out = jnp.stack([blk(qc[i], pc[i]) for i in range(nchunks)])
        else:
            _, out = jax.lax.scan(lambda c, xs: (c, fn(*xs)), None, (qc, pc))
        out = jnp.moveaxis(out, 0, 1).reshape(b, sq, hkv, g, dh)
    else:
        out = blk(qr, q_pos)
    return out.reshape(b, sq, hq, dh)


def attention_apply(
    params: Params,
    x: jax.Array,                       # (B, S, D)
    cfg,
    positions: jax.Array,               # (S,) or (B, S) int32 abs positions
    cache: Params | None = None,        # {"k","v": (B, S_cache, Hkv, dh)}
    lengths: jax.Array | None = None,   # (B,) per-slot valid cache prefix
    active: jax.Array | None = None,    # (B,) or (B, S) write/advance mask
    chunk_q: int | None = None,
    prefill: bool = False,              # serving prefill (fwd-only, no grad)
    pages: jax.Array | None = None,     # (B, max_pages) int32 page table
    paged=None,                         # runtime.paging.PageSpec (static)
) -> tuple[jax.Array, Params | None]:
    from repro.parallel.sharding import gather_weight
    b, s, _ = x.shape
    q = x @ gather_weight(params["wq"]).astype(x.dtype)
    k = x @ gather_weight(params["wk"]).astype(x.dtype)
    v = x @ gather_weight(params["wv"]).astype(x.dtype)
    if cfg.qkv_bias:
        q = q + params["bq"].astype(x.dtype)
        k = k + params["bk"].astype(x.dtype)
        v = v + params["bv"].astype(x.dtype)
    q = q.reshape(b, s, cfg.num_heads, cfg.head_dim)
    k = k.reshape(b, s, cfg.num_kv_heads, cfg.head_dim)
    v = v.reshape(b, s, cfg.num_kv_heads, cfg.head_dim)
    if cfg.qk_norm:
        q = rmsnorm(params["q_norm"], q, cfg.norm_eps)
        k = rmsnorm(params["k_norm"], k, cfg.norm_eps)
    # Shared (S,) positions broadcast across the batch; per-slot (B, S)
    # positions (ragged decode) index each sequence at its own depth.
    pos_b = positions if positions.ndim == 2 else positions[None]
    q = apply_rope(q, pos_b, cfg.rope_theta)
    k = apply_rope(k, pos_b, cfg.rope_theta)
    q = constrain(q, "batch", "seq", "heads", None)
    scale = 1.0 / math.sqrt(cfg.head_dim)
    if chunk_q is None:
        if cfg.attn_chunk == 0:
            chunk_q = None
        elif cfg.attn_chunk > 0:
            chunk_q = cfg.attn_chunk
        elif s > 2048:
            chunk_q = 512

    if cache is None:
        k = constrain(k, "batch", "seq", "kv_heads", None)
        v = constrain(v, "batch", "seq", "kv_heads", None)
        if prefill and jax.default_backend() == "tpu":
            # Serving prefill: the forward-only hot spot goes through the
            # registry's autotuned flash kernel (analytic plan at trace
            # time — the cache was pre-warmed by `autotune.plan_for_model`).
            # Training keeps the differentiable jnp path below.
            from repro.kernels.autotune import dispatch
            out = dispatch("attention", q, k, v, causal=cfg.causal,
                           window=cfg.sliding_window)
        else:
            out = attention_core(q, k, v, positions, positions,
                                 causal=cfg.causal,
                                 window=cfg.sliding_window, scale=scale,
                                 chunk_q=chunk_q, unroll=cfg.probe_unroll,
                                 remat_chunks=(cfg.remat == "full"))
        new_cache = None
    else:
        # Decode: every slot writes its new K/V at its OWN depth
        # (`lengths[b]`; ring-buffer modulo for SWA) and attends only over
        # its own valid cache prefix — ragged continuous batching.  A shared
        # scalar depth is the degenerate case where `lengths` is uniform.
        # ``active`` may be (B,) — the slot writes/advances all S positions
        # — or (B, S) — chunked prefill, where each admitted slot writes
        # only its own prompt's prefix of the packed chunk.
        # An int8 cache carries parallel per-token-row scale leaves
        # (runtime/quantize.py): tokens are quantized ONCE here at
        # write time, and reads either stream q+scale through the
        # quantized fused kernel or dequantize for the jnp fallback.
        ck, cv = cache["k"], cache["v"]
        quantized = "k_scale" in cache
        if quantized:
            kq_w, ks_w = quantize.quantize_rows(k)
            vq_w, vs_w = quantize.quantize_rows(v)
            cks, cvs = cache["k_scale"], cache["v_scale"]
        if lengths is None:
            lengths = jnp.zeros((b,), jnp.int32)
        if active is None:
            act2d = jnp.ones((b, s), bool)
        else:
            act = jnp.asarray(active).astype(bool)
            act2d = (act if act.ndim == 2
                     else jnp.broadcast_to(act[:, None], (b, s)))
        t_abs = lengths[:, None] + jnp.arange(s, dtype=jnp.int32)  # (B, S)
        # Valid prefix after the write, per slot (inactive: unchanged).
        new_len = lengths + jnp.sum(act2d, axis=1, dtype=jnp.int32)
        mode = os.environ.get("REPRO_DECODE_KERNEL", "auto")
        use_fused = (s == 1 and cfg.causal and not cfg.sliding_window
                     and mode != "off"
                     and (mode == "interpret"
                          or jax.default_backend() == "tpu"))
        if paged is not None and pages is not None:
            # Paged cache: ck/cv are the layer's physical page pools
            # (num_pages, page_size, Hkv, dh) shared by every slot; the
            # (B, max_pages) page table maps each slot's logical page to
            # its pool row.  Writes scatter through the table (masked
            # rows aimed at num_pages and dropped), reads either gather
            # the slot's pages back into a contiguous view (jnp
            # reference) or ride the table into the fused kernel as a
            # second scalar-prefetch vector.  SWA is gated off (the
            # ring-buffer layout stays contiguous-only).
            psz, mp, npg = paged.page_size, paged.max_pages, paged.num_pages
            page_idx = t_abs // psz                                # (B, S)
            row = t_abs % psz
            page_id = jnp.take_along_axis(
                pages, jnp.clip(page_idx, 0, mp - 1), axis=1)      # (B, S)
            ok_w = act2d & (page_idx < mp) & (page_id >= 0)
            page_w = jnp.where(ok_w, page_id, npg)     # OOB sentinel: drop
            if quantized:
                ck = ck.at[page_w, row].set(kq_w, mode="drop")
                cv = cv.at[page_w, row].set(vq_w, mode="drop")
                cks = cks.at[page_w, row].set(ks_w, mode="drop")
                cvs = cvs.at[page_w, row].set(vs_w, mode="drop")
            else:
                ck = ck.at[page_w, row].set(k.astype(ck.dtype), mode="drop")
                cv = cv.at[page_w, row].set(v.astype(cv.dtype), mode="drop")
            if use_fused and quantized:
                from repro.kernels.attention.decode_int8 import \
                    paged_quantized_gqa_decode_attention
                out = paged_quantized_gqa_decode_attention(
                    q[:, 0], ck, cks, cv, cvs, pages, length=new_len,
                    scale=scale, interpret=(mode == "interpret"))[:, None]
            elif use_fused:
                from repro.kernels.attention.decode import \
                    paged_gqa_decode_attention
                out = paged_gqa_decode_attention(
                    q[:, 0], ck, cv, pages, length=new_len, scale=scale,
                    interpret=(mode == "interpret"))[:, None]
            else:
                safe = jnp.clip(pages, 0, npg - 1)
                kg = ck[safe].reshape(b, mp * psz, cfg.num_kv_heads,
                                      cfg.head_dim)
                vg = cv[safe].reshape(b, mp * psz, cfg.num_kv_heads,
                                      cfg.head_dim)
                if quantized:
                    kg = quantize.dequantize_rows(
                        kg, cks[safe].reshape(b, mp * psz,
                                              cfg.num_kv_heads))
                    vg = quantize.dequantize_rows(
                        vg, cvs[safe].reshape(b, mp * psz,
                                              cfg.num_kv_heads))
                k_pos = jnp.arange(mp * psz, dtype=jnp.int32)
                k_valid = k_pos[None, :] < new_len[:, None]
                out = attention_core(q, kg, vg, pos_b, k_pos,
                                     causal=cfg.causal, window=None,
                                     scale=scale, k_valid=k_valid)
            new_cache = {"k": ck, "v": cv}
            if quantized:
                new_cache.update({"k_scale": cks, "v_scale": cvs})
            out = out.reshape(b, s, cfg.q_dim).astype(x.dtype)
            y = out @ gather_weight(params["wo"]).astype(x.dtype)
            return constrain(y, "batch", "res_seq", "embed"), new_cache
        cache_len = ck.shape[1]
        b_idx = jnp.arange(b, dtype=jnp.int32)[:, None]            # (B, 1)
        t_write = t_abs % cache_len if cfg.sliding_window else t_abs
        # Inactive slots must not write: aim their rows out of bounds and
        # let mode="drop" discard them (also guards depth overflow).
        t_write = jnp.where(act2d, t_write, cache_len)
        if quantized:
            ck = ck.at[b_idx, t_write].set(kq_w, mode="drop")
            cv = cv.at[b_idx, t_write].set(vq_w, mode="drop")
            cks = cks.at[b_idx, t_write].set(ks_w, mode="drop")
            cvs = cvs.at[b_idx, t_write].set(vs_w, mode="drop")
        else:
            ck = ck.at[b_idx, t_write].set(k.astype(ck.dtype), mode="drop")
            cv = cv.at[b_idx, t_write].set(v.astype(cv.dtype), mode="drop")
        ck = constrain(ck, "batch", "kv_seq", "kv_heads", None)
        cv = constrain(cv, "batch", "kv_seq", "kv_heads", None)
        k_slots = jnp.arange(cache_len, dtype=jnp.int32)
        if cfg.sliding_window:
            # Ring buffer, per slot: ring slot j holds absolute position
            # end - ((end % L - j) % L) where end is the slot's newest
            # written position.
            end = new_len - 1                                      # (B,)
            k_pos = (end[:, None]
                     - ((end[:, None] % cache_len - k_slots[None, :])
                        % cache_len))                              # (B, L)
            k_valid = (k_pos >= 0) & (k_pos < new_len[:, None])
        else:
            k_pos = k_slots                                        # (L,)
            k_valid = k_slots[None, :] < new_len[:, None]          # (B, L)
        if use_fused:
            # Serving decode: the single-token hot loop goes through the
            # registry's fused autotuned decode kernel (plan resolved at
            # trace time against the cache `plan_for_model` pre-warmed;
            # the per-slot valid prefixes ride the scalar-prefetch vector
            # the kernel skips on — each slot streams only its own
            # blocks).  The ring-buffer SWA layout and training stay on
            # the jnp path below.  $REPRO_DECODE_KERNEL: "auto" (TPU
            # only), "interpret" (force interpret mode — CPU
            # tests/demos), "off"; resolved at trace time, so changing it
            # after the serve step is jitted requires a retrace (new
            # process / cache clear).
            from repro.kernels.autotune import dispatch
            if quantized:
                out = dispatch("decode_int8", q[:, 0], ck, cks, cv, cvs,
                               length=new_len,
                               interpret=(mode == "interpret"))[:, None]
            else:
                out = dispatch("decode", q[:, 0], ck, cv, length=new_len,
                               interpret=(mode == "interpret"))[:, None]
        else:
            kr, vr = ck, cv
            if quantized:
                kr = quantize.dequantize_rows(ck, cks)
                vr = quantize.dequantize_rows(cv, cvs)
            out = attention_core(q, kr, vr, pos_b, k_pos,
                                 causal=cfg.causal,
                                 window=cfg.sliding_window, scale=scale,
                                 k_valid=k_valid)
        new_cache = {"k": ck, "v": cv}
        if quantized:
            new_cache.update({"k_scale": cks, "v_scale": cvs})

    out = out.reshape(b, s, cfg.q_dim).astype(x.dtype)
    y = out @ gather_weight(params["wo"]).astype(x.dtype)
    return constrain(y, "batch", "res_seq", "embed"), new_cache


def attention_cache_init(cfg, batch: int, cache_len: int, dtype=jnp.bfloat16,
                         paged=None) -> Params:
    quantized = jnp.dtype(dtype) == jnp.int8
    if paged is not None:
        # Paged layout: a pool of physical pages shared by every slot
        # (the per-slot page table lives once at the cache root, not per
        # layer — page id p is pool row p in every layer's K and V).
        if cfg.sliding_window:
            raise ValueError(
                "paged KV cache does not support sliding-window attention "
                "(the ring-buffer layout is contiguous-only)")
        shape = (paged.num_pages, paged.page_size, cfg.num_kv_heads,
                 cfg.head_dim)
    else:
        if cfg.sliding_window:
            cache_len = min(cache_len, cfg.sliding_window)
        shape = (batch, cache_len, cfg.num_kv_heads, cfg.head_dim)
    if quantized:
        # Int8 layout: q values + a parallel f32 per-token-row scale leaf
        # per KV head (one scale for each written (dh,) vector — see
        # runtime/quantize.py for why the block is a row, not a page).
        kq, ks = quantize.quantized_zeros(shape)
        vq, vs = quantize.quantized_zeros(shape)
        return {"k": kq, "k_scale": ks, "v": vq, "v_scale": vs}
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------

def swiglu_init(key, d_model: int, d_ff: int, dtype=jnp.float32) -> Params:
    ks = jax.random.split(key, 3)
    return {
        "w_gate": _dense_init(ks[0], (d_model, d_ff), dtype=dtype),
        "w_up": _dense_init(ks[1], (d_model, d_ff), dtype=dtype),
        "w_down": _dense_init(ks[2], (d_ff, d_model), dtype=dtype),
    }


def swiglu_param_specs() -> Params:
    return {
        "w_gate": ("embed", "ff"),
        "w_up": ("embed", "ff"),
        "w_down": ("ff", "embed"),
    }


def swiglu_apply(params: Params, x: jax.Array) -> jax.Array:
    h = jax.nn.silu(x @ params["w_gate"].astype(x.dtype)) * (
        x @ params["w_up"].astype(x.dtype)
    )
    h = constrain(h, "batch", "seq", "ff")
    return constrain(h @ params["w_down"].astype(x.dtype), "batch", "res_seq", "embed")


def gelu_mlp_init(key, d_model: int, d_ff: int, dtype=jnp.float32) -> Params:
    ks = jax.random.split(key, 2)
    return {
        "w_up": _dense_init(ks[0], (d_model, d_ff), dtype=dtype),
        "w_down": _dense_init(ks[1], (d_ff, d_model), dtype=dtype),
    }


def gelu_mlp_param_specs() -> Params:
    return {"w_up": ("embed", "ff"), "w_down": ("ff", "embed")}


def gelu_mlp_apply(params: Params, x: jax.Array) -> jax.Array:
    h = jax.nn.gelu(x @ params["w_up"].astype(x.dtype))
    h = constrain(h, "batch", "seq", "ff")
    return constrain(h @ params["w_down"].astype(x.dtype), "batch", "res_seq", "embed")


# ---------------------------------------------------------------------------
# Embeddings / heads
# ---------------------------------------------------------------------------

def embedding_init(key, vocab: int, d_model: int, dtype=jnp.float32) -> Params:
    return {"table": _dense_init(key, (vocab, d_model), dtype=dtype)}


def embedding_lookup(params: Params, tokens: jax.Array) -> jax.Array:
    out = jnp.take(params["table"], tokens, axis=0)
    return constrain(out, "batch", "res_seq", "embed")


def unembed(params: Params, x: jax.Array) -> jax.Array:
    """Logits (vocab-sharded; never gathered — the loss is sharded too)."""
    logits = x @ params["table"].T.astype(x.dtype)
    return constrain(logits, "batch", "seq", "vocab")
