"""Mixture-of-Experts with load-balanced dispatch.

Three dispatch implementations, in increasing realism:

- `apply_dense`   — every token through every expert, weighted combine.
                    O(T*E) compute; the correctness oracle for tests.
- `apply_grouped` — single-device sort-based dispatch into a static
                    (E, capacity, D) buffer + batched expert einsum +
                    scatter-add combine.  No collectives; exact modulo
                    capacity drops.
- `apply_sharded` — expert parallelism over the mesh's model axis with
                    explicit `lax.all_to_all` token exchange inside
                    `shard_map` (manual over all axes).  This is the paper's
                    NoC data-movement programming adapted to ICI: tokens are
                    the nonzeros, experts the cores, and capacity absorbs the
                    imbalance exactly like the paper's round-robin nnz law
                    (`core.loadbalance`).

All shapes are static; over-capacity tokens are dropped (combine weight 0),
which the capacity factor makes rare under balanced routing.  Dropped items
scatter to an out-of-bounds index with ``mode="drop"`` so they can never
clobber a kept token's slot.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core.loadbalance import expert_capacity
from repro.models import layers
from repro.parallel.sharding import active_rules

Params = dict


def moe_init(key, cfg, dtype=jnp.float32) -> Params:
    ks = jax.random.split(key, 4)
    e, d, f = cfg.num_experts, cfg.d_model, cfg.moe_d_ff
    return {
        "router": layers._dense_init(ks[0], (d, e), dtype=jnp.float32),
        "w_gate": layers._dense_init(ks[1], (e, d, f), dtype=dtype),
        "w_up": layers._dense_init(ks[2], (e, d, f), dtype=dtype),
        "w_down": layers._dense_init(ks[3], (e, f, d), dtype=dtype),
    }


def moe_param_specs() -> Params:
    return {
        "router": ("embed", None),
        "w_gate": ("experts", "embed", None),
        "w_up": ("experts", "embed", None),
        "w_down": ("experts", None, "embed"),
    }


def route(params: Params, x: jax.Array, cfg):
    """x: (T, D) -> (idx (T,k), weights (T,k), aux_loss scalar)."""
    logits = x.astype(jnp.float32) @ params["router"]
    probs = jax.nn.softmax(logits, axis=-1)                  # (T, E)
    weights, idx = jax.lax.top_k(probs, cfg.top_k)           # (T, k)
    weights = weights / jnp.sum(weights, axis=-1, keepdims=True)
    # Switch-style load-balance loss: E * sum_e f_e * P_e
    e = cfg.num_experts
    hot = jax.nn.one_hot(idx[:, 0], e, dtype=jnp.float32)    # primary choice
    f_e = jnp.mean(hot, axis=0)
    p_e = jnp.mean(probs, axis=0)
    aux = e * jnp.sum(f_e * p_e)
    return idx, weights.astype(x.dtype), aux


def _expert_ffn(params: Params, buf: jax.Array) -> jax.Array:
    """buf: (E, C, D) -> (E, C, D), batched SwiGLU over the expert axis."""
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, params["w_gate"].astype(buf.dtype)))
    h = h * jnp.einsum("ecd,edf->ecf", buf, params["w_up"].astype(buf.dtype))
    return jnp.einsum("ecf,efd->ecd", h, params["w_down"].astype(buf.dtype))


# ---------------------------------------------------------------------------
# Dense oracle
# ---------------------------------------------------------------------------

def apply_dense(params: Params, x: jax.Array, cfg):
    """(T, D) -> (T, D); exact (no capacity drops)."""
    t, d = x.shape
    idx, weights, aux = route(params, x, cfg)
    buf = jnp.broadcast_to(x[None], (cfg.num_experts, t, d))
    out_all = _expert_ffn(params, buf)                        # (E, T, D)
    gate = jnp.zeros((t, cfg.num_experts), x.dtype)
    gate = gate.at[jnp.arange(t)[:, None], idx].set(weights)
    out = jnp.einsum("etd,te->td", out_all, gate)
    return out, aux


# ---------------------------------------------------------------------------
# Sort-based grouped dispatch (local)
# ---------------------------------------------------------------------------

def _dispatch_indices(flat_e: jax.Array, num_groups: int, capacity: int):
    """Slot assignment for sorted group dispatch.

    flat_e: (N,) destination group of each item.  Returns (slot (N,), keep
    (N,)).  ``slot`` is unique among kept items; use ``where(keep, slot, OOB)``
    with ``mode='drop'`` when scattering.
    """
    n = flat_e.shape[0]
    order = jnp.argsort(flat_e, stable=True)
    se = flat_e[order]
    counts = jnp.bincount(flat_e, length=num_groups)
    starts = jnp.cumsum(counts) - counts
    pos = jnp.arange(n, dtype=jnp.int32) - starts[se].astype(jnp.int32)
    keep_sorted = pos < capacity
    slot_sorted = se.astype(jnp.int32) * capacity + jnp.minimum(pos, capacity - 1)
    inv = jnp.argsort(order, stable=True)  # undo the sort
    return slot_sorted[inv], keep_sorted[inv]


def _scatter_slots(values: jax.Array, slot: jax.Array, keep: jax.Array,
                   num_slots: int, fill) -> jax.Array:
    """values (N,) -> (num_slots,) buffer; dropped items write out of bounds."""
    out = jnp.full((num_slots,), fill, dtype=values.dtype)
    write = jnp.where(keep, slot, num_slots)  # OOB => dropped by mode="drop"
    return out.at[write].set(values, mode="drop")


def apply_grouped(params: Params, x: jax.Array, cfg,
                  capacity: int | None = None):
    """(T, D) -> (T, D) via static (E, C, D) buffers. Single-device exact
    path (modulo drops); also the per-device inner loop of `apply_sharded`."""
    t, d = x.shape
    k, e = cfg.top_k, cfg.num_experts
    if capacity is None:
        capacity = expert_capacity(t, e, k, cfg.capacity_factor)
    idx, weights, aux = route(params, x, cfg)

    flat_e = idx.reshape(-1)                                   # (T*k,)
    flat_t = jnp.repeat(jnp.arange(t, dtype=jnp.int32), k)
    flat_w = weights.reshape(-1)
    slot, keep = _dispatch_indices(flat_e, e, capacity)

    slot_token = _scatter_slots(flat_t, slot, keep, e * capacity, t)
    x_pad = jnp.concatenate([x, jnp.zeros((1, d), x.dtype)], axis=0)
    buf = x_pad[slot_token].reshape(e, capacity, d)
    out_buf = _expert_ffn(params, buf).reshape(e * capacity, d)

    gathered = out_buf[jnp.where(keep, slot, 0)]               # (T*k, D)
    contrib = gathered * (flat_w * keep.astype(flat_w.dtype))[:, None]
    out = jnp.zeros((t, d), x.dtype).at[flat_t].add(contrib.astype(x.dtype))
    return out, aux


# ---------------------------------------------------------------------------
# Expert-parallel dispatch: shard_map + all_to_all over the model axis
# ---------------------------------------------------------------------------

def apply_sharded(params: Params, x: jax.Array, cfg, mesh=None):
    """(B, S, D) -> ((B, S, D), aux) with experts sharded over the model axis.

    Tokens travel to their expert shard and back via two all_to_alls;
    everything else is local.  Falls back to the local grouped path when no
    sharding rules are active (CPU tests).  With a replicated batch (e.g.
    batch=1 decode) every device sources the same tokens, receives the same
    contributions back, and the output stays replicated — still correct.
    """
    rules = active_rules()
    b, s, d = x.shape
    if rules is None or rules.table.get("experts") is None:
        out, aux = apply_grouped(params, x.reshape(b * s, d), cfg)
        return out.reshape(b, s, d), aux

    model_axis = rules.table["experts"][0]
    batch_axes = tuple(rules.table.get("batch") or ())
    # Lazy import: the cross-version jax shims live in launch/mesh.py.
    from repro.launch import mesh as mesh_compat
    if mesh is None:
        mesh = mesh_compat.get_abstract_mesh()
    n_shards = mesh.shape[model_axis]
    e = cfg.num_experts
    if e % n_shards:
        raise ValueError(f"{e} experts not divisible by model axis {n_shards}")
    e_loc = e // n_shards

    dp_size = 1
    for a in batch_axes:
        dp_size *= mesh.shape[a]
    if dp_size > 1 and b % dp_size != 0:
        # Batch too small to shard (e.g. batch-1 long-context decode):
        # keep it replicated; the a2a exchange stays correct (see docstring).
        batch_axes, dp_size = (), 1
    # Tokens must also divide across the MODEL axis (sequence-sharded
    # dispatch) or every model rank redundantly routes identical tokens.
    if s % n_shards == 0:
        seq_axes = model_axis          # shard sequence over model
        t_loc = (b // dp_size) * (s // n_shards)
    elif (b // dp_size) % n_shards == 0:
        batch_axes = tuple(batch_axes) + (model_axis,)
        seq_axes = None                # model joins the batch sharding
        t_loc = (b // (dp_size * n_shards)) * s
    else:
        seq_axes = None                # tiny decode: replicate over model
        t_loc = (b // dp_size) * s
    k = cfg.top_k
    c_send = expert_capacity(t_loc * k, n_shards, 1, cfg.capacity_factor)
    c_local = expert_capacity(n_shards * c_send, e_loc, 1, cfg.capacity_factor)

    def local_moe(router_w, w_gate, w_up, w_down, x_loc):
        tl = x_loc.shape[0] * x_loc.shape[1]
        xf = x_loc.reshape(tl, d)
        lp = {"router": router_w}
        idx, weights, aux = route(lp, xf, cfg)
        flat_e = idx.reshape(-1)                                # global expert id
        flat_t = jnp.repeat(jnp.arange(tl, dtype=jnp.int32), k)
        flat_w = weights.reshape(-1)
        dest = flat_e // e_loc                                  # destination shard
        slot, keep = _dispatch_indices(dest, n_shards, c_send)

        n_send = n_shards * c_send
        send_tok = _scatter_slots(flat_t, slot, keep, n_send, tl)
        send_eid = _scatter_slots(flat_e % e_loc, slot, keep, n_send, 0)
        send_valid = _scatter_slots(
            jnp.ones_like(flat_t, dtype=jnp.int32), slot, keep, n_send, 0)
        x_padded = jnp.concatenate([xf, jnp.zeros((1, d), xf.dtype)], axis=0)
        send_x = x_padded[send_tok].reshape(n_shards, c_send, d)

        def a2a(v):
            v = v.reshape(n_shards, c_send, *v.shape[2:]) if v.ndim >= 2 else \
                v.reshape(n_shards, c_send)
            return jax.lax.all_to_all(v, model_axis, split_axis=0, concat_axis=0)

        recv_x = a2a(send_x)                                    # (n_shards, c_send, d)
        recv_eid = a2a(send_eid.reshape(n_shards, c_send))
        recv_valid = a2a(send_valid.reshape(n_shards, c_send))

        # Local grouped expert apply over my e_loc experts.
        r = n_shards * c_send
        rx = recv_x.reshape(r, d)
        re = recv_eid.reshape(r)
        rv = recv_valid.reshape(r).astype(jnp.bool_)
        # Invalid slots go to a phantom group e_loc so they can't consume
        # real experts' capacity; their slots land out of bounds and drop.
        lslot, lkeep = _dispatch_indices(
            jnp.where(rv, re, e_loc), e_loc + 1, c_local)
        lkeep = lkeep & rv
        slot_token = _scatter_slots(
            jnp.arange(r, dtype=jnp.int32), lslot, lkeep, e_loc * c_local, r)
        rx_pad = jnp.concatenate([rx, jnp.zeros((1, d), rx.dtype)], axis=0)
        buf = rx_pad[slot_token].reshape(e_loc, c_local, d)
        outb = _expert_ffn(
            {"w_gate": w_gate, "w_up": w_up, "w_down": w_down}, buf
        ).reshape(e_loc * c_local, d)
        back = outb[jnp.where(lkeep, lslot, 0)] * lkeep[:, None].astype(outb.dtype)
        back = back.reshape(n_shards, c_send, d)

        res = a2a(back).reshape(n_send, d)                      # results home again
        safe_slot = jnp.where(keep, slot, 0)
        contrib = res[safe_slot] * (flat_w * keep.astype(flat_w.dtype))[:, None]
        out = jnp.zeros((tl, d), xf.dtype).at[flat_t].add(contrib.astype(xf.dtype))
        axes = tuple(dict.fromkeys(tuple(batch_axes) + (model_axis,)))
        aux = jax.lax.pmean(aux, axis_name=axes if len(axes) > 1 else axes[0])
        return out.reshape(x_loc.shape), aux

    manual = frozenset(batch_axes) | {model_axis}
    batch_spec = P(tuple(batch_axes) if batch_axes else None, seq_axes, None)
    out, aux = mesh_compat.shard_map(
        local_moe,
        mesh,
        in_specs=(P(None, None), P(model_axis, None, None),
                  P(model_axis, None, None), P(model_axis, None, None),
                  batch_spec),
        out_specs=(batch_spec, P()),
        axis_names=manual,
    )(params["router"], params["w_gate"], params["w_up"], params["w_down"], x)
    return out, jnp.mean(aux)
