"""RWKV-6 ("Finch") block: attention-free time mix with data-dependent decay.

The WKV recurrence keeps a per-head (dh x dh) state, so decode is O(1) in
sequence length — `long_500k` costs the same per token as short contexts.

Faithful structure: token-shift interpolation (static mix vectors), a
low-rank data-dependent decay `w_t = exp(-exp(w0 + tanh(x W_a) W_b))`
(the defining Finch feature), bonus `u`, per-head normalization, gated
output, and squared-ReLU channel mix.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import layers
from repro.parallel.sharding import constrain

Params = dict


def rwkv_time_init(key, cfg, dtype=jnp.float32) -> Params:
    d = cfg.d_model
    l = cfg.rwkv_lora_dim
    ks = jax.random.split(key, 7)
    h = d // cfg.rwkv_head_dim
    return {
        "mix": 0.5 * jnp.ones((5, d), dtype),          # r,k,v,g,w shift mixes
        "wr": layers._dense_init(ks[0], (d, d), dtype=dtype),
        "wk": layers._dense_init(ks[1], (d, d), dtype=dtype),
        "wv": layers._dense_init(ks[2], (d, d), dtype=dtype),
        "wg": layers._dense_init(ks[3], (d, d), dtype=dtype),
        "wo": layers._dense_init(ks[4], (d, d), dtype=dtype),
        "decay_w0": jnp.full((d,), -2.0, jnp.float32),
        "decay_a": layers._dense_init(ks[5], (d, l), dtype=jnp.float32),
        "decay_b": layers._dense_init(ks[6], (l, d), dtype=jnp.float32),
        "bonus_u": jnp.zeros((h, cfg.rwkv_head_dim), jnp.float32),
        "ln_x": layers.rmsnorm_init(d, jnp.float32),
    }


def rwkv_channel_init(key, cfg, dtype=jnp.float32) -> Params:
    d = cfg.d_model
    ks = jax.random.split(key, 3)
    return {
        "cmix": 0.5 * jnp.ones((2, d), dtype),         # r,k shift mixes
        "ck": layers._dense_init(ks[0], (d, cfg.d_ff), dtype=dtype),
        "cv": layers._dense_init(ks[1], (cfg.d_ff, d), dtype=dtype),
        "cr": layers._dense_init(ks[2], (d, d), dtype=dtype),
    }


def rwkv_time_param_specs(cfg) -> Params:
    return {
        "mix": (None, "embed"),
        "wr": ("embed", "heads"), "wk": ("embed", "heads"),
        "wv": ("embed", "heads"), "wg": ("embed", "heads"),
        "wo": ("heads", "embed"),
        "decay_w0": ("embed",),
        "decay_a": ("embed", None), "decay_b": (None, "embed"),
        "bonus_u": ("heads", None),
        "ln_x": {"scale": (None,)},
    }


def rwkv_channel_param_specs(cfg) -> Params:
    return {
        "cmix": (None, "embed"),
        "ck": ("embed", "ff"), "cv": ("ff", "embed"), "cr": ("embed", "embed"),
    }


def _token_shift(x: jax.Array, prev: jax.Array | None):
    """Shifted-by-one sequence; ``prev`` is the last token of the previous
    chunk (decode state), zeros at the very start."""
    if prev is None:
        prev = jnp.zeros_like(x[:, :1])
    shifted = jnp.concatenate([prev.astype(x.dtype), x[:, :-1]], axis=1)
    return shifted, x[:, -1:].astype(prev.dtype)


def _wkv_scan(r, k, v, w, u, s0):
    """Recurrence per head.  r,k,v: (B,S,H,dh); w: (B,S,H,dh) decay in (0,1);
    u: (H,dh) bonus; s0: (B,H,dh,dh) state (k-dim x v-dim)."""

    def step(s, inp):
        r_t, k_t, v_t, w_t = inp                       # (B,H,dh)
        kv = k_t[..., :, None] * v_t[..., None, :]     # (B,H,dh,dh)
        y = jnp.einsum("bhk,bhkv->bhv", r_t, s + u[None, :, :, None] * kv)
        s = w_t[..., :, None] * s + kv
        return s, y

    xs = tuple(jnp.moveaxis(t, 1, 0) for t in (r, k, v, w))
    s_last, ys = jax.lax.scan(step, s0, xs)
    return jnp.moveaxis(ys, 0, 1), s_last              # (B,S,H,dh)


def rwkv_time_mix(params: Params, x: jax.Array, cfg,
                  state: Params | None = None):
    b, s, d = x.shape
    h, dh = d // cfg.rwkv_head_dim, cfg.rwkv_head_dim
    prev = state["shift_t"] if state is not None else None
    shifted, last = _token_shift(x, prev)
    mix = params["mix"].astype(x.dtype)
    xr, xk, xv, xg, xw = (x + (shifted - x) * mix[i] for i in range(5))

    r = (xr @ params["wr"].astype(x.dtype)).reshape(b, s, h, dh)
    k = (xk @ params["wk"].astype(x.dtype)).reshape(b, s, h, dh)
    v = (xv @ params["wv"].astype(x.dtype)).reshape(b, s, h, dh)
    g = jax.nn.silu(xg @ params["wg"].astype(x.dtype))

    # Data-dependent decay (the RWKV6 novelty).
    dd = jnp.tanh(xw.astype(jnp.float32) @ params["decay_a"]) @ params["decay_b"]
    w = jnp.exp(-jnp.exp(params["decay_w0"][None, None] + dd))  # (B,S,D)
    w = w.reshape(b, s, h, dh)

    s0 = (state["wkv"] if state is not None
          else jnp.zeros((b, h, dh, dh), jnp.float32))
    y, s_last = _wkv_scan(r.astype(jnp.float32), k.astype(jnp.float32),
                          v.astype(jnp.float32), w, params["bonus_u"], s0)
    y = layers.rmsnorm(params["ln_x"], y.reshape(b, s, d), cfg.norm_eps)
    out = (y.astype(x.dtype) * g) @ params["wo"].astype(x.dtype)
    new_state = None
    if state is not None:
        new_state = {"shift_t": last, "wkv": s_last}
    return constrain(out, "batch", "res_seq", "embed"), new_state


def rwkv_channel_mix(params: Params, x: jax.Array, cfg,
                     state: Params | None = None):
    prev = state["shift_c"] if state is not None else None
    shifted, last = _token_shift(x, prev)
    cmix = params["cmix"].astype(x.dtype)
    xk = x + (shifted - x) * cmix[0]
    xr = x + (shifted - x) * cmix[1]
    kk = jnp.square(jax.nn.relu(xk @ params["ck"].astype(x.dtype)))
    kk = constrain(kk, "batch", "seq", "ff")
    out = jax.nn.sigmoid(xr @ params["cr"].astype(x.dtype)) * (
        kk @ params["cv"].astype(x.dtype))
    new_state = {"shift_c": last} if state is not None else None
    return constrain(out, "batch", "res_seq", "embed"), new_state


def rwkv_cache_init(cfg, batch: int, dtype=jnp.bfloat16) -> Params:
    d = cfg.d_model
    h, dh = d // cfg.rwkv_head_dim, cfg.rwkv_head_dim
    return {
        "shift_t": jnp.zeros((batch, 1, d), dtype),
        "wkv": jnp.zeros((batch, h, dh, dh), jnp.float32),
        "shift_c": jnp.zeros((batch, 1, d), dtype),
    }
