#!/usr/bin/env python
"""Serving-smoke log checker, run by the CI serve-smoke job.

Validates the stdout of `python -m repro.launch.serve` (typically the
`--smoke` run):

1. **The `serving_plan` line parses** as JSON and reports a positive
   predicted decode throughput with a batch >= 1 — the autotuner's batch
   sweep actually produced a decision, not a crash or a degenerate plan.
2. **The final summary line parses** and shows every queued request
   completed with a positive generated-token count — the ragged
   continuous-batching loop drained the queue.

Optional flags pin the expected workload: ``--requests N`` asserts the
summary served exactly N requests, ``--min-tokens T`` floors
``tokens_generated``.

Usage: python tools/check_serve.py serve.log [--requests N]
       [--min-tokens T]
Exit code 0 = clean; 1 = problems (listed one per line).
"""

from __future__ import annotations

import argparse
import json
import pathlib
import sys


def _json_lines(text: str) -> list[dict]:
    out = []
    for line in text.splitlines():
        line = line.strip()
        if not line.startswith("{"):
            continue
        try:
            row = json.loads(line)
        except ValueError:
            continue
        if isinstance(row, dict):
            out.append(row)
    return out


def check(text: str, requests: int | None = None,
          min_tokens: int = 1) -> list[str]:
    problems: list[str] = []
    rows = _json_lines(text)

    plans = [r["serving_plan"] for r in rows if "serving_plan" in r]
    if not plans:
        problems.append("no parseable {\"serving_plan\": ...} JSON line")
    else:
        plan = plans[-1]
        if not isinstance(plan, dict) or plan.get("batch", 0) < 1:
            problems.append(f"serving_plan: batch must be >= 1, got "
                            f"{plan.get('batch') if isinstance(plan, dict) else plan!r}")
        if isinstance(plan, dict) and plan.get("source") == "autotune":
            tok = plan.get("predicted_tok_per_s", 0)
            if not (isinstance(tok, (int, float)) and tok > 0):
                problems.append(
                    f"serving_plan: predicted_tok_per_s must be positive, "
                    f"got {tok!r}")

    summaries = [r for r in rows if "tokens_generated" in r]
    if not summaries:
        problems.append("no parseable serve summary JSON line "
                        "(tokens_generated)")
    else:
        s = summaries[-1]
        if s.get("tokens_generated", 0) < min_tokens:
            problems.append(f"summary: tokens_generated "
                            f"{s.get('tokens_generated')} < {min_tokens}")
        if requests is not None and s.get("requests") != requests:
            problems.append(f"summary: served {s.get('requests')} requests, "
                            f"expected {requests}")
        elif requests is None and s.get("requests", 0) < 1:
            problems.append("summary: no requests completed")
    return problems


def main(argv: list[str]) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("log", type=pathlib.Path,
                    help="captured stdout of repro.launch.serve")
    ap.add_argument("--requests", type=int, default=None)
    ap.add_argument("--min-tokens", type=int, default=1)
    args = ap.parse_args(argv[1:])

    try:
        text = args.log.read_text()
    except OSError as e:
        print(f"{args.log}: unreadable ({e!r})")
        return 1
    problems = check(text, requests=args.requests,
                     min_tokens=args.min_tokens)
    for p in problems:
        print(p)
    if not problems:
        print(f"ok: {args.log} (serving_plan parsed, positive predicted "
              f"throughput, queue drained)")
    return 1 if problems else 0


if __name__ == "__main__":
    raise SystemExit(main(sys.argv))
