#!/usr/bin/env python
"""Serving-smoke log checker, run by the CI serve-smoke and chaos-smoke jobs.

Validates the stdout of `python -m repro.launch.serve` (typically the
`--smoke` run):

1. **The `serving_plan` line parses** as JSON and reports a positive
   predicted decode throughput with a batch >= 1 — the autotuner's batch
   sweep actually produced a decision, not a crash or a degenerate plan.
2. **The final summary line parses** and shows every queued request
   completed with a positive generated-token count — the ragged
   continuous-batching loop drained the queue.
3. **Request conservation**: the summary's outcome counters are present
   and account for every submitted request exactly once
   (``submitted == completed + timed_out + failed + rejected``) — the
   fault-tolerance layer's core invariant: a request may be slow, evicted,
   or refused, but never silently lost.
4. **TTFT percentiles are present** (``ttft_ms.p50``/``p99``) whenever
   anything completed.

Optional flags pin the expected workload: ``--requests N`` asserts the
summary completed exactly N requests, ``--min-tokens T`` floors
``tokens_generated``, and ``--chaos`` additionally requires the fault
schedule to have fired (at least one injected fault of each scheduled
class reached the server) with zero failed requests.

**Recovery mode** (the crash-smoke CI job): ``--recovery`` validates the
log of a `serve --resume` run after an injected crash.  ``--crash-log``
points at the crashed run's stdout (must contain the ``{"crash": ...}``
marker and NO summary — the process really died mid-serve);
``--journal`` points at the shared request journal, over which this
checker independently re-folds exactly-once accounting: every submitted
rid reaches a terminal state exactly once *across both process
lifetimes*, token indices are contiguous per attempt, and every
completed request carries its full token count.  ``--snapshot-every``
bounds the recovery block's ``replayed_steps``.  The journal fold here
is a deliberate stdlib-only reimplementation — double-entry bookkeeping
against `repro.runtime.journal`.

**Geometry mode**: ``--serving-json PATH`` points at the durable
``serving.json`` the run wrote to its state dir; the checker
cross-checks the declared geometry against the summary — kv dtype, the
paged pool's ``page_size``/``num_pages`` against the summary's ``kv``
block, and the batch against the ``serving_plan`` line.  A mismatch
means ``serve --resume`` would rebuild a cache whose layout does not
match the snapshots on disk, so it fails loudly here instead of
corrupting a recovery later.  The ok line always reports the kv dtype
the summary ran with.

Usage: python tools/check_serve.py serve.log [--requests N]
       [--min-tokens T] [--chaos] [--serving-json serving.json]
       [--recovery [--crash-log LOG] [--journal J] [--snapshot-every N]]
Exit code 0 = clean; 1 = problems (listed one per line).
"""

from __future__ import annotations

import argparse
import json
import pathlib
import sys

OUTCOME_KEYS = ("completed", "timed_out", "failed", "rejected",
                "evicted", "retried")


def _json_lines(text: str) -> list[dict]:
    out = []
    for line in text.splitlines():
        line = line.strip()
        if not line.startswith("{"):
            continue
        try:
            row = json.loads(line)
        except ValueError:
            continue
        if isinstance(row, dict):
            out.append(row)
    return out


def _check_outcomes(s: dict, problems: list[str]) -> None:
    outcomes = s.get("outcomes")
    if not isinstance(outcomes, dict):
        problems.append("summary: missing outcome counters "
                        "(\"outcomes\": {...})")
        return
    for key in OUTCOME_KEYS:
        if not isinstance(outcomes.get(key), int):
            problems.append(f"summary: outcome counter {key!r} missing or "
                            f"non-integer, got {outcomes.get(key)!r}")
    submitted = s.get("submitted")
    if not isinstance(submitted, int):
        problems.append(f"summary: missing integer \"submitted\" count, "
                        f"got {submitted!r}")
        return
    terminal = sum(outcomes.get(k) or 0 for k in
                   ("completed", "timed_out", "failed", "rejected"))
    if terminal != submitted:
        problems.append(
            f"summary: request conservation violated — submitted="
            f"{submitted} but completed+timed_out+failed+rejected="
            f"{terminal} (a request was lost or double-counted)")


def _check_ttft(s: dict, problems: list[str]) -> None:
    ttft = s.get("ttft_ms")
    if not isinstance(ttft, dict):
        problems.append("summary: missing TTFT percentiles "
                        "(\"ttft_ms\": {\"p50\": ..., \"p99\": ...})")
        return
    completed = (s.get("outcomes") or {}).get("completed", 0)
    for key in ("p50", "p99"):
        v = ttft.get(key)
        if completed and not isinstance(v, (int, float)):
            problems.append(f"summary: ttft_ms.{key} must be numeric when "
                            f"requests completed, got {v!r}")


def _check_chaos(rows: list[dict], s: dict, problems: list[str]) -> None:
    plans = [r["fault_plan"] for r in rows if "fault_plan" in r]
    if not plans:
        problems.append("chaos: no parseable {\"fault_plan\": ...} line "
                        "(was --chaos passed to serve?)")
    faults = s.get("faults")
    if not isinstance(faults, dict):
        problems.append("chaos: summary has no \"faults\" record")
        return
    scheduled = {e.get("kind") for e in faults.get("schedule", [])}
    fired = {e.get("kind") for e in faults.get("fired", [])
             if not e.get("skipped")}
    missing = scheduled - fired
    if missing:
        problems.append(f"chaos: scheduled fault class(es) never fired: "
                        f"{sorted(missing)}")
    failed = (s.get("outcomes") or {}).get("failed", 0)
    if failed:
        problems.append(f"chaos: {failed} request(s) FAILED under the "
                        f"smoke schedule (retry budget should absorb it)")


TERMINAL_STATES = ("completed", "timed_out", "failed", "rejected")


def fold_journal(path: pathlib.Path) -> tuple[dict, list[str]]:
    """Stdlib re-fold of a request journal: per-rid terminal-entry counts,
    token contiguity, and final state.  A malformed *final* line is the
    crash signature and is dropped; malformed interior lines are
    reported as problems."""
    problems: list[str] = []
    reqs: dict[int, dict] = {}
    try:
        raw = path.read_text()
    except OSError as e:
        return {}, [f"journal {path}: unreadable ({e!r})"]
    lines = raw.split("\n")
    torn = lines.pop() if lines and lines[-1] != "" else None
    if torn is not None:
        try:
            rec = json.loads(torn)
            lines.append(torn)      # parseable, just newline-less: keep
        except ValueError:
            pass                    # truncated mid-append: dropped
    for ln, line in enumerate(lines, start=1):
        if not line.strip():
            continue
        try:
            rec = json.loads(line)
        except ValueError:
            problems.append(f"journal {path}:{ln}: corrupt interior line")
            continue
        kind = rec.get("kind")
        rid = rec.get("rid")
        if kind == "submit":
            reqs[rid] = {"gen_len": rec.get("gen_len"), "tokens": 0,
                         "terminal_entries": 0, "state": None}
        elif kind == "state":
            r = reqs.get(rid)
            if r is None:
                problems.append(f"journal {path}:{ln}: state record for "
                                f"unknown rid {rid}")
                continue
            state = rec.get("state")
            if state in TERMINAL_STATES:
                r["terminal_entries"] += 1
            if state == "queued":
                r["tokens"] = 0      # eviction requeue discards output
            r["state"] = state
        elif kind == "token":
            r = reqs.get(rid)
            if r is None:
                problems.append(f"journal {path}:{ln}: token record for "
                                f"unknown rid {rid}")
                continue
            i = rec.get("i")
            if not isinstance(i, int) or i > r["tokens"]:
                problems.append(
                    f"journal {path}:{ln}: token index gap for rid {rid} "
                    f"(i={i}, have {r['tokens']})")
                continue
            r["tokens"] = i + 1      # overwrite semantics past i
    return reqs, problems


def check_recovery(text: str, crash_text: str | None = None,
                   journal: pathlib.Path | None = None,
                   snapshot_every: int | None = None) -> list[str]:
    """The crash-smoke gate: crashed run really died, resumed run really
    recovered, and the shared journal conserves every request exactly
    once across both lifetimes."""
    problems: list[str] = []

    if crash_text is not None:
        crash_rows = _json_lines(crash_text)
        if not any("crash" in r for r in crash_rows):
            problems.append("recovery: crash log has no {\"crash\": ...} "
                            "marker — did the fault fire?")
        if any("tokens_generated" in r for r in crash_rows):
            problems.append("recovery: crash log contains a summary line "
                            "— the process did NOT die mid-serve")

    rows = _json_lines(text)
    summaries = [r for r in rows if "tokens_generated" in r]
    rec = (summaries[-1].get("recovery") if summaries else None) or next(
        (r["recovery"] for r in rows if "recovery" in r), None)
    if not isinstance(rec, dict) or not rec.get("resumed"):
        problems.append("recovery: resume log has no recovery block "
                        "(was --resume passed to serve?)")
        return problems
    replayed = rec.get("replayed_steps")
    if not isinstance(replayed, int) or replayed < 1:
        problems.append(f"recovery: replayed_steps must be a positive "
                        f"int, got {replayed!r}")
    elif snapshot_every is not None and replayed > snapshot_every:
        problems.append(f"recovery: replayed {replayed} steps > snapshot "
                        f"interval {snapshot_every} — snapshots are not "
                        f"bounding the journal replay")

    if journal is not None:
        reqs, jproblems = fold_journal(journal)
        problems.extend(jproblems)
        if not reqs:
            problems.append(f"recovery: journal {journal} holds no "
                            f"submitted requests")
        for rid in sorted(reqs):
            r = reqs[rid]
            if r["terminal_entries"] != 1:
                problems.append(
                    f"recovery: rid {rid} entered a terminal state "
                    f"{r['terminal_entries']} times across both "
                    f"lifetimes (must be exactly once)")
            if r["state"] not in TERMINAL_STATES:
                problems.append(f"recovery: rid {rid} ended the journal "
                                f"in non-terminal state {r['state']!r}")
            if r["state"] == "completed" and \
                    r["tokens"] != r["gen_len"] + 1:
                problems.append(
                    f"recovery: rid {rid} completed with {r['tokens']} "
                    f"journaled tokens, expected gen_len+1="
                    f"{r['gen_len'] + 1} (duplicated or lost tokens)")
    return problems


def check_serving_json(text: str, serving: dict) -> list[str]:
    """Cross-check the durable serving.json geometry against the run's
    summary.  The kv dtype and the paged-pool geometry must agree — a
    disagreement means `serve --resume` would rebuild a cache whose
    layout (int8+scale leaves vs float, pool shape) does not match the
    snapshots on disk, which must fail here, not mid-recovery."""
    problems: list[str] = []
    rows = _json_lines(text)
    summaries = [r for r in rows if "tokens_generated" in r]
    if not summaries:
        return ["serving-json: no summary line to cross-check against"]
    s = summaries[-1]

    want_dtype = serving.get("kv_dtype", "float32")
    got_dtype = s.get("kv_dtype")
    if got_dtype is None:
        problems.append(
            "serving-json: summary reports no \"kv_dtype\" — cannot "
            "confirm which cache layout the run actually used")
    elif got_dtype != want_dtype:
        problems.append(
            f"serving-json: kv dtype mismatch — serving.json declares "
            f"{want_dtype!r} but the summary ran {got_dtype!r} (resume "
            f"would rebuild the wrong cache layout)")

    pg = serving.get("paging")
    kv = s.get("kv")
    if pg is not None:
        if not isinstance(kv, dict):
            problems.append(
                "serving-json: paged geometry declared but the summary "
                "has no \"kv\" block — the run was not actually paged")
        else:
            for field in ("page_size", "num_pages"):
                if kv.get(field) != pg.get(field):
                    problems.append(
                        f"serving-json: paged geometry mismatch — "
                        f"serving.json {field}={pg.get(field)!r} but the "
                        f"summary's kv block reports {kv.get(field)!r}")
    elif isinstance(kv, dict):
        problems.append(
            "serving-json: summary has a paged \"kv\" block but "
            "serving.json declares no paging geometry")

    batch = serving.get("batch")
    plans = [r["serving_plan"] for r in rows if "serving_plan" in r]
    if batch is not None and plans and isinstance(plans[-1], dict) \
            and plans[-1].get("batch") != batch:
        problems.append(
            f"serving-json: batch mismatch — serving.json declares "
            f"{batch} but the serving_plan line chose "
            f"{plans[-1].get('batch')!r}")
    return problems


def check(text: str, requests: int | None = None,
          min_tokens: int = 1, chaos: bool = False,
          require_plan: bool = True) -> list[str]:
    problems: list[str] = []
    rows = _json_lines(text)

    plans = [r["serving_plan"] for r in rows if "serving_plan" in r]
    if not plans:
        # a --resume run re-derives its plan from serving.json and prints
        # no serving_plan line; recovery mode relaxes the requirement
        if require_plan:
            problems.append("no parseable {\"serving_plan\": ...} JSON line")
    else:
        plan = plans[-1]
        if not isinstance(plan, dict) or plan.get("batch", 0) < 1:
            problems.append(f"serving_plan: batch must be >= 1, got "
                            f"{plan.get('batch') if isinstance(plan, dict) else plan!r}")
        if isinstance(plan, dict) and plan.get("source") == "autotune":
            tok = plan.get("predicted_tok_per_s", 0)
            if not (isinstance(tok, (int, float)) and tok > 0):
                problems.append(
                    f"serving_plan: predicted_tok_per_s must be positive, "
                    f"got {tok!r}")

    summaries = [r for r in rows if "tokens_generated" in r]
    if not summaries:
        problems.append("no parseable serve summary JSON line "
                        "(tokens_generated)")
        return problems
    s = summaries[-1]
    if s.get("tokens_generated", 0) < min_tokens:
        problems.append(f"summary: tokens_generated "
                        f"{s.get('tokens_generated')} < {min_tokens}")
    if requests is not None and s.get("requests") != requests:
        problems.append(f"summary: served {s.get('requests')} requests, "
                        f"expected {requests}")
    elif requests is None and s.get("requests", 0) < 1:
        problems.append("summary: no requests completed")
    _check_outcomes(s, problems)
    _check_ttft(s, problems)
    if chaos:
        _check_chaos(rows, s, problems)
    return problems


def main(argv: list[str]) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("log", type=pathlib.Path,
                    help="captured stdout of repro.launch.serve")
    ap.add_argument("--requests", type=int, default=None)
    ap.add_argument("--min-tokens", type=int, default=1)
    ap.add_argument("--chaos", action="store_true",
                    help="require the fault schedule to have fired with "
                         "zero FAILED requests")
    ap.add_argument("--recovery", action="store_true",
                    help="validate a `serve --resume` log (crash-smoke "
                         "job): recovery block, bounded replay, and "
                         "journal-folded exactly-once accounting")
    ap.add_argument("--crash-log", type=pathlib.Path, default=None,
                    help="stdout of the crashed run (recovery mode): must "
                         "hold the crash marker and no summary")
    ap.add_argument("--journal", type=pathlib.Path, default=None,
                    help="the shared request journal (recovery mode)")
    ap.add_argument("--snapshot-every", type=int, default=None,
                    help="snapshot interval that must bound "
                         "replayed_steps (recovery mode)")
    ap.add_argument("--serving-json", type=pathlib.Path, default=None,
                    help="the run's durable serving.json: cross-check its "
                         "kv dtype / paged geometry / batch against the "
                         "summary and fail loudly on disagreement")
    args = ap.parse_args(argv[1:])

    try:
        text = args.log.read_text()
    except OSError as e:
        print(f"{args.log}: unreadable ({e!r})")
        return 1
    problems = check(text, requests=args.requests,
                     min_tokens=args.min_tokens, chaos=args.chaos,
                     require_plan=not args.recovery)
    if args.recovery:
        crash_text = None
        if args.crash_log is not None:
            try:
                crash_text = args.crash_log.read_text()
            except OSError as e:
                problems.append(f"{args.crash_log}: unreadable ({e!r})")
        problems.extend(check_recovery(
            text, crash_text=crash_text, journal=args.journal,
            snapshot_every=args.snapshot_every))
    if args.serving_json is not None:
        try:
            serving = json.loads(args.serving_json.read_text())
        except (OSError, ValueError) as e:
            serving = None
            problems.append(f"{args.serving_json}: unreadable serving.json "
                            f"({e!r})")
        if isinstance(serving, dict):
            problems.extend(check_serving_json(text, serving))
    for p in problems:
        print(p)
    if not problems:
        summaries = [r for r in _json_lines(text)
                     if "tokens_generated" in r]
        kv_dtype = summaries[-1].get("kv_dtype", "?") if summaries else "?"
        extra = (", chaos schedule fired" if args.chaos else "") + \
            (", crash recovered with exactly-once accounting"
             if args.recovery else "") + \
            (", serving.json geometry agrees"
             if args.serving_json is not None else "")
        print(f"ok: {args.log} (summary parsed, queue drained, outcomes "
              f"conserve the submitted count, kv dtype {kv_dtype}{extra})")
    return 1 if problems else 0


if __name__ == "__main__":
    raise SystemExit(main(sys.argv))
