#!/usr/bin/env python
"""Serving-smoke log checker, run by the CI serve-smoke and chaos-smoke jobs.

Validates the stdout of `python -m repro.launch.serve` (typically the
`--smoke` run):

1. **The `serving_plan` line parses** as JSON and reports a positive
   predicted decode throughput with a batch >= 1 — the autotuner's batch
   sweep actually produced a decision, not a crash or a degenerate plan.
2. **The final summary line parses** and shows every queued request
   completed with a positive generated-token count — the ragged
   continuous-batching loop drained the queue.
3. **Request conservation**: the summary's outcome counters are present
   and account for every submitted request exactly once
   (``submitted == completed + timed_out + failed + rejected``) — the
   fault-tolerance layer's core invariant: a request may be slow, evicted,
   or refused, but never silently lost.
4. **TTFT percentiles are present** (``ttft_ms.p50``/``p99``) whenever
   anything completed.

Optional flags pin the expected workload: ``--requests N`` asserts the
summary completed exactly N requests, ``--min-tokens T`` floors
``tokens_generated``, and ``--chaos`` additionally requires the fault
schedule to have fired (at least one injected fault of each scheduled
class reached the server) with zero failed requests.

Usage: python tools/check_serve.py serve.log [--requests N]
       [--min-tokens T] [--chaos]
Exit code 0 = clean; 1 = problems (listed one per line).
"""

from __future__ import annotations

import argparse
import json
import pathlib
import sys

OUTCOME_KEYS = ("completed", "timed_out", "failed", "rejected",
                "evicted", "retried")


def _json_lines(text: str) -> list[dict]:
    out = []
    for line in text.splitlines():
        line = line.strip()
        if not line.startswith("{"):
            continue
        try:
            row = json.loads(line)
        except ValueError:
            continue
        if isinstance(row, dict):
            out.append(row)
    return out


def _check_outcomes(s: dict, problems: list[str]) -> None:
    outcomes = s.get("outcomes")
    if not isinstance(outcomes, dict):
        problems.append("summary: missing outcome counters "
                        "(\"outcomes\": {...})")
        return
    for key in OUTCOME_KEYS:
        if not isinstance(outcomes.get(key), int):
            problems.append(f"summary: outcome counter {key!r} missing or "
                            f"non-integer, got {outcomes.get(key)!r}")
    submitted = s.get("submitted")
    if not isinstance(submitted, int):
        problems.append(f"summary: missing integer \"submitted\" count, "
                        f"got {submitted!r}")
        return
    terminal = sum(outcomes.get(k) or 0 for k in
                   ("completed", "timed_out", "failed", "rejected"))
    if terminal != submitted:
        problems.append(
            f"summary: request conservation violated — submitted="
            f"{submitted} but completed+timed_out+failed+rejected="
            f"{terminal} (a request was lost or double-counted)")


def _check_ttft(s: dict, problems: list[str]) -> None:
    ttft = s.get("ttft_ms")
    if not isinstance(ttft, dict):
        problems.append("summary: missing TTFT percentiles "
                        "(\"ttft_ms\": {\"p50\": ..., \"p99\": ...})")
        return
    completed = (s.get("outcomes") or {}).get("completed", 0)
    for key in ("p50", "p99"):
        v = ttft.get(key)
        if completed and not isinstance(v, (int, float)):
            problems.append(f"summary: ttft_ms.{key} must be numeric when "
                            f"requests completed, got {v!r}")


def _check_chaos(rows: list[dict], s: dict, problems: list[str]) -> None:
    plans = [r["fault_plan"] for r in rows if "fault_plan" in r]
    if not plans:
        problems.append("chaos: no parseable {\"fault_plan\": ...} line "
                        "(was --chaos passed to serve?)")
    faults = s.get("faults")
    if not isinstance(faults, dict):
        problems.append("chaos: summary has no \"faults\" record")
        return
    scheduled = {e.get("kind") for e in faults.get("schedule", [])}
    fired = {e.get("kind") for e in faults.get("fired", [])
             if not e.get("skipped")}
    missing = scheduled - fired
    if missing:
        problems.append(f"chaos: scheduled fault class(es) never fired: "
                        f"{sorted(missing)}")
    failed = (s.get("outcomes") or {}).get("failed", 0)
    if failed:
        problems.append(f"chaos: {failed} request(s) FAILED under the "
                        f"smoke schedule (retry budget should absorb it)")


def check(text: str, requests: int | None = None,
          min_tokens: int = 1, chaos: bool = False) -> list[str]:
    problems: list[str] = []
    rows = _json_lines(text)

    plans = [r["serving_plan"] for r in rows if "serving_plan" in r]
    if not plans:
        problems.append("no parseable {\"serving_plan\": ...} JSON line")
    else:
        plan = plans[-1]
        if not isinstance(plan, dict) or plan.get("batch", 0) < 1:
            problems.append(f"serving_plan: batch must be >= 1, got "
                            f"{plan.get('batch') if isinstance(plan, dict) else plan!r}")
        if isinstance(plan, dict) and plan.get("source") == "autotune":
            tok = plan.get("predicted_tok_per_s", 0)
            if not (isinstance(tok, (int, float)) and tok > 0):
                problems.append(
                    f"serving_plan: predicted_tok_per_s must be positive, "
                    f"got {tok!r}")

    summaries = [r for r in rows if "tokens_generated" in r]
    if not summaries:
        problems.append("no parseable serve summary JSON line "
                        "(tokens_generated)")
        return problems
    s = summaries[-1]
    if s.get("tokens_generated", 0) < min_tokens:
        problems.append(f"summary: tokens_generated "
                        f"{s.get('tokens_generated')} < {min_tokens}")
    if requests is not None and s.get("requests") != requests:
        problems.append(f"summary: served {s.get('requests')} requests, "
                        f"expected {requests}")
    elif requests is None and s.get("requests", 0) < 1:
        problems.append("summary: no requests completed")
    _check_outcomes(s, problems)
    _check_ttft(s, problems)
    if chaos:
        _check_chaos(rows, s, problems)
    return problems


def main(argv: list[str]) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("log", type=pathlib.Path,
                    help="captured stdout of repro.launch.serve")
    ap.add_argument("--requests", type=int, default=None)
    ap.add_argument("--min-tokens", type=int, default=1)
    ap.add_argument("--chaos", action="store_true",
                    help="require the fault schedule to have fired with "
                         "zero FAILED requests")
    args = ap.parse_args(argv[1:])

    try:
        text = args.log.read_text()
    except OSError as e:
        print(f"{args.log}: unreadable ({e!r})")
        return 1
    problems = check(text, requests=args.requests,
                     min_tokens=args.min_tokens, chaos=args.chaos)
    for p in problems:
        print(p)
    if not problems:
        print(f"ok: {args.log} (serving_plan parsed, positive predicted "
              f"throughput, queue drained, outcomes conserve the "
              f"submitted count{', chaos schedule fired' if args.chaos else ''})")
    return 1 if problems else 0


if __name__ == "__main__":
    raise SystemExit(main(sys.argv))
