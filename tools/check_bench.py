#!/usr/bin/env python
"""Benchmark-report schema checker, run by the CI bench-smoke job.

Validates a BENCH_kernels.json produced by `benchmarks/run.py` (typically
`--smoke`):

1. **Schema version** matches what the current harness writes — a row shape
   regression (renamed/dropped key) fails loudly instead of silently
   truncating the perf trajectory.
2. **Every kernel family is present and non-empty**, with the fields the
   trajectory diffs rely on.
3. **The causal-skip row exists and holds the tentpole claim**: counted
   K-steps of the block-skipping kernel at sq=sk must be >= 1.5x fewer
   than the dense grid (the deterministic form of the ~2x causal-prefill
   speedup; wall-clock is recorded alongside but interpret-mode grid
   overhead makes it advisory off-TPU).
4. **The ragged-decode row exists and holds the continuous-batching
   claim**: per-slot lengths must stream >= 1.3x fewer K/V blocks through
   the fused decode kernel than the shared-scalar (batch-max) broadcast
   at the staggered steady-state length mix (deterministic block
   counting; wall-clock advisory off-TPU, as above).
5. **The int8-decode row exists and holds the quantization claim** —
   both sides recomputed here, never trusted from the report:
   the per-token KV stream ratio is re-derived from the row's shape
   (bf16 ``4*dh`` bytes vs int8+scale ``2*(dh+4)``) and must be
   >= 1.6x AND agree with the reported bytes fields; the measured
   ``max_abs_err`` must land under the declared ``err_budget``, and the
   budget itself is capped at ``MAX_INT8_ERR_BUDGET`` so a report cannot
   fabricate accuracy by declaring a loose budget.

Usage: python tools/check_bench.py [BENCH_kernels.json]
Exit code 0 = clean; 1 = problems (listed one per line).
"""

from __future__ import annotations

import json
import pathlib
import sys

SCHEMA = 3

REQUIRED_LIST_KEYS = {
    "matmul_tuned_vs_fixed": ("shape", "tuned_tile", "speedup_model"),
    "spmv_tuned": ("matrix", "block_rows", "waste"),
    "attention_tuned_vs_fixed": ("shape", "tuned_block", "speedup_model"),
}
REQUIRED_DICT_KEYS = {
    "matmul_measured": ("tuned_us", "mxu_us", "speedup_vs_mxu"),
    "attention_measured": ("tuned_us", "fixed_us", "speedup_vs_fixed"),
    "attention_causal_skip": ("k_steps_dense", "k_steps_skip",
                              "kstep_speedup", "wall_speedup", "block"),
    "attention_decode": ("tuned_block_k", "tuned_us", "fixed_us",
                         "speedup_vs_fixed", "model_time_us"),
    "decode_ragged": ("lengths", "block_k", "fetched_speedup",
                      "wall_speedup", "ragged_us", "broadcast_us"),
    "decode_int8": ("shape", "tuned_block_k", "tuned_us", "bf16_us",
                    "bytes_per_token_int8", "bytes_per_token_bf16",
                    "bytes_ratio", "max_abs_err", "err_budget"),
}
MIN_CAUSAL_KSTEP_SPEEDUP = 1.5
MIN_RAGGED_FETCH_SPEEDUP = 1.3
MIN_INT8_BYTES_RATIO = 1.6
# Ceiling on the *declared* accuracy budget: err_budget is part of the
# report, so without a cap a fabricated report could pass the accuracy
# gate by declaring err_budget=1e9.
MAX_INT8_ERR_BUDGET = 0.05


def check(path: pathlib.Path) -> list[str]:
    problems: list[str] = []
    try:
        report = json.loads(path.read_text())
    except (OSError, ValueError) as e:
        return [f"{path}: unreadable report ({e!r})"]

    if report.get("schema") != SCHEMA:
        problems.append(f"schema regressed: {report.get('schema')!r} "
                        f"!= {SCHEMA}")

    for key, fields in REQUIRED_LIST_KEYS.items():
        rows = report.get(key)
        if not isinstance(rows, list) or not rows:
            problems.append(f"{key}: missing or empty")
            continue
        for f in fields:
            if any(f not in r for r in rows):
                problems.append(f"{key}: rows missing field {f!r}")

    for key, fields in REQUIRED_DICT_KEYS.items():
        row = report.get(key)
        if not isinstance(row, dict):
            problems.append(f"{key}: missing row")
            continue
        for f in fields:
            if f not in row:
                problems.append(f"{key}: missing field {f!r}")

    skip = report.get("attention_causal_skip")
    if isinstance(skip, dict) and "kstep_speedup" in skip:
        if skip["kstep_speedup"] < MIN_CAUSAL_KSTEP_SPEEDUP:
            problems.append(
                f"attention_causal_skip: kstep_speedup "
                f"{skip['kstep_speedup']:.3f} < {MIN_CAUSAL_KSTEP_SPEEDUP} "
                f"— block skipping regressed")

    ragged = report.get("decode_ragged")
    if isinstance(ragged, dict) and "fetched_speedup" in ragged:
        if ragged["fetched_speedup"] < MIN_RAGGED_FETCH_SPEEDUP:
            problems.append(
                f"decode_ragged: fetched_speedup "
                f"{ragged['fetched_speedup']:.3f} < "
                f"{MIN_RAGGED_FETCH_SPEEDUP} — per-slot length skipping "
                f"regressed (ragged batch must beat the shared-scalar "
                f"broadcast)")

    q8 = report.get("decode_int8")
    if isinstance(q8, dict) and all(
            f in q8 for f in REQUIRED_DICT_KEYS["decode_int8"]):
        problems += _check_decode_int8(q8)
    return problems


def _check_decode_int8(q8: dict) -> list[str]:
    """The quantized-stream gate.  The bandwidth claim is RECOMPUTED from
    the row's shape — 2*dh bf16 bytes vs 2*(dh+4) int8+scale bytes per
    token per kv head — and cross-checked against the reported fields, so
    a report cannot assert a ratio its own geometry does not deliver."""
    problems: list[str] = []
    try:
        dh = int(q8["shape"][3])
    except (TypeError, ValueError, IndexError):
        return [f"decode_int8: malformed shape {q8.get('shape')!r}"]
    bpt_int8 = 2 * (dh + 4)         # K+V int8 rows + one f32 scale each
    bpt_bf16 = 2 * dh * 2           # K+V bf16 rows
    ratio = bpt_bf16 / bpt_int8
    for field, want in (("bytes_per_token_int8", bpt_int8),
                        ("bytes_per_token_bf16", bpt_bf16)):
        if q8[field] != want:
            problems.append(
                f"decode_int8: {field} {q8[field]!r} != {want} recomputed "
                f"from shape (dh={dh}) — fabricated bandwidth claim")
    if abs(q8["bytes_ratio"] - ratio) > 1e-6:
        problems.append(
            f"decode_int8: bytes_ratio {q8['bytes_ratio']!r} != "
            f"{ratio:.6f} recomputed from shape (dh={dh})")
    if ratio < MIN_INT8_BYTES_RATIO:
        problems.append(
            f"decode_int8: recomputed bytes ratio {ratio:.3f} < "
            f"{MIN_INT8_BYTES_RATIO} — the quantized stream no longer "
            f"saves enough bandwidth at dh={dh}")
    budget = q8["err_budget"]
    if not isinstance(budget, (int, float)) or budget <= 0 \
            or budget > MAX_INT8_ERR_BUDGET:
        problems.append(
            f"decode_int8: declared err_budget {budget!r} outside "
            f"(0, {MAX_INT8_ERR_BUDGET}] — budget fabrication refused")
    elif not isinstance(q8["max_abs_err"], (int, float)) \
            or q8["max_abs_err"] > budget:
        problems.append(
            f"decode_int8: max_abs_err {q8['max_abs_err']!r} > declared "
            f"budget {budget} — quantization accuracy regressed")
    return problems


def main(argv: list[str]) -> int:
    path = pathlib.Path(argv[1] if len(argv) > 1 else "BENCH_kernels.json")
    problems = check(path)
    for p in problems:
        print(p)
    if not problems:
        print(f"ok: {path} (schema {SCHEMA}, causal kstep_speedup "
              f">= {MIN_CAUSAL_KSTEP_SPEEDUP}, ragged fetched_speedup "
              f">= {MIN_RAGGED_FETCH_SPEEDUP}, int8 bytes ratio "
              f">= {MIN_INT8_BYTES_RATIO} within err budget "
              f"<= {MAX_INT8_ERR_BUDGET})")
    return 1 if problems else 0


if __name__ == "__main__":
    raise SystemExit(main(sys.argv))
