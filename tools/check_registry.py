#!/usr/bin/env python
"""Kernel-registry coverage checker, run by the CI docs job (and
tests/test_docs.py).

Every kernel family shipped through the KernelSpec registry
(`src/repro/kernels/registry.py` -> `BUILTIN_SPEC_MODULES` ->
`kernels/<family>/spec.py`) must stay observable:

1. **A benchmark row.**  The spec's declared ``bench_key`` must be present
   and non-empty in BENCH_kernels.json — a family the perf trajectory
   cannot see is a family whose regressions land silently.
2. **An equivalence test.**  Some file under tests/ must exercise the
   family against its oracle: either through the engine
   (``dispatch("<name>"`` / ``tune("<name>"``) or through the legacy shim
   (``tuned_<name>(``).

The spec files are parsed *statically* (ast), so this check needs no jax
install — it runs in the same bare-python CI job as check_docs.py.

Usage: python tools/check_registry.py [BENCH_kernels.json]
Exit code 0 = clean; 1 = problems (listed one per line).
"""

from __future__ import annotations

import ast
import json
import pathlib
import sys

REPO = pathlib.Path(__file__).resolve().parent.parent
REGISTRY_PY = REPO / "src" / "repro" / "kernels" / "registry.py"


def _registry_assign(name: str):
    tree = ast.parse(REGISTRY_PY.read_text())
    for node in ast.walk(tree):
        if (isinstance(node, ast.Assign)
                and any(isinstance(t, ast.Name) and t.id == name
                        for t in node.targets)):
            return ast.literal_eval(node.value)
    raise SystemExit(f"{name} not found in {REGISTRY_PY}")


def builtin_spec_files() -> list[pathlib.Path]:
    """Resolve BUILTIN_SPEC_MODULES from registry.py without importing it."""
    return [REPO / "src" / (m.replace(".", "/") + ".py")
            for m in _registry_assign("BUILTIN_SPEC_MODULES")]


def declared_builtin_families() -> set[str]:
    """The BUILTIN_FAMILIES names registry.unregister() protects."""
    return set(_registry_assign("BUILTIN_FAMILIES"))


def registered_families(spec_file: pathlib.Path) -> list[dict]:
    """Statically extract KernelSpec(name=..., bench_key=...) registrations."""
    out = []
    for node in ast.walk(ast.parse(spec_file.read_text())):
        if not isinstance(node, ast.Call):
            continue
        fn = node.func
        fn_name = fn.attr if isinstance(fn, ast.Attribute) else \
            getattr(fn, "id", None)
        if fn_name != "KernelSpec":
            continue
        fields = {}
        for kw in node.keywords:
            if kw.arg in ("name", "bench_key") \
                    and isinstance(kw.value, ast.Constant):
                fields[kw.arg] = kw.value.value
        if "name" in fields:
            out.append({"name": fields["name"],
                        "bench_key": fields.get("bench_key", ""),
                        "file": spec_file.relative_to(REPO).as_posix()})
    return out


def check(bench_path: pathlib.Path) -> list[str]:
    problems: list[str] = []
    families: list[dict] = []
    for spec_file in builtin_spec_files():
        if not spec_file.exists():
            problems.append(f"registry: spec module missing -> "
                            f"{spec_file.relative_to(REPO).as_posix()}")
            continue
        found = registered_families(spec_file)
        if not found:
            problems.append(
                f"{spec_file.relative_to(REPO).as_posix()}: no KernelSpec "
                f"registration found")
        families.extend(found)

    names = [f["name"] for f in families]
    for dup in {n for n in names if names.count(n) > 1}:
        problems.append(f"registry: family {dup!r} registered twice")
    declared = declared_builtin_families()
    if declared != set(names):
        problems.append(
            f"registry: BUILTIN_FAMILIES {sorted(declared)} does not match "
            f"the names the spec modules register {sorted(set(names))}")

    try:
        report = json.loads(bench_path.read_text())
    except (OSError, ValueError) as e:
        report = None
        problems.append(f"{bench_path}: unreadable benchmark report ({e!r})")

    tests_text = "\n".join(p.read_text()
                           for p in sorted((REPO / "tests").glob("*.py")))

    for fam in families:
        name, bench_key = fam["name"], fam["bench_key"]
        if not bench_key:
            problems.append(
                f"{fam['file']}: family {name!r} declares no bench_key — "
                f"every shipped family needs a BENCH_kernels.json row")
        elif report is not None:
            rows = report.get(bench_key)
            if rows is None or (isinstance(rows, (list, dict)) and not rows):
                problems.append(
                    f"{bench_path.name}: family {name!r} has no "
                    f"{bench_key!r} row — benchmarks/run.py must cover "
                    f"every registered family")
        test_patterns = (f'dispatch("{name}"', f"dispatch('{name}'",
                         f'tune("{name}"', f"tune('{name}'",
                         f"tuned_{name}(")
        if not any(p in tests_text for p in test_patterns):
            problems.append(
                f"tests/: family {name!r} has no equivalence test "
                f"(expected one of {', '.join(test_patterns)})")
    if not families:
        problems.append("registry: no built-in families found at all")
    return problems


def main(argv: list[str]) -> int:
    bench = pathlib.Path(argv[1] if len(argv) > 1
                         else REPO / "BENCH_kernels.json")
    problems = check(bench)
    for p in problems:
        print(p)
    if problems:
        print(f"{len(problems)} registry problem(s)", file=sys.stderr)
        return 1
    n = sum(len(registered_families(f)) for f in builtin_spec_files())
    print(f"registry OK: {n} families, each with a benchmark row and an "
          f"equivalence test")
    return 0


if __name__ == "__main__":
    raise SystemExit(main(sys.argv))
