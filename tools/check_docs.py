#!/usr/bin/env python
"""Docs hygiene checker, run by the CI docs job (and tests/test_docs.py).

Two invariants:

1. **No broken relative links.**  Every markdown link/image target in
   `docs/*.md` and the repo-root markdown files that points at a local path
   must resolve (anchors and external URLs are skipped).
2. **The architecture map is complete.**  Every module under `src/repro/**`
   (every ``.py`` except ``__init__.py``) must be mentioned by its
   package-relative path (e.g. ``core/dse.py``) in
   ``docs/ARCHITECTURE.md`` — so the map cannot silently rot as the tree
   grows.

Exit code 0 = clean; 1 = problems (listed one per line).
"""

from __future__ import annotations

import pathlib
import re
import sys

REPO = pathlib.Path(__file__).resolve().parent.parent

# [text](target) and ![alt](target); target up to the first closing paren
# (markdown titles like `(path "title")` are split off below).
_LINK_RE = re.compile(r"!?\[[^\]]*\]\(([^)\s]+)(?:\s+\"[^\"]*\")?\)")
_EXTERNAL = ("http://", "https://", "mailto:", "ftp://")


def doc_files() -> list[pathlib.Path]:
    return sorted(REPO.glob("*.md")) + sorted((REPO / "docs").glob("*.md"))


def check_links() -> list[str]:
    problems = []
    for md in doc_files():
        text = md.read_text()
        # Fenced code blocks hold example syntax, not navigable links.
        text = re.sub(r"```.*?```", "", text, flags=re.S)
        for target in _LINK_RE.findall(text):
            if target.startswith(_EXTERNAL) or target.startswith("#"):
                continue
            path = target.split("#", 1)[0]
            if not path:
                continue
            resolved = (md.parent / path).resolve()
            if not resolved.exists():
                problems.append(
                    f"{md.relative_to(REPO)}: broken relative link "
                    f"-> {target}")
    return problems


def check_architecture_coverage() -> list[str]:
    arch = REPO / "docs" / "ARCHITECTURE.md"
    if not arch.exists():
        return ["docs/ARCHITECTURE.md is missing"]
    text = arch.read_text()
    problems = []
    for py in sorted((REPO / "src" / "repro").rglob("*.py")):
        if py.name == "__init__.py":
            continue
        rel = py.relative_to(REPO / "src" / "repro").as_posix()
        if rel not in text:
            problems.append(
                f"docs/ARCHITECTURE.md: module not in the map -> {rel}")
    return problems


def main() -> int:
    problems = check_links() + check_architecture_coverage()
    for p in problems:
        print(p)
    if problems:
        print(f"{len(problems)} docs problem(s)", file=sys.stderr)
        return 1
    print(f"docs OK: {len(doc_files())} files linked, architecture map "
          f"covers src/repro")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
