#!/usr/bin/env python
"""Serving-load report gate, run by the CI load-smoke job.

Validates a BENCH_serving.json produced by `benchmarks/serving_load.py`
(typically `--smoke`) the way `check_bench.py` gates the kernel report:

1. **Schema version** matches what the harness writes — a renamed or
   dropped metric fails loudly instead of silently truncating the
   serving-perf trajectory.
2. **At least two mixes**, each with the full metric block: TTFT and
   per-token p50/p99, sustained tokens/sec, queue-depth timeline, and
   the predicted-vs-measured step-time row.
3. **Conservation**: every mix drained with
   ``submitted == completed + timed_out + failed + rejected`` and a
   consistent per-request row count.
4. **SLOs hold**: every mix's ``slo_ok`` is true and its measured
   latencies/throughput actually satisfy the recorded budgets (recomputed
   here, so a report that *claims* slo_ok with violating numbers fails
   too).
5. **Crash recovery**: the ``recovery`` block shows the injected-crash
   cycle really crashed (exit 17) and resumed (exit 0), conserved every
   request exactly once across both process lifetimes, and replayed no
   more journal than one snapshot interval.
6. **Paging pays for itself**: a heavy-tail (lognormal-length) mix ran
   on the paged KV cache with a clean pool (no allocator OOMs, no failed
   requests), and the ``paging`` comparison block shows the paged
   allocator sustaining >= ``ratio_floor`` (>= 1.5) times the contiguous
   path's concurrent active slots at the **same KV-memory budget** — the
   ratio is recomputed here from the two sub-runs' numbers, so a report
   that merely *claims* ``ratio_ok`` fails too.

Usage: python tools/check_load.py [BENCH_serving.json]
Exit code 0 = clean; 1 = problems (listed one per line).
"""

from __future__ import annotations

import json
import pathlib
import sys

SCHEMA = 3
MIN_MIXES = 2
MIN_PAGING_RATIO = 1.5

# Per-mix blocks the serving trajectory diffs rely on.
REQUIRED_MIX_FIELDS = (
    "name", "kind", "seed", "batch", "step_time_us",
    "trace", "submitted", "outcomes", "conserved", "tokens_total",
    "ttft_ms", "per_token_ms", "tok_per_s", "queue_depth",
    "queue_depth_max", "predicted_vs_measured", "requests",
    "slo", "slo_ok", "slo_violations",
    "max_concurrent", "paged", "sched",
)
PERCENTILE_FIELDS = ("p50", "p99", "n")

# KV-memory utilization block every paged mix must report (schema 3):
# pages allocated vs tokens resident at the pool's peak.
REQUIRED_KV_FIELDS = (
    "page_size", "num_pages", "pages_allocated", "pages_free",
    "tokens_resident", "token_capacity", "utilization",
    "pages_peak", "kv_ooms",
)


def _check_mix(name: str, mix: dict) -> list[str]:
    problems: list[str] = []
    for f in REQUIRED_MIX_FIELDS:
        if f not in mix:
            problems.append(f"mix {name}: missing field {f!r}")
    if problems:
        return problems

    for key in ("ttft_ms", "per_token_ms"):
        block = mix[key]
        if not isinstance(block, dict) or \
                any(f not in block for f in PERCENTILE_FIELDS):
            problems.append(f"mix {name}: {key} is not a p50/p99/n block")

    if not mix["conserved"]:
        problems.append(f"mix {name}: request conservation violated "
                        f"({mix['outcomes']} vs submitted="
                        f"{mix['submitted']})")
    out = mix["outcomes"]
    terminal = sum(out.get(k, 0) for k in
                   ("completed", "timed_out", "failed", "rejected"))
    if terminal != mix["submitted"]:
        problems.append(f"mix {name}: terminal outcomes {terminal} != "
                        f"submitted {mix['submitted']}")
    rows = mix.get("requests")
    if not isinstance(rows, list) or len(rows) != mix["submitted"]:
        problems.append(f"mix {name}: per-request rows missing or "
                        f"count != submitted")

    # SLOs: trust nothing — recompute each budget comparison from the
    # recorded numbers, and require the mix's own verdict to agree.
    slo = mix["slo"]
    ttft_p99 = (mix["ttft_ms"] or {}).get("p99")
    ptok_p99 = (mix["per_token_ms"] or {}).get("p99")
    tok_per_s = mix["tok_per_s"]
    violations = []
    if ttft_p99 is None or ttft_p99 > slo["ttft_p99_ms"]:
        violations.append(f"ttft p99 {ttft_p99} > {slo['ttft_p99_ms']} ms")
    if ptok_p99 is None or ptok_p99 > slo["per_token_p99_ms"]:
        violations.append(f"per-token p99 {ptok_p99} > "
                          f"{slo['per_token_p99_ms']} ms")
    if tok_per_s is None or tok_per_s < slo["min_tok_per_s"]:
        violations.append(f"tok/s {tok_per_s} < {slo['min_tok_per_s']}")
    for v in violations:
        problems.append(f"mix {name}: SLO violated: {v}")
    if not mix["slo_ok"] and not violations:
        # report says violated but numbers look fine — still a failure:
        # the harness saw something this checker must not paper over
        problems.append(f"mix {name}: slo_ok false "
                        f"({mix['slo_violations']})")
    if mix["slo_ok"] and violations:
        problems.append(f"mix {name}: slo_ok true but budgets violated "
                        f"— report inconsistent")

    # Paged mixes must carry the KV-memory utilization block and must
    # have drained without tripping allocator OOMs or failing requests —
    # backpressure is allowed (evictions / rejections), silent loss and
    # FAILED-from-OOM are not.
    if mix["paged"]:
        kv = mix.get("kv")
        if not isinstance(kv, dict):
            problems.append(f"mix {name}: paged but kv block missing")
        else:
            for f in REQUIRED_KV_FIELDS:
                if f not in kv:
                    problems.append(f"mix {name}: kv missing field {f!r}")
            if kv.get("kv_ooms", 0):
                problems.append(f"mix {name}: {kv['kv_ooms']} allocator "
                                f"OOMs — admission is over-promising the "
                                f"pool")
            alloc, total = kv.get("pages_allocated"), kv.get("num_pages")
            if isinstance(alloc, int) and isinstance(total, int) \
                    and alloc > total:
                problems.append(f"mix {name}: kv pages_allocated {alloc} "
                                f"> pool {total}")
        if out.get("failed", 0):
            problems.append(f"mix {name}: paged mix has "
                            f"{out['failed']} FAILED requests — OOM "
                            f"backpressure must evict/reject, not fail")
    return problems


def _check_recovery(rec) -> list[str]:
    problems: list[str] = []
    if not isinstance(rec, dict):
        return ["recovery: block missing — the crash-recovery cycle "
                "never ran"]
    for f in ("crash_step", "snapshot_every", "replayed_steps",
              "submitted", "outcomes", "conserved",
              "crash_exit_ok", "resume_exit_ok"):
        if f not in rec:
            problems.append(f"recovery: missing field {f!r}")
    if problems:
        return problems
    if not rec["crash_exit_ok"]:
        problems.append("recovery: crash run did not die with the crash "
                        "exit code — the fault never killed the process")
    if not rec["resume_exit_ok"]:
        problems.append("recovery: `serve --resume` exited non-zero")
    if not rec["conserved"]:
        problems.append(f"recovery: conservation violated across the "
                        f"crash ({rec['outcomes']} vs submitted="
                        f"{rec['submitted']})")
    out = rec["outcomes"]
    terminal = sum(out.get(k, 0) for k in
                   ("completed", "timed_out", "failed", "rejected"))
    if terminal != rec["submitted"]:
        problems.append(f"recovery: terminal outcomes {terminal} != "
                        f"submitted {rec['submitted']} — a request was "
                        f"lost or completed twice across the crash")
    replayed, every = rec["replayed_steps"], rec["snapshot_every"]
    if not isinstance(replayed, int) or replayed < 1:
        problems.append(f"recovery: replayed_steps must be a positive "
                        f"int, got {replayed!r}")
    elif replayed > every:
        problems.append(f"recovery: replayed {replayed} steps > snapshot "
                        f"interval {every} — snapshots are not bounding "
                        f"the journal replay")
    return problems


def _check_paging(blk) -> list[str]:
    problems: list[str] = []
    if not isinstance(blk, dict):
        return ["paging: block missing — the paged-vs-contiguous "
                "comparison never ran"]
    for f in ("page_size", "budget_tokens", "pool_pages", "contiguous",
              "paged", "concurrency_ratio", "ratio_floor", "ratio_ok"):
        if f not in blk:
            problems.append(f"paging: missing field {f!r}")
    if problems:
        return problems
    cont, paged = blk["contiguous"], blk["paged"]
    for side, sub in (("contiguous", cont), ("paged", paged)):
        if not isinstance(sub, dict) or "max_concurrent" not in sub:
            problems.append(f"paging: {side} sub-run missing "
                            f"max_concurrent")
    if problems:
        return problems
    if blk["ratio_floor"] < MIN_PAGING_RATIO:
        problems.append(f"paging: ratio_floor {blk['ratio_floor']} < "
                        f"required {MIN_PAGING_RATIO}")
    # Recompute the headline ratio — trust numbers, not verdicts.
    ratio = paged["max_concurrent"] / max(1, cont["max_concurrent"])
    if abs(ratio - blk["concurrency_ratio"]) > 0.01:
        problems.append(f"paging: recorded ratio "
                        f"{blk['concurrency_ratio']} != recomputed "
                        f"{ratio:.3f}")
    if ratio < blk["ratio_floor"]:
        problems.append(f"paging: paged sustains only {ratio:.2f}x the "
                        f"contiguous concurrency at the same KV budget "
                        f"(floor {blk['ratio_floor']}x)")
    if not blk["ratio_ok"]:
        problems.append("paging: report's own ratio_ok is false")
    elif ratio < blk["ratio_floor"]:
        problems.append("paging: ratio_ok true but the numbers violate "
                        "the floor — report inconsistent")
    kv = paged.get("kv")
    if not isinstance(kv, dict):
        problems.append("paging: paged sub-run missing kv block")
    elif kv.get("kv_ooms", 0):
        problems.append(f"paging: paged sub-run hit {kv['kv_ooms']} "
                        f"allocator OOMs")
    for side, sub in (("contiguous", cont), ("paged", paged)):
        out = sub.get("outcomes", {})
        if out.get("failed", 0):
            problems.append(f"paging: {side} sub-run has "
                            f"{out['failed']} FAILED requests")
    return problems


def check(path: pathlib.Path) -> list[str]:
    problems: list[str] = []
    try:
        report = json.loads(path.read_text())
    except (OSError, ValueError) as e:
        return [f"{path}: unreadable report ({e!r})"]

    if report.get("schema") != SCHEMA:
        problems.append(f"schema regressed: {report.get('schema')!r} "
                        f"!= {SCHEMA}")

    mixes = report.get("mixes")
    if not isinstance(mixes, dict) or len(mixes) < MIN_MIXES:
        problems.append(f"mixes: need >= {MIN_MIXES} trace mixes, got "
                        f"{0 if not isinstance(mixes, dict) else len(mixes)}")
        return problems

    kinds = set()
    for name in sorted(mixes):
        mix = mixes[name]
        if not isinstance(mix, dict):
            problems.append(f"mix {name}: not a report row")
            continue
        kinds.add(mix.get("kind"))
        problems.extend(_check_mix(name, mix))
    if "open" not in kinds:
        problems.append("mixes: no open-loop (Poisson trace) mix present")
    if not any(isinstance(m, dict) and m.get("paged")
               for m in mixes.values()):
        problems.append("mixes: no paged (heavy-tail) mix present")

    problems.extend(_check_recovery(report.get("recovery")))
    problems.extend(_check_paging(report.get("paging")))

    if not report.get("slo_ok") and not any("SLO" in p for p in problems):
        problems.append("report slo_ok false")
    return problems


def main(argv: list[str]) -> int:
    path = pathlib.Path(argv[1] if len(argv) > 1 else "BENCH_serving.json")
    problems = check(path)
    for p in problems:
        print(p)
    if not problems:
        print(f"ok: {path} (schema {SCHEMA}, >= {MIN_MIXES} mixes, "
              f"conservation + SLO budgets hold, crash recovery bounded, "
              f"paging >= {MIN_PAGING_RATIO}x concurrency at equal KV "
              f"budget)")
    return 1 if problems else 0


if __name__ == "__main__":
    raise SystemExit(main(sys.argv))
