"""Batched MoE serving example: continuous batching on a smoke-scale
Phi-3.5-MoE through the serving stack (prefill + KV-cached decode + expert
routing on every token).

Run:  PYTHONPATH=src python examples/serve_moe.py
"""

from repro.launch import serve


def main():
    serve.main([
        "--arch", "phi3_5_moe_42b", "--smoke",
        "--requests", "6", "--batch", "2",
        "--prompt-len", "12", "--gen", "8",
    ])


if __name__ == "__main__":
    main()
