"""Quickstart: the paper's design flow, end to end, in five steps.

1. Describe the machine at SYSTEM level (ManyCoreConfig — the paper's
   parameter set: cores/interconnect/local-mem/ops/formats).
2. Let the flow derive the communication-minimizing tile plan (eq. 2).
3. Score candidate configurations with the analytical machine model
   (the SystemC-simulation analogue) via automated DSE.
4. Execute the generated kernels (Pallas; interpret mode on CPU) and check
   them against the oracles.
5. Print the plan you would deploy.

Run:  PYTHONPATH=src python examples/quickstart.py
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import cost_model, dse, manycore
from repro.kernels import autotune
from repro.kernels.matmul.ref import matmul_ref
from repro.kernels.spmv import pack_csr


def main():
    # 1. system-level machine description
    mc = manycore.ManyCoreConfig()
    print("=== machine (system-level parameters) ===")
    print(mc.describe())

    # 2. eq.2 tile plan for a dense matmul workload
    m = n = k = 8192
    tile = mc.matmul_tile(m, n, k)
    print(f"\n=== eq.2 tile plan for {m}x{n}x{k} ===\n{tile}")

    # 3. automated DSE over tiles (the paper's manual loop, automated)
    tuned = dse.autotune_matmul_tile(m, n, k)
    res = cost_model.matmul_time_model(m, n, k, tuned)
    print(f"DSE pick: {tuned}  model-efficiency={res['efficiency']:.1%} "
          f"({res['gflops']:.0f} GFLOP/s model)")

    # 4a. run the autotuned matmul kernel (small instance, interpret mode).
    # dispatch("matmul", ...) closes the loop through the KernelSpec
    # registry: rank tiles with the family's declared cost model, time the
    # top-K on the backend, memoize the winner on disk.
    key = jax.random.PRNGKey(0)
    a = jax.random.normal(key, (256, 192), jnp.float32)
    b = jax.random.normal(key, (192, 128), jnp.float32)
    out = autotune.dispatch("matmul", a, b, interpret=True)
    (am, ak), bn = a.shape, b.shape[1]
    # cache hit from the dispatch above
    plan = autotune.tune("matmul", {"m": am, "n": bn, "k": ak})
    err = float(jnp.max(jnp.abs(out - matmul_ref(a, b))))
    print(f"\ntuned matmul vs oracle: max err {err:.2e} "
          f"(tile {plan.knobs['tile']}, source={plan.source})")

    # 4b. run the balanced SpMV (paper §V-B)
    rng = np.random.default_rng(0)
    dense = (rng.random((555, 300)) < 0.03) * rng.standard_normal((555, 300))
    nnz_row = (dense != 0).sum(1)
    indptr = np.concatenate([[0], np.cumsum(nnz_row)]).astype(np.int32)
    cols = np.concatenate([np.nonzero(r)[0] for r in dense]).astype(np.int32)
    vals = dense[dense != 0].astype(np.float32)
    mat = pack_csr(indptr, cols, vals, dense.shape, scheme="sorted")
    x = rng.standard_normal(300).astype(np.float32)
    y = autotune.dispatch("spmv", mat, jnp.asarray(x), interpret=True)
    splan = autotune.tune("spmv", {"mat": mat})
    err = float(np.max(np.abs(np.asarray(y) - dense @ x)))
    print(f"tuned spmv vs dense: max err {err:.2e}  "
          f"(block_rows={splan.knobs['block_rows']}, "
          f"block_cols={splan.knobs['block_cols']}, "
          f"active/fetched waste {splan.detail['waste']:.2f}x)")

    # 5. the deployable plan
    print("\n=== deploy plan ===")
    print(f"mesh: {dict(zip(mc.mesh_axes, mc.mesh_shape))}")
    print(f"matmul tile: {tuned}; kernels: {', '.join(mc.kernels)}")
    print("dry-run the full production mesh with: "
          "python -m repro.launch.sweep --mesh both")


if __name__ == "__main__":
    main()
