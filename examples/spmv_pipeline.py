"""Sparse matrix-vector pipeline (paper §V-B) through the public API:
pack with each balancing law, compare balance + padding, execute the kernel,
and report the Table-II-style summary.

Run:  PYTHONPATH=src python examples/spmv_pipeline.py
"""

import jax.numpy as jnp
import numpy as np

from repro.core import loadbalance
from repro.kernels import autotune
from repro.kernels.spmv import pack_csr, spmv


def make_matrix(m=2030, n=512, lo=1, hi=96, seed=87):
    """LD_pilot87-like row-length distribution."""
    rng = np.random.default_rng(seed)
    per_row = rng.integers(lo, hi + 1, size=m)
    indptr = np.concatenate([[0], np.cumsum(per_row)]).astype(np.int32)
    indices = np.concatenate(
        [rng.choice(n, size=c, replace=False) for c in per_row]
    ).astype(np.int32)
    data = rng.standard_normal(indptr[-1]).astype(np.float32)
    return indptr, indices, data, (m, n)


def main():
    indptr, indices, data, shape = make_matrix()
    x = np.random.default_rng(1).standard_normal(shape[1]).astype(np.float32)
    nnz = int(indptr[-1])
    print(f"matrix: {shape[0]}x{shape[1]}, nnz={nnz}")

    # paper claim: round-robin balances nnz across p workers (~1/p each)
    for p in (2, 4, 8):
        _, st = loadbalance.nnz_balanced_row_order(indptr, p)
        print(f"  round-robin p={p}: max worker share "
              f"{st.max_fraction:.3f} (ideal {1 / p:.3f})")

    print("\npacking law comparison (SIMD padding waste, lower=better):")
    y_ref = None
    for scheme in ("none", "round_robin", "lpt", "sorted"):
        mat = pack_csr(indptr, indices, data, shape, scheme=scheme)
        y = spmv(mat, jnp.asarray(x), use_kernel=False)
        if y_ref is None:
            y_ref = y
        err = float(jnp.max(jnp.abs(y - y_ref)))
        print(f"  {scheme:12s} sliced waste {mat.sliced_waste():.2f}x "
              f"(global {mat.padding_waste:.2f}x)  err vs first: {err:.1e}")

    # Close the DSE loop: let the tuner pick the execution config for the
    # sorted packing (the balance metric above is its ranking input), and
    # demonstrate the blocked-x kernel that lifts the whole-vector VMEM cap.
    mat = pack_csr(indptr, indices, data, shape, scheme="sorted")
    plan = autotune.tune("spmv", {"mat": mat})
    print(f"\nautotuned execution config: "
          f"block_rows={plan.knobs['block_rows']}, "
          f"block_cols={plan.knobs['block_cols']} (None = whole-x resident), "
          f"source={plan.source}")
    y_blk = spmv(mat, jnp.asarray(x), block_rows=plan.knobs['block_rows'],
                 block_cols=256, interpret=True)
    err = float(jnp.max(jnp.abs(y_blk - spmv(mat, jnp.asarray(x),
                                             use_kernel=False))))
    print(f"blocked-x kernel (256-col slabs) vs oracle: max err {err:.1e} "
          f"— n no longer bounded by VMEM")

    print("\nresult: the paper's balancing law survives the port, but on a "
          "SIMD target the optimal permutation is SORTED (equal widths), "
          "not round-robin — see DESIGN.md §Hardware adaptation.")


if __name__ == "__main__":
    main()
