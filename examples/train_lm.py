"""End-to-end training driver: a ~100M-class LM on the synthetic pipeline
for a few hundred steps, with checkpointing + fault-tolerant step loop.

The default config is CPU-sized (single core container); ``--hundred-m``
selects the full ~124M-parameter model (same code path, longer wall time).

Run:  PYTHONPATH=src python examples/train_lm.py --steps 200
"""

import argparse
import json
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.data import DataConfig, SyntheticSource
from repro.launch import steps as step_lib
from repro.models import transformer
from repro.models.config import ModelConfig
from repro.optim import adamw
from repro.runtime.fault_tolerance import ResilienceConfig, run_resilient


def model_config(hundred_m: bool) -> ModelConfig:
    if hundred_m:
        return ModelConfig(
            name="lm-124m", family="dense", num_layers=12, d_model=768,
            d_ff=2048, vocab_size=32768, num_heads=12, num_kv_heads=4)
    return ModelConfig(
        name="lm-27m", family="dense", num_layers=8, d_model=512,
        d_ff=1408, vocab_size=8192, num_heads=8, num_kv_heads=4)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--hundred-m", action="store_true")
    ap.add_argument("--ckpt-dir", default="checkpoints/train_lm")
    args = ap.parse_args(argv)

    cfg = model_config(args.hundred_m)
    n_params = cfg.param_count()
    opt_cfg = adamw.AdamWConfig(peak_lr=args.lr, warmup_steps=20,
                                total_steps=args.steps)
    params = transformer.init(cfg, jax.random.PRNGKey(0))
    state = {"params": params, "opt": adamw.init_state(params, opt_cfg)}
    dcfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=args.seq,
                      global_batch=args.batch, seed=0)
    source = SyntheticSource(dcfg)
    train_step = jax.jit(step_lib.make_train_step(cfg, opt_cfg),
                         donate_argnums=(0,))
    ckpt = CheckpointManager(Path(args.ckpt_dir) / cfg.name, keep=2)

    def batch_fn(step):
        return {k: jnp.asarray(v)
                for k, v in source.batch(step, 0, 1).items()}

    print(f"training {cfg.name}: {n_params / 1e6:.1f}M params, "
          f"{args.steps} steps, batch {args.batch} x seq {args.seq}")
    t0 = time.time()
    state, history, monitor = run_resilient(
        train_step, state, args.steps, ckpt, batch_fn,
        config=ResilienceConfig(checkpoint_every=max(args.steps // 4, 10)))
    wall = time.time() - t0

    losses = [h["loss"] for h in history]
    window = max(args.steps // 10, 5)
    tok_per_step = args.batch * args.seq
    print(json.dumps({
        "params_m": round(n_params / 1e6, 1),
        "steps": len(history),
        "wall_s": round(wall, 1),
        "tokens_per_s": round(len(history) * tok_per_step / wall, 1),
        "loss_first": round(float(np.mean(losses[:window])), 4),
        "loss_last": round(float(np.mean(losses[-window:])), 4),
        "stragglers_flagged": len(monitor.reports),
        "final_checkpoint": ckpt.latest_step(),
    }, indent=2))


if __name__ == "__main__":
    main()
