"""KernelSpec registry: registration round-trip, duplicate rejection, the
v2->v3 cache migration, and a fifth toy family registered in-test to prove
the extension path end to end (the ~50-line "adding kernel family #5"
claim)."""

import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import dse
from repro.kernels import autotune, registry

KEY = jax.random.PRNGKey(0)


@pytest.fixture
def cache(tmp_path, monkeypatch):
    path = tmp_path / "autotune.json"
    monkeypatch.setenv(autotune.CACHE_ENV, str(path))
    return autotune.TuneCache(path)


# ---------------------------------------------------------------------------
# registration round-trip
# ---------------------------------------------------------------------------

def _toy_spec(name="toy_scale"):
    """A complete (if silly) family: y = x * alpha, knob = unroll chunk.

    The cost model charges one pass over x plus a per-chunk overhead, so
    the ranking deterministically prefers the largest chunk.
    """
    def cost_fn(problem, knobs, dtype_bytes=4):
        n = problem["n"]
        chunks = -(-n // knobs["chunk"])
        time_s = n * dtype_bytes / 1e9 + chunks * 1e-6
        return {"time_s": time_s, "vmem_bytes": knobs["chunk"] * dtype_bytes}

    def enumerate_candidates(problem, dtype_bytes, vmem_bytes, top):
        cands = []
        for chunk in (64, 128, 256):
            row = cost_fn(problem, {"chunk": chunk}, dtype_bytes)
            if vmem_bytes is not None and row["vmem_bytes"] > vmem_bytes:
                continue
            cands.append(dse.Candidate({"chunk": chunk}, row["time_s"], {}))
        return cands or [dse.Candidate({"chunk": 64}, 1.0, {})]

    def launcher(problem, knobs, interpret):
        return lambda x: x * problem["alpha"]

    return registry.KernelSpec(
        name=name,
        key_fn=lambda p, dtype, backend: f"n{p['n']}:{dtype}:{backend}",
        enumerate_candidates=enumerate_candidates,
        cost_fn=cost_fn,
        make_inputs=lambda p, dtype: (
            jax.random.normal(KEY, (p["n"],), dtype),),
        build_launcher=launcher,
        reference_fn=lambda x, alpha=2.0: x * alpha,
        problem_fn=lambda x, alpha=2.0: ({"n": x.shape[0], "alpha": alpha},
                                         x.dtype),
        run_fn=lambda plan, x, *, interpret=False, alpha=2.0: x * alpha,
        measure_elems=lambda p: p["n"],
        tie_break=lambda knobs: (-knobs["chunk"],),
        default_measure_k=2,
        bench_key="",
    )


@pytest.fixture
def toy_spec():
    spec = registry.register(_toy_spec())
    yield spec
    registry.unregister(spec.name)


def test_register_roundtrip(toy_spec):
    assert registry.get(toy_spec.name) is toy_spec
    assert toy_spec.name in registry.families()


def test_builtin_families_registered():
    assert {"matmul", "spmv", "attention", "decode"} \
        <= set(registry.families())
    # the static declaration unregister() guards on must agree with what
    # the spec modules actually register
    assert set(registry.BUILTIN_FAMILIES) <= set(registry.families())


def test_duplicate_name_rejected(toy_spec):
    with pytest.raises(ValueError, match="already registered"):
        registry.register(_toy_spec(toy_spec.name))
    # builtin names are protected the same way
    with pytest.raises(ValueError, match="already registered"):
        registry.register(_toy_spec("matmul"))


def test_builtin_families_cannot_be_unregistered():
    """Spec modules register at import time, so an unregistered builtin
    could never be reloaded in-process — the call is refused outright."""
    registry.families()                         # latch the builtins
    with pytest.raises(ValueError, match="cannot unregister built-in"):
        registry.unregister("matmul")
    assert "matmul" in registry.families()


def test_unknown_family_lists_registered():
    with pytest.raises(KeyError, match="unknown kernel family"):
        registry.get("no_such_family")


def test_register_rejects_non_spec():
    with pytest.raises(TypeError):
        registry.register({"name": "dict_not_spec"})


# ---------------------------------------------------------------------------
# fifth family end to end: tune -> cache -> dispatch
# ---------------------------------------------------------------------------

def test_toy_spec_tunes_through_generic_engine(cache, toy_spec):
    p1 = autotune.tune(toy_spec.name, {"n": 512, "alpha": 2.0},
                       cache=cache, measure_k=0)
    assert p1.family == toy_spec.name
    assert p1.knobs == {"chunk": 256}          # largest chunk wins the model
    assert p1.source == "model" and p1.provenance == "analytic"
    assert p1.key.startswith(f"{toy_spec.name}:n512:")
    # second call is a cache hit with identical knobs
    p2 = autotune.tune(toy_spec.name, {"n": 512, "alpha": 2.0},
                       cache=cache, measure_k=0)
    assert p2.source == "cache" and p2.knobs == p1.knobs
    # measuring caller upgrades the analytic entry (the shared engine rule)
    p3 = autotune.tune(toy_spec.name, {"n": 512, "alpha": 2.0},
                       cache=cache, measure_k=2)
    assert p3.source == "measured" and p3.measured_us is not None
    assert p3.provenance == "measured"


def test_toy_spec_respects_vmem_budget(cache, toy_spec):
    p = autotune.tune(toy_spec.name, {"n": 512, "alpha": 2.0},
                      cache=cache, measure_k=0, vmem_bytes=300)
    assert p.knobs == {"chunk": 64}            # only 64*4B fits the budget
    assert ":v300" in p.key                    # budget is part of the key


def test_toy_spec_dispatches(cache, toy_spec):
    x = jax.random.normal(KEY, (256,), jnp.float32)
    out = autotune.dispatch(toy_spec.name, x, alpha=3.0, interpret=True,
                            cache=cache)
    np.testing.assert_allclose(np.asarray(out), np.asarray(x) * 3.0)
    # the oracle path pays no tuning state
    hits, misses = cache.hits, cache.misses
    out_ref = autotune.dispatch(toy_spec.name, x, alpha=3.0,
                                use_kernel=False, cache=cache)
    np.testing.assert_allclose(np.asarray(out_ref), np.asarray(x) * 3.0)
    assert (cache.hits, cache.misses) == (hits, misses)


# ---------------------------------------------------------------------------
# cache schema v2 -> v3 migration
# ---------------------------------------------------------------------------

def _v2_file(path):
    backend = autotune._backend()
    entries = {
        # measured matmul entry: must survive with its wall-clock evidence
        f"matmul:128x128x128:float32:{backend}:vdflt": {
            "tile": [128, 128, 128], "source": "measured",
            "model_time_s": 3.2e-5, "measured_us": 41.5},
        # analytic spmv entry with its balance metric
        f"spmv:64x10:n300:nnz512:labc:float32:{backend}:vdflt": {
            "block_rows": 16, "block_cols": None, "source": "model",
            "model_time_s": 1.1e-6, "measured_us": None, "waste": 1.25},
        # measured attention entry
        f"attention:8x256x256x64:c1:wnone:float32:{backend}:vdflt": {
            "block_q": 256, "block_k": 128, "source": "measured",
            "model_time_s": 2.0e-6, "measured_us": 120.0},
        # decode entry
        f"decode:4x2x256x32:float32:{backend}:vdflt": {
            "block_k": 256, "source": "model", "model_time_s": 5.0e-7,
            "measured_us": None},
        # a family that no longer exists: dropped, not crashed on
        "ghost:1x1:float32:cpu:vdflt": {"widget": 7, "source": "measured",
                                        "model_time_s": 1.0,
                                        "measured_us": 1.0},
    }
    path.write_text(json.dumps({"version": 2, "entries": entries}))
    return entries


def test_v2_cache_migrates_to_v3(cache):
    _v2_file(cache.path)
    data = autotune.TuneCache(cache.path)._load()
    assert data["version"] == autotune.ENGINE_VERSION
    entries = data["entries"]
    backend = autotune._backend()
    # measured entries survive, re-shaped to the unified v3 format and
    # still keyed under the family-prefixed key
    mm = entries[f"matmul:128x128x128:float32:{backend}:vdflt"]
    assert mm == {"knobs": {"tile": [128, 128, 128]}, "source": "measured",
                  "model_time_s": 3.2e-5, "measured_us": 41.5, "detail": {}}
    sp = entries[f"spmv:64x10:n300:nnz512:labc:float32:{backend}:vdflt"]
    assert sp["knobs"] == {"block_rows": 16, "block_cols": None}
    assert sp["detail"] == {"waste": 1.25}
    dc = entries[f"decode:4x2x256x32:float32:{backend}:vdflt"]
    assert dc["knobs"] == {"block_k": 256}
    # unknown-family entries are dropped, not mis-applied
    assert not any(k.startswith("ghost:") for k in entries)


def test_v2_measured_entry_served_as_hit_after_migration(cache):
    """A measured v2 winner must come back as a cache hit through tune() —
    the whole point of migrating instead of dropping the file."""
    _v2_file(cache.path)
    p = autotune.tune("matmul", {"m": 128, "n": 128, "k": 128},
                      jnp.float32, cache=autotune.TuneCache(cache.path),
                      measure_k=2)
    assert p.source == "cache"
    assert p.knobs == {"tile": [128, 128, 128]}
    assert p.measured_us == 41.5 and p.provenance == "measured"
    ap = autotune.tune_attention(8, 256, 256, 64, jnp.float32, measure_k=0,
                                 cache=autotune.TuneCache(cache.path))
    assert ap.source == "cache" and (ap.block_q, ap.block_k) == (256, 128)


def test_v1_cache_still_dropped_wholesale(cache):
    """Migration applies to v2 only: v1 predates block skipping, so its
    winners mean something different and must never be served."""
    backend = autotune._backend()
    cache.path.write_text(json.dumps({
        "version": 1,
        "entries": {
            f"attention:8x256x256x64:c1:wnone:float32:{backend}:vdflt": {
                "block_q": 7, "block_k": 13, "source": "measured",
                "model_time_s": 1e-9, "measured_us": 0.1}},
    }))
    data = autotune.TuneCache(cache.path)._load()
    assert data["version"] == autotune.ENGINE_VERSION
    assert data["entries"] == {}


def test_malformed_v2_entries_dropped_not_crashed(cache):
    backend = autotune._backend()
    cache.path.write_text(json.dumps({
        "version": 2,
        "entries": {
            f"matmul:64x64x64:float32:{backend}:vdflt": {"source": "model"},
            "attention:missing_fields": ["not", "a", "dict"],
        },
    }))
    data = autotune.TuneCache(cache.path)._load()
    assert data["version"] == autotune.ENGINE_VERSION
    assert data["entries"] == {}


# ---------------------------------------------------------------------------
# the engine is family-agnostic
# ---------------------------------------------------------------------------

def test_v3_key_format_is_family_prefixed(cache):
    p = autotune.tune("matmul", {"m": 128, "n": 128, "k": 128},
                      cache=cache, measure_k=0)
    family, rest = p.key.split(":", 1)
    assert family == "matmul" and rest.endswith(":vdflt")
    entry = json.loads(cache.path.read_text())["entries"][p.key]
    assert set(entry) == {"knobs", "source", "model_time_s", "measured_us",
                          "detail"}


def test_all_builtin_families_share_one_engine(cache):
    """Every registered built-in family tunes through the same tune() call
    and lands in the same cache file with the same entry shape."""
    from repro.kernels.spmv import pack_csr
    rng = np.random.default_rng(0)
    dense = (rng.random((64, 200)) < 0.1) * rng.standard_normal((64, 200))
    nnz_per_row = (dense != 0).sum(1)
    indptr = np.concatenate([[0], np.cumsum(nnz_per_row)]).astype(np.int32)
    cols = np.concatenate(
        [np.nonzero(r)[0] for r in dense]).astype(np.int32)
    vals = dense[dense != 0].astype(np.float32)
    mat = pack_csr(indptr, cols, vals, (64, 200), scheme="sorted")
    problems = {
        "matmul": {"m": 128, "n": 128, "k": 128},
        "spmv": {"mat": mat},
        "attention": {"bh": 4, "sq": 128, "sk": 128, "dh": 32,
                      "causal": True, "window": None},
        "decode": {"bkv": 4, "g": 2, "cache_len": 128, "dh": 32},
    }
    for family, problem in problems.items():
        plan = autotune.tune(family, problem, cache=cache, measure_k=0)
        assert plan.family == family and plan.key.startswith(f"{family}:")
    entries = json.loads(cache.path.read_text())["entries"]
    assert len(entries) == len(problems)
    for entry in entries.values():
        assert set(entry) == {"knobs", "source", "model_time_s",
                              "measured_us", "detail"}
