"""AdamW: convergence, schedule properties, int8 blockwise moments."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # minimal env: property tests skip, rest run
    from _hypothesis_stub import given, settings, st

from repro.optim import adamw


def _rosenbrockish(params):
    x, y = params["x"], params["y"]
    return jnp.sum((1 - x) ** 2) + 5 * jnp.sum((y - x**2) ** 2)


@pytest.mark.parametrize("moment_dtype", ["float32", "int8"])
def test_adamw_converges(moment_dtype):
    cfg = adamw.AdamWConfig(peak_lr=5e-2, warmup_steps=10, total_steps=300,
                            weight_decay=0.0, moment_dtype=moment_dtype)
    params = {"x": jnp.zeros(4), "y": jnp.zeros(4)}
    state = adamw.init_state(params, cfg)

    @jax.jit
    def step(params, state):
        loss, g = jax.value_and_grad(_rosenbrockish)(params)
        params, state, _ = adamw.update(params, g, state, cfg)
        return params, state, loss

    first = None
    for _ in range(300):
        params, state, loss = step(params, state)
        if first is None:
            first = float(loss)
    assert float(loss) < first * 0.01, (first, float(loss))


def test_int8_tracks_f32_closely():
    cfg32 = adamw.AdamWConfig(peak_lr=1e-2, warmup_steps=5, total_steps=100)
    cfg8 = adamw.AdamWConfig(peak_lr=1e-2, warmup_steps=5, total_steps=100,
                             moment_dtype="int8")
    params32 = {"w": jnp.ones(300) * 2.0}
    params8 = {"w": jnp.ones(300) * 2.0}
    s32 = adamw.init_state(params32, cfg32)
    s8 = adamw.init_state(params8, cfg8)
    loss = lambda p: jnp.sum(jnp.square(p["w"] - 0.5))
    for _ in range(50):
        g32 = jax.grad(loss)(params32)
        params32, s32, _ = adamw.update(params32, g32, s32, cfg32)
        g8 = jax.grad(loss)(params8)
        params8, s8, _ = adamw.update(params8, g8, s8, cfg8)
    np.testing.assert_allclose(np.asarray(params8["w"]),
                               np.asarray(params32["w"]), atol=5e-2)


@settings(max_examples=30, deadline=None)
@given(shape=st.sampled_from([(7,), (3, 130), (2, 5, 128), (1, 256)]),
       seed=st.integers(0, 1000))
def test_quantize_roundtrip_error_bounded(shape, seed):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal(shape) * 10, jnp.float32)
    packed = adamw.quantize_blockwise(x)
    back = adamw.dequantize_blockwise(packed, shape[-1])
    assert back.shape == x.shape
    # blockwise absmax/127 quantization error bound
    blocks = np.asarray(jnp.abs(x)).reshape(-1)
    err = np.abs(np.asarray(back - x))
    assert err.max() <= np.abs(np.asarray(x)).max() / 127 + 1e-6


def test_schedule_shape():
    cfg = adamw.AdamWConfig(peak_lr=1.0, warmup_steps=100, total_steps=1000,
                            min_lr_frac=0.1)
    s = lambda t: float(adamw.schedule(cfg, jnp.asarray(t)))
    assert s(0) == 0.0
    assert abs(s(100) - 1.0) < 0.02
    assert s(50) == pytest.approx(0.5, rel=0.05)
    assert s(1000) == pytest.approx(0.1, rel=0.05)
    assert s(550) < s(300)  # monotone decay after warmup


def test_grad_clip_applied():
    cfg = adamw.AdamWConfig(peak_lr=0.0, clip_norm=1.0)
    params = {"w": jnp.zeros(10)}
    state = adamw.init_state(params, cfg)
    big = {"w": jnp.full(10, 1e6)}
    _, _, metrics = adamw.update(params, big, state, cfg)
    assert float(metrics["grad_norm"]) > 1e6  # reported pre-clip
