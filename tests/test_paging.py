"""Paged KV cache subsystem: allocator invariants (no double-assign,
idempotent frees, exact pool conservation — property-tested), paged
decode equivalence with the contiguous cache token-for-token on both the
jnp reference and the fused interpret-mode kernel paths, the fused paged
kernel against its pure-jnp oracle, pluggable admission policies, and
allocator-OOM backpressure through the serve loop (evict/requeue, never
FAILED)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # minimal env: property tests skip, rest run
    from _hypothesis_stub import given, settings, st

from repro.launch.scheduler import POLICIES, Scheduler
from repro.launch.serve import Server, serve_loop
from repro.models.config import ModelConfig
from repro.runtime.lifecycle import Lifecycle, State
from repro.runtime.paging import PageAllocator, PageOOM, PageSpec

KEY = jax.random.PRNGKey(23)


def _cfg(**kw):
    base = dict(name="tiny-paged", family="dense", num_layers=2, d_model=32,
                d_ff=64, vocab_size=101, num_heads=4, num_kv_heads=2)
    base.update(kw)
    return ModelConfig(**base)


def _spec(page_size=4, num_pages=8, max_pages=6):
    return PageSpec(page_size=page_size, num_pages=num_pages,
                    max_pages=max_pages)


# ---------------------------------------------------------------------------
# PageSpec / allocator unit behaviour
# ---------------------------------------------------------------------------

def test_spec_build_contiguous_equivalent_and_budgeted():
    spec = PageSpec.build(batch=4, max_len=33, page_size=8)
    assert spec.max_pages == 5                 # ceil(33 / 8)
    assert spec.num_pages == 4 * 5             # contiguous-equivalent pool
    tight = PageSpec.build(batch=4, max_len=33, page_size=8, pool_pages=7)
    assert tight.num_pages == 7                # budgeted pool override
    assert spec.pages_for(0) == 0
    assert spec.pages_for(1) == 1
    assert spec.pages_for(8) == 1
    assert spec.pages_for(9) == 2


def test_ensure_grows_in_canonical_lowest_page_order():
    alloc = PageAllocator(_spec(), batch=3)
    assert alloc.ensure(0, 7)                  # 2 pages: 0, 1
    assert alloc.ensure(1, 3)                  # 1 page: 2
    assert list(alloc.table[0][:2]) == [0, 1]
    assert alloc.table[1][0] == 2
    assert not alloc.ensure(0, 8)              # still 2 pages: no growth
    alloc.free_slot(0)
    assert alloc.ensure(2, 5)                  # reuses lowest frees: 0, 1
    assert list(alloc.table[2][:2]) == [0, 1]
    alloc.check_conserved()


def test_ensure_oom_carries_slot_and_rid():
    alloc = PageAllocator(_spec(num_pages=2), batch=2)
    alloc.ensure(0, 8)                         # both pages
    with pytest.raises(PageOOM) as e:
        alloc.ensure(1, 4, rid=17)
    assert e.value.slot == 1 and e.value.rid == 17
    # the failed grow must not have leaked a page
    alloc.check_conserved()
    assert alloc.allocated_pages == 2


def test_ensure_rejects_over_table_width():
    alloc = PageAllocator(_spec(max_pages=2, num_pages=8), batch=1)
    with pytest.raises(PageOOM, match="page-table width"):
        alloc.ensure(0, 100)


def test_free_slot_is_idempotent():
    alloc = PageAllocator(_spec(), batch=2)
    alloc.ensure(0, 10)
    assert alloc.free_slot(0)
    assert not alloc.free_slot(0)              # second free: no-op
    assert not alloc.free_slot(1)              # never-allocated: no-op
    assert alloc.free_pages == alloc.spec.num_pages
    alloc.check_conserved()


def test_reservations_price_admission():
    alloc = PageAllocator(_spec(page_size=4, num_pages=6), batch=4)
    alloc.reserve(1, 16)                       # pledge 4 pages to rid 1
    assert alloc.reserved_pages == 4
    assert alloc.can_admit(8)                  # 2 more pages still fit
    assert not alloc.can_admit(12)             # 3 would over-promise
    # the pledge is consumed page-by-page as the slot actually grows
    alloc.ensure(0, 8, rid=1)
    assert alloc.reserved_pages == 2
    alloc.ensure(0, 16, rid=1)
    assert alloc.reserved_pages == 0
    # freeing the slot with its rid drops any leftover pledge too
    alloc.reserve(2, 8)
    alloc.ensure(1, 4, rid=2)
    alloc.free_slot(1, rid=2)
    assert alloc.reserved_pages == 0
    alloc.check_conserved()


def test_fits_pool_bounds_admissible_footprints():
    alloc = PageAllocator(_spec(page_size=4, num_pages=3, max_pages=8),
                          batch=2)
    assert alloc.fits_pool(12)                 # 3 pages == whole pool
    assert not alloc.fits_pool(13)             # could never fit: reject


def test_utilization_reports_pages_vs_tokens():
    alloc = PageAllocator(_spec(page_size=4), batch=2)
    alloc.ensure(0, 6)                         # 2 pages for 6 tokens
    u = alloc.utilization()
    assert u["pages_allocated"] == 2
    assert u["tokens_resident"] == 6
    assert u["token_capacity"] == 8
    assert u["utilization"] == pytest.approx(0.75)
    u2 = alloc.utilization(tokens_resident=5)  # explicit numerator wins
    assert u2["tokens_resident"] == 5


def test_adopt_rebuilds_exact_allocator_state():
    rng = np.random.default_rng(3)
    alloc = PageAllocator(_spec(num_pages=10), batch=4)
    for _ in range(40):
        slot = int(rng.integers(0, 4))
        if rng.integers(0, 3) == 0:
            alloc.free_slot(slot)
        else:
            try:
                alloc.ensure(slot, int(rng.integers(1, 20)))
            except PageOOM:
                pass
    twin = PageAllocator.adopt(alloc.spec, alloc.table)
    np.testing.assert_array_equal(twin.table, alloc.table)
    np.testing.assert_array_equal(twin._owner, alloc._owner)
    # canonical (min-heap) allocation order makes the free list a pure
    # function of the table: both must hand out the same next page
    assert sorted(twin._free) == sorted(alloc._free)
    assert twin.ensure(0, (twin.slot_pages(0) + 1) * twin.spec.page_size) \
        == alloc.ensure(0, (alloc.slot_pages(0) + 1) * alloc.spec.page_size)
    np.testing.assert_array_equal(twin.table, alloc.table)


def test_adopt_rejects_double_assigned_table():
    spec = _spec()
    table = np.full((2, spec.max_pages), -1, np.int32)
    table[0, 0] = table[1, 0] = 2              # page 2 owned twice
    with pytest.raises(ValueError, match="page 2"):
        PageAllocator.adopt(spec, table)


# ---------------------------------------------------------------------------
# allocator properties: seeded fuzz (always runs) + hypothesis variants
# ---------------------------------------------------------------------------

def _apply_ops(alloc, ops):
    """Replay (kind, slot, tokens) ops, checking every invariant the
    module docstring promises after each one."""
    for kind, slot, tokens in ops:
        slot %= alloc.batch
        if kind == 0:
            try:
                alloc.ensure(slot, tokens)
            except PageOOM:
                pass                           # loud OOM is legal; leaks not
        elif kind == 1:
            alloc.free_slot(slot)
        else:                                  # double free: must be no-op
            alloc.free_slot(slot)
            assert not alloc.free_slot(slot)
        alloc.check_conserved()                # no double-assign, no leak
        assert alloc.free_pages + alloc.allocated_pages \
            == alloc.spec.num_pages


@pytest.mark.parametrize("seed", [0, 1, 2, 3])
def test_fuzz_pool_conservation(seed):
    rng = np.random.default_rng(seed)
    alloc = PageAllocator(_spec(page_size=3, num_pages=7, max_pages=5),
                          batch=4)
    ops = [(int(rng.integers(0, 3)), int(rng.integers(0, 4)),
            int(rng.integers(0, 16))) for _ in range(120)]
    _apply_ops(alloc, ops)


@settings(max_examples=60, deadline=None)
@given(st.lists(st.tuples(st.integers(0, 2), st.integers(0, 7),
                          st.integers(0, 24)), max_size=80))
def test_property_no_double_assign_and_conserved(ops):
    _apply_ops(PageAllocator(_spec(page_size=2, num_pages=9, max_pages=6),
                             batch=3), ops)


@settings(max_examples=40, deadline=None)
@given(st.lists(st.integers(0, 30), min_size=1, max_size=12),
       st.integers(2, 5))
def test_property_adopt_is_lossless(tokens_per_slot, page_size):
    spec = PageSpec(page_size=page_size, num_pages=12, max_pages=8)
    alloc = PageAllocator(spec, batch=len(tokens_per_slot))
    for slot, tokens in enumerate(tokens_per_slot):
        try:
            alloc.ensure(slot, tokens)
        except PageOOM:
            pass
    twin = PageAllocator.adopt(spec, alloc.table)
    np.testing.assert_array_equal(twin.table, alloc.table)
    assert sorted(twin._free) == sorted(alloc._free)


# ---------------------------------------------------------------------------
# decode equivalence: paged must be token-for-token contiguous
# ---------------------------------------------------------------------------

def _requests(cfg, spec):
    out = []
    for rid, (plen, gen) in enumerate(spec):
        prompt = np.asarray(
            jax.random.randint(jax.random.PRNGKey(300 + rid), (plen,), 0,
                               cfg.vocab_size), np.int32)
        out.append((rid, prompt, gen))
    return out


def _serve_all(cfg, batch, requests, max_len, paged=None):
    """test_serving's drain loop, optionally on the paged cache: the
    Server handles allocation internally (prefill covers the prompt,
    decode_step grows page-by-page, release_slot drains the pool)."""
    server = Server(cfg, batch, max_len, autotune_kernels=False,
                    paged=paged)
    queue = list(requests)
    tokens = {rid: [] for rid, _, _ in requests}
    slot_rid = {}
    for slot in range(min(batch, len(queue))):
        rid, prompt, gen = queue.pop(0)
        server.prefill(slot, rid, prompt, gen)
        slot_rid[slot] = rid
        tokens[rid].append(int(server.last_tok[slot, 0]))
    completed, guard = 0, 0
    while completed < len(requests):
        nxt, done, _ = server.decode_step()
        for slot, rid in slot_rid.items():
            if server.slot_req[slot] == rid:
                tokens[rid].append(int(nxt[slot, 0]))
        for slot in done:
            completed += 1
            server.release_slot(slot)
            if queue:
                rid, prompt, gen = queue.pop(0)
                server.prefill(slot, rid, prompt, gen)
                slot_rid[slot] = rid
                tokens[rid].append(int(server.last_tok[slot, 0]))
        guard += 1
        assert guard < 200, "serve loop failed to drain the queue"
    if server.allocator is not None:
        server.allocator.check_conserved()
        assert server.allocator.allocated_pages == 0, \
            "release_slot must drain the pool"
    return tokens


def test_paged_decode_matches_contiguous_token_for_token():
    """The acceptance invariant: the same ragged workload (mixed lengths
    plus a mid-run slot refill) through the paged pool reproduces the
    contiguous cache's tokens exactly, on the jnp reference path."""
    cfg = _cfg()
    spec = [(5, 7), (9, 4), (3, 6)]
    reqs = _requests(cfg, spec)
    max_len = max(p + g for p, g in spec) + 4
    contiguous = _serve_all(cfg, 2, reqs, max_len)
    paged = _serve_all(cfg, 2, reqs, max_len,
                       paged=PageSpec.build(2, max_len, page_size=4))
    assert paged == contiguous


def test_paged_decode_through_fused_kernel_matches_contiguous(
        monkeypatch, tmp_path):
    """Same invariant with the fused paged decode kernel forced on
    (interpret mode): the page table rides scalar-prefetch into the
    kernel and must not change a single token."""
    monkeypatch.setenv("REPRO_DECODE_KERNEL", "interpret")
    monkeypatch.setenv("REPRO_AUTOTUNE_CACHE",
                       str(tmp_path / "autotune.json"))
    cfg = _cfg()
    spec = [(4, 5), (7, 3)]
    reqs = _requests(cfg, spec)
    max_len = max(p + g for p, g in spec) + 4
    contiguous = _serve_all(cfg, 2, reqs, max_len)
    paged = _serve_all(cfg, 2, reqs, max_len,
                       paged=PageSpec.build(2, max_len, page_size=4))
    assert paged == contiguous


def test_paged_kernel_matches_jnp_oracle():
    """`paged_gqa_decode_attention` (interpret mode) against
    `paged_decode_ref` on a ragged batch with a shuffled page table."""
    from repro.kernels.attention.decode import (paged_decode_ref,
                                                paged_gqa_decode_attention)
    b, hq, hkv, dh = 3, 4, 2, 16
    num_pages, page_size, max_pages = 10, 4, 3
    k1, k2, k3 = jax.random.split(KEY, 3)
    q = jax.random.normal(k1, (b, hq, dh), jnp.float32)
    k_pool = jax.random.normal(k2, (num_pages, page_size, hkv, dh),
                               jnp.float32)
    v_pool = jax.random.normal(k3, (num_pages, page_size, hkv, dh),
                               jnp.float32)
    # non-monotonic physical pages, ragged depths, -1 tails
    pages = np.full((b, max_pages), -1, np.int32)
    pages[0, :3] = [7, 2, 9]
    pages[1, :1] = [4]
    pages[2, :2] = [0, 8]
    lengths = jnp.asarray([11, 3, 6], jnp.int32)
    got = paged_gqa_decode_attention(q, k_pool, v_pool,
                                     jnp.asarray(pages), length=lengths,
                                     interpret=True)
    want = paged_decode_ref(q, k_pool, v_pool, jnp.asarray(pages),
                            length=lengths)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------------------
# admission policies
# ---------------------------------------------------------------------------

def _lc_with(reqs):
    lc = Lifecycle(clock=lambda: 0.0)
    for rid, plen, gen in reqs:
        lc.submit(rid, np.zeros(plen, np.int32), gen)
    return lc


def test_scheduler_rejects_unknown_policy():
    with pytest.raises(ValueError, match="unknown policy"):
        Scheduler("lifo")
    assert set(POLICIES) == {"fcfs", "spf", "paged-aware"}


def test_fcfs_is_head_of_line_blocking():
    alloc = PageAllocator(_spec(page_size=4, num_pages=4, max_pages=4),
                          batch=2)
    # head needs 4 pages, pool has 4 free but 2 are pledged elsewhere
    alloc.reserve(99, 8)
    lc = _lc_with([(0, 8, 8), (1, 2, 2)])      # head 16 tokens, next 4
    sched = Scheduler("fcfs", allocator=alloc)
    assert sched.pop_ready(lc, 0) is None      # head doesn't fit: nothing
    alloc.release_reservation(99)
    assert sched.pop_ready(lc, 0).rid == 0     # now the head goes first


def test_spf_admits_smallest_footprint_first():
    lc = _lc_with([(0, 8, 8), (1, 2, 2), (2, 4, 4)])
    sched = Scheduler("spf",
                      allocator=PageAllocator(_spec(num_pages=32), batch=4))
    assert [sched.pop_ready(lc, 0).rid for _ in range(3)] == [1, 2, 0]


def test_paged_aware_is_first_fit_past_blocked_head():
    alloc = PageAllocator(_spec(page_size=4, num_pages=4, max_pages=4),
                          batch=2)
    alloc.reserve(99, 8)                       # only 2 pages effectively free
    lc = _lc_with([(0, 8, 8), (1, 2, 2)])
    sched = Scheduler("paged-aware", allocator=alloc)
    req = sched.pop_ready(lc, 0)               # skips the too-big head
    assert req.rid == 1
    assert lc.requests[0].state is State.QUEUED


def test_admission_reserves_predicted_footprint():
    alloc = PageAllocator(_spec(page_size=4, num_pages=8, max_pages=8),
                          batch=2)
    lc = _lc_with([(0, 6, 6)])                 # 12 tokens -> 3 pages
    Scheduler("fcfs", allocator=alloc).pop_ready(lc, 0)
    assert alloc.reserved_pages == 3


def test_oversize_request_rejected_loudly():
    alloc = PageAllocator(_spec(page_size=4, num_pages=3, max_pages=8),
                          batch=2)
    lc = _lc_with([(0, 20, 20), (1, 2, 2)])    # rid 0 can never fit
    sched = Scheduler("fcfs", allocator=alloc)
    assert sched.pop_ready(lc, 0).rid == 1
    assert lc.requests[0].state is State.REJECTED
    assert sched.rejected_oversize == 1


# ---------------------------------------------------------------------------
# OOM backpressure through the serve loop
# ---------------------------------------------------------------------------

def test_decode_oom_backpressure_evicts_never_fails():
    """A deliberately overcommitted pool (no scheduler reservations):
    decode growth exhausts it mid-flight, the loop must evict the
    lightest victim for a later retry — every request still completes,
    none FAILED, and the pool drains leak-free."""
    cfg = _cfg()
    max_len = 20
    # Each request peaks at ceil(14/2)=7 pages; two in flight need 14
    # but the pool holds 10 — an OOM mid-decode is guaranteed.
    paged = PageSpec.build(2, max_len, page_size=2, pool_pages=10)
    server = Server(cfg, 2, max_len, autotune_kernels=False, paged=paged)
    # backoff long enough that the evicted victim re-enters only after
    # the survivor finished and drained its pages — without scheduler
    # reservations that patience is what breaks the OOM ping-pong
    lc = Lifecycle(clock=lambda: 0.0, backoff_steps=16)
    for rid, (plen, gen) in enumerate([(6, 8), (6, 8)]):
        lc.submit(rid, np.arange(plen, dtype=np.int32) % cfg.vocab_size,
                  gen)
    stats = serve_loop(server, lc, max_steps=400)
    counts = lc.counters()
    assert stats["kv_ooms"] >= 1, "the overcommit never tripped"
    assert counts["failed"] == 0, "OOM must backpressure, not fail"
    assert counts["completed"] == 2
    assert lc.conserved()
    server.allocator.check_conserved()
    assert server.allocator.allocated_pages == 0


def test_scheduler_reservations_prevent_oom():
    """Same overcommitted pool, but admission priced through the
    scheduler: reservations defer the second request instead of letting
    it OOM mid-decode."""
    cfg = _cfg()
    max_len = 20
    paged = PageSpec.build(2, max_len, page_size=2, pool_pages=10)
    server = Server(cfg, 2, max_len, autotune_kernels=False, paged=paged)
    sched = Scheduler("spf", allocator=server.allocator)
    lc = Lifecycle(clock=lambda: 0.0)
    for rid, (plen, gen) in enumerate([(6, 8), (6, 8)]):
        lc.submit(rid, np.arange(plen, dtype=np.int32) % cfg.vocab_size,
                  gen)
    stats = serve_loop(server, lc, max_steps=400, scheduler=sched)
    counts = lc.counters()
    assert stats["kv_ooms"] == 0, "reservations must prevent OOM"
    assert stats["max_concurrent"] == 1        # pool covers one at a time
    assert counts["completed"] == 2 and counts["failed"] == 0
    assert lc.conserved()


def test_paged_serve_loop_tokens_match_contiguous():
    """serve_loop end-to-end (scheduler, chunked admission, paged pool)
    emits exactly the tokens of the contiguous FCFS loop."""
    cfg = _cfg()
    spec = [(5, 6), (3, 4), (7, 5), (4, 6)]
    max_len = max(p + g for p, g in spec) + 4

    def run(paged, policy):
        server = Server(cfg, 2, max_len, autotune_kernels=False,
                        paged=paged)
        sched = (Scheduler(policy, allocator=server.allocator)
                 if policy else None)
        lc = Lifecycle(clock=lambda: 0.0)
        for rid, (plen, gen) in enumerate(spec):
            prompt = np.asarray(jax.random.randint(
                jax.random.PRNGKey(500 + rid), (plen,), 0,
                cfg.vocab_size), np.int32)
            lc.submit(rid, prompt, gen)
        serve_loop(server, lc, max_steps=400, scheduler=sched)
        assert lc.conserved()
        return {r.rid: list(r.tokens) for r in lc.requests.values()}

    contiguous = run(None, None)
    pspec = PageSpec.build(2, max_len, page_size=4)
    paged = run(pspec, "fcfs")
    assert paged == contiguous
