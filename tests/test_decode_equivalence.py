"""Decode (KV cache / recurrent state) must reproduce teacher-forced
training logits exactly — covers RoPE offsets, SWA ring buffer, Mamba conv
tails, RWKV token shifts, and hybrid stacking."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import transformer
from repro.models.config import ModelConfig

KEY = jax.random.PRNGKey(7)


def _tiny(family, **kw):
    base = dict(name=f"tiny-{family}", family=family, num_layers=4,
                d_model=64, d_ff=128, vocab_size=97, num_heads=4,
                num_kv_heads=2)
    base.update(kw)
    return ModelConfig(**base)


CASES = {
    "dense": _tiny("dense", qk_norm=True),
    "dense_bias": _tiny("dense", qkv_bias=True),
    "swa_ring": _tiny("dense", sliding_window=6),
    "rwkv": _tiny("ssm", num_heads=0, num_kv_heads=0, rwkv_head_dim=16,
                  rwkv_lora_dim=8),
    "jamba": _tiny("hybrid", num_layers=8, attn_period=4, attn_offset=2,
                   num_experts=4, top_k=2, moe_d_ff=32, moe_every=2,
                   moe_offset=1, ssm_state=4, ssm_conv=3,
                   capacity_factor=8.0),
}


@pytest.mark.parametrize("name", list(CASES))
def test_decode_matches_teacher_forcing(name):
    cfg = CASES[name]
    b, s = 2, 12
    params = transformer.init(cfg, KEY)
    toks = jax.random.randint(KEY, (b, s), 0, cfg.vocab_size)
    full, _, _ = transformer.forward(cfg, params, {"tokens": toks},
                                     compute_dtype=jnp.float32)
    cache = transformer.cache_init(cfg, b, s, dtype=jnp.float32)
    outs = []
    for t in range(s):
        lg, cache, _ = transformer.forward(
            cfg, params, {"tokens": toks[:, t:t + 1]}, cache=cache,
            compute_dtype=jnp.float32)
        outs.append(lg[:, 0])
    dec = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(full),
                               rtol=2e-3, atol=2e-3)


@pytest.mark.parametrize("name", ["dense", "dense_bias"])
def test_decode_through_fused_kernel_matches_teacher_forcing(
        name, monkeypatch, tmp_path):
    """The serving decode hot loop routed through the fused autotuned
    decode-attention kernel (REPRO_DECODE_KERNEL=interpret forces the TPU
    path in interpret mode) must still reproduce teacher-forced logits."""
    monkeypatch.setenv("REPRO_DECODE_KERNEL", "interpret")
    monkeypatch.setenv("REPRO_AUTOTUNE_CACHE",
                       str(tmp_path / "autotune.json"))
    cfg = CASES[name]
    b, s = 2, 8
    params = transformer.init(cfg, KEY)
    toks = jax.random.randint(KEY, (b, s), 0, cfg.vocab_size)
    full, _, _ = transformer.forward(cfg, params, {"tokens": toks},
                                     compute_dtype=jnp.float32)
    cache = transformer.cache_init(cfg, b, s, dtype=jnp.float32)
    outs = []
    for t in range(s):
        lg, cache, _ = transformer.forward(
            cfg, params, {"tokens": toks[:, t:t + 1]}, cache=cache,
            compute_dtype=jnp.float32)
        outs.append(lg[:, 0])
    dec = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(full),
                               rtol=2e-3, atol=2e-3)


def test_swa_ring_buffer_bounded_cache():
    cfg = CASES["swa_ring"]
    cache = transformer.cache_init(cfg, 1, 1000, dtype=jnp.float32)
    k = jax.tree.leaves(cache["blocks"])[0]
    # cache length is clamped to the window, not the full 1000
    assert cfg.sliding_window in k.shape
