"""Fallback shims for ``hypothesis`` in minimal environments.

Test modules do ``from hypothesis import given, settings, strategies as st``;
when hypothesis is absent (it is a dev-only dependency, see
requirements-dev.txt) they fall back to these no-op stand-ins so that
collection succeeds and only the property-based tests are skipped — the
plain pytest tests in the same module still run.
"""

from __future__ import annotations

import pytest


class _Strategies:
    """Accepts any ``st.<name>(...)`` call and returns an inert placeholder."""

    def __getattr__(self, name):
        return lambda *args, **kwargs: None


st = _Strategies()
strategies = st


def settings(*args, **kwargs):
    """No-op decorator factory matching ``hypothesis.settings``."""

    def deco(fn):
        return fn

    return deco


def given(*args, **kwargs):
    """Replace the property test with a zero-arg test that skips.

    The replacement takes no parameters on purpose: pytest would otherwise
    try to resolve the original hypothesis-driven arguments as fixtures.
    """

    def deco(fn):
        def _skipped():
            pytest.skip("hypothesis not installed (pip install -r "
                        "requirements-dev.txt)")

        _skipped.__name__ = fn.__name__
        _skipped.__doc__ = fn.__doc__
        return _skipped

    return deco
