"""Per-arch smoke tests (deliverable f): every assigned architecture's
reduced config runs one forward + one train step on CPU with shape and
finiteness asserts."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.configs as configs
from repro.launch import steps
from repro.models import transformer
from repro.optim import adamw

KEY = jax.random.PRNGKey(0)


def _batch_for(cfg, b=2, s=16):
    rng = np.random.default_rng(0)
    batch = {}
    if cfg.frontend == "frame":
        batch["frames"] = jnp.asarray(
            rng.standard_normal((b, s, cfg.frontend_dim)), jnp.float32)
        batch["labels"] = jnp.asarray(
            rng.integers(0, cfg.vocab_size, (b, s)), jnp.int32)
    elif cfg.frontend == "patch":
        npatch = 4
        batch["patches"] = jnp.asarray(
            rng.standard_normal((b, npatch, cfg.frontend_dim)), jnp.float32)
        batch["tokens"] = jnp.asarray(
            rng.integers(0, cfg.vocab_size, (b, s - npatch)), jnp.int32)
        batch["labels"] = jnp.asarray(
            rng.integers(0, cfg.vocab_size, (b, s)), jnp.int32)
    else:
        batch["tokens"] = jnp.asarray(
            rng.integers(0, cfg.vocab_size, (b, s)), jnp.int32)
        batch["labels"] = jnp.asarray(
            rng.integers(0, cfg.vocab_size, (b, s)), jnp.int32)
    return batch


@pytest.mark.parametrize("arch", configs.list_archs())
def test_smoke_forward_shapes_and_finite(arch):
    cfg = configs.get_smoke(arch)
    params = transformer.init(cfg, KEY)
    b, s = 2, 16
    batch = _batch_for(cfg, b, s)
    inputs = {k: v for k, v in batch.items() if k != "labels"}
    logits, _, aux = transformer.forward(cfg, params, inputs,
                                         compute_dtype=jnp.float32)
    assert logits.shape == (b, s, cfg.vocab_size)
    assert np.all(np.isfinite(np.asarray(logits, np.float32)))
    assert np.isfinite(float(aux))


@pytest.mark.parametrize("arch", configs.list_archs())
def test_smoke_train_step(arch):
    cfg = configs.get_smoke(arch)
    opt_cfg = adamw.AdamWConfig(peak_lr=1e-3, warmup_steps=2, total_steps=10)
    params = transformer.init(cfg, KEY)
    state = {"params": params, "opt": adamw.init_state(params, opt_cfg)}
    step = jax.jit(steps.make_train_step(cfg, opt_cfg))
    batch = _batch_for(cfg)
    state, metrics = step(state, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert np.isfinite(float(metrics["grad_norm"]))
    assert int(state["opt"]["step"]) == 1
    # params actually changed
    delta = sum(
        float(jnp.sum(jnp.abs(a.astype(jnp.float32) - b.astype(jnp.float32))))
        for a, b in zip(jax.tree.leaves(params),
                        jax.tree.leaves(state["params"])))
    assert delta > 0


@pytest.mark.parametrize("arch", [a for a in configs.list_archs()
                                  if configs.get_smoke(a).family != "encoder"])
def test_smoke_decode_step(arch):
    cfg = configs.get_smoke(arch)
    params = transformer.init(cfg, KEY)
    serve = jax.jit(steps.make_serve_step(cfg))
    cache = transformer.cache_init(cfg, 2, 32, dtype=jnp.float32)
    tok = jnp.ones((2, 1), jnp.int32)
    for _ in range(3):
        tok, cache = serve(params, cache, tok)
    assert tok.shape == (2, 1)
    assert int(cache["index"]) == 3


def test_full_config_param_counts_match_published():
    expected = {
        "jamba_1_5_large_398b": (398e9, 0.05),
        "phi3_5_moe_42b": (42e9, 0.05),
        "qwen3_moe_235b": (235e9, 0.05),
        "phi3_mini_3_8b": (3.8e9, 0.06),
        "qwen3_14b": (14e9, 0.08),
        "qwen2_5_32b": (32e9, 0.06),
        "h2o_danube_1_8b": (1.8e9, 0.06),
        "rwkv6_7b": (7e9, 0.2),
        "internvl2_2b": (2e9, 0.1),
    }
    for arch, (target, tol) in expected.items():
        n = configs.get(arch).param_count()
        assert abs(n - target) / target < tol, (arch, n)


def test_active_params_match_published_moe():
    assert abs(configs.get("qwen3_moe_235b").active_param_count()
               - 22e9) / 22e9 < 0.05
    assert abs(configs.get("phi3_5_moe_42b").active_param_count()
               - 6.6e9) / 6.6e9 < 0.05
    assert abs(configs.get("jamba_1_5_large_398b").active_param_count()
               - 94e9) / 94e9 < 0.05
