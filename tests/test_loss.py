"""Fused/chunked cross entropy == reference; gradients too."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # minimal env: property tests skip, rest run
    from _hypothesis_stub import given, settings, st

from repro.parallel.loss import IGNORE, cross_entropy, fused_cross_entropy

KEY = jax.random.PRNGKey(0)


def _case(b=2, s=24, d=16, v=37, masked=False, seed=0):
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(seed), 3)
    x = jax.random.normal(k1, (b, s, d))
    table = jax.random.normal(k2, (v, d))
    labels = jax.random.randint(k3, (b, s), 0, v)
    if masked:
        labels = labels.at[:, :5].set(IGNORE)
    return x, table, labels


@pytest.mark.parametrize("chunk", [0, 8, 16, 48, 1000])
@pytest.mark.parametrize("masked", [False, True])
def test_fused_matches_reference(chunk, masked):
    x, table, labels = _case(masked=masked)
    logits = x @ table.T
    ref, _ = cross_entropy(logits, labels)
    fused, _ = fused_cross_entropy(x, table, labels, chunk=chunk)
    np.testing.assert_allclose(float(fused), float(ref), rtol=1e-5)


@pytest.mark.parametrize("chunk", [0, 16])
def test_fused_gradients_match(chunk):
    x, table, labels = _case()

    def ref_loss(x, t):
        return cross_entropy(x @ t.T, labels)[0]

    def fused_loss(x, t):
        return fused_cross_entropy(x, t, labels, chunk=chunk)[0]

    gx_ref, gt_ref = jax.grad(ref_loss, argnums=(0, 1))(x, table)
    gx_f, gt_f = jax.grad(fused_loss, argnums=(0, 1))(x, table)
    np.testing.assert_allclose(np.asarray(gx_f), np.asarray(gx_ref),
                               rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(np.asarray(gt_f), np.asarray(gt_ref),
                               rtol=1e-4, atol=1e-5)


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 1000), chunk=st.sampled_from([0, 7, 13, 32]))
def test_fused_chunk_invariance(seed, chunk):
    """The loss must not depend on the chunking (including ragged pads)."""
    x, table, labels = _case(b=1, s=19, seed=seed)
    l0, _ = fused_cross_entropy(x, table, labels, chunk=0)
    lc, _ = fused_cross_entropy(x, table, labels, chunk=chunk)
    np.testing.assert_allclose(float(lc), float(l0), rtol=1e-5)


def test_all_masked_is_finite():
    x, table, labels = _case()
    labels = jnp.full_like(labels, IGNORE)
    loss, metrics = fused_cross_entropy(x, table, labels, chunk=8)
    assert np.isfinite(float(loss)) and float(metrics["tokens"]) == 0


def test_uniform_logits_loss_is_log_v():
    v = 64
    x = jnp.zeros((1, 10, 8))
    table = jnp.zeros((v, 8))
    labels = jnp.zeros((1, 10), jnp.int32)
    loss, _ = fused_cross_entropy(x, table, labels, chunk=4)
    assert float(loss) == pytest.approx(np.log(v), rel=1e-5)
