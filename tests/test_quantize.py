"""Int8 KV-cache quantization: the properties `runtime/quantize.py`'s
docstring pins (half-step round-trip bound, zero rows exact, outliers
isolated to their own row, bitwise-idempotent re-quantization — the
crash/resume invariant), plus the quantized decode kernel family #5
against its dequantize-then-attend oracle and the float path.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # minimal env: property tests skip, rest run
    from _hypothesis_stub import given, settings, st

from repro.kernels import autotune
from repro.kernels.attention import decode as attn_decode
from repro.kernels.attention import decode_int8 as attn_decode_int8
from repro.runtime import quantize

# float32 slop on top of the analytic half-step bound: the bound divides
# the same absmax the kernel multiplies back, so only rounding eps rides
# on top.
EPS = 1e-5


def _rows(seed: int, n: int, dh: int) -> jax.Array:
    return jax.random.normal(jax.random.PRNGKey(seed), (n, dh),
                             jnp.float32) * 3.0


# ---------------------------------------------------------------- properties

@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), dh=st.sampled_from([8, 16, 32, 64]))
def test_round_trip_error_within_half_step(seed, dh):
    x = _rows(seed, 5, dh)
    q, s = quantize.quantize_rows(x)
    err = jnp.abs(quantize.dequantize_rows(q, s) - x)
    bound = quantize.max_abs_error_bound(x)
    assert bool(jnp.all(err <= bound[:, None] + EPS)), (
        np.asarray(err).max(), np.asarray(bound).max())


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), dh=st.sampled_from([8, 16, 32, 64]))
def test_requantize_is_idempotent(seed, dh):
    """quant(deq(quant(x))) == quant(x) bit-for-bit: a snapshot/resume
    cycle (which stores q + scale, never dequantized values) cannot
    drift the cache."""
    x = _rows(seed, 5, dh)
    q1, s1 = quantize.quantize_rows(x)
    q2, s2 = quantize.quantize_rows(quantize.dequantize_rows(q1, s1))
    np.testing.assert_array_equal(np.asarray(q1), np.asarray(q2))
    np.testing.assert_array_equal(np.asarray(s1), np.asarray(s2))


def test_zero_row_quantizes_exactly():
    x = jnp.zeros((3, 16), jnp.float32)
    q, s = quantize.quantize_rows(x)
    assert q.dtype == jnp.int8 and s.dtype == jnp.float32
    np.testing.assert_array_equal(np.asarray(q), 0)
    np.testing.assert_array_equal(np.asarray(s), 0.0)
    np.testing.assert_array_equal(
        np.asarray(quantize.dequantize_rows(q, s)), 0.0)


def test_quantized_zeros_is_the_image_of_quantizing_zeros():
    """A reset cache slot must be bitwise a freshly-written zero row."""
    zq, zs = quantize.quantized_zeros((2, 4, 8))
    q, s = quantize.quantize_rows(jnp.zeros((2, 4, 8), jnp.float32))
    np.testing.assert_array_equal(np.asarray(zq), np.asarray(q))
    np.testing.assert_array_equal(np.asarray(zs), np.asarray(s))


def test_outlier_dominates_only_its_own_row():
    """The block is one token row on purpose: a huge outlier coarsens its
    own row's step but leaves every other row at full resolution."""
    x = _rows(0, 4, 32)
    x = x.at[1, 7].set(1000.0)
    q, s = quantize.quantize_rows(x)
    err = jnp.abs(quantize.dequantize_rows(q, s) - x)
    clean = jnp.asarray([0, 2, 3])
    clean_bound = quantize.max_abs_error_bound(x[clean])
    assert bool(jnp.all(err[clean] <= clean_bound[:, None] + EPS))
    # and the clean rows' bound is untouched by the outlier: tiny
    assert float(clean_bound.max()) < 0.1
    # the outlier row maps its own absmax to exactly +-QMAX
    assert int(np.abs(np.asarray(q[1])).max()) == quantize.QMAX


def test_bytes_per_token_accounting():
    for dh in (16, 32, 64, 128):
        int8 = quantize.bytes_per_token(dh)
        assert int8 == 2 * (dh + 4)
        bf16 = 2 * dh * 2
        assert bf16 / int8 >= 1.6          # the CI-gated floor
    assert quantize.bytes_per_token(64, kv=1) == 68


# ------------------------------------------------------------ kernel family

def _gqa_case(b=2, hq=4, hkv=2, dh=32, cache_len=96, seed=3):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = jax.random.normal(ks[0], (b, hq, dh), jnp.float32)
    k = jax.random.normal(ks[1], (b, cache_len, hkv, dh), jnp.float32)
    v = jax.random.normal(ks[2], (b, cache_len, hkv, dh), jnp.float32)
    kq, ksc = quantize.quantize_rows(k)
    vq, vsc = quantize.quantize_rows(v)
    return q, k, v, kq, ksc, vq, vsc


def test_quantized_kernel_matches_oracle_contiguous():
    q, _, _, kq, ksc, vq, vsc = _gqa_case()
    b, cache_len = q.shape[0], kq.shape[1]
    length = jnp.asarray([cache_len, cache_len // 3], jnp.int32)
    out = attn_decode_int8.quantized_gqa_decode_attention(
        q, kq, ksc, vq, vsc, length=length, block_k=32, interpret=True)
    ref = attn_decode_int8.quantized_decode_ref(
        q, kq, ksc, vq, vsc, length=length)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


def test_quantized_kernel_matches_oracle_paged():
    b, hq, hkv, dh = 2, 4, 2, 16
    page_size, num_pages, max_pages = 8, 16, 6
    ks = jax.random.split(jax.random.PRNGKey(11), 4)
    q = jax.random.normal(ks[0], (b, hq, dh), jnp.float32)
    kp = jax.random.normal(ks[1], (num_pages, page_size, hkv, dh),
                           jnp.float32)
    vp = jax.random.normal(ks[2], (num_pages, page_size, hkv, dh),
                           jnp.float32)
    kq, ksc = quantize.quantize_rows(kp)
    vq, vsc = quantize.quantize_rows(vp)
    pages = jax.random.permutation(ks[3], num_pages)[: b * max_pages]
    pages = pages.reshape(b, max_pages).astype(jnp.int32)
    length = jnp.asarray([page_size * max_pages, 13], jnp.int32)
    out = attn_decode_int8.paged_quantized_gqa_decode_attention(
        q, kq, ksc, vq, vsc, pages, length=length, interpret=True)
    ref = attn_decode_int8.paged_quantized_decode_ref(
        q, kq, ksc, vq, vsc, pages, length=length)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


def test_dispatch_decode_int8_matches_oracle(monkeypatch, tmp_path):
    """The engine path layers.py actually takes: tune + run family #5
    through `autotune.dispatch("decode_int8", ...)` in interpret mode and
    through the off-TPU reference route; both must agree with the
    oracle."""
    monkeypatch.setenv("REPRO_AUTOTUNE_CACHE",
                       str(tmp_path / "autotune.json"))
    q, _, _, kq, ksc, vq, vsc = _gqa_case(cache_len=64)
    length = jnp.asarray([64, 17], jnp.int32)
    ref = attn_decode_int8.quantized_decode_ref(
        q, kq, ksc, vq, vsc, length=length)
    out = autotune.dispatch("decode_int8", q, kq, ksc, vq, vsc,
                            length=length, interpret=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)
    out_ref_path = autotune.dispatch("decode_int8", q, kq, ksc, vq, vsc,
                                     length=length)
    np.testing.assert_allclose(np.asarray(out_ref_path), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


def test_quantized_attention_tracks_float_attention():
    """End-to-end accuracy claim: int8-cache attention vs the f32-cache
    oracle on the same pre-quantization values stays inside the declared
    bench budget (attention is an average of rows each within the
    half-step bound)."""
    q, k, v, kq, ksc, vq, vsc = _gqa_case(dh=32, cache_len=128, seed=9)
    length = jnp.asarray([128, 77], jnp.int32)
    out_q = attn_decode_int8.quantized_decode_ref(
        q, kq, ksc, vq, vsc, length=length)
    out_f = attn_decode.decode_ref(q, k, v, length=length)
    err = float(jnp.max(jnp.abs(out_q - out_f)))
    assert err < 0.05, err            # the decode_int8 bench err budget
    assert err > 0.0                  # quantization really happened
