"""Ragged continuous batching end-to-end: per-slot decode lengths from the
serve loop through the transformer cache into the (fused) decode kernel.

The load-bearing invariant: a ragged batch — mixed prompt/gen lengths plus
a mid-run slot refill — must produce tokens identical to serving each
sequence alone, because every slot attends only over its own valid cache
prefix.  The masked batched prefill must write ONLY the target slot's
cache rows (the old slot-local loop stepped the shared cache with zero
tokens for every other slot, polluting their KV and advancing their
depths), and a recycled slot must reproduce single-sequence decode
exactly.  The cost-model side: the active-prefix length accounting must
price a ragged batch strictly below the batch-max broadcast.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import autotune
from repro.launch.serve import Server
from repro.models import transformer
from repro.models.config import ModelConfig

KEY = jax.random.PRNGKey(11)


def _cfg(**kw):
    base = dict(name="tiny-serve", family="dense", num_layers=2, d_model=32,
                d_ff=64, vocab_size=101, num_heads=4, num_kv_heads=2)
    base.update(kw)
    return ModelConfig(**base)


def _requests(cfg, spec):
    """spec: list of (prompt_len, gen_len) -> [(rid, prompt, gen)]."""
    out = []
    for rid, (plen, gen) in enumerate(spec):
        prompt = np.asarray(
            jax.random.randint(jax.random.PRNGKey(100 + rid), (plen,), 0,
                               cfg.vocab_size), np.int32)
        out.append((rid, prompt, gen))
    return out


def _serve_all(cfg, batch, requests, max_len, paged=None,
               kv_dtype=jnp.float32):
    """Run the continuous-batching loop from launch.serve's main(); returns
    {rid: [generated token ids]} (the prefill's next-token prediction plus
    every decode-step token)."""
    server = Server(cfg, batch, max_len, autotune_kernels=False,
                    paged=paged, kv_dtype=kv_dtype)
    queue = list(requests)
    tokens = {rid: [] for rid, _, _ in requests}
    slot_rid = {}
    for slot in range(min(batch, len(queue))):
        rid, prompt, gen = queue.pop(0)
        server.prefill(slot, rid, prompt, gen)
        slot_rid[slot] = rid
        tokens[rid].append(int(server.last_tok[slot, 0]))
    completed, guard = 0, 0
    while completed < len(requests):
        nxt, done, _ = server.decode_step()
        for slot, rid in slot_rid.items():
            if server.slot_req[slot] == rid:
                tokens[rid].append(int(nxt[slot, 0]))
        for slot in done:
            completed += 1
            if paged is not None:
                server.release_slot(slot)
            else:
                server.slot_req[slot] = -1
            if queue:
                rid, prompt, gen = queue.pop(0)
                server.prefill(slot, rid, prompt, gen)
                slot_rid[slot] = rid
                tokens[rid].append(int(server.last_tok[slot, 0]))
        guard += 1
        assert guard < 200, "serve loop failed to drain the queue"
    return tokens


def test_ragged_batch_with_refill_matches_single_sequence():
    """The acceptance invariant: mixed prompt/gen lengths + a mid-run slot
    refill, batched, reproduce each sequence served alone — token for
    token."""
    cfg = _cfg()
    spec = [(5, 7), (9, 4), (3, 6)]      # 3 requests, 2 slots -> refill
    reqs = _requests(cfg, spec)
    max_len = max(p + g for p, g in spec) + 4
    batched = _serve_all(cfg, 2, reqs, max_len)
    for rid, prompt, gen in reqs:
        solo = _serve_all(cfg, 1, [(rid, prompt, gen)], max_len)
        assert batched[rid] == solo[rid], (
            f"request {rid}: ragged batch diverged from solo decode")
        # prefill next-token + gen decode steps, minus the final stop step
        assert len(batched[rid]) == gen + 1


def test_refilled_slot_reproduces_single_sequence_bitwise():
    """Regression for the recycled-slot bug: `prefill` must clear the
    slot's stale KV rows (and length), so the SECOND request through a
    slot decodes exactly like a fresh single-sequence server."""
    cfg = _cfg()
    reqs = _requests(cfg, [(6, 5), (4, 8)])
    max_len = 16
    batched = _serve_all(cfg, 1, reqs, max_len)   # one slot, serial refill
    for rid, prompt, gen in reqs:
        solo = _serve_all(cfg, 1, [(rid, prompt, gen)], max_len)
        assert batched[rid] == solo[rid]


def test_masked_prefill_leaves_other_slots_untouched():
    """The masked batched prefill writes ONLY the target slot's cache rows
    and lengths — the other slots' KV entries and depths are bitwise
    unchanged (the old loop advanced everyone)."""
    cfg = _cfg()
    server = Server(cfg, 2, 16, autotune_kernels=False)
    (rid0, p0, g0), (rid1, p1, g1) = _requests(cfg, [(5, 4), (7, 4)])
    server.prefill(0, rid0, p0, g0)
    before = jax.tree.map(lambda a: np.asarray(a), server.cache)
    server.prefill(1, rid1, p1, g1)
    after = jax.tree.map(lambda a: np.asarray(a), server.cache)
    assert int(after["lengths"][0]) == int(before["lengths"][0]) == len(p0)
    assert int(after["lengths"][1]) == len(p1)
    for b, a in zip(jax.tree.leaves(before["blocks"]),
                    jax.tree.leaves(after["blocks"])):
        np.testing.assert_array_equal(b[:, 0], a[:, 0])


def test_ragged_batch_through_fused_kernel_matches_solo(monkeypatch,
                                                        tmp_path):
    """The same ragged invariant with the decode hot loop routed through
    the fused decode-attention kernel (interpret mode): the per-slot
    lengths ride the scalar-prefetch vector end to end."""
    monkeypatch.setenv("REPRO_DECODE_KERNEL", "interpret")
    monkeypatch.setenv("REPRO_AUTOTUNE_CACHE",
                       str(tmp_path / "autotune.json"))
    cfg = _cfg(num_layers=2)
    spec = [(6, 4), (3, 5), (4, 3)]
    reqs = _requests(cfg, spec)
    max_len = 12
    batched = _serve_all(cfg, 2, reqs, max_len)
    for rid, prompt, gen in reqs:
        solo = _serve_all(cfg, 1, [(rid, prompt, gen)], max_len)
        assert batched[rid] == solo[rid]


def test_cache_reset_slot_matches_fresh_init():
    """A reset slot is indistinguishable from a freshly initialized one."""
    cfg = _cfg()
    cache = transformer.cache_init(cfg, 2, 8, dtype=jnp.float32)
    params = transformer.init(cfg, KEY)
    toks = jax.random.randint(KEY, (2, 3), 0, cfg.vocab_size)
    _, cache, _ = transformer.forward(cfg, params, {"tokens": toks},
                                      cache=cache,
                                      compute_dtype=jnp.float32)
    reset = transformer.cache_reset_slot(cache, 1)
    fresh = transformer.cache_init(cfg, 2, 8, dtype=jnp.float32)
    assert int(reset["lengths"][1]) == 0
    assert int(reset["lengths"][0]) == 3        # slot 0 untouched
    for r, f in zip(jax.tree.leaves(reset["blocks"]),
                    jax.tree.leaves(fresh["blocks"])):
        np.testing.assert_array_equal(np.asarray(r)[:, 1],
                                      np.asarray(f)[:, 1])


def test_ragged_sliding_window_batch_matches_solo():
    """Per-slot ring buffers: ragged decode with a sliding-window config
    (each slot's ring wraps at its own depth) still matches solo."""
    cfg = _cfg(sliding_window=5)
    spec = [(7, 5), (3, 4)]
    reqs = _requests(cfg, spec)
    batched = _serve_all(cfg, 2, reqs, 16)
    for rid, prompt, gen in reqs:
        solo = _serve_all(cfg, 1, [(rid, prompt, gen)], 16)
        assert batched[rid] == solo[rid]


def test_predicted_step_time_ragged_below_batch_max(tmp_path):
    """The active-prefix cost accounting: a ragged length distribution
    must price the decode step strictly below the batch-max broadcast."""
    cache = autotune.TuneCache(tmp_path / "cache.json")
    cfg = _cfg(num_layers=2, d_model=64, d_ff=128, vocab_size=256)
    ragged = autotune.predict_decode_step_us(
        cfg, 4, cache_len=512, lengths=[32, 64, 128, 512], cache=cache)
    batch_max = autotune.predict_decode_step_us(
        cfg, 4, cache_len=512, cache=cache)
    assert ragged < batch_max
    # the sweep records the quantile lengths it priced each candidate at
    d = autotune.select_serving_batch(
        cfg, cache_len=512, candidates=(1, 2, 4),
        slot_lengths=[32, 64, 128, 512], cache=cache)
    assert d["length_model"] == "active-prefix"
    assert all("slot_lengths" in r and len(r["slot_lengths"]) == r["batch"]
               for r in d["sweep"])
    d_max = autotune.select_serving_batch(
        cfg, cache_len=512, candidates=(1, 2, 4), cache=cache)
    assert d_max["length_model"] == "batch-max"
    by_batch = {r["batch"]: r["step_us"] for r in d["sweep"]}
    by_batch_max = {r["batch"]: r["step_us"] for r in d_max["sweep"]}
    assert all(by_batch[b] < by_batch_max[b] for b in (2, 4))


def test_int8_paged_matches_int8_contiguous_token_for_token():
    """The quantized layout invariant: the SAME ragged workload through
    the int8 paged pool and the int8 contiguous cache produces identical
    tokens — quantization happens once at cache-write, so the layout
    (and its parallel scales leaves) must not change a single token."""
    from repro.runtime.paging import PageSpec
    cfg = _cfg()
    spec = [(5, 7), (9, 4), (3, 6)]
    reqs = _requests(cfg, spec)
    max_len = max(p + g for p, g in spec) + 4
    contiguous = _serve_all(cfg, 2, reqs, max_len, kv_dtype=jnp.int8)
    paged = _serve_all(cfg, 2, reqs, max_len,
                       paged=PageSpec.build(2, max_len, page_size=4),
                       kv_dtype=jnp.int8)
    assert paged == contiguous


def test_int8_paged_fused_kernel_matches_contiguous(monkeypatch, tmp_path):
    """Same invariant with the fused quantized kernels forced on
    (interpret mode): the paged int8 kernel and the contiguous
    decode_int8 dispatch must agree token-for-token."""
    from repro.runtime.paging import PageSpec
    monkeypatch.setenv("REPRO_DECODE_KERNEL", "interpret")
    monkeypatch.setenv("REPRO_AUTOTUNE_CACHE",
                       str(tmp_path / "autotune.json"))
    cfg = _cfg()
    spec = [(4, 5), (7, 3)]
    reqs = _requests(cfg, spec)
    max_len = max(p + g for p, g in spec) + 4
    contiguous = _serve_all(cfg, 2, reqs, max_len, kv_dtype=jnp.int8)
    paged = _serve_all(cfg, 2, reqs, max_len,
                       paged=PageSpec.build(2, max_len, page_size=4),
                       kv_dtype=jnp.int8)
    assert paged == contiguous


def test_int8_cache_tracks_f32_tokens_under_budget():
    """Int8 vs f32 cache, token-match-under-budget: decode logits
    through the int8 cache stay within a bounded distance of the
    f32-cache logits, and the sampled (argmax) token matches at every
    step where the f32 top-1/top-2 margin exceeds twice that error —
    the only steps where an under-budget perturbation could legally flip
    the argmax are the ones the f32 model itself was nearly undecided
    on.  (The paged int8 layout is token-identical to this contiguous
    one — `test_int8_paged_matches_int8_contiguous_token_for_token` — so
    the budget transfers to int8-paged vs f32-contiguous.)"""
    cfg = _cfg()
    b, s, max_len = 2, 6, 16
    params = transformer.init(cfg, KEY)
    toks = jax.random.randint(KEY, (b, s), 0, cfg.vocab_size)
    cache_f = transformer.cache_init(cfg, b, max_len, dtype=jnp.float32)
    cache_q = transformer.cache_init(cfg, b, max_len, dtype=jnp.int8)
    assert "k_scale" in cache_q["blocks"]    # parallel scale leaves present
    max_err, flips, decided = 0.0, 0, 0
    for t in range(s):
        step = {"tokens": toks[:, t:t + 1]}
        lg_f, cache_f, _ = transformer.forward(cfg, params, step,
                                               cache=cache_f,
                                               compute_dtype=jnp.float32)
        lg_q, cache_q, _ = transformer.forward(cfg, params, step,
                                               cache=cache_q,
                                               compute_dtype=jnp.float32)
        lf = np.asarray(lg_f[:, 0], np.float32)
        lq = np.asarray(lg_q[:, 0], np.float32)
        err = float(np.abs(lq - lf).max())
        max_err = max(max_err, err)
        top2 = np.sort(lf, axis=-1)[:, -2:]
        margin = top2[:, 1] - top2[:, 0]
        for i in range(b):
            if margin[i] > 2.0 * err:
                decided += 1
                if lq[i].argmax() != lf[i].argmax():
                    flips += 1
    assert max_err < 0.5, f"int8 logit error {max_err} blew the budget"
    assert decided > 0, "margin threshold decided nothing — test inert"
    assert flips == 0, (
        f"{flips} argmax flips at margins above 2x the logit error")


def test_serve_step_active_none_advances_everyone():
    """`active=None` stays the uniform-batch degenerate case: every slot
    writes and advances (the pre-ragged contract, used by dryrun)."""
    from repro.launch import steps
    cfg = _cfg()
    params = transformer.init(cfg, KEY)
    serve = jax.jit(steps.make_serve_step(cfg))
    cache = transformer.cache_init(cfg, 2, 8, dtype=jnp.float32)
    tok = jnp.ones((2, 1), jnp.int32)
    tok, cache = serve(params, cache, tok)
    assert list(np.asarray(cache["lengths"])) == [1, 1]
    assert int(cache["index"]) == 1


def test_chunked_prefill_matches_solo_token_for_token():
    """Chunked prefill (several variable-length prompts packed into one
    forward, in-flight decode slots riding along) must reproduce each
    request served alone — the 2-D active mask keeps every slot's writes
    inside its own prompt prefix."""
    from repro.launch.serve import serve_loop
    from repro.runtime.lifecycle import Lifecycle

    cfg = _cfg()
    spec = [(5, 6), (3, 4), (7, 5), (4, 6)]
    reqs = _requests(cfg, spec)
    max_len = max(p + g for p, g in spec) + 4

    server = Server(cfg, 2, max_len, autotune_kernels=False)
    assert server.can_chunk()
    lc = Lifecycle(clock=lambda: 0.0)
    for rid, prompt, gen in reqs:
        lc.submit(rid, prompt, gen)
    stats = serve_loop(server, lc, max_steps=400)
    assert stats["chunked_prefills"] >= 1, "the packed path never ran"
    assert lc.conserved()

    for rid, prompt, gen in reqs:
        solo = _serve_all(cfg, 1, [(rid, prompt, gen)], max_len)
        assert list(lc.requests[rid].tokens) == solo[rid], (
            f"request {rid}: chunked prefill diverged from solo decode")


def test_chunk_gate_rejects_unchunkable_configs():
    """SWA (and non-causal/injected servers) must fall back to the
    legacy one-slot masked prefill."""
    server = Server(_cfg(sliding_window=6), 2, 16, autotune_kernels=False)
    assert not server.can_chunk()
