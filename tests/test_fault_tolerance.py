"""Fault-tolerance runtime: injected failures, resume correctness, straggler
detection, data determinism under re-sharding (elastic)."""

import numpy as np
import pytest

from repro.checkpoint import CheckpointManager
from repro.core import loadbalance
from repro.data import DataConfig, SyntheticSource
from repro.runtime import elastic
from repro.runtime.fault_tolerance import (Heartbeat, ResilienceConfig,
                                           StragglerMonitor, run_resilient)


def test_run_resilient_recovers_from_injected_fault(tmp_path):
    ckpt = CheckpointManager(tmp_path, keep=3)
    calls = {"faults": 0}

    def step_fn(state, batch):
        return state + batch, {"loss": float(state)}

    def batch_fn(step):
        return 1

    def fault_hook(step):
        if step == 7 and calls["faults"] == 0:
            calls["faults"] += 1
            raise RuntimeError("injected node failure")

    def on_restore(step):
        st, meta = ckpt.restore(None, np.asarray(0))
        return np.asarray(st), meta["step"]

    state, history, _ = run_resilient(
        step_fn, np.asarray(0), 12, ckpt, batch_fn,
        config=ResilienceConfig(checkpoint_every=5),
        fault_hook=fault_hook, on_restore=on_restore)
    assert calls["faults"] == 1
    # replayed from step 5; final state is exactly 12 increments' worth
    assert int(state) == 12
    assert ckpt.latest_step() == 12


def test_run_resilient_gives_up_after_max_restarts(tmp_path):
    ckpt = CheckpointManager(tmp_path)

    def always_fail(step):
        raise RuntimeError("hard failure")

    with pytest.raises(RuntimeError):
        run_resilient(lambda s, b: (s, {}), 0, 5, ckpt, lambda s: 0,
                      config=ResilienceConfig(max_restarts=2),
                      fault_hook=always_fail,
                      on_restore=lambda step: (0, 0))


def test_straggler_monitor_flags_slow_steps():
    mon = StragglerMonitor(window=16, threshold=2.0)
    for i in range(16):
        assert mon.observe(i, 0.1) is None
    rep = mon.observe(16, 0.5)
    assert rep is not None and rep.ratio == pytest.approx(5.0, rel=0.01)


def test_heartbeat_detects_dead_host():
    t = [0.0]
    hb = Heartbeat(4, timeout_s=10, clock=lambda: t[0])
    t[0] = 5.0
    hb.beat(0); hb.beat(1); hb.beat(2)  # host 3 silent
    t[0] = 12.0
    assert hb.dead() == [3]


def test_data_determinism_across_restart_and_remesh():
    """(seed, step, shard) determinism: restarting at a step reproduces the
    same batch; re-sharding 4->2 shards keeps per-shard streams pure."""
    cfg = DataConfig(vocab_size=101, seq_len=8, global_batch=8, seed=42)
    src = SyntheticSource(cfg)
    b1 = src.batch(5, 0, 4)
    b2 = SyntheticSource(cfg).batch(5, 0, 4)  # "restarted" pipeline
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    # different shards differ
    b3 = src.batch(5, 1, 4)
    assert not np.array_equal(b1["tokens"], b3["tokens"])
    # re-meshed to 2 shards: still deterministic
    c1 = src.batch(5, 0, 2)
    c2 = SyntheticSource(cfg).batch(5, 0, 2)
    np.testing.assert_array_equal(c1["tokens"], c2["tokens"])
    assert c1["tokens"].shape[0] == 4


def test_elastic_mesh_shrinks_sanely():
    assert elastic.largest_mesh_shape(256, 16) == (16, 16)
    assert elastic.largest_mesh_shape(192, 16) == (12, 16)
    assert elastic.largest_mesh_shape(8, 16) == (1, 8)   # degrade TP
    assert elastic.largest_mesh_shape(1, 16) == (1, 1)
