"""HLO parsing: collective operand accounting and shape-size math."""

import jax
import jax.numpy as jnp

from repro.core import hlo_stats


def test_shape_bytes():
    assert hlo_stats.shape_bytes("f32[256,1024]{1,0}") == 256 * 1024 * 4
    assert hlo_stats.shape_bytes("bf16[8]") == 16
    assert hlo_stats.shape_bytes("pred[]") == 1
    assert hlo_stats.shape_bytes("(f32[2,2], s32[4])") == 16 + 16
    assert hlo_stats.shape_bytes("token[]") == 0


def test_collectives_parsed_from_synthetic_module():
    text = """
HloModule m
ENTRY %main (p0: f32[128,64]) -> f32[128,64] {
  %p0 = f32[128,64]{1,0} parameter(0)
  %ar = f32[128,64]{1,0} all-reduce(%p0), replica_groups={}
  %ag = f32[256,64]{1,0} all-gather(%ar), dimensions={0}
  %a2a = f32[128,64]{1,0} all-to-all(%ar), dimensions={0}
  ROOT %out = f32[128,64]{1,0} add(%ar, %a2a)
}
"""
    stats = hlo_stats.collect_collectives(text)
    sz = 128 * 64 * 4
    assert stats.count_by_op == {"all-reduce": 1, "all-gather": 1,
                                 "all-to-all": 1}
    assert stats.bytes_by_op["all-reduce"] == sz
    assert stats.bytes_by_op["all-gather"] == sz   # operand, not result
    assert stats.total_bytes == 3 * sz


def test_real_compiled_module_roundtrip():
    """Parser tolerates a real XLA dump (no collectives on 1 device)."""
    c = jax.jit(lambda x: x @ x).lower(
        jax.ShapeDtypeStruct((64, 64), jnp.float32)).compile()
    stats = hlo_stats.collect_collectives(c.as_text())
    assert stats.total_bytes == 0
    flops, bytes_accessed = hlo_stats.cost_analysis_stats(c)
    assert flops == 2 * 64 * 64 * 64
    assert bytes_accessed > 0
