"""Negative coverage for the CI gate scripts (tools/check_bench.py,
tools/check_registry.py, tools/check_serve.py): a missing row, a schema
regression, or a below-floor speedup must each exit non-zero — CI only
ever ran their happy paths, so a gate that silently passed everything
would rot unnoticed."""

import json
import pathlib
import sys

import pytest

REPO = pathlib.Path(__file__).resolve().parent.parent
sys.path.insert(0, str(REPO / "tools"))

import check_bench  # noqa: E402
import check_registry  # noqa: E402
import check_serve  # noqa: E402


@pytest.fixture
def good_report():
    """A minimal report that passes check_bench (schema + every required
    row with every required field, floors satisfied)."""
    report = {"schema": check_bench.SCHEMA}
    for key, fields in check_bench.REQUIRED_LIST_KEYS.items():
        report[key] = [{f: 1 for f in fields}]
    for key, fields in check_bench.REQUIRED_DICT_KEYS.items():
        report[key] = {f: 1 for f in fields}
    report["attention_causal_skip"]["kstep_speedup"] = 2.0
    report["decode_ragged"]["fetched_speedup"] = 1.6
    # the decode_int8 gate RECOMPUTES the bytes accounting from the
    # shape, so the fixture must be internally consistent (dh=64)
    report["decode_int8"].update({
        "shape": [4, 2, 256, 64],
        "bytes_per_token_int8": 2 * (64 + 4),
        "bytes_per_token_bf16": 2 * 64 * 2,
        "bytes_ratio": (2 * 64 * 2) / (2 * (64 + 4)),
        "max_abs_err": 0.004,
        "err_budget": 0.05,
    })
    return report


def _write(tmp_path, report):
    p = tmp_path / "BENCH.json"
    p.write_text(json.dumps(report))
    return p


# ---------------------------------------------------------------------------
# check_bench
# ---------------------------------------------------------------------------

def test_check_bench_happy_path(tmp_path, good_report):
    path = _write(tmp_path, good_report)
    assert check_bench.check(path) == []
    assert check_bench.main(["check_bench.py", str(path)]) == 0


def test_check_bench_repo_report_is_clean():
    """The committed BENCH_kernels.json must satisfy the current gate."""
    assert check_bench.check(REPO / "BENCH_kernels.json") == []


@pytest.mark.parametrize("missing", ["decode_ragged", "attention_decode",
                                     "matmul_tuned_vs_fixed"])
def test_check_bench_missing_row_fails(tmp_path, good_report, missing):
    del good_report[missing]
    path = _write(tmp_path, good_report)
    problems = check_bench.check(path)
    assert any(missing in p for p in problems)
    assert check_bench.main(["check_bench.py", str(path)]) == 1


def test_check_bench_schema_regression_fails(tmp_path, good_report):
    good_report["schema"] = check_bench.SCHEMA - 1
    path = _write(tmp_path, good_report)
    problems = check_bench.check(path)
    assert any("schema" in p for p in problems)
    assert check_bench.main(["check_bench.py", str(path)]) == 1


def test_check_bench_missing_field_fails(tmp_path, good_report):
    del good_report["decode_ragged"]["fetched_speedup"]
    path = _write(tmp_path, good_report)
    assert any("decode_ragged" in p for p in check_bench.check(path))


def test_check_bench_below_floor_causal_fails(tmp_path, good_report):
    good_report["attention_causal_skip"]["kstep_speedup"] = 1.2
    path = _write(tmp_path, good_report)
    problems = check_bench.check(path)
    assert any("block skipping regressed" in p for p in problems)
    assert check_bench.main(["check_bench.py", str(path)]) == 1


def test_check_bench_below_floor_ragged_fails(tmp_path, good_report):
    """The new gate: a ragged batch that no longer beats the shared-scalar
    broadcast must fail CI."""
    good_report["decode_ragged"]["fetched_speedup"] = 1.0
    path = _write(tmp_path, good_report)
    problems = check_bench.check(path)
    assert any("shared-scalar broadcast" in p for p in problems)
    assert check_bench.main(["check_bench.py", str(path)]) == 1


def test_check_bench_int8_missing_row_fails(tmp_path, good_report):
    del good_report["decode_int8"]
    path = _write(tmp_path, good_report)
    assert any("decode_int8" in p for p in check_bench.check(path))
    assert check_bench.main(["check_bench.py", str(path)]) == 1


def test_check_bench_int8_fabricated_ratio_fails(tmp_path, good_report):
    """A report asserting a bytes ratio its own shape does not deliver
    must fail — the gate recomputes from dh, never trusts the field."""
    good_report["decode_int8"]["bytes_ratio"] = 4.0
    path = _write(tmp_path, good_report)
    problems = check_bench.check(path)
    assert any("recomputed from shape" in p for p in problems)
    assert check_bench.main(["check_bench.py", str(path)]) == 1


def test_check_bench_int8_fabricated_bytes_fails(tmp_path, good_report):
    good_report["decode_int8"]["bytes_per_token_int8"] = 1
    path = _write(tmp_path, good_report)
    assert any("fabricated bandwidth claim" in p
               for p in check_bench.check(path))


def test_check_bench_int8_small_dh_below_ratio_floor_fails(tmp_path,
                                                           good_report):
    """dh=8 only yields 2*8/(8+4) = 1.33x — below the 1.6x floor even
    with every field internally consistent."""
    good_report["decode_int8"].update({
        "shape": [4, 2, 256, 8],
        "bytes_per_token_int8": 2 * (8 + 4),
        "bytes_per_token_bf16": 2 * 8 * 2,
        "bytes_ratio": (2 * 8 * 2) / (2 * (8 + 4)),
    })
    path = _write(tmp_path, good_report)
    problems = check_bench.check(path)
    assert any("bytes ratio" in p and "1.6" in p for p in problems)
    assert check_bench.main(["check_bench.py", str(path)]) == 1


def test_check_bench_int8_error_over_budget_fails(tmp_path, good_report):
    good_report["decode_int8"]["max_abs_err"] = 0.1
    path = _write(tmp_path, good_report)
    problems = check_bench.check(path)
    assert any("accuracy regressed" in p for p in problems)
    assert check_bench.main(["check_bench.py", str(path)]) == 1


def test_check_bench_int8_fabricated_budget_fails(tmp_path, good_report):
    """Declaring a loose budget to hide a bad error must fail: the
    declared budget itself is capped by the gate."""
    good_report["decode_int8"]["max_abs_err"] = 0.4
    good_report["decode_int8"]["err_budget"] = 0.5
    path = _write(tmp_path, good_report)
    problems = check_bench.check(path)
    assert any("budget fabrication refused" in p for p in problems)
    assert check_bench.main(["check_bench.py", str(path)]) == 1


def test_check_bench_unreadable_report_fails(tmp_path):
    path = tmp_path / "nope.json"
    assert check_bench.check(path) != []
    path.write_text("{not json")
    assert check_bench.main(["check_bench.py", str(path)]) == 1


# ---------------------------------------------------------------------------
# check_registry
# ---------------------------------------------------------------------------

def test_check_registry_missing_family_row_fails(tmp_path):
    """Strip one registered family's bench row from an otherwise-good
    report: the registry gate must name the family and exit non-zero."""
    report = json.loads((REPO / "BENCH_kernels.json").read_text())
    del report["attention_decode"]
    path = tmp_path / "BENCH.json"
    path.write_text(json.dumps(report))
    problems = check_registry.check(path)
    assert any("attention_decode" in p for p in problems)
    assert check_registry.main(["check_registry.py", str(path)]) == 1


def test_check_registry_empty_row_fails(tmp_path):
    report = json.loads((REPO / "BENCH_kernels.json").read_text())
    report["matmul_tuned_vs_fixed"] = []
    path = tmp_path / "BENCH.json"
    path.write_text(json.dumps(report))
    assert any("matmul" in p for p in check_registry.check(path))


def test_check_registry_unreadable_report_fails(tmp_path):
    path = tmp_path / "nope.json"
    problems = check_registry.check(path)
    assert any("unreadable" in p for p in problems)
    assert check_registry.main(["check_registry.py", str(path)]) == 1


# ---------------------------------------------------------------------------
# check_serve
# ---------------------------------------------------------------------------

def _good_summary(**overrides):
    s = {"arch": "x", "requests": 6, "submitted": 6, "batch": 4,
         "tokens_generated": 72, "tok_per_s": 10.0,
         "outcomes": {"completed": 6, "timed_out": 0, "failed": 0,
                      "rejected": 0, "evicted": 1, "retried": 1},
         "ttft_ms": {"p50": 12.0, "p99": 30.0, "n": 6},
         "kv_dtype": "float32"}
    s.update(overrides)
    return s


def _log(summary=None, extra_rows=()):
    rows = [json.dumps({"serving_plan": {
        "batch": 4, "source": "autotune",
        "predicted_tok_per_s": 1234.5, "sweep": []}}),
        "some non-json noise",
        *extra_rows,
        json.dumps(summary if summary is not None else _good_summary())]
    return "\n".join(rows)


GOOD_LOG = _log()


def test_check_serve_happy_path(tmp_path):
    log = tmp_path / "serve.log"
    log.write_text(GOOD_LOG)
    assert check_serve.check(GOOD_LOG) == []
    assert check_serve.main(["check_serve.py", str(log),
                             "--requests", "6", "--min-tokens", "72"]) == 0


def test_check_serve_missing_plan_fails(tmp_path):
    text = json.dumps(_good_summary())
    assert any("serving_plan" in p for p in check_serve.check(text))


def test_check_serve_nonpositive_throughput_fails():
    text = GOOD_LOG.replace("1234.5", "0")
    assert any("predicted_tok_per_s" in p for p in check_serve.check(text))


def test_check_serve_undrained_queue_fails(tmp_path):
    log = tmp_path / "serve.log"
    log.write_text(GOOD_LOG)
    assert check_serve.main(["check_serve.py", str(log),
                             "--requests", "7"]) == 1
    assert check_serve.main(["check_serve.py", str(log),
                             "--min-tokens", "100"]) == 1


@pytest.mark.parametrize("counter", check_serve.OUTCOME_KEYS)
def test_check_serve_missing_counter_fails(tmp_path, counter):
    """Each outcome counter is individually required — a summary that
    drops one must exit non-zero."""
    summary = _good_summary()
    del summary["outcomes"][counter]
    log = tmp_path / "serve.log"
    log.write_text(_log(summary))
    problems = check_serve.check(_log(summary))
    assert any(counter in p for p in problems)
    assert check_serve.main(["check_serve.py", str(log)]) == 1


def test_check_serve_missing_outcomes_block_fails(tmp_path):
    summary = _good_summary()
    del summary["outcomes"]
    assert any("outcome counters" in p for p in
               check_serve.check(_log(summary)))


def test_check_serve_nonconserving_summary_fails(tmp_path):
    """submitted != completed+timed_out+failed+rejected — a lost request —
    must exit non-zero even though every counter is present."""
    summary = _good_summary(submitted=7)    # one request unaccounted for
    log = tmp_path / "serve.log"
    log.write_text(_log(summary))
    problems = check_serve.check(_log(summary))
    assert any("conservation" in p for p in problems)
    assert check_serve.main(["check_serve.py", str(log)]) == 1


def test_check_serve_missing_ttft_fails(tmp_path):
    summary = _good_summary()
    del summary["ttft_ms"]
    assert any("TTFT" in p for p in check_serve.check(_log(summary)))


def test_check_serve_chaos_requires_fired_schedule(tmp_path):
    """--chaos: every scheduled fault class must actually have fired."""
    faults = {"schedule": [{"kind": "nan_logits", "step": 3, "slot": 0,
                            "stall_s": 0.0}],
              "fired": [], "pending": []}
    summary = _good_summary(faults=faults)
    fault_line = json.dumps({"fault_plan": {"seed": 0,
                                            "schedule": faults["schedule"]}})
    text = _log(summary, extra_rows=[fault_line])
    problems = check_serve.check(text, chaos=True)
    assert any("never fired" in p for p in problems)
    # same log with the fault fired is clean under --chaos
    faults_ok = dict(faults, fired=[{"kind": "nan_logits", "step": 3,
                                     "slot": 0, "stall_s": 0.0}])
    text_ok = _log(_good_summary(faults=faults_ok),
                   extra_rows=[fault_line])
    assert check_serve.check(text_ok, chaos=True) == []


def test_check_serve_chaos_failed_requests_fail(tmp_path):
    faults = {"schedule": [], "fired": [], "pending": []}
    outcomes = {"completed": 5, "timed_out": 0, "failed": 1,
                "rejected": 0, "evicted": 1, "retried": 0}
    summary = _good_summary(requests=5, faults=faults, outcomes=outcomes)
    fault_line = json.dumps({"fault_plan": {"seed": 0, "schedule": []}})
    problems = check_serve.check(_log(summary, extra_rows=[fault_line]),
                                 chaos=True)
    assert any("FAILED" in p for p in problems)


def test_check_serve_unreadable_log_fails(tmp_path):
    assert check_serve.main(["check_serve.py",
                             str(tmp_path / "nope.log")]) == 1


def _write_serving_json(tmp_path, **overrides):
    serving = {"batch": 4, "kv_dtype": "float32", "paging": None}
    serving.update(overrides)
    p = tmp_path / "serving.json"
    p.write_text(json.dumps(serving))
    return p


def test_check_serve_serving_json_happy_path(tmp_path, capsys):
    log = tmp_path / "serve.log"
    log.write_text(GOOD_LOG)
    sj = _write_serving_json(tmp_path)
    assert check_serve.main(["check_serve.py", str(log),
                             "--serving-json", str(sj)]) == 0
    out = capsys.readouterr().out
    assert "kv dtype float32" in out        # the ok line reports the dtype


def test_check_serve_serving_json_kv_dtype_mismatch_fails(tmp_path):
    """serving.json declaring int8 while the summary ran float32 means
    resume would rebuild the wrong cache layout — must fail loudly."""
    log = tmp_path / "serve.log"
    log.write_text(GOOD_LOG)
    sj = _write_serving_json(tmp_path, kv_dtype="int8")
    assert check_serve.main(["check_serve.py", str(log),
                             "--serving-json", str(sj)]) == 1
    problems = check_serve.check_serving_json(
        GOOD_LOG, json.loads(sj.read_text()))
    assert any("kv dtype mismatch" in p for p in problems)


def test_check_serve_serving_json_summary_without_kv_dtype_fails(tmp_path):
    summary = _good_summary()
    del summary["kv_dtype"]
    problems = check_serve.check_serving_json(
        _log(summary), {"batch": 4, "kv_dtype": "float32"})
    assert any("kv_dtype" in p for p in problems)


def test_check_serve_serving_json_paged_geometry_mismatch_fails(tmp_path):
    """The paged-pool geometry cross-check: serving.json and the
    summary's kv block disagreeing on num_pages must fail (previously
    only the pages themselves were checked, never the declared
    geometry)."""
    summary = _good_summary(kv={"page_size": 4, "num_pages": 8,
                                "pages_allocated": 0})
    serving = {"batch": 4, "kv_dtype": "float32",
               "paging": {"page_size": 4, "num_pages": 16}}
    problems = check_serve.check_serving_json(_log(summary), serving)
    assert any("geometry mismatch" in p and "num_pages" in p
               for p in problems)
    # and an agreeing geometry is clean
    serving["paging"]["num_pages"] = 8
    assert check_serve.check_serving_json(_log(summary), serving) == []


def test_check_serve_serving_json_paging_without_kv_block_fails(tmp_path):
    serving = {"batch": 4, "kv_dtype": "float32",
               "paging": {"page_size": 4, "num_pages": 8}}
    problems = check_serve.check_serving_json(GOOD_LOG, serving)
    assert any("no \"kv\" block" in p for p in problems)


def test_check_serve_serving_json_batch_mismatch_fails(tmp_path):
    problems = check_serve.check_serving_json(
        GOOD_LOG, {"batch": 2, "kv_dtype": "float32", "paging": None})
    assert any("batch mismatch" in p for p in problems)


# ---------------------------------------------------------------------------
# check_load
# ---------------------------------------------------------------------------

import check_load  # noqa: E402


def _good_mix(name="steady", kind="open"):
    """A minimal mix row that passes check_load: conservation holds and
    every measured number sits inside its recorded SLO budget."""
    return {
        "name": name, "kind": kind, "seed": 11, "batch": 2,
        "step_time_us": 1000.0,
        "trace": [{"rid": i, "arrival_s": 0.0, "prompt_len": 4,
                   "gen_len": 2, "think_s": 0.0} for i in range(4)],
        "submitted": 4,
        "outcomes": {"completed": 3, "timed_out": 0, "failed": 0,
                     "rejected": 1, "evicted": 0, "retried": 0},
        "conserved": True,
        "tokens_total": 9,
        "ttft_ms": {"p50": 1.0, "p99": 4.0, "n": 3},
        "per_token_ms": {"p50": 1.0, "p99": 1.0, "n": 3},
        "tok_per_s": 900.0,
        "queue_depth": [[0, 2, 4]], "queue_depth_max": 2,
        "predicted_vs_measured": {"predicted_step_us": 1000.0},
        "requests": [{"rid": i, "state": ("rejected" if i == 3
                                          else "completed"),
                      "retries": 0, "tokens": 0 if i == 3 else 3,
                      "ttft_ms": None if i == 3 else 1.0,
                      "per_token_ms": None if i == 3 else 1.0}
                     for i in range(4)],
        "slo": {"ttft_p99_ms": 30.0, "per_token_p99_ms": 3.0,
                "min_tok_per_s": 300.0,
                "budget_steps": {"ttft_p99_steps": 30,
                                 "per_token_p99_steps": 3,
                                 "min_tok_per_step_frac": 0.15}},
        "slo_ok": True, "slo_violations": [],
        "max_concurrent": 2, "paged": False, "sched": "fcfs",
        "wall": {"wall_s": 0.5},
    }


def _good_kv():
    """A minimal KV-memory utilization block for a paged row."""
    return {"page_size": 4, "num_pages": 8, "pages_allocated": 5,
            "pages_free": 3, "pages_reserved": 0, "tokens_resident": 18,
            "token_capacity": 20, "utilization": 0.9,
            "pages_peak": 5, "kv_ooms": 0}


def _good_paged_mix(name="heavytail"):
    mix = _good_mix(name)
    mix.update(paged=True, sched="spf", kv=_good_kv())
    return mix


def _good_paging():
    """A minimal paged-vs-contiguous comparison block: paged sustains 2x
    the contiguous concurrency at the same KV budget."""
    sub = {"batch": 2, "max_concurrent": 2, "generated": 20,
           "decode_steps": 10, "tok_per_s": 900.0,
           "outcomes": {"completed": 4, "failed": 0}}
    return {"mix": "heavytail", "page_size": 4, "max_len": 24,
            "budget_tokens": 48, "pool_pages": 12,
            "contiguous": sub,
            "paged": {**sub, "batch": 8, "max_concurrent": 4,
                      "pool_pages": 12, "kv": _good_kv()},
            "concurrency_ratio": 2.0, "ratio_floor": 1.5,
            "ratio_ok": True}


def _good_recovery():
    """A minimal recovery row that passes check_load: crash really
    crashed, resume really resumed, replay bounded by the snapshot
    interval, nothing lost across the two process lifetimes."""
    return {"requests": 6, "gen": 12, "crash_step": 9, "snapshot_every": 4,
            "crash_exit_ok": True, "resume_exit_ok": True,
            "snapshot_step": 8, "resume_step": 9, "replayed_steps": 1,
            "replayed_records": 8, "reprefilled_slots": 2,
            "submitted": 6,
            "outcomes": {"completed": 6, "timed_out": 0, "failed": 0,
                         "rejected": 0, "evicted": 0, "retried": 0},
            "conserved": True,
            "wall": {"resume_wall_s": 0.5, "prepare_s": 0.1,
                     "first_new_token_s": 0.2}}


@pytest.fixture
def good_serving_report():
    return {"schema": check_load.SCHEMA, "arch": "x", "backend": "cpu",
            "host": "x", "smoke": True,
            "mixes": {"steady": _good_mix("steady"),
                      "interactive": _good_mix("interactive", "closed"),
                      "heavytail": _good_paged_mix()},
            "recovery": _good_recovery(),
            "paging": _good_paging(),
            "slo_ok": True}


def _write_serving(tmp_path, report):
    p = tmp_path / "BENCH_serving.json"
    p.write_text(json.dumps(report))
    return p


def test_check_load_happy_path(tmp_path, good_serving_report):
    path = _write_serving(tmp_path, good_serving_report)
    assert check_load.check(path) == []
    assert check_load.main(["check_load.py", str(path)]) == 0


def test_check_load_repo_report_is_clean():
    """The committed BENCH_serving.json must satisfy the current gate."""
    assert check_load.check(REPO / "BENCH_serving.json") == []


def test_check_load_schema_regression_fails(tmp_path, good_serving_report):
    good_serving_report["schema"] = check_load.SCHEMA + 1
    path = _write_serving(tmp_path, good_serving_report)
    assert any("schema" in p for p in check_load.check(path))
    assert check_load.main(["check_load.py", str(path)]) == 1


def test_check_load_too_few_mixes_fails(tmp_path, good_serving_report):
    del good_serving_report["mixes"]["interactive"]
    del good_serving_report["mixes"]["heavytail"]
    path = _write_serving(tmp_path, good_serving_report)
    assert any("mixes" in p for p in check_load.check(path))
    assert check_load.main(["check_load.py", str(path)]) == 1


@pytest.mark.parametrize("missing", ["ttft_ms", "tok_per_s", "queue_depth",
                                     "predicted_vs_measured", "slo"])
def test_check_load_missing_mix_field_fails(tmp_path, good_serving_report,
                                            missing):
    del good_serving_report["mixes"]["steady"][missing]
    path = _write_serving(tmp_path, good_serving_report)
    problems = check_load.check(path)
    assert any(missing in p for p in problems)
    assert check_load.main(["check_load.py", str(path)]) == 1


def test_check_load_slo_violation_fails(tmp_path, good_serving_report):
    """A fabricated TTFT blowout must fail even though the report still
    *claims* slo_ok — the gate recomputes the budget comparisons."""
    good_serving_report["mixes"]["steady"]["ttft_ms"]["p99"] = 1e9
    path = _write_serving(tmp_path, good_serving_report)
    problems = check_load.check(path)
    assert any("SLO violated" in p for p in problems)
    assert any("inconsistent" in p for p in problems)
    assert check_load.main(["check_load.py", str(path)]) == 1


def test_check_load_throughput_floor_fails(tmp_path, good_serving_report):
    good_serving_report["mixes"]["steady"]["tok_per_s"] = 1.0
    path = _write_serving(tmp_path, good_serving_report)
    assert any("tok/s" in p for p in check_load.check(path))


def test_check_load_reported_violation_fails(tmp_path, good_serving_report):
    """slo_ok false in the report fails the gate even when the recomputed
    budgets look fine — the harness saw something at run time."""
    good_serving_report["mixes"]["steady"]["slo_ok"] = False
    good_serving_report["mixes"]["steady"]["slo_violations"] = ["x"]
    path = _write_serving(tmp_path, good_serving_report)
    assert any("slo_ok false" in p for p in check_load.check(path))
    assert check_load.main(["check_load.py", str(path)]) == 1


def test_check_load_conservation_violation_fails(tmp_path,
                                                 good_serving_report):
    mix = good_serving_report["mixes"]["steady"]
    mix["conserved"] = False
    mix["outcomes"]["completed"] = 2      # one request lost
    path = _write_serving(tmp_path, good_serving_report)
    problems = check_load.check(path)
    assert any("conservation" in p for p in problems)
    assert any("terminal outcomes" in p for p in problems)
    assert check_load.main(["check_load.py", str(path)]) == 1


def test_check_load_no_open_loop_mix_fails(tmp_path, good_serving_report):
    good_serving_report["mixes"] = {
        "a": _good_mix("a", "closed"), "b": _good_mix("b", "closed")}
    path = _write_serving(tmp_path, good_serving_report)
    assert any("open-loop" in p for p in check_load.check(path))


def test_check_load_unreadable_report_fails(tmp_path):
    path = tmp_path / "nope.json"
    assert any("unreadable" in p for p in check_load.check(path))
    path.write_text("{not json")
    assert check_load.main(["check_load.py", str(path)]) == 1


def test_check_load_missing_recovery_block_fails(tmp_path,
                                                 good_serving_report):
    """Schema 2 requires the crash-recovery row — a report without it
    means the injected-crash cycle never ran."""
    del good_serving_report["recovery"]
    path = _write_serving(tmp_path, good_serving_report)
    assert any("recovery" in p for p in check_load.check(path))
    assert check_load.main(["check_load.py", str(path)]) == 1


def test_check_load_recovery_no_crash_fails(tmp_path, good_serving_report):
    """crash_exit_ok false: the fault never killed the process, so the
    'recovery' that followed proved nothing."""
    good_serving_report["recovery"]["crash_exit_ok"] = False
    path = _write_serving(tmp_path, good_serving_report)
    assert any("never killed" in p for p in check_load.check(path))


def test_check_load_recovery_unbounded_replay_fails(tmp_path,
                                                    good_serving_report):
    """replayed_steps > snapshot_every: snapshots are not bounding the
    journal replay — the whole point of taking them."""
    rec = good_serving_report["recovery"]
    rec["replayed_steps"] = rec["snapshot_every"] + 1
    path = _write_serving(tmp_path, good_serving_report)
    assert any("not bounding" in p for p in check_load.check(path))
    assert check_load.main(["check_load.py", str(path)]) == 1


def test_check_load_recovery_lost_request_fails(tmp_path,
                                                good_serving_report):
    rec = good_serving_report["recovery"]
    rec["outcomes"]["completed"] -= 1      # one request vanished
    path = _write_serving(tmp_path, good_serving_report)
    assert any("lost or completed twice" in p
               for p in check_load.check(path))


def test_check_load_missing_paging_block_fails(tmp_path,
                                               good_serving_report):
    """Schema 3 requires the paged-vs-contiguous comparison — a report
    without it means the paging argument was never measured."""
    del good_serving_report["paging"]
    path = _write_serving(tmp_path, good_serving_report)
    assert any("paging: block missing" in p for p in check_load.check(path))
    assert check_load.main(["check_load.py", str(path)]) == 1


def test_check_load_no_paged_mix_fails(tmp_path, good_serving_report):
    del good_serving_report["mixes"]["heavytail"]
    good_serving_report["mixes"]["bursty"] = _good_mix("bursty")
    path = _write_serving(tmp_path, good_serving_report)
    assert any("no paged" in p for p in check_load.check(path))


def test_check_load_paging_ratio_below_floor_fails(tmp_path,
                                                   good_serving_report):
    """A fabricated ratio_ok with numbers below the floor must fail —
    the gate recomputes the ratio from the two sub-runs."""
    blk = good_serving_report["paging"]
    blk["paged"]["max_concurrent"] = 2          # 1.0x, floor is 1.5x
    blk["concurrency_ratio"] = 1.0
    path = _write_serving(tmp_path, good_serving_report)
    problems = check_load.check(path)
    assert any("sustains only" in p for p in problems)
    assert any("ratio_ok" in p for p in problems)
    assert check_load.main(["check_load.py", str(path)]) == 1


def test_check_load_paging_ratio_mismatch_fails(tmp_path,
                                                good_serving_report):
    good_serving_report["paging"]["concurrency_ratio"] = 9.0
    path = _write_serving(tmp_path, good_serving_report)
    assert any("recomputed" in p for p in check_load.check(path))


def test_check_load_paging_oom_fails(tmp_path, good_serving_report):
    good_serving_report["paging"]["paged"]["kv"]["kv_ooms"] = 3
    path = _write_serving(tmp_path, good_serving_report)
    assert any("allocator OOM" in p for p in check_load.check(path))


def test_check_load_paged_mix_missing_kv_fails(tmp_path,
                                               good_serving_report):
    del good_serving_report["mixes"]["heavytail"]["kv"]
    path = _write_serving(tmp_path, good_serving_report)
    assert any("kv block missing" in p for p in check_load.check(path))
    assert check_load.main(["check_load.py", str(path)]) == 1


def test_check_load_paged_mix_oom_or_failed_fails(tmp_path,
                                                  good_serving_report):
    """OOM backpressure must surface as evictions/rejections — FAILED
    requests or raw allocator OOMs in a paged mix fail the gate."""
    mix = good_serving_report["mixes"]["heavytail"]
    mix["kv"]["kv_ooms"] = 1
    mix["outcomes"]["failed"] = 1
    mix["outcomes"]["completed"] -= 1      # keep conservation intact
    for row in mix["requests"]:
        if row["state"] == "completed":
            row["state"] = "failed"
            break
    path = _write_serving(tmp_path, good_serving_report)
    problems = check_load.check(path)
    assert any("over-promising" in p for p in problems)
    assert any("FAILED requests" in p for p in problems)
    assert check_load.main(["check_load.py", str(path)]) == 1


# ---------------------------------------------------------------------------
# check_serve --recovery (crash-smoke gate)
# ---------------------------------------------------------------------------

def _journal_lines(rid=0, gen_len=2, tokens=(11, 12, 13),
                   terminal="completed", extra_states=()):
    """Journal records for one request: submit -> queued -> ... -> terminal
    with a token record per emitted token."""
    rows = [{"kind": "submit", "rid": rid, "gen_len": gen_len, "seq": 0},
            {"kind": "state", "rid": rid, "state": "queued", "seq": 1}]
    for st in extra_states:
        rows.append({"kind": "state", "rid": rid, "state": st})
    for i, t in enumerate(tokens):
        rows.append({"kind": "token", "rid": rid, "i": i, "tok": t})
    rows.append({"kind": "state", "rid": rid, "state": terminal})
    return [json.dumps(r) for r in rows]


def _write_journal(tmp_path, lines, name="journal.jsonl", torn_tail=None):
    text = "\n".join(lines) + "\n"
    if torn_tail is not None:
        text += torn_tail        # no trailing newline: the crash signature
    p = tmp_path / name
    p.write_text(text)
    return p


def _resume_log(recovery=None, **summary_overrides):
    rec = {"resumed": True, "snapshot_step": 8, "resume_step": 9,
           "replayed_steps": 1, "replayed_records": 8,
           "reprefilled_slots": 2}
    if recovery is not None:
        rec.update(recovery)
    summary = _good_summary(recovery=rec, **summary_overrides)
    return json.dumps(summary)      # --resume prints no serving_plan line


CRASH_LOG = json.dumps({"crash": {"step": 9, "msg": "injected crash"}})


def test_check_serve_recovery_happy_path(tmp_path):
    journal = _write_journal(tmp_path, _journal_lines())
    text = _resume_log()
    assert check_serve.check(text, require_plan=False) == []
    assert check_serve.check_recovery(
        text, crash_text=CRASH_LOG, journal=journal,
        snapshot_every=4) == []
    log = tmp_path / "resume.log"
    log.write_text(text)
    crash = tmp_path / "crash.log"
    crash.write_text(CRASH_LOG)
    assert check_serve.main(
        ["check_serve.py", str(log), "--recovery",
         "--crash-log", str(crash), "--journal", str(journal),
         "--snapshot-every", "4"]) == 0


def test_check_serve_recovery_requires_recovery_block():
    """A plain serve summary (no recovery block) must fail --recovery:
    the run did not actually resume anything."""
    text = json.dumps(_good_summary())
    problems = check_serve.check_recovery(text)
    assert any("no recovery block" in p for p in problems)


def test_check_serve_recovery_missing_crash_marker_fails(tmp_path):
    """A crash log without the {"crash": ...} marker means the fault
    never fired — the resume proved nothing."""
    problems = check_serve.check_recovery(
        _resume_log(), crash_text="no json here")
    assert any("crash" in p and "marker" in p for p in problems)


def test_check_serve_recovery_summary_in_crash_log_fails():
    """A summary line in the crash log means the process drained the
    queue and exited cleanly — it did NOT die mid-serve."""
    crash_text = CRASH_LOG + "\n" + json.dumps(_good_summary())
    problems = check_serve.check_recovery(_resume_log(),
                                          crash_text=crash_text)
    assert any("did NOT die" in p for p in problems)


def test_check_serve_recovery_unbounded_replay_fails():
    problems = check_serve.check_recovery(
        _resume_log(recovery={"replayed_steps": 9}), snapshot_every=4)
    assert any("not bounding" in p for p in problems)


def test_check_serve_recovery_duplicate_terminal_fails(tmp_path):
    """A rid that completes in both process lifetimes (journaled twice)
    is the double-serve bug the exactly-once fold exists to catch."""
    lines = _journal_lines()
    lines.append(json.dumps({"kind": "state", "rid": 0,
                             "state": "completed"}))
    journal = _write_journal(tmp_path, lines)
    problems = check_serve.check_recovery(_resume_log(), journal=journal)
    assert any("exactly once" in p for p in problems)


def test_check_serve_recovery_nonterminal_rid_fails(tmp_path):
    """A rid still DECODING at the end of the journal was lost across
    the crash — the resume never finished it."""
    lines = _journal_lines()[:-1]      # drop the terminal state record
    lines.append(json.dumps({"kind": "state", "rid": 0,
                             "state": "decoding"}))
    journal = _write_journal(tmp_path, lines)
    problems = check_serve.check_recovery(_resume_log(), journal=journal)
    assert any("non-terminal" in p for p in problems)


def test_check_serve_recovery_token_count_mismatch_fails(tmp_path):
    """A completed rid with fewer journaled tokens than gen_len+1 lost
    output across the crash."""
    journal = _write_journal(
        tmp_path, _journal_lines(gen_len=5, tokens=(11, 12, 13)))
    problems = check_serve.check_recovery(_resume_log(), journal=journal)
    assert any("journaled tokens" in p for p in problems)


def test_fold_journal_tolerates_torn_tail(tmp_path):
    """A truncated final line is the crash signature: dropped silently,
    never reported as corruption."""
    journal = _write_journal(tmp_path, _journal_lines(),
                             torn_tail='{"kind": "token", "rid": 0, "i"')
    reqs, problems = check_serve.fold_journal(journal)
    assert problems == []
    assert reqs[0]["state"] == "completed"
    assert reqs[0]["tokens"] == 3


def test_fold_journal_flags_interior_corruption(tmp_path):
    """Corruption anywhere but the final line is NOT a crash signature
    — it must be reported, not absorbed."""
    lines = _journal_lines()
    lines.insert(2, "{garbage interior line")
    journal = _write_journal(tmp_path, lines)
    reqs, problems = check_serve.fold_journal(journal)
    assert any("corrupt interior" in p for p in problems)


def test_fold_journal_requeue_resets_tokens(tmp_path):
    """Eviction requeue discards generated output: after a queued state
    record the token count restarts from zero and the retry's tokens
    overwrite by index without tripping the gap check."""
    lines = _journal_lines(gen_len=2, tokens=(11, 12),
                           terminal="completed")
    # splice a requeue + full retry before the terminal record
    retry = [{"kind": "state", "rid": 0, "state": "queued"},
             {"kind": "token", "rid": 0, "i": 0, "tok": 21},
             {"kind": "token", "rid": 0, "i": 1, "tok": 22},
             {"kind": "token", "rid": 0, "i": 2, "tok": 23}]
    lines[-1:-1] = [json.dumps(r) for r in retry]
    journal = _write_journal(tmp_path, lines)
    reqs, problems = check_serve.fold_journal(journal)
    assert problems == []
    assert reqs[0]["tokens"] == 3      # gen_len + 1 after the retry


def test_fold_journal_flags_token_index_gap(tmp_path):
    lines = _journal_lines(tokens=(11,))
    lines.insert(-1, json.dumps({"kind": "token", "rid": 0, "i": 5,
                                 "tok": 99}))
    journal = _write_journal(tmp_path, lines)
    reqs, problems = check_serve.fold_journal(journal)
    assert any("token index gap" in p for p in problems)
