"""MoE dispatch equivalences: dense oracle == grouped == sharded (a2a)."""

import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # minimal env: property tests skip, rest run
    from _hypothesis_stub import given, settings, st

from repro.models import moe
from repro.models.config import ModelConfig

KEY = jax.random.PRNGKey(3)


def _cfg(e=8, k=2, cf=16.0):
    return ModelConfig(name="t", family="moe", num_layers=1, d_model=32,
                       d_ff=64, vocab_size=64, num_heads=4, num_kv_heads=2,
                       num_experts=e, top_k=k, moe_d_ff=16,
                       capacity_factor=cf)


@pytest.mark.parametrize("e,k", [(4, 1), (8, 2), (16, 4)])
def test_grouped_matches_dense(e, k):
    cfg = _cfg(e, k)
    p = moe.moe_init(KEY, cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (64, cfg.d_model))
    od, auxd = moe.apply_dense(p, x, cfg)
    og, auxg = moe.apply_grouped(p, x, cfg)
    np.testing.assert_allclose(np.asarray(od), np.asarray(og), rtol=1e-4,
                               atol=1e-5)
    assert abs(float(auxd - auxg)) < 1e-5


@settings(max_examples=20, deadline=None)
@given(t=st.integers(8, 128), seed=st.integers(0, 1000))
def test_grouped_capacity_drops_are_bounded(t, seed):
    """With cf=1.0 drops may occur but outputs stay finite and the kept
    contributions match dense for tokens that were not dropped."""
    cfg = _cfg(8, 2, cf=1.0)
    p = moe.moe_init(KEY, cfg)
    x = jax.random.normal(jax.random.PRNGKey(seed), (t, cfg.d_model))
    og, _ = moe.apply_grouped(p, x, cfg)
    assert np.all(np.isfinite(np.asarray(og)))


def test_router_topk_normalized():
    cfg = _cfg(8, 2)
    p = moe.moe_init(KEY, cfg)
    x = jax.random.normal(KEY, (32, cfg.d_model))
    idx, w, aux = moe.route(p, x, cfg)
    np.testing.assert_allclose(np.asarray(jnp.sum(w, -1)), 1.0, rtol=1e-5)
    assert idx.shape == (32, 2)
    assert float(aux) >= 1.0 - 1e-3  # E*sum(f*p) >= 1 at optimum


SUBPROCESS_SNIPPET = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from repro.launch.mesh import axis_types_kwargs, set_mesh
from repro.models import moe
from repro.models.config import ModelConfig
from repro.parallel import sharding as shd

cfg = ModelConfig(name="t", family="moe", num_layers=1, d_model=32, d_ff=64,
                  vocab_size=64, num_heads=4, num_kv_heads=2,
                  num_experts=8, top_k=2, moe_d_ff=16, capacity_factor=8.0)
mesh = jax.sharding.Mesh(np.array(jax.devices()).reshape(2, 4),
                         ("data", "model"), **axis_types_kwargs(2))
rules = shd.single_pod_rules().with_sizes(mesh)
p = moe.moe_init(jax.random.PRNGKey(0), cfg)
x = jax.random.normal(jax.random.PRNGKey(1), (4, 16, 32))
with set_mesh(mesh), shd.use_rules(rules):
    out, _ = jax.jit(lambda p, x: moe.apply_sharded(p, x, cfg))(p, x)
ref, _ = moe.apply_grouped(p, x.reshape(-1, 32), cfg)
err = float(jnp.max(jnp.abs(out - ref.reshape(4, 16, 32))))
assert err < 1e-4, err
print("OK", err)
"""


def test_sharded_matches_grouped_on_8_device_mesh():
    """Runs in a subprocess so the 8-device XLA flag never leaks into this
    test session (per the brief: tests see 1 device)."""
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    out = subprocess.run([sys.executable, "-c", SUBPROCESS_SNIPPET],
                         capture_output=True, text=True, env=env,
                         cwd=os.path.dirname(os.path.dirname(__file__)),
                         timeout=300)
    assert out.returncode == 0, out.stderr[-2000:]
    assert "OK" in out.stdout
