"""Request lifecycle state machine: legal/illegal edges, bounded
admission backpressure, retry-with-backoff on the step virtual clock,
deadline sweeps on an injectable wall clock, and the conservation
invariant (every submitted request ends in exactly one terminal state).
Pure-python — no jax, no server."""

import numpy as np
import pytest

from repro.runtime.lifecycle import (Lifecycle, State, TransitionError,
                                     submit_all)


def _lc(**kw):
    kw.setdefault("clock", lambda: 0.0)
    return Lifecycle(**kw)


def _reqs(n, gen=4):
    return [(rid, np.arange(3, dtype=np.int32), gen) for rid in range(n)]


# ---------------------------------------------------------------------------
# transitions
# ---------------------------------------------------------------------------

def test_happy_path_transitions():
    lc = _lc()
    req = lc.submit(0, [1, 2], 4)
    assert req.state is State.QUEUED
    assert lc.pop_ready(0) is req
    lc.transition(req, State.PREFILLING, 0)
    lc.transition(req, State.DECODING, 0)
    lc.transition(req, State.COMPLETED, 3)
    assert [s for s, _ in req.history] == [
        State.QUEUED, State.PREFILLING, State.DECODING, State.COMPLETED]
    assert lc.conserved() and lc.counters()["completed"] == 1


@pytest.mark.parametrize("start,bad", [
    (State.QUEUED, State.COMPLETED),       # must prefill first
    (State.QUEUED, State.DECODING),
    (State.PREFILLING, State.COMPLETED),   # must decode first
    (State.DECODING, State.PREFILLING),    # no going back
    (State.COMPLETED, State.DECODING),     # terminal states have no exits
    (State.REJECTED, State.QUEUED),
    (State.FAILED, State.QUEUED),
])
def test_illegal_edges_raise(start, bad):
    lc = _lc()
    req = lc.submit(0, [1], 1)
    req.state = start
    with pytest.raises(TransitionError, match="illegal transition"):
        lc.transition(req, bad, 0)


def test_duplicate_rid_rejected():
    lc = _lc()
    lc.submit(0, [1], 1)
    with pytest.raises(ValueError, match="duplicate"):
        lc.submit(0, [1], 1)


# ---------------------------------------------------------------------------
# bounded admission
# ---------------------------------------------------------------------------

def test_queue_limit_rejects_overflow():
    lc = _lc(queue_limit=2)
    submit_all(lc, _reqs(5))
    states = [lc.requests[r].state for r in range(5)]
    assert states[:2] == [State.QUEUED, State.QUEUED]
    assert states[2:] == [State.REJECTED] * 3
    assert lc.counters()["rejected"] == 3
    # a rejected request is terminal immediately: it never enters the queue
    assert lc.pop_ready(0).rid == 0 and lc.pop_ready(0).rid == 1
    assert lc.pop_ready(0) is None


def test_retries_bypass_the_admission_bound():
    """An admitted request is owed a terminal answer: eviction must requeue
    it even when the queue sits at its limit."""
    lc = _lc(queue_limit=1, max_retries=1)
    req = lc.submit(0, [1], 1)
    lc.pop_ready(0)
    lc.transition(req, State.PREFILLING, 0)
    lc.submit(1, [1], 1)            # fills the bound again
    assert lc.submit(2, [1], 1).state is State.REJECTED
    assert lc.evict(req, 0) is True
    assert req.state is State.QUEUED and len(lc._queue) == 2


def test_zero_limit_is_unbounded():
    lc = _lc(queue_limit=0)
    submit_all(lc, _reqs(50))
    assert lc.counters()["rejected"] == 0


# ---------------------------------------------------------------------------
# retry with backoff
# ---------------------------------------------------------------------------

def test_evict_requeues_with_exponential_step_backoff():
    lc = _lc(max_retries=3, backoff_steps=4)
    req = lc.submit(0, [1], 4)
    for retry, expected_wait in enumerate([4, 8, 16], start=1):
        lc.pop_ready(req.not_before_step)
        lc.transition(req, State.PREFILLING, 10)
        assert lc.evict(req, 10) is True
        assert req.retries == retry
        assert req.not_before_step == 10 + expected_wait
        # not eligible before the backoff elapses, eligible exactly at it
        assert lc.pop_ready(req.not_before_step - 1) is None
        assert lc.next_eligible_step() == req.not_before_step
    # retry budget spent: the fourth eviction is FAILED, not requeued
    lc.pop_ready(req.not_before_step)
    lc.transition(req, State.PREFILLING, 40)
    assert lc.evict(req, 40) is False
    assert req.state is State.FAILED
    assert lc.conserved()
    assert lc.counters() == {"completed": 0, "timed_out": 0, "failed": 1,
                             "rejected": 0, "evicted": 4, "retried": 3}


def test_evict_discards_partial_tokens():
    """A retried request starts over — stale tokens would break the
    retry-reproduces-solo-decode guarantee."""
    lc = _lc()
    req = lc.submit(0, [1], 4)
    lc.pop_ready(0)
    lc.transition(req, State.PREFILLING, 0)
    lc.transition(req, State.DECODING, 0)
    req.tokens = [5, 6, 7]
    lc.evict(req, 3)
    assert req.tokens == []


def test_pop_ready_fcfs_among_eligible():
    """Backoff must not starve: an in-backoff head of queue is skipped,
    but order is preserved among the eligible."""
    lc = _lc()
    a = lc.submit(0, [1], 1)
    b = lc.submit(1, [1], 1)
    a.not_before_step = 10
    assert lc.pop_ready(5) is b      # a is in backoff, b is eligible
    assert lc.pop_ready(5) is None
    assert lc.pop_ready(10) is a


# ---------------------------------------------------------------------------
# deadlines (injectable wall clock)
# ---------------------------------------------------------------------------

class FakeClock:
    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t


def test_total_deadline_times_out_open_request():
    clock = FakeClock()
    lc = _lc(clock=clock)
    req = lc.submit(0, [1], 4, deadline_s=1.0)
    lc.pop_ready(0)
    lc.transition(req, State.PREFILLING, 0)
    lc.transition(req, State.DECODING, 0)
    clock.t = 0.5
    assert lc.check_deadlines(1) == []
    clock.t = 1.5
    assert lc.check_deadlines(2) == [req]
    assert req.state is State.TIMED_OUT
    assert lc.check_deadlines(3) == []       # terminal: swept once only
    assert lc.conserved()


def test_ttft_deadline_only_until_first_token():
    clock = FakeClock()
    lc = _lc(clock=clock)
    fast = lc.submit(0, [1], 4, ttft_deadline_s=1.0)
    slow = lc.submit(1, [1], 4, ttft_deadline_s=1.0)
    for req in (fast, slow):
        lc.pop_ready(0)
        lc.transition(req, State.PREFILLING, 0)
    clock.t = 0.4
    lc.record_first_token(fast)              # fast met its TTFT
    lc.transition(fast, State.DECODING, 0)
    clock.t = 2.0
    assert lc.check_deadlines(1) == [slow]   # fast keeps decoding
    assert fast.state is State.DECODING
    assert fast.ttft_ms == pytest.approx(400.0)


def test_deadline_sweep_drops_queued_request_from_queue():
    clock = FakeClock()
    lc = _lc(clock=clock)
    lc.submit(0, [1], 4, deadline_s=1.0)
    clock.t = 2.0
    assert len(lc.check_deadlines(0)) == 1
    assert lc.pop_ready(0) is None and lc.conserved()


# ---------------------------------------------------------------------------
# accounting
# ---------------------------------------------------------------------------

def test_conservation_detects_leaked_request():
    lc = _lc()
    submit_all(lc, _reqs(3))
    for rid in range(2):
        req = lc.pop_ready(0)
        lc.transition(req, State.PREFILLING, 0)
        lc.transition(req, State.DECODING, 0)
        lc.transition(req, State.COMPLETED, 1)
    assert not lc.conserved()               # rid 2 still open
    assert lc.open_count() == 1
    req = lc.pop_ready(0)
    lc.transition(req, State.PREFILLING, 2)
    lc.transition(req, State.DECODING, 2)
    lc.transition(req, State.COMPLETED, 3)
    assert lc.conserved() and lc.open_count() == 0


def test_ttft_percentiles():
    clock = FakeClock()
    lc = _lc(clock=clock)
    for rid in range(4):
        req = lc.submit(rid, [1], 1)
        clock.t = 0.01 * (rid + 1)
        lc.record_first_token(req)
        clock.t = 0.0
    p = lc.ttft_percentiles()
    assert p["n"] == 4 and p["p50"] == pytest.approx(25.0)
    assert p["p99"] <= 40.0
    assert _lc().ttft_percentiles() == {"p50": None, "p99": None, "n": 0}


def test_outcome_trace_is_rid_ordered_and_json_shaped():
    import json
    lc = _lc(queue_limit=1)
    submit_all(lc, _reqs(2))
    trace = lc.outcome_trace()
    assert [row["rid"] for row in trace] == [0, 1]
    assert trace[1]["state"] == "rejected"
    json.dumps(trace)


def test_table_names_every_request_and_history():
    lc = _lc(max_retries=0)
    req = lc.submit(7, [1], 1)
    lc.pop_ready(0)
    lc.transition(req, State.PREFILLING, 2)
    lc.evict(req, 3)
    table = lc.table()
    assert "7" in table and "failed" in table
    assert "prefilling@2" in table and "evicted@3" in table
