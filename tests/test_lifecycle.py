"""Request lifecycle state machine: legal/illegal edges, bounded
admission backpressure, retry-with-backoff on the step virtual clock,
deadline sweeps on an injectable wall clock, and the conservation
invariant (every submitted request ends in exactly one terminal state).
Pure-python — no jax, no server."""

import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # minimal env: property tests skip, rest run
    from _hypothesis_stub import given, settings, st

from repro.runtime.lifecycle import (_ALLOWED, Lifecycle, State,
                                     TransitionError, submit_all)


def _lc(**kw):
    kw.setdefault("clock", lambda: 0.0)
    return Lifecycle(**kw)


def _reqs(n, gen=4):
    return [(rid, np.arange(3, dtype=np.int32), gen) for rid in range(n)]


# ---------------------------------------------------------------------------
# transitions
# ---------------------------------------------------------------------------

def test_happy_path_transitions():
    lc = _lc()
    req = lc.submit(0, [1, 2], 4)
    assert req.state is State.QUEUED
    assert lc.pop_ready(0) is req
    lc.transition(req, State.PREFILLING, 0)
    lc.transition(req, State.DECODING, 0)
    lc.transition(req, State.COMPLETED, 3)
    assert [s for s, _ in req.history] == [
        State.QUEUED, State.PREFILLING, State.DECODING, State.COMPLETED]
    assert lc.conserved() and lc.counters()["completed"] == 1


@pytest.mark.parametrize("start,bad", [
    (State.QUEUED, State.COMPLETED),       # must prefill first
    (State.QUEUED, State.DECODING),
    (State.PREFILLING, State.COMPLETED),   # must decode first
    (State.DECODING, State.PREFILLING),    # no going back
    (State.COMPLETED, State.DECODING),     # terminal states have no exits
    (State.REJECTED, State.QUEUED),
    (State.FAILED, State.QUEUED),
])
def test_illegal_edges_raise(start, bad):
    lc = _lc()
    req = lc.submit(0, [1], 1)
    req.state = start
    with pytest.raises(TransitionError, match="illegal transition"):
        lc.transition(req, bad, 0)


def test_duplicate_rid_rejected():
    lc = _lc()
    lc.submit(0, [1], 1)
    with pytest.raises(ValueError, match="duplicate"):
        lc.submit(0, [1], 1)


# ---------------------------------------------------------------------------
# bounded admission
# ---------------------------------------------------------------------------

def test_queue_limit_rejects_overflow():
    lc = _lc(queue_limit=2)
    submit_all(lc, _reqs(5))
    states = [lc.requests[r].state for r in range(5)]
    assert states[:2] == [State.QUEUED, State.QUEUED]
    assert states[2:] == [State.REJECTED] * 3
    assert lc.counters()["rejected"] == 3
    # a rejected request is terminal immediately: it never enters the queue
    assert lc.pop_ready(0).rid == 0 and lc.pop_ready(0).rid == 1
    assert lc.pop_ready(0) is None


def test_retries_bypass_the_admission_bound():
    """An admitted request is owed a terminal answer: eviction must requeue
    it even when the queue sits at its limit."""
    lc = _lc(queue_limit=1, max_retries=1)
    req = lc.submit(0, [1], 1)
    lc.pop_ready(0)
    lc.transition(req, State.PREFILLING, 0)
    lc.submit(1, [1], 1)            # fills the bound again
    assert lc.submit(2, [1], 1).state is State.REJECTED
    assert lc.evict(req, 0) is True
    assert req.state is State.QUEUED and len(lc._queue) == 2


def test_zero_limit_is_unbounded():
    lc = _lc(queue_limit=0)
    submit_all(lc, _reqs(50))
    assert lc.counters()["rejected"] == 0


# ---------------------------------------------------------------------------
# retry with backoff
# ---------------------------------------------------------------------------

def test_evict_requeues_with_exponential_step_backoff():
    lc = _lc(max_retries=3, backoff_steps=4)
    req = lc.submit(0, [1], 4)
    for retry, expected_wait in enumerate([4, 8, 16], start=1):
        lc.pop_ready(req.not_before_step)
        lc.transition(req, State.PREFILLING, 10)
        assert lc.evict(req, 10) is True
        assert req.retries == retry
        assert req.not_before_step == 10 + expected_wait
        # not eligible before the backoff elapses, eligible exactly at it
        assert lc.pop_ready(req.not_before_step - 1) is None
        assert lc.next_eligible_step() == req.not_before_step
    # retry budget spent: the fourth eviction is FAILED, not requeued
    lc.pop_ready(req.not_before_step)
    lc.transition(req, State.PREFILLING, 40)
    assert lc.evict(req, 40) is False
    assert req.state is State.FAILED
    assert lc.conserved()
    assert lc.counters() == {"completed": 0, "timed_out": 0, "failed": 1,
                             "rejected": 0, "evicted": 4, "retried": 3}


def test_evict_discards_partial_tokens():
    """A retried request starts over — stale tokens would break the
    retry-reproduces-solo-decode guarantee."""
    lc = _lc()
    req = lc.submit(0, [1], 4)
    lc.pop_ready(0)
    lc.transition(req, State.PREFILLING, 0)
    lc.transition(req, State.DECODING, 0)
    req.tokens = [5, 6, 7]
    lc.evict(req, 3)
    assert req.tokens == []


def test_pop_ready_fcfs_among_eligible():
    """Backoff must not starve: an in-backoff head of queue is skipped,
    but order is preserved among the eligible."""
    lc = _lc()
    a = lc.submit(0, [1], 1)
    b = lc.submit(1, [1], 1)
    a.not_before_step = 10
    assert lc.pop_ready(5) is b      # a is in backoff, b is eligible
    assert lc.pop_ready(5) is None
    assert lc.pop_ready(10) is a


# ---------------------------------------------------------------------------
# deadlines (injectable wall clock)
# ---------------------------------------------------------------------------

class FakeClock:
    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t


def test_total_deadline_times_out_open_request():
    clock = FakeClock()
    lc = _lc(clock=clock)
    req = lc.submit(0, [1], 4, deadline_s=1.0)
    lc.pop_ready(0)
    lc.transition(req, State.PREFILLING, 0)
    lc.transition(req, State.DECODING, 0)
    clock.t = 0.5
    assert lc.check_deadlines(1) == []
    clock.t = 1.5
    assert lc.check_deadlines(2) == [req]
    assert req.state is State.TIMED_OUT
    assert lc.check_deadlines(3) == []       # terminal: swept once only
    assert lc.conserved()


def test_ttft_deadline_only_until_first_token():
    clock = FakeClock()
    lc = _lc(clock=clock)
    fast = lc.submit(0, [1], 4, ttft_deadline_s=1.0)
    slow = lc.submit(1, [1], 4, ttft_deadline_s=1.0)
    for req in (fast, slow):
        lc.pop_ready(0)
        lc.transition(req, State.PREFILLING, 0)
    clock.t = 0.4
    lc.record_first_token(fast)              # fast met its TTFT
    lc.transition(fast, State.DECODING, 0)
    clock.t = 2.0
    assert lc.check_deadlines(1) == [slow]   # fast keeps decoding
    assert fast.state is State.DECODING
    assert fast.ttft_ms == pytest.approx(400.0)


def test_deadline_sweep_drops_queued_request_from_queue():
    clock = FakeClock()
    lc = _lc(clock=clock)
    lc.submit(0, [1], 4, deadline_s=1.0)
    clock.t = 2.0
    assert len(lc.check_deadlines(0)) == 1
    assert lc.pop_ready(0) is None and lc.conserved()


# ---------------------------------------------------------------------------
# accounting
# ---------------------------------------------------------------------------

def test_conservation_detects_leaked_request():
    lc = _lc()
    submit_all(lc, _reqs(3))
    for rid in range(2):
        req = lc.pop_ready(0)
        lc.transition(req, State.PREFILLING, 0)
        lc.transition(req, State.DECODING, 0)
        lc.transition(req, State.COMPLETED, 1)
    assert not lc.conserved()               # rid 2 still open
    assert lc.open_count() == 1
    req = lc.pop_ready(0)
    lc.transition(req, State.PREFILLING, 2)
    lc.transition(req, State.DECODING, 2)
    lc.transition(req, State.COMPLETED, 3)
    assert lc.conserved() and lc.open_count() == 0


def test_ttft_percentiles():
    clock = FakeClock()
    lc = _lc(clock=clock)
    for rid in range(4):
        req = lc.submit(rid, [1], 1)
        clock.t = 0.01 * (rid + 1)
        lc.record_first_token(req)
        clock.t = 0.0
    p = lc.ttft_percentiles()
    assert p["n"] == 4 and p["p50"] == pytest.approx(25.0)
    assert p["p99"] <= 40.0
    assert _lc().ttft_percentiles() == {"p50": None, "p99": None, "n": 0}


def test_outcome_trace_is_rid_ordered_and_json_shaped():
    import json
    lc = _lc(queue_limit=1)
    submit_all(lc, _reqs(2))
    trace = lc.outcome_trace()
    assert [row["rid"] for row in trace] == [0, 1]
    assert trace[1]["state"] == "rejected"
    json.dumps(trace)


def test_finish_t_set_on_every_terminal_entry():
    clock = FakeClock()
    lc = _lc(clock=clock, queue_limit=1)
    done = lc.submit(0, [1], 2)
    rejected = lc.submit(1, [1], 2)          # over the bound: terminal now
    assert rejected.finish_t == rejected.submit_t
    lc.pop_ready(0)
    lc.transition(done, State.PREFILLING, 0)
    clock.t = 0.1
    lc.record_first_token(done)
    lc.transition(done, State.DECODING, 0)
    done.tokens = [1, 2, 3]
    clock.t = 0.3
    lc.transition(done, State.COMPLETED, 2)
    assert done.finish_t == pytest.approx(0.3)
    # mean decode latency per post-first token: (0.3 - 0.1) s / 2 tokens
    assert done.per_token_ms == pytest.approx(100.0)
    p = lc.per_token_percentiles()
    assert p["n"] == 1 and p["p50"] == pytest.approx(100.0)


def test_table_names_every_request_and_history():
    lc = _lc(max_retries=0)
    req = lc.submit(7, [1], 1)
    lc.pop_ready(0)
    lc.transition(req, State.PREFILLING, 2)
    lc.evict(req, 3)
    table = lc.table()
    assert "7" in table and "failed" in table
    assert "prefilling@2" in table and "evicted@3" in table

# ---------------------------------------------------------------------------
# property-based: conservation under randomized schedules
# ---------------------------------------------------------------------------

def _random_drive(seed: int, n: int, queue_limit: int,
                  max_retries: int) -> Lifecycle:
    """A seeded adversarial serve loop over the lifecycle's public
    surface: random arrival steps, random TTFT/total deadlines, random
    prefill/decode faults (evictions), two decode slots.  Pure python —
    the property tests assert the *tracker's* invariants, not the
    server's."""
    rng = np.random.default_rng(seed)
    clock = FakeClock()
    lc = Lifecycle(queue_limit=queue_limit, max_retries=max_retries,
                   backoff_steps=2, clock=clock)
    arrivals = sorted(int(a) for a in rng.integers(0, 30, size=n))
    slots: dict[int, int] = {}               # rid -> tokens remaining
    next_rid = 0
    for step in range(500):
        clock.t = step * 0.1
        while next_rid < n and arrivals[next_rid] <= step:
            kw = {}
            if rng.random() < 0.3:
                kw["ttft_deadline_s"] = float(rng.uniform(0.1, 2.0))
            if rng.random() < 0.3:
                kw["deadline_s"] = float(rng.uniform(0.5, 4.0))
            lc.submit(next_rid, [1, 2], int(rng.integers(1, 6)), **kw)
            next_rid += 1
        while len(slots) < 2:                # fill
            req = lc.pop_ready(step)
            if req is None:
                break
            lc.transition(req, State.PREFILLING, step)
            if rng.random() < 0.15:          # prefill fault
                lc.evict(req, step)
                continue
            req.tokens.append(0)
            lc.record_first_token(req)
            lc.transition(req, State.DECODING, step)
            slots[req.rid] = req.gen_len
        for req in lc.check_deadlines(step):
            slots.pop(req.rid, None)
        for rid in list(slots):              # decode
            req = lc.requests[rid]
            if rng.random() < 0.05:          # decode fault
                del slots[rid]
                lc.evict(req, step)
                continue
            req.tokens.append(0)
            slots[rid] -= 1
            if slots[rid] <= 0:
                del slots[rid]
                lc.transition(req, State.COMPLETED, step)
        if next_rid >= n and lc.open_count() == 0:
            break
    return lc


def _assert_invariants(lc: Lifecycle, n: int) -> None:
    assert lc.submitted == n
    assert lc.open_count() == 0, lc.table()  # the schedule always drains
    assert lc.conserved(), lc.table()
    c = lc.counters()
    assert (c["completed"] + c["timed_out"] + c["failed"]
            + c["rejected"]) == n
    for req in lc.requests.values():
        states = [s for s, _ in req.history]
        # no request skips a state: the recorded history starts at an
        # initial state and walks only legal machine edges
        assert states[0] in (State.QUEUED, State.REJECTED)
        for a, b in zip(states, states[1:]):
            assert b in _ALLOWED.get(a, frozenset()), (
                f"rid {req.rid}: illegal recorded edge "
                f"{a.value} -> {b.value}")
        assert req.state is states[-1] and req.state in (
            State.COMPLETED, State.TIMED_OUT, State.FAILED, State.REJECTED)
        assert req.finish_t is not None      # terminal => finish stamped
        if req.state is State.COMPLETED:
            assert len(req.tokens) == req.gen_len + 1
            assert req.first_token_t is not None


@settings(max_examples=30, deadline=None)
@given(seed=st.integers(0, 2**32 - 1), n=st.integers(1, 12),
       queue_limit=st.integers(0, 3), max_retries=st.integers(0, 3))
def test_property_conservation_under_random_schedules(seed, n, queue_limit,
                                                      max_retries):
    """For any seeded arrival/deadline/fault schedule: every submitted
    request drains to exactly one terminal state through legal edges."""
    _assert_invariants(_random_drive(seed, n, queue_limit, max_retries), n)


@pytest.mark.parametrize("seed", range(10))
def test_conservation_under_random_schedules_seeded(seed):
    """Pinned-seed slice of the property above, so the invariant stays
    covered in environments without hypothesis."""
    _assert_invariants(_random_drive(seed, n=10, queue_limit=2,
                                     max_retries=2), 10)
