"""Autotuning kernel engine: cache behavior, deterministic ranking, and
numerical equality of the tuned kernels against the pure-jnp oracles
(interpret mode on CPU)."""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import dse, tiling
from repro.kernels import autotune
from repro.kernels.matmul.ref import matmul_ref
from repro.kernels.spmv import pack_csr, spmv
from repro.kernels.spmv.ref import spmv_ell_ref

KEY = jax.random.PRNGKey(0)


@pytest.fixture
def cache(tmp_path, monkeypatch):
    """Isolated on-disk cache; env override is what production uses too."""
    path = tmp_path / "autotune.json"
    monkeypatch.setenv(autotune.CACHE_ENV, str(path))
    return autotune.TuneCache(path)


def _random_csr(rng, m, n, density):
    dense = (rng.random((m, n)) < density) * rng.standard_normal((m, n))
    nnz_per_row = (dense != 0).sum(1)
    indptr = np.concatenate([[0], np.cumsum(nnz_per_row)]).astype(np.int32)
    cols = (np.concatenate([np.nonzero(r)[0] for r in dense]).astype(np.int32)
            if nnz_per_row.sum() else np.zeros(0, np.int32))
    vals = dense[dense != 0].astype(np.float32)
    return dense, indptr, cols, vals


# ---------------------------------------------------------------------------
# candidate ranking
# ---------------------------------------------------------------------------

def test_matmul_ranking_is_deterministic():
    r1 = dse.rank_matmul_tiles(1024, 1024, 1024, top=8)
    r2 = dse.rank_matmul_tiles(1024, 1024, 1024, top=8)
    assert [c.detail["tile"] for c in r1] == [c.detail["tile"] for c in r2]
    scores = [c.score for c in r1]
    assert scores == sorted(scores)
    assert len(r1) >= 1


def test_matmul_ranking_contains_eq2_seed_or_better():
    """The top candidate is never worse than the closed-form eq.2 tile."""
    from repro.core import cost_model
    m = n = k = 8192
    seed = tiling.solve_tpu(m=m, n=n, k=k)
    seed_t = cost_model.matmul_time_model(m, n, k, seed)["time_s"]
    best = dse.rank_matmul_tiles(m, n, k, top=1)[0]
    assert best.score <= seed_t * (1 + 1e-12)


def test_spmv_ranking_deterministic_and_feasible():
    rng = np.random.default_rng(3)
    dense, indptr, cols, vals = _random_csr(rng, 128, 400, 0.1)
    mat = pack_csr(indptr, cols, vals, (128, 400), scheme="sorted")
    r1 = autotune.rank_spmv_configs(mat)
    r2 = autotune.rank_spmv_configs(mat)
    assert r1 == r2 and len(r1) > 0
    assert [r[0] for r in r1] == sorted(r[0] for r in r1)
    # every candidate's block_rows divides the packed row count
    rows = mat.cols.shape[0]
    assert all(rows % br == 0 for _, br, _, _ in r1)


def test_spmv_ranking_uses_balance_metric():
    """The waste column is exactly the active/fetched metric at that block
    size — the loadbalance input the tuner ranks with."""
    rng = np.random.default_rng(4)
    dense, indptr, cols, vals = _random_csr(rng, 64, 200, 0.2)
    mat = pack_csr(indptr, cols, vals, (64, 200), scheme="sorted")
    for _, br, _, waste in autotune.rank_spmv_configs(mat):
        assert waste == pytest.approx(mat.sliced_waste(block_rows=br))


# ---------------------------------------------------------------------------
# cache hit/miss
# ---------------------------------------------------------------------------

def test_matmul_cache_miss_then_hit(cache):
    p1 = autotune.tune_matmul(192, 128, 160, cache=cache, measure_k=0)
    assert p1.source == "model"
    assert cache.misses == 1 and cache.hits == 0
    p2 = autotune.tune_matmul(192, 128, 160, cache=cache, measure_k=0)
    assert p2.source == "cache"
    assert p2.tile == p1.tile
    assert cache.hits == 1
    # a fresh cache object re-reads the same file (persistence)
    p3 = autotune.tune_matmul(192, 128, 160, measure_k=0,
                              cache=autotune.TuneCache(cache.path))
    assert p3.source == "cache" and p3.tile == p1.tile


def test_model_entry_upgraded_by_measuring_caller(cache):
    """An analytic-only entry (e.g. serve startup, measure_k=0) must not
    suppress measurement forever: a measuring caller re-tunes and the
    measured result replaces the entry."""
    p1 = autotune.tune_matmul(128, 128, 128, cache=cache, measure_k=0)
    assert p1.source == "model" and p1.measured_us is None
    p2 = autotune.tune_matmul(128, 128, 128, cache=cache, measure_k=2)
    assert p2.source == "measured" and p2.measured_us is not None
    p3 = autotune.tune_matmul(128, 128, 128, cache=cache, measure_k=2)
    assert p3.source == "cache" and p3.measured_us is not None


def test_cache_key_separates_shapes_and_dtypes(cache):
    autotune.tune_matmul(128, 128, 128, jnp.float32, cache=cache,
                         measure_k=0)
    p = autotune.tune_matmul(128, 128, 128, jnp.bfloat16, cache=cache,
                             measure_k=0)
    assert p.source != "cache"      # different dtype, different key
    p = autotune.tune_matmul(128, 128, 256, jnp.float32, cache=cache,
                             measure_k=0)
    assert p.source != "cache"      # different shape, different key


def test_env_var_routes_default_cache(cache):
    # get_cache() must honor the monkeypatched env var from the fixture
    assert autotune.get_cache().path == cache.path


def test_corrupt_cache_file_is_ignored(cache):
    cache.path.write_text("{not json")
    p = autotune.tune_matmul(128, 128, 128, cache=autotune.TuneCache(
        cache.path), measure_k=0)
    assert p.source == "model"


def test_spmv_cache_miss_then_hit(cache):
    rng = np.random.default_rng(5)
    dense, indptr, cols, vals = _random_csr(rng, 64, 300, 0.1)
    mat = pack_csr(indptr, cols, vals, (64, 300))
    p1 = autotune.tune_spmv(mat, cache=cache, measure_k=0)
    assert p1.source == "model"
    p2 = autotune.tune_spmv(mat, cache=cache, measure_k=0)
    assert p2.source == "cache"
    assert (p2.block_rows, p2.block_cols) == (p1.block_rows, p1.block_cols)


def test_spmv_key_distinguishes_packings(cache):
    """Different packings of the SAME matrix have different fetch behavior
    (the balance metric differs); they must not share a cache entry."""
    rng = np.random.default_rng(9)
    dense, indptr, cols, vals = _random_csr(rng, 200, 300, 0.1)
    sorted_mat = pack_csr(indptr, cols, vals, (200, 300), scheme="sorted")
    rr_mat = pack_csr(indptr, cols, vals, (200, 300), scheme="round_robin")
    assert sorted_mat.layout_fingerprint() != rr_mat.layout_fingerprint()
    p1 = autotune.tune_spmv(sorted_mat, cache=cache, measure_k=0)
    p2 = autotune.tune_spmv(rr_mat, cache=cache, measure_k=0)
    assert p2.source == "model"        # not a (wrong) cache hit
    assert p2.waste != pytest.approx(p1.waste)


def test_measurement_path_records_wall_time(cache):
    p = autotune.tune_matmul(128, 128, 128, cache=cache, measure_k=2)
    assert p.source == "measured"
    assert p.measured_us is not None and p.measured_us > 0


# ---------------------------------------------------------------------------
# tuned kernels match the oracles (interpret mode)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("m,n,k", [(128, 128, 128), (130, 70, 50),
                                   (256, 384, 512)])
def test_tuned_matmul_matches_oracle(cache, m, n, k):
    a = jax.random.normal(KEY, (m, k), jnp.float32)
    b = jax.random.normal(jax.random.PRNGKey(1), (k, n), jnp.float32)
    out = autotune.tuned_matmul(a, b, interpret=True, cache=cache)
    np.testing.assert_allclose(np.asarray(out),
                               np.asarray(matmul_ref(a, b)),
                               rtol=5e-4, atol=5e-4)


@pytest.mark.parametrize("activation", [None, "relu", "gelu", "silu"])
def test_tuned_matmul_fused_epilogue(cache, activation):
    a = jax.random.normal(KEY, (96, 64), jnp.float32)
    b = jax.random.normal(jax.random.PRNGKey(1), (64, 80), jnp.float32)
    bias = jax.random.normal(jax.random.PRNGKey(2), (80,), jnp.float32)
    out = autotune.tuned_matmul(a, b, bias=bias, activation=activation,
                                interpret=True, cache=cache)
    ref = matmul_ref(a, b, bias=bias[None, :], activation=activation)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=5e-4, atol=5e-4)


def test_tuned_matmul_bf16_inputs_f32_accum(cache):
    a = jax.random.normal(KEY, (128, 256), jnp.float32)
    b = jax.random.normal(jax.random.PRNGKey(1), (256, 128), jnp.float32)
    out = autotune.tuned_matmul(a, b, compute_dtype=jnp.bfloat16,
                                out_dtype=jnp.float32, interpret=True,
                                cache=cache)
    assert out.dtype == jnp.float32
    ref = matmul_ref(a.astype(jnp.bfloat16), b.astype(jnp.bfloat16),
                     out_dtype=jnp.float32)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-6, atol=1e-6)


def test_tuned_spmv_matches_dense(cache):
    rng = np.random.default_rng(6)
    dense, indptr, cols, vals = _random_csr(rng, 200, 333, 0.05)
    mat = pack_csr(indptr, cols, vals, (200, 333), scheme="sorted")
    x = rng.standard_normal(333).astype(np.float32)
    y = autotune.tuned_spmv(mat, jnp.asarray(x), interpret=True, cache=cache)
    np.testing.assert_allclose(np.asarray(y), dense @ x, rtol=1e-4,
                               atol=1e-4)


# ---------------------------------------------------------------------------
# blocked-x SpMV: n beyond the whole-vector VMEM limit
# ---------------------------------------------------------------------------

def test_blocked_x_spmv_matches_ref_beyond_vmem_limit(cache):
    """With a forced tiny VMEM budget the whole-x kernel is infeasible
    (n * 4B alone exceeds it); the tuner must pick a blocked-x config and
    the result must equal the ELL oracle."""
    rng = np.random.default_rng(7)
    m, n = 64, 4096              # x alone: 16 KiB
    budget = 24 * 1024           # fits ELL blocks + a slab, not all of x
    dense, indptr, cols, vals = _random_csr(rng, m, n, 0.02)
    mat = pack_csr(indptr, cols, vals, (m, n), scheme="sorted")
    plan = autotune.tune_spmv(mat, vmem_bytes=budget, cache=cache,
                              measure_k=0)
    assert plan.block_cols is not None, \
        "tuner kept whole-x residency despite the budget"
    assert plan.block_cols * 4 <= budget
    x = rng.standard_normal(n).astype(np.float32)
    y = spmv(mat, jnp.asarray(x), block_rows=plan.block_rows,
             block_cols=plan.block_cols, interpret=True)
    y_ref = spmv(mat, jnp.asarray(x), use_kernel=False)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(y), dense @ x, rtol=1e-4,
                               atol=1e-4)


@pytest.mark.parametrize("block_cols", [128, 256, 1024])
def test_blocked_x_slab_sweep(block_cols):
    rng = np.random.default_rng(8)
    m, n = 48, 1000
    dense, indptr, cols, vals = _random_csr(rng, m, n, 0.05)
    mat = pack_csr(indptr, cols, vals, (m, n))
    x = rng.standard_normal(n).astype(np.float32)
    y = spmv(mat, jnp.asarray(x), block_cols=block_cols, interpret=True)
    np.testing.assert_allclose(np.asarray(y), dense @ x, rtol=1e-4,
                               atol=1e-4)
