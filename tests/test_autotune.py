"""Autotuning kernel engine: cache behavior, deterministic ranking, and
numerical equality of the tuned kernels against the pure-jnp oracles
(interpret mode on CPU)."""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import dse, tiling
from repro.kernels import autotune
from repro.kernels.matmul.ref import matmul_ref
from repro.kernels.spmv import pack_csr, spmv
from repro.kernels.spmv.ref import spmv_ell_ref

KEY = jax.random.PRNGKey(0)


@pytest.fixture
def cache(tmp_path, monkeypatch):
    """Isolated on-disk cache; env override is what production uses too."""
    path = tmp_path / "autotune.json"
    monkeypatch.setenv(autotune.CACHE_ENV, str(path))
    return autotune.TuneCache(path)


def _random_csr(rng, m, n, density):
    dense = (rng.random((m, n)) < density) * rng.standard_normal((m, n))
    nnz_per_row = (dense != 0).sum(1)
    indptr = np.concatenate([[0], np.cumsum(nnz_per_row)]).astype(np.int32)
    cols = (np.concatenate([np.nonzero(r)[0] for r in dense]).astype(np.int32)
            if nnz_per_row.sum() else np.zeros(0, np.int32))
    vals = dense[dense != 0].astype(np.float32)
    return dense, indptr, cols, vals


# ---------------------------------------------------------------------------
# candidate ranking
# ---------------------------------------------------------------------------

def test_matmul_ranking_is_deterministic():
    r1 = dse.rank_matmul_tiles(1024, 1024, 1024, top=8)
    r2 = dse.rank_matmul_tiles(1024, 1024, 1024, top=8)
    assert [c.detail["tile"] for c in r1] == [c.detail["tile"] for c in r2]
    scores = [c.score for c in r1]
    assert scores == sorted(scores)
    assert len(r1) >= 1


def test_matmul_ranking_contains_eq2_seed_or_better():
    """The top candidate is never worse than the closed-form eq.2 tile."""
    from repro.core import cost_model
    m = n = k = 8192
    seed = tiling.solve_tpu(m=m, n=n, k=k)
    seed_t = cost_model.matmul_time_model(m, n, k, seed)["time_s"]
    best = dse.rank_matmul_tiles(m, n, k, top=1)[0]
    assert best.score <= seed_t * (1 + 1e-12)


def test_spmv_ranking_deterministic_and_feasible():
    rng = np.random.default_rng(3)
    dense, indptr, cols, vals = _random_csr(rng, 128, 400, 0.1)
    mat = pack_csr(indptr, cols, vals, (128, 400), scheme="sorted")
    r1 = autotune.rank_spmv_configs(mat)
    r2 = autotune.rank_spmv_configs(mat)
    assert r1 == r2 and len(r1) > 0
    assert [r[0] for r in r1] == sorted(r[0] for r in r1)
    # every candidate's block_rows divides the packed row count
    rows = mat.cols.shape[0]
    assert all(rows % br == 0 for _, br, _, _ in r1)


def test_spmv_ranking_uses_balance_metric():
    """The waste column is exactly the active/fetched metric at that block
    size — the loadbalance input the tuner ranks with."""
    rng = np.random.default_rng(4)
    dense, indptr, cols, vals = _random_csr(rng, 64, 200, 0.2)
    mat = pack_csr(indptr, cols, vals, (64, 200), scheme="sorted")
    for _, br, _, waste in autotune.rank_spmv_configs(mat):
        assert waste == pytest.approx(mat.sliced_waste(block_rows=br))


# ---------------------------------------------------------------------------
# cache hit/miss
# ---------------------------------------------------------------------------

def test_matmul_cache_miss_then_hit(cache):
    p1 = autotune.tune_matmul(192, 128, 160, cache=cache, measure_k=0)
    assert p1.source == "model"
    assert cache.misses == 1 and cache.hits == 0
    p2 = autotune.tune_matmul(192, 128, 160, cache=cache, measure_k=0)
    assert p2.source == "cache"
    assert p2.tile == p1.tile
    assert cache.hits == 1
    # a fresh cache object re-reads the same file (persistence)
    p3 = autotune.tune_matmul(192, 128, 160, measure_k=0,
                              cache=autotune.TuneCache(cache.path))
    assert p3.source == "cache" and p3.tile == p1.tile


def test_model_entry_upgraded_by_measuring_caller(cache):
    """An analytic-only entry (e.g. serve startup, measure_k=0) must not
    suppress measurement forever: a measuring caller re-tunes and the
    measured result replaces the entry."""
    p1 = autotune.tune_matmul(128, 128, 128, cache=cache, measure_k=0)
    assert p1.source == "model" and p1.measured_us is None
    p2 = autotune.tune_matmul(128, 128, 128, cache=cache, measure_k=2)
    assert p2.source == "measured" and p2.measured_us is not None
    p3 = autotune.tune_matmul(128, 128, 128, cache=cache, measure_k=2)
    assert p3.source == "cache" and p3.measured_us is not None


def test_cache_key_separates_shapes_and_dtypes(cache):
    autotune.tune_matmul(128, 128, 128, jnp.float32, cache=cache,
                         measure_k=0)
    p = autotune.tune_matmul(128, 128, 128, jnp.bfloat16, cache=cache,
                             measure_k=0)
    assert p.source != "cache"      # different dtype, different key
    p = autotune.tune_matmul(128, 128, 256, jnp.float32, cache=cache,
                             measure_k=0)
    assert p.source != "cache"      # different shape, different key


def test_env_var_routes_default_cache(cache):
    # get_cache() must honor the monkeypatched env var from the fixture
    assert autotune.get_cache().path == cache.path


def test_corrupt_cache_file_is_ignored(cache):
    cache.path.write_text("{not json")
    p = autotune.tune_matmul(128, 128, 128, cache=autotune.TuneCache(
        cache.path), measure_k=0)
    assert p.source == "model"


def test_corrupt_cache_file_is_quarantined_with_warning(cache):
    """A corrupt cache file must be renamed to *.corrupt (evidence kept for
    forensics) with a warning — not silently overwritten — and the fresh
    cache must work end to end."""
    cache.path.write_text('{"version": 3, "entries": {truncated')
    fresh = autotune.TuneCache(cache.path)
    with pytest.warns(RuntimeWarning, match="quarantined"):
        p = autotune.tune_matmul(128, 128, 128, cache=fresh, measure_k=0)
    assert p.source == "model"
    corrupt = cache.path.with_name(cache.path.name + ".corrupt")
    assert corrupt.exists()
    assert corrupt.read_text().startswith('{"version": 3')
    # the rewritten cache file is valid and serves hits again
    p2 = autotune.tune_matmul(128, 128, 128,
                              cache=autotune.TuneCache(cache.path),
                              measure_k=0)
    assert p2.source == "cache"


def test_poisoned_plan_is_retuned_not_served(cache):
    """mark_plan_poisoned quarantines a cached winner whose launch failed:
    the next tune re-runs the DSE (source == "model", not "cache") and the
    fresh put clears the flag."""
    p1 = autotune.tune_matmul(192, 128, 160, cache=cache, measure_k=0)
    autotune.mark_plan_poisoned(p1.key, cache=cache)
    assert cache._load()["entries"][p1.key]["poisoned"] is True
    p2 = autotune.tune_matmul(192, 128, 160, cache=cache, measure_k=0)
    assert p2.source == "model"           # re-tuned, not the poisoned hit
    assert not cache._load()["entries"][p1.key].get("poisoned")
    p3 = autotune.tune_matmul(192, 128, 160, cache=cache, measure_k=0)
    assert p3.source == "cache"           # fresh entry serves again


def test_dispatch_fault_falls_back_to_reference_and_poisons_plan(cache):
    """A kernel launch that raises (here: the chaos hook) must fall back
    one-shot to the jnp reference — numerically identical result — and
    poison the plan so the next tune re-runs the DSE."""
    a = jax.random.normal(KEY, (96, 64), jnp.float32)
    b = jax.random.normal(jax.random.PRNGKey(1), (64, 80), jnp.float32)
    calls = []

    def hook(family):
        calls.append(family)
        raise RuntimeError("injected kernel-dispatch fault")

    autotune.install_dispatch_hook(hook)
    try:
        with pytest.warns(RuntimeWarning, match="falling back"):
            out = autotune.dispatch("matmul", a, b, interpret=True,
                                    cache=cache)
    finally:
        autotune.install_dispatch_hook(None)
    assert calls == ["matmul"]
    np.testing.assert_allclose(np.asarray(out),
                               np.asarray(matmul_ref(a, b)),
                               rtol=5e-4, atol=5e-4)
    poisoned = [k for k, e in cache._load()["entries"].items()
                if e.get("poisoned")]
    assert len(poisoned) == 1 and poisoned[0].startswith("matmul:")
    # with the hook cleared, the same dispatch re-tunes and runs the kernel
    out2 = autotune.dispatch("matmul", a, b, interpret=True, cache=cache)
    np.testing.assert_allclose(np.asarray(out2),
                               np.asarray(matmul_ref(a, b)),
                               rtol=5e-4, atol=5e-4)
    assert not any(e.get("poisoned")
                   for e in cache._load()["entries"].values())


def test_stale_version_entries_ignored_not_misapplied(cache):
    """Block skipping changed what a cached (block_q, block_k) means for
    causal=True, so v1 entries must be dropped wholesale (re-tuned), never
    returned as hits.  (v2 entries mean the same thing as v3 and are
    *migrated* instead — see tests/test_registry.py.)"""
    import json
    # A v1-era file whose entry sits under the *current* key with an
    # absurd winner — if version checking ever regresses, the poisoned
    # block pair would surface as a cache hit.
    key = autotune._attention_key(8, 256, 256, 64, True, None, "float32",
                                  autotune._backend(), None)
    cache.path.write_text(json.dumps({
        "version": 1,
        "entries": {key: {"block_q": 7, "block_k": 13, "source": "measured",
                          "model_time_s": 1e-9, "measured_us": 0.1}},
    }))
    p = autotune.tune_attention(8, 256, 256, 64, measure_k=0,
                                cache=autotune.TuneCache(cache.path))
    assert p.source == "model"          # stale entry re-tuned, not served
    assert (p.block_q, p.block_k) != (7, 13)
    # and the rewritten file carries the current version
    data = json.loads(cache.path.read_text())
    assert data["version"] == autotune.ENGINE_VERSION


def test_spmv_cache_miss_then_hit(cache):
    rng = np.random.default_rng(5)
    dense, indptr, cols, vals = _random_csr(rng, 64, 300, 0.1)
    mat = pack_csr(indptr, cols, vals, (64, 300))
    p1 = autotune.tune_spmv(mat, cache=cache, measure_k=0)
    assert p1.source == "model"
    p2 = autotune.tune_spmv(mat, cache=cache, measure_k=0)
    assert p2.source == "cache"
    assert (p2.block_rows, p2.block_cols) == (p1.block_rows, p1.block_cols)


def test_spmv_key_distinguishes_packings(cache):
    """Different packings of the SAME matrix have different fetch behavior
    (the balance metric differs); they must not share a cache entry."""
    rng = np.random.default_rng(9)
    dense, indptr, cols, vals = _random_csr(rng, 200, 300, 0.1)
    sorted_mat = pack_csr(indptr, cols, vals, (200, 300), scheme="sorted")
    rr_mat = pack_csr(indptr, cols, vals, (200, 300), scheme="round_robin")
    assert sorted_mat.layout_fingerprint() != rr_mat.layout_fingerprint()
    p1 = autotune.tune_spmv(sorted_mat, cache=cache, measure_k=0)
    p2 = autotune.tune_spmv(rr_mat, cache=cache, measure_k=0)
    assert p2.source == "model"        # not a (wrong) cache hit
    assert p2.waste != pytest.approx(p1.waste)


def test_measurement_path_records_wall_time(cache):
    p = autotune.tune_matmul(128, 128, 128, cache=cache, measure_k=2)
    assert p.source == "measured"
    assert p.measured_us is not None and p.measured_us > 0


# ---------------------------------------------------------------------------
# tuned kernels match the oracles (interpret mode)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("m,n,k", [(128, 128, 128), (130, 70, 50),
                                   (256, 384, 512)])
def test_tuned_matmul_matches_oracle(cache, m, n, k):
    a = jax.random.normal(KEY, (m, k), jnp.float32)
    b = jax.random.normal(jax.random.PRNGKey(1), (k, n), jnp.float32)
    out = autotune.tuned_matmul(a, b, interpret=True, cache=cache)
    np.testing.assert_allclose(np.asarray(out),
                               np.asarray(matmul_ref(a, b)),
                               rtol=5e-4, atol=5e-4)


@pytest.mark.parametrize("activation", [None, "relu", "gelu", "silu"])
def test_tuned_matmul_fused_epilogue(cache, activation):
    a = jax.random.normal(KEY, (96, 64), jnp.float32)
    b = jax.random.normal(jax.random.PRNGKey(1), (64, 80), jnp.float32)
    bias = jax.random.normal(jax.random.PRNGKey(2), (80,), jnp.float32)
    out = autotune.tuned_matmul(a, b, bias=bias, activation=activation,
                                interpret=True, cache=cache)
    ref = matmul_ref(a, b, bias=bias[None, :], activation=activation)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=5e-4, atol=5e-4)


def test_tuned_matmul_bf16_inputs_f32_accum(cache):
    a = jax.random.normal(KEY, (128, 256), jnp.float32)
    b = jax.random.normal(jax.random.PRNGKey(1), (256, 128), jnp.float32)
    out = autotune.tuned_matmul(a, b, compute_dtype=jnp.bfloat16,
                                out_dtype=jnp.float32, interpret=True,
                                cache=cache)
    assert out.dtype == jnp.float32
    ref = matmul_ref(a.astype(jnp.bfloat16), b.astype(jnp.bfloat16),
                     out_dtype=jnp.float32)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-6, atol=1e-6)


def test_tuned_spmv_matches_dense(cache):
    rng = np.random.default_rng(6)
    dense, indptr, cols, vals = _random_csr(rng, 200, 333, 0.05)
    mat = pack_csr(indptr, cols, vals, (200, 333), scheme="sorted")
    x = rng.standard_normal(333).astype(np.float32)
    y = autotune.tuned_spmv(mat, jnp.asarray(x), interpret=True, cache=cache)
    np.testing.assert_allclose(np.asarray(y), dense @ x, rtol=1e-4,
                               atol=1e-4)


# ---------------------------------------------------------------------------
# blocked-x SpMV: n beyond the whole-vector VMEM limit
# ---------------------------------------------------------------------------

def test_blocked_x_spmv_matches_ref_beyond_vmem_limit(cache):
    """With a forced tiny VMEM budget the whole-x kernel is infeasible
    (n * 4B alone exceeds it); the tuner must pick a blocked-x config and
    the result must equal the ELL oracle."""
    rng = np.random.default_rng(7)
    m, n = 64, 4096              # x alone: 16 KiB
    budget = 24 * 1024           # fits ELL blocks + a slab, not all of x
    dense, indptr, cols, vals = _random_csr(rng, m, n, 0.02)
    mat = pack_csr(indptr, cols, vals, (m, n), scheme="sorted")
    plan = autotune.tune_spmv(mat, vmem_bytes=budget, cache=cache,
                              measure_k=0)
    assert plan.block_cols is not None, \
        "tuner kept whole-x residency despite the budget"
    assert plan.block_cols * 4 <= budget
    x = rng.standard_normal(n).astype(np.float32)
    y = spmv(mat, jnp.asarray(x), block_rows=plan.block_rows,
             block_cols=plan.block_cols, interpret=True)
    y_ref = spmv(mat, jnp.asarray(x), use_kernel=False)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(y), dense @ x, rtol=1e-4,
                               atol=1e-4)


# ---------------------------------------------------------------------------
# attention tuning
# ---------------------------------------------------------------------------

def test_attention_ranking_deterministic_and_feasible():
    r1 = dse.rank_attention_blocks(8, 1024, 1024, 128)
    r2 = dse.rank_attention_blocks(8, 1024, 1024, 128)
    assert [(c.detail["block_q"], c.detail["block_k"]) for c in r1] \
        == [(c.detail["block_q"], c.detail["block_k"]) for c in r2]
    scores = [c.score for c in r1]
    assert scores == sorted(scores) and len(r1) >= 1
    # every candidate's effective blocks divide the sequence
    assert all(1024 % c.detail["block_q"] == 0
               and 1024 % c.detail["block_k"] == 0 for c in r1)


def test_attention_ranking_respects_vmem_budget():
    """A budget that only fits the smallest blocks must exclude the rest,
    and the kept candidates' modeled VMEM must fit."""
    budget = 420 * 1024          # fits 128x128 f32 working set, not 512x512
    ranked = dse.rank_attention_blocks(4, 1024, 1024, 64,
                                       vmem_bytes=budget, dtype_bytes=4)
    assert all(c.detail["vmem_bytes"] <= budget for c in ranked)
    big = dse.rank_attention_blocks(4, 1024, 1024, 64, dtype_bytes=4)
    assert max(c.detail["block_q"] for c in big) \
        > max(c.detail["block_q"] for c in ranked)


def test_attention_deeper_q_blocks_cut_kv_traffic():
    """The communication-avoiding story: K/V re-streaming falls as block_q
    grows, so the model must strictly prefer deeper q-blocks when VMEM
    allows (same reason eq.2 pushes y up in the matmul)."""
    from repro.core import cost_model
    shallow = cost_model.attention_time_model(8, 4096, 4096, 128, 128, 512)
    deep = cost_model.attention_time_model(8, 4096, 4096, 128, 1024, 512)
    assert deep["traffic_bytes"] < shallow["traffic_bytes"]
    assert deep["time_s"] <= shallow["time_s"]


def test_attention_tie_break_survives_truncation():
    """Compute-bound shapes tie many configs on model time; the deeper-
    block_q preference must hold even at top=1 (the serving measure_k=0
    path) — i.e. the tie-break runs before the top-cut, not after."""
    top1 = dse.rank_attention_blocks(320, 2048, 2048, 128, top=1)[0]
    full = dse.rank_attention_blocks(320, 2048, 2048, 128, top=32)
    tied = [c for c in full if c.score == top1.score]
    assert top1.detail["block_q"] == max(c.detail["block_q"] for c in tied)


def test_attention_cache_miss_then_hit(cache):
    p1 = autotune.tune_attention(8, 256, 256, 64, cache=cache, measure_k=0)
    assert p1.source == "model"
    p2 = autotune.tune_attention(8, 256, 256, 64, cache=cache, measure_k=0)
    assert p2.source == "cache"
    assert (p2.block_q, p2.block_k) == (p1.block_q, p1.block_k)
    # persistence: a fresh cache object re-reads the same file
    p3 = autotune.tune_attention(8, 256, 256, 64, measure_k=0,
                                 cache=autotune.TuneCache(cache.path))
    assert p3.source == "cache"


def test_attention_model_entry_upgraded_by_measuring_caller(cache):
    """Analytic-only plans written at serve startup must not suppress
    measurement forever — same upgrade rule as matmul/SpMV."""
    p1 = autotune.tune_attention(2, 128, 128, 32, cache=cache, measure_k=0)
    assert p1.source == "model" and p1.measured_us is None
    p2 = autotune.tune_attention(2, 128, 128, 32, cache=cache, measure_k=2)
    assert p2.source == "measured" and p2.measured_us is not None
    p3 = autotune.tune_attention(2, 128, 128, 32, cache=cache, measure_k=2)
    assert p3.source == "cache" and p3.measured_us is not None


def test_attention_key_separates_masking_and_shape(cache):
    autotune.tune_attention(4, 256, 256, 64, cache=cache, measure_k=0)
    p = autotune.tune_attention(4, 256, 256, 64, causal=False, cache=cache,
                                measure_k=0)
    assert p.source != "cache"       # causal flag is part of the key
    p = autotune.tune_attention(4, 256, 256, 64, window=128, cache=cache,
                                measure_k=0)
    assert p.source != "cache"       # window is part of the key
    p = autotune.tune_attention(4, 256, 512, 64, cache=cache, measure_k=0)
    assert p.source != "cache"       # kv length is part of the key


@pytest.mark.parametrize("causal,window,hq,hkv", [
    (True, None, 4, 4),              # causal MHA
    (True, 64, 4, 4),                # sliding window
    (True, None, 4, 2),              # GQA
    (False, None, 2, 2),             # bidirectional (encoder prefill)
])
def test_tuned_attention_matches_reference(cache, causal, window, hq, hkv):
    from repro.kernels.attention import mha_attention
    q = jax.random.normal(KEY, (2, 128, hq, 32), jnp.float32)
    k = jax.random.normal(jax.random.PRNGKey(1), (2, 128, hkv, 32),
                          jnp.float32)
    v = jax.random.normal(jax.random.PRNGKey(2), (2, 128, hkv, 32),
                          jnp.float32)
    out = autotune.tuned_attention(q, k, v, causal=causal, window=window,
                                   interpret=True, cache=cache)
    ref = mha_attention(q, k, v, causal=causal, window=window,
                        use_kernel=False)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-3, atol=2e-3)


def test_attention_model_credits_causal_skip():
    """attention_time_model(causal=True) must price the block triangle:
    at sq=sk its traffic/FLOPs are the active/total fraction of the dense
    accounting, and the predicted speedup tracks the counted K-steps."""
    from repro.core import cost_model
    kw = dict(bh=8, sq=4096, sk=4096, dh=128, block_q=512, block_k=512)
    dense = cost_model.attention_time_model(**kw, causal=True,
                                            block_skipping=False)
    skip = cost_model.attention_time_model(**kw, causal=True)
    active, total = cost_model.attention_active_block_pairs(
        4096, 4096, 512, 512, causal=True)
    assert skip["active_block_pairs"] == active < total
    assert skip["flops"] == pytest.approx(dense["flops"] * active / total)
    assert skip["time_s"] < dense["time_s"]
    # the model's predicted ranking matches the counted-K-step ordering
    assert total / active >= 1.5


def test_attention_model_credits_window_band():
    """A sliding window keeps only the block band, which must beat the
    full causal triangle in the model."""
    from repro.core import cost_model
    kw = dict(bh=8, sq=4096, sk=4096, dh=128, block_q=256, block_k=256)
    tri = cost_model.attention_time_model(**kw, causal=True)
    band = cost_model.attention_time_model(**kw, causal=True, window=512)
    assert band["active_block_pairs"] < tri["active_block_pairs"]
    assert band["time_s"] < tri["time_s"]


def test_attention_window_enters_ranking(cache):
    """The window now changes the scored traffic, not just the cache key:
    ranking the same shape with/without a window must produce different
    model times for at least the dense winner."""
    full = dse.rank_attention_blocks(8, 2048, 2048, 64, causal=True)
    win = dse.rank_attention_blocks(8, 2048, 2048, 64, causal=True,
                                    window=256)
    assert win[0].score < full[0].score


def test_tuned_attention_ragged_prefill(cache):
    """Ragged prefill lengths must tune and run (the old kernel asserted
    on divisibility; the tuner's candidates no longer require it)."""
    from repro.kernels.attention import mha_attention
    q = jax.random.normal(KEY, (1, 300, 4, 32), jnp.float32)
    k = jax.random.normal(jax.random.PRNGKey(1), (1, 300, 2, 32),
                          jnp.float32)
    v = jax.random.normal(jax.random.PRNGKey(2), (1, 300, 2, 32),
                          jnp.float32)
    out = autotune.tuned_attention(q, k, v, interpret=True, cache=cache)
    ref = mha_attention(q, k, v, use_kernel=False)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-3, atol=2e-3)


def test_tuned_attention_oracle_path_skips_tuning(cache):
    """CPU callers that never reach the kernel path must not pay (or write)
    any tuning state — same contract as tuned_matmul/tuned_spmv."""
    q = jax.random.normal(KEY, (1, 64, 2, 16), jnp.float32)
    k = jax.random.normal(jax.random.PRNGKey(1), (1, 64, 2, 16), jnp.float32)
    v = jax.random.normal(jax.random.PRNGKey(2), (1, 64, 2, 16), jnp.float32)
    autotune.tuned_attention(q, k, v, use_kernel=False, cache=cache)
    assert cache.hits == 0 and cache.misses == 0


# ---------------------------------------------------------------------------
# decode tuning
# ---------------------------------------------------------------------------

def test_rank_decode_blocks_deterministic_and_feasible():
    r1 = dse.rank_decode_blocks(16, 4, 1024, 64)
    r2 = dse.rank_decode_blocks(16, 4, 1024, 64)
    assert [c.detail["block_k"] for c in r1] \
        == [c.detail["block_k"] for c in r2]
    scores = [c.score for c in r1]
    assert scores == sorted(scores) and len(r1) >= 1
    budget = min(c.detail["vmem_bytes"] for c in r1)
    capped = dse.rank_decode_blocks(16, 4, 1024, 64, vmem_bytes=budget)
    assert all(c.detail["vmem_bytes"] <= budget for c in capped)


def test_decode_model_charges_ragged_tail_overfetch():
    """The fetched-vs-active accounting: a block_k that rounds a ragged
    cache far up must be charged for the over-fetch."""
    from repro.core import cost_model
    fine = cost_model.decode_time_model(16, 4, 1000, 64, 128)
    coarse = cost_model.decode_time_model(16, 4, 1000, 64, 1024)
    assert fine["fetched_k"] == 1024 and coarse["fetched_k"] == 1024
    tight = cost_model.decode_time_model(16, 4, 1000, 64, 1000)
    assert tight["fetched_k"] == 1000
    assert tight["waste"] == pytest.approx(1.0)
    assert coarse["waste"] > 1.0


def test_decode_model_lengths_active_prefix_accounting():
    """The ragged length distribution is charged per-row block-rounded
    active prefixes, not the batch max — and degenerates to the scalar
    path when every row sits at the full depth."""
    from repro.core import cost_model
    bmax = cost_model.decode_time_model(8, 4, 1024, 64, 128)
    ragged = cost_model.decode_time_model(8, 4, 1024, 64, 128,
                                          lengths=[128, 256, 512, 1024])
    assert ragged["time_s"] < bmax["time_s"]
    # rep=2 rows per length; fetched = mean per-row block-rounded prefix
    assert ragged["fetched_k"] == pytest.approx((128 + 256 + 512 + 1024) / 4)
    full = cost_model.decode_time_model(8, 4, 1024, 64, 128,
                                        lengths=[1024] * 4)
    assert full["time_s"] == pytest.approx(bmax["time_s"])
    assert full["fetched_k"] == bmax["fetched_k"]
    # lengths are clamped to the allocated depth; an idle slot still pays
    # one block (the kernel always executes block 0)
    clamped = cost_model.decode_time_model(8, 4, 1024, 64, 128,
                                           lengths=[0, 9999, 64, 64])
    assert clamped["fetched_k"] == pytest.approx((128 + 1024 + 128 + 128) / 4)
    with pytest.raises(ValueError):
        cost_model.decode_time_model(8, 4, 1024, 64, 128, lengths=[1, 2, 3])


def test_rank_decode_blocks_prefers_finer_blocks_for_ragged_lengths():
    """A ragged distribution shifts the ranking toward finer block_k (the
    shallow rows skip more), while batch-max keeps the coarse tie-break."""
    ragged = dse.rank_decode_blocks(8, 2, 512, 64,
                                    lengths=[32, 64, 128, 512])
    bmax = dse.rank_decode_blocks(8, 2, 512, 64)
    assert ragged[0].detail["block_k"] < bmax[0].detail["block_k"]


def test_plan_for_model_lengths_key_and_runtime_pin(cache):
    """A slot-length distribution tunes a lengths-keyed decode plan AND
    pins its knobs under the plain runtime dispatch key (re-scored at
    batch-max) so the jitted serve step runs the workload-aware block."""
    cfg = _serve_cfg()
    plans = autotune.plan_for_model(cfg, 4, cache_len=512,
                                    slot_lengths=[32, 64, 128, 512],
                                    cache=cache)
    dec = next(p for p in plans if p.op == "attn_decode")
    assert dec.plan.problem["lengths"] == (32, 64, 128, 512)
    assert ":l32-64-128-512:" in dec.plan.key
    run_problem = {k: v for k, v in dec.plan.problem.items()
                   if k != "lengths"}
    run_key = autotune.cache_key(
        autotune.registry.get("decode"), run_problem, "bfloat16",
        autotune._backend(), None)
    entry = cache._load()["entries"][run_key]
    assert entry["knobs"] == dec.plan.knobs
    assert entry["detail"]["pinned_from"] == dec.plan.key
    # the pinned entry is re-scored at the batch-max problem it lives under
    spec = autotune.registry.get("decode")
    assert entry["model_time_s"] == pytest.approx(
        spec.cost_fn(run_problem, dec.plan.knobs)["time_s"])
    # a later measured winner must not be clobbered by re-pinning
    entry2 = dict(entry, source="measured", measured_us=1.0)
    cache.put(run_key, entry2)
    autotune.plan_for_model(cfg, 4, cache_len=512,
                            slot_lengths=[32, 64, 128, 512], cache=cache)
    assert cache._load()["entries"][run_key]["source"] == "measured"


def test_decode_cache_miss_then_hit_and_upgrade(cache):
    p1 = autotune.tune_decode(4, 2, 256, 32, cache=cache, measure_k=0)
    assert p1.source == "model" and p1.measured_us is None
    p2 = autotune.tune_decode(4, 2, 256, 32, cache=cache, measure_k=0)
    assert p2.source == "cache" and p2.block_k == p1.block_k
    # analytic-only entries are upgraded by the first measuring caller
    p3 = autotune.tune_decode(4, 2, 256, 32, cache=cache, measure_k=2)
    assert p3.source == "measured" and p3.measured_us is not None
    p4 = autotune.tune_decode(4, 2, 256, 32, cache=cache, measure_k=2)
    assert p4.source == "cache" and p4.measured_us is not None


def test_decode_key_separates_shapes(cache):
    autotune.tune_decode(4, 2, 256, 32, cache=cache, measure_k=0)
    p = autotune.tune_decode(4, 2, 512, 32, cache=cache, measure_k=0)
    assert p.source != "cache"       # cache depth is part of the key
    p = autotune.tune_decode(8, 2, 256, 32, cache=cache, measure_k=0)
    assert p.source != "cache"       # folded rows are part of the key


@pytest.mark.parametrize("hq,hkv,length", [
    (4, 2, 256),       # GQA, full cache
    (4, 2, 100),       # partial prefix
    (2, 2, 77),        # MHA, ragged vs any block_k
])
def test_tuned_decode_matches_reference(cache, hq, hkv, length):
    from repro.kernels.attention import decode_ref
    b, dh, cache_len = 2, 32, 256
    q = jax.random.normal(KEY, (b, hq, dh), jnp.float32)
    k = jax.random.normal(jax.random.PRNGKey(1), (b, cache_len, hkv, dh),
                          jnp.float32)
    v = jax.random.normal(jax.random.PRNGKey(2), (b, cache_len, hkv, dh),
                          jnp.float32)
    out = autotune.tuned_decode(q, k, v, length=length, interpret=True,
                                cache=cache)
    ref = decode_ref(q, k, v, length=length)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-3, atol=2e-3)


def test_tuned_decode_oracle_path_skips_tuning(cache):
    q = jax.random.normal(KEY, (1, 2, 16), jnp.float32)
    k = jax.random.normal(jax.random.PRNGKey(1), (1, 64, 2, 16), jnp.float32)
    v = jax.random.normal(jax.random.PRNGKey(2), (1, 64, 2, 16), jnp.float32)
    autotune.tuned_decode(q, k, v, length=64, use_kernel=False, cache=cache)
    assert cache.hits == 0 and cache.misses == 0


# ---------------------------------------------------------------------------
# serving plans: all four kernel families + the batch sweep
# ---------------------------------------------------------------------------

def _serve_cfg():
    from repro.models.config import ModelConfig
    return ModelConfig(name="t", family="dense", num_layers=2, d_model=64,
                       d_ff=128, vocab_size=256, num_heads=4, num_kv_heads=2)


def test_plan_for_model_covers_attention(cache):
    cfg = _serve_cfg()
    plans = autotune.plan_for_model(cfg, 2, prefill_len=64, cache=cache)
    ops = {p.op for p in plans}
    assert {"qkv_proj", "out_proj", "ffn_up", "ffn_down", "logits",
            "attn_prefill"} <= ops
    attn = next(p for p in plans if p.op == "attn_prefill")
    assert attn.plan.family == "attention"
    assert attn.plan.problem == {"bh": 2 * cfg.num_heads, "sq": 64,
                                 "sk": 64, "dh": cfg.head_dim,
                                 "causal": cfg.causal,
                                 "window": cfg.sliding_window}
    assert attn.plan.knobs["block_q"] >= 1 and attn.plan.model_time_us > 0
    # attention plans ride the same cache pipeline: second call hits
    plans2 = autotune.plan_for_model(cfg, 2, prefill_len=64, cache=cache)
    attn2 = next(p for p in plans2 if p.op == "attn_prefill")
    assert attn2.plan.source == "cache"
    assert attn2.plan.knobs == attn.plan.knobs
    # the log record is plain JSON (what serve.py dumps at startup)
    import json
    rec = attn.record()
    assert rec["op"] == "attn_prefill" and rec["family"] == "attention"
    json.dumps(rec)


def test_plan_for_model_covers_decode(cache):
    cfg = _serve_cfg()
    plans = autotune.plan_for_model(cfg, 2, prefill_len=64, cache_len=128,
                                    cache=cache)
    dec = next(p for p in plans if p.op == "attn_decode")
    assert dec.plan.family == "decode"
    assert dec.plan.problem == {"bkv": 2 * cfg.num_kv_heads,
                                "g": cfg.num_heads // cfg.num_kv_heads,
                                "cache_len": 128, "dh": cfg.head_dim}
    assert dec.plan.knobs["block_k"] >= 1 and dec.plan.model_time_us > 0
    assert dec.plan.provenance == "analytic"        # measure_k=0 warmup
    plans2 = autotune.plan_for_model(cfg, 2, prefill_len=64, cache_len=128,
                                     cache=cache)
    dec2 = next(p for p in plans2 if p.op == "attn_decode")
    assert dec2.plan.source == "cache"
    assert dec2.plan.knobs == dec.plan.knobs


def test_select_serving_batch_logs_decode_plan(cache):
    cfg = _serve_cfg()
    d = autotune.select_serving_batch(cfg, cache_len=128, prefill_len=64,
                                      candidates=(1, 2, 4), cache=cache)
    assert d["decode_plan"] is not None
    assert d["decode_plan"]["op"] == "attn_decode"
    assert d["decode_plan"]["problem"]["bkv"] \
        == d["batch"] * cfg.num_kv_heads
    # volatile provenance/wall-clock fields are excluded; the kept
    # knobs/model_time_us are reproducible given the same cache contents
    assert "source" not in d["decode_plan"]
    assert "provenance" not in d["decode_plan"]


def test_select_serving_batch_deterministic(cache):
    cfg = _serve_cfg()
    kw = dict(cache_len=128, prefill_len=64, candidates=(1, 2, 4, 8),
              cache=cache)
    d1 = autotune.select_serving_batch(cfg, **kw)
    d2 = autotune.select_serving_batch(cfg, **kw)
    assert d1 == d2                          # cache hits change nothing
    assert [r["batch"] for r in d1["sweep"]] == [1, 2, 4, 8]
    assert all(r["step_us"] > 0 for r in d1["sweep"])
    # predicted step time is monotone in batch (more work per step)
    steps = [r["step_us"] for r in d1["sweep"]]
    assert steps == sorted(steps)


def test_select_serving_batch_maximizes_predicted_throughput(cache):
    cfg = _serve_cfg()
    d = autotune.select_serving_batch(cfg, cache_len=128, prefill_len=64,
                                      candidates=(1, 2, 4, 8), cache=cache)
    best = max(d["sweep"], key=lambda r: r["tok_per_s"])
    assert d["batch"] == best["batch"]
    assert d["predicted_tok_per_s"] == best["tok_per_s"]


def test_select_serving_batch_respects_latency_budget(cache):
    cfg = _serve_cfg()
    free = autotune.select_serving_batch(cfg, cache_len=128, prefill_len=64,
                                         candidates=(1, 2, 4, 8), cache=cache)
    # budget set just under the unconstrained winner's step time forces a
    # smaller batch
    budget_ms = free["predicted_step_us"] * 0.99 / 1e3
    capped = autotune.select_serving_batch(
        cfg, cache_len=128, prefill_len=64, candidates=(1, 2, 4, 8),
        latency_budget_ms=budget_ms, cache=cache)
    assert capped["batch"] < free["batch"]
    assert capped["predicted_step_us"] <= budget_ms * 1e3
    # impossible budget: least-bad latency fallback, not a crash
    floor = autotune.select_serving_batch(
        cfg, cache_len=128, prefill_len=64, candidates=(1, 2, 4, 8),
        latency_budget_ms=1e-9, cache=cache)
    assert floor["batch"] == 1


def test_decode_matmul_traffic_has_weight_floor():
    """comm_volume_rect must charge at least one full pass over B even when
    m << tile.y — the weight-bound decode regime the batch sweep ranks."""
    t = tiling.Tile(128, 128, 128)
    assert tiling.comm_volume_rect(4, 512, 512, t) >= 512 * 512


@pytest.mark.parametrize("block_cols", [128, 256, 1024])
def test_blocked_x_slab_sweep(block_cols):
    rng = np.random.default_rng(8)
    m, n = 48, 1000
    dense, indptr, cols, vals = _random_csr(rng, m, n, 0.05)
    mat = pack_csr(indptr, cols, vals, (m, n))
    x = rng.standard_normal(n).astype(np.float32)
    y = spmv(mat, jnp.asarray(x), block_cols=block_cols, interpret=True)
    np.testing.assert_allclose(np.asarray(y), dense @ x, rtol=1e-4,
                               atol=1e-4)
