"""End-to-end behaviour tests of the generated system (replaces the
scaffold placeholder): the paper's design-flow invariants at system level."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import cost_model, dse, manycore, tiling
from repro.launch import mesh as mesh_compat
from repro.parallel import sharding as shd


def test_manycore_config_generates_consistent_plan():
    mc = manycore.ManyCoreConfig()
    assert mc.num_chips == 256
    t = mc.matmul_tile(8192, 8192, 8192)
    used = (t.y * t.z + 2 * t.z * t.x) * 2 + t.y * t.x * 4
    assert used <= mc.usable_vmem
    assert "256 chips" in mc.describe()


def test_table1_style_efficiency_from_machine_model():
    """The paper's Table-I structure: efficiency (peak/measured) of the
    eq.2-tiled blocked matmul under the analytical machine model is high
    (paper reports 84-86% on FPGA; the TPU machine model with VMEM-scale
    L gives >95% for MXU-scale matrices)."""
    t = tiling.solve_tpu(m=8192, n=8192, k=8192)
    res = cost_model.matmul_time_model(8192, 8192, 8192, t)
    assert res["efficiency"] > 0.84  # at least the paper's own number


def test_dse_autotune_never_worse_than_eq2_seed():
    m = n = k = 4096
    seed = tiling.solve_tpu(m=m, n=n, k=k)
    tuned = dse.autotune_matmul_tile(m, n, k)
    q_seed = cost_model.matmul_time_model(m, n, k, seed)["time_s"]
    q_tuned = cost_model.matmul_time_model(m, n, k, tuned)["time_s"]
    assert q_tuned <= q_seed * 1.001


def test_roofline_terms_and_dominance():
    r = cost_model.roofline(flops=1e15, bytes_accessed=1e12,
                            collective_bytes=1e11, chips=256,
                            model_flops=9e14)
    assert r.dominant == "compute"
    assert 0 < r.useful_fraction <= 1
    assert r.bound_s == r.compute_s
    r2 = cost_model.roofline(1e12, 1e15, 1e11, 256)
    assert r2.dominant == "memory"


def test_sharding_rules_drop_indivisible_dims():
    mesh = mesh_compat.make_mesh((1, 1), ("data", "model"))
    rules = shd.single_pod_rules().with_sizes(mesh)
    # sizes say model=1 => constraint becomes fully replicated, no error
    with shd.use_rules(rules):
        x = jnp.zeros((4, 6, 8))
        y = shd.constrain(x, "batch", "seq", "heads")
        assert y.shape == x.shape


def test_sharding_candidates_enumeration():
    cands = dse.sharding_candidates(256)
    assert {"data": 16, "model": 16} in cands
    assert all(c["data"] * c["model"] == 256 for c in cands)
