"""Crash tolerance end-to-end: the journal's write-ahead discipline, the
atomic snapshot store, and deterministic `serve --resume` recovery.

The invariants (docs/ROBUSTNESS.md, "Crash recovery"): (1) a torn final
journal line is the crash signature and is absorbed, while interior
corruption raises loudly; (2) a snapshot round-trips the full server +
lifecycle state bitwise, and one decode step after restore matches the
original run exactly; (3) a crashed serve resumed from its --state-dir
continues token-for-token identical to an uninterrupted run, replaying
at most one snapshot interval of journal; (4) every durable artifact
(autotune cache, BENCH reports, snapshots) is written atomically — a
kill mid-save leaves the previous committed file, never a torn one."""

import json
import os

import numpy as np
import pytest

import jax

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # minimal env: property tests skip, rest run
    from _hypothesis_stub import given, settings, st

from repro.core import ioutil
from repro.kernels.autotune import TuneCache
from repro.launch.serve import CRASH_EXIT, Server, serve_loop
from repro.models.config import ModelConfig
from repro.runtime import faults, journal as journal_mod, snapshot
from repro.runtime.lifecycle import Lifecycle, State, submit_all

MAX_LEN = 24


def _cfg(**kw):
    base = dict(name="tiny-recovery", family="dense", num_layers=2,
                d_model=32, d_ff=64, vocab_size=101, num_heads=4,
                num_kv_heads=2)
    base.update(kw)
    return ModelConfig(**base)


def _requests(cfg, spec):
    out = []
    for rid, (plen, gen) in enumerate(spec):
        prompt = np.asarray(
            jax.random.randint(jax.random.PRNGKey(100 + rid), (plen,), 0,
                               cfg.vocab_size), np.int32)
        out.append((rid, prompt, gen))
    return out


# ---------------------------------------------------------------------------
# journal: write-ahead log crash signatures
# ---------------------------------------------------------------------------

def _write_records(path, n=4):
    with journal_mod.Journal(path, durable=False) as j:
        j.submit(0, [1, 2, 3], gen_len=n)
        for i in range(n):
            j.token(0, i, 10 + i, step=i)
    return journal_mod.read_journal(path)


def test_journal_roundtrip_with_monotonic_seq(tmp_path):
    records = _write_records(tmp_path / "j.jsonl")
    assert [r["seq"] for r in records] == list(range(len(records)))
    assert records[0]["kind"] == "submit"
    assert records[0]["prompt"] == [1, 2, 3]


def test_journal_torn_final_line_is_absorbed(tmp_path):
    """A truncated final line — the crash-mid-append signature — is
    dropped silently; the committed prefix survives untouched."""
    path = tmp_path / "j.jsonl"
    committed = _write_records(path)
    with open(path, "a") as f:
        f.write('{"kind": "token", "rid": 0, "i": 4, "se')   # no newline
    records, torn = journal_mod.read_journal(path, return_torn=True)
    assert records == committed
    assert torn is not None


def test_journal_newlineless_complete_final_line_is_kept(tmp_path):
    """The crash can also hit between the payload and the newline: a
    *parseable* final line with the expected seq is complete — keep it."""
    path = tmp_path / "j.jsonl"
    committed = _write_records(path)
    rec = {"kind": "token", "rid": 0, "i": 4, "tok": 99, "step": 4,
           "seq": committed[-1]["seq"] + 1}
    with open(path, "a") as f:
        f.write(json.dumps(rec))                              # no newline
    records, torn = journal_mod.read_journal(path, return_torn=True)
    assert torn is None
    assert records[-1]["tok"] == 99


def test_journal_interior_corruption_raises(tmp_path):
    """Corruption anywhere but the final line is NOT a crash signature:
    it must raise with the line number and payload, never be absorbed."""
    path = tmp_path / "j.jsonl"
    _write_records(path)
    lines = path.read_text().splitlines()
    lines[1] = lines[1][:10]          # truncate an interior record
    path.write_text("\n".join(lines) + "\n")
    with pytest.raises(journal_mod.JournalError, match=r":2:"):
        journal_mod.read_journal(path)


def test_journal_interior_seq_gap_raises(tmp_path):
    """A whole missing interior record (seq jump) is lost history, not a
    torn tail — recovery on top of it would silently drop effects."""
    path = tmp_path / "j.jsonl"
    _write_records(path)
    lines = path.read_text().splitlines()
    del lines[2]
    path.write_text("\n".join(lines) + "\n")
    with pytest.raises(journal_mod.JournalError, match="seq jumped"):
        journal_mod.read_journal(path)


def test_journal_reopen_truncates_torn_tail_and_continues(tmp_path):
    """Re-opening after a crash truncates the torn tail so the next
    append starts on a clean line boundary with the next seq."""
    path = tmp_path / "j.jsonl"
    committed = _write_records(path)
    with open(path, "a") as f:
        f.write('{"kind": "token", "rid"')
    with journal_mod.Journal(path, durable=False) as j:
        assert j.seq == committed[-1]["seq"] + 1
        j.state(0, "completed", step=9)
    records = journal_mod.read_journal(path)
    assert [r["seq"] for r in records] == list(range(len(records)))
    assert records[-1]["state"] == "completed"


def test_journal_replay_overwrites_tokens_by_index(tmp_path):
    """An eviction requeue discards partial output; the retry's token
    records overwrite by index instead of duplicating."""
    path = tmp_path / "j.jsonl"
    with journal_mod.Journal(path, durable=False) as j:
        j.submit(0, [1, 2], gen_len=2)
        j.token(0, 0, 11, step=1)
        j.token(0, 1, 12, step=2)
        j.state(0, "queued", step=3)            # evicted + requeued
        j.token(0, 0, 21, step=5)               # retry starts over
        j.token(0, 1, 22, step=6)
        j.token(0, 2, 23, step=7)
        j.state(0, "completed", step=7)
    reqs = journal_mod.replay(journal_mod.read_journal(path))
    assert reqs[0]["tokens"] == [21, 22, 23]
    assert reqs[0]["state"] == "completed"


# ---------------------------------------------------------------------------
# snapshot: atomic commit + bitwise round-trip
# ---------------------------------------------------------------------------

def _arrays_from_seed(seed: int) -> dict:
    rng = np.random.default_rng(seed)
    return {
        "kv": rng.standard_normal((2, 3, 4)).astype(np.float32),
        "lengths": rng.integers(0, 9, size=(3,)).astype(np.int32),
        "mask": rng.integers(0, 2, size=(5,)).astype(bool),
    }


def _roundtrip(tmp_path, seed: int) -> None:
    store = snapshot.SnapshotStore(tmp_path / "snaps", every=4)
    arrays = _arrays_from_seed(seed)
    store.save(step=4, arrays=arrays, meta={"seed": seed}, journal_seq=7)
    manifest, loaded = snapshot.latest_snapshot(tmp_path / "snaps")
    assert manifest["step"] == 4 and manifest["journal_seq"] == 7
    assert set(loaded) == set(arrays)
    for leaf, a in arrays.items():
        assert loaded[leaf].dtype == a.dtype
        np.testing.assert_array_equal(loaded[leaf], a)


@pytest.mark.parametrize("seed", [0, 1, 2])
def test_snapshot_roundtrip_bitwise(tmp_path, seed):
    """Seeded fallback for the property test below — runs even without
    hypothesis installed."""
    _roundtrip(tmp_path, seed)


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(min_value=0, max_value=2**31 - 1))
def test_snapshot_roundtrip_bitwise_property(tmp_path_factory, seed):
    """Property form: any array dict round-trips bitwise through the
    npz payload + hashed manifest."""
    _roundtrip(tmp_path_factory.mktemp("snap"), seed)


def test_snapshot_incremental_reuses_unchanged_leaves(tmp_path):
    """A leaf unchanged since the previous snapshot is *referenced* from
    the older payload file, not rewritten."""
    store = snapshot.SnapshotStore(tmp_path, every=4)
    arrays = _arrays_from_seed(0)
    store.save(step=4, arrays=arrays, meta={}, journal_seq=0)
    arrays2 = dict(arrays, kv=arrays["kv"] + 1.0)    # one leaf changed
    store.save(step=8, arrays=arrays2, meta={}, journal_seq=5)
    man2 = json.loads((tmp_path / "snap-00000008.json").read_text())
    assert man2["arrays"]["kv"]["file"] == "snap-00000008.npz"
    assert man2["arrays"]["lengths"]["file"] == "snap-00000004.npz"
    _, loaded = snapshot.load_snapshot(tmp_path / "snap-00000008.json")
    np.testing.assert_array_equal(loaded["kv"], arrays2["kv"])
    np.testing.assert_array_equal(loaded["lengths"], arrays["lengths"])


def test_snapshot_torn_payload_falls_back_to_older(tmp_path):
    """The manifest is the commit point: a snapshot whose payload is torn
    (crash mid-write window) is skipped and the next-older one loads."""
    store = snapshot.SnapshotStore(tmp_path, every=4)
    store.save(step=4, arrays=_arrays_from_seed(0), meta={}, journal_seq=0)
    store.save(step=8, arrays=_arrays_from_seed(1), meta={}, journal_seq=5)
    (tmp_path / "snap-00000008.npz").write_bytes(b"torn!")
    manifest, loaded = snapshot.latest_snapshot(tmp_path)
    assert manifest["step"] == 4
    np.testing.assert_array_equal(loaded["kv"], _arrays_from_seed(0)["kv"])


def test_snapshot_prune_keeps_referenced_payloads(tmp_path):
    """Pruning drops old manifests but keeps any payload file a surviving
    (incremental) manifest still references."""
    store = snapshot.SnapshotStore(tmp_path, every=4, keep=2)
    arrays = _arrays_from_seed(0)
    for step in (4, 8, 12, 16):
        store.save(step=step, arrays=arrays, meta={}, journal_seq=step)
    manifests = sorted(p.name for p in tmp_path.glob("snap-*.json"))
    assert manifests == ["snap-00000012.json", "snap-00000016.json"]
    # every leaf was unchanged: all manifests reference the FIRST payload
    assert (tmp_path / "snap-00000004.npz").exists()
    _, loaded = snapshot.latest_snapshot(tmp_path)
    np.testing.assert_array_equal(loaded["kv"], arrays["kv"])


def test_lifecycle_state_roundtrip(tmp_path):
    """lifecycle_state -> restore_lifecycle preserves every request field,
    the queue order, and the event counters."""
    cfg = _cfg()
    lc = Lifecycle(max_retries=3, clock=lambda: 2.5)
    submit_all(lc, _requests(cfg, [(4, 6), (5, 6), (3, 6)]))
    req = lc.requests[0]
    lc.transition(req, State.PREFILLING, 0)
    req.tokens.extend([7, 8])
    lc.record_first_token(req)
    lc.transition(req, State.DECODING, 0)
    lc2 = snapshot.restore_lifecycle(snapshot.lifecycle_state(lc))
    assert sorted(lc2.requests) == sorted(lc.requests)
    assert [r.rid for r in lc2._queue] == [r.rid for r in lc._queue]
    for rid, r in lc.requests.items():
        r2 = lc2.requests[rid]
        assert (r2.state, r2.retries, r2.tokens, r2.gen_len) == \
            (r.state, r.retries, r.tokens, r.gen_len)
        np.testing.assert_array_equal(r2.prompt, r.prompt)
        assert r2.history == r.history


# ---------------------------------------------------------------------------
# server state: export/restore + deterministic re-prefill
# ---------------------------------------------------------------------------

def _decode_tokens(server, slot, steps, start=0):
    toks = []
    for step in range(start, start + steps):
        nxt, done, bad = server.decode_step(step)
        assert not bad
        toks.append(int(nxt[slot, 0]))
    return toks


def test_restore_state_decode_step_matches_bitwise():
    """A server restored from export_state must produce the exact same
    next decode step as the original — the snapshot-resume acceptance
    criterion at the single-step level."""
    cfg = _cfg()
    reqs = _requests(cfg, [(5, 10), (4, 10)])
    a = Server(cfg, 2, MAX_LEN, autotune_kernels=False)
    for slot, (rid, prompt, gen) in enumerate(reqs):
        a.prefill(slot, rid, prompt, gen)
    _decode_tokens(a, 0, 3)

    b = Server(cfg, 2, MAX_LEN, autotune_kernels=False)
    b.restore_state(a.export_state())
    nxt_a, done_a, _ = a.decode_step(3)
    nxt_b, done_b, _ = b.decode_step(3)
    np.testing.assert_array_equal(np.asarray(nxt_a), np.asarray(nxt_b))
    assert list(done_a) == list(done_b)


def test_restore_slot_reprefill_is_deterministic():
    """Re-prefilling prompt ++ tokens[:-1] must re-predict tokens[-1]
    (teacher-forcing determinism) and leave the slot continuing exactly
    where the crashed run stopped."""
    cfg = _cfg()
    [(rid, prompt, gen)] = _requests(cfg, [(5, 12)])
    a = Server(cfg, 2, MAX_LEN, autotune_kernels=False)
    a.prefill(0, rid, prompt, gen)
    tokens = [int(a.last_tok[0, 0])]
    tokens += _decode_tokens(a, 0, 4)

    b = Server(cfg, 2, MAX_LEN, autotune_kernels=False)
    b.restore_slot(0, rid, prompt, tokens, gen)
    assert int(b.slot_len[0]) == len(tokens) - 1
    nxt_a, _, _ = a.decode_step(4)
    nxt_b, _, _ = b.decode_step(4)
    assert int(nxt_b[0, 0]) == int(nxt_a[0, 0])


def test_restore_slot_rejects_diverged_journal():
    """A journaled continuation the model would NOT have produced means
    params/config drift or corruption: refuse to serve it."""
    cfg = _cfg()
    [(rid, prompt, gen)] = _requests(cfg, [(5, 12)])
    a = Server(cfg, 2, MAX_LEN, autotune_kernels=False)
    a.prefill(0, rid, prompt, gen)
    tokens = [int(a.last_tok[0, 0])] + _decode_tokens(a, 0, 3)
    tampered = tokens[:-1] + [(tokens[-1] + 1) % cfg.vocab_size]
    b = Server(cfg, 2, MAX_LEN, autotune_kernels=False)
    with pytest.raises(RuntimeError, match="deterministic recovery"):
        b.restore_slot(0, rid, prompt, tampered, gen)


# ---------------------------------------------------------------------------
# serve loop: write-ahead journaling, snapshot cadence, crash propagation
# ---------------------------------------------------------------------------

def test_serve_loop_journal_replay_matches_lifecycle(tmp_path):
    """After a clean drain, folding the journal reproduces every
    request's final state and exact token list — the journal really is
    the authoritative record."""
    cfg = _cfg()
    journal = journal_mod.Journal(tmp_path / "j.jsonl", durable=False)
    lc = Lifecycle(clock=lambda: 0.0, journal=journal)
    submit_all(lc, _requests(cfg, [(5, 8), (4, 8), (6, 8)]))
    server = Server(cfg, 2, MAX_LEN, autotune_kernels=False)
    snaps = snapshot.SnapshotStore(tmp_path / "snaps", every=4)
    stats = serve_loop(server, lc, journal=journal, snapshots=snaps)
    journal.close()
    assert stats["snapshots_saved"] >= 1
    folded = journal_mod.replay(journal_mod.read_journal(tmp_path / "j.jsonl"))
    for rid, req in lc.requests.items():
        assert folded[rid]["state"] == req.state.value
        assert folded[rid]["tokens"] == list(req.tokens)
        assert len(req.tokens) == req.gen_len + 1


def test_crash_fault_propagates_out_of_serve_loop(tmp_path):
    """CrashFault is the one fault the loop must NOT absorb: it kills the
    process (exit 17 at the CLI) with the journal left on disk."""
    cfg = _cfg()
    plan = faults.FaultPlan.crash(0, step=5)
    injector = faults.FaultInjector(plan, sleep=lambda s: None)
    journal = journal_mod.Journal(tmp_path / "j.jsonl", durable=False)
    lc = Lifecycle(clock=lambda: 0.0, journal=journal)
    submit_all(lc, _requests(cfg, [(5, 10), (4, 10)]))
    server = Server(cfg, 2, MAX_LEN, autotune_kernels=False,
                    injector=injector)
    with pytest.raises(faults.CrashFault):
        serve_loop(server, lc, journal=journal)
    journal.close()
    records = journal_mod.read_journal(tmp_path / "j.jsonl")
    assert any(r["kind"] == "token" for r in records)
    assert CRASH_EXIT == 17


def test_crash_plan_is_seed_deterministic():
    p1 = faults.FaultPlan.crash(3)
    p2 = faults.FaultPlan.crash(3)
    assert p1.record() == p2.record()
    assert [e.kind for e in p1.events] == ["crash"]
    assert faults.FaultPlan.crash(4).record() != p1.record()


# ---------------------------------------------------------------------------
# end-to-end: crash + resume token-for-token vs uninterrupted
# ---------------------------------------------------------------------------

def _run_serve(argv):
    import contextlib
    import io

    from repro.launch import serve

    buf = io.StringIO()
    with contextlib.redirect_stdout(buf):
        rc = serve.main(argv)
    return rc, buf.getvalue()


def _folded_tokens(state_dir):
    reqs = journal_mod.replay(
        journal_mod.read_journal(os.path.join(state_dir, "journal.jsonl")))
    return {rid: r["tokens"] for rid, r in reqs.items()}, reqs


def test_crash_resume_token_for_token(tmp_path):
    """The recovery acceptance criterion: crash a serve mid-decode (exit
    17), `--resume` it, and the combined journal must hold exactly the
    token streams an uninterrupted run produces — with the replay bounded
    by the snapshot interval."""
    from repro.launch import serve

    sd_crash = str(tmp_path / "crashed")
    sd_clean = str(tmp_path / "clean")
    base = ["--arch", "qwen3_14b", "--smoke", "--requests", "4",
            "--prompt-len", "8", "--gen", "8", "--snapshot-every", "3"]

    rc, out = _run_serve(base + ["--state-dir", sd_crash,
                                 "--crash", "--crash-step", "5"])
    assert rc == serve.CRASH_EXIT
    assert any("\"crash\"" in ln for ln in out.splitlines())
    assert not any("tokens_generated" in ln for ln in out.splitlines())

    rc, out = _run_serve(["--resume", "--state-dir", sd_crash])
    assert rc == 0
    summary = json.loads([ln for ln in out.splitlines()
                          if "tokens_generated" in ln][-1])
    rec = summary["recovery"]
    assert rec["resumed"] is True
    assert 1 <= rec["replayed_steps"] <= 3      # bounded by --snapshot-every
    assert summary["outcomes"]["failed"] == 0

    rc, _ = _run_serve(base + ["--state-dir", sd_clean])
    assert rc == 0

    crashed, creqs = _folded_tokens(sd_crash)
    clean, _ = _folded_tokens(sd_clean)
    assert crashed == clean                     # token-for-token identical
    assert all(r["state"] == "completed" for r in creqs.values())
    assert all(len(t) == 8 + 1 for t in crashed.values())


def test_paged_crash_resume_token_for_token(tmp_path):
    """Crash recovery on the paged KV cache: the snapshot carries the
    page table, `--resume` re-adopts the allocator from it (canonical
    min-heap order makes the free list a pure function of the table) and
    re-pledges in-flight footprints — and the combined journal still
    matches an uninterrupted paged run token-for-token, which itself
    matches a contiguous run."""
    from repro.launch import serve

    paged = ["--paged", "--page-size", "4", "--sched", "spf"]
    base = ["--arch", "qwen3_14b", "--smoke", "--requests", "4",
            "--prompt-len", "8", "--gen", "8", "--snapshot-every", "3"]

    sd_crash = str(tmp_path / "crashed")
    rc, out = _run_serve(base + paged + ["--state-dir", sd_crash,
                                         "--crash", "--crash-step", "5"])
    assert rc == serve.CRASH_EXIT
    assert any('"paging"' in ln for ln in out.splitlines())

    rc, out = _run_serve(["--resume", "--state-dir", sd_crash])
    assert rc == 0
    summary = json.loads([ln for ln in out.splitlines()
                          if "tokens_generated" in ln][-1])
    assert summary["recovery"]["resumed"] is True
    assert 1 <= summary["recovery"]["replayed_steps"] <= 3
    assert summary["outcomes"]["failed"] == 0
    # the resumed run kept serving on the paged pool, leak-free
    assert summary["kv"]["kv_ooms"] == 0
    assert summary["sched"]["policy"] == "spf"

    sd_clean = str(tmp_path / "clean")
    rc, _ = _run_serve(base + paged + ["--state-dir", sd_clean])
    assert rc == 0
    sd_cont = str(tmp_path / "contiguous")
    rc, _ = _run_serve(base + ["--state-dir", sd_cont])
    assert rc == 0

    crashed, creqs = _folded_tokens(sd_crash)
    clean, _ = _folded_tokens(sd_clean)
    cont, _ = _folded_tokens(sd_cont)
    assert crashed == clean                     # crash+resume is invisible
    assert clean == cont                        # and paging never moves a token
    assert all(r["state"] == "completed" for r in creqs.values())


# ---------------------------------------------------------------------------
# atomic writes: the durable artifacts survive a kill mid-save
# ---------------------------------------------------------------------------

def test_atomic_write_failure_preserves_old_file(tmp_path):
    """A failed write (serialization error here; a crash in real life)
    leaves the previous committed file intact and no temp litter."""
    path = tmp_path / "report.json"
    ioutil.atomic_write_json(path, {"good": 1})
    with pytest.raises(TypeError):
        ioutil.atomic_write_json(path, {"bad": object()})
    assert json.loads(path.read_text()) == {"good": 1}
    assert list(tmp_path.glob("*.tmp")) == []


def test_atomic_write_crash_window_preserves_old_file(tmp_path,
                                                      monkeypatch):
    """Die at the worst instant — payload written, rename not yet done —
    and the old file must survive with the orphan cleaned up."""
    path = tmp_path / "cache.json"
    ioutil.atomic_write_json(path, {"v": 1})
    monkeypatch.setattr(ioutil.os, "replace",
                        lambda *a: (_ for _ in ()).throw(OSError("kill")))
    with pytest.raises(OSError):
        ioutil.atomic_write_json(path, {"v": 2})
    assert json.loads(path.read_text()) == {"v": 1}
    assert list(tmp_path.glob("*.tmp")) == []


def test_tune_cache_put_survives_unwritable_disk(tmp_path, monkeypatch):
    """TuneCache.put through the atomic guard: an OSError mid-save keeps
    the previous cache on disk AND the new entry served from memory —
    the compute path must never die on an unwritable cache."""
    path = tmp_path / "autotune.json"
    cache = TuneCache(path)
    cache.put("k1", {"knobs": {"tile": 8}, "detail": {}})
    before = path.read_text()
    monkeypatch.setattr(ioutil.os, "replace",
                        lambda *a: (_ for _ in ()).throw(OSError("full")))
    cache.put("k2", {"knobs": {"tile": 16}, "detail": {}})   # must not raise
    assert path.read_text() == before
    assert cache.get("k2")["knobs"]["tile"] == 16
