"""Paper claim (§V-B): round-robin row assignment balances nnz to ~1/p."""

import numpy as np
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # minimal env: property tests skip, rest run
    from _hypothesis_stub import given, settings, st

from repro.core import loadbalance as lb


@settings(max_examples=50, deadline=None)
@given(
    rows=st.integers(200, 4000),
    p=st.sampled_from([2, 4, 8, 16]),
    dist=st.sampled_from(["poisson", "uniform", "powerlaw"]),
    seed=st.integers(0, 2**31 - 1),
)
def test_round_robin_balances_random_matrices(rows, p, dist, seed):
    rng = np.random.default_rng(seed)
    if dist == "poisson":
        nnz = rng.poisson(20, rows) + 1
    elif dist == "uniform":
        nnz = rng.integers(1, 100, rows)
    else:
        nnz = np.clip(rng.pareto(1.5, rows) * 5, 1, 2000).astype(int)
    indptr = np.concatenate([[0], np.cumsum(nnz)])
    _, stats = lb.nnz_balanced_row_order(indptr, p)
    # Paper's Table II-style claim: each worker near 1/p of the total.
    # Random row order => round-robin is a random p-way split.  Power-law
    # weights have heavy tails, so bound against the single heaviest row
    # (one worker must hold it) plus sampling noise.
    heaviest = nnz.max() / nnz.sum()
    bound = max((1 / p) * (1 + 6 / np.sqrt(rows / p)) + 0.05,
                1 / p + heaviest + 0.02)
    assert stats.max_fraction < bound


@settings(max_examples=30, deadline=None)
@given(rows=st.integers(64, 2000), p=st.sampled_from([2, 4, 8]),
       seed=st.integers(0, 2**31 - 1))
def test_lpt_no_worse_than_round_robin(rows, p, seed):
    rng = np.random.default_rng(seed)
    nnz = np.clip(rng.pareto(1.2, rows) * 10, 1, 5000).astype(int)
    indptr = np.concatenate([[0], np.cumsum(nnz)])
    _, rr = lb.nnz_balanced_row_order(indptr, p)
    _, greedy = lb.nnz_balanced_row_order(indptr, p, "lpt")
    assert greedy.imbalance <= rr.imbalance + 1e-9


def test_paper_table2_like_distribution():
    """LD_pilot87-like stats (M=2030, nnz/col in [1,96]): ~25% per core."""
    rng = np.random.default_rng(87)
    nnz = np.clip(rng.integers(1, 96, 2030), 1, None)
    indptr = np.concatenate([[0], np.cumsum(nnz)])
    _, stats = lb.nnz_balanced_row_order(indptr, 4)
    frac = stats.per_worker / stats.per_worker.sum()
    assert np.all(np.abs(frac - 0.25) < 0.02), frac


@given(t=st.integers(1, 10_000), e=st.sampled_from([8, 16, 64, 128]),
       k=st.integers(1, 8))
@settings(max_examples=50, deadline=None)
def test_expert_capacity_covers_uniform_routing(t, e, k):
    cap = lb.expert_capacity(t, e, k, capacity_factor=1.25)
    assert cap * e >= t * k          # total capacity >= total assignments
    assert cap % 8 == 0
